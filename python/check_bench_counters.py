#!/usr/bin/env python3
"""CI guard: deterministic MSM-counter regression check for zkdl bench JSONs.

Wall-clock numbers in a ``BENCH_*.json`` are machine-dependent and noisy, so
CI cannot gate on them. The MSM counters are different: for a fixed grid
config (width/batch/data_rows/seed) the number of MSM invocations, the points
fed to them, and the accumulator flush/equation counts are structural
properties of the protocol — byte-identical across machines. A drift in any
of them means the proving system itself changed shape, which must be a
conscious decision (re-record the baseline), never an accident.

Usage:
    python3 python/check_bench_counters.py NEW.json [BASELINE.json]

BASELINE defaults to ``BENCH_counters_quick.json`` in the repo root. If the
baseline file does not exist the check is a no-op bootstrap: it prints the
command that records one and exits 0, so the guard can be committed before
the first recorded baseline exists.

Exit codes: 0 ok / baseline missing (bootstrap), 1 counter drift or config
mismatch, 2 usage or unreadable input.
"""

import json
import os
import sys

SCHEMA = "zkdl/bench/v2"

# Structural (machine-independent) per-case fields, checked for exact
# equality. prove_s / verify_s / wall_s are deliberately absent.
COUNTER_KEYS = (
    "prove_calls",
    "prove_points",
    "verify_calls",
    "verify_points",
    "verify_flushes",
    "verify_equations",
)
CONFIG_KEYS = ("width", "batch", "data_rows", "seed")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench_counters: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def case_key(case):
    # v2 cells are keyed on thread count too: the same (variant, T, depth)
    # may be measured at several ZKDL_THREADS settings, and the counters
    # must match the baseline cell measured at the *same* setting (they are
    # thread-count-independent by design — a mismatch across thread counts
    # would itself be a determinism bug, caught by tests/parallel_determinism).
    return (case["variant"], case["steps"], case["depth"], case.get("threads", 0))


def compare(new, old, baseline_path):
    errors = []
    for doc, name in ((new, "new report"), (old, "baseline")):
        if doc.get("schema") != SCHEMA:
            errors.append(f"{name}: schema {doc.get('schema')!r}, expected {SCHEMA!r}")
    if errors:
        return errors

    new_cfg = {k: new.get("config", {}).get(k) for k in CONFIG_KEYS}
    old_cfg = {k: old.get("config", {}).get(k) for k in CONFIG_KEYS}
    if new_cfg != old_cfg:
        return [
            "grid config mismatch — counters are only comparable for identical "
            f"configs: new {new_cfg} vs baseline {old_cfg}"
        ]

    old_cases = {case_key(c): c for c in old.get("cases", [])}
    compared = 0
    for c in new.get("cases", []):
        key = case_key(c)
        base = old_cases.pop(key, None)
        label = "variant={} T={} depth={} threads={}".format(*key)
        if base is None:
            errors.append(f"{label}: cell missing from baseline")
            continue
        if (c.get("skipped") is None) != (base.get("skipped") is None):
            errors.append(
                f"{label}: skip status changed "
                f"(new={c.get('skipped')!r}, baseline={base.get('skipped')!r})"
            )
            continue
        if c.get("skipped") is not None:
            continue
        for field in COUNTER_KEYS:
            nv = c.get("msm", {}).get(field)
            ov = base.get("msm", {}).get(field)
            if nv != ov:
                errors.append(f"{label}: msm.{field} {ov} -> {nv}")
        if c.get("proof_bytes") != base.get("proof_bytes"):
            errors.append(
                f"{label}: proof_bytes {base.get('proof_bytes')} -> {c.get('proof_bytes')}"
            )
        compared += 1
    for key in old_cases:
        errors.append(
            "variant={} T={} depth={} threads={}: cell missing from new report".format(*key)
        )

    if errors:
        errors.append(
            "counter drift means the protocol changed shape; if intentional, "
            f"re-record the baseline: zkdl bench --quick --data-n 32 --out {baseline_path}"
        )
    else:
        print(f"bench counters ok: {compared} measured cell(s) match {baseline_path}")
    return errors


def self_test():
    base = {
        "schema": SCHEMA,
        "config": {"width": 16, "batch": 8, "data_rows": 32, "seed": 2662},
        "cases": [
            {
                "variant": "plain",
                "steps": 1,
                "depth": 2,
                "threads": 1,
                "skipped": None,
                "proof_bytes": 4096,
                "msm": {
                    "prove_calls": 10,
                    "prove_points": 1000,
                    "verify_calls": 1,
                    "verify_points": 500,
                    "verify_flushes": 1,
                    "verify_equations": 7,
                },
            },
            {
                "variant": "plain",
                "steps": 1,
                "depth": 2,
                "threads": 2,
                "skipped": None,
                "proof_bytes": 4096,
                "msm": {
                    "prove_calls": 10,
                    "prove_points": 1000,
                    "verify_calls": 1,
                    "verify_points": 500,
                    "verify_flushes": 1,
                    "verify_equations": 7,
                },
            },
            {
                "variant": "chained",
                "steps": 1,
                "depth": 2,
                "threads": 1,
                "skipped": "chained trace needs T >= 2",
                "proof_bytes": 0,
                "msm": {k: 0 for k in COUNTER_KEYS},
            },
        ],
    }
    assert compare(base, base, "b.json") == []

    import copy

    drift = copy.deepcopy(base)
    drift["cases"][0]["msm"]["verify_points"] = 501
    errs = compare(drift, base, "b.json")
    assert any("verify_points 500 -> 501" in e for e in errs), errs

    resized = copy.deepcopy(base)
    resized["cases"][0]["proof_bytes"] = 4128
    errs = compare(resized, base, "b.json")
    assert any("proof_bytes 4096 -> 4128" in e for e in errs), errs

    unskipped = copy.deepcopy(base)
    unskipped["cases"][2]["skipped"] = None
    errs = compare(unskipped, base, "b.json")
    assert any("skip status changed" in e for e in errs), errs

    missing = copy.deepcopy(base)
    missing["cases"].pop(0)
    errs = compare(missing, base, "b.json")
    assert any("missing from new report" in e for e in errs), errs

    other_cfg = copy.deepcopy(base)
    other_cfg["config"]["width"] = 32
    errs = compare(other_cfg, base, "b.json")
    assert any("config mismatch" in e for e in errs), errs

    bad_schema = copy.deepcopy(base)
    bad_schema["schema"] = "zkdl/other"
    errs = compare(bad_schema, base, "b.json")
    assert any("schema" in e for e in errs), errs

    # thread count is part of the cell key: a threads=4 cell does not match
    # the baseline's threads=2 cell, and both ends report the orphan
    rethreaded = copy.deepcopy(base)
    rethreaded["cases"][1]["threads"] = 4
    errs = compare(rethreaded, base, "b.json")
    assert any("threads=4: cell missing from baseline" in e for e in errs), errs
    assert any("threads=2: cell missing from new report" in e for e in errs), errs

    # counter drift confined to one thread count is still pinned to it
    drift_t2 = copy.deepcopy(base)
    drift_t2["cases"][1]["msm"]["prove_calls"] = 11
    errs = compare(drift_t2, base, "b.json")
    assert any("threads=2: msm.prove_calls 10 -> 11" in e for e in errs), errs

    print("check_bench_counters self-test ok")


def main(argv):
    if len(argv) >= 2 and argv[1] == "--self-test":
        self_test()
        return 0
    if len(argv) < 2 or len(argv) > 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    new_path = argv[1]
    baseline_path = argv[2] if len(argv) == 3 else "BENCH_counters_quick.json"
    if not os.path.exists(baseline_path):
        print(
            f"check_bench_counters: no baseline at {baseline_path} — skipping "
            "(bootstrap). Record one on a trusted run with:\n"
            f"    zkdl bench --quick --data-n 32 --out {baseline_path}\n"
            "and commit it to enable the regression gate."
        )
        return 0
    errors = compare(load(new_path), load(baseline_path), baseline_path)
    for e in errors:
        print(f"check_bench_counters: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
