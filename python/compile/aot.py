"""AOT lowering: jax train_step → HLO **text** artifacts for the rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        --configs "2,64,16;3,512,32"       # depth,width,batch triples

Each config produces artifacts/model_L{depth}_d{width}_b{batch}.hlo.txt
plus a manifest line. `make artifacts` drives this.
"""

import argparse
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import train_step  # noqa: E402

DEFAULT_CONFIGS = "2,8,4;2,64,16;3,64,16"
R_BITS = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(depth: int, width: int, batch: int, r_bits: int = R_BITS) -> str:
    x = jax.ShapeDtypeStruct((batch, width), jnp.int64)
    y = jax.ShapeDtypeStruct((batch, width), jnp.int64)
    w = jax.ShapeDtypeStruct((depth, width, width), jnp.int64)
    fn = lambda x, y, w: train_step(x, y, w, depth=depth, r_bits=r_bits)
    lowered = jax.jit(fn).lower(x, y, w)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=DEFAULT_CONFIGS,
                    help="semicolon-separated depth,width,batch triples")
    ap.add_argument("--r-bits", type=int, default=R_BITS)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for spec in args.configs.split(";"):
        spec = spec.strip()
        if not spec:
            continue
        depth, width, batch = (int(v) for v in spec.split(","))
        name = f"model_L{depth}_d{width}_b{batch}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_config(depth, width, batch, args.r_bits)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{depth},{width},{batch},{args.r_bits},{name}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} configs")


if __name__ == "__main__":
    main()
