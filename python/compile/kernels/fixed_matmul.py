"""L1 — Pallas kernel: tiled fixed-point integer matmul.

The witness-generation hot spot of zkDL's training step is the quantized
matmul A (B×k, scale 2^R) · W (k×n, scale 2^R) → Z (scale 2^{2R}).
The kernel tiles the product over a (rows, cols, k) grid so each VMEM-
resident block is bounded (BLOCK² int64 = 128·128·8 B = 128 KiB per
operand), accumulating partial products into the output block across the
k-dimension of the grid — the HBM↔VMEM schedule a TPU would use to feed
the MXU. On this image Pallas must run with ``interpret=True`` (the CPU
PJRT plugin cannot execute Mosaic custom-calls), so MXU numbers are
estimates recorded in DESIGN.md §Hardware-Adaptation, but the lowered HLO
is exactly what the rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.matmul(
        a_ref[...], b_ref[...], preferred_element_type=jnp.int64
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_pallas(a, b, interpret=True):
    """Tiled integer matmul C = A·B via pallas_call.

    Dimensions need not be multiples of BLOCK; Pallas pads partial blocks
    with zeros, which is exact for integer accumulation.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, "inner dimensions must match"
    bm, bk, bn = min(BLOCK, m), min(BLOCK, k), min(BLOCK, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=interpret,
    )(a, b)


def fixed_matmul(a, b, r_bits: int, interpret=True):
    """Fixed-point matmul with fused rescale: round(A·B / 2^r_bits)."""
    z = matmul_pallas(a, b, interpret=interpret)
    if r_bits == 0:
        return z
    half = jnp.int64(1) << (r_bits - 1)
    return jnp.floor_divide(z + half, jnp.int64(1) << r_bits)
