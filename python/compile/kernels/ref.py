"""Pure-jnp oracle for the Pallas fixed-point kernels.

This is the correctness reference (the L1 kernel's contract): integer
matmul with round-to-nearest rescale by 2**r_bits, matching the rust
native witness generator's ``matmul_i64`` + ``round_div_pow2`` bit-exactly.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain integer matmul (int64 accumulation)."""
    return jnp.matmul(a, b, preferred_element_type=jnp.int64)


def round_div_pow2_ref(v, r_bits: int):
    """Round-to-nearest division by 2**r_bits, ties toward +inf.

    Matches rust ``round_div_pow2``: (v + 2**(r-1)).div_euclid(2**r);
    jnp.floor_divide is Euclidean for positive divisors.
    """
    if r_bits == 0:
        return v
    half = jnp.int64(1) << (r_bits - 1)
    return jnp.floor_divide(v + half, jnp.int64(1) << r_bits)


def fixed_matmul_ref(a, b, r_bits: int):
    """Fixed-point matmul: rescaled product — the L1 kernel's contract."""
    return round_div_pow2_ref(matmul_ref(a, b), r_bits)
