"""L2 — the quantized FCNN training step in JAX (build-time only).

Computes one fixed-point SGD forward/backward pass of the L-layer ReLU
network of paper Example 4.5, emitting exactly the tensors the rust prover
needs for witnessing relations (30)–(35): Z per layer, G_A per inner
layer, G_Z per layer and G_W per layer. The zkReLU auxiliary decomposition
(Z″, B_{Q−1}, R_Z, …) is elementwise and re-derived in rust (it must hold
bit-exactly over these outputs — `witness::validate` enforces that).

All arithmetic is int64 (jax_enable_x64); matmuls go through the L1 Pallas
kernel so they lower into the same HLO the rust PJRT runtime executes.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.fixed_matmul import matmul_pallas  # noqa: E402
from .kernels.ref import round_div_pow2_ref  # noqa: E402


def train_step(x, y, w_stack, *, depth: int, r_bits: int, use_pallas: bool = True):
    """One quantized training step.

    Args:
      x: (B, d) int64 inputs at scale 2^R.
      y: (B, d) int64 targets at scale 2^R.
      w_stack: (L, d, d) int64 weights at scale 2^R.
      depth: number of layers L (static).
      r_bits: fractional bits R (static).
      use_pallas: route matmuls through the Pallas kernel (interpret mode).

    Returns a tuple of stacked int64 tensors:
      z_stack  (L, B, d) — pre-activations at scale 2^{2R}
      ga_stack (L, B, d) — activation gradients at scale 2^{2R}
                           (slot L−1 is zeros: the last layer has no G_A)
      gz_stack (L, B, d) — pre-activation gradients at scale 2^R
      gw_stack (L, d, d) — weight gradients at scale 2^{2R}
    """
    mm = matmul_pallas if use_pallas else (
        lambda a, b: jnp.matmul(a, b, preferred_element_type=jnp.int64)
    )

    # ---- forward ----
    zs, acts, signs = [], [], []
    a_prev = x
    for l in range(depth):
        z = mm(a_prev, w_stack[l])
        zs.append(z)
        z_prime = round_div_pow2_ref(z, r_bits)
        sign = (z_prime < 0).astype(jnp.int64)
        signs.append(sign)
        if l + 1 < depth:
            a_prev = (1 - sign) * z_prime  # ReLU on the rescaled value
            acts.append(a_prev)
        else:
            zs_last_prime = z_prime

    # ---- backward ----
    gzs = [None] * depth
    gas = [jnp.zeros_like(x)] * depth
    gzs[depth - 1] = zs_last_prime - y  # (32)
    for l in range(depth - 2, -1, -1):
        g_a = mm(gzs[l + 1], w_stack[l + 1].T)  # (33)
        gas[l] = g_a
        g_a_prime = round_div_pow2_ref(g_a, r_bits)
        gzs[l] = (1 - signs[l]) * g_a_prime  # (4)

    gws = []
    for l in range(depth):
        a_in = x if l == 0 else acts[l - 1]
        gws.append(mm(gzs[l].T, a_in))  # (34)

    return (
        jnp.stack(zs),
        jnp.stack(gas),
        jnp.stack(gzs),
        jnp.stack(gws),
    )
