"""L1 correctness: Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes and value magnitudes; the kernel must agree
bit-exactly (integer arithmetic — no tolerance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.fixed_matmul import fixed_matmul, matmul_pallas  # noqa: E402
from compile.kernels.ref import fixed_matmul_ref, matmul_ref, round_div_pow2_ref  # noqa: E402


def rand_ints(rng, shape, lo=-(1 << 20), hi=1 << 20):
    return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=np.int64))


@pytest.mark.parametrize("m,k,n", [(4, 4, 4), (8, 16, 8), (16, 8, 32), (128, 128, 128), (130, 70, 65)])
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = rand_ints(rng, (m, k))
    b = rand_ints(rng, (k, n))
    got = matmul_pallas(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    r_bits=st.sampled_from([0, 1, 8, 16]),
)
def test_fixed_matmul_hypothesis(m, k, n, seed, r_bits):
    rng = np.random.default_rng(seed)
    a = rand_ints(rng, (m, k), -(1 << 16), 1 << 16)
    b = rand_ints(rng, (k, n), -(1 << 16), 1 << 16)
    got = fixed_matmul(a, b, r_bits)
    want = fixed_matmul_ref(a, b, r_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=50, deadline=None)
@given(v=st.integers(-(1 << 40), 1 << 40), r_bits=st.sampled_from([1, 4, 16]))
def test_round_div_matches_rust_semantics(v, r_bits):
    # remainder in [-2^(r-1), 2^(r-1)) — the zkReLU range requirement
    q = int(round_div_pow2_ref(jnp.int64(v), r_bits))
    rem = v - (q << r_bits)
    assert -(1 << (r_bits - 1)) <= rem < (1 << (r_bits - 1))


def test_negative_rounding_ties():
    # ties round toward +inf, matching rust round_div_pow2
    assert int(round_div_pow2_ref(jnp.int64(3), 1)) == 2
    assert int(round_div_pow2_ref(jnp.int64(-3), 1)) == -1
    assert int(round_div_pow2_ref(jnp.int64(-4), 2)) == -1
