"""L2 correctness: train_step shape/consistency checks and the
Pallas-vs-jnp route agreement at the whole-step level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile.model import train_step  # noqa: E402
from compile.kernels.ref import round_div_pow2_ref  # noqa: E402

R = 16


def make_inputs(depth, width, batch, seed=0):
    rng = np.random.default_rng(seed)
    scale = 1 << R
    x = jnp.asarray(rng.integers(-scale, scale, size=(batch, width), dtype=np.int64))
    y = jnp.zeros((batch, width), dtype=jnp.int64).at[:, 0].set(scale)
    bound = max(1, int((2.0 / width) ** 0.5 * scale))
    w = jnp.asarray(
        rng.integers(-bound, bound + 1, size=(depth, width, width), dtype=np.int64)
    )
    return x, y, w


@pytest.mark.parametrize("depth,width,batch", [(1, 8, 4), (2, 8, 4), (3, 16, 8)])
def test_shapes(depth, width, batch):
    x, y, w = make_inputs(depth, width, batch)
    z, ga, gz, gw = train_step(x, y, w, depth=depth, r_bits=R)
    assert z.shape == (depth, batch, width)
    assert ga.shape == (depth, batch, width)
    assert gz.shape == (depth, batch, width)
    assert gw.shape == (depth, width, width)
    # last layer has no activation gradient
    np.testing.assert_array_equal(np.asarray(ga[depth - 1]), 0)


@pytest.mark.parametrize("depth,width,batch", [(2, 8, 4), (3, 16, 8)])
def test_pallas_and_jnp_routes_agree(depth, width, batch):
    x, y, w = make_inputs(depth, width, batch, seed=7)
    outs_pallas = train_step(x, y, w, depth=depth, r_bits=R, use_pallas=True)
    outs_jnp = train_step(x, y, w, depth=depth, r_bits=R, use_pallas=False)
    for p, j in zip(outs_pallas, outs_jnp):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(j))


def test_relations_hold():
    """Spot-check the paper's relations (30), (32), (34) on the outputs."""
    depth, width, batch = 2, 8, 4
    x, y, w = make_inputs(depth, width, batch, seed=3)
    z, ga, gz, gw = train_step(x, y, w, depth=depth, r_bits=R)
    # (30) layer 0: Z^0 = X·W^0
    np.testing.assert_array_equal(
        np.asarray(z[0]), np.asarray(jnp.matmul(x, w[0]))
    )
    # (32): G_Z^{L−1} = Z^{L−1}′ − Y
    z_prime_last = round_div_pow2_ref(z[depth - 1], R)
    np.testing.assert_array_equal(np.asarray(gz[depth - 1]), np.asarray(z_prime_last - y))
    # (34) layer 0: G_W^0 = G_Z^{0ᵀ}·X
    np.testing.assert_array_equal(
        np.asarray(gw[0]), np.asarray(jnp.matmul(gz[0].T, x))
    )


def test_aot_lowering_smoke():
    """The config lowers to HLO text parseable by the rust loader."""
    from compile.aot import lower_config

    text = lower_config(2, 8, 4)
    assert "HloModule" in text
    assert len(text) > 1000
