"""Simulation of the zkTurbo MSM algorithms (rust/src/curve/msm.rs,
rust/src/curve/fixed.rs) over the real BN254 parameters.

The Rust implementations are mirrored step for step:

* batch-affine bucket accumulation — counting-sort points into buckets,
  then pairwise tree reduction where every sweep resolves all pair
  denominators with ONE batched inversion (Montgomery's trick), with the
  affine special cases (equal points -> doubling denominator 2y, inverse
  points -> the pair cancels to identity and is dropped);
* Pippenger over those bucket passes (running-sum combine + Horner);
* the FixedBaseTable decomposition: shifted copies 2^{jw}·P_i stored per
  window so a fixed-base MSM is ONE bucket pass over n·ceil(256/w) terms
  with w-bit digits and no doublings;
* 64-bit fragment windowing for msm_u64.

Run: python3 python/tests/test_msm_turbo_sim.py
"""

import random

# BN254 G1: y^2 = x^3 + 3 over F_p, scalar field of size R.
P = 21888242871839275222246405745257275088696311157297823662689037894645226208583
R = 21888242871839275222246405745257275088548364400416034343698204186575808495617
GEN = (1, 2)
INF = None  # identity


def add(p, q):
    """Reference affine addition (per-point inversion)."""
    if p is INF:
        return q
    if q is INF:
        return p
    x1, y1 = p
    x2, y2 = q
    if x1 == x2:
        if (y1 + y2) % P == 0:
            return INF
        lam = (3 * x1 * x1) * pow(2 * y1, P - 2, P) % P
    else:
        lam = (y2 - y1) * pow(x2 - x1, P - 2, P) % P
    x3 = (lam * lam - x1 - x2) % P
    y3 = (lam * (x1 - x3) - y1) % P
    return (x3, y3)


def neg(p):
    return INF if p is INF else (p[0], (-p[1]) % P)


def scalar_mul(p, k):
    acc = INF
    q = p
    while k:
        if k & 1:
            acc = add(acc, q)
        q = add(q, q)
        k >>= 1
    return acc


def naive_msm(points, scalars):
    acc = INF
    for p, s in zip(points, scalars):
        acc = add(acc, scalar_mul(p, s % R))
    return acc


def batch_invert(values):
    """Montgomery's trick, zeros skipped — mirrors field::batch_invert."""
    prods, acc = [], 1
    for v in values:
        prods.append(acc)
        if v % P != 0:
            acc = acc * v % P
    inv = pow(acc, P - 2, P)
    out = list(values)
    for i in reversed(range(len(values))):
        if values[i] % P != 0:
            out[i] = inv * prods[i] % P
            inv = inv * values[i] % P
    return out


def batch_affine_bucket_sums(num_buckets, entries):
    """entries: list of (bucket_index >= 1, affine point). Returns the list
    of per-bucket sums (index 0 <-> bucket 1), reduced via batched-inverse
    sweeps — the exact algorithm of msm.rs::bucket_sums_batch_affine."""
    buckets = [[] for _ in range(num_buckets)]
    for idx, pt in entries:
        assert 1 <= idx <= num_buckets
        if pt is not INF:
            buckets[idx - 1].append(pt)
    sweeps = 0
    while any(len(b) >= 2 for b in buckets):
        sweeps += 1
        # Collect one addition per adjacent pair in every bucket.
        pairs = []  # (bucket, slot, p, q) with q = None marking a cancel
        denoms = []
        for bi, b in enumerate(buckets):
            for k in range(0, len(b) - 1, 2):
                pp, qq = b[k], b[k + 1]
                if pp[0] == qq[0] and (pp[1] + qq[1]) % P == 0:
                    pairs.append((bi, k, None, None))  # P + (-P) = identity
                    denoms.append(0)  # skipped by batch_invert
                elif pp == qq:
                    pairs.append((bi, k, pp, qq))
                    denoms.append(2 * pp[1] % P)  # doubling: lam = 3x^2 / 2y
                else:
                    pairs.append((bi, k, pp, qq))
                    denoms.append((qq[0] - pp[0]) % P)
        inv = batch_invert(denoms)
        new_buckets = [[] for _ in range(num_buckets)]
        cursor = 0
        for bi, b in enumerate(buckets):
            npairs = len(b) // 2
            for _ in range(npairs):
                (pbi, k, pp, qq) = pairs[cursor]
                assert pbi == bi
                d = inv[cursor]
                cursor += 1
                if pp is None:
                    continue  # cancelled pair contributes identity
                x1, y1 = pp
                if pp == qq:
                    lam = 3 * x1 * x1 * d % P
                else:
                    lam = (qq[1] - y1) * d % P
                x3 = (lam * lam - x1 - qq[0]) % P
                y3 = (lam * (x1 - x3) - y1) % P
                new_buckets[bi].append((x3, y3))
            if len(b) % 2 == 1:
                new_buckets[bi].append(b[-1])
        buckets = new_buckets
    return [b[0] if b else INF for b in buckets], sweeps


def bucket_pass(num_buckets, entries):
    """Bucket sums -> running-sum combine: sum idx·bucket[idx]."""
    sums, _sweeps = batch_affine_bucket_sums(num_buckets, entries)
    running, acc = INF, INF
    for b in reversed(sums):
        running = add(running, b)
        acc = add(acc, running)
    return acc


def pippenger(points, scalars, w):
    """Variable-base MSM with batch-affine windows (msm.rs::msm)."""
    nwin = (256 + w - 1) // w
    window_sums = []
    for wi in range(nwin):
        shift = wi * w
        entries = []
        for p, s in zip(points, scalars):
            idx = (s >> shift) & ((1 << w) - 1)
            if idx and p is not INF:
                entries.append((idx, p))
        window_sums.append(bucket_pass((1 << w) - 1, entries))
    total = INF
    for ws in reversed(window_sums):
        for _ in range(w):
            total = add(total, total)
        total = add(total, ws)
    return total


def fixed_table(points, w, bits=256):
    """FixedBaseTable::build — shifted[j][i] = 2^{jw}·P_i."""
    nwin = (bits + w - 1) // w
    shifted = []
    cur = list(points)
    for j in range(nwin):
        shifted.append(list(cur))
        if j + 1 < nwin:
            for _ in range(w):
                cur = [add(p, p) for p in cur]
    return shifted


def fixed_msm(shifted, scalars, w):
    """FixedBaseTable::msm_range — one bucket pass, no doublings."""
    entries = []
    for j, row in enumerate(shifted):
        shift = j * w
        for i, s in enumerate(scalars):
            idx = (s >> shift) & ((1 << w) - 1)
            if idx:
                entries.append((idx, row[i]))
    return bucket_pass((1 << w) - 1, entries)


def msm_u64(points, scalars, w):
    """64-bit fragment windowing (msm.rs::msm_u64): ceil(64/w) windows."""
    nwin = (64 + w - 1) // w
    window_sums = []
    for wi in range(nwin):
        shift = wi * w
        entries = []
        for p, s in zip(points, scalars):
            idx = (s >> shift) & ((1 << w) - 1)
            if idx and p is not INF:
                entries.append((idx, p))
        window_sums.append(bucket_pass((1 << w) - 1, entries))
    total = INF
    for ws in reversed(window_sums):
        for _ in range(w):
            total = add(total, total)
        total = add(total, ws)
    return total


def random_point(rng):
    return scalar_mul(GEN, rng.randrange(1, R))


def main():
    rng = random.Random(0x7e57)

    # --- batch-affine bucket reduction edge cases ---
    p1 = random_point(rng)
    p2 = random_point(rng)
    # equal points in one bucket -> doubling path
    sums, _ = batch_affine_bucket_sums(3, [(1, p1), (1, p1)])
    assert sums[0] == add(p1, p1), "doubling case"
    # inverse points -> pair cancels to identity
    sums, _ = batch_affine_bucket_sums(3, [(2, p1), (2, neg(p1))])
    assert sums[1] is INF, "cancellation case"
    # odd leftovers + cancellation interleaved
    sums, _ = batch_affine_bucket_sums(3, [(3, p1), (3, neg(p1)), (3, p2)])
    assert sums[2] == p2, "cancel + leftover"
    # many duplicates (forces multiple sweeps incl. repeated doublings)
    sums, sweeps = batch_affine_bucket_sums(1, [(1, p1)] * 9)
    assert sums[0] == scalar_mul(p1, 9), "9 duplicates"
    assert sweeps == 4, f"ceil(log2(9)) sweeps, got {sweeps}"
    print("batch-affine edge cases ok")

    # --- Pippenger vs naive (mixed edge-case inputs) ---
    for n, w in [(5, 4), (17, 5), (33, 8)]:
        pts = [random_point(rng) for _ in range(n)]
        scs = [rng.randrange(R) for _ in range(n)]
        scs[0] = 0
        pts[1] = INF if n > 1 else pts[1]
        if n > 3:
            pts[3] = pts[2]          # duplicate base
            scs[3] = scs[2]          # same scalar -> same bucket every window
        assert pippenger(pts, scs, w) == naive_msm(pts, scs), f"msm n={n} w={w}"
    print("pippenger (batch-affine windows) matches naive")

    # --- fixed-base table across window sizes, incl. prefix slices ---
    n = 9
    pts = [random_point(rng) for _ in range(n)]
    scs = [rng.randrange(R) for _ in range(n)]
    scs[4] = 1
    scs[5] = R - 1  # max scalar exercises the top window
    want = naive_msm(pts, scs)
    for w in (4, 8, 13, 16):
        shifted = fixed_table(pts, w)
        assert fixed_msm(shifted, scs, w) == want, f"fixed w={w}"
        # prefix evaluation: table rows beyond len(scalars) unused
        k = 6
        wk = naive_msm(pts[:k], scs[:k])
        assert fixed_msm([row[:k] for row in shifted], scs[:k], w) == wk
    print("fixed-base table matches naive across window sizes")

    # --- 64-bit fragment windowing ---
    pts = [random_point(rng) for _ in range(12)]
    scs = [rng.randrange(1 << 64) for _ in range(12)]
    scs[0] = 0
    scs[1] = (1 << 64) - 1
    for w in (3, 5, 8):
        assert msm_u64(pts, scs, w) == naive_msm(pts, scs), f"u64 w={w}"
    print("64-bit fragment windowing matches naive")
    print("all msm-turbo simulations pass")


if __name__ == "__main__":
    main()
