#!/usr/bin/env python3
"""CI guard: structural validation of zkFlight observability artifacts.

Two artifact kinds, both produced by the ``zkdl`` CLI:

* the event journal (``--journal <path>``) — JSONL, one ``zkdl/events/v1``
  record per proof artifact. Checked: schema tag, required keys, strictly
  increasing ``seq``, non-decreasing ``ts_unix``, a known ``verb`` and
  ``outcome``, and the taxonomy invariant that ``failure_class`` is present
  iff the outcome is ``rejected``.
* the Perfetto/Chrome trace-event export (``--trace-out <path>``) — one JSON
  document with a ``traceEvents`` array. Checked: parseability, known phase
  tags, per-track (``tid``) stack discipline — every ``E`` matches the name
  of the innermost open ``B``, nothing left open at the end — non-decreasing
  timestamps per track, and a ``thread_name`` metadata event for every track
  that carries duration events.

Either check alone makes a loadable-but-wrong artifact (reordered events,
orphaned spans, a rejection without a class) fail CI instead of silently
rendering as a broken timeline.

Usage:
    python3 python/check_obs_artifacts.py --journal FLIGHT.jsonl
    python3 python/check_obs_artifacts.py --trace TRACE.json
    python3 python/check_obs_artifacts.py --journal A.jsonl --trace B.json

Exit codes: 0 ok, 1 validation failure, 2 usage or unreadable input.
"""

import json
import sys

EVENT_SCHEMA = "zkdl/events/v1"

VERBS = ("prove", "prove-trace", "verify-trace", "serve-verify", "serve-frame")
OUTCOMES = ("proved", "accepted", "rejected", "overloaded")
FAILURE_CLASSES = (
    "wire-decode",
    "version-unsupported",
    "shape",
    "transcript-binding",
    "sumcheck",
    "opening",
    "validity",
    "booleanity",
    "chain-relation",
    "provenance-selection",
    "root-mismatch",
    "msm-final-check",
)

# every record carries the full schema; optionals are null, never absent
JOURNAL_KEYS = (
    "schema",
    "seq",
    "ts_unix",
    "verb",
    "outcome",
    "duration_s",
    "wire_version",
    "artifact_bytes",
    "artifact_sha256",
    "rule",
    "dataset_root",
    "failure_class",
    "batch_index",
    "batch_size",
    "counters",
)


def check_journal(lines):
    errors = []
    prev_seq = None
    prev_ts = None
    records = 0
    for lineno, line in enumerate(lines, 1):
        if not line.strip():
            continue
        where = f"journal line {lineno}"
        try:
            rec = json.loads(line)
        except ValueError as e:
            errors.append(f"{where}: not JSON: {e}")
            continue
        records += 1
        if rec.get("schema") != EVENT_SCHEMA:
            errors.append(
                f"{where}: schema {rec.get('schema')!r}, expected {EVENT_SCHEMA!r}"
            )
            continue
        missing = [k for k in JOURNAL_KEYS if k not in rec]
        if missing:
            errors.append(f"{where}: missing keys {missing}")
            continue
        seq, ts = rec["seq"], rec["ts_unix"]
        if prev_seq is not None and seq <= prev_seq:
            errors.append(f"{where}: seq {seq} not greater than previous {prev_seq}")
        if prev_ts is not None and ts < prev_ts:
            errors.append(f"{where}: ts_unix {ts} went backwards from {prev_ts}")
        prev_seq, prev_ts = seq, ts
        if rec["verb"] not in VERBS:
            errors.append(f"{where}: unknown verb {rec['verb']!r}")
        if rec["outcome"] not in OUTCOMES:
            errors.append(f"{where}: unknown outcome {rec['outcome']!r}")
        cls = rec["failure_class"]
        if rec["outcome"] == "rejected":
            if cls is None:
                errors.append(f"{where}: rejected record has no failure_class")
            elif cls not in FAILURE_CLASSES:
                errors.append(f"{where}: unknown failure_class {cls!r}")
        elif cls is not None:
            errors.append(
                f"{where}: outcome {rec['outcome']!r} must not carry a "
                f"failure_class (got {cls!r})"
            )
        if not isinstance(rec["counters"], dict):
            errors.append(f"{where}: counters is not an object")
    if records == 0:
        errors.append("journal: no records")
    return records, errors


def check_trace(doc):
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return 0, ["trace: no traceEvents array"]
    open_stacks = {}  # tid -> [names], innermost last
    last_ts = {}  # tid -> ts of the latest duration event
    named_tids = set()
    duration_tids = set()
    for i, ev in enumerate(events):
        where = f"trace event {i}"
        ph = ev.get("ph")
        if ph not in ("B", "E", "M", "C"):
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_tids.add(ev.get("tid"))
            continue
        if ph == "C":
            if not isinstance(ev.get("args", {}).get("value"), (int, float)):
                errors.append(f"{where}: counter event has no numeric args.value")
            continue
        name, tid, ts = ev.get("name"), ev.get("tid"), ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: duration event has no numeric ts")
            continue
        duration_tids.add(tid)
        if tid in last_ts and ts < last_ts[tid]:
            errors.append(f"{where}: ts {ts} went backwards on tid {tid}")
        last_ts[tid] = ts
        stack = open_stacks.setdefault(tid, [])
        if ph == "B":
            stack.append(name)
        else:  # "E"
            if not stack:
                errors.append(f"{where}: E {name!r} with no open span on tid {tid}")
            elif stack[-1] != name:
                errors.append(
                    f"{where}: E {name!r} closes {stack[-1]!r} on tid {tid} "
                    "(unbalanced nesting)"
                )
            else:
                stack.pop()
    for tid, stack in sorted(open_stacks.items(), key=lambda kv: str(kv[0])):
        if stack:
            errors.append(f"trace: tid {tid} left spans open at end: {stack}")
    for tid in sorted(duration_tids, key=str):
        if tid not in named_tids:
            errors.append(f"trace: tid {tid} has duration events but no thread_name")
    if not duration_tids:
        errors.append("trace: no duration events")
    return len(events), errors


def load_lines(path):
    try:
        with open(path) as f:
            return f.readlines()
    except OSError as e:
        print(f"check_obs_artifacts: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def load_json(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_obs_artifacts: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def self_test():
    def rec(**kw):
        base = {k: None for k in JOURNAL_KEYS}
        base.update(
            schema=EVENT_SCHEMA,
            seq=0,
            ts_unix=100,
            verb="verify-trace",
            outcome="accepted",
            duration_s=0.5,
            wire_version=6,
            artifact_bytes=4096,
            counters={"msm/calls": 1},
        )
        base.update(kw)
        return json.dumps(base)

    good = [
        rec(seq=0, verb="prove-trace", outcome="proved"),
        rec(seq=1, ts_unix=101),
        rec(seq=2, ts_unix=101, outcome="rejected", failure_class="sumcheck"),
        rec(seq=3, ts_unix=102, verb="serve-verify", outcome="overloaded"),
        rec(
            seq=4,
            ts_unix=102,
            verb="serve-frame",
            outcome="rejected",
            failure_class="wire-decode",
        ),
    ]
    n, errs = check_journal(good)
    assert (n, errs) == (5, []), errs

    _, errs = check_journal([rec(seq=5), rec(seq=5)])
    assert any("not greater" in e for e in errs), errs

    _, errs = check_journal([rec(seq=0, ts_unix=9), rec(seq=1, ts_unix=8)])
    assert any("backwards" in e for e in errs), errs

    _, errs = check_journal([rec(outcome="rejected")])
    assert any("no failure_class" in e for e in errs), errs

    _, errs = check_journal([rec(outcome="rejected", failure_class="cosmic-rays")])
    assert any("unknown failure_class" in e for e in errs), errs

    _, errs = check_journal([rec(failure_class="sumcheck")])
    assert any("must not carry" in e for e in errs), errs

    _, errs = check_journal([rec(schema="zkdl/events/v999")])
    assert any("schema" in e for e in errs), errs

    bad = json.loads(rec())
    del bad["wire_version"]
    _, errs = check_journal([json.dumps(bad)])
    assert any("missing keys" in e for e in errs), errs

    def b(name, ts, tid=1):
        return {"ph": "B", "name": name, "ts": ts, "pid": 1, "tid": tid}

    def e(name, ts, tid=1):
        return {"ph": "E", "name": name, "ts": ts, "pid": 1, "tid": tid}

    def m(tid):
        return {
            "ph": "M",
            "name": "thread_name",
            "pid": 1,
            "tid": tid,
            "args": {"name": f"t{tid}"},
        }

    def c(ts):
        return {"ph": "C", "name": "msm/points", "ts": ts, "pid": 1, "args": {"value": 7}}

    good_trace = {
        "traceEvents": [
            m(1),
            m(2),
            b("outer", 1.0),
            b("inner", 2.0),
            e("inner", 3.0),
            c(3.0),
            b("worker", 1.5, tid=2),
            e("worker", 2.5, tid=2),
            e("outer", 4.0),
        ],
        "displayTimeUnit": "ms",
    }
    n, errs = check_trace(good_trace)
    assert (n, errs) == (9, []), errs

    _, errs = check_trace({"traceEvents": [m(1), b("a", 1.0), e("b", 2.0)]})
    assert any("unbalanced" in e_ for e_ in errs), errs

    _, errs = check_trace({"traceEvents": [m(1), b("a", 1.0)]})
    assert any("left spans open" in e_ for e_ in errs), errs

    _, errs = check_trace({"traceEvents": [m(1), e("a", 1.0)]})
    assert any("no open span" in e_ for e_ in errs), errs

    _, errs = check_trace({"traceEvents": [m(1), b("a", 2.0), e("a", 1.0)]})
    assert any("backwards" in e_ for e_ in errs), errs

    _, errs = check_trace({"traceEvents": [b("a", 1.0), e("a", 2.0)]})
    assert any("no thread_name" in e_ for e_ in errs), errs

    _, errs = check_trace({"notTraceEvents": []})
    assert any("no traceEvents" in e_ for e_ in errs), errs

    print("check_obs_artifacts self-test ok")


def main(argv):
    if len(argv) >= 2 and argv[1] == "--self-test":
        self_test()
        return 0
    journal_path = trace_path = None
    args = argv[1:]
    while args:
        if args[0] == "--journal" and len(args) >= 2:
            journal_path, args = args[1], args[2:]
        elif args[0] == "--trace" and len(args) >= 2:
            trace_path, args = args[1], args[2:]
        else:
            print(__doc__.strip(), file=sys.stderr)
            return 2
    if journal_path is None and trace_path is None:
        print(__doc__.strip(), file=sys.stderr)
        return 2

    errors = []
    if journal_path is not None:
        n, errs = check_journal(load_lines(journal_path))
        errors.extend(errs)
        if not errs:
            print(f"journal ok: {n} record(s) in {journal_path}")
    if trace_path is not None:
        n, errs = check_trace(load_json(trace_path))
        errors.extend(errs)
        if not errs:
            print(f"trace ok: {n} event(s) in {trace_path}")
    for e in errors:
        print(f"check_obs_artifacts: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
