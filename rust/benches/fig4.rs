//! Figure 4 — per-step proving time and proof size vs network depth L,
//! comparing the parallel order of proof (zkReLU-compatible circuit, ours)
//! against the conventional sequential layer-by-layer order [1].
//!
//!     cargo bench --bench fig4                 # depths 2..8, small layers
//!     cargo bench --bench fig4 -- --full       # depths 2..16, width 64

use std::path::Path;
use std::time::Instant;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::util::bench::{BenchArgs, Table};
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};

fn main() {
    let args = BenchArgs::from_env();
    let full = args.has("--full");
    let width = args.get_usize("--width", if full { 64 } else { 16 });
    let batch = args.get_usize("--batch", if full { 16 } else { 8 });
    let max_depth = args.get_usize("--max-depth", if full { 16 } else { 8 });

    println!("== Figure 4: proving time & proof size vs depth (width={width}, BS={batch}) ==");
    let mut table = Table::new(&[
        "L",
        "#param",
        "par time(s)",
        "par size(kB)",
        "seq time(s)",
        "seq size(kB)",
        "speedup",
        "size ratio",
    ]);
    let mut depth = 2usize;
    while depth <= max_depth {
        let cfg = ModelConfig::new(depth, width, batch);
        let mut rng = Rng::seed_from_u64(depth as u64);
        let ds = Dataset::synthetic(batch.max(16), width / 2, 4, cfg.r_bits, 5);
        let (x, y) = ds.batch(&cfg, 0);
        let w = Weights::init(cfg, &mut rng);
        let src = WitnessSource::auto(Path::new("artifacts"), cfg);
        let wit = src.compute_witness(&x, &y, &w).expect("witness");
        let pk = ProverKey::setup(cfg);

        let t0 = Instant::now();
        let par = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let par_s = t0.elapsed().as_secs_f64();
        verify_step(&pk, &par).expect("parallel verifies");

        let t0 = Instant::now();
        let seq = prove_step(&pk, &wit, ProofMode::Sequential, &mut rng);
        let seq_s = t0.elapsed().as_secs_f64();
        verify_step(&pk, &seq).expect("sequential verifies");

        table.row(vec![
            depth.to_string(),
            format!("{:.1}K", cfg.param_count() as f64 / 1e3),
            format!("{par_s:.3}"),
            format!("{:.1}", par.size_bytes() as f64 / 1024.0),
            format!("{seq_s:.3}"),
            format!("{:.1}", seq.size_bytes() as f64 / 1024.0),
            format!("{:.2}x", seq_s / par_s),
            format!("{:.2}x", seq.size_bytes() as f64 / par.size_bytes() as f64),
        ]);
        depth *= 2;
    }
    table.print();
    println!("expected shape: par size grows ~O(log L); seq grows ~O(L).");
}
