//! Figure 1 — fraction of SC-BD proving time spent on bit-decomposition
//! (BD) components. The paper re-runs the general-purpose pipeline with all
//! BD components removed and reports the BD share (>90%).
//!
//!     cargo bench --bench fig1

use std::path::Path;
use std::time::Instant;
use zkdl::baseline;
use zkdl::commit::CommitKey;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::transcript::Transcript;
use zkdl::util::bench::{BenchArgs, Table};
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, ProofMode, ProverKey};

fn main() {
    let args = BenchArgs::from_env();
    let widths: Vec<usize> = if args.has("--full") {
        vec![16, 32, 64]
    } else {
        vec![16, 32]
    };
    let batch = args.get_usize("--batch", 4);

    println!("== Figure 1: share of SC-BD proving time spent on BD ==");
    let mut table = Table::new(&["width", "BD time(s)", "arith time(s)", "BD share"]);
    for &width in &widths {
        let cfg = ModelConfig::new(2, width, batch);
        let mut rng = Rng::seed_from_u64(width as u64);
        let ds = Dataset::synthetic(16, width / 2, 4, cfg.r_bits, 3);
        let (x, y) = ds.batch(&cfg, 0);
        let w = Weights::init(cfg, &mut rng);
        let src = WitnessSource::auto(Path::new("artifacts"), cfg);
        let wit = src.compute_witness(&x, &y, &w).expect("witness");

        // arithmetic share: the full zkDL proof stands in for the matmul
        // part of the general-purpose pipeline (over-counts it slightly —
        // in the paper's favor this makes the measured BD share a lower
        // bound on the true one)
        let pk = ProverKey::setup(cfg);
        let t0 = Instant::now();
        let _ = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let arith_s = t0.elapsed().as_secs_f64();

        // BD share: the bit-decomposition sumchecks of all aux tensors
        let d = cfg.d_size();
        let q = cfg.q_bits as usize;
        let ck = CommitKey::setup(b"scbd-bench", d * q);
        let mut t = Transcript::new(b"fig1");
        let t0 = Instant::now();
        for lw in &wit.layers {
            let zeros = vec![0i64; d];
            let gap = lw.g_a_prime.as_deref().unwrap_or(&zeros);
            let rga = lw.g_a_aux.as_ref().map(|a| a.rem.as_slice()).unwrap_or(&zeros);
            let _ = baseline::prove_layer_relu_bd(
                &lw.z_aux.dprime,
                gap,
                &lw.z_aux.rem,
                rga,
                q,
                cfg.r_bits as usize,
                &ck,
                &mut t,
                &mut rng,
            );
        }
        let bd_s = t0.elapsed().as_secs_f64();
        table.row(vec![
            width.to_string(),
            format!("{bd_s:.2}"),
            format!("{arith_s:.2}"),
            format!("{:.1}%", 100.0 * bd_s / (bd_s + arith_s)),
        ]);
    }
    table.print();
    println!("paper reports the BD share exceeding 90% and growing with D.");
}
