//! Table 2 — zkReLU (ours) vs Sum-Check Bit-Decomposition (SC-BD) on a
//! fully-connected network of L = 2 layers: per-batch proving time (s) and
//! proof size (kB) across widths and batch sizes.
//!
//!     cargo bench --bench table2              # reduced sweep
//!     cargo bench --bench table2 -- --full    # paper's full grid (slow!)
//!
//! SC-BD runs are executed directly while the joint bit table D²Q stays
//! within a memory budget, and extrapolated from a calibration run above
//! it (the paper likewise reports >10³ s timeouts). SC-BD total time =
//! BD handling of the aux tensors + the same arithmetic (matmul) phase as
//! zkDL, which is conservative *toward* the baseline since the arithmetic
//! share is counted as the whole zkDL proof.

use std::path::Path;
use std::time::Instant;
use zkdl::baseline;
use zkdl::commit::CommitKey;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::transcript::Transcript;
use zkdl::util::bench::{BenchArgs, Table};
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, ProofMode, ProverKey};

/// Run SC-BD directly if D²Q is affordable; otherwise calibrate on a
/// smaller D and extrapolate quadratically. Returns (seconds, bytes,
/// extrapolated?).
fn scbd_cost(wit: &zkdl::witness::StepWitness, rng: &mut Rng) -> (f64, usize, bool) {
    let cfg = &wit.cfg;
    let d_size = cfg.d_size();
    let q = cfg.q_bits as usize;
    const BUDGET: usize = 1 << 22; // joint-table entries we are willing to hold
    let (run_d, extrapolated) = if d_size * d_size * q <= BUDGET {
        (d_size, false)
    } else {
        let mut d = d_size;
        while d * d * q > BUDGET {
            d /= 2;
        }
        (d, true)
    };
    let ck = CommitKey::setup(b"scbd-bench", run_d * q);
    let mut t = Transcript::new(b"scbd-bench");
    let t0 = Instant::now();
    let mut bytes = 0usize;
    for lw in &wit.layers {
        let zdp = &lw.z_aux.dprime[..run_d];
        let zeros = vec![0i64; run_d];
        let gap_full;
        let gap: &[i64] = match lw.g_a_prime.as_deref() {
            Some(g) => {
                gap_full = g.to_vec();
                &gap_full[..run_d]
            }
            None => &zeros,
        };
        let rz = &lw.z_aux.rem[..run_d];
        let rga_full;
        let rga: &[i64] = match lw.g_a_aux.as_ref() {
            Some(a) => {
                rga_full = a.rem.clone();
                &rga_full[..run_d]
            }
            None => &zeros,
        };
        let proofs = baseline::prove_layer_relu_bd(
            zdp,
            gap,
            rz,
            rga,
            q,
            cfg.r_bits as usize,
            &ck,
            &mut t,
            rng,
        );
        bytes += proofs.iter().map(|p| p.size_bytes()).sum::<usize>();
    }
    let measured = t0.elapsed().as_secs_f64();
    if extrapolated {
        // prover cost is Θ(D²Q): scale by (D/run_d)²
        let factor = (d_size as f64 / run_d as f64).powi(2);
        // per-layer proof size grows with log(D²Q) — rescale analytically
        let size_factor =
            ((d_size * d_size * q) as f64).log2() / ((run_d * run_d * q) as f64).log2();
        (measured * factor, (bytes as f64 * size_factor) as usize, true)
    } else {
        (measured, bytes, false)
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let full = args.has("--full");
    let widths: Vec<usize> = if full {
        vec![64, 256, 1024, 4096]
    } else {
        vec![16, 64]
    };
    let batches: Vec<usize> = if full {
        vec![16, 32, 64, 128]
    } else {
        vec![4, 16]
    };
    let time_limit = args.get_f64("--time-limit", 1000.0);

    println!("== Table 2: zkReLU vs SC-BD (L=2) ==");
    let mut table = Table::new(&[
        "width",
        "#param",
        "BS",
        "#aux",
        "zkDL time(s)",
        "zkDL size(kB)",
        "SC-BD time(s)",
        "SC-BD size(kB)",
    ]);
    for &width in &widths {
        for &bs in &batches {
            let cfg = ModelConfig::new(2, width, bs);
            let mut rng = Rng::seed_from_u64((width * 1000 + bs) as u64);
            let ds = Dataset::synthetic(bs.max(16), width / 2, 4, cfg.r_bits, 3);
            let (x, y) = ds.batch(&cfg, 0);
            let w = Weights::init(cfg, &mut rng);
            let src = WitnessSource::auto(Path::new("artifacts"), cfg);
            let wit = src.compute_witness(&x, &y, &w).expect("witness");

            let pk = ProverKey::setup(cfg);
            let t0 = Instant::now();
            let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
            let zkdl_s = t0.elapsed().as_secs_f64();
            let zkdl_kb = proof.size_bytes() as f64 / 1024.0;

            let (bd_s, bd_bytes, extrapolated) = scbd_cost(&wit, &mut rng);
            let scbd_s = bd_s + zkdl_s; // + the arithmetic phase (conservative)
            let scbd_cell = if scbd_s > time_limit {
                format!("> {time_limit:.0}")
            } else if extrapolated {
                format!("~{scbd_s:.2}")
            } else {
                format!("{scbd_s:.2}")
            };
            // aux inputs: 5 tensors of size D per ReLU layer + rescale aux
            let aux = 5 * cfg.depth * cfg.d_size();
            table.row(vec![
                width.to_string(),
                format!("{:.1}K", cfg.param_count() as f64 / 1e3),
                bs.to_string(),
                format!("{:.1e}", aux as f64),
                format!("{zkdl_s:.3}"),
                format!("{zkdl_kb:.1}"),
                scbd_cell,
                format!(
                    "{:.0}",
                    (bd_bytes as f64 + proof.size_bytes() as f64) / 1024.0
                ),
            ]);
        }
    }
    table.print();
    println!("(~ = extrapolated from a calibration run; paper marks these >10^3 s.)");
}
