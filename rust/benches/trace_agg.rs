//! FAC4DNN aggregation benchmark: aggregated T-step proving / verification /
//! proof size versus T independent `StepProof`s, for T ∈ {1, 4, 16}; at
//! T ∈ {4, 16} a third row measures the zkOptim-chained trace (inter-step
//! weight recurrence proven, plain-SGD rule) against the unchained
//! aggregate, a fourth the heavy-ball momentum rule (two relations per
//! boundary + a committed accumulator per step), and a fifth the zkData
//! provenance trace (batch selection against a committed 256-row dataset).
//!
//!     cargo bench --bench trace_agg
//!     cargo bench --bench trace_agg -- --depth 2 --width 16 --batch 8

use zkdl::aggregate::{
    prove_trace, prove_trace_chained, prove_trace_chained_with, prove_trace_provenance,
    verify_trace, TraceKey,
};
use zkdl::data::Dataset;
use zkdl::model::ModelConfig;
use zkdl::provenance::ProverDataset;
use zkdl::update::{LrSchedule, UpdateRule};
use zkdl::util::bench::{fmt_dur, time_once, BenchArgs, Table};
use zkdl::util::rng::Rng;
use zkdl::witness::native::{rule_witness_chain, sgd_witness_chain};
use zkdl::witness::StepWitness;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};

fn bench_dataset(cfg: &ModelConfig, seed: u64) -> Dataset {
    Dataset::synthetic(256, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77)
}

fn witness_chain(cfg: ModelConfig, steps: usize, seed: u64) -> (Dataset, Vec<StepWitness>) {
    let ds = bench_dataset(&cfg, seed);
    let wits = sgd_witness_chain(cfg, &ds, steps, seed);
    (ds, wits)
}

fn momentum_witness_chain(cfg: ModelConfig, steps: usize, seed: u64) -> Vec<StepWitness> {
    let ds = bench_dataset(&cfg, seed);
    rule_witness_chain(
        cfg,
        &UpdateRule::momentum_default(),
        &LrSchedule::Constant(cfg.lr_shift),
        &ds,
        steps,
        seed,
    )
}

fn main() {
    let args = BenchArgs::from_env();
    let cfg = ModelConfig::new(
        args.get_usize("--depth", 2),
        args.get_usize("--width", 16),
        args.get_usize("--batch", 8),
    );
    println!(
        "trace aggregation: L={} d={} B={} ({} threads)",
        cfg.depth,
        cfg.width,
        cfg.batch,
        zkdl::util::threads::num_threads()
    );
    let mut table = Table::new(&[
        "T",
        "scheme",
        "prove",
        "verify",
        "proof kB",
        "vs T× steps",
    ]);

    let mut rng = Rng::seed_from_u64(0xa66);
    let pk = ProverKey::setup(cfg);
    for t in [1usize, 4, 16] {
        let (ds, wits) = witness_chain(cfg, t, t as u64);

        // T independent per-step proofs (parallel mode)
        let (step_proofs, prove_d) = time_once(|| {
            wits.iter()
                .map(|w| prove_step(&pk, w, ProofMode::Parallel, &mut rng))
                .collect::<Vec<_>>()
        });
        let (_, verify_d) = time_once(|| {
            for p in &step_proofs {
                verify_step(&pk, p).expect("step verifies");
            }
        });
        let step_bytes: usize = step_proofs.iter().map(|p| p.size_bytes()).sum();
        table.row(vec![
            format!("{t}"),
            "independent".into(),
            fmt_dur(prove_d),
            fmt_dur(verify_d),
            format!("{:.1}", step_bytes as f64 / 1024.0),
            "1.00×".into(),
        ]);

        // one aggregated trace proof
        let tk = TraceKey::setup(cfg, t);
        let (trace_proof, prove_d) = time_once(|| prove_trace(&tk, &wits, &mut rng));
        let (_, verify_d) = time_once(|| {
            verify_trace(&tk, &trace_proof).expect("trace verifies");
        });
        let trace_bytes = trace_proof.size_bytes();
        table.row(vec![
            format!("{t}"),
            "aggregated".into(),
            fmt_dur(prove_d),
            fmt_dur(verify_d),
            format!("{:.1}", trace_bytes as f64 / 1024.0),
            format!("{:.2}×", trace_bytes as f64 / step_bytes as f64),
        ]);

        // zkOptim-chained trace (T ≥ 2): the weight-update recurrence proven
        // on top of the per-step relations, plain-SGD rule
        if t >= 2 {
            let (chained_proof, prove_d) = time_once(|| {
                prove_trace_chained(&tk, &wits, &mut rng).expect("witnesses chain")
            });
            let (_, verify_d) = time_once(|| {
                verify_trace(&tk, &chained_proof).expect("chained trace verifies");
            });
            let chained_bytes = chained_proof.size_bytes();
            table.row(vec![
                format!("{t}"),
                "chained".into(),
                fmt_dur(prove_d),
                fmt_dur(verify_d),
                format!("{:.1}", chained_bytes as f64 / 1024.0),
                format!("{:.2}×", chained_bytes as f64 / step_bytes as f64),
            ]);

            // heavy-ball momentum rule: double the remainder stack plus
            // T·L committed accumulators
            let m_wits = momentum_witness_chain(cfg, t, t as u64 ^ 0x6d);
            let rule = UpdateRule::momentum_default();
            let shifts = vec![cfg.lr_shift; t - 1];
            let (m_proof, prove_d) = time_once(|| {
                prove_trace_chained_with(&tk, &m_wits, &rule, &shifts, &mut rng)
                    .expect("momentum witnesses chain")
            });
            let (_, verify_d) = time_once(|| {
                verify_trace(&tk, &m_proof).expect("momentum trace verifies");
            });
            let m_bytes = m_proof.size_bytes();
            table.row(vec![
                format!("{t}"),
                "momentum".into(),
                fmt_dur(prove_d),
                fmt_dur(verify_d),
                format!("{:.1}", m_bytes as f64 / 1024.0),
                format!("{:.2}×", m_bytes as f64 / step_bytes as f64),
            ]);

            // zkData provenance: every step's batch bound to the committed
            // 256-row dataset (dataset commitment amortized outside the
            // timed region, as in deployment — one commitment per dataset)
            let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
            let (p_proof, prove_d) = time_once(|| {
                prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open")
            });
            let (_, verify_d) = time_once(|| {
                verify_trace(&tk, &p_proof).expect("provenance trace verifies");
            });
            let p_bytes = p_proof.size_bytes();
            table.row(vec![
                format!("{t}"),
                "provenance".into(),
                fmt_dur(prove_d),
                fmt_dur(verify_d),
                format!("{:.1}", p_bytes as f64 / 1024.0),
                format!("{:.2}×", p_bytes as f64 / step_bytes as f64),
            ]);
        }
    }
    table.print();
}
