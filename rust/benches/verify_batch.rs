//! Verification-engine benchmark: the three verifier strategies at
//! T ∈ {1, 4, 16} proofs —
//!
//! * `eager`   — one MSM per deferred equation (the pre-refactor cost
//!               model: per-opening / per-validity Pippenger calls),
//! * `one-msm` — the default wrappers: one MSM per proof,
//! * `batched` — `verify_steps_batch` / `verify_traces_batch`: every
//!               proof ρ-scaled into one shared accumulator, one MSM total.
//!
//!     cargo bench --bench verify_batch
//!     cargo bench --bench verify_batch -- --depth 2 --width 8 --batch 4

use zkdl::aggregate::{prove_trace, verify_trace, verify_traces_batch, TraceKey, TraceProof};
use zkdl::curve::accum::MsmAccumulator;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::util::bench::{fmt_dur, time_once, BenchArgs, Table};
use zkdl::util::rng::Rng;
use zkdl::witness::native::compute_witness;
use zkdl::witness::StepWitness;
use zkdl::zkdl::{
    prove_step, verify_step, verify_step_accum, verify_steps_batch, ProofMode, ProverKey,
    StepProof,
};

fn witness_chain(cfg: ModelConfig, steps: usize, seed: u64) -> Vec<StepWitness> {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = Dataset::synthetic(256, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
    let mut weights = Weights::init(cfg, &mut rng);
    let mut out = Vec::with_capacity(steps);
    for step in 0..steps {
        let (x, y) = ds.batch(&cfg, step);
        let wit = compute_witness(cfg, &x, &y, &weights);
        weights.apply_update(&wit.weight_grads());
        out.push(wit);
    }
    out
}

fn main() {
    let args = BenchArgs::from_env();
    let cfg = ModelConfig::new(
        args.get_usize("--depth", 2),
        args.get_usize("--width", 8),
        args.get_usize("--batch", 4),
    );
    println!(
        "verification engine: L={} d={} B={} ({} threads)",
        cfg.depth,
        cfg.width,
        cfg.batch,
        zkdl::util::threads::num_threads()
    );

    let mut table = Table::new(&["T", "proof", "mode", "verify", "MSMs"]);
    let pk = ProverKey::setup(cfg);
    for t in [1usize, 4, 16] {
        let wits = witness_chain(cfg, t, t as u64);
        let mut rng = Rng::seed_from_u64(0xbe2c);
        let proofs: Vec<StepProof> = wits
            .iter()
            .map(|w| prove_step(&pk, w, ProofMode::Parallel, &mut rng))
            .collect();

        // eager: one MSM per deferred equation (pre-refactor cost model)
        let mut msms = 0usize;
        let (_, d_eager) = time_once(|| {
            for p in &proofs {
                let mut seed = Rng::seed_from_u64(1);
                let mut acc = MsmAccumulator::eager_from_rng(&mut seed);
                verify_step_accum(&pk, p, &mut acc).expect("verifies");
                assert!(acc.flush(), "eager verification accepts");
                msms += acc.flushes();
            }
        });
        table.row(vec![
            format!("{t}"),
            "step".into(),
            "eager".into(),
            fmt_dur(d_eager),
            format!("{msms}"),
        ]);

        // one MSM per proof (the verify_step wrapper)
        let (_, d_one) = time_once(|| {
            for p in &proofs {
                verify_step(&pk, p).expect("verifies");
            }
        });
        table.row(vec![
            format!("{t}"),
            "step".into(),
            "one-msm".into(),
            fmt_dur(d_one),
            format!("{t}"),
        ]);

        // one MSM for the whole batch
        let (_, d_batch) = time_once(|| {
            let mut vrng = Rng::seed_from_u64(2);
            verify_steps_batch(&pk, &proofs, &mut vrng).expect("batch verifies");
        });
        table.row(vec![
            format!("{t}"),
            "step".into(),
            "batched".into(),
            fmt_dur(d_batch),
            "1".into(),
        ]);

        // trace proofs: per-proof wrappers vs cross-proof batch
        let tk = TraceKey::setup(cfg, 1);
        let trace_proofs: Vec<TraceProof> = (0..t)
            .map(|i| prove_trace(&tk, &wits[i..i + 1], &mut rng))
            .collect();
        let (_, d_trace_one) = time_once(|| {
            for p in &trace_proofs {
                verify_trace(&tk, p).expect("verifies");
            }
        });
        table.row(vec![
            format!("{t}"),
            "trace".into(),
            "one-msm".into(),
            fmt_dur(d_trace_one),
            format!("{t}"),
        ]);
        let (_, d_trace_batch) = time_once(|| {
            let pairs: Vec<(&TraceKey, &TraceProof)> =
                trace_proofs.iter().map(|p| (&tk, p)).collect();
            let mut vrng = Rng::seed_from_u64(3);
            verify_traces_batch(&pairs, &mut vrng).expect("batch verifies");
        });
        table.row(vec![
            format!("{t}"),
            "trace".into(),
            "batched".into(),
            fmt_dur(d_trace_batch),
            "1".into(),
        ]);
    }
    table.print();
}
