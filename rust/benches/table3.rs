//! Table 3 — proof size (# hash values) and verification time (ms) of the
//! (non-)membership protocol across hash functions, query counts and
//! positivity ratios, plus tree construction time; and §5.2's comparison
//! against naively scanning the committed dataset.
//!
//!     cargo bench --bench table3              # n = 10 000 points
//!     cargo bench --bench table3 -- --full    # n = 50 000 (CIFAR-10 scale)
//!
//! Leaf payloads are synthetic 64-byte commitment encodings: tree metrics
//! depend only on hash structure, never on pixel values (DESIGN.md).

use std::time::Instant;
use zkdl::hash::HashFn;
use zkdl::merkle::{verify_membership, MerkleTree};
use zkdl::util::bench::{BenchArgs, Table};
use zkdl::util::rng::Rng;

fn main() {
    let args = BenchArgs::from_env();
    let n = if args.has("--full") { 50_000 } else { 10_000 };
    let query_counts = [10usize, 100, 1000];
    let ratios = [0.0f64, 0.1, 0.5, 0.9, 1.0];

    let mut rng = Rng::seed_from_u64(0x7ab1e3);
    let coms: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            let mut b = vec![0u8; 64];
            rng.fill_bytes(&mut b);
            b
        })
        .collect();

    println!("== Table 3: (non-)membership proofs over {n} data points ==");
    let mut table = Table::new(&[
        "hash", "t_tree(s)", "#data", "ratio", "size(#)", "verify(ms)",
    ]);
    for hash in [HashFn::Md5, HashFn::Sha1, HashFn::Sha256] {
        let t0 = Instant::now();
        let tree = MerkleTree::build(hash, &coms);
        let t_tree = t0.elapsed().as_secs_f64();
        for &nq in &query_counts {
            for &ratio in &ratios {
                let n_pos = (nq as f64 * ratio).round() as usize;
                let mut queries: Vec<Vec<u8>> =
                    coms[..n_pos].iter().map(|c| hash.hash(c)).collect();
                while queries.len() < nq {
                    let mut fake = vec![0u8; 64];
                    rng.fill_bytes(&mut fake);
                    queries.push(hash.hash(&fake));
                }
                let proof = tree.prove(&queries);
                let t0 = Instant::now();
                verify_membership(hash, &tree.root, &queries, &proof).expect("verifies");
                let verify_ms = t0.elapsed().as_secs_f64() * 1e3;
                table.row(vec![
                    hash.name().to_string(),
                    format!("{t_tree:.1}"),
                    nq.to_string(),
                    format!("{ratio:.1}"),
                    proof.size_hashes().to_string(),
                    format!("{verify_ms:.2}"),
                ]);
            }
        }
    }
    table.print();

    // §5.2: single non-member check vs naive scan of the committed set
    let hash = HashFn::Md5;
    let tree = MerkleTree::build(hash, &coms);
    let mut fake = vec![0u8; 64];
    rng.fill_bytes(&mut fake);
    let queries = vec![hash.hash(&fake)];
    let proof = tree.prove(&queries);
    let t0 = Instant::now();
    verify_membership(hash, &tree.root, &queries, &proof).expect("verifies");
    let merkle_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let found = coms.iter().any(|c| hash.hash(c) == queries[0]);
    let scan_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "single non-membership check: merkle {merkle_ms:.3} ms vs naive scan {scan_ms:.1} ms (found={found})"
    );
}
