//! Micro-benchmarks of the proving hot paths (the §Perf instrumentation):
//! MSM, field multiplication, sumcheck rounds, IPA, generator derivation.
//!
//!     cargo bench --bench micro

use std::time::{Duration, Instant};
use zkdl::commit::CommitKey;
use zkdl::curve::{derive_generators, msm::msm, G1};
use zkdl::field::Fr;
use zkdl::ipa;
use zkdl::poly::{eq_table, Mle};
use zkdl::sumcheck::{self, Instance, Term};
use zkdl::transcript::Transcript;
use zkdl::util::bench::{fmt_dur, time_budgeted, Table};
use zkdl::util::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(0xbe7c);
    let budget = Duration::from_secs(5);
    println!("threads: {}", zkdl::util::threads::num_threads());
    let mut table = Table::new(&["benchmark", "n", "median", "throughput"]);

    // field multiplication
    {
        let a = Fr::random(&mut rng);
        let b = Fr::random(&mut rng);
        let st = time_budgeted(
            || {
                let mut acc = a;
                for _ in 0..1_000_000 {
                    acc *= b;
                }
                std::hint::black_box(acc);
            },
            20,
            budget,
        );
        table.row(vec![
            "field mul".into(),
            "1e6".into(),
            fmt_dur(st.median),
            format!("{:.0} Mmul/s", 1.0 / st.median.as_secs_f64()),
        ]);
    }

    // MSM at the commitment sizes the prover uses
    for log_n in [10usize, 14, 16] {
        let n = 1 << log_n;
        let t0 = Instant::now();
        let bases = derive_generators(b"micro-msm", n);
        let gen_s = t0.elapsed().as_secs_f64();
        let scalars: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
        let st = time_budgeted(
            || {
                std::hint::black_box(msm(&bases, &scalars));
            },
            10,
            budget,
        );
        table.row(vec![
            format!("msm (gen {gen_s:.2}s)"),
            format!("2^{log_n}"),
            fmt_dur(st.median),
            format!(
                "{:.2} Mscalar/s",
                n as f64 / st.median.as_secs_f64() / 1e6
            ),
        ]);
    }

    // bit-scalar MSM (the Protocol-1 commitment of B/B′ matrices)
    {
        let n = 1 << 16;
        let bases = derive_generators(b"micro-msm", n);
        let bits: Vec<Fr> = (0..n)
            .map(|_| Fr::from_u64(rng.gen_range(2)))
            .collect();
        let st = time_budgeted(
            || {
                std::hint::black_box(msm(&bases, &bits));
            },
            10,
            budget,
        );
        table.row(vec![
            "msm 0/1 scalars".into(),
            "2^16".into(),
            fmt_dur(st.median),
            format!("{:.2} Mbit/s", n as f64 / st.median.as_secs_f64() / 1e6),
        ]);
    }

    // sumcheck: degree-3 product over 2^16 entries
    {
        let nv = 16usize;
        let mk = |rng: &mut Rng| Mle::new((0..1 << nv).map(|_| Fr::random(rng)).collect());
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let c = mk(&mut rng);
        let st = time_budgeted(
            || {
                let inst = Instance::new(vec![Term::new(
                    Fr::ONE,
                    vec![a.clone(), b.clone(), c.clone()],
                )]);
                let mut t = Transcript::new(b"micro");
                std::hint::black_box(sumcheck::prove(inst, &mut t));
            },
            10,
            budget,
        );
        table.row(vec![
            "sumcheck deg-3".into(),
            "2^16".into(),
            fmt_dur(st.median),
            format!(
                "{:.2} Mevals/s",
                (1 << nv) as f64 / st.median.as_secs_f64() / 1e6
            ),
        ]);
    }

    // IPA evaluation opening at 2^14
    {
        let n = 1 << 14;
        let ck = CommitKey::setup(b"micro-ipa", n);
        let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
        let u: Vec<Fr> = (0..14).map(|_| Fr::random(&mut rng)).collect();
        let e = eq_table(&u);
        let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
        let blind = Fr::random(&mut rng);
        let com = ck.commit(&vals, blind);
        let st = time_budgeted(
            || {
                let mut t = Transcript::new(b"micro");
                std::hint::black_box(ipa::prove_eval(
                    &ck, &com, &vals, blind, &e, v, &mut t, &mut rng,
                ));
            },
            5,
            budget,
        );
        table.row(vec![
            "ipa prove_eval".into(),
            "2^14".into(),
            fmt_dur(st.median),
            String::new(),
        ]);
        let mut tp = Transcript::new(b"micro-v");
        let proof = ipa::prove_eval(&ck, &com, &vals, blind, &e, v, &mut tp, &mut rng);
        let st = time_budgeted(
            || {
                let mut t = Transcript::new(b"micro-v");
                std::hint::black_box(ipa::verify_eval(&ck, &com, &e, v, &proof, &mut t).is_ok());
            },
            5,
            budget,
        );
        table.row(vec![
            "ipa verify_eval".into(),
            "2^14".into(),
            fmt_dur(st.median),
            String::new(),
        ]);
    }

    // scalar mul / batch normalization
    {
        let p = G1::random(&mut rng);
        let s = Fr::random(&mut rng);
        let st = time_budgeted(
            || {
                std::hint::black_box(p.mul(&s));
            },
            1000,
            Duration::from_secs(2),
        );
        table.row(vec![
            "scalar mul".into(),
            "1".into(),
            fmt_dur(st.median),
            format!("{:.0} mul/s", 1.0 / st.median.as_secs_f64()),
        ]);
    }

    table.print();
}
