//! Table 1 — empirical check of the asymptotic claims:
//!   zkDL proving time O(DQ + log L), proof size O(log(DQL));
//!   SC-BD proving time O(D²QL).
//!
//!     cargo bench --bench table1_scaling
//!
//! Prints the per-unit ratios: time/DQ should stay ~flat for zkDL while
//! time/DQ grows ~linearly in D for SC-BD; proof size divided by log(DQL)
//! should stay ~flat.

use std::path::Path;
use std::time::Instant;
use zkdl::baseline;
use zkdl::commit::CommitKey;
use zkdl::data::Dataset;
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::transcript::Transcript;
use zkdl::util::bench::Table;
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, ProofMode, ProverKey};

fn main() {
    println!("== Table 1: scaling shape check ==");
    let mut table = Table::new(&[
        "D=B*d",
        "zkDL t(s)",
        "t/DQ (us)",
        "size(kB)",
        "size/log(DQL)",
        "SC-BD t(s)",
        "t/D2Q (ns)",
    ]);
    for (width, bs) in [(8usize, 4usize), (16, 4), (16, 8), (32, 8)] {
        let cfg = ModelConfig::new(2, width, bs);
        let d = cfg.d_size();
        let q = cfg.q_bits as usize;
        let mut rng = Rng::seed_from_u64((width + bs) as u64);
        let ds = Dataset::synthetic(16, width / 2, 4, cfg.r_bits, 3);
        let (x, y) = ds.batch(&cfg, 0);
        let w = Weights::init(cfg, &mut rng);
        let src = WitnessSource::auto(Path::new("artifacts"), cfg);
        let wit = src.compute_witness(&x, &y, &w).expect("witness");
        let pk = ProverKey::setup(cfg);

        let t0 = Instant::now();
        let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let zkdl_s = t0.elapsed().as_secs_f64();

        let ck = CommitKey::setup(b"scbd-bench", d * q);
        let mut tr = Transcript::new(b"t1");
        let t0 = Instant::now();
        for lw in &wit.layers {
            let zeros = vec![0i64; d];
            let gap = lw.g_a_prime.as_deref().unwrap_or(&zeros);
            let rga = lw.g_a_aux.as_ref().map(|a| a.rem.as_slice()).unwrap_or(&zeros);
            let _ = baseline::prove_layer_relu_bd(
                &lw.z_aux.dprime,
                gap,
                &lw.z_aux.rem,
                rga,
                q,
                cfg.r_bits as usize,
                &ck,
                &mut tr,
                &mut rng,
            );
        }
        let scbd_s = t0.elapsed().as_secs_f64();

        let dq = (d * q) as f64;
        let d2q = (d * d * q) as f64;
        let logdql = ((d * q * cfg.depth) as f64).log2();
        table.row(vec![
            d.to_string(),
            format!("{zkdl_s:.3}"),
            format!("{:.1}", zkdl_s / dq * 1e6),
            format!("{:.1}", proof.size_bytes() as f64 / 1024.0),
            format!("{:.2}", proof.size_bytes() as f64 / 1024.0 / logdql),
            format!("{scbd_s:.3}"),
            format!("{:.1}", scbd_s / d2q * 1e9),
        ]);
    }
    table.print();
    println!("shape: zkDL t/DQ and size/log(DQL) ~flat; SC-BD t/D2Q ~flat (i.e. t ~ D2Q).");
}
