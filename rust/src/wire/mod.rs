//! Canonical binary wire format for persisted proofs.
//!
//! Proofs become verifier-portable artifacts: `zkdl prove-trace --out f`
//! writes a [`TraceProof`] to disk and a *separate* `zkdl verify-trace
//! --in f` process re-reads and verifies it. The codec is deliberately
//! serde-free and versioned:
//!
//! * envelope: magic `"ZKDL"` ‖ version u16 LE ‖ kind u16 LE ‖ embedded
//!   [`ModelConfig`] ‖ payload — a file is self-describing, so the verifier
//!   reconstructs the (deterministic, label-derived) keys from the file
//!   alone;
//! * scalars are canonical 32-byte little-endian [`Fr`]; points are the
//!   32-byte compressed [`G1Affine`] encoding (sign bit + x), so serialized
//!   sizes match the paper's compressed-point proof-size accounting.
//!   Decoding *rejects* non-canonical scalars and encodings that are not a
//!   curve point, so every proof has exactly one byte representation and
//!   `decode(encode(p)) == p` re-encodes to the identical bytes;
//! * vectors carry u32 length prefixes bounded by the remaining input, and
//!   the envelope must be consumed exactly (no trailing garbage).
//!
//! Bumping [`VERSION`] is required for any layout change; the golden-bytes
//! test in `rust/tests/wire_format.rs` pins the current header.

use crate::aggregate::{StepCommitmentSet, TraceProof};
use crate::curve::G1Affine;
use crate::field::Fr;
use crate::ipa::IpaProof;
use crate::model::ModelConfig;
use crate::provenance::{DatasetCommitment, ProvenanceProof};
use crate::sumcheck::SumcheckProof;
use crate::update::rule::{RULE_TAG_MOMENTUM, RULE_TAG_SGD};
use crate::update::{ChainProof, UpdateRule};
use crate::zkdl::{GroupProof, ProofMode, StepProof};
use crate::zkrelu::{Protocol1Msg, ValidityProof};
use anyhow::{bail, ensure, Context, Result};

/// File magic, first four bytes of every proof artifact.
pub const MAGIC: [u8; 4] = *b"ZKDL";
/// Format version; bump on any layout change *or* Fiat–Shamir transcript
/// schedule change (a proof generated under an older schedule decodes fine
/// but can never verify — better to reject it as an unsupported version).
/// v2: deferred-verification transcript — batched openings absorb values
/// only, zkReLU's statement point P is no longer absorbed.
/// v3: 32-byte compressed point encoding; trace envelope carries the
/// optional zkSGD chain payload; the trace transcript absorbs a chained
/// flag.
/// v4: chain payload carries one stacked remainder commitment `com_u`
/// (was per-boundary commitment rows) and the chain transcript absorbs
/// `com/u` and draws the `upd/gamma` block-selector challenge.
/// v5: zkOptim — the chain payload opens with an update-rule tag (plus
/// rule parameters), a per-boundary lr-shift table, and per-step rule
/// state commitments (momentum accumulators); the stacked remainder
/// tensor gains a relation axis and the transcript absorbs the full rule
/// statement. v4 chained artifacts are rejected as unsupported, not
/// misparsed.
/// v6: zkData — the trace envelope carries an optional batch-provenance
/// payload (dataset commitment + endorsed root, selection commitment,
/// selection sumcheck, five openings, booleanity instance) and the trace
/// transcript absorbs a provenance flag for EVERY trace, so v5 artifacts
/// are rejected as unsupported, not misparsed.
pub const VERSION: u16 = 6;

/// Hard ceiling on a whole artifact's wire length, enforced *before* any
/// payload allocation — by [`decode_envelope`] for in-memory buffers, by
/// [`read_artifact`] for files (a multi-GB file is rejected from its
/// metadata, not read), and by the serve daemon's frame reader before it
/// allocates the frame body. The largest legitimate artifact (a provenance
/// trace at the decoder's basis ceiling) is far below this.
pub const MAX_ARTIFACT_BYTES: usize = 1 << 26; // 64 MiB

/// Read a proof artifact from disk, refusing oversized files from their
/// metadata before any bytes are read. Oversize carries the `wire-decode`
/// failure class so journals attribute it like any other decode rejection.
pub fn read_artifact(path: &std::path::Path) -> Result<Vec<u8>> {
    let len = std::fs::metadata(path)
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    crate::ensure_class!(
        len <= MAX_ARTIFACT_BYTES as u64,
        crate::telemetry::failure::VerifyFailureClass::WireDecode,
        "artifact {} is {len} bytes (limit {})",
        path.display(),
        MAX_ARTIFACT_BYTES
    );
    std::fs::read(path).with_context(|| format!("reading {}", path.display()))
}

/// Payload discriminant in the envelope header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProofKind {
    Step,
    Trace,
}

impl ProofKind {
    fn tag(self) -> u16 {
        match self {
            ProofKind::Step => 1,
            ProofKind::Trace => 2,
        }
    }

    fn from_tag(tag: u16) -> Result<Self> {
        match tag {
            1 => Ok(ProofKind::Step),
            2 => Ok(ProofKind::Trace),
            other => bail!("wire: unknown proof kind {other}"),
        }
    }
}

/// Append-only byte sink.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn put_len(&mut self, n: usize) {
        assert!(n <= u32::MAX as usize, "wire: vector too long");
        self.put_u32(n as u32);
    }

    pub fn put<T: ToWire + ?Sized>(&mut self, v: &T) {
        v.to_wire(self);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Checked cursor over an input buffer.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "wire: unexpected end of input");
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Raw byte run of a known length (e.g. an endorsement root digest).
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Length prefix, sanity-bounded by the remaining input so corrupted
    /// prefixes cannot trigger absurd allocations.
    pub fn get_len(&mut self) -> Result<usize> {
        let n = self.get_u32()? as usize;
        ensure!(n <= self.remaining(), "wire: length prefix exceeds input");
        Ok(n)
    }

    pub fn get<T: FromWire>(&mut self) -> Result<T> {
        T::from_wire(self)
    }

    /// Length-prefixed vector of compressed points, decoded with ONE
    /// batched decompression pass ([`G1Affine::batch_from_bytes_compressed`])
    /// instead of a sqrt per element in the element loop — the point
    /// vectors dominate artifact decode time, and the batch path runs the
    /// root exponentiations across worker threads. Byte-compatible with
    /// the generic `Vec<G1Affine>` element-wise codec (equivalence is
    /// pinned by tests).
    pub fn get_points(&mut self) -> Result<Vec<G1Affine>> {
        let n = self.get_len()?;
        let total = n.checked_mul(32).context("wire: point vector overflow")?;
        let raw = self.take(total)?;
        let encodings: Vec<[u8; 32]> = raw
            .chunks_exact(32)
            .map(|c| c.try_into().unwrap())
            .collect();
        G1Affine::batch_from_bytes_compressed(&encodings).context("wire: invalid curve point")
    }

    /// The input must be consumed exactly.
    pub fn expect_end(&self) -> Result<()> {
        ensure!(self.remaining() == 0, "wire: {} trailing bytes", self.remaining());
        Ok(())
    }
}

/// Encode `self` into the writer.
pub trait ToWire {
    fn to_wire(&self, w: &mut WireWriter);
}

/// Decode an instance from the reader, rejecting malformed input.
pub trait FromWire: Sized {
    fn from_wire(r: &mut WireReader) -> Result<Self>;
}

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

impl ToWire for Fr {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_bytes(&self.to_bytes());
    }
}

impl FromWire for Fr {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let raw: [u8; 32] = r.take(32)?.try_into().unwrap();
        let v = Fr::from_bytes(&raw);
        // `from_bytes` reduces silently; only canonical encodings round-trip.
        ensure!(v.to_bytes() == raw, "wire: non-canonical field element");
        Ok(v)
    }
}

impl ToWire for G1Affine {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_bytes(&self.to_bytes_compressed());
    }
}

impl FromWire for G1Affine {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let raw: [u8; 32] = r.take(32)?.try_into().unwrap();
        G1Affine::from_bytes_compressed(&raw).context("wire: invalid curve point")
    }
}

impl ToWire for u32 {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_u32(*self);
    }
}

impl FromWire for u32 {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        r.get_u32()
    }
}

impl<T: ToWire> ToWire for Vec<T> {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_len(self.len());
        for item in self {
            item.to_wire(w);
        }
    }
}

impl<T: FromWire> FromWire for Vec<T> {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let n = r.get_len()?;
        // cap the up-front reservation: `n` is bounded by remaining *bytes*,
        // but elements are many bytes wide — a corrupted prefix must not
        // amplify into a huge allocation before the first element fails
        let mut out = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            out.push(T::from_wire(r)?);
        }
        Ok(out)
    }
}

impl<T: ToWire> ToWire for Option<T> {
    fn to_wire(&self, w: &mut WireWriter) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.to_wire(w);
            }
        }
    }
}

impl<T: FromWire> FromWire for Option<T> {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::from_wire(r)?)),
            other => bail!("wire: invalid option tag {other}"),
        }
    }
}

impl ToWire for (Fr, Fr) {
    fn to_wire(&self, w: &mut WireWriter) {
        self.0.to_wire(w);
        self.1.to_wire(w);
    }
}

impl FromWire for (Fr, Fr) {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok((r.get()?, r.get()?))
    }
}

impl ToWire for [Fr; 5] {
    fn to_wire(&self, w: &mut WireWriter) {
        for v in self {
            v.to_wire(w);
        }
    }
}

impl FromWire for [Fr; 5] {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok([r.get()?, r.get()?, r.get()?, r.get()?, r.get()?])
    }
}

// ---------------------------------------------------------------------------
// Proof components
// ---------------------------------------------------------------------------

impl ToWire for ModelConfig {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_u32(self.depth as u32);
        w.put_u32(self.width as u32);
        w.put_u32(self.batch as u32);
        w.put_u32(self.r_bits);
        w.put_u32(self.q_bits);
        w.put_u32(self.lr_shift);
    }
}

impl FromWire for ModelConfig {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let depth = r.get_u32()? as usize;
        let width = r.get_u32()? as usize;
        let batch = r.get_u32()? as usize;
        let r_bits = r.get_u32()?;
        let q_bits = r.get_u32()?;
        let lr_shift = r.get_u32()?;
        // resource bounds: decoded configs drive key setup before the proof
        // body is validated, so untrusted files must not be able to request
        // absurd basis sizes (paper maximum is width 4096)
        ensure!(depth >= 1 && depth <= 256, "wire: bad depth");
        ensure!(
            width.is_power_of_two() && width <= 4096,
            "wire: bad width (power of two ≤ 4096 required)"
        );
        ensure!(
            batch.is_power_of_two() && batch <= 4096,
            "wire: bad batch (power of two ≤ 4096 required)"
        );
        ensure!(
            r_bits >= 1 && q_bits >= 2 && r_bits + q_bits <= 64,
            "wire: bad quantization bits"
        );
        // the zkReLU e_bit tables require power-of-two decomposition widths,
        // and the zkSGD chain needs ≥ 2 update-remainder digits — reject
        // configs the verifier would otherwise abort on
        ensure!(
            r_bits.is_power_of_two() && q_bits.is_power_of_two(),
            "wire: quantization widths must be powers of two"
        );
        ensure!(lr_shift <= 63, "wire: bad lr shift");
        ensure!(
            r_bits + lr_shift >= 2,
            "wire: degenerate update-remainder width"
        );
        Ok(ModelConfig {
            depth,
            width,
            batch,
            r_bits,
            q_bits,
            lr_shift,
        })
    }
}

impl ToWire for ProofMode {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_u8(match self {
            ProofMode::Parallel => 0,
            ProofMode::Sequential => 1,
        });
    }
}

impl FromWire for ProofMode {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        match r.get_u8()? {
            0 => Ok(ProofMode::Parallel),
            1 => Ok(ProofMode::Sequential),
            other => bail!("wire: unknown proof mode {other}"),
        }
    }
}

impl ToWire for SumcheckProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_u32(self.degree as u32);
        w.put_u32(self.num_vars as u32);
        w.put(&self.round_evals);
    }
}

impl FromWire for SumcheckProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let degree = r.get_u32()? as usize;
        let num_vars = r.get_u32()? as usize;
        let round_evals: Vec<Vec<Fr>> = r.get()?;
        Ok(SumcheckProof {
            round_evals,
            degree,
            num_vars,
        })
    }
}

impl ToWire for IpaProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.l);
        w.put(&self.r);
        w.put(&self.a);
        w.put(&self.b);
        w.put(&self.blind);
    }
}

impl FromWire for IpaProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(IpaProof {
            l: r.get_points()?,
            r: r.get_points()?,
            a: r.get()?,
            b: r.get()?,
            blind: r.get()?,
        })
    }
}

impl ToWire for Protocol1Msg {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.com_b_ip);
        w.put(&self.com_sign_prime);
    }
}

impl FromWire for Protocol1Msg {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(Protocol1Msg {
            com_b_ip: r.get()?,
            com_sign_prime: r.get()?,
        })
    }
}

impl ToWire for ValidityProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.ipa);
    }
}

impl FromWire for ValidityProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(ValidityProof { ipa: r.get()? })
    }
}

impl ToWire for GroupProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.p1_main);
        w.put(&self.p1_rem);
        w.put(&self.v_z);
        w.put(&self.v_ga);
        w.put(&self.v_gw);
        w.put(&self.mm30);
        w.put(&self.mm30_evals);
        w.put(&self.mm33);
        w.put(&self.mm33_evals);
        w.put(&self.mm34);
        w.put(&self.mm34_evals);
        w.put(&self.stack);
        w.put(&self.va1);
        w.put(&self.va2);
        w.put(&self.vgz1);
        w.put(&self.vgz2);
        w.put(&self.aux_evals);
        w.put(&self.openings);
        w.put(&self.validity_main);
        w.put(&self.validity_rem);
    }
}

impl FromWire for GroupProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(GroupProof {
            p1_main: r.get()?,
            p1_rem: r.get()?,
            v_z: r.get()?,
            v_ga: r.get()?,
            v_gw: r.get()?,
            mm30: r.get()?,
            mm30_evals: r.get()?,
            mm33: r.get()?,
            mm33_evals: r.get()?,
            mm34: r.get()?,
            mm34_evals: r.get()?,
            stack: r.get()?,
            va1: r.get()?,
            va2: r.get()?,
            vgz1: r.get()?,
            vgz2: r.get()?,
            aux_evals: r.get()?,
            openings: r.get()?,
            validity_main: r.get()?,
            validity_rem: r.get()?,
        })
    }
}

impl ToWire for StepProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.mode);
        w.put(&self.com_w);
        w.put(&self.com_gw);
        w.put(&self.com_zdp);
        w.put(&self.com_sign);
        w.put(&self.com_rz);
        w.put(&self.com_gap);
        w.put(&self.com_rga);
        w.put(&self.com_x);
        w.put(&self.com_y);
        w.put(&self.groups);
    }
}

impl FromWire for StepProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(StepProof {
            mode: r.get()?,
            com_w: r.get_points()?,
            com_gw: r.get_points()?,
            com_zdp: r.get_points()?,
            com_sign: r.get_points()?,
            com_rz: r.get_points()?,
            com_gap: r.get_points()?,
            com_rga: r.get_points()?,
            com_x: r.get()?,
            com_y: r.get()?,
            groups: r.get()?,
        })
    }
}

impl ToWire for StepCommitmentSet {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.com_w);
        w.put(&self.com_gw);
        w.put(&self.com_zdp);
        w.put(&self.com_sign);
        w.put(&self.com_rz);
        w.put(&self.com_gap);
        w.put(&self.com_rga);
        w.put(&self.com_x);
        w.put(&self.com_y);
    }
}

impl FromWire for StepCommitmentSet {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(StepCommitmentSet {
            com_w: r.get_points()?,
            com_gw: r.get_points()?,
            com_zdp: r.get_points()?,
            com_sign: r.get_points()?,
            com_rz: r.get_points()?,
            com_gap: r.get_points()?,
            com_rga: r.get_points()?,
            com_x: r.get()?,
            com_y: r.get()?,
        })
    }
}

impl ToWire for UpdateRule {
    fn to_wire(&self, w: &mut WireWriter) {
        match *self {
            UpdateRule::Sgd => w.put_u8(RULE_TAG_SGD),
            UpdateRule::Momentum {
                beta_num,
                beta_shift,
            } => {
                w.put_u8(RULE_TAG_MOMENTUM);
                w.put_u32(beta_num);
                w.put_u32(beta_shift);
            }
        }
    }
}

impl FromWire for UpdateRule {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let rule = match r.get_u8()? {
            RULE_TAG_SGD => UpdateRule::Sgd,
            RULE_TAG_MOMENTUM => UpdateRule::Momentum {
                beta_num: r.get_u32()?,
                beta_shift: r.get_u32()?,
            },
            other => bail!("wire: unknown update-rule tag {other}"),
        };
        rule.validate().context("wire: update rule")?;
        Ok(rule)
    }
}

impl ToWire for ChainProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.rule);
        w.put(&self.lr_shifts);
        w.put(&self.com_state);
        w.put(&self.com_u);
        w.put(&self.p1_upd);
        w.put(&self.v_w);
        w.put(&self.v_gw);
        w.put(&self.v_state);
        w.put(&self.v_stack);
        w.put(&self.openings);
        w.put(&self.validity);
    }
}

impl FromWire for ChainProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let rule: UpdateRule = r.get()?;
        let lr_shifts: Vec<u32> = r.get()?;
        let n_rows = r.get_len()?;
        let mut com_state = Vec::with_capacity(n_rows.min(4096));
        for _ in 0..n_rows {
            com_state.push(r.get_points()?);
        }
        Ok(ChainProof {
            rule,
            lr_shifts,
            com_state,
            com_u: r.get()?,
            p1_upd: r.get()?,
            v_w: r.get()?,
            v_gw: r.get()?,
            v_state: r.get()?,
            v_stack: r.get()?,
            openings: r.get()?,
            validity: r.get()?,
        })
    }
}

impl ToWire for DatasetCommitment {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_u64(self.n_rows as u64);
        w.put(&self.com_d);
        w.put_len(self.root.len());
        w.put_bytes(&self.root);
    }
}

impl FromWire for DatasetCommitment {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let n_rows = r.get_u64()? as usize;
        ensure!(n_rows >= 1, "wire: empty dataset commitment");
        let com_d: G1Affine = r.get()?;
        let n = r.get_len()?;
        ensure!(
            n == crate::provenance::PROVENANCE_HASH.output_len(),
            "wire: bad endorsement root length {n}"
        );
        let root = r.get_raw(n)?.to_vec();
        Ok(DatasetCommitment { n_rows, com_d, root })
    }
}

impl ToWire for ProvenanceProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put(&self.dataset);
        w.put(&self.com_s);
        w.put(&self.p1_sel);
        w.put(&self.v_x);
        w.put(&self.v_y);
        w.put(&self.sel);
        w.put(&self.sel_evals);
        w.put(&self.v_dpts);
        w.put(&self.v_dlab);
        w.put(&self.v_sel);
        w.put(&self.openings);
        w.put(&self.validity);
    }
}

impl FromWire for ProvenanceProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        Ok(ProvenanceProof {
            dataset: r.get()?,
            com_s: r.get()?,
            p1_sel: r.get()?,
            v_x: r.get()?,
            v_y: r.get()?,
            sel: r.get()?,
            sel_evals: r.get()?,
            v_dpts: r.get()?,
            v_dlab: r.get()?,
            v_sel: r.get()?,
            openings: r.get()?,
            validity: r.get()?,
        })
    }
}

impl ToWire for TraceProof {
    fn to_wire(&self, w: &mut WireWriter) {
        w.put_u32(self.steps as u32);
        w.put(&self.coms);
        w.put(&self.p1_main);
        w.put(&self.p1_rem);
        w.put(&self.v_z);
        w.put(&self.v_ga);
        w.put(&self.v_gw);
        w.put(&self.mm30);
        w.put(&self.mm30_evals);
        w.put(&self.mm33);
        w.put(&self.mm33_evals);
        w.put(&self.mm34);
        w.put(&self.mm34_evals);
        w.put(&self.stack);
        w.put(&self.va1);
        w.put(&self.va2);
        w.put(&self.vgz1);
        w.put(&self.vgz2);
        w.put(&self.aux_evals);
        w.put(&self.openings);
        w.put(&self.validity_main);
        w.put(&self.validity_rem);
        w.put(&self.chain);
        w.put(&self.provenance);
    }
}

impl FromWire for TraceProof {
    fn from_wire(r: &mut WireReader) -> Result<Self> {
        let steps = r.get_u32()? as usize;
        ensure!(steps >= 1 && steps <= 1 << 16, "wire: bad step count");
        Ok(TraceProof {
            steps,
            coms: r.get()?,
            p1_main: r.get()?,
            p1_rem: r.get()?,
            v_z: r.get()?,
            v_ga: r.get()?,
            v_gw: r.get()?,
            mm30: r.get()?,
            mm30_evals: r.get()?,
            mm33: r.get()?,
            mm33_evals: r.get()?,
            mm34: r.get()?,
            mm34_evals: r.get()?,
            stack: r.get()?,
            va1: r.get()?,
            va2: r.get()?,
            vgz1: r.get()?,
            vgz2: r.get()?,
            aux_evals: r.get()?,
            openings: r.get()?,
            validity_main: r.get()?,
            validity_rem: r.get()?,
            chain: r.get()?,
            provenance: r.get()?,
        })
    }
}

// ---------------------------------------------------------------------------
// Envelope
// ---------------------------------------------------------------------------

fn encode_envelope(kind: ProofKind, cfg: &ModelConfig, body: &dyn ToWire) -> Vec<u8> {
    crate::span!("wire/encode");
    let mut w = WireWriter::new();
    w.put_bytes(&MAGIC);
    w.put_u16(VERSION);
    w.put_u16(kind.tag());
    w.put(cfg);
    body.to_wire(&mut w);
    let bytes = w.finish();
    crate::telemetry::count(
        crate::telemetry::Counter::WireBytesEncoded,
        bytes.len() as u64,
    );
    crate::telemetry::hist::record(
        crate::telemetry::hist::Hist::WireBytes,
        bytes.len() as u64,
    );
    bytes
}

fn decode_envelope<'a>(bytes: &'a [u8], want: ProofKind) -> Result<(ModelConfig, WireReader<'a>)> {
    crate::telemetry::count(
        crate::telemetry::Counter::WireBytesDecoded,
        bytes.len() as u64,
    );
    crate::telemetry::hist::record(
        crate::telemetry::hist::Hist::WireBytes,
        bytes.len() as u64,
    );
    ensure!(
        bytes.len() <= MAX_ARTIFACT_BYTES,
        "wire: artifact of {} bytes exceeds the {MAX_ARTIFACT_BYTES}-byte limit",
        bytes.len()
    );
    let mut r = WireReader::new(bytes);
    let magic = r.take(4)?;
    ensure!(magic == MAGIC.as_slice(), "wire: bad magic");
    let version = r.get_u16()?;
    crate::ensure_class!(
        version == VERSION,
        crate::telemetry::failure::VerifyFailureClass::VersionUnsupported,
        "wire: unsupported version {version}"
    );
    let kind = ProofKind::from_tag(r.get_u16()?)?;
    ensure!(kind == want, "wire: expected {want:?} payload, found {kind:?}");
    let cfg: ModelConfig = r.get()?;
    Ok((cfg, r))
}

/// Serialize one per-step proof with its configuration.
pub fn encode_step_proof(cfg: &ModelConfig, proof: &StepProof) -> Vec<u8> {
    encode_envelope(ProofKind::Step, cfg, proof)
}

/// Parse a [`encode_step_proof`] artifact, rejecting malformed input.
/// Rejections carry the `wire-decode` failure class (or the more specific
/// `version-unsupported`, which wins under attach-once).
pub fn decode_step_proof(bytes: &[u8]) -> Result<(ModelConfig, StepProof)> {
    crate::span!("wire/decode");
    let inner = || -> Result<(ModelConfig, StepProof)> {
        let (cfg, mut r) = decode_envelope(bytes, ProofKind::Step)?;
        let proof: StepProof = r.get()?;
        r.expect_end()?;
        Ok((cfg, proof))
    };
    inner().map_err(|e| {
        crate::telemetry::failure::classified(
            crate::telemetry::failure::VerifyFailureClass::WireDecode,
            e,
        )
    })
}

/// Serialize an aggregated trace proof with its configuration.
pub fn encode_trace_proof(cfg: &ModelConfig, proof: &TraceProof) -> Vec<u8> {
    encode_envelope(ProofKind::Trace, cfg, proof)
}

/// Largest trace-stacked aux basis a decoded artifact may request
/// (`verify-trace` derives keys from the embedded config before the proof
/// body can be checked, so this is the decoder's resource ceiling).
pub const MAX_TRACE_AUX_SIZE: usize = 1 << 28;

/// Parse an [`encode_trace_proof`] artifact, rejecting malformed input.
/// Beyond the envelope, this enforces the structural invariants that key
/// setup and verification rely on: per-step commitment counts match the
/// config's depth, and the implied trace basis stays within
/// [`MAX_TRACE_AUX_SIZE`].
/// Rejections carry the `wire-decode` failure class (or the more specific
/// `version-unsupported`, which wins under attach-once).
pub fn decode_trace_proof(bytes: &[u8]) -> Result<(ModelConfig, TraceProof)> {
    crate::span!("wire/decode");
    decode_trace_proof_inner(bytes).map_err(|e| {
        crate::telemetry::failure::classified(
            crate::telemetry::failure::VerifyFailureClass::WireDecode,
            e,
        )
    })
}

fn decode_trace_proof_inner(bytes: &[u8]) -> Result<(ModelConfig, TraceProof)> {
    let (cfg, mut r) = decode_envelope(bytes, ProofKind::Trace)?;
    let proof: TraceProof = r.get()?;
    r.expect_end()?;
    ensure!(proof.coms.len() == proof.steps, "wire: commitment set count");
    for set in &proof.coms {
        ensure!(
            set.com_w.len() == cfg.depth
                && set.com_gw.len() == cfg.depth
                && set.com_zdp.len() == cfg.depth
                && set.com_sign.len() == cfg.depth
                && set.com_rz.len() == cfg.depth
                && set.com_gap.len() == cfg.depth
                && set.com_rga.len() == cfg.depth,
            "wire: per-step commitment count"
        );
    }
    let n = proof
        .steps
        .next_power_of_two()
        .checked_mul(cfg.depth.next_power_of_two())
        .and_then(|x| x.checked_mul(cfg.d_size()))
        .context("wire: trace dimensions overflow")?;
    ensure!(
        n <= MAX_TRACE_AUX_SIZE,
        "wire: trace basis of {n} elements exceeds the decoder limit"
    );
    if let Some(chain) = &proof.chain {
        // rule parameters, shift-table digit budgets, state/evaluation
        // tensor counts, the degenerate 1-element stack, and dimension
        // overflow — the verifier's key setup would otherwise panic (or
        // compute a wrong-shaped instance) on untrusted input
        crate::update::validate_chain_shape(&cfg, proof.steps, chain)
            .context("wire: chain payload")?;
        let (_, _, _, n_upd) =
            crate::update::checked_stack_dims(&cfg, proof.steps, chain.rule.n_rem())
                .context("wire: chain dimensions")?;
        ensure!(
            n_upd <= MAX_TRACE_AUX_SIZE,
            "wire: chain basis of {n_upd} elements exceeds the decoder limit"
        );
    }
    if let Some(prov) = &proof.provenance {
        // claim-vector lengths, opening count, the booleanity instance's
        // sign commitment, degenerate shapes, dimension overflow — the
        // verifier's key setup would otherwise panic on untrusted input
        crate::provenance::validate_provenance_shape(&cfg, proof.steps, prov)
            .context("wire: provenance payload")?;
        let (_, _, n_sel, n_data) =
            crate::provenance::checked_selection_dims(&cfg, proof.steps, prov.dataset.n_rows)
                .context("wire: provenance dimensions")?;
        ensure!(
            n_sel <= MAX_TRACE_AUX_SIZE && n_data <= MAX_TRACE_AUX_SIZE,
            "wire: provenance bases ({n_sel} selection, {n_data} dataset) exceed the decoder limit"
        );
    }
    Ok((cfg, proof))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curve::G1;
    use crate::util::rng::Rng;

    #[test]
    fn primitive_roundtrips() {
        let mut rng = Rng::seed_from_u64(0x111e);
        let fr = Fr::random(&mut rng);
        let pt = G1::random(&mut rng).to_affine();
        let mut w = WireWriter::new();
        w.put(&fr);
        w.put(&pt);
        w.put(&G1Affine::IDENTITY);
        w.put(&Some(fr));
        w.put(&None::<Fr>);
        w.put(&vec![fr, fr + Fr::ONE]);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.get::<Fr>().unwrap(), fr);
        assert_eq!(r.get::<G1Affine>().unwrap(), pt);
        assert_eq!(r.get::<G1Affine>().unwrap(), G1Affine::IDENTITY);
        assert_eq!(r.get::<Option<Fr>>().unwrap(), Some(fr));
        assert_eq!(r.get::<Option<Fr>>().unwrap(), None);
        assert_eq!(r.get::<Vec<Fr>>().unwrap(), vec![fr, fr + Fr::ONE]);
        r.expect_end().unwrap();
    }

    #[test]
    fn point_vectors_roundtrip_through_batched_decoder() {
        // get_points must parse exactly the bytes the element-wise encoder
        // writes — including identities — and reject malformed elements
        let mut rng = Rng::seed_from_u64(0x917);
        let mut pts: Vec<G1Affine> = (0..9).map(|_| G1::random(&mut rng).to_affine()).collect();
        pts.push(G1Affine::IDENTITY);
        let mut w = WireWriter::new();
        w.put(&pts);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.get_points().unwrap(), pts);
        r.expect_end().unwrap();
        // corrupt one element: the batch fails like the scalar path would
        let mut bad = bytes.clone();
        bad[4 + 3 * 32 + 31] = 0xc0;
        let mut r = WireReader::new(&bad);
        assert!(r.get_points().is_err());
        // truncation inside the vector body
        let mut r = WireReader::new(&bytes[..bytes.len() - 1]);
        assert!(r.get_points().is_err());
    }

    #[test]
    fn update_rule_and_shift_table_roundtrip() {
        for rule in [
            UpdateRule::Sgd,
            UpdateRule::momentum_default(),
            UpdateRule::Momentum {
                beta_num: 3,
                beta_shift: 2,
            },
        ] {
            let shifts: Vec<u32> = vec![8, 9, 10];
            let mut w = WireWriter::new();
            w.put(&rule);
            w.put(&shifts);
            let bytes = w.finish();
            let mut r = WireReader::new(&bytes);
            assert_eq!(r.get::<UpdateRule>().unwrap(), rule);
            assert_eq!(r.get::<Vec<u32>>().unwrap(), vec![8, 9, 10]);
            r.expect_end().unwrap();
        }
        // unknown tag and invalid β are rejected at decode time
        let mut r = WireReader::new(&[7u8]);
        assert!(r.get::<UpdateRule>().is_err());
        let mut w = WireWriter::new();
        w.put_u8(crate::update::rule::RULE_TAG_MOMENTUM);
        w.put_u32(8); // β = 8/8 = 1: not a contraction
        w.put_u32(3);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert!(r.get::<UpdateRule>().is_err());
    }

    #[test]
    fn rejects_non_canonical_scalar() {
        let bytes = [0xffu8; 32];
        let mut r = WireReader::new(&bytes);
        assert!(r.get::<Fr>().is_err());
    }

    #[test]
    fn rejects_invalid_point_encodings() {
        // malformed identity: infinity flag plus a sign bit
        let mut bytes = [0u8; 32];
        bytes[31] = 0xc0;
        let mut r = WireReader::new(&bytes);
        assert!(r.get::<G1Affine>().is_err());
        // some x below 32 has no y with y² = x³ + 3 (non-residue)
        let rejected = (0u64..32).any(|v| {
            let mut b = [0u8; 32];
            b[..8].copy_from_slice(&v.to_le_bytes());
            WireReader::new(&b).get::<G1Affine>().is_err()
        });
        assert!(rejected, "expected a non-decodable x below 32");
    }

    #[test]
    fn rejects_truncation_and_bad_length() {
        let mut w = WireWriter::new();
        w.put(&vec![Fr::ONE; 3]);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes[..bytes.len() - 1]);
        assert!(r.get::<Vec<Fr>>().is_err());
        // length prefix claiming more than the input holds
        let mut huge = 1000u32.to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 8]);
        let mut r = WireReader::new(&huge);
        assert!(r.get::<Vec<Fr>>().is_err());
    }
}
