//! The sumcheck protocol (Lund–Fortnow–Karloff–Nisan), linear-time prover.
//!
//! Proves claims of the form
//!     claimed = Σ_{b ∈ {0,1}ⁿ} Σ_t c_t · Π_j f_{t,j}(b)
//! where each f_{t,j} is a multilinear polynomial given by its evaluation
//! table. Products of up to three multilinears cover every relation in
//! zkDL: matmul layers are eq·A·W (degree ≤ 2 after fixing outputs),
//! Hadamard/ReLU relations are eq·(1−B)·Z (degree 3), and the stacking
//! equation (27) is a two-term degree-3 instance.
//!
//! The prover sends, per round, the round polynomial's evaluations at
//! 0..=deg; the verifier checks g(0)+g(1) against the running claim and
//! evaluates g at the Fiat–Shamir challenge by Lagrange interpolation.
//! Proof size: n·(deg+1) field elements — the paper's O(log) per-relation
//! proof-size building block.

use crate::field::Fr;
use crate::poly::{interpolate_uni, Mle};
use crate::transcript::Transcript;
use crate::util::threads;
use anyhow::{bail, Result};

/// Maximum product degree (factors per term) an instance may carry. The
/// prover's per-index line scratch is a stack array sized by this, which is
/// what makes the inner loop allocation-free; every relation in zkDL has
/// degree ≤ 3 (eq·(1−B)·Z), so 4 leaves headroom. Enforced by
/// [`Instance::new`].
pub const MAX_FACTORS: usize = 4;

/// Parallelism floor for the round-evaluation split: below this many
/// hypercube indices a round carries ≲10µs of multiply-adds total, where a
/// pooled dispatch no longer pays (measured crossover ≈64 on 8 lanes; see
/// `util::threads` threshold notes).
const PAR_MIN_HALF: usize = 64;

/// One product term: coefficient × product of multilinear factors.
pub struct Term {
    pub coeff: Fr,
    pub factors: Vec<Mle>,
}

impl Term {
    pub fn new(coeff: Fr, factors: Vec<Mle>) -> Self {
        Self { coeff, factors }
    }
}

/// A sumcheck instance: Σ_b Σ_t c_t Π_j f_{t,j}(b).
pub struct Instance {
    pub terms: Vec<Term>,
    pub num_vars: usize,
}

impl Instance {
    pub fn new(terms: Vec<Term>) -> Self {
        let num_vars = terms
            .first()
            .and_then(|t| t.factors.first())
            .map(|f| f.num_vars)
            .expect("instance needs at least one factor");
        for t in &terms {
            assert!(
                t.factors.len() <= MAX_FACTORS,
                "term degree {} exceeds MAX_FACTORS = {MAX_FACTORS}",
                t.factors.len()
            );
            for f in &t.factors {
                assert_eq!(f.num_vars, num_vars, "factor arity mismatch");
            }
        }
        Self { terms, num_vars }
    }

    /// Max product degree across terms (the round-polynomial degree).
    pub fn degree(&self) -> usize {
        self.terms.iter().map(|t| t.factors.len()).max().unwrap()
    }

    /// Direct evaluation of the sum (for testing / the honest prover's
    /// claim). Chunk-reduced on the pool: per-chunk partials are combined
    /// in ascending chunk order, which for exact field addition equals the
    /// sequential sum bit-for-bit at every lane count.
    pub fn sum(&self) -> Fr {
        let n = 1usize << self.num_vars;
        threads::par_reduce(
            n,
            1 << 10,
            Fr::ZERO,
            |range, mut acc| {
                for t in &self.terms {
                    for b in range.clone() {
                        let mut prod = t.coeff;
                        for f in &t.factors {
                            prod *= f.evals[b];
                        }
                        acc += prod;
                    }
                }
                acc
            },
            |a, b| a + b,
        )
    }
}

/// Proof: per-round evaluations of the round polynomial at 0..=deg.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SumcheckProof {
    pub round_evals: Vec<Vec<Fr>>,
    pub degree: usize,
    pub num_vars: usize,
}

impl SumcheckProof {
    /// Proof size in bytes (32 B per field element).
    pub fn size_bytes(&self) -> usize {
        self.round_evals.iter().map(|r| r.len() * 32).sum()
    }
}

/// Output of proving: the proof, the challenge point r, and the evaluation
/// of each term's factors at r (in instance order) for the caller to open.
pub struct ProverOutput {
    pub proof: SumcheckProof,
    pub point: Vec<Fr>,
    pub factor_evals: Vec<Vec<Fr>>,
}

/// Run the sumcheck prover. Mutates (consumes) the instance's tables.
pub fn prove(mut inst: Instance, transcript: &mut Transcript) -> ProverOutput {
    crate::span!("sumcheck/prove");
    crate::telemetry::count(
        crate::telemetry::Counter::SumcheckProveRounds,
        inst.num_vars as u64,
    );
    let num_vars = inst.num_vars;
    let deg = inst.degree();
    let mut rounds = Vec::with_capacity(num_vars);
    let mut point = Vec::with_capacity(num_vars);

    for _round in 0..num_vars {
        let half = inst.terms[0].factors[0].len() / 2;
        // Round polynomial evaluations at X = 0..=deg, accumulated
        // chunk-wise on the zkLanes pool. Each chunk owns a stack
        // `[Fr; MAX_FACTORS + 1]` partial plus a fixed per-factor line
        // scratch, so the inner loop performs zero heap allocations per
        // hypercube index (asserted via the counting allocator in
        // tests/telemetry.rs). Partials are summed in ascending chunk
        // order; exact field addition makes the result independent of the
        // chunking, so transcript bytes are identical for every
        // ZKDL_THREADS (pinned by tests/parallel_determinism.rs).
        let terms = &inst.terms;
        let acc = threads::par_reduce(
            half,
            PAR_MIN_HALF,
            [Fr::ZERO; MAX_FACTORS + 1],
            |range, mut acc| {
                crate::telemetry::count(crate::telemetry::Counter::SumcheckParChunks, 1);
                // per-factor line: f(X) = lo + X·(hi − lo)
                let mut lines = [(Fr::ZERO, Fr::ZERO); MAX_FACTORS];
                for t in terms {
                    let nf = t.factors.len();
                    for i in range.clone() {
                        for (line, f) in lines[..nf].iter_mut().zip(&t.factors) {
                            let lo = f.evals[i];
                            *line = (lo, f.evals[i + half] - lo);
                        }
                        let mut x = Fr::ZERO;
                        for e in acc[..=deg].iter_mut() {
                            let mut prod = t.coeff;
                            for &(lo, slope) in &lines[..nf] {
                                prod *= lo + x * slope;
                            }
                            *e += prod;
                            x += Fr::ONE;
                        }
                    }
                }
                acc
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
        );
        let evals = acc[..=deg].to_vec();
        transcript.absorb_frs(b"sumcheck/round", &evals);
        let r = transcript.challenge_fr(b"sumcheck/challenge");
        for t in inst.terms.iter_mut() {
            for f in t.factors.iter_mut() {
                f.fold(r);
            }
        }
        point.push(r);
        rounds.push(evals);
    }

    let factor_evals = inst
        .terms
        .iter()
        .map(|t| t.factors.iter().map(|f| f.evals[0]).collect())
        .collect();

    ProverOutput {
        proof: SumcheckProof {
            round_evals: rounds,
            degree: deg,
            num_vars,
        },
        point,
        factor_evals,
    }
}

/// Output of verification: the challenge point and the final reduced claim
/// Σ_t c_t Π_j f_{t,j}(r), which the caller must check against openings.
pub struct VerifierOutput {
    pub point: Vec<Fr>,
    pub final_claim: Fr,
}

/// Verify the round structure of a sumcheck proof against `claimed_sum`.
pub fn verify(
    claimed_sum: Fr,
    proof: &SumcheckProof,
    transcript: &mut Transcript,
) -> Result<VerifierOutput> {
    if proof.round_evals.len() != proof.num_vars {
        bail!("sumcheck: wrong number of rounds");
    }
    crate::span!("sumcheck/verify");
    crate::telemetry::count(
        crate::telemetry::Counter::SumcheckVerifyRounds,
        proof.num_vars as u64,
    );
    let mut claim = claimed_sum;
    let mut point = Vec::with_capacity(proof.num_vars);
    for evals in &proof.round_evals {
        if evals.len() != proof.degree + 1 {
            bail!("sumcheck: wrong round polynomial degree");
        }
        if evals[0] + evals[1] != claim {
            bail!("sumcheck: round consistency check failed");
        }
        transcript.absorb_frs(b"sumcheck/round", evals);
        let r = transcript.challenge_fr(b"sumcheck/challenge");
        claim = interpolate_uni(evals, r);
        point.push(r);
    }
    Ok(VerifierOutput {
        point,
        final_claim: claim,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::eq_table;
    use crate::util::rng::Rng;

    fn rng() -> Rng {
        Rng::seed_from_u64(0x5c5c)
    }

    fn random_mle(r: &mut Rng, nv: usize) -> Mle {
        Mle::new((0..1 << nv).map(|_| Fr::random(r)).collect())
    }

    fn roundtrip(inst: Instance) {
        let claimed = inst.sum();
        let terms_meta: Vec<(Fr, usize)> = inst
            .terms
            .iter()
            .map(|t| (t.coeff, t.factors.len()))
            .collect();
        let mut tp = Transcript::new(b"test");
        let out = prove(inst, &mut tp);
        let mut tv = Transcript::new(b"test");
        let v = verify(claimed, &out.proof, &mut tv).expect("verify");
        assert_eq!(v.point, out.point);
        // final claim must equal Σ_t c_t Π f(r)
        let mut expect = Fr::ZERO;
        for ((c, nf), fe) in terms_meta.iter().zip(out.factor_evals.iter()) {
            assert_eq!(*nf, fe.len());
            expect += *c * fe.iter().copied().product::<Fr>();
        }
        assert_eq!(v.final_claim, expect);
    }

    #[test]
    fn single_mle_sum() {
        let mut r = rng();
        let m = random_mle(&mut r, 6);
        roundtrip(Instance::new(vec![Term::new(Fr::ONE, vec![m])]));
    }

    #[test]
    fn product_of_two() {
        let mut r = rng();
        let a = random_mle(&mut r, 5);
        let b = random_mle(&mut r, 5);
        roundtrip(Instance::new(vec![Term::new(Fr::from_u64(7), vec![a, b])]));
    }

    #[test]
    fn product_of_three_with_eq() {
        let mut r = rng();
        let u: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let eq = Mle::new(eq_table(&u));
        let a = random_mle(&mut r, 4);
        let b = random_mle(&mut r, 4);
        roundtrip(Instance::new(vec![Term::new(Fr::ONE, vec![eq, a, b])]));
    }

    #[test]
    fn multi_term() {
        let mut r = rng();
        let a = random_mle(&mut r, 4);
        let b = random_mle(&mut r, 4);
        let c = random_mle(&mut r, 4);
        roundtrip(Instance::new(vec![
            Term::new(Fr::random(&mut r), vec![a.clone(), b]),
            Term::new(Fr::random(&mut r), vec![c, a]),
        ]));
    }

    #[test]
    fn rejects_wrong_claim() {
        let mut r = rng();
        let m = random_mle(&mut r, 4);
        let claimed = Instance::new(vec![Term::new(Fr::ONE, vec![m.clone()])]).sum();
        let mut tp = Transcript::new(b"t");
        let out = prove(
            Instance::new(vec![Term::new(Fr::ONE, vec![m])]),
            &mut tp,
        );
        let mut tv = Transcript::new(b"t");
        assert!(verify(claimed + Fr::ONE, &out.proof, &mut tv).is_err());
    }

    #[test]
    fn rejects_tampered_round() {
        let mut r = rng();
        let m = random_mle(&mut r, 4);
        let claimed = Instance::new(vec![Term::new(Fr::ONE, vec![m.clone()])]).sum();
        let mut tp = Transcript::new(b"t");
        let mut out = prove(
            Instance::new(vec![Term::new(Fr::ONE, vec![m])]),
            &mut tp,
        );
        out.proof.round_evals[2][0] += Fr::ONE;
        let mut tv = Transcript::new(b"t");
        assert!(verify(claimed, &out.proof, &mut tv).is_err());
    }

    #[test]
    fn matmul_shape_sumcheck() {
        // C(u,v) = Σ_w A(u,w) B(w,v): verify via sumcheck on fixed u,v
        let mut r = rng();
        let logn = 3usize;
        let n = 1 << logn;
        let a: Vec<Vec<Fr>> = (0..n)
            .map(|_| (0..n).map(|_| Fr::random(&mut r)).collect())
            .collect();
        let b: Vec<Vec<Fr>> = (0..n)
            .map(|_| (0..n).map(|_| Fr::random(&mut r)).collect())
            .collect();
        let u: Vec<Fr> = (0..logn).map(|_| Fr::random(&mut r)).collect();
        let v: Vec<Fr> = (0..logn).map(|_| Fr::random(&mut r)).collect();
        // A(u, ·) as an MLE over w
        let eu = eq_table(&u);
        let ev = eq_table(&v);
        let a_u: Vec<Fr> = (0..n)
            .map(|w| (0..n).map(|i| eu[i] * a[i][w]).sum())
            .collect();
        let b_v: Vec<Fr> = (0..n)
            .map(|w| (0..n).map(|j| ev[j] * b[w][j]).sum())
            .collect();
        let inst = Instance::new(vec![Term::new(
            Fr::ONE,
            vec![Mle::new(a_u), Mle::new(b_v)],
        )]);
        // claimed = C̃(u,v)
        let mut c_uv = Fr::ZERO;
        for i in 0..n {
            for j in 0..n {
                let mut dot = Fr::ZERO;
                for w in 0..n {
                    dot += a[i][w] * b[w][j];
                }
                c_uv += eu[i] * ev[j] * dot;
            }
        }
        assert_eq!(inst.sum(), c_uv);
        roundtrip(inst);
    }
}
