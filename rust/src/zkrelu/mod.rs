//! zkReLU — validity of the auxiliary inputs (paper §4.1).
//!
//! After the arithmetic sumchecks have produced verified evaluation claims
//! on the stacked auxiliary tensors at a random point ρ —
//!     v = (1−u″)·Z̃″(ρ) + u″·G̃_A′(ρ)   and   v_{Q−1} = B̃_{Q−1}(ρ) —
//! this module proves that the *committed* auxiliary inputs lie in their
//! prescribed ranges:
//!     Z″ ∈ [0, 2^{Q−1})ᴺ,  B_{Q−1} ∈ {0,1}ᴺ,  G_A′ ∈ [−2^{Q−1}, 2^{Q−1})ᴺ,
//! by reducing binarity + recomposition + pattern checks (eqs. 16–18) to the
//! single inner product (19), proven with one Bulletproofs IPA over vectors
//! of length 2NQ (Protocol 1 commitments + Algorithm 1 transformation).
//! A structurally identical second instance covers the rounding remainders
//! R_Z, R_{G_A} ∈ [−2^{R−1}, 2^{R−1})ᴺ.
//!
//! Key structural trick (paper Protocol 1, line 3): the commitment basis
//! G ∈ 𝔾^{2N×Q} satisfies G[0:N, Q−1] = g[0:N] — the same basis the sign
//! tensor B_{Q−1} is committed under — so com_{B_{Q−1}} *is* a valid
//! commitment of the padded B̄_{Q−1} and the sign column needs no separate
//! decomposition proof.

use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::{msm::msm, G1Affine, G1};
use crate::field::Fr;
use crate::ipa::{self, IpaBasis, IpaProof};
use crate::poly::{eq_eval_index, eq_table};
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use crate::util::threads;
use anyhow::{ensure, Result};

/// Active-digit layout of a validity instance: row i of the 2N rows has
/// `digits_at(i)` active digit columns out of the shared power-of-two
/// `width`; columns ≥ its digit count are zero-weight pads whose bits the
/// pattern check forces to zero, so row i's proven range is exactly
/// [−2^{digits_at(i)−1}, 2^{digits_at(i)−1}).
///
/// `Uniform(width)` recovers the paper's instances verbatim; a uniform
/// `digits < width` is the zkSGD padded-digit instance; `PerBlock` is the
/// zkOptim multi-width instance — one digit budget per remainder-tensor
/// block, so a momentum remainder (β_shift digits) and a learning-rate
/// remainder (R + lr_b digits, *varying per boundary*) ride one instance.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum DigitLayout {
    /// Every row uses the same digit count.
    Uniform(usize),
    /// Row i uses `digits[i / block]` — block-constant per-slot widths.
    PerBlock { block: usize, digits: Vec<usize> },
}

impl DigitLayout {
    pub fn digits_at(&self, row: usize) -> usize {
        match self {
            DigitLayout::Uniform(d) => *d,
            DigitLayout::PerBlock { block, digits } => digits[row / *block],
        }
    }

    /// Largest digit count of any row (the instance width must cover it).
    pub fn max_digits(&self) -> usize {
        match self {
            DigitLayout::Uniform(d) => *d,
            DigitLayout::PerBlock { digits, .. } => digits.iter().copied().max().unwrap_or(0),
        }
    }

    pub fn is_uniform_full(&self, width: usize) -> bool {
        matches!(self, DigitLayout::Uniform(d) if *d == width)
    }

    /// Structural validity against an instance of 2N rows and `width`
    /// columns: every digit count in 2..=width, and per-block layouts
    /// tiling the rows exactly.
    fn validate(&self, rows: usize, width: usize) {
        match self {
            DigitLayout::Uniform(d) => assert!((2..=width).contains(d)),
            DigitLayout::PerBlock { block, digits } => {
                assert!(*block >= 1);
                assert_eq!(block * digits.len(), rows, "layout must tile the rows");
                assert!(digits.iter().all(|d| (2..=width).contains(d)));
            }
        }
    }
}

/// Bases for one validity instance of row count 2N and bit width WIDTH;
/// see [`DigitLayout`] for the active-digit structure.
#[derive(Clone)]
pub struct ValidityBases {
    /// G ∈ 𝔾^{2N·W}; for the main instance G[i·W + (W−1)] = g_aux[i], i < N.
    pub big_g: Vec<G1Affine>,
    /// H ∈ 𝔾^{2N·W}, independent.
    pub big_h: Vec<G1Affine>,
    /// Blinding base (shared with the aux commitment key).
    pub blind_h: G1Affine,
    pub n: usize,
    pub width: usize,
    /// Active digit columns per row; pads are zero-weight.
    pub layout: DigitLayout,
    pub label: Vec<u8>,
}

#[allow(clippy::type_complexity)]
static VBASES_CACHE: once_cell::sync::Lazy<
    std::sync::Mutex<
        std::collections::HashMap<
            (Vec<u8>, usize, usize, DigitLayout),
            std::sync::Arc<ValidityBases>,
        >,
    >,
> = once_cell::sync::Lazy::new(|| std::sync::Mutex::new(std::collections::HashMap::new()));

/// Cache-entry ceiling: digit layouts (and hence keys) derive from
/// artifact-controlled statements (rule parameters, lr-shift tables), so
/// verifying hostile artifacts must not grow resident memory without
/// bound — at the cap, an arbitrary entry is evicted.
const VBASES_CACHE_CAP: usize = 128;

/// Bounded-insert helper shared by the `ValidityBases` constructors: at
/// the cap an arbitrary entry is evicted rather than refusing the insert,
/// so hostile key churn can neither grow memory nor permanently disable
/// caching for honest configurations.
fn vbases_cache_put(
    key: (Vec<u8>, usize, usize, DigitLayout),
    vb: &std::sync::Arc<ValidityBases>,
) {
    let mut cache = VBASES_CACHE.lock().unwrap();
    if cache.len() >= VBASES_CACHE_CAP {
        let evict = cache.keys().next().cloned();
        if let Some(evict) = evict {
            cache.remove(&evict);
            crate::telemetry::count(crate::telemetry::Counter::VBasesEvictions, 1);
        }
    }
    cache.insert(key, vb.clone());
}

impl ValidityBases {
    /// Main-instance basis: ties column W−1 of the Z″ block to `g_aux`.
    /// Cached behind an `Arc` (provers and verifiers call this once per
    /// proof; the 4·n·width-point bases must not be cloned per call) — base
    /// derivation is a one-time setup cost per configuration. The
    /// sign-column coupling lives in column W−1, so the main instance
    /// always uses the full digit width.
    pub fn setup_main(
        label: &[u8],
        g_aux: &CommitKey,
        n: usize,
        width: usize,
    ) -> std::sync::Arc<Self> {
        assert!(g_aux.g.len() >= n);
        assert!(width.is_power_of_two());
        let key = (label.to_vec(), n, width, DigitLayout::Uniform(width));
        if let Some(vb) = VBASES_CACHE.lock().unwrap().get(&key) {
            crate::telemetry::count(crate::telemetry::Counter::VBasesHits, 1);
            return vb.clone();
        }
        crate::telemetry::count(crate::telemetry::Counter::VBasesMisses, 1);
        let mut glabel = label.to_vec();
        glabel.extend_from_slice(b"/G");
        let mut big_g = crate::curve::derive_generators(&glabel, 2 * n * width);
        for i in 0..n {
            big_g[i * width + (width - 1)] = g_aux.g[i];
        }
        let mut hlabel = label.to_vec();
        hlabel.extend_from_slice(b"/H");
        let big_h = crate::curve::derive_generators(&hlabel, 2 * n * width);
        let vb = std::sync::Arc::new(Self {
            big_g,
            big_h,
            blind_h: g_aux.h,
            n,
            width,
            layout: DigitLayout::Uniform(width),
            label: label.to_vec(),
        });
        vbases_cache_put(key, &vb);
        vb
    }

    /// Remainder-instance basis: fully independent generators. Cached.
    pub fn setup_plain(
        label: &[u8],
        blind_h: G1Affine,
        n: usize,
        width: usize,
    ) -> std::sync::Arc<Self> {
        Self::setup_plain_digits(label, blind_h, n, width, width)
    }

    /// [`Self::setup_plain`] with a uniform padded digit basis: values are
    /// signed `digits`-bit, decomposed over a power-of-two `width` whose
    /// top `width − digits` columns carry zero weight (and are forced to
    /// zero bits by the pattern check).
    pub fn setup_plain_digits(
        label: &[u8],
        blind_h: G1Affine,
        n: usize,
        width: usize,
        digits: usize,
    ) -> std::sync::Arc<Self> {
        Self::setup_plain_layout(label, blind_h, n, width, DigitLayout::Uniform(digits))
    }

    /// The general plain-instance constructor: an arbitrary [`DigitLayout`]
    /// over 2N rows. Used by the zkOptim chain, whose remainder tensors
    /// have per-relation, per-boundary digit budgets. Cached — the key
    /// includes the full layout, so instances with the same shape but
    /// different digit budgets (e.g. two lr schedules) never share an
    /// entry.
    pub fn setup_plain_layout(
        label: &[u8],
        blind_h: G1Affine,
        n: usize,
        width: usize,
        layout: DigitLayout,
    ) -> std::sync::Arc<Self> {
        assert!(width.is_power_of_two());
        layout.validate(2 * n, width);
        let key = (label.to_vec(), n, width, layout.clone());
        if let Some(vb) = VBASES_CACHE.lock().unwrap().get(&key) {
            crate::telemetry::count(crate::telemetry::Counter::VBasesHits, 1);
            return vb.clone();
        }
        crate::telemetry::count(crate::telemetry::Counter::VBasesMisses, 1);
        let mut glabel = label.to_vec();
        glabel.extend_from_slice(b"/G");
        let big_g = crate::curve::derive_generators(&glabel, 2 * n * width);
        let mut hlabel = label.to_vec();
        hlabel.extend_from_slice(b"/H");
        let big_h = crate::curve::derive_generators(&hlabel, 2 * n * width);
        let vb = std::sync::Arc::new(Self {
            big_g,
            big_h,
            blind_h,
            n,
            width,
            layout,
            label: label.to_vec(),
        });
        vbases_cache_put(key, &vb);
        vb
    }

    /// H column extraction h = H[0:N, W−1] used by Protocol 1 line 2.
    pub fn h_sign_column(&self) -> Vec<G1Affine> {
        (0..self.n)
            .map(|i| self.big_h[i * self.width + (self.width - 1)])
            .collect()
    }
}

/// The signed digit basis s_W = (1, 2, …, 2^{W−2}, −2^{W−1}).
pub fn s_basis(width: usize) -> Vec<Fr> {
    s_basis_digits(width, width)
}

/// Padded signed digit basis: (1, 2, …, 2^{D−2}, −2^{D−1}, 0, …, 0) with
/// `digits` active columns out of `width`. Recomposition ⟨bits, s⟩ over
/// binary digits spans exactly [−2^{D−1}, 2^{D−1}), so the zero-weight tail
/// lets a non-power-of-two bit budget ride a power-of-two e_bit table.
pub fn s_basis_digits(width: usize, digits: usize) -> Vec<Fr> {
    assert!((2..=width).contains(&digits));
    let mut s: Vec<Fr> = (0..digits - 1)
        .map(|j| Fr::from_u128(1u128 << j))
        .collect();
    s.push(-Fr::from_u128(1u128 << (digits - 1)));
    s.resize(width, Fr::ZERO);
    s
}

/// Bit-decompose signed values into the 2N×W matrices B (bits) and B′
/// (B − 1 on active cells), row i carrying `layout.digits_at(i)` active
/// digits. Columns ≥ a row's digit count are zero-weight pads with
/// B = B′ = 0. `zero_top_bit_rows`: number of leading rows whose sign
/// column `digits−1` must also be zero in B *and* B′ (the Z″ block's "|0"
/// pad — those rows' values are unsigned (digits−1)-bit).
///
/// Returns (B, B′) flattened row-major (i·W + j).
pub fn bit_matrices_layout(
    values: &[Fr],
    width: usize,
    layout: &DigitLayout,
    zero_top_bit_rows: usize,
) -> (Vec<Fr>, Vec<Fr>) {
    layout.validate(values.len(), width);
    let rows = values.len();
    let mut b = vec![Fr::ZERO; rows * width];
    let mut bp = vec![Fr::ZERO; rows * width];
    for (i, v) in values.iter().enumerate() {
        let digits = layout.digits_at(i);
        let signed = v
            .to_i128()
            .expect("auxiliary value too large for bit decomposition");
        let pad_top = i < zero_top_bit_rows;
        let half = 1i128 << (digits - 1);
        let mag = if pad_top {
            assert!(
                (0..half).contains(&signed),
                "unsigned aux value out of range"
            );
            signed as u128
        } else {
            assert!(
                (-half..half).contains(&signed),
                "signed aux value out of range"
            );
            // <bits, s> = v: magnitude part = v + 2^{D-1}·sign
            (signed + ((signed < 0) as i128) * half) as u128
        };
        let sign_bit = !pad_top && signed < 0;
        for j in 0..width {
            if j >= digits {
                // zero-weight pad column: B = B′ = 0
                continue;
            }
            let bit = if j == digits - 1 {
                if pad_top {
                    // pad cell: B = B′ = 0
                    continue;
                }
                u128::from(sign_bit)
            } else {
                (mag >> j) & 1
            };
            b[i * width + j] = Fr::from_u64(bit as u64);
            bp[i * width + j] = Fr::from_u64(bit as u64) - Fr::ONE;
        }
    }
    (b, bp)
}

/// [`bit_matrices_layout`] with a uniform digit count — the paper's
/// instances and the single-width zkSGD padded basis.
pub fn bit_matrices(
    values: &[Fr],
    width: usize,
    digits: usize,
    zero_top_bit_rows: usize,
) -> (Vec<Fr>, Vec<Fr>) {
    bit_matrices_layout(values, width, &DigitLayout::Uniform(digits), zero_top_bit_rows)
}

/// Protocol 1 message: the prover's bit-tensor commitments.
#[derive(Clone, Debug)]
pub struct Protocol1Msg {
    /// com_B^ip = h^ρ·G^B·H^{B′}.
    pub com_b_ip: G1Affine,
    /// com_{B′_{Q−1}} = h^{ρ′}·h_col^{B_{Q−1}−1} (main instance only).
    pub com_sign_prime: Option<G1Affine>,
}

/// Prover state carried from Protocol 1 into the validity proof.
pub struct ProverAux {
    pub b: Vec<Fr>,
    pub bp: Vec<Fr>,
    pub rho: Fr,
    /// sign tensor and blinds (main instance only)
    pub sign: Option<Vec<Fr>>,
    pub rho_sign: Fr,
    pub rho_sign_prime: Fr,
}

/// Protocol 1 (main instance): commit to the bit decompositions of the
/// paired tensor (Z″ ‖ G_A′), plus com_{B′_{Q−1}}.
///
/// `values`: 2N entries, first N unsigned (Q−1)-bit (Z″), last N signed
/// Q-bit (G_A′). `sign`: the N sign bits B_{Q−1} (already committed as part
/// of the aux commitments with blind `rho_sign`).
pub fn protocol1_main(
    bases: &ValidityBases,
    values: &[Fr],
    sign: &[Fr],
    rho_sign: Fr,
    rng: &mut Rng,
) -> (Protocol1Msg, ProverAux) {
    let n = bases.n;
    assert_eq!(values.len(), 2 * n);
    assert_eq!(sign.len(), n);
    assert!(
        bases.layout.is_uniform_full(bases.width),
        "main instance requires the full digit width (sign-column coupling)"
    );
    let (b, bp) = bit_matrices(values, bases.width, bases.width, n);
    let rho = Fr::random(rng);
    let com_b_ip = (msm(&bases.big_g, &b)
        + msm(&bases.big_h, &bp)
        + bases.blind_h.to_projective().mul(&rho))
    .to_affine();
    let rho_sp = Fr::random(rng);
    let h_col = bases.h_sign_column();
    let sign_minus_1: Vec<Fr> = sign.iter().map(|s| *s - Fr::ONE).collect();
    let com_sign_prime = (msm(&h_col, &sign_minus_1)
        + bases.blind_h.to_projective().mul(&rho_sp))
    .to_affine();
    (
        Protocol1Msg {
            com_b_ip,
            com_sign_prime: Some(com_sign_prime),
        },
        ProverAux {
            b,
            bp,
            rho,
            sign: Some(sign.to_vec()),
            rho_sign,
            rho_sign_prime: rho_sp,
        },
    )
}

/// Protocol 1 (remainder instance): all 2N rows are signed `digits`-bit
/// values, no sign-tensor coupling.
pub fn protocol1_plain(
    bases: &ValidityBases,
    values: &[Fr],
    rng: &mut Rng,
) -> (Protocol1Msg, ProverAux) {
    assert_eq!(values.len(), 2 * bases.n);
    let (b, bp) = bit_matrices_layout(values, bases.width, &bases.layout, 0);
    let rho = Fr::random(rng);
    let com_b_ip = (msm(&bases.big_g, &b)
        + msm(&bases.big_h, &bp)
        + bases.blind_h.to_projective().mul(&rho))
    .to_affine();
    (
        Protocol1Msg {
            com_b_ip,
            com_sign_prime: None,
        },
        ProverAux {
            b,
            bp,
            rho,
            sign: None,
            rho_sign: Fr::ZERO,
            rho_sign_prime: Fr::ZERO,
        },
    )
}

/// The zkReLU validity proof: a single IPA on equation (19).
#[derive(Clone, Debug)]
pub struct ValidityProof {
    pub ipa: IpaProof,
}

impl ValidityProof {
    pub fn size_bytes(&self) -> usize {
        self.ipa.size_bytes()
    }
}

/// Shared challenge bundle for one validity instance.
struct Challenges {
    k: Fr,
    z: Fr,
    u_bit: Vec<Fr>,
    e_bit: Vec<Fr>,
}

fn draw_challenges(width: usize, transcript: &mut Transcript, main: bool) -> Challenges {
    let tag: &[u8] = if main { b"relu" } else { b"rem" };
    let k = if main {
        transcript.challenge_fr(b"zkrelu/k")
    } else {
        Fr::ZERO
    };
    let log_w = width.trailing_zeros() as usize;
    let mut lbl = tag.to_vec();
    lbl.extend_from_slice(b"/u_bit");
    let u_bit = transcript.challenge_frs(&lbl, log_w);
    let mut lbl = tag.to_vec();
    lbl.extend_from_slice(b"/z");
    let z = loop {
        let z = transcript.challenge_fr(&lbl);
        if !z.is_zero() {
            break z;
        }
    };
    let e_bit = eq_table(&u_bit);
    Challenges { k, z, u_bit, e_bit }
}

/// Per-distinct-digit-count tables of [`s_basis_digits`], built lazily so a
/// multi-width layout costs one small table per budget, not one per row.
struct STables {
    width: usize,
    tables: Vec<Option<Vec<Fr>>>,
}

impl STables {
    fn new(width: usize) -> Self {
        Self {
            width,
            tables: vec![None; width + 1],
        }
    }

    fn get(&mut self, digits: usize) -> &[Fr] {
        let width = self.width;
        self.tables[digits].get_or_insert_with(|| s_basis_digits(width, digits))
    }
}

/// Build the two inner-product vectors of (19), row i using its layout's
/// signed digit basis s_{D_i}:
///   a = B_k − z·1
///   b[i,·] = z²·e_row[i]·s_{D_i} + (z·1 + B′_k[i,·]) ⊙ (e_row[i]·e_bit)
/// and (in [`targets`]) the target t = z³ − (1−v_k)·z² + z·v′_k. The
/// per-row basis is sound because every s_{D} sums to −1 (1 + 2 + … +
/// 2^{D−2} − 2^{D−1}), so the z³ coefficient of ⟨a, b⟩ is row-independent.
fn build_vectors(
    aux: &ProverAux,
    ch: &Challenges,
    e_row: &[Fr],
    width: usize,
    layout: &DigitLayout,
    n: usize,
) -> (Vec<Fr>, Vec<Fr>) {
    let mut s_tables = STables::new(width);
    // Materialize every distinct digit-budget basis up front (≤ width+1
    // small tables) so the row fill below is read-only and can tile rows
    // across the pool — each row's width-slice of a and b is written by
    // exactly one lane.
    for i in 0..2 * n {
        s_tables.get(layout.digits_at(i));
    }
    let s_tables = &s_tables;
    let total = 2 * n * width;
    let mut a = vec![Fr::ZERO; total];
    let mut b = vec![Fr::ZERO; total];
    // B_k = B + k·B̄_sign; B̄_sign only populates (i < n, j = width−1)
    let bk_at = |i: usize, j: usize| -> Fr {
        let mut bk = aux.b[i * width + j];
        if j == width - 1 && i < n {
            if let Some(sign) = &aux.sign {
                bk += ch.k * sign[i];
            }
        }
        bk
    };
    let bpk_at = |i: usize, j: usize| -> Fr {
        let mut bpk = aux.bp[i * width + j];
        if j == width - 1 && i < n {
            if let Some(sign) = &aux.sign {
                bpk += ch.k * (sign[i] - Fr::ONE);
            }
        }
        bpk
    };
    threads::par_chunks_mut(&mut a, width, |i, arow| {
        for (j, slot) in arow.iter_mut().enumerate() {
            *slot = bk_at(i, j) - ch.z;
        }
    });
    threads::par_chunks_mut(&mut b, width, |i, brow| {
        let s_w = s_tables.tables[layout.digits_at(i)]
            .as_deref()
            .expect("prebuilt above");
        for (j, slot) in brow.iter_mut().enumerate() {
            *slot = ch.z.square() * e_row[i] * s_w[j]
                + (ch.z + bpk_at(i, j)) * e_row[i] * ch.e_bit[j];
        }
    });
    (a, b)
}

/// v_k and v′_k per eqs. (12) and (15). `e_row` enters only for per-block
/// layouts, whose pattern target is row-weighted.
#[allow(clippy::too_many_arguments)]
fn targets(
    ch: &Challenges,
    width: usize,
    layout: &DigitLayout,
    e_row: &[Fr],
    u_dd: Fr,
    v: Fr,
    v_sign: Fr,
    main: bool,
) -> Fr {
    let (v_k, v_k_prime) = if main {
        let q_top = Fr::from_u128(1u128 << (width - 1));
        let v_k = v - ch.k * q_top * (Fr::ONE - u_dd) * v_sign;
        // v′_k = 1 + (k−1)·β̃(bits(W−1), u_bit)·(1−u″)
        let beta = eq_eval_index(&ch.u_bit, width - 1);
        let v_k_prime = Fr::ONE + (ch.k - Fr::ONE) * beta * (Fr::ONE - u_dd);
        (v_k, v_k_prime)
    } else {
        // pattern target (B − B′)~(u_dd, ρ, u_bit): row i contributes
        // e_row[i]·Σ_{j<D_i} e_bit[j] — the prefix weight of its active
        // digits. Uniform full width gives 1 (Σ_j e_bit[j] = 1 and
        // Σ_i e_row[i] = 1); a uniform padded width drops the common
        // row factor; per-block layouts weight each row by its budget,
        // which is exactly what forces every pad cell to B = B′ = 0.
        let v_k_prime = match layout {
            DigitLayout::Uniform(d) if *d == width => Fr::ONE,
            DigitLayout::Uniform(d) => (0..*d).map(|j| eq_eval_index(&ch.u_bit, j)).sum(),
            DigitLayout::PerBlock { .. } => {
                // prefix sums of e_bit: prefix[d] = Σ_{j<d} e_bit[j]
                let mut prefix = vec![Fr::ZERO; width + 1];
                for j in 0..width {
                    prefix[j + 1] = prefix[j] + ch.e_bit[j];
                }
                e_row
                    .iter()
                    .enumerate()
                    .map(|(i, e)| *e * prefix[layout.digits_at(i)])
                    .sum()
            }
        };
        (v, v_k_prime)
    };
    let z = ch.z;
    z * z * z - (Fr::ONE - v_k) * z.square() + z * v_k_prime
}

/// The public scalar vector w_pub with H^{w_pub} entering P (Algorithm 1):
/// w_pub[i,j] = z²·s_{D_i}[j]/e_bit[j] + z, row i using its layout's digit
/// basis (mirroring [`build_vectors`]).
fn w_pub(ch: &Challenges, width: usize, layout: &DigitLayout, n: usize) -> Vec<Fr> {
    let mut inv_ebit = ch.e_bit.clone();
    Fr::batch_invert(&mut inv_ebit);
    // one column vector per distinct digit budget, built on first use
    let mut cols: Vec<Option<Vec<Fr>>> = vec![None; width + 1];
    let mut out = Vec::with_capacity(2 * n * width);
    for i in 0..2 * n {
        let digits = layout.digits_at(i);
        let col = cols[digits].get_or_insert_with(|| {
            let s_w = s_basis_digits(width, digits);
            (0..width)
                .map(|j| ch.z.square() * s_w[j] * inv_ebit[j] + ch.z)
                .collect()
        });
        out.extend_from_slice(col);
    }
    out
}

/// Prove one validity instance. `e_row` = expansion e((u″, ρ)) of length 2N;
/// `v`, `v_sign` are the (already opened) evaluation claims.
#[allow(clippy::too_many_arguments)]
pub fn prove_validity(
    bases: &ValidityBases,
    aux: &ProverAux,
    e_row: &[Fr],
    u_dd: Fr,
    v: Fr,
    v_sign: Fr,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> ValidityProof {
    crate::span!("zkrelu/prove_validity");
    let n = bases.n;
    let width = bases.width;
    let layout = &bases.layout;
    let main = aux.sign.is_some();
    assert!(
        !main || layout.is_uniform_full(width),
        "main instance is full-width"
    );
    let ch = draw_challenges(width, transcript, main);
    let (a, b) = build_vectors(aux, &ch, e_row, width, layout, n);
    let t = targets(&ch, width, layout, e_row, u_dd, v, v_sign, main);

    // The transformed basis H′ = H^{e^{∘−1}} stays *virtual*: both prover
    // and verifier fold e^{∘−1} into their MSM scalars (§Perf — avoids
    // 2NW scalar multiplications per proof).
    let mut e_inv: Vec<Fr> = (0..2 * n * width)
        .map(|idx| e_row[idx / width] * ch.e_bit[idx % width])
        .collect();
    Fr::batch_invert(&mut e_inv);

    // blinding of P: ρ_k = ρ + k(ρ_sign + ρ′_sign)
    let blind = aux.rho + ch.k * (aux.rho_sign + aux.rho_sign_prime);
    let basis = IpaBasis {
        g: bases.big_g.clone(),
        h: bases.big_h.clone(),
        blind_h: bases.blind_h,
        label: bases.label.clone(),
    };
    // P = blind^ρ · G^a · H′^b is a public combination of the already-
    // absorbed Protocol-1 commitments and challenge-derived exponents, so
    // neither side materializes or re-absorbs it (§verification engine) —
    // the nocom IPA core drops the P-sized MSM the prover used to pay just
    // to absorb the point.
    let ipa = ipa::prove_ip_core(&basis, &a, &b, blind, t, Some(&e_inv), transcript, rng);
    ValidityProof { ipa }
}

/// Verify one validity instance. Thin wrapper: one accumulator, one MSM.
///
/// `com_sign`: the aux commitment of B_{Q−1} (main instance), which by the
/// shared-basis construction is a commitment of B̄_{Q−1} under G.
#[allow(clippy::too_many_arguments)]
pub fn verify_validity(
    bases: &ValidityBases,
    p1: &Protocol1Msg,
    com_sign: Option<&G1>,
    e_row: &[Fr],
    u_dd: Fr,
    v: Fr,
    v_sign: Fr,
    proof: &ValidityProof,
    transcript: &mut Transcript,
) -> Result<()> {
    let expr = com_sign.map(|c| ComExpr::point(*c));
    let mut acc = MsmAccumulator::new();
    verify_validity_accum(
        bases,
        p1,
        expr.as_ref(),
        e_row,
        u_dd,
        v,
        v_sign,
        proof,
        transcript,
        &mut acc,
    )?;
    ensure!(acc.flush(), "validity: final check failed");
    Ok(())
}

/// [`verify_validity`] with every group operation deferred into `acc`.
///
/// The Algorithm-1 statement point P = com_B^ip · (com_sign^ip)^k ·
/// G^{−z·1} · H^{w_pub} stays symbolic: its point factors become `com_terms`
/// of the IPA core and its basis exponents ride along as `g_pub`/`h_pub`,
/// merging with the final-check scalars — the w_pub MSM the eager verifier
/// paid disappears entirely. Sound because every factor of P is already
/// transcript-bound (Protocol-1 / aux commitments) or challenge-derived.
#[allow(clippy::too_many_arguments)]
pub fn verify_validity_accum(
    bases: &ValidityBases,
    p1: &Protocol1Msg,
    com_sign: Option<&ComExpr>,
    e_row: &[Fr],
    u_dd: Fr,
    v: Fr,
    v_sign: Fr,
    proof: &ValidityProof,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("zkrelu/verify_validity");
    let n = bases.n;
    let width = bases.width;
    let layout = &bases.layout;
    let main = p1.com_sign_prime.is_some();
    ensure!(main == com_sign.is_some(), "validity: instance mismatch");
    ensure!(
        !main || layout.is_uniform_full(width),
        "validity: main instance is full-width"
    );
    ensure!(e_row.len() == 2 * n, "validity: e_row length mismatch");
    let ch = draw_challenges(width, transcript, main);
    let t = targets(&ch, width, layout, e_row, u_dd, v, v_sign, main);

    let mut com_terms: Vec<(Fr, G1)> = vec![(Fr::ONE, p1.com_b_ip.to_projective())];
    if main {
        for (c, p) in &com_sign.unwrap().terms {
            com_terms.push((ch.k * *c, *p));
        }
        com_terms.push((ch.k, p1.com_sign_prime.unwrap().to_projective()));
    }
    let total = 2 * n * width;
    let g_pub = vec![-ch.z; total];
    let h_pub = w_pub(&ch, width, layout, n);

    // verify against virtual basis H′ = H^{e^{∘−1}}
    let mut e_inv: Vec<Fr> = (0..total)
        .map(|idx| e_row[idx / width] * ch.e_bit[idx % width])
        .collect();
    Fr::batch_invert(&mut e_inv);
    ipa::verify_ip_core(
        &bases.big_g,
        &bases.big_h,
        bases.blind_h,
        &bases.label,
        &com_terms,
        Some(&g_pub),
        Some(&h_pub),
        total,
        t,
        &proof.ipa,
        Some(&e_inv),
        transcript,
        acc,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::Mle;

    fn rng() -> Rng {
        Rng::seed_from_u64(0x2e1u64)
    }

    /// End-to-end validity roundtrip on a small main instance.
    fn main_instance(
        n: usize,
        width: usize,
        tamper: impl FnOnce(&mut Vec<Fr>, &mut Vec<Fr>),
    ) -> Result<()> {
        let mut r = rng();
        let g_aux = CommitKey::setup(b"zkrelu-test-aux", n);
        let bases = ValidityBases::setup_main(b"zkrelu-test", &g_aux, n, width);

        // Z″ ∈ [0, 2^{W−1}), G_A′ ∈ [−2^{W−1}, 2^{W−1})
        let half = 1i64 << (width - 1);
        let mut zdp: Vec<Fr> = (0..n)
            .map(|_| Fr::from_i64(r.gen_i64(0, half)))
            .collect();
        let mut gap: Vec<Fr> = (0..n)
            .map(|_| Fr::from_i64(r.gen_i64(-half, half)))
            .collect();
        let sign: Vec<Fr> = (0..n).map(|_| Fr::from_u64(r.gen_range(2))).collect();
        tamper(&mut zdp, &mut gap);

        let rho_sign = Fr::random(&mut r);
        let com_sign = g_aux.commit(&sign, rho_sign);

        let values: Vec<Fr> = zdp.iter().chain(gap.iter()).copied().collect();
        let (p1, aux) = protocol1_main(&bases, &values, &sign, rho_sign, &mut r);

        // random evaluation point (u″, ρ) and honest claims
        let mut t = Transcript::new(b"vt");
        t.absorb_point(b"p1", &p1.com_b_ip);
        let u_dd = Fr::random(&mut r);
        let log_n = n.trailing_zeros() as usize;
        let rho_pt: Vec<Fr> = (0..log_n).map(|_| Fr::random(&mut r)).collect();
        let v_z = Mle::new(zdp.clone()).evaluate(&rho_pt);
        let v_g = Mle::new(gap.clone()).evaluate(&rho_pt);
        let v = (Fr::ONE - u_dd) * v_z + u_dd * v_g;
        let v_sign = Mle::new(sign.clone()).evaluate(&rho_pt);

        // e_row = e((u″, ρ))
        let mut point = vec![u_dd];
        point.extend_from_slice(&rho_pt);
        let e_row = eq_table(&point);

        let proof = prove_validity(&bases, &aux, &e_row, u_dd, v, v_sign, &mut t.clone(), &mut r);
        verify_validity(
            &bases,
            &p1,
            Some(&com_sign),
            &e_row,
            u_dd,
            v,
            v_sign,
            &proof,
            &mut t.clone(),
        )
    }

    #[test]
    fn validity_accepts_honest() {
        main_instance(8, 8, |_, _| {}).expect("honest instance verifies");
    }

    #[test]
    fn validity_wider() {
        main_instance(4, 16, |_, _| {}).expect("width-16 instance verifies");
    }

    #[test]
    fn remainder_instance_roundtrip() {
        let mut r = rng();
        let (n, width) = (8usize, 8usize);
        let blind_h = crate::curve::hash_to_curve(b"rem-blind", 0);
        let bases = ValidityBases::setup_plain(b"zkrelu-rem-test", blind_h, n, width);
        let half = 1i64 << (width - 1);
        let vals: Vec<Fr> = (0..2 * n)
            .map(|_| Fr::from_i64(r.gen_i64(-half, half)))
            .collect();
        let (p1, aux) = protocol1_plain(&bases, &vals, &mut r);

        let mut t = Transcript::new(b"vr");
        t.absorb_point(b"p1", &p1.com_b_ip);
        let u_dd = Fr::random(&mut r);
        let log_n = n.trailing_zeros() as usize;
        let rho_pt: Vec<Fr> = (0..log_n).map(|_| Fr::random(&mut r)).collect();
        let v_lo = Mle::new(vals[..n].to_vec()).evaluate(&rho_pt);
        let v_hi = Mle::new(vals[n..].to_vec()).evaluate(&rho_pt);
        let v = (Fr::ONE - u_dd) * v_lo + u_dd * v_hi;
        let mut point = vec![u_dd];
        point.extend_from_slice(&rho_pt);
        let e_row = eq_table(&point);

        let proof =
            prove_validity(&bases, &aux, &e_row, u_dd, v, Fr::ZERO, &mut t.clone(), &mut r);
        verify_validity(
            &bases,
            &p1,
            None,
            &e_row,
            u_dd,
            v,
            Fr::ZERO,
            &proof,
            &mut t.clone(),
        )
        .expect("remainder instance verifies");
    }

    #[test]
    fn validity_rejects_wrong_claim() {
        // honest tensors but the claimed evaluation v is shifted: the
        // verifier's target t no longer matches the committed bits.
        let mut r = rng();
        let (n, width) = (8usize, 8usize);
        let g_aux = CommitKey::setup(b"zkrelu-test-aux", n);
        let bases = ValidityBases::setup_main(b"zkrelu-test", &g_aux, n, width);
        let half = 1i64 << (width - 1);
        let zdp: Vec<Fr> = (0..n).map(|_| Fr::from_i64(r.gen_i64(0, half))).collect();
        let gap: Vec<Fr> = (0..n)
            .map(|_| Fr::from_i64(r.gen_i64(-half, half)))
            .collect();
        let sign: Vec<Fr> = (0..n).map(|_| Fr::from_u64(r.gen_range(2))).collect();
        let rho_sign = Fr::random(&mut r);
        let com_sign = g_aux.commit(&sign, rho_sign);
        let values: Vec<Fr> = zdp.iter().chain(gap.iter()).copied().collect();
        let (p1, aux) = protocol1_main(&bases, &values, &sign, rho_sign, &mut r);

        let mut t = Transcript::new(b"vt");
        let u_dd = Fr::random(&mut r);
        let rho_pt: Vec<Fr> = (0..3).map(|_| Fr::random(&mut r)).collect();
        let v = (Fr::ONE - u_dd) * Mle::new(zdp).evaluate(&rho_pt)
            + u_dd * Mle::new(gap).evaluate(&rho_pt)
            + Fr::ONE; // ← lie
        let v_sign = Mle::new(sign).evaluate(&rho_pt);
        let mut point = vec![u_dd];
        point.extend_from_slice(&rho_pt);
        let e_row = eq_table(&point);
        let proof =
            prove_validity(&bases, &aux, &e_row, u_dd, v, v_sign, &mut t.clone(), &mut r);
        assert!(verify_validity(
            &bases,
            &p1,
            Some(&com_sign),
            &e_row,
            u_dd,
            v,
            v_sign,
            &proof,
            &mut t.clone(),
        )
        .is_err());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_aux_cannot_be_decomposed() {
        // a malicious Z″ ≥ 2^{W−1} has no valid unsigned decomposition:
        // the honest decomposition path panics, and any forged bit matrix
        // fails (16)–(18) w.h.p. (covered by validity_rejects_wrong_claim).
        let vals = vec![Fr::from_u64(1 << 7); 2]; // width 8 ⇒ max 127
        bit_matrices(&vals, 8, 8, 2);
    }

    /// Roundtrip of a padded-digit plain instance (the zkSGD remainder
    /// shape: signed `digits`-bit values, digits < width). When `tamper`
    /// swaps in a forged full-width decomposition, verification must fail.
    fn padded_digit_instance(digits: usize, forge_out_of_range: bool) -> Result<()> {
        let mut r = rng();
        let (n, width) = (8usize, 16usize);
        let blind_h = crate::curve::hash_to_curve(b"upd-test-blind", 0);
        let label = format!("zkrelu-upd-test-{digits}-{forge_out_of_range}");
        let bases =
            ValidityBases::setup_plain_digits(label.as_bytes(), blind_h, n, width, digits);
        let half = 1i64 << (digits - 1);
        let mut vals: Vec<Fr> = (0..2 * n)
            .map(|_| Fr::from_i64(r.gen_i64(-half, half)))
            .collect();

        let (p1, aux) = if forge_out_of_range {
            // a value outside the digit range but inside the full width:
            // forge its decomposition over all `width` columns (pad bits
            // set) — the verifier's padded pattern target must reject it
            vals[3] = Fr::from_i64(half + 3);
            let (b, bp) = bit_matrices(&vals, width, width, 0);
            let rho = Fr::random(&mut r);
            let com_b_ip = (msm(&bases.big_g, &b)
                + msm(&bases.big_h, &bp)
                + bases.blind_h.to_projective().mul(&rho))
            .to_affine();
            (
                Protocol1Msg {
                    com_b_ip,
                    com_sign_prime: None,
                },
                ProverAux {
                    b,
                    bp,
                    rho,
                    sign: None,
                    rho_sign: Fr::ZERO,
                    rho_sign_prime: Fr::ZERO,
                },
            )
        } else {
            protocol1_plain(&bases, &vals, &mut r)
        };

        let mut t = Transcript::new(b"vu");
        t.absorb_point(b"p1", &p1.com_b_ip);
        let u_dd = Fr::random(&mut r);
        let log_n = n.trailing_zeros() as usize;
        let rho_pt: Vec<Fr> = (0..log_n).map(|_| Fr::random(&mut r)).collect();
        let v_lo = Mle::new(vals[..n].to_vec()).evaluate(&rho_pt);
        let v_hi = Mle::new(vals[n..].to_vec()).evaluate(&rho_pt);
        let v = (Fr::ONE - u_dd) * v_lo + u_dd * v_hi;
        let mut point = vec![u_dd];
        point.extend_from_slice(&rho_pt);
        let e_row = eq_table(&point);
        let proof =
            prove_validity(&bases, &aux, &e_row, u_dd, v, Fr::ZERO, &mut t.clone(), &mut r);
        verify_validity(
            &bases,
            &p1,
            None,
            &e_row,
            u_dd,
            v,
            Fr::ZERO,
            &proof,
            &mut t.clone(),
        )
    }

    #[test]
    fn padded_digit_instance_accepts_honest() {
        // 11 active digits over width 16: the zkSGD remainder shape
        padded_digit_instance(11, false).expect("padded-digit instance verifies");
    }

    #[test]
    fn padded_digit_instance_rejects_out_of_range_value() {
        assert!(
            padded_digit_instance(11, true).is_err(),
            "a value ≥ 2^{{digits−1}} forged via the pad columns must not verify"
        );
    }

    /// Roundtrip of a *per-block* layout (the zkOptim multi-width shape):
    /// block 0 holds 4-digit remainders, block 1 holds 11-digit ones, one
    /// instance covers both. With `forge`, a block-0 value outside its
    /// 4-digit range (but inside block 1's) is decomposed over extra
    /// columns — the row-weighted pattern target must reject it.
    fn per_block_instance(forge: bool) -> Result<()> {
        let mut r = rng();
        let (n, width) = (8usize, 16usize);
        let blind_h = crate::curve::hash_to_curve(b"mixw-test-blind", 0);
        let layout = DigitLayout::PerBlock {
            block: n,
            digits: vec![4, 11],
        };
        let label = format!("zkrelu-mixw-test-{forge}");
        let bases =
            ValidityBases::setup_plain_layout(label.as_bytes(), blind_h, n, width, layout);
        let mut vals: Vec<Fr> = (0..n)
            .map(|_| Fr::from_i64(r.gen_i64(-8, 8)))
            .collect();
        vals.extend((0..n).map(|_| Fr::from_i64(r.gen_i64(-1024, 1024))));

        let (p1, aux) = if forge {
            // 100 ∉ [−8, 8) but fits 11 digits: decompose every row at 11
            // digits so the out-of-range bits land in block 0's pad columns
            vals[3] = Fr::from_i64(100);
            let (b, bp) = bit_matrices(&vals, width, 11, 0);
            let rho = Fr::random(&mut r);
            let com_b_ip = (msm(&bases.big_g, &b)
                + msm(&bases.big_h, &bp)
                + bases.blind_h.to_projective().mul(&rho))
            .to_affine();
            (
                Protocol1Msg {
                    com_b_ip,
                    com_sign_prime: None,
                },
                ProverAux {
                    b,
                    bp,
                    rho,
                    sign: None,
                    rho_sign: Fr::ZERO,
                    rho_sign_prime: Fr::ZERO,
                },
            )
        } else {
            protocol1_plain(&bases, &vals, &mut r)
        };

        let mut t = Transcript::new(b"vm");
        t.absorb_point(b"p1", &p1.com_b_ip);
        let u_dd = Fr::random(&mut r);
        let log_n = n.trailing_zeros() as usize;
        let rho_pt: Vec<Fr> = (0..log_n).map(|_| Fr::random(&mut r)).collect();
        let v_lo = Mle::new(vals[..n].to_vec()).evaluate(&rho_pt);
        let v_hi = Mle::new(vals[n..].to_vec()).evaluate(&rho_pt);
        let v = (Fr::ONE - u_dd) * v_lo + u_dd * v_hi;
        let mut point = vec![u_dd];
        point.extend_from_slice(&rho_pt);
        let e_row = eq_table(&point);
        let proof =
            prove_validity(&bases, &aux, &e_row, u_dd, v, Fr::ZERO, &mut t.clone(), &mut r);
        verify_validity(
            &bases,
            &p1,
            None,
            &e_row,
            u_dd,
            v,
            Fr::ZERO,
            &proof,
            &mut t.clone(),
        )
    }

    #[test]
    fn per_block_layout_accepts_honest() {
        per_block_instance(false).expect("multi-width instance verifies");
    }

    #[test]
    fn per_block_layout_rejects_cross_block_forgery() {
        assert!(
            per_block_instance(true).is_err(),
            "a value outside its own block's digit budget must not verify"
        );
    }

    #[test]
    fn per_block_layout_bases_are_cached_per_layout() {
        let blind_h = crate::curve::hash_to_curve(b"mixw-cache-blind", 0);
        let (n, width) = (4usize, 8usize);
        let la = DigitLayout::PerBlock {
            block: n,
            digits: vec![3, 7],
        };
        let lb = DigitLayout::PerBlock {
            block: n,
            digits: vec![4, 7],
        };
        let a1 = ValidityBases::setup_plain_layout(b"mixw-cache", blind_h, n, width, la.clone());
        let a2 = ValidityBases::setup_plain_layout(b"mixw-cache", blind_h, n, width, la);
        let b1 = ValidityBases::setup_plain_layout(b"mixw-cache", blind_h, n, width, lb);
        assert!(std::sync::Arc::ptr_eq(&a1, &a2), "same layout shares bases");
        assert!(
            !std::sync::Arc::ptr_eq(&a1, &b1),
            "a different digit layout must not reuse a cached instance"
        );
        assert_eq!(b1.layout.digits_at(0), 4);
    }

    #[test]
    fn padded_digit_basis_recomposes_exact_range() {
        let (width, digits) = (16usize, 11usize);
        let s = s_basis_digits(width, digits);
        assert_eq!(s.len(), width);
        assert!(s[digits..].iter().all(|v| v.is_zero()));
        let half = 1i64 << (digits - 1);
        for v in [0i64, 1, -1, half - 1, -half, 37, -1000] {
            let (b, _) = bit_matrices(&[Fr::from_i64(v)], width, digits, 0);
            let recomposed: Fr = (0..width).map(|j| b[j] * s[j]).sum();
            assert_eq!(recomposed, Fr::from_i64(v), "v={v}");
            assert!(b[digits..].iter().all(|x| x.is_zero()));
        }
    }

    #[test]
    fn bit_matrices_recompose() {
        let mut r = rng();
        let width = 12usize;
        let half = 1i64 << (width - 1);
        let n = 4;
        let mut vals: Vec<Fr> = (0..n).map(|_| Fr::from_i64(r.gen_i64(0, half))).collect();
        vals.extend((0..n).map(|_| Fr::from_i64(r.gen_i64(-half, half))));
        let (b, bp) = bit_matrices(&vals, width, width, n);
        let s = s_basis(width);
        for i in 0..2 * n {
            let recomposed: Fr = (0..width).map(|j| b[i * width + j] * s[j]).sum();
            assert_eq!(recomposed, vals[i], "row {i}");
            for j in 0..width {
                let bij = b[i * width + j];
                let bpij = bp[i * width + j];
                // binarity via B⊙B′ = 0 and pattern via B−B′
                assert_eq!(bij * bpij, Fr::ZERO);
                if i < n && j == width - 1 {
                    assert_eq!(bij, Fr::ZERO);
                    assert_eq!(bpij, Fr::ZERO);
                } else {
                    assert_eq!(bij - bpij, Fr::ONE);
                }
            }
        }
    }
}
