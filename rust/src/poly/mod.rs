//! Dense multilinear extensions (MLEs) over 𝔽 = Fr.
//!
//! Conventions (used consistently by `sumcheck`, `gkr`, `zkrelu`):
//! * An MLE over n variables is stored as its 2ⁿ evaluations on the boolean
//!   hypercube. Index i encodes the assignment with **variable 0 as the most
//!   significant bit** of i.
//! * Folding ("fixing") variable 0 at r maps the table of size 2ⁿ to size
//!   2ⁿ⁻¹: new[i] = (1−r)·f[i] + r·f[i + 2ⁿ⁻¹]. Sumcheck rounds fix
//!   variables in order 0, 1, …, n−1.
//! * `eq_table(u)` is the paper's expansion e(u) = (β̃(u, b))_b, laid out in
//!   the same index convention, so that S̃(u) = ⟨S, e(u)⟩.

use crate::field::Fr;

/// Dense multilinear extension: 2^num_vars evaluations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mle {
    pub evals: Vec<Fr>,
    pub num_vars: usize,
}

impl Mle {
    pub fn new(evals: Vec<Fr>) -> Self {
        let n = evals.len();
        assert!(n.is_power_of_two(), "MLE table must be a power of two");
        Self {
            evals,
            num_vars: n.trailing_zeros() as usize,
        }
    }

    /// Build from integers (quantized tensor values).
    pub fn from_i64(values: &[i64]) -> Self {
        let mut evals: Vec<Fr> = values.iter().map(|&v| Fr::from_i64(v)).collect();
        let n = evals.len().next_power_of_two();
        evals.resize(n, Fr::ZERO);
        Self::new(evals)
    }

    /// Zero-padded to the next power of two ≥ len.
    pub fn from_frs_padded(values: &[Fr], len: usize) -> Self {
        assert!(len >= values.len());
        let mut evals = values.to_vec();
        evals.resize(len.next_power_of_two(), Fr::ZERO);
        Self::new(evals)
    }

    pub fn zero(num_vars: usize) -> Self {
        Self {
            evals: vec![Fr::ZERO; 1 << num_vars],
            num_vars,
        }
    }

    pub fn len(&self) -> usize {
        self.evals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }

    /// Fix variable 0 (most significant index bit) at r, in place.
    /// Pool-chunked: the low half is updated in parallel lanes against a
    /// shared view of the high half (disjoint slices from `split_at_mut`,
    /// so each output index is written exactly once — the value per index
    /// is identical at every lane count).
    pub fn fold(&mut self, r: Fr) {
        let half = self.evals.len() / 2;
        let (lo_half, hi_half) = self.evals.split_at_mut(half);
        let hi_half = &*hi_half;
        crate::util::threads::par_chunks_mut(lo_half, 1 << 12, |ci, chunk| {
            let base = ci << 12;
            for (k, slot) in chunk.iter_mut().enumerate() {
                let lo = *slot;
                *slot = lo + r * (hi_half[base + k] - lo);
            }
        });
        self.evals.truncate(half);
        self.num_vars -= 1;
    }

    /// Fix the first `point.len()` variables (prefix) and return the
    /// restricted MLE over the remaining variables.
    pub fn partial_eval(&self, point: &[Fr]) -> Mle {
        assert!(point.len() <= self.num_vars);
        let mut m = self.clone();
        for &r in point {
            m.fold(r);
        }
        m
    }

    /// Full evaluation f̃(u); u.len() must equal num_vars.
    pub fn evaluate(&self, point: &[Fr]) -> Fr {
        assert_eq!(point.len(), self.num_vars);
        // inner-product with the eq table — O(2ⁿ) but single pass
        let table = eq_table(point);
        self.evals
            .iter()
            .zip(table.iter())
            .map(|(a, b)| *a * *b)
            .sum()
    }
}

/// Evaluate the MLE whose table is `evals` (length 2^point.len()) at
/// `point` by folding in place — the allocation-free twin of
/// [`Mle::evaluate`], for callers that own a scratch buffer (the prover's
/// tensor arena). Clobbers `evals`; the result lands in `evals[0]`.
pub fn eval_in_place(evals: &mut [Fr], point: &[Fr]) -> Fr {
    assert_eq!(evals.len(), 1 << point.len(), "MLE table/point mismatch");
    let mut len = evals.len();
    for &r in point {
        let half = len / 2;
        for i in 0..half {
            let lo = evals[i];
            let hi = evals[i + half];
            evals[i] = lo + r * (hi - lo);
        }
        len = half;
    }
    evals[0]
}

/// ⟨from_i64(values), eq⟩: evaluate a quantized tensor's MLE against a
/// precomputed eq table. Same operation order as [`Mle::evaluate`], so the
/// result is the identical field element — but the table is computed once
/// per challenge point by the caller instead of once per tensor.
pub fn eval_i64_with_eq(values: &[i64], eq: &[Fr]) -> Fr {
    debug_assert_eq!(values.len(), eq.len(), "tensor/eq-table length mismatch");
    values
        .iter()
        .zip(eq.iter())
        .map(|(&v, e)| Fr::from_i64(v) * *e)
        .sum()
}

/// The equality polynomial table e(u): e[idx] = β̃(u, idx) with variable 0 in
/// the most significant bit of idx. Σ_idx e[idx] = 1.
pub fn eq_table(u: &[Fr]) -> Vec<Fr> {
    let mut table = vec![Fr::ZERO; 1 << u.len()];
    eq_table_into(u, &mut table);
    table
}

/// [`eq_table`] into a caller-owned buffer of length 2^u.len() (arena
/// scratch): expands level by level from the back, no allocation.
pub fn eq_table_into(u: &[Fr], out: &mut [Fr]) {
    assert_eq!(out.len(), 1 << u.len(), "eq table buffer mismatch");
    out[0] = Fr::ONE;
    let mut len = 1usize;
    for &uj in u {
        // writes for slot i land at 2i/2i+1 ≥ i, so descending i never
        // clobbers an unread slot
        for i in (0..len).rev() {
            let e = out[i];
            out[2 * i + 1] = e * uj; // bit 1
            out[2 * i] = e * (Fr::ONE - uj); // bit 0
        }
        len *= 2;
    }
}

/// β̃(u, v) = Π_i (uᵢvᵢ + (1−uᵢ)(1−vᵢ)).
pub fn eq_eval(u: &[Fr], v: &[Fr]) -> Fr {
    assert_eq!(u.len(), v.len());
    u.iter()
        .zip(v.iter())
        .map(|(&a, &b)| a * b + (Fr::ONE - a) * (Fr::ONE - b))
        .product()
}

/// β̃(u, idx) for a boolean index (binary expansion of `idx`, variable 0 as
/// the most significant of `n` bits).
pub fn eq_eval_index(u: &[Fr], idx: usize) -> Fr {
    let n = u.len();
    let mut acc = Fr::ONE;
    for (j, &uj) in u.iter().enumerate() {
        let bit = (idx >> (n - 1 - j)) & 1;
        acc *= if bit == 1 { uj } else { Fr::ONE - uj };
    }
    acc
}

/// Evaluate the unique degree-≤d polynomial through points (0, ys[0]) …
/// (d, ys[d]) at x (Lagrange on the integer grid). Used by sumcheck
/// verifiers on round polynomials.
pub fn interpolate_uni(ys: &[Fr], x: Fr) -> Fr {
    let d = ys.len() - 1;
    // If x is one of the grid points the generic formula divides by zero;
    // handle via direct scan (x is a random challenge so this is rare).
    for (i, &y) in ys.iter().enumerate() {
        if x == Fr::from_u64(i as u64) {
            return y;
        }
    }
    // prefix[i] = Π_{j<i} (x - j), suffix[i] = Π_{j>i} (x - j)
    let mut prefix = vec![Fr::ONE; d + 1];
    for i in 1..=d {
        prefix[i] = prefix[i - 1] * (x - Fr::from_u64((i - 1) as u64));
    }
    let mut suffix = vec![Fr::ONE; d + 1];
    for i in (0..d).rev() {
        suffix[i] = suffix[i + 1] * (x - Fr::from_u64((i + 1) as u64));
    }
    // denominators: i!·(d−i)!·(−1)^{d−i}, inverted in one batched sweep
    // (one inversion + O(d) muls instead of d+1 inversions)
    let mut fact = vec![Fr::ONE; d + 1];
    for i in 1..=d {
        fact[i] = fact[i - 1] * Fr::from_u64(i as u64);
    }
    let mut denoms: Vec<Fr> = (0..=d)
        .map(|i| {
            let dd = fact[i] * fact[d - i];
            if (d - i) % 2 == 1 {
                -dd
            } else {
                dd
            }
        })
        .collect();
    Fr::batch_invert(&mut denoms);
    let mut acc = Fr::ZERO;
    for i in 0..=d {
        acc += ys[i] * prefix[i] * suffix[i] * denoms[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rng() -> Rng {
        Rng::seed_from_u64(0x901e)
    }

    #[test]
    fn eq_table_sums_to_one() {
        let mut r = rng();
        let u: Vec<Fr> = (0..5).map(|_| Fr::random(&mut r)).collect();
        let t = eq_table(&u);
        assert_eq!(t.len(), 32);
        assert_eq!(t.iter().copied().sum::<Fr>(), Fr::ONE);
    }

    #[test]
    fn eq_table_matches_eval_index() {
        let mut r = rng();
        let u: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let t = eq_table(&u);
        for idx in 0..16 {
            assert_eq!(t[idx], eq_eval_index(&u, idx));
        }
    }

    #[test]
    fn evaluate_agrees_on_hypercube() {
        let mut r = rng();
        let vals: Vec<Fr> = (0..8).map(|_| Fr::random(&mut r)).collect();
        let m = Mle::new(vals.clone());
        for idx in 0..8usize {
            let point: Vec<Fr> = (0..3)
                .map(|j| Fr::from_u64(((idx >> (2 - j)) & 1) as u64))
                .collect();
            assert_eq!(m.evaluate(&point), vals[idx]);
        }
    }

    #[test]
    fn fold_consistent_with_evaluate() {
        let mut r = rng();
        let m = Mle::new((0..16).map(|_| Fr::random(&mut r)).collect());
        let u: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let full = m.evaluate(&u);
        let mut folded = m.clone();
        for &c in &u {
            folded.fold(c);
        }
        assert_eq!(folded.evals[0], full);
        // partial eval then evaluate the rest
        let part = m.partial_eval(&u[..2]);
        assert_eq!(part.evaluate(&u[2..]), full);
    }

    #[test]
    fn evaluate_is_multilinear() {
        // f(u) is affine in each coordinate
        let mut r = rng();
        let m = Mle::new((0..8).map(|_| Fr::random(&mut r)).collect());
        let mut u: Vec<Fr> = (0..3).map(|_| Fr::random(&mut r)).collect();
        let f0 = {
            u[1] = Fr::ZERO;
            m.evaluate(&u)
        };
        let f1 = {
            u[1] = Fr::ONE;
            m.evaluate(&u)
        };
        let t = Fr::random(&mut r);
        u[1] = t;
        assert_eq!(m.evaluate(&u), f0 + t * (f1 - f0));
    }

    #[test]
    fn eq_eval_matches_table_product() {
        let mut r = rng();
        let u: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let v: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        // β̃(u,v) = Σ_b β̃(u,b)β̃(v,b)
        let tu = eq_table(&u);
        let tv = eq_table(&v);
        let sum: Fr = tu.iter().zip(tv.iter()).map(|(a, b)| *a * *b).sum();
        assert_eq!(eq_eval(&u, &v), sum);
    }

    #[test]
    fn interpolate_roundtrip() {
        let mut r = rng();
        // polynomial p(x) = 3x³ + x + 7 evaluated on grid 0..=3
        let p = |x: Fr| Fr::from_u64(3) * x * x * x + x + Fr::from_u64(7);
        let ys: Vec<Fr> = (0..4).map(|i| p(Fr::from_u64(i))).collect();
        let x = Fr::random(&mut r);
        assert_eq!(interpolate_uni(&ys, x), p(x));
        // grid point
        assert_eq!(interpolate_uni(&ys, Fr::from_u64(2)), ys[2]);
    }

    #[test]
    fn eval_in_place_matches_mle_evaluate() {
        let mut r = rng();
        let vals: Vec<Fr> = (0..32).map(|_| Fr::random(&mut r)).collect();
        let u: Vec<Fr> = (0..5).map(|_| Fr::random(&mut r)).collect();
        let want = Mle::new(vals.clone()).evaluate(&u);
        let mut buf = vals;
        assert_eq!(eval_in_place(&mut buf, &u), want);
        // single element, empty point
        let mut one = [Fr::from_u64(9)];
        assert_eq!(eval_in_place(&mut one, &[]), Fr::from_u64(9));
    }

    #[test]
    fn eq_table_into_matches_alloc() {
        let mut r = rng();
        let u: Vec<Fr> = (0..6).map(|_| Fr::random(&mut r)).collect();
        let mut buf = vec![Fr::ZERO; 64];
        eq_table_into(&u, &mut buf);
        assert_eq!(buf, eq_table(&u));
    }

    #[test]
    fn from_i64_pads() {
        let m = Mle::from_i64(&[1, -2, 3]);
        assert_eq!(m.len(), 4);
        assert_eq!(m.evals[1], Fr::from_i64(-2));
        assert_eq!(m.evals[3], Fr::ZERO);
    }
}
