//! SC-BD — the Sum-Check Bit-Decomposition baseline (paper §5, Table 2,
//! Figure 1).
//!
//! This is how a *general-purpose* sumcheck backend handles ReLU: every
//! auxiliary tensor is bit-decomposed and the recomposition
//!     aux̃(u) = Σ_{i,j,k} β̃(u,i)·ãdd(i,j,k)·B̃(j,k)·2^k        (36)
//! is proven as a sumcheck over the *joint* index space (i, j, k) with the
//! dense wiring predicate ãdd(i,j,k) = eq(i,j) — Ω(D²Q) prover work per
//! layer, versus zkReLU's O(DQ). We deliberately do not exploit the
//! predicate's sparsity: that optimization is exactly what zkReLU's
//! specialized design contributes, and the paper's baseline (general ZKP
//! backend used as a black box) does not perform it.

use crate::commit::CommitKey;
use crate::field::Fr;
use crate::ipa::{self, IpaProof};
use crate::poly::{eq_eval, eq_table, Mle};
use crate::sumcheck::{self, Instance, SumcheckProof, Term};
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};

/// Proof of one bit-decomposition relation (one aux tensor of one layer).
#[derive(Clone, Debug)]
pub struct BdProof {
    /// Claimed aux̃(u).
    pub v: Fr,
    pub com_bits: crate::curve::G1Affine,
    pub sumcheck: SumcheckProof,
    /// Opened B̃(r_j, r_k).
    pub bit_eval: Fr,
    pub opening: IpaProof,
}

impl BdProof {
    pub fn size_bytes(&self) -> usize {
        32 + 32 + self.sumcheck.size_bytes() + 32 + self.opening.size_bytes()
    }
}

/// MLE of the power table (1, 2, 4, …, 2^{Q−1}) evaluated at a point —
/// verifier-side, O(log Q).
fn pow2_mle(point: &[Fr]) -> Fr {
    // table entry at index k (MSB-first bits b_0..b_{n-1}): 2^k where
    // k = Σ b_j·2^{n−1−j}; the MLE factors: Π_j (1 − u_j + u_j·2^{2^{n−1−j}})
    let n = point.len();
    let mut acc = Fr::ONE;
    for (j, u) in point.iter().enumerate() {
        let shift = 1u128 << (n - 1 - j);
        let two_pow = Fr::from_u128(1u128 << shift.min(127))
            * if shift > 127 {
                // not reachable for Q ≤ 64, defensive
                Fr::from_u128(1u128 << (shift - 127))
            } else {
                Fr::ONE
            };
        acc *= Fr::ONE - *u + *u * two_pow;
    }
    acc
}

/// Unsigned bit decomposition of small non-negative values (SC-BD treats
/// each aux tensor shifted into the non-negative range first, as generic
/// backends do).
fn bits_unsigned(values: &[Fr], q: usize) -> Vec<Fr> {
    let mut out = vec![Fr::ZERO; values.len() * q];
    for (i, v) in values.iter().enumerate() {
        let x = v.to_i128().expect("value fits") as u128;
        assert!(x < (1u128 << q), "value exceeds {q} bits");
        for k in 0..q {
            out[i * q + k] = Fr::from_u64(((x >> k) & 1) as u64);
        }
    }
    out
}

/// Prove the recomposition (36) for one aux tensor (values must be
/// non-negative `q`-bit integers; callers shift signed tensors first).
/// Prover cost is Θ(D²·Q) field operations — the baseline's bottleneck.
pub fn prove_bd(
    values: &[Fr],
    q: usize,
    ck: &CommitKey,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> BdProof {
    let d = values.len();
    assert!(d.is_power_of_two() && q.is_power_of_two());
    let log_d = d.trailing_zeros() as usize;
    let _log_q = q.trailing_zeros() as usize;

    let bits = bits_unsigned(values, q);
    // commit to the bit tensor (this is also what inflates the baseline's
    // commitment cost — D·Q group elements instead of D)
    let blind = Fr::random(rng);
    let com_bits = ck.commit(&bits, blind);
    let com_bits_aff = com_bits.to_affine();
    transcript.absorb_point(b"scbd/com_bits", &com_bits_aff);

    let u = transcript.challenge_frs(b"scbd/u", log_d);
    let v = Mle::new(values.to_vec()).evaluate(&u);
    transcript.absorb_fr(b"scbd/v", &v);

    // dense joint tables over (i, j, k): size D²Q
    let beta_u = eq_table(&u);
    let total = d * d * q;
    let mut f1 = Vec::with_capacity(total); // β(u, i)
    let mut f2 = Vec::with_capacity(total); // eq(i,j)·2^k  (wiring ⊗ weight)
    let mut f3 = Vec::with_capacity(total); // B(j, k)
    for i in 0..d {
        for j in 0..d {
            for k in 0..q {
                f1.push(beta_u[i]);
                f2.push(if i == j {
                    Fr::from_u128(1u128 << k)
                } else {
                    Fr::ZERO
                });
                f3.push(bits[j * q + k]);
            }
        }
    }
    let inst = Instance::new(vec![Term::new(
        Fr::ONE,
        vec![Mle::new(f1), Mle::new(f2), Mle::new(f3)],
    )]);
    let out = sumcheck::prove(inst, transcript);
    let bit_eval = out.factor_evals[0][2];
    transcript.absorb_fr(b"scbd/bit_eval", &bit_eval);

    // open B̃(r_j, r_k) against com_bits
    let r = &out.point;
    let (rj, rk) = (&r[log_d..2 * log_d], &r[2 * log_d..]);
    let point_jk: Vec<Fr> = [rj.to_vec(), rk.to_vec()].concat();
    let e = eq_table(&point_jk);
    let opening = ipa::prove_eval(ck, &com_bits, &bits, blind, &e, bit_eval, transcript, rng);

    BdProof {
        v,
        com_bits: com_bits_aff,
        sumcheck: out.proof,
        bit_eval,
        opening,
    }
}

/// Verify a BD recomposition proof.
pub fn verify_bd(
    proof: &BdProof,
    d: usize,
    q: usize,
    ck: &CommitKey,
    transcript: &mut Transcript,
) -> Result<()> {
    let log_d = d.trailing_zeros() as usize;
    let log_q = q.trailing_zeros() as usize;
    transcript.absorb_point(b"scbd/com_bits", &proof.com_bits);
    let u = transcript.challenge_frs(b"scbd/u", log_d);
    transcript.absorb_fr(b"scbd/v", &proof.v);
    let out = sumcheck::verify(proof.v, &proof.sumcheck, transcript).context("scbd sumcheck")?;
    ensure!(
        out.point.len() == 2 * log_d + log_q,
        "scbd: wrong variable count"
    );
    let (ri, rj, rk) = (
        &out.point[..log_d],
        &out.point[log_d..2 * log_d],
        &out.point[2 * log_d..],
    );
    // F1 = β̃(u, r_i); F2 = eq(r_i, r_j)·pow̃2(r_k); F3 = opened bits
    let f1 = eq_eval(&u, ri);
    let f2 = eq_eval(ri, rj) * pow2_mle(rk);
    ensure!(
        out.final_claim == f1 * f2 * proof.bit_eval,
        "scbd: final claim mismatch"
    );
    transcript.absorb_fr(b"scbd/bit_eval", &proof.bit_eval);
    let point_jk: Vec<Fr> = [rj.to_vec(), rk.to_vec()].concat();
    let e = eq_table(&point_jk);
    ipa::verify_eval(
        ck,
        &proof.com_bits.to_projective(),
        &e,
        proof.bit_eval,
        &proof.opening,
        transcript,
    )
    .context("scbd opening")
}

/// The SC-BD handling of one layer's ReLU: bit-decomposition proofs for the
/// shifted Z″-range tensor, the gradient tensor and both remainders —
/// the work zkReLU replaces. Returns (proofs, total bytes).
pub fn prove_layer_relu_bd(
    zdp: &[i64],
    gap: &[i64],
    rz: &[i64],
    rga: &[i64],
    q_bits: usize,
    r_bits: usize,
    ck: &CommitKey,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> Vec<BdProof> {
    let shift_q = 1i128 << (q_bits - 1);
    let shift_r = 1i128 << (r_bits - 1);
    let to_frs = |vals: &[i64], shift: i128| -> Vec<Fr> {
        vals.iter()
            .map(|&v| Fr::from_i128(v as i128 + shift))
            .collect()
    };
    // Z″ already non-negative (Q−1 bits); G_A′ shifted into [0, 2^Q);
    // remainders shifted into [0, 2^R).
    let mut proofs = Vec::new();
    proofs.push(prove_bd(&to_frs(zdp, 0), q_bits, ck, transcript, rng));
    proofs.push(prove_bd(&to_frs(gap, shift_q), q_bits, ck, transcript, rng));
    proofs.push(prove_bd(&to_frs(rz, shift_r), r_bits.max(2), ck, transcript, rng));
    proofs.push(prove_bd(&to_frs(rga, shift_r), r_bits.max(2), ck, transcript, rng));
    proofs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(0xbd)
    }

    #[test]
    fn pow2_mle_matches_table() {
        let mut r = rng();
        for log_q in [2usize, 3, 5] {
            let q = 1 << log_q;
            let table: Vec<Fr> = (0..q).map(|k| Fr::from_u128(1u128 << k)).collect();
            let point: Vec<Fr> = (0..log_q).map(|_| Fr::random(&mut r)).collect();
            assert_eq!(pow2_mle(&point), Mle::new(table).evaluate(&point));
        }
    }

    #[test]
    fn bd_roundtrip() {
        let mut r = rng();
        let d = 8usize;
        let q = 8usize;
        let ck = CommitKey::setup(b"scbd-test", d * q);
        let values: Vec<Fr> = (0..d)
            .map(|_| Fr::from_u64(r.gen_range(1 << q as u64)))
            .collect();
        let mut tp = Transcript::new(b"bd");
        let proof = prove_bd(&values, q, &ck, &mut tp, &mut r);
        let mut tv = Transcript::new(b"bd");
        verify_bd(&proof, d, q, &ck, &mut tv).expect("verifies");
        // and the claimed v matches the actual MLE evaluation
        let mut tu = Transcript::new(b"bd");
        tu.absorb_point(b"scbd/com_bits", &proof.com_bits);
        let u = tu.challenge_frs(b"scbd/u", 3);
        assert_eq!(proof.v, Mle::new(values).evaluate(&u));
    }

    #[test]
    fn bd_rejects_tampered_value() {
        let mut r = rng();
        let (d, q) = (8usize, 8usize);
        let ck = CommitKey::setup(b"scbd-test", d * q);
        let values: Vec<Fr> = (0..d).map(|_| Fr::from_u64(r.gen_range(200))).collect();
        let mut tp = Transcript::new(b"bd");
        let mut proof = prove_bd(&values, q, &ck, &mut tp, &mut r);
        proof.v += Fr::ONE;
        let mut tv = Transcript::new(b"bd");
        assert!(verify_bd(&proof, d, q, &ck, &mut tv).is_err());
    }

    #[test]
    fn layer_relu_bd_shapes() {
        let mut r = rng();
        let d = 4usize;
        let (q_bits, r_bits) = (8usize, 4usize);
        let ck = CommitKey::setup(b"scbd-test", d * q_bits);
        let zdp: Vec<i64> = (0..d).map(|_| r.gen_i64(0, 1 << (q_bits - 1))).collect();
        let gap: Vec<i64> = (0..d)
            .map(|_| r.gen_i64(-(1 << (q_bits - 1)), 1 << (q_bits - 1)))
            .collect();
        let rz: Vec<i64> = (0..d)
            .map(|_| r.gen_i64(-(1 << (r_bits - 1)), 1 << (r_bits - 1)))
            .collect();
        let rga: Vec<i64> = rz.clone();
        let mut tp = Transcript::new(b"bdl");
        let proofs =
            prove_layer_relu_bd(&zdp, &gap, &rz, &rga, q_bits, r_bits, &ck, &mut tp, &mut r);
        assert_eq!(proofs.len(), 4);
        let total: usize = proofs.iter().map(|p| p.size_bytes()).sum();
        assert!(total > 0);
        // verify all four in transcript order
        let mut tv = Transcript::new(b"bdl");
        verify_bd(&proofs[0], d, q_bits, &ck, &mut tv).unwrap();
        verify_bd(&proofs[1], d, q_bits, &ck, &mut tv).unwrap();
        verify_bd(&proofs[2], d, r_bits.max(2), &ck, &mut tv).unwrap();
        verify_bd(&proofs[3], d, r_bits.max(2), &ck, &mut tv).unwrap();
    }
}
