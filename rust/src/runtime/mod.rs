//! PJRT runtime — loads the AOT-compiled JAX/Pallas training step and
//! executes it from the rust coordinator. Python is never on this path:
//! the artifact is HLO text produced once by `make artifacts`
//! (python/compile/aot.py), compiled here with the PJRT CPU client.

use crate::model::{ModelConfig, Weights};
use crate::witness::{rescale_decompose, LayerWitness, StepWitness};
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};

/// A compiled training-step executable for one model configuration.
pub struct StepRuntime {
    pub cfg: ModelConfig,
    exe: xla::PjRtLoadedExecutable,
}

/// Default artifact path for a config.
pub fn artifact_path(dir: &Path, cfg: &ModelConfig) -> PathBuf {
    dir.join(format!(
        "model_L{}_d{}_b{}.hlo.txt",
        cfg.depth, cfg.width, cfg.batch
    ))
}

impl StepRuntime {
    /// Load + compile the HLO artifact for `cfg` from `dir`.
    pub fn load(dir: &Path, cfg: ModelConfig) -> Result<Self> {
        let path = artifact_path(dir, &cfg);
        ensure!(
            path.exists(),
            "artifact {} not found — run `make artifacts` (CONFIGS=\"{},{},{}\")",
            path.display(),
            cfg.depth,
            cfg.width,
            cfg.batch
        );
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .context("parse HLO text")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(Self { cfg, exe })
    }

    /// Execute one training step: returns the stacked output tensors
    /// (z, g_a, g_z, g_w) exactly as `python/compile/model.py` defines them.
    pub fn run_raw(
        &self,
        x: &[i64],
        y: &[i64],
        weights: &Weights,
    ) -> Result<(Vec<i64>, Vec<i64>, Vec<i64>, Vec<i64>)> {
        let cfg = &self.cfg;
        let (b, d, depth) = (cfg.batch as i64, cfg.width as i64, cfg.depth as i64);
        ensure!(x.len() == (b * d) as usize && y.len() == (b * d) as usize);
        let w_flat: Vec<i64> = weights.layers.iter().flatten().copied().collect();
        ensure!(w_flat.len() == (depth * d * d) as usize);

        let lx = xla::Literal::vec1(x).reshape(&[b, d])?;
        let ly = xla::Literal::vec1(y).reshape(&[b, d])?;
        let lw = xla::Literal::vec1(&w_flat).reshape(&[depth, d, d])?;

        let result = self.exe.execute::<xla::Literal>(&[lx, ly, lw])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(outs.len() == 4, "expected 4 outputs, got {}", outs.len());
        let z = outs[0].to_vec::<i64>()?;
        let ga = outs[1].to_vec::<i64>()?;
        let gz = outs[2].to_vec::<i64>()?;
        let gw = outs[3].to_vec::<i64>()?;
        Ok((z, ga, gz, gw))
    }

    /// Execute the step and assemble the full [`StepWitness`] (deriving the
    /// elementwise zkReLU auxiliary decompositions in rust).
    pub fn compute_witness(&self, x: &[i64], y: &[i64], weights: &Weights) -> Result<StepWitness> {
        let cfg = self.cfg;
        let (b, d, depth) = (cfg.batch, cfg.width, cfg.depth);
        let bd = b * d;
        let (z_all, ga_all, gz_all, gw_all) = self.run_raw(x, y, weights)?;
        ensure!(z_all.len() == depth * bd && gw_all.len() == depth * d * d);

        let mut layers = Vec::with_capacity(depth);
        for l in 0..depth {
            let z = z_all[l * bd..(l + 1) * bd].to_vec();
            let (z_aux, z_prime) = rescale_decompose(&z, cfg.r_bits, cfg.q_bits);
            let last = l + 1 == depth;
            let (a, g_a, g_a_aux, g_a_prime) = if last {
                (None, None, None, None)
            } else {
                let a: Vec<i64> = z_aux
                    .dprime
                    .iter()
                    .zip(z_aux.sign.iter())
                    .map(|(&dp, &s)| (1 - s) * dp)
                    .collect();
                let g_a = ga_all[l * bd..(l + 1) * bd].to_vec();
                let (aux, g_a_prime) = rescale_decompose(&g_a, cfg.r_bits, cfg.q_bits);
                (Some(a), Some(g_a), Some(aux), Some(g_a_prime))
            };
            layers.push(LayerWitness {
                w: weights.layers[l].clone(),
                z,
                z_prime,
                z_aux,
                a,
                g_a,
                g_a_aux,
                g_a_prime,
                g_z: gz_all[l * bd..(l + 1) * bd].to_vec(),
                g_w: gw_all[l * d * d..(l + 1) * d * d].to_vec(),
            });
        }
        Ok(StepWitness {
            cfg,
            x: x.to_vec(),
            y: y.to_vec(),
            layers,
            // rule-owned optimizer state and batch provenance are attached
            // by the coordinator, which owns the update loop and the batch
            // sampler; the runtime computes one step
            opt_state: Vec::new(),
            batch_rows: Vec::new(),
        })
    }
}

/// Witness source for the coordinator: AOT/PJRT artifact when available,
/// pure-rust native step otherwise.
pub enum WitnessSource {
    Pjrt(StepRuntime),
    Native(ModelConfig),
}

impl WitnessSource {
    /// Prefer the PJRT artifact; fall back to the native generator (bench
    /// sweeps cover shapes that were never AOT-compiled).
    pub fn auto(dir: &Path, cfg: ModelConfig) -> Self {
        match StepRuntime::load(dir, cfg) {
            Ok(rt) => WitnessSource::Pjrt(rt),
            Err(_) => WitnessSource::Native(cfg),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WitnessSource::Pjrt(_) => "pjrt",
            WitnessSource::Native(_) => "native",
        }
    }

    pub fn compute_witness(&self, x: &[i64], y: &[i64], w: &Weights) -> Result<StepWitness> {
        match self {
            WitnessSource::Pjrt(rt) => rt.compute_witness(x, y, w),
            WitnessSource::Native(cfg) => {
                Ok(crate::witness::native::compute_witness(*cfg, x, y, w))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn artifact_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn inputs(cfg: &ModelConfig, seed: u64) -> (Vec<i64>, Vec<i64>, Weights) {
        let mut rng = Rng::seed_from_u64(seed);
        let scale = cfg.scale();
        let x: Vec<i64> = (0..cfg.batch * cfg.width)
            .map(|_| rng.gen_i64(-scale, scale))
            .collect();
        let mut y = vec![0i64; cfg.batch * cfg.width];
        for i in 0..cfg.batch {
            y[i * cfg.width] = scale;
        }
        let w = Weights::init(*cfg, &mut rng);
        (x, y, w)
    }

    #[test]
    fn pjrt_witness_matches_native_bit_exactly() {
        let cfg = ModelConfig::new(2, 8, 4);
        let rt = match StepRuntime::load(&artifact_dir(), cfg) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: {e:#} (run `make artifacts`)");
                return;
            }
        };
        let (x, y, w) = inputs(&cfg, 11);
        let pjrt = rt.compute_witness(&x, &y, &w).expect("pjrt witness");
        pjrt.validate().expect("pjrt witness satisfies all relations");
        let native = crate::witness::native::compute_witness(cfg, &x, &y, &w);
        for (lp, ln) in pjrt.layers.iter().zip(native.layers.iter()) {
            assert_eq!(lp.z, ln.z, "Z mismatch");
            assert_eq!(lp.g_z, ln.g_z, "G_Z mismatch");
            assert_eq!(lp.g_w, ln.g_w, "G_W mismatch");
            assert_eq!(lp.g_a, ln.g_a, "G_A mismatch");
            assert_eq!(lp.z_aux, ln.z_aux, "aux mismatch");
        }
    }

    #[test]
    fn pjrt_witness_depth3() {
        let cfg = ModelConfig::new(3, 64, 16);
        let rt = match StepRuntime::load(&artifact_dir(), cfg) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: {e:#}");
                return;
            }
        };
        let (x, y, w) = inputs(&cfg, 12);
        let wit = rt.compute_witness(&x, &y, &w).expect("witness");
        wit.validate().expect("valid");
        let native = crate::witness::native::compute_witness(cfg, &x, &y, &w);
        assert_eq!(wit.layers[2].g_w, native.layers[2].g_w);
    }

    #[test]
    fn witness_source_fallback() {
        // a config with no artifact falls back to native
        let cfg = ModelConfig::new(4, 16, 8);
        let src = WitnessSource::auto(&artifact_dir(), cfg);
        assert_eq!(src.name(), "native");
        let (x, y, w) = inputs(&cfg, 13);
        let wit = src.compute_witness(&x, &y, &w).expect("witness");
        wit.validate().expect("valid");
    }
}
