//! Hash functions used across zkDL.
//!
//! * [`Md5`] — from-scratch RFC 1321 (Table 3 baseline hash).
//! * [`HashFn`] — runtime-selectable hash for the Merkle membership tree
//!   (md5 / sha1 / sha256, matching the paper's Table 3 columns).

pub mod md5;

pub use md5::Md5;

use sha1::Digest as _;

/// Runtime-selectable hash function for the Merkle tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashFn {
    Md5,
    Sha1,
    Sha256,
}

impl HashFn {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "md5" => Some(Self::Md5),
            "sha1" => Some(Self::Sha1),
            "sha256" => Some(Self::Sha256),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Md5 => "md5",
            Self::Sha1 => "sha1",
            Self::Sha256 => "sha256",
        }
    }

    /// Output length in bytes (16 / 20 / 32) — the Merkle tree height is
    /// 8 × this, as in the paper (k-bit hash ⇒ depth-k conceptual tree).
    pub fn output_len(&self) -> usize {
        match self {
            Self::Md5 => 16,
            Self::Sha1 => 20,
            Self::Sha256 => 32,
        }
    }

    pub fn hash(&self, data: &[u8]) -> Vec<u8> {
        match self {
            Self::Md5 => Md5::digest(data).to_vec(),
            Self::Sha1 => sha1::Sha1::digest(data).to_vec(),
            Self::Sha256 => sha2::Sha256::digest(data).to_vec(),
        }
    }

    /// Two-input hash (Merkle inner nodes): H(left ‖ right).
    pub fn hash2(&self, left: &[u8], right: &[u8]) -> Vec<u8> {
        let mut buf = Vec::with_capacity(left.len() + right.len());
        buf.extend_from_slice(left);
        buf.extend_from_slice(right);
        self.hash(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_lengths() {
        for (h, l) in [(HashFn::Md5, 16), (HashFn::Sha1, 20), (HashFn::Sha256, 32)] {
            assert_eq!(h.hash(b"x").len(), l);
            assert_eq!(h.output_len(), l);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["md5", "sha1", "sha256"] {
            assert_eq!(HashFn::parse(name).unwrap().name(), name);
        }
        assert!(HashFn::parse("blake3").is_none());
    }

    #[test]
    fn sha256_known_vector() {
        let d = HashFn::Sha256.hash(b"abc");
        assert_eq!(
            d.iter().map(|b| format!("{b:02x}")).collect::<String>(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn sha1_known_vector() {
        let d = HashFn::Sha1.hash(b"abc");
        assert_eq!(
            d.iter().map(|b| format!("{b:02x}")).collect::<String>(),
            "a9993e364706816aba3e25717850c26c9cd0d89d"
        );
    }

    #[test]
    fn hash2_concatenates() {
        assert_eq!(
            HashFn::Sha256.hash2(b"ab", b"c"),
            HashFn::Sha256.hash(b"abc")
        );
    }
}
