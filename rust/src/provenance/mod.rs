//! zkData — batch provenance: binding every training step's inputs to a
//! committed, endorsable dataset.
//!
//! A [`crate::aggregate::TraceProof`] (chained or not) proves that each
//! step's relations hold over *its own* committed input `com_x` and target
//! `com_y` — but nothing ties those commitments to any particular dataset.
//! A prover holding an Appendix-B endorsement for dataset D can still train
//! on arbitrary data. This module closes that gap:
//!
//! * **One-time dataset commitment.** The full quantized dataset — points
//!   *and* one-hot labels — is laid out as one tiled tensor on a dedicated
//!   `zkdl/data` basis: row k owns block k·2d with its padded point in the
//!   first d entries and scale·onehot(label) in the second d. Each row's
//!   block commitment C_k (deterministic, r = 0, paper §3.1) is a leaf of
//!   the Appendix-B Merkle tree via the canonical 32-byte compressed-point
//!   codec ([`crate::merkle::point_leaf`]); the single dataset commitment
//!   `com_d = Σ_k C_k` is then *derivable from the endorsed leaf set* — the
//!   endorser checks exactly this ([`verify_dataset_endorsement`]) before
//!   signing the root, so "com_d is the dataset under the endorsed root" is
//!   a public, recomputable fact, not a trust assumption.
//!
//! * **Per-trace batch-selection argument.** The prover commits one stacked
//!   selection tensor S (T̄ slots of B×n̄ each, slot t = the step's selection
//!   matrix S_t) with a single commitment `com_s` on a `zkdl/data/sel`
//!   basis, and proves, for every step t:
//!     X_t = S_t·D_pts  and  Y_t = S_t·D_lab
//!   via ONE γ-folded matmul sumcheck over the dataset-row axis, with the
//!   claims bound homomorphically: X̃_t/Ỹ_t open against the trace's own
//!   `com_x`/`com_y`, D̃ against `com_d` (a δ-fold of the points/labels
//!   halves), and the per-step S̃_t(u, r) against `com_s` through the same
//!   γ-powered slot selector the zkOptim chain uses ([`crate::update`]).
//!
//! * **One-hot rows.** Booleanity of every S entry rides the existing
//!   zkReLU validity machinery: a Protocol-1 *main* instance whose sign
//!   tensor is S itself (`com_s` plays com_{B_{Q−1}}; the paired value
//!   tensor is identically zero), so S ∈ {0,1}ᴺ follows from the paper's
//!   k-coupled binarity check — no new range gadget. A row-sum claim
//!   (⟨S, e_rows(u)⊗1_{k<n}⟩ = Σ_{live rows} e_rows(u), RLC'd into the same
//!   S opening) then pins every live row to exactly one live selection.
//!   Together: every batch row of X_t *is* a dataset row and its Y_t row is
//!   that row's label — the `com_x`/`com_y` the trace's matmul and loss
//!   arguments already constrain.
//!
//! Everything defers into the trace's `MsmAccumulator`; a provenance trace
//! still verifies with exactly one MSM flush. See DESIGN.md §provenance.

use crate::aggregate::StepCommitmentSet;
use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::{G1Affine, G1};
use crate::data::Dataset;
use crate::field::Fr;
use crate::hash::HashFn;
use crate::ipa::{self, EvalClaim, IpaProof};
use crate::merkle::{leaf_point, point_leaf, MerkleTree};
use crate::model::ModelConfig;
use crate::poly::{eq_table, Mle};
use crate::sumcheck::{self, Instance, SumcheckProof, Term};
use crate::telemetry::failure::Classify;
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use crate::util::threads;
use crate::witness::StepWitness;
use crate::zkdl::{commit, frs, tile_claims_at, tiled_eq, Committed};
use crate::zkrelu::{self, Protocol1Msg, ProverAux, ValidityBases, ValidityProof};
use anyhow::{ensure, Context, Result};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// The endorsement hash of the Appendix-B bridge. Pinned (rather than
/// artifact-chosen) so every provenance statement's root lives in one
/// 32-byte domain.
pub const PROVENANCE_HASH: HashFn = HashFn::Sha256;

/// Digit width of the booleanity instance: S entries are {0,1}, so the
/// minimal power-of-two width suffices (the sign column is column 1).
const SEL_WIDTH: usize = 2;

/// Padded step count T̄, padded dataset-row count n̄, the stacked selection
/// size N_S = T̄·B·n̄ (slot t's row i, dataset column k lives at index
/// (t·B + i)·n̄ + k), and the dataset tensor size N_D = n̄·2d. Errors on
/// degenerate or overflowing shapes — the wire decoder, the provers, and
/// `verify_trace_accum` all guard with this before any key setup.
pub fn checked_selection_dims(
    cfg: &ModelConfig,
    steps: usize,
    n_rows: usize,
) -> Result<(usize, usize, usize, usize)> {
    ensure!(steps >= 1, "provenance needs at least one step");
    ensure!(n_rows >= 1, "empty dataset");
    ensure!(cfg.width >= 2, "provenance needs width >= 2");
    // n_rows is wire-controlled: the unchecked next_power_of_two would
    // panic (debug) or wrap to 0 (release) past 2^63 — fail cleanly instead
    let tbar = steps
        .checked_next_power_of_two()
        .context("step count overflows padding")?;
    let nbar = n_rows
        .checked_next_power_of_two()
        .context("dataset row count overflows padding")?
        .max(2);
    let n_sel = tbar
        .checked_mul(cfg.batch)
        .and_then(|x| x.checked_mul(nbar))
        .context("selection stack dimensions overflow")?;
    let n_data = nbar
        .checked_mul(2 * cfg.width)
        .context("dataset tensor dimensions overflow")?;
    ensure!(n_sel >= 2, "degenerate selection stack");
    Ok((tbar, nbar, n_sel, n_data))
}

/// The public dataset statement a provenance trace carries: the one MLE
/// commitment to the full dataset tensor plus the Appendix-B root its
/// per-row leaf commitments hash to. Both are absorbed into the trace
/// transcript before any challenge.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DatasetCommitment {
    /// Live dataset rows n (the statement; padding rows are zero).
    pub n_rows: usize,
    /// com_d = Σ_k C_k — the tiled dataset MLE commitment (deterministic).
    pub com_d: G1Affine,
    /// Merkle root over the 32-byte compressed leaf encodings of the C_k,
    /// the object a trusted verifier endorses (paper Appendix B).
    pub root: Vec<u8>,
}

/// Prover-side dataset: the embedded tensor, its commitment, and the
/// endorsement material (leaves + tree). Built once, reused across every
/// trace window proving against this dataset.
pub struct ProverDataset {
    /// Model width d the tensor was embedded for.
    pub width: usize,
    /// Scale 2^R the labels were embedded at.
    pub r_bits: u32,
    /// The tiled dataset tensor, length n̄·2d — shared (`Arc`) so the
    /// coordinator's per-window proofs never deep-copy it.
    tensor: Arc<Vec<Fr>>,
    pub commitment: DatasetCommitment,
    /// Canonical 32-byte leaf encodings of the per-row commitments C_k.
    pub leaves: Vec<Vec<u8>>,
    /// The Appendix-B tree over `leaves`; `tree.root` is what gets endorsed.
    pub tree: MerkleTree,
}

impl ProverDataset {
    /// Embed and commit `ds` for models of configuration `cfg`. Row k's
    /// block is [point_k ∥ scale·onehot(label_k)], zero-padded to 2d.
    pub fn build(ds: &Dataset, cfg: &ModelConfig) -> Result<Self> {
        let d = cfg.width;
        let n = ds.len();
        let (_, nbar, _, n_data) = checked_selection_dims(cfg, 1, n)?;
        ensure!(ds.dim <= d, "dataset dim {} exceeds model width {d}", ds.dim);
        ensure!(
            ds.num_classes <= d,
            "dataset classes {} exceed model width {d}",
            ds.num_classes
        );
        let scale = cfg.scale();
        let mut tensor = vec![Fr::ZERO; n_data];
        for k in 0..n {
            let base = k * 2 * d;
            for (j, &v) in ds.points[k].iter().enumerate() {
                tensor[base + j] = Fr::from_i64(v);
            }
            tensor[base + d + ds.labels[k]] = Fr::from_i64(scale);
        }
        let g_data = CommitKey::setup(b"zkdl/data", n_data);
        g_data.warm_table();
        // per-row leaf commitments C_k on the row's basis block (r = 0);
        // each row is a slice commit against the shared fixed-base table
        let row_coms: Vec<G1> = (0..n)
            .map(|k| {
                g_data
                    .slice(k * 2 * d, (k + 1) * 2 * d)
                    .commit_deterministic(&tensor[k * 2 * d..(k + 1) * 2 * d])
            })
            .collect();
        let affine = G1::batch_to_affine(&row_coms);
        let leaves: Vec<Vec<u8>> = affine.iter().map(point_leaf).collect();
        let tree = MerkleTree::build(PROVENANCE_HASH, &leaves);
        let mut com_d = G1::IDENTITY;
        for c in &row_coms {
            com_d = com_d + *c;
        }
        let commitment = DatasetCommitment {
            n_rows: n,
            com_d: com_d.to_affine(),
            root: tree.root.clone(),
        };
        Ok(Self {
            width: d,
            r_bits: cfg.r_bits,
            tensor: Arc::new(tensor),
            commitment,
            leaves,
            tree,
        })
    }

    pub fn n_rows(&self) -> usize {
        self.commitment.n_rows
    }

    /// The embedded dataset tensor (n̄·2d field elements).
    pub fn tensor(&self) -> &[Fr] {
        &self.tensor
    }
}

/// The endorser's side of the Appendix-B bridge: given the released leaf
/// set, check that (a) the leaves rebuild `root` under the canonical
/// encoding and (b) the claimed dataset MLE commitment is exactly the sum
/// of the leaf points. A root endorsed after this check binds `com_d`
/// transitively: any trace proving against `com_d` proves against the
/// endorsed dataset.
pub fn verify_dataset_endorsement(
    leaves: &[Vec<u8>],
    root: &[u8],
    com_d: &G1Affine,
) -> Result<()> {
    ensure!(!leaves.is_empty(), "endorsement: empty leaf set");
    let tree = MerkleTree::build(PROVENANCE_HASH, leaves);
    ensure!(tree.root == root, "endorsement: leaf set does not rebuild the root");
    let mut sum = G1::IDENTITY;
    for leaf in leaves {
        let p = leaf_point(leaf).context("endorsement: malformed leaf")?;
        sum = sum + p.to_projective();
    }
    ensure!(
        sum.to_affine() == *com_d,
        "endorsement: leaf commitments do not sum to the dataset commitment"
    );
    Ok(())
}

/// Commitment bases for the provenance argument of a T-step trace against
/// an n-row dataset.
pub struct ProvenanceKey {
    pub cfg: ModelConfig,
    pub steps: usize,
    pub n_rows: usize,
    /// Padded step count T̄ and dataset-row count n̄.
    pub tbar: usize,
    pub nbar: usize,
    /// Stacked selection size N_S = T̄·B·n̄.
    pub n_sel: usize,
    /// Dataset tensor basis, length n̄·2d (shared with [`ProverDataset`]).
    pub g_data: CommitKey,
    /// Stacked selection basis, length N_S.
    pub g_sel: CommitKey,
}

#[allow(clippy::type_complexity)]
static PROVKEY_CACHE: Lazy<
    Mutex<HashMap<((usize, usize, usize, u32, u32, u32), usize, usize), Arc<ProvenanceKey>>>,
> = Lazy::new(|| Mutex::new(HashMap::new()));

/// Cache-entry ceiling: (steps, n_rows) come from artifact statements, so
/// verifying hostile artifacts must not grow resident memory without bound.
const PROVKEY_CACHE_CAP: usize = 128;

impl ProvenanceKey {
    /// Derive (or fetch) the key for (cfg, steps, n_rows). Callers on
    /// untrusted input must guard with [`checked_selection_dims`] first —
    /// this panics on degenerate shapes.
    pub fn setup(cfg: ModelConfig, steps: usize, n_rows: usize) -> Arc<Self> {
        let cfg_key = (cfg.depth, cfg.width, cfg.batch, cfg.r_bits, cfg.q_bits, cfg.lr_shift);
        let key = (cfg_key, steps, n_rows);
        if let Some(pk) = PROVKEY_CACHE.lock().unwrap().get(&key) {
            crate::telemetry::count(crate::telemetry::Counter::ProvKeyHits, 1);
            return pk.clone();
        }
        crate::telemetry::count(crate::telemetry::Counter::ProvKeyMisses, 1);
        let (tbar, nbar, n_sel, n_data) =
            checked_selection_dims(&cfg, steps, n_rows).expect("invalid provenance dimensions");
        let pk = Arc::new(Self {
            cfg,
            steps,
            n_rows,
            tbar,
            nbar,
            n_sel,
            g_data: CommitKey::setup(b"zkdl/data", n_data),
            g_sel: CommitKey::setup(b"zkdl/data/sel", n_sel),
        });
        // fixed-base tables (Arc-cached with the key; no-ops past the
        // table size cap): g_data serves every per-row leaf commitment
        pk.g_data.warm_table();
        pk.g_sel.warm_table();
        let mut cache = PROVKEY_CACHE.lock().unwrap();
        if cache.len() >= PROVKEY_CACHE_CAP {
            let evict = cache.keys().next().cloned();
            if let Some(evict) = evict {
                cache.remove(&evict);
                crate::telemetry::count(crate::telemetry::Counter::ProvKeyEvictions, 1);
            }
        }
        cache.insert(key, pk.clone());
        pk
    }
}

/// Booleanity bases: a zkReLU *main* instance over N_S rows at the minimal
/// width, the sign column tied to `g_sel` — so `com_s` itself is the sign
/// commitment and S ∈ {0,1}ᴺ rides the paper's k-coupled binarity check.
/// The label pins (T, n), so two traces with the same padded layout but
/// different live extents never share an instance.
fn selection_validity_bases(pk: &ProvenanceKey) -> Arc<ValidityBases> {
    let t = pk.steps as u64;
    let n = pk.n_rows as u64;
    let label = [
        b"zkdl/trace/validity/sel/".as_ref(),
        &t.to_le_bytes(),
        &n.to_le_bytes(),
    ]
    .concat();
    ValidityBases::setup_main(&label, &pk.g_sel, pk.n_sel, SEL_WIDTH)
}

fn dot(a: &[Fr], b: &[Fr]) -> Fr {
    let n = a.len().min(b.len());
    threads::par_reduce(
        n,
        1 << 10,
        Fr::ZERO,
        |r, acc| {
            a[r.clone()]
                .iter()
                .zip(&b[r])
                .fold(acc, |s, (x, y)| s + *x * *y)
        },
        |x, y| x + y,
    )
}

/// Σᵢ γⁱ·valsᵢ.
fn gamma_fold(vals: &[Fr], gamma: Fr) -> Fr {
    let mut coeff = Fr::ONE;
    let mut acc = Fr::ZERO;
    for v in vals {
        acc += coeff * *v;
        coeff *= gamma;
    }
    acc
}

/// The prover's batch-provenance witness: `rows[t][i]` is the dataset row
/// index behind step t's batch row i.
pub struct ProvenanceWitness {
    pub rows: Vec<Vec<usize>>,
}

impl ProvenanceWitness {
    /// Recover the selection witness from the step witnesses' `batch_rows`
    /// and validate it against the committed dataset: every X row must be
    /// exactly the claimed dataset point row and every Y row its one-hot
    /// label row. Fails — naming step and batch row — otherwise ("does not
    /// open against the dataset").
    pub fn build(pd: &ProverDataset, wits: &[StepWitness]) -> Result<Self> {
        ensure!(!wits.is_empty(), "provenance needs at least one step");
        let cfg = wits[0].cfg;
        ensure!(pd.width == cfg.width, "dataset embedded for a different width");
        ensure!(pd.r_bits == cfg.r_bits, "dataset embedded at a different scale");
        let (b, d) = (cfg.batch, cfg.width);
        let n = pd.n_rows();
        let mut rows = Vec::with_capacity(wits.len());
        for (t, wit) in wits.iter().enumerate() {
            ensure!(
                wit.batch_rows.len() == b,
                "step {t} carries {} batch-row indices, batch is {b} \
                 (witness generated without provenance tracking?)",
                wit.batch_rows.len()
            );
            let x = frs(&wit.x);
            let y = frs(&wit.y);
            for (i, &k) in wit.batch_rows.iter().enumerate() {
                ensure!(k < n, "step {t} row {i}: dataset row {k} out of range (n = {n})");
                let base = k * 2 * d;
                ensure!(
                    x[i * d..(i + 1) * d] == pd.tensor[base..base + d],
                    "step {t} row {i}: X does not open against dataset row {k}"
                );
                ensure!(
                    y[i * d..(i + 1) * d] == pd.tensor[base + d..base + 2 * d],
                    "step {t} row {i}: labels do not open against dataset row {k}"
                );
            }
            rows.push(wit.batch_rows.clone());
        }
        Ok(Self { rows })
    }
}

/// The provenance argument appended to a [`crate::aggregate::TraceProof`].
/// The dataset commitment (with its endorsed root) and `com_s` are part of
/// the *statement* — a verifying party audits the root against the
/// endorsement exactly like the step commitments.
#[derive(Clone, Debug)]
pub struct ProvenanceProof {
    pub dataset: DatasetCommitment,
    /// The single commitment to the stacked selection tensor S.
    pub com_s: G1Affine,
    /// Protocol-1 message of the booleanity instance (sign tensor = S).
    pub p1_sel: Protocol1Msg,
    /// X̃_t(u_r, u_c) per step.
    pub v_x: Vec<Fr>,
    /// Ỹ_t(u_r, u_c) per step.
    pub v_y: Vec<Fr>,
    /// The γ-folded selection sumcheck over the dataset-row axis.
    pub sel: SumcheckProof,
    /// S̃_t(u_r, r_k) per step.
    pub sel_evals: Vec<Fr>,
    /// D̃_pts(r_k, u_c) and D̃_lab(r_k, u_c).
    pub v_dpts: Fr,
    pub v_dlab: Fr,
    /// S̃(ρ_v) — the booleanity instance's sign-tensor opening.
    pub v_sel: Fr,
    /// Opening IPAs: [X @ p, Y @ p (tiled), D δ-fold @ (r_k, ·, u_c),
    /// S γ-fold slots + row-sum, S @ validity point].
    pub openings: Vec<IpaProof>,
    pub validity: ValidityProof,
}

impl ProvenanceProof {
    /// Compressed-point accounting, matching
    /// [`crate::aggregate::TraceProof::size_bytes`].
    pub fn size_bytes(&self) -> usize {
        let coms = 2 + 1 + usize::from(self.p1_sel.com_sign_prime.is_some());
        let scalars = self.v_x.len() + self.v_y.len() + self.sel_evals.len() + 3;
        let statement = 8 + self.dataset.root.len();
        let openings: usize = self.openings.iter().map(|o| o.size_bytes()).sum();
        (coms + scalars) * 32
            + statement
            + self.sel.size_bytes()
            + openings
            + self.validity.size_bytes()
    }
}

/// Prover-side commitments of the provenance argument, produced before any
/// transcript challenge (the trace absorbs them up front, alongside the
/// step and chain commitments, so the shared-randomness property covers the
/// selection tensor too).
pub(crate) struct ProvenanceCommitments {
    pub(crate) dataset: DatasetCommitment,
    /// The dataset tensor (opening values of `com_d`; blind 0) — shared
    /// with the [`ProverDataset`], copied only once, at the P3 claim.
    pub(crate) d_tensor: Arc<Vec<Fr>>,
    /// The stacked selection tensor with its single hiding commitment.
    pub(crate) s: Committed,
    pub(crate) com_s: G1Affine,
    pub(crate) p1: Protocol1Msg,
    pub(crate) aux: ProverAux,
    pub(crate) vb: Arc<ValidityBases>,
}

pub(crate) fn commit_provenance(
    pk: &ProvenanceKey,
    pd: &ProverDataset,
    pw: &ProvenanceWitness,
    rng: &mut Rng,
) -> Result<ProvenanceCommitments> {
    crate::span!("provenance/commit");
    let cfg = &pk.cfg;
    let (b, nbar, n_sel) = (cfg.batch, pk.nbar, pk.n_sel);
    ensure!(pw.rows.len() == pk.steps, "provenance witness step count");
    ensure!(
        pd.n_rows() == pk.n_rows && pd.width == cfg.width,
        "dataset/key mismatch"
    );
    let mut stacked = vec![Fr::ZERO; n_sel];
    for (t, per_step) in pw.rows.iter().enumerate() {
        ensure!(per_step.len() == b, "provenance witness batch shape");
        for (i, &k) in per_step.iter().enumerate() {
            ensure!(k < pk.n_rows, "provenance witness row index");
            stacked[(t * b + i) * nbar + k] = Fr::ONE;
        }
    }
    let s = commit(&pk.g_sel, stacked, rng);
    let com_s = s.com.to_affine();
    let vb = selection_validity_bases(pk);
    // booleanity: a main instance whose paired value tensor is identically
    // zero and whose sign tensor is S — com_s doubles as com_{B_{Q−1}}
    let zeros = vec![Fr::ZERO; 2 * n_sel];
    let (p1, aux) = zkrelu::protocol1_main(&vb, &zeros, &s.values, s.blind, rng);
    Ok(ProvenanceCommitments {
        dataset: pd.commitment.clone(),
        d_tensor: pd.tensor.clone(),
        s,
        com_s,
        p1,
        aux,
        vb,
    })
}

/// Absorb the provenance statement — dataset size, MLE commitment, endorsed
/// root, selection commitment — right after the chain statement, before
/// Protocol 1 / any challenge. A swapped root, substituted dataset, or
/// edited selection tensor therefore lands in a different transcript and
/// fails every subsequent check.
pub(crate) fn absorb_provenance_statement(
    tr: &mut Transcript,
    dataset: &DatasetCommitment,
    com_s: &G1Affine,
) {
    tr.absorb_u64(b"prov/n_rows", dataset.n_rows as u64);
    tr.absorb_point(b"com/d", &dataset.com_d);
    tr.absorb_bytes(b"prov/root", &dataset.root);
    tr.absorb_point(b"com/s", com_s);
}

/// Structural validation shared by the wire decoder and the verifier.
pub fn validate_provenance_shape(
    cfg: &ModelConfig,
    steps: usize,
    proof: &ProvenanceProof,
) -> Result<()> {
    checked_selection_dims(cfg, steps, proof.dataset.n_rows)?;
    ensure!(
        proof.dataset.root.len() == PROVENANCE_HASH.output_len(),
        "provenance: root is not a {} digest",
        PROVENANCE_HASH.name()
    );
    ensure!(proof.v_x.len() == steps, "provenance: v_x length");
    ensure!(proof.v_y.len() == steps, "provenance: v_y length");
    ensure!(proof.sel_evals.len() == steps, "provenance: sel_evals length");
    ensure!(proof.openings.len() == 5, "provenance: opening count");
    ensure!(
        proof.p1_sel.com_sign_prime.is_some(),
        "provenance: booleanity instance must carry com_sign_prime"
    );
    Ok(())
}

/// The provenance argument proper, appended after the trace's chain phase.
/// `x`/`y` are the per-step input/target commitments (the same objects the
/// trace's matmul and loss openings use); `y_slots[t]` is step t's
/// last-layer slot in the `trace_slots`-slot stacked aux basis.
#[allow(clippy::too_many_arguments)]
pub(crate) fn prove_provenance(
    pk: &ProvenanceKey,
    g_x: &CommitKey,
    g_aux: &CommitKey,
    trace_slots: usize,
    y_slots: &[usize],
    x: &[&Committed],
    y: &[&Committed],
    pc: ProvenanceCommitments,
    tr: &mut Transcript,
    rng: &mut Rng,
) -> ProvenanceProof {
    crate::span!("provenance/prove");
    let ProvenanceCommitments {
        dataset,
        d_tensor,
        s,
        com_s,
        p1,
        aux,
        vb,
    } = pc;
    let cfg = &pk.cfg;
    let (b, d) = (cfg.batch, cfg.width);
    let dd = cfg.d_size();
    let t_steps = pk.steps;
    let nbar = pk.nbar;
    let n_sel = pk.n_sel;
    let log_b = b.trailing_zeros() as usize;
    let log_d = d.trailing_zeros() as usize;

    // one challenge pair over the (batch-row, feature) space, shared by the
    // X and Y claims of every step
    let u_pr = tr.challenge_frs(b"prov/u_r", log_b);
    let u_pc = tr.challenge_frs(b"prov/u_c", log_d);
    let p_xy: Vec<Fr> = [u_pr.clone(), u_pc.clone()].concat();
    let e_xy = eq_table(&p_xy);
    let v_x: Vec<Fr> = x.iter().map(|c| dot(&c.values, &e_xy)).collect();
    let v_y: Vec<Fr> = y.iter().map(|c| dot(&c.values, &e_xy)).collect();
    tr.absorb_frs(b"prov/v_x", &v_x);
    tr.absorb_frs(b"prov/v_y", &v_y);
    let gamma = tr.challenge_fr(b"prov/gamma");

    // γ-folded selection sumcheck over the dataset-row axis k:
    //   Σ_t γ^{2t}·X̃_t(u) + γ^{2t+1}·Ỹ_t(u)
    //     = Σ_k [Σ_t γ^{2t}·S̃_t(u_r,k)]·D̃_pts(k,u_c) + (labels analogue)
    let e_r = eq_table(&u_pr);
    let e_c = eq_table(&u_pc);
    // Per-row restrictions of the dataset tensor: each k is an independent
    // d-length fold, tiled across the pool (the in-row accumulation order
    // is unchanged, so every lane count gives the same field elements).
    let dp_fix = threads::par_tabulate(nbar, 1 << 7, Fr::ZERO, |k| {
        let base = k * 2 * d;
        (0..d).fold(Fr::ZERO, |acc, c| acc + e_c[c] * d_tensor[base + c])
    });
    let dl_fix = threads::par_tabulate(nbar, 1 << 7, Fr::ZERO, |k| {
        let base = k * 2 * d;
        (0..d).fold(Fr::ZERO, |acc, c| acc + e_c[c] * d_tensor[base + d + c])
    });
    let dp_mle = Mle::new(dp_fix);
    let dl_mle = Mle::new(dl_fix);
    // Per-step row-fixes of the selection tensor fan out over steps; the
    // k-axis within a step is additionally chunk-tiled (nested calls run
    // inline, so whichever level wins the lanes, the other is sequential).
    let gpow: Vec<Fr> = {
        let mut out = Vec::with_capacity(2 * t_steps);
        let mut c = Fr::ONE;
        for _ in 0..2 * t_steps {
            out.push(c);
            c *= gamma;
        }
        out
    };
    let step_mles: Vec<Mle> = threads::par_map_indexed(t_steps, |t| {
        let base = t * b * nbar;
        let mut s_fix = vec![Fr::ZERO; nbar];
        threads::par_chunks_mut(&mut s_fix, 256, |ci, chunk| {
            let k0 = ci * 256;
            for (i, er) in e_r.iter().enumerate() {
                let row = base + i * nbar + k0;
                for (k, sf) in chunk.iter_mut().enumerate() {
                    *sf += *er * s.values[row + k];
                }
            }
        });
        Mle::new(s_fix)
    });
    let mut terms = Vec::with_capacity(2 * t_steps);
    for (t, s_mle) in step_mles.into_iter().enumerate() {
        terms.push(Term::new(gpow[2 * t], vec![s_mle.clone(), dp_mle.clone()]));
        terms.push(Term::new(gpow[2 * t + 1], vec![s_mle, dl_mle.clone()]));
    }
    let out = sumcheck::prove(Instance::new(terms), tr);
    let r_k = out.point.clone();
    let sel_evals: Vec<Fr> = (0..t_steps).map(|t| out.factor_evals[2 * t][0]).collect();
    let v_dpts = out.factor_evals[0][1];
    let v_dlab = out.factor_evals[1][1];
    tr.absorb_frs(b"prov/sel_evals", &sel_evals);
    tr.absorb_fr(b"prov/v_dpts", &v_dpts);
    tr.absorb_fr(b"prov/v_dlab", &v_dlab);

    let mut openings = Vec::with_capacity(5);
    // P1: every X̃_t(u) on the shared g_x basis, one RLC'd IPA
    {
        let claims: Vec<EvalClaim> = x
            .iter()
            .zip(v_x.iter())
            .map(|(c, v)| EvalClaim {
                com: c.com,
                values: c.values.clone(),
                blind: c.blind,
                v: *v,
            })
            .collect();
        openings.push(ipa::batch_prove_eval_expr(g_x, &claims, &e_xy, tr, rng));
    }
    // P2: every Ỹ_t(u), tiled at the step's last-layer slot of g_aux
    {
        let claims: Vec<EvalClaim> = y
            .iter()
            .zip(v_y.iter())
            .map(|(c, v)| EvalClaim {
                com: c.com,
                values: c.values.clone(),
                blind: c.blind,
                v: *v,
            })
            .collect();
        let claims = tile_claims_at(claims, y_slots, trace_slots, dd);
        openings.push(ipa::batch_prove_eval_expr(
            g_aux,
            &claims,
            &tiled_eq(&p_xy, trace_slots),
            tr,
            rng,
        ));
    }
    // P3: the dataset tensor at (r_k, ·, u_c): a δ-fold of the points half
    // (middle variable 0) and the labels half (1) — one opening of com_d
    {
        let delta = tr.challenge_fr(b"prov/delta");
        let mut pt0 = r_k.clone();
        pt0.push(Fr::ZERO);
        pt0.extend_from_slice(&u_pc);
        let mut pt1 = r_k.clone();
        pt1.push(Fr::ONE);
        pt1.extend_from_slice(&u_pc);
        let e0 = eq_table(&pt0);
        let e1 = eq_table(&pt1);
        let evec =
            threads::par_tabulate(e0.len().min(e1.len()), 1 << 10, Fr::ZERO, |i| {
                e0[i] + delta * e1[i]
            });
        let claim = EvalClaim {
            com: dataset.com_d.to_projective(),
            values: (*d_tensor).clone(),
            blind: Fr::ZERO,
            v: v_dpts + delta * v_dlab,
        };
        openings.push(ipa::batch_prove_eval_expr(&pk.g_data, &[claim], &evec, tr, rng));
    }
    // P4: com_s — the γ_s-folded live-slot openings S̃_t(u_r, r_k) plus the
    // row-sum claim ⟨S, e_rows(u_row) ⊗ 1_{k<n}⟩ = Σ_{live rows} e_rows,
    // all RLC'd into one IPA. γ_s is drawn after the sumcheck absorbed the
    // per-slot evals, so Schwartz–Zippel over γ_s pins each live slot (and
    // the row-sum identity) individually.
    {
        let gamma_s = tr.challenge_fr(b"prov/gamma_s");
        let log_rows = (pk.tbar * b).trailing_zeros() as usize;
        let u_row = tr.challenge_frs(b"prov/u_row", log_rows);
        let e_row_tbl = eq_table(&u_row);
        let e_a = eq_table(&[u_pr.clone(), r_k.clone()].concat());
        let mut w = vec![Fr::ZERO; n_sel];
        // γ_s-powers up front; each step's b·nbar block of w is disjoint,
        // so the folded e_a scatter tiles step-blocks across the pool, and
        // the row-sum weights then tile row-blocks the same way.
        let gpow_s: Vec<Fr> = {
            let mut out = Vec::with_capacity(t_steps + 1);
            let mut c = Fr::ONE;
            for _ in 0..=t_steps {
                out.push(c);
                c *= gamma_s;
            }
            out
        };
        let coeff = gpow_s[t_steps];
        threads::par_chunks_mut(&mut w[..t_steps * b * nbar], b * nbar, |t, chunk| {
            for (o, v) in chunk.iter_mut().zip(e_a.iter()) {
                *o += gpow_s[t] * *v;
            }
        });
        threads::par_chunks_mut(&mut w[..t_steps * b * nbar], nbar, |row, chunk| {
            for slot in chunk.iter_mut().take(pk.n_rows) {
                *slot += coeff * e_row_tbl[row];
            }
        });
        let mut rowsum_target = Fr::ZERO;
        for row in 0..t_steps * b {
            rowsum_target += e_row_tbl[row];
        }
        let claim = EvalClaim {
            com: s.com,
            values: s.values.clone(),
            blind: s.blind,
            v: gamma_fold(&sel_evals, gamma_s) + coeff * rowsum_target,
        };
        openings.push(ipa::batch_prove_eval_expr(&pk.g_sel, &[claim], &w, tr, rng));
    }

    // validity point over the stacked selection tensor
    let u_dd = tr.challenge_fr(b"prov/u_dd");
    let log_s = n_sel.trailing_zeros() as usize;
    let rho_v = tr.challenge_frs(b"prov/rho", log_s);
    let e_rho = eq_table(&rho_v);
    let v_sel = dot(&s.values, &e_rho);
    // P5: the sign-tensor opening binding v_sel (and thus the booleanity
    // instance) to com_s — the last use of the tensor, so it moves in
    {
        let claim = EvalClaim {
            com: s.com,
            values: s.values,
            blind: s.blind,
            v: v_sel,
        };
        openings.push(ipa::batch_prove_eval_expr(&pk.g_sel, &[claim], &e_rho, tr, rng));
    }
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho_v);
    let e_row_v = eq_table(&vpoint);
    // the paired value tensor is identically zero by construction, so the
    // claimed paired evaluation is the constant 0 on both sides
    let validity = zkrelu::prove_validity(&vb, &aux, &e_row_v, u_dd, Fr::ZERO, v_sel, tr, rng);

    ProvenanceProof {
        dataset,
        com_s,
        p1_sel: p1,
        v_x,
        v_y,
        sel: out.proof,
        sel_evals,
        v_dpts,
        v_dlab,
        v_sel,
        openings,
        validity,
    }
}

/// Transcript replay + deferred checks of the provenance argument (mirrors
/// [`prove_provenance`] exactly). No curve arithmetic: every group equation
/// lands in `acc`, preserving the trace's one-MSM invariant.
#[allow(clippy::too_many_arguments)]
pub(crate) fn verify_provenance_accum(
    pk: &ProvenanceKey,
    g_x: &CommitKey,
    g_aux: &CommitKey,
    trace_slots: usize,
    y_slots: &[usize],
    coms: &[StepCommitmentSet],
    proof: &ProvenanceProof,
    tr: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("provenance/verify");
    let cfg = &pk.cfg;
    let (b, d) = (cfg.batch, cfg.width);
    let t_steps = pk.steps;
    let nbar = pk.nbar;
    let n_sel = pk.n_sel;
    let log_b = b.trailing_zeros() as usize;
    let log_d = d.trailing_zeros() as usize;
    validate_provenance_shape(cfg, t_steps, proof)?;
    ensure!(proof.dataset.n_rows == pk.n_rows, "provenance: dataset/key mismatch");
    ensure!(coms.len() == t_steps, "provenance: step commitment count");
    ensure!(y_slots.len() == t_steps, "provenance: y slot count");

    let u_pr = tr.challenge_frs(b"prov/u_r", log_b);
    let u_pc = tr.challenge_frs(b"prov/u_c", log_d);
    let p_xy: Vec<Fr> = [u_pr.clone(), u_pc.clone()].concat();
    let e_xy = eq_table(&p_xy);
    tr.absorb_frs(b"prov/v_x", &proof.v_x);
    tr.absorb_frs(b"prov/v_y", &proof.v_y);
    let gamma = tr.challenge_fr(b"prov/gamma");

    let mut claimed = Fr::ZERO;
    let mut coeff = Fr::ONE;
    for t in 0..t_steps {
        claimed += coeff * proof.v_x[t];
        coeff *= gamma;
        claimed += coeff * proof.v_y[t];
        coeff *= gamma;
    }
    let out = sumcheck::verify(claimed, &proof.sel, tr).context("selection sumcheck")?;
    ensure!(
        out.point.len() == nbar.trailing_zeros() as usize,
        "provenance: selection sumcheck variable count"
    );
    let r_k = out.point;
    let mut expect = Fr::ZERO;
    let mut coeff = Fr::ONE;
    for t in 0..t_steps {
        expect += coeff * proof.sel_evals[t] * proof.v_dpts;
        coeff *= gamma;
        expect += coeff * proof.sel_evals[t] * proof.v_dlab;
        coeff *= gamma;
    }
    ensure!(expect == out.final_claim, "selection factor evals mismatch");
    tr.absorb_frs(b"prov/sel_evals", &proof.sel_evals);
    tr.absorb_fr(b"prov/v_dpts", &proof.v_dpts);
    tr.absorb_fr(b"prov/v_dlab", &proof.v_dlab);

    // P1: X openings
    {
        let claims: Vec<(ComExpr, Fr)> = coms
            .iter()
            .zip(proof.v_x.iter())
            .map(|(set, v)| (ComExpr::point(set.com_x.to_projective()), *v))
            .collect();
        ipa::batch_verify_eval_expr(g_x, &claims, &e_xy, &proof.openings[0], tr, acc)
            .context("provenance X opening")?;
    }
    // P2: Y openings (tiled)
    {
        let claims: Vec<(ComExpr, Fr)> = coms
            .iter()
            .zip(proof.v_y.iter())
            .map(|(set, v)| (ComExpr::point(set.com_y.to_projective()), *v))
            .collect();
        ipa::batch_verify_eval_expr(
            g_aux,
            &claims,
            &tiled_eq(&p_xy, trace_slots),
            &proof.openings[1],
            tr,
            acc,
        )
        .context("provenance Y opening")?;
    }
    // P3: dataset δ-fold opening
    {
        let delta = tr.challenge_fr(b"prov/delta");
        let mut pt0 = r_k.clone();
        pt0.push(Fr::ZERO);
        pt0.extend_from_slice(&u_pc);
        let mut pt1 = r_k.clone();
        pt1.push(Fr::ONE);
        pt1.extend_from_slice(&u_pc);
        let e0 = eq_table(&pt0);
        let e1 = eq_table(&pt1);
        let evec =
            threads::par_tabulate(e0.len().min(e1.len()), 1 << 10, Fr::ZERO, |i| {
                e0[i] + delta * e1[i]
            });
        ipa::batch_verify_eval_expr(
            &pk.g_data,
            &[(
                ComExpr::point(proof.dataset.com_d.to_projective()),
                proof.v_dpts + delta * proof.v_dlab,
            )],
            &evec,
            &proof.openings[2],
            tr,
            acc,
        )
        .context("provenance dataset opening")?;
    }
    // P4: folded slot + row-sum opening of com_s
    {
        let gamma_s = tr.challenge_fr(b"prov/gamma_s");
        let log_rows = (pk.tbar * b).trailing_zeros() as usize;
        let u_row = tr.challenge_frs(b"prov/u_row", log_rows);
        let e_row_tbl = eq_table(&u_row);
        let e_a = eq_table(&[u_pr.clone(), r_k.clone()].concat());
        let mut w = vec![Fr::ZERO; n_sel];
        // γ_s-powers up front; each step's b·nbar block of w is disjoint,
        // so the folded e_a scatter tiles step-blocks across the pool, and
        // the row-sum weights then tile row-blocks the same way.
        let gpow_s: Vec<Fr> = {
            let mut out = Vec::with_capacity(t_steps + 1);
            let mut c = Fr::ONE;
            for _ in 0..=t_steps {
                out.push(c);
                c *= gamma_s;
            }
            out
        };
        let coeff = gpow_s[t_steps];
        threads::par_chunks_mut(&mut w[..t_steps * b * nbar], b * nbar, |t, chunk| {
            for (o, v) in chunk.iter_mut().zip(e_a.iter()) {
                *o += gpow_s[t] * *v;
            }
        });
        threads::par_chunks_mut(&mut w[..t_steps * b * nbar], nbar, |row, chunk| {
            for slot in chunk.iter_mut().take(pk.n_rows) {
                *slot += coeff * e_row_tbl[row];
            }
        });
        let mut rowsum_target = Fr::ZERO;
        for row in 0..t_steps * b {
            rowsum_target += e_row_tbl[row];
        }
        let v = gamma_fold(&proof.sel_evals, gamma_s) + coeff * rowsum_target;
        ipa::batch_verify_eval_expr(
            &pk.g_sel,
            &[(ComExpr::point(proof.com_s.to_projective()), v)],
            &w,
            &proof.openings[3],
            tr,
            acc,
        )
        .context("provenance selection opening")?;
    }
    // validity point + P5 + booleanity instance
    let u_dd = tr.challenge_fr(b"prov/u_dd");
    let log_s = n_sel.trailing_zeros() as usize;
    let rho_v = tr.challenge_frs(b"prov/rho", log_s);
    let e_rho = eq_table(&rho_v);
    {
        ipa::batch_verify_eval_expr(
            &pk.g_sel,
            &[(ComExpr::point(proof.com_s.to_projective()), proof.v_sel)],
            &e_rho,
            &proof.openings[4],
            tr,
            acc,
        )
        .context("provenance sign opening")?;
    }
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho_v);
    let e_row_v = eq_table(&vpoint);
    let vb = selection_validity_bases(pk);
    let com_s_expr = ComExpr::point(proof.com_s.to_projective());
    zkrelu::verify_validity_accum(
        &vb,
        &proof.p1_sel,
        Some(&com_s_expr),
        &e_row_v,
        u_dd,
        Fr::ZERO,
        proof.v_sel,
        &proof.validity,
        tr,
        acc,
    )
    .classify(crate::telemetry::failure::VerifyFailureClass::Booleanity)
    .context("selection booleanity")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::{self, TraceKey};
    use crate::witness::native::sgd_witness_chain;

    fn setup(steps: usize, seed: u64) -> (ModelConfig, Dataset, Vec<StepWitness>, ProverDataset) {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(24, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
        let wits = sgd_witness_chain(cfg, &ds, steps, seed);
        let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
        (cfg, ds, wits, pd)
    }

    #[test]
    fn dims_pad_steps_and_rows() {
        let cfg = ModelConfig::new(2, 8, 4);
        let (tbar, nbar, n_sel, n_data) = checked_selection_dims(&cfg, 3, 24).expect("dims");
        assert_eq!((tbar, nbar), (4, 32));
        assert_eq!(n_sel, 4 * 4 * 32);
        assert_eq!(n_data, 32 * 16);
        // a single dataset row still pads to a 2-row MLE
        let (_, nbar1, _, _) = checked_selection_dims(&cfg, 1, 1).expect("dims");
        assert_eq!(nbar1, 2);
        assert!(checked_selection_dims(&cfg, 0, 4).is_err());
        assert!(checked_selection_dims(&cfg, 2, 0).is_err());
    }

    #[test]
    fn dataset_commitment_bridges_to_the_merkle_root() {
        let (_, _, _, pd) = setup(1, 0xd5);
        // leaves rebuild the root AND sum to the MLE commitment — the
        // endorser's check that makes com_d ↔ root a public fact
        verify_dataset_endorsement(&pd.leaves, &pd.commitment.root, &pd.commitment.com_d)
            .expect("honest dataset endorses");
        // any tampered leaf breaks it
        let mut bad = pd.leaves.clone();
        bad[3] = bad[4].clone();
        assert!(verify_dataset_endorsement(&bad, &pd.commitment.root, &pd.commitment.com_d).is_err());
        // a different dataset commitment with the right root breaks it
        let other = G1Affine::IDENTITY;
        assert!(verify_dataset_endorsement(&pd.leaves, &pd.commitment.root, &other).is_err());
        // determinism: rebuilding yields the identical statement
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(24, cfg.width / 2, 4, cfg.r_bits, 0xd5 ^ 0x77);
        let pd2 = ProverDataset::build(&ds, &cfg).expect("dataset commits");
        assert_eq!(pd.commitment, pd2.commitment);
    }

    #[test]
    fn witness_build_validates_rows_against_the_dataset() {
        let (_cfg, _ds, mut wits, pd) = setup(2, 0xa0);
        ProvenanceWitness::build(&pd, &wits).expect("honest rows open");
        // swapped row index: X no longer matches the claimed dataset row
        let good = wits[0].batch_rows[0];
        wits[0].batch_rows[0] = (good + 1) % pd.n_rows();
        let err = ProvenanceWitness::build(&pd, &wits).unwrap_err();
        assert!(format!("{err:#}").contains("does not open"), "{err:#}");
        wits[0].batch_rows[0] = good;
        // out-of-dataset row: X itself tampered
        wits[1].x[2] += 1;
        assert!(ProvenanceWitness::build(&pd, &wits).is_err());
        wits[1].x[2] -= 1;
        // label swap: Y row 0 re-pointed at a different class
        let d = wits[1].cfg.width;
        let hot = (0..d).find(|&c| wits[1].y[c] != 0).expect("one-hot row");
        wits[1].y[hot] = 0;
        wits[1].y[(hot + 1) % d] = wits[1].cfg.scale();
        assert!(ProvenanceWitness::build(&pd, &wits).is_err());
        // stripped provenance info
        let (_, _, mut wits2, _) = setup(2, 0xa0);
        wits2[0].batch_rows.clear();
        assert!(ProvenanceWitness::build(&pd, &wits2).is_err());
    }

    /// Rebuild step 0's witness from (x, y) while keeping its weights and
    /// batch-row indices — every per-step relation still holds, so only the
    /// provenance argument can reject the result.
    fn rewitness_step0(wits: &mut [StepWitness], x: &[i64], y: &[i64]) {
        let cfg = wits[0].cfg;
        let w = crate::model::Weights {
            layers: wits[0].layers.iter().map(|l| l.w.clone()).collect(),
            cfg,
        };
        let rows = wits[0].batch_rows.clone();
        wits[0] = crate::witness::native::compute_witness(cfg, x, y, &w);
        wits[0].batch_rows = rows;
    }

    /// Drive the full trace pipeline with a doctored selection stack: the
    /// white-box seam for tamper classes the honest witness API refuses to
    /// produce. `craft` may rewrite the committed stack and the witnesses.
    fn prove_with_stack(
        craft: impl FnOnce(&ProvenanceKey, &Dataset, &mut Vec<Fr>, &mut Vec<StepWitness>),
    ) -> Result<()> {
        let (cfg, ds, mut wits, pd) = setup(2, 0xbead);
        let steps = wits.len();
        let pk = ProvenanceKey::setup(cfg, steps, pd.n_rows());
        let (b, nbar) = (cfg.batch, pk.nbar);
        let mut stacked = vec![Fr::ZERO; pk.n_sel];
        for (t, wit) in wits.iter().enumerate() {
            for (i, &k) in wit.batch_rows.iter().enumerate() {
                stacked[(t * b + i) * nbar + k] = Fr::ONE;
            }
        }
        craft(&pk, &ds, &mut stacked, &mut wits);
        let mut rng = Rng::seed_from_u64(0x5e1ec7);
        let s = commit(&pk.g_sel, stacked, &mut rng);
        let com_s = s.com.to_affine();
        let vb = selection_validity_bases(&pk);
        let zeros = vec![Fr::ZERO; 2 * pk.n_sel];
        let (p1, aux) = zkrelu::protocol1_main(&vb, &zeros, &s.values, s.blind, &mut rng);
        let pc = ProvenanceCommitments {
            dataset: pd.commitment.clone(),
            d_tensor: pd.tensor.clone(),
            s,
            com_s,
            p1,
            aux,
            vb,
        };
        let tk = TraceKey::setup(cfg, steps);
        let proof = aggregate::prove_trace_with_parts(&tk, &wits, None, Some((pk, pc)), &mut rng);
        aggregate::verify_trace(&tk, &proof)
    }

    #[test]
    fn honest_stack_roundtrips_through_the_white_box_seam() {
        prove_with_stack(|_, _, _, _| {}).expect("honest selection verifies");
    }

    #[test]
    fn two_hot_selection_row_is_rejected_by_the_row_sum() {
        // select TWO dataset rows for batch row (t=0, i=0) and make X/Y the
        // matching sums, so the matmul claims hold and booleanity holds —
        // only the row-sum claim can catch it
        assert!(prove_with_stack(|pk, ds, stacked, wits| {
            let cfg = wits[0].cfg;
            let k0 = wits[0].batch_rows[0];
            let k1 = (k0 + 1) % pk.n_rows;
            stacked[k1] = Fr::ONE; // batch row (0, 0) selects k0 AND k1
            let mut x = wits[0].x.clone();
            let mut y = wits[0].y.clone();
            for (j, &v) in ds.points[k1].iter().enumerate() {
                x[j] += v;
            }
            y[ds.labels[k1]] += cfg.scale();
            rewitness_step0(wits, &x, &y);
        })
        .is_err());
    }

    #[test]
    fn swapped_selection_row_is_rejected_by_the_matmul() {
        // S points at a different dataset row than the one X was built
        // from: booleanity and row sums hold, the matmul claim cannot
        assert!(prove_with_stack(|pk, _, stacked, wits| {
            let k0 = wits[0].batch_rows[0];
            let k1 = (k0 + 1) % pk.n_rows;
            stacked[k0] = Fr::ZERO;
            stacked[k1] = Fr::ONE;
        })
        .is_err());
    }

    #[test]
    fn out_of_dataset_input_is_rejected() {
        // X row 0 tampered away from every dataset row; S left honest
        assert!(prove_with_stack(|_, _, _, wits| {
            let mut x = wits[0].x.clone();
            x[0] += 1;
            let y = wits[0].y.clone();
            rewitness_step0(wits, &x, &y);
        })
        .is_err());
    }

    #[test]
    fn label_swap_is_rejected_by_the_label_matmul() {
        // Y row 0 re-pointed at a different class; X and S honest — only
        // the labels half of the selection argument can catch it
        assert!(prove_with_stack(|_, _, _, wits| {
            let cfg = wits[0].cfg;
            let d = cfg.width;
            let x = wits[0].x.clone();
            let mut y = wits[0].y.clone();
            let hot = (0..d).find(|&c| y[c] != 0).expect("one-hot row");
            y[hot] = 0;
            y[(hot + 1) % d] = cfg.scale();
            rewitness_step0(wits, &x, &y);
        })
        .is_err());
    }

    #[test]
    fn provenance_key_cache_is_keyed_on_steps_and_rows() {
        let cfg = ModelConfig::new(2, 8, 4);
        let a = ProvenanceKey::setup(cfg, 2, 24);
        let b = ProvenanceKey::setup(cfg, 2, 24);
        assert!(Arc::ptr_eq(&a, &b), "same (cfg, T, n) shares one key");
        let c = ProvenanceKey::setup(cfg, 3, 24);
        assert!(!Arc::ptr_eq(&a, &c));
        let d = ProvenanceKey::setup(cfg, 2, 25);
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(a.n_sel, 2 * 4 * 32);
    }
}
