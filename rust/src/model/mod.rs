//! The workload being proven: a quantized L-layer ReLU fully-connected
//! network trained with SGD under square loss (paper Example 4.5).
//!
//! All values are fixed-point integers: real x ↦ round(x·2^R) with R = 16
//! by default, and every tensor element is asserted to fit the paper's
//! (Q+R)-bit budget (Q = 32). Multiplying two scaled values yields scale
//! 2^{2R}; the rescale-by-2^R with remainder is exactly what zkReLU's
//! auxiliary inputs witness.

use crate::util::rng::Rng;

/// Shape / quantization configuration of one training setup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    /// Number of layers L (weight matrices); L−1 ReLU activations.
    pub depth: usize,
    /// Width d: every layer is d×d; inputs/outputs are d-dimensional
    /// (zero-padded, as in the paper's CIFAR-10 setup padded to 4096).
    pub width: usize,
    /// Batch size B.
    pub batch: usize,
    /// Fixed-point fractional bits R (paper: 16).
    pub r_bits: u32,
    /// Total signed bit-width Q of rescaled values (paper: 32).
    pub q_bits: u32,
    /// Learning rate = 2^{−lr_shift} (applied in the coordinator's weight
    /// update; the proof covers the forward/backward relations (30)–(35)).
    pub lr_shift: u32,
}

impl ModelConfig {
    pub fn new(depth: usize, width: usize, batch: usize) -> Self {
        assert!(depth >= 1);
        assert!(width.is_power_of_two(), "width must be a power of two");
        assert!(batch.is_power_of_two(), "batch must be a power of two");
        Self {
            depth,
            width,
            batch,
            r_bits: 16,
            q_bits: 32,
            lr_shift: 8,
        }
    }

    /// Scale factor 2^R.
    pub fn scale(&self) -> i64 {
        1i64 << self.r_bits
    }

    /// Per-layer activation tensor size D = B·d (the paper's D).
    pub fn d_size(&self) -> usize {
        self.batch * self.width
    }

    /// Total parameter count L·d².
    pub fn param_count(&self) -> usize {
        self.depth * self.width * self.width
    }

    /// Log2 of the padded activation tensor size.
    pub fn log_d(&self) -> usize {
        self.d_size().next_power_of_two().trailing_zeros() as usize
    }
}

/// Fixed-point model parameters: `depth` weight matrices, each d×d
/// row-major, at scale 2^R.
#[derive(Clone, Debug)]
pub struct Weights {
    pub layers: Vec<Vec<i64>>,
    pub cfg: ModelConfig,
}

impl Weights {
    /// He-style init scaled to fixed point: w ~ U(−a, a) with a ≈ √(2/d),
    /// quantized to scale 2^R.
    pub fn init(cfg: ModelConfig, rng: &mut Rng) -> Self {
        let d = cfg.width;
        let scale = cfg.scale() as f64;
        // √(2/d) bound keeps activations from exploding through depth
        let bound = ((2.0 / d as f64).sqrt() * scale) as i64;
        let bound = bound.max(1);
        let layers = (0..cfg.depth)
            .map(|_| {
                (0..d * d)
                    .map(|_| rng.gen_i64(-bound, bound + 1))
                    .collect()
            })
            .collect();
        Self { layers, cfg }
    }

    /// SGD update: W ← W − round(G_W / 2^{R + lr_shift}).
    /// G_W is at scale 2^{2R}; dividing by 2^R returns it to weight scale
    /// and 2^{lr_shift} applies the learning rate.
    pub fn apply_update(&mut self, grads: &[Vec<i64>]) {
        assert_eq!(grads.len(), self.layers.len());
        let shift = self.cfg.r_bits + self.cfg.lr_shift;
        for (w, g) in self.layers.iter_mut().zip(grads.iter()) {
            assert_eq!(w.len(), g.len());
            for (wi, gi) in w.iter_mut().zip(g.iter()) {
                *wi -= round_div_pow2(*gi, shift);
            }
        }
    }
}

/// Round-to-nearest division by 2^shift (ties toward +∞), the paper's ⌊·⌉:
/// remainder lies in [−2^{shift−1}, 2^{shift−1}).
#[inline]
pub fn round_div_pow2(v: i64, shift: u32) -> i64 {
    if shift == 0 {
        return v;
    }
    let half = 1i64 << (shift - 1);
    (v + half).div_euclid(1i64 << shift)
}

/// i128 variant for high-scale intermediates.
#[inline]
pub fn round_div_pow2_i128(v: i128, shift: u32) -> i128 {
    if shift == 0 {
        return v;
    }
    let half = 1i128 << (shift - 1);
    (v + half).div_euclid(1i128 << shift)
}

/// Integer matmul C = A·B with A: m×k, B: k×n (row-major), i128
/// accumulation, asserting the result fits i64.
pub fn matmul_i64(a: &[i64], b: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0i64; m * n];
    // Explicit 64-element floor: each output element costs k i128 MACs
    // (tens of ns at typical k), so even small outputs split profitably on
    // the pool — the seed's 1024 floor was sized for thread-spawn cost.
    crate::util::threads::par_chunks_mut_with(64, &mut out, n.max(1), |row, chunk| {
        // each chunk is one output row (chunk_size = n)
        let i = row;
        for (j, c) in chunk.iter_mut().enumerate() {
            let mut acc: i128 = 0;
            for l in 0..k {
                acc += a[i * k + l] as i128 * b[l * n + j] as i128;
            }
            *c = i64::try_from(acc).expect("matmul overflow: scale down inputs");
        }
    });
    out
}

/// C = Aᵀ·B with A: m×k viewed transposed → k×m result times B m×n.
pub fn matmul_at_b(a: &[i64], b: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
    // A is m×k (row-major); compute Aᵀ B: (k×m)·(m×n)? — callers pass
    // dimensions of the *result*: here result is k×n from A(m×k), B(m×n).
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut out = vec![0i64; k * n];
    crate::util::threads::par_chunks_mut_with(64, &mut out, n.max(1), |row, chunk| {
        let i = row; // row of Aᵀ = column of A
        for (j, c) in chunk.iter_mut().enumerate() {
            let mut acc: i128 = 0;
            for l in 0..m {
                acc += a[l * k + i] as i128 * b[l * n + j] as i128;
            }
            *c = i64::try_from(acc).expect("matmul overflow: scale down inputs");
        }
    });
    out
}

/// C = A·Bᵀ with A: m×k, B: n×k → m×n.
pub fn matmul_a_bt(a: &[i64], b: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0i64; m * n];
    crate::util::threads::par_chunks_mut_with(64, &mut out, n.max(1), |row, chunk| {
        let i = row;
        for (j, c) in chunk.iter_mut().enumerate() {
            let mut acc: i128 = 0;
            for l in 0..k {
                acc += a[i * k + l] as i128 * b[j * k + l] as i128;
            }
            *c = i64::try_from(acc).expect("matmul overflow: scale down inputs");
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_div_matches_spec() {
        // remainder must land in [−2^{R−1}, 2^{R−1})
        for v in [-100000i64, -32769, -32768, -1, 0, 1, 32767, 32768, 99999] {
            let q = round_div_pow2(v, 16);
            let rem = v - (q << 16);
            assert!((-(1i64 << 15)..(1i64 << 15)).contains(&rem), "v={v} rem={rem}");
        }
        assert_eq!(round_div_pow2(3, 1), 2); // 1.5 → 2 (ties toward +∞)
        assert_eq!(round_div_pow2(-3, 1), -1); // −1.5 → −1
        assert_eq!(round_div_pow2(4, 2), 1);
    }

    #[test]
    fn matmul_small() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = vec![1, 2, 3, 4];
        let b = vec![5, 6, 7, 8];
        assert_eq!(matmul_i64(&a, &b, 2, 2, 2), vec![19, 22, 43, 50]);
    }

    #[test]
    fn transposed_matmuls_consistent() {
        let mut rng = Rng::seed_from_u64(5);
        let (m, k, n) = (3usize, 4usize, 5usize);
        let a: Vec<i64> = (0..m * k).map(|_| rng.gen_i64(-9, 10)).collect();
        let b: Vec<i64> = (0..m * n).map(|_| rng.gen_i64(-9, 10)).collect();
        // Aᵀ·B via explicit transpose
        let mut at = vec![0i64; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        assert_eq!(matmul_at_b(&a, &b, m, k, n), matmul_i64(&at, &b, k, m, n));

        let c: Vec<i64> = (0..n * k).map(|_| rng.gen_i64(-9, 10)).collect();
        let mut ct = vec![0i64; k * n];
        for i in 0..n {
            for j in 0..k {
                ct[j * n + i] = c[i * k + j];
            }
        }
        assert_eq!(matmul_a_bt(&a, &c, m, k, n), matmul_i64(&a, &ct, m, k, n));
    }

    #[test]
    fn weights_init_in_range() {
        let cfg = ModelConfig::new(2, 64, 16);
        let mut rng = Rng::seed_from_u64(1);
        let w = Weights::init(cfg, &mut rng);
        assert_eq!(w.layers.len(), 2);
        let bound = ((2.0f64 / 64.0).sqrt() * 65536.0) as i64 + 1;
        for l in &w.layers {
            assert_eq!(l.len(), 64 * 64);
            assert!(l.iter().all(|&v| v.abs() <= bound));
        }
    }

    #[test]
    fn sgd_update_direction() {
        let cfg = ModelConfig::new(1, 2, 2);
        let mut w = Weights {
            layers: vec![vec![1000, -1000, 0, 0]],
            cfg,
        };
        // positive gradient decreases the weight
        let g = vec![vec![1i64 << 40, -(1i64 << 40), 0, 0]];
        w.apply_update(&g);
        assert!(w.layers[0][0] < 1000);
        assert!(w.layers[0][1] > -1000);
        assert_eq!(w.layers[0][2], 0);
    }
}
