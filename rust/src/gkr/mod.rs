//! Specialized GKR-style sumchecks for the arithmetic components of the
//! network (paper §3.2, §4.2; Thaler's matmul protocol [43]).
//!
//! Each linear layer contributes three matmul relations —
//! (30) Z = A·W, (33) G_A = G_Z·Wᵀ, (34) G_W = G_Zᵀ·A — each proven by a
//! single sumcheck over the contracted index:
//!     C̃(u_row, u_col) = Σ_w Ã(u_row, w)·B̃(w, u_col),
//! reducing one evaluation claim on the output to one claim on each input.
//! All layers run these with the *same* randomness (the anchored circuit of
//! §4.2), which is what lets zkDL batch per-layer claims by random linear
//! combination and parallelize proof generation across layers.

use crate::field::Fr;
use crate::poly::{eq_table, Mle};
use crate::sumcheck::{self, Instance, SumcheckProof, Term};
use crate::transcript::Transcript;
use anyhow::{ensure, Result};

/// A field matrix (row-major, power-of-two dimensions) with MLE helpers.
/// Index layout: idx = row·cols + col, so row variables are the most
/// significant MLE variables — matching `poly::Mle`'s fold order.
#[derive(Clone, Debug)]
pub struct Matrix {
    pub data: Vec<Fr>,
    pub rows: usize,
    pub cols: usize,
}

impl Matrix {
    pub fn new(data: Vec<Fr>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        assert!(rows.is_power_of_two() && cols.is_power_of_two());
        Self { data, rows, cols }
    }

    pub fn from_i64(values: &[i64], rows: usize, cols: usize) -> Self {
        Self::new(values.iter().map(|&v| Fr::from_i64(v)).collect(), rows, cols)
    }

    pub fn log_rows(&self) -> usize {
        self.rows.trailing_zeros() as usize
    }

    pub fn log_cols(&self) -> usize {
        self.cols.trailing_zeros() as usize
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = vec![Fr::ZERO; self.data.len()];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        Matrix::new(out, self.cols, self.rows)
    }

    pub fn mle(&self) -> Mle {
        Mle::new(self.data.clone())
    }

    /// M̃(point) with point = (row vars ‖ col vars).
    pub fn evaluate(&self, point: &[Fr]) -> Fr {
        self.mle().evaluate(point)
    }

    /// Restrict the row variables at `u_row`, producing the column MLE
    /// M̃(u_row, ·).
    pub fn fix_rows(&self, u_row: &[Fr]) -> Mle {
        assert_eq!(u_row.len(), self.log_rows());
        self.mle().partial_eval(u_row)
    }
}

/// Sumcheck proof that C̃(u_row, u_col) = Σ_w Ã(u_row, w)·B̃(w, u_col).
/// `a_fixed` = Ã(u_row, ·), `bt_fixed` = B̃(·, u_col) given as the row-fixed
/// MLE of Bᵀ. Emits the contraction point r_w and both factor evaluations.
pub struct MatmulProof {
    pub proof: SumcheckProof,
    /// Ã(u_row, r_w)
    pub eval_a: Fr,
    /// B̃(r_w, u_col)
    pub eval_b: Fr,
}

impl MatmulProof {
    pub fn size_bytes(&self) -> usize {
        self.proof.size_bytes() + 2 * 32
    }
}

/// Prove Σ_w a_fixed(w)·bt_fixed(w) = claimed (the inner dimension
/// contraction). Returns the proof and the challenge point r_w.
pub fn prove_matmul(
    a_fixed: Mle,
    bt_fixed: Mle,
    transcript: &mut Transcript,
) -> (MatmulProof, Vec<Fr>) {
    assert_eq!(a_fixed.num_vars, bt_fixed.num_vars);
    let inst = Instance::new(vec![Term::new(Fr::ONE, vec![a_fixed, bt_fixed])]);
    let out = sumcheck::prove(inst, transcript);
    let eval_a = out.factor_evals[0][0];
    let eval_b = out.factor_evals[0][1];
    (
        MatmulProof {
            proof: out.proof,
            eval_a,
            eval_b,
        },
        out.point,
    )
}

/// Verify a matmul contraction sumcheck against the claimed output
/// evaluation. Returns r_w; the caller must separately verify `eval_a` and
/// `eval_b` (against commitments or downstream reductions).
pub fn verify_matmul(
    claimed: Fr,
    mp: &MatmulProof,
    transcript: &mut Transcript,
) -> Result<Vec<Fr>> {
    let out = sumcheck::verify(claimed, &mp.proof, transcript)?;
    ensure!(
        out.final_claim == mp.eval_a * mp.eval_b,
        "matmul: factor evaluations inconsistent with final sumcheck claim"
    );
    Ok(out.point)
}

/// Merge two evaluation claims T̃(p1)=v1, T̃(p2)=v2 on the *same* tensor into
/// one claim at a fresh point, via the degree-2 sumcheck on
/// Σ_b (β̃(p1,b) + α·β̃(p2,b))·T̃(b) = v1 + α·v2.
pub struct ClaimMergeProof {
    pub proof: SumcheckProof,
    /// T̃(r) at the merged point r.
    pub eval: Fr,
}

impl ClaimMergeProof {
    pub fn size_bytes(&self) -> usize {
        self.proof.size_bytes() + 32
    }
}

/// Prover side of claim merging. Returns (proof, merged point r).
pub fn prove_claim_merge(
    tensor: &Mle,
    p1: &[Fr],
    p2: &[Fr],
    transcript: &mut Transcript,
) -> (ClaimMergeProof, Vec<Fr>) {
    assert_eq!(p1.len(), tensor.num_vars);
    assert_eq!(p2.len(), tensor.num_vars);
    let alpha = transcript.challenge_fr(b"merge/alpha");
    let e1 = eq_table(p1);
    let e2 = eq_table(p2);
    let mixed: Vec<Fr> = e1
        .iter()
        .zip(e2.iter())
        .map(|(a, b)| *a + alpha * *b)
        .collect();
    let inst = Instance::new(vec![Term::new(
        Fr::ONE,
        vec![Mle::new(mixed), tensor.clone()],
    )]);
    let out = sumcheck::prove(inst, transcript);
    let eval = out.factor_evals[0][1];
    (
        ClaimMergeProof {
            proof: out.proof,
            eval,
        },
        out.point,
    )
}

/// Verifier side of claim merging: checks the sumcheck against v1 + α·v2 and
/// the mixed-eq factor, returning the merged point. The caller continues
/// with the claim T̃(r) = proof.eval.
pub fn verify_claim_merge(
    v1: Fr,
    v2: Fr,
    p1: &[Fr],
    p2: &[Fr],
    cm: &ClaimMergeProof,
    transcript: &mut Transcript,
) -> Result<Vec<Fr>> {
    let alpha = transcript.challenge_fr(b"merge/alpha");
    let out = sumcheck::verify(v1 + alpha * v2, &cm.proof, transcript)?;
    let eq1 = crate::poly::eq_eval(p1, &out.point);
    let eq2 = crate::poly::eq_eval(p2, &out.point);
    ensure!(
        out.final_claim == (eq1 + alpha * eq2) * cm.eval,
        "claim merge: final check failed"
    );
    Ok(out.point)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rng() -> Rng {
        Rng::seed_from_u64(0x6312)
    }

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::new((0..rows * cols).map(|_| Fr::random(r)).collect(), rows, cols)
    }

    fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows);
        let mut out = vec![Fr::ZERO; a.rows * b.cols];
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = Fr::ZERO;
                for w in 0..a.cols {
                    acc += a.data[i * a.cols + w] * b.data[w * b.cols + j];
                }
                out[i * b.cols + j] = acc;
            }
        }
        Matrix::new(out, a.rows, b.cols)
    }

    #[test]
    fn matmul_sumcheck_roundtrip() {
        let mut r = rng();
        let a = random_matrix(&mut r, 4, 8);
        let b = random_matrix(&mut r, 8, 4);
        let c = matmul(&a, &b);
        let u_row: Vec<Fr> = (0..2).map(|_| Fr::random(&mut r)).collect();
        let u_col: Vec<Fr> = (0..2).map(|_| Fr::random(&mut r)).collect();
        let claimed = c.evaluate(&[u_row.clone(), u_col.clone()].concat());

        let a_fixed = a.fix_rows(&u_row);
        let bt_fixed = b.transpose().fix_rows(&u_col);
        let mut tp = Transcript::new(b"mm");
        let (mp, r_w) = prove_matmul(a_fixed, bt_fixed, &mut tp);

        let mut tv = Transcript::new(b"mm");
        let r_w_v = verify_matmul(claimed, &mp, &mut tv).expect("verify");
        assert_eq!(r_w, r_w_v);

        // the emitted evaluations match direct computation
        assert_eq!(mp.eval_a, a.evaluate(&[u_row.clone(), r_w.clone()].concat()));
        assert_eq!(mp.eval_b, b.evaluate(&[r_w, u_col].concat()));
    }

    #[test]
    fn matmul_sumcheck_rejects_wrong_output() {
        let mut r = rng();
        let a = random_matrix(&mut r, 4, 4);
        let b = random_matrix(&mut r, 4, 4);
        let c = matmul(&a, &b);
        let u_row: Vec<Fr> = (0..2).map(|_| Fr::random(&mut r)).collect();
        let u_col: Vec<Fr> = (0..2).map(|_| Fr::random(&mut r)).collect();
        let claimed = c.evaluate(&[u_row.clone(), u_col.clone()].concat()) + Fr::ONE;
        let mut tp = Transcript::new(b"mm");
        let (mp, _) = prove_matmul(a.fix_rows(&u_row), b.transpose().fix_rows(&u_col), &mut tp);
        let mut tv = Transcript::new(b"mm");
        assert!(verify_matmul(claimed, &mp, &mut tv).is_err());
    }

    #[test]
    fn transposed_variants() {
        // (34)-style: G_W = G_Zᵀ·A, proven via transposed copies
        let mut r = rng();
        let g_z = random_matrix(&mut r, 8, 4); // B×d
        let a = random_matrix(&mut r, 8, 4); // B×d
        let g_w = matmul(&g_z.transpose(), &a); // d×d
        let u_r: Vec<Fr> = (0..2).map(|_| Fr::random(&mut r)).collect();
        let u_c: Vec<Fr> = (0..2).map(|_| Fr::random(&mut r)).collect();
        let claimed = g_w.evaluate(&[u_r.clone(), u_c.clone()].concat());
        // Σ_w G_Zᵀ(u_r, w)·Aᵀ(u_c, w): both factors from transposed copies
        let mut tp = Transcript::new(b"mm2");
        let (mp, r_w) = prove_matmul(
            g_z.transpose().fix_rows(&u_r),
            a.transpose().fix_rows(&u_c),
            &mut tp,
        );
        let mut tv = Transcript::new(b"mm2");
        verify_matmul(claimed, &mp, &mut tv).expect("verify");
        // claims open at the swapped point on the original tensors
        assert_eq!(mp.eval_a, g_z.evaluate(&[r_w.clone(), u_r].concat()));
        assert_eq!(mp.eval_b, a.evaluate(&[r_w, u_c].concat()));
    }

    #[test]
    fn claim_merge_roundtrip() {
        let mut r = rng();
        let t = Mle::new((0..16).map(|_| Fr::random(&mut r)).collect());
        let p1: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let p2: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let v1 = t.evaluate(&p1);
        let v2 = t.evaluate(&p2);
        let mut tp = Transcript::new(b"merge");
        let (cm, rp) = prove_claim_merge(&t, &p1, &p2, &mut tp);
        let mut tv = Transcript::new(b"merge");
        let rv = verify_claim_merge(v1, v2, &p1, &p2, &cm, &mut tv).expect("verify");
        assert_eq!(rp, rv);
        assert_eq!(cm.eval, t.evaluate(&rp));
    }

    #[test]
    fn claim_merge_rejects_wrong_value() {
        let mut r = rng();
        let t = Mle::new((0..16).map(|_| Fr::random(&mut r)).collect());
        let p1: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let p2: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let v1 = t.evaluate(&p1);
        let v2 = t.evaluate(&p2) + Fr::ONE; // lie about one claim
        let mut tp = Transcript::new(b"merge");
        let (cm, _) = prove_claim_merge(&t, &p1, &p2, &mut tp);
        let mut tv = Transcript::new(b"merge");
        assert!(verify_claim_merge(v1, v2, &p1, &p2, &cm, &mut tv).is_err());
    }
}
