//! Witness tensors of one training step — everything the prover commits to
//! and proves relations over.
//!
//! A [`StepWitness`] holds, per layer ℓ, the tensors of Example 4.5 plus the
//! zkReLU auxiliary inputs aux^{(ℓ)} = (Z″, B_{Q−1}, R_Z, G_A′, R_{G_A}).
//! [`StepWitness::validate`] checks every arithmetic relation (2)–(5) and
//! (30)–(35) over the integers — this is the ground truth that both the
//! native and the PJRT (JAX/Pallas-compiled) witness generators must satisfy
//! bit-exactly.

pub mod native;

use crate::model::ModelConfig;
use crate::update::rule::{Operand, UpdateRule};
use anyhow::{ensure, Context, Result};

/// Rescale decomposition of a tensor T (scale 2^{2R}) into
/// T = 2^R·T″ − 2^{Q+R−1}·B + R_T with T″ ∈ [0, 2^{Q−1}), B ∈ {0,1},
/// R_T ∈ [−2^{R−1}, 2^{R−1}).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RescaleAux {
    /// Re-compressed magnitude Z″ = Z′ + 2^{Q−1}·B_{Q−1}, in [0, 2^{Q−1}).
    pub dprime: Vec<i64>,
    /// Sign bits B_{Q−1} ∈ {0,1} (1 ⇔ Z′ < 0).
    pub sign: Vec<i64>,
    /// Rounding remainder in [−2^{R−1}, 2^{R−1}).
    pub rem: Vec<i64>,
}

/// Per-layer witness.
#[derive(Clone, Debug)]
pub struct LayerWitness {
    /// Weights W^{(ℓ)} (d×d, scale 2^R).
    pub w: Vec<i64>,
    /// Pre-activation Z^{(ℓ)} = A^{(ℓ−1)}·W^{(ℓ)} (B×d, scale 2^{2R}).
    pub z: Vec<i64>,
    /// Rescaled Z^{(ℓ)′} = ⌊Z/2^R⌉ (B×d, scale 2^R).
    pub z_prime: Vec<i64>,
    /// zkReLU decomposition of Z.
    pub z_aux: RescaleAux,
    /// Activation A^{(ℓ)} = (1−B_{Q−1})⊙Z″ for ℓ<L; None for the last layer.
    pub a: Option<Vec<i64>>,
    /// Activation gradient G_A^{(ℓ)} = G_Z^{(ℓ+1)}·W^{(ℓ+1)ᵀ}
    /// (scale 2^{2R}); None for the last layer.
    pub g_a: Option<Vec<i64>>,
    /// zkReLU decomposition of G_A (G_A′ = dprime−2^{Q−1}·sign_ga where
    /// sign_ga tracks G_A′ < 0); None for the last layer.
    pub g_a_aux: Option<RescaleAux>,
    /// Rescaled gradient G_A^{(ℓ)′} (scale 2^R); None for the last layer.
    pub g_a_prime: Option<Vec<i64>>,
    /// Pre-activation gradient G_Z^{(ℓ)} (B×d, scale 2^R):
    /// (1−B_{Q−1})⊙G_A′ for ℓ<L, Z^{(L)′}−Y for ℓ=L.
    pub g_z: Vec<i64>,
    /// Weight gradient G_W^{(ℓ)} = G_Z^{(ℓ)ᵀ}·A^{(ℓ−1)} (d×d, scale 2^{2R}).
    pub g_w: Vec<i64>,
}

/// Full witness of one training step.
#[derive(Clone, Debug)]
pub struct StepWitness {
    pub cfg: ModelConfig,
    /// Input batch X = A^{(0)} (B×d, scale 2^R).
    pub x: Vec<i64>,
    /// Targets Y (B×d, scale 2^R; one-hot·2^R for classification).
    pub y: Vec<i64>,
    pub layers: Vec<LayerWitness>,
    /// Rule-owned optimizer state *entering* this step, `opt_state[s][l]`
    /// a d² tensor for state slot s, layer ℓ (the momentum accumulator m_t
    /// for heavy-ball; empty for plain SGD). Not constrained by
    /// [`Self::validate`] — the zkOptim chain relations constrain it
    /// across boundaries.
    pub opt_state: Vec<Vec<Vec<i64>>>,
    /// Dataset row index behind each batch row (length B), the zkData
    /// provenance witness; empty when the batch was assembled without
    /// row tracking. Not constrained by [`Self::validate`] — the batch
    /// selection argument ([`crate::provenance`]) constrains it against
    /// the committed dataset.
    pub batch_rows: Vec<usize>,
}

impl StepWitness {
    /// Training loss of this step: ½‖Z^{(L)′} − Y‖² in real units.
    pub fn loss(&self) -> f64 {
        let last = self.layers.last().unwrap();
        let scale = self.cfg.scale() as f64;
        let sum: f64 = last
            .g_z
            .iter()
            .map(|&g| {
                let r = g as f64 / scale;
                r * r
            })
            .sum();
        0.5 * sum / self.cfg.batch as f64
    }

    /// Verify every arithmetic relation of the paper over the integers.
    pub fn validate(&self) -> Result<()> {
        let cfg = &self.cfg;
        let (b, d, depth) = (cfg.batch, cfg.width, cfg.depth);
        let r = cfg.r_bits;
        let q = cfg.q_bits;
        let half_r = 1i64 << (r - 1);
        let q_mag = 1i64 << (q - 1);
        ensure!(self.layers.len() == depth, "layer count");
        ensure!(self.x.len() == b * d && self.y.len() == b * d, "io shapes");

        let mut a_prev: &[i64] = &self.x;
        for (l, lw) in self.layers.iter().enumerate() {
            let last = l + 1 == depth;
            ensure!(lw.w.len() == d * d, "w shape");
            ensure!(lw.z.len() == b * d, "z shape");

            // (30): Z = A_prev · W
            let z = crate::model::matmul_i64(a_prev, &lw.w, b, d, d);
            ensure!(z == lw.z, "relation (30) failed at layer {l}");

            // (3): Z = 2^R·Z″ − 2^{Q+R−1}·B + R_Z, with ranges
            for i in 0..b * d {
                let dp = lw.z_aux.dprime[i];
                let s = lw.z_aux.sign[i];
                let rem = lw.z_aux.rem[i];
                ensure!((0..q_mag).contains(&dp), "Z'' out of range");
                ensure!(s == 0 || s == 1, "B_{{Q-1}} not binary");
                ensure!((-half_r..half_r).contains(&rem), "R_Z out of range");
                let rhs = (dp << r) - (s << (q + r - 1)) + rem;
                ensure!(lw.z[i] == rhs, "relation (3) failed at layer {l}");
                ensure!(
                    lw.z_prime[i] == dp - (s << (q - 1)),
                    "Z' decomposition failed at layer {l}"
                );
            }

            if !last {
                // (2): A = (1 − B)⊙Z″
                let a = lw.a.as_ref().expect("inner layer has activation");
                for i in 0..b * d {
                    ensure!(
                        a[i] == (1 - lw.z_aux.sign[i]) * lw.z_aux.dprime[i],
                        "relation (2) failed at layer {l}"
                    );
                }
                // (5): G_A = 2^R·G_A′ + R_{G_A}  (signed Q-bit G_A′)
                let g_a = lw.g_a.as_ref().unwrap();
                let g_a_prime = lw.g_a_prime.as_ref().unwrap();
                let aux = lw.g_a_aux.as_ref().unwrap();
                for i in 0..b * d {
                    let gp = g_a_prime[i];
                    ensure!((-q_mag..q_mag).contains(&gp), "G_A' out of range");
                    ensure!(
                        (-half_r..half_r).contains(&aux.rem[i]),
                        "R_GA out of range"
                    );
                    ensure!(
                        g_a[i] == (gp << r) + aux.rem[i],
                        "relation (5) failed at layer {l}"
                    );
                    // signed decomposition consistency
                    ensure!(aux.sign[i] == 0 || aux.sign[i] == 1, "G_A' sign bit");
                    ensure!(
                        gp == aux.dprime[i] - (aux.sign[i] << (q - 1)),
                        "G_A' magnitude/sign decomposition at layer {l}"
                    );
                    ensure!((0..q_mag).contains(&aux.dprime[i]), "G_A'' range");
                }
                // (4): G_Z = (1 − B)⊙G_A′
                for i in 0..b * d {
                    ensure!(
                        lw.g_z[i] == (1 - lw.z_aux.sign[i]) * g_a_prime[i],
                        "relation (4) failed at layer {l}"
                    );
                }
                // (33): G_A^{(ℓ)} = G_Z^{(ℓ+1)}·W^{(ℓ+1)ᵀ}
                let next = &self.layers[l + 1];
                let expect = crate::model::matmul_a_bt(&next.g_z, &next.w, b, d, d);
                ensure!(*g_a == expect, "relation (33) failed at layer {l}");
            } else {
                // (32): G_Z^{(L)} = Z^{(L)′} − Y
                for i in 0..b * d {
                    ensure!(
                        lw.g_z[i] == lw.z_prime[i] - self.y[i],
                        "relation (32) failed"
                    );
                }
            }

            // (34): G_W = G_Zᵀ·A_prev
            let gw = crate::model::matmul_at_b(&lw.g_z, a_prev, b, d, d);
            ensure!(gw == lw.g_w, "relation (34) failed at layer {l}");

            if let Some(a) = &lw.a {
                a_prev = a;
            }
        }
        Ok(())
    }

    /// Weight gradients (for the coordinator's SGD update).
    pub fn weight_grads(&self) -> Vec<Vec<i64>> {
        self.layers.iter().map(|l| l.g_w.clone()).collect()
    }
}

/// Exact remainder of one linear update relation over committed tensors
/// (the zkOptim chain witness primitive):
///     Σ_k c_k·X_k = 2^{s_bits}·(Σ_k d_k·Y_k) + R,  R ∈ [−2^{s−1}, 2^{s−1}).
///
/// The range is exactly the round-to-nearest remainder range of
/// [`crate::model::round_div_pow2`], so the decomposition is unique and an
/// out-of-range entry means the tensors are *not* the exact rounded update
/// — reported as "does not chain". All arithmetic is checked i128; an
/// overflow of the exact value certainly exceeds the range and errors the
/// same way (the witness is refused, never silently wrong).
pub fn relation_remainder(
    s_bits: u32,
    lhs: &[(i64, &[i64])],
    shifted: &[(i64, &[i64])],
) -> Result<Vec<i64>> {
    // beyond 64 the shifted side drops high bits and an in-range R would
    // not fit the i64 the prover embeds, so refuse to witness such widths
    ensure!(
        (2..=64).contains(&s_bits),
        "relation digit budget {s_bits} outside the provable 2..=64"
    );
    let n = lhs
        .first()
        .or(shifted.first())
        .map(|(_, t)| t.len())
        .unwrap_or(0);
    ensure!(n > 0, "empty update relation");
    for (_, t) in lhs.iter().chain(shifted.iter()) {
        ensure!(t.len() == n, "update tensor shape mismatch");
    }
    let half = 1i128 << (s_bits - 1);
    let side = |terms: &[(i64, &[i64])], i: usize| -> Option<i128> {
        let mut acc = 0i128;
        for (c, t) in terms {
            acc = acc.checked_add((*c as i128).checked_mul(t[i] as i128)?)?;
        }
        Some(acc)
    };
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let r = side(lhs, i).and_then(|l| {
            side(shifted, i)
                .and_then(|s| s.checked_mul(1i128 << s_bits))
                .and_then(|s| l.checked_sub(s))
        });
        match r {
            // |r| ≤ 2^63 inside the range (s_bits ≤ 64), so the cast is exact
            Some(r) if (-half..half).contains(&r) => out.push(r as i64),
            _ => anyhow::bail!(
                "update remainder out of range at index {i}: the tensors do not chain"
            ),
        }
    }
    Ok(out)
}

/// SGD remainder of one boundary/layer: G_W = 2^{R+lr}·(W_t − W_{t+1}) + R.
/// Thin wrapper over [`relation_remainder`], kept as the legacy entry
/// point (and the reference the SGD rule is tested against).
pub fn update_remainder(
    cfg: &ModelConfig,
    w_prev: &[i64],
    w_next: &[i64],
    g_w: &[i64],
) -> Result<Vec<i64>> {
    relation_remainder(
        cfg.r_bits + cfg.lr_shift,
        &[(1, g_w)],
        &[(1, w_prev), (-1, w_next)],
    )
}

/// Resolve a relation operand to its witness tensor at boundary b
/// (`prev` = wits[b], `next` = wits[b+1]).
fn operand_tensor<'a>(
    prev: &'a StepWitness,
    next: &'a StepWitness,
    l: usize,
    op: Operand,
) -> Result<&'a [i64]> {
    let state = |w: &'a StepWitness, slot: usize| -> Result<&'a [i64]> {
        let s = w
            .opt_state
            .get(slot)
            .and_then(|per_layer| per_layer.get(l))
            .map(|t| t.as_slice());
        s.context("witness is missing the rule's optimizer state tensor")
    };
    Ok(match op {
        Operand::WPrev => &prev.layers[l].w,
        Operand::WNext => &next.layers[l].w,
        Operand::GradW => &prev.layers[l].g_w,
        Operand::StatePrev(s) => state(prev, s)?,
        Operand::StateNext(s) => state(next, s)?,
    })
}

/// Remainder tensors of every (boundary, layer, relation) of a consecutive
/// witness chain under `rule`: `result[b][l][j]` is relation j's remainder
/// at boundary b / layer ℓ. `lr_shifts[b]` is the boundary's learning-rate
/// shift (length T−1). Fails — naming boundary, layer, and relation — if
/// any boundary is not the exact rounded update of the previous step. The
/// single source of the chain-walk logic: [`validate_chain_rule`] and the
/// zkOptim prover (`update::ChainWitness`) both build on it.
pub fn rule_chain_remainders(
    rule: &UpdateRule,
    lr_shifts: &[u32],
    wits: &[StepWitness],
) -> Result<Vec<Vec<Vec<Vec<i64>>>>> {
    ensure!(wits.len() >= 2, "chaining needs at least two steps");
    ensure!(
        lr_shifts.len() == wits.len() - 1,
        "shift table length {} != {} boundaries",
        lr_shifts.len(),
        wits.len() - 1
    );
    let cfg = wits[0].cfg;
    crate::update::rule::validate_shift_table(&cfg, rule, lr_shifts)?;
    let relations = rule.relations();
    let mut out = Vec::with_capacity(wits.len() - 1);
    for b in 0..wits.len() - 1 {
        let (prev, next) = (&wits[b], &wits[b + 1]);
        ensure!(prev.cfg == next.cfg, "config mismatch at boundary {b}");
        let mut per_layer = Vec::with_capacity(cfg.depth);
        for l in 0..cfg.depth {
            let mut per_rel = Vec::with_capacity(relations.len());
            for rel in &relations {
                let gather = |terms: &[crate::update::rule::RelTerm]| -> Result<Vec<(i64, &[i64])>> {
                    terms
                        .iter()
                        .map(|t| Ok((t.coeff, operand_tensor(prev, next, l, t.op)?)))
                        .collect()
                };
                let lhs = gather(&rel.lhs)?;
                let shifted = gather(&rel.shifted)?;
                per_rel.push(
                    relation_remainder(rel.digits(&cfg, lr_shifts[b]), &lhs, &shifted)
                        .with_context(|| {
                            format!("boundary {b}, layer {l}, relation {}", rel.name)
                        })?,
                );
            }
            per_layer.push(per_rel);
        }
        out.push(per_layer);
    }
    Ok(out)
}

/// SGD remainders at the config's constant shift, in the legacy
/// `result[b][l]` shape (relation axis flattened — SGD has one relation).
pub fn chain_remainders(wits: &[StepWitness]) -> Result<Vec<Vec<Vec<i64>>>> {
    ensure!(wits.len() >= 2, "chaining needs at least two steps");
    let shifts = vec![wits[0].cfg.lr_shift; wits.len() - 1];
    let rems = rule_chain_remainders(&UpdateRule::Sgd, &shifts, wits)?;
    Ok(rems
        .into_iter()
        .map(|per_layer| {
            per_layer
                .into_iter()
                .map(|mut per_rel| per_rel.swap_remove(0))
                .collect()
        })
        .collect())
}

/// Validate that consecutive step witnesses chain under `rule`: every
/// boundary satisfies the rule's relations exactly (equivalently, all
/// relation remainders are in range — the decompositions are unique).
pub fn validate_chain_rule(
    rule: &UpdateRule,
    lr_shifts: &[u32],
    wits: &[StepWitness],
) -> Result<()> {
    rule_chain_remainders(rule, lr_shifts, wits).map(|_| ())
}

/// [`validate_chain_rule`] specialized to plain SGD at the config's
/// constant shift — the pre-rule behavior.
pub fn validate_chain(wits: &[StepWitness]) -> Result<()> {
    chain_remainders(wits).map(|_| ())
}

/// Decompose a scale-2^{2R} tensor into its zkReLU auxiliary inputs.
/// Returns (aux, rescaled values T′).
pub fn rescale_decompose(t: &[i64], r_bits: u32, q_bits: u32) -> (RescaleAux, Vec<i64>) {
    let q_mag = 1i64 << (q_bits - 1);
    let mut dprime = Vec::with_capacity(t.len());
    let mut sign = Vec::with_capacity(t.len());
    let mut rem = Vec::with_capacity(t.len());
    let mut prime = Vec::with_capacity(t.len());
    for &v in t {
        let p = crate::model::round_div_pow2(v, r_bits);
        assert!(
            (-q_mag..q_mag).contains(&p),
            "rescaled value {p} exceeds Q-bit budget (Q={q_bits}); scale down inputs"
        );
        let s = i64::from(p < 0);
        dprime.push(p + (s << (q_bits - 1)));
        sign.push(s);
        rem.push(v - (p << r_bits));
        prime.push(p);
    }
    (RescaleAux { dprime, sign, rem }, prime)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_remainder_matches_rounded_update() {
        let cfg = ModelConfig::new(1, 2, 2);
        let shift = cfg.r_bits + cfg.lr_shift;
        let w_prev = vec![1000i64, -1000, 0, 12345];
        let g_w = vec![1i64 << 40, -(1i64 << 40), 17, -(1i64 << 25)];
        let w_next: Vec<i64> = w_prev
            .iter()
            .zip(g_w.iter())
            .map(|(w, g)| w - crate::model::round_div_pow2(*g, shift))
            .collect();
        let rem = update_remainder(&cfg, &w_prev, &w_next, &g_w).expect("chains");
        let half = 1i64 << (shift - 1);
        for i in 0..4 {
            assert!((-half..half).contains(&rem[i]));
            assert_eq!(
                g_w[i],
                ((w_prev[i] - w_next[i]) << shift) + rem[i],
                "decomposition at {i}"
            );
        }
        // any off-by-one weight breaks the range — the decomposition is unique
        let mut bad = w_next.clone();
        bad[2] += 1;
        assert!(update_remainder(&cfg, &w_prev, &bad, &g_w).is_err());
    }

    #[test]
    fn update_remainder_rejects_unprovable_widths() {
        // R+lr beyond 64 would shift high bits out silently and truncate the
        // i64 embedding — refused up front rather than mis-accepted
        let mut cfg = ModelConfig::new(1, 2, 2);
        cfg.r_bits = 62;
        cfg.lr_shift = 63;
        let err = update_remainder(&cfg, &[0], &[0], &[0]);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("2..=64"), "{msg}");

        // extreme weight swings stay exact: the i128-checked path reports
        // "does not chain" instead of wrapping into range
        let cfg = ModelConfig::new(1, 2, 2); // S = 24
        assert!(update_remainder(&cfg, &[i64::MAX], &[i64::MIN], &[0]).is_err());
        let mut cfg = ModelConfig::new(1, 2, 2);
        cfg.r_bits = 32;
        cfg.lr_shift = 32; // S = 64: diff·2^S overflows i128 → must error
        assert!(update_remainder(&cfg, &[i64::MAX], &[i64::MIN], &[0]).is_err());
    }

    /// Property test backing the zkOptim refactor: the SGD rule's
    /// remainder witnesses are identical to the pre-refactor direct
    /// computation (round-to-nearest remainder of ⌊G_W/2^{R+lr}⌉) on
    /// random chaining weight updates.
    #[test]
    fn sgd_rule_remainders_match_legacy_path() {
        use crate::model::round_div_pow2;
        use crate::util::rng::Rng;
        let cfg = ModelConfig::new(1, 2, 2);
        let shift = cfg.r_bits + cfg.lr_shift;
        let mut rng = Rng::seed_from_u64(0x1e6);
        for _ in 0..50 {
            let w_prev: Vec<i64> = (0..4).map(|_| rng.gen_i64(-100_000, 100_000)).collect();
            let g_w: Vec<i64> = (0..4)
                .map(|_| rng.gen_i64(-(1 << 45), 1 << 45))
                .collect();
            let w_next: Vec<i64> = w_prev
                .iter()
                .zip(g_w.iter())
                .map(|(w, g)| w - round_div_pow2(*g, shift))
                .collect();
            // legacy closed form: R = G_W − 2^S·(W_t − W_{t+1})
            let legacy: Vec<i64> = (0..4)
                .map(|i| g_w[i] - ((w_prev[i] - w_next[i]) << shift))
                .collect();
            let rule = update_remainder(&cfg, &w_prev, &w_next, &g_w).expect("chains");
            assert_eq!(rule, legacy);
        }
    }

    #[test]
    fn rule_chain_remainders_cover_momentum_relations() {
        use crate::update::rule::UpdateRule;
        let cfg = ModelConfig::new(1, 2, 2);
        let rule = UpdateRule::momentum_default();
        // hand-build a two-step momentum chain: m1 = ⌊7m0/8⌉ + g,
        // w1 = w0 − ⌊m1/2^S⌉, shift 8 (S = 24)
        let shift = 8u32;
        let s_bits = cfg.r_bits + shift;
        let m0 = vec![1000i64, -4096, 7, 0];
        let g = vec![1i64 << 30, -(1i64 << 28), 123, -9];
        let m1: Vec<i64> = m0
            .iter()
            .zip(g.iter())
            .map(|(m, gi)| crate::model::round_div_pow2(7 * m, 3) + gi)
            .collect();
        let w0 = vec![500i64, -500, 0, 42];
        let w1: Vec<i64> = w0
            .iter()
            .zip(m1.iter())
            .map(|(w, m)| w - crate::model::round_div_pow2(*m, s_bits))
            .collect();
        let zeros = vec![0i64; cfg.batch * cfg.width];
        let mk = |w: &[i64], m: &[i64], g: &[i64]| {
            let mut wit = native::compute_witness(
                cfg,
                &zeros,
                &zeros,
                &crate::model::Weights {
                    layers: vec![w.to_vec()],
                    cfg,
                },
            );
            wit.layers[0].g_w = g.to_vec();
            wit.opt_state = vec![vec![m.to_vec()]];
            wit
        };
        let wits = vec![mk(&w0, &m0, &g), mk(&w1, &m1, &[0, 0, 0, 0])];
        let rems = rule_chain_remainders(&rule, &[shift], &wits).expect("chains");
        assert_eq!(rems.len(), 1);
        assert_eq!(rems[0][0].len(), 2, "two relations, two remainders");
        for i in 0..4 {
            // relation 0: 7·m0 = 8·(m1 − g) + R_m
            assert_eq!(7 * m0[i], 8 * (m1[i] - g[i]) + rems[0][0][0][i]);
            // relation 1: m1 = 2^S·(w0 − w1) + R_w
            assert_eq!(
                m1[i] as i128,
                ((w0[i] - w1[i]) as i128) * (1i128 << s_bits) + rems[0][0][1][i] as i128
            );
        }
        // a perturbed momentum accumulator no longer chains
        let mut bad = wits.clone();
        bad[1].opt_state[0][0][2] += 1;
        let err = rule_chain_remainders(&rule, &[shift], &bad);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("momentum"), "{msg}");
        // missing state tensors are reported, not panicked on
        let mut stripped = wits.clone();
        stripped[0].opt_state.clear();
        assert!(rule_chain_remainders(&rule, &[shift], &stripped).is_err());
    }

    #[test]
    fn rescale_decompose_relation3() {
        let r = 16u32;
        let q = 32u32;
        let vals: Vec<i64> = vec![
            0,
            1,
            -1,
            65536,
            -65536,
            (1i64 << 40) + 12345,
            -(1i64 << 40) - 54321,
            32767,
            32768,
            -32768,
            -32769,
        ];
        let (aux, prime) = rescale_decompose(&vals, r, q);
        for i in 0..vals.len() {
            let rhs = (aux.dprime[i] << r) - (aux.sign[i] << (q + r - 1)) + aux.rem[i];
            assert_eq!(vals[i], rhs);
            assert_eq!(prime[i], aux.dprime[i] - (aux.sign[i] << (q - 1)));
            assert!((0..(1i64 << (q - 1))).contains(&aux.dprime[i]));
            assert!((-(1i64 << (r - 1))..(1i64 << (r - 1))).contains(&aux.rem[i]));
        }
    }
}
