//! Witness tensors of one training step — everything the prover commits to
//! and proves relations over.
//!
//! A [`StepWitness`] holds, per layer ℓ, the tensors of Example 4.5 plus the
//! zkReLU auxiliary inputs aux^{(ℓ)} = (Z″, B_{Q−1}, R_Z, G_A′, R_{G_A}).
//! [`StepWitness::validate`] checks every arithmetic relation (2)–(5) and
//! (30)–(35) over the integers — this is the ground truth that both the
//! native and the PJRT (JAX/Pallas-compiled) witness generators must satisfy
//! bit-exactly.

pub mod native;

use crate::model::ModelConfig;
use anyhow::{ensure, Context, Result};

/// Rescale decomposition of a tensor T (scale 2^{2R}) into
/// T = 2^R·T″ − 2^{Q+R−1}·B + R_T with T″ ∈ [0, 2^{Q−1}), B ∈ {0,1},
/// R_T ∈ [−2^{R−1}, 2^{R−1}).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RescaleAux {
    /// Re-compressed magnitude Z″ = Z′ + 2^{Q−1}·B_{Q−1}, in [0, 2^{Q−1}).
    pub dprime: Vec<i64>,
    /// Sign bits B_{Q−1} ∈ {0,1} (1 ⇔ Z′ < 0).
    pub sign: Vec<i64>,
    /// Rounding remainder in [−2^{R−1}, 2^{R−1}).
    pub rem: Vec<i64>,
}

/// Per-layer witness.
#[derive(Clone, Debug)]
pub struct LayerWitness {
    /// Weights W^{(ℓ)} (d×d, scale 2^R).
    pub w: Vec<i64>,
    /// Pre-activation Z^{(ℓ)} = A^{(ℓ−1)}·W^{(ℓ)} (B×d, scale 2^{2R}).
    pub z: Vec<i64>,
    /// Rescaled Z^{(ℓ)′} = ⌊Z/2^R⌉ (B×d, scale 2^R).
    pub z_prime: Vec<i64>,
    /// zkReLU decomposition of Z.
    pub z_aux: RescaleAux,
    /// Activation A^{(ℓ)} = (1−B_{Q−1})⊙Z″ for ℓ<L; None for the last layer.
    pub a: Option<Vec<i64>>,
    /// Activation gradient G_A^{(ℓ)} = G_Z^{(ℓ+1)}·W^{(ℓ+1)ᵀ}
    /// (scale 2^{2R}); None for the last layer.
    pub g_a: Option<Vec<i64>>,
    /// zkReLU decomposition of G_A (G_A′ = dprime−2^{Q−1}·sign_ga where
    /// sign_ga tracks G_A′ < 0); None for the last layer.
    pub g_a_aux: Option<RescaleAux>,
    /// Rescaled gradient G_A^{(ℓ)′} (scale 2^R); None for the last layer.
    pub g_a_prime: Option<Vec<i64>>,
    /// Pre-activation gradient G_Z^{(ℓ)} (B×d, scale 2^R):
    /// (1−B_{Q−1})⊙G_A′ for ℓ<L, Z^{(L)′}−Y for ℓ=L.
    pub g_z: Vec<i64>,
    /// Weight gradient G_W^{(ℓ)} = G_Z^{(ℓ)ᵀ}·A^{(ℓ−1)} (d×d, scale 2^{2R}).
    pub g_w: Vec<i64>,
}

/// Full witness of one SGD step.
#[derive(Clone, Debug)]
pub struct StepWitness {
    pub cfg: ModelConfig,
    /// Input batch X = A^{(0)} (B×d, scale 2^R).
    pub x: Vec<i64>,
    /// Targets Y (B×d, scale 2^R; one-hot·2^R for classification).
    pub y: Vec<i64>,
    pub layers: Vec<LayerWitness>,
}

impl StepWitness {
    /// Training loss of this step: ½‖Z^{(L)′} − Y‖² in real units.
    pub fn loss(&self) -> f64 {
        let last = self.layers.last().unwrap();
        let scale = self.cfg.scale() as f64;
        let sum: f64 = last
            .g_z
            .iter()
            .map(|&g| {
                let r = g as f64 / scale;
                r * r
            })
            .sum();
        0.5 * sum / self.cfg.batch as f64
    }

    /// Verify every arithmetic relation of the paper over the integers.
    pub fn validate(&self) -> Result<()> {
        let cfg = &self.cfg;
        let (b, d, depth) = (cfg.batch, cfg.width, cfg.depth);
        let r = cfg.r_bits;
        let q = cfg.q_bits;
        let half_r = 1i64 << (r - 1);
        let q_mag = 1i64 << (q - 1);
        ensure!(self.layers.len() == depth, "layer count");
        ensure!(self.x.len() == b * d && self.y.len() == b * d, "io shapes");

        let mut a_prev: &[i64] = &self.x;
        for (l, lw) in self.layers.iter().enumerate() {
            let last = l + 1 == depth;
            ensure!(lw.w.len() == d * d, "w shape");
            ensure!(lw.z.len() == b * d, "z shape");

            // (30): Z = A_prev · W
            let z = crate::model::matmul_i64(a_prev, &lw.w, b, d, d);
            ensure!(z == lw.z, "relation (30) failed at layer {l}");

            // (3): Z = 2^R·Z″ − 2^{Q+R−1}·B + R_Z, with ranges
            for i in 0..b * d {
                let dp = lw.z_aux.dprime[i];
                let s = lw.z_aux.sign[i];
                let rem = lw.z_aux.rem[i];
                ensure!((0..q_mag).contains(&dp), "Z'' out of range");
                ensure!(s == 0 || s == 1, "B_{{Q-1}} not binary");
                ensure!((-half_r..half_r).contains(&rem), "R_Z out of range");
                let rhs = (dp << r) - (s << (q + r - 1)) + rem;
                ensure!(lw.z[i] == rhs, "relation (3) failed at layer {l}");
                ensure!(
                    lw.z_prime[i] == dp - (s << (q - 1)),
                    "Z' decomposition failed at layer {l}"
                );
            }

            if !last {
                // (2): A = (1 − B)⊙Z″
                let a = lw.a.as_ref().expect("inner layer has activation");
                for i in 0..b * d {
                    ensure!(
                        a[i] == (1 - lw.z_aux.sign[i]) * lw.z_aux.dprime[i],
                        "relation (2) failed at layer {l}"
                    );
                }
                // (5): G_A = 2^R·G_A′ + R_{G_A}  (signed Q-bit G_A′)
                let g_a = lw.g_a.as_ref().unwrap();
                let g_a_prime = lw.g_a_prime.as_ref().unwrap();
                let aux = lw.g_a_aux.as_ref().unwrap();
                for i in 0..b * d {
                    let gp = g_a_prime[i];
                    ensure!((-q_mag..q_mag).contains(&gp), "G_A' out of range");
                    ensure!(
                        (-half_r..half_r).contains(&aux.rem[i]),
                        "R_GA out of range"
                    );
                    ensure!(
                        g_a[i] == (gp << r) + aux.rem[i],
                        "relation (5) failed at layer {l}"
                    );
                    // signed decomposition consistency
                    ensure!(aux.sign[i] == 0 || aux.sign[i] == 1, "G_A' sign bit");
                    ensure!(
                        gp == aux.dprime[i] - (aux.sign[i] << (q - 1)),
                        "G_A' magnitude/sign decomposition at layer {l}"
                    );
                    ensure!((0..q_mag).contains(&aux.dprime[i]), "G_A'' range");
                }
                // (4): G_Z = (1 − B)⊙G_A′
                for i in 0..b * d {
                    ensure!(
                        lw.g_z[i] == (1 - lw.z_aux.sign[i]) * g_a_prime[i],
                        "relation (4) failed at layer {l}"
                    );
                }
                // (33): G_A^{(ℓ)} = G_Z^{(ℓ+1)}·W^{(ℓ+1)ᵀ}
                let next = &self.layers[l + 1];
                let expect = crate::model::matmul_a_bt(&next.g_z, &next.w, b, d, d);
                ensure!(*g_a == expect, "relation (33) failed at layer {l}");
            } else {
                // (32): G_Z^{(L)} = Z^{(L)′} − Y
                for i in 0..b * d {
                    ensure!(
                        lw.g_z[i] == lw.z_prime[i] - self.y[i],
                        "relation (32) failed"
                    );
                }
            }

            // (34): G_W = G_Zᵀ·A_prev
            let gw = crate::model::matmul_at_b(&lw.g_z, a_prev, b, d, d);
            ensure!(gw == lw.g_w, "relation (34) failed at layer {l}");

            if let Some(a) = &lw.a {
                a_prev = a;
            }
        }
        Ok(())
    }

    /// Weight gradients (for the coordinator's SGD update).
    pub fn weight_grads(&self) -> Vec<Vec<i64>> {
        self.layers.iter().map(|l| l.g_w.clone()).collect()
    }
}

/// Exact remainder of one quantized SGD update (the zkSGD chain witness).
///
/// The coordinator's update is W_{t+1} = W_t − ⌊G_W / 2^{R+lr}⌉, whose
/// round-to-nearest remainder is the unique R with
///     G_W = 2^{R+lr}·(W_t − W_{t+1}) + R,   R ∈ [−2^{S−1}, 2^{S−1}),
/// S = R_bits + lr_shift. Returns an error — "the weights do not chain" —
/// if any entry's remainder falls outside that range, which happens exactly
/// when W_{t+1} is not the rounded update of (W_t, G_W).
pub fn update_remainder(
    cfg: &ModelConfig,
    w_prev: &[i64],
    w_next: &[i64],
    g_w: &[i64],
) -> Result<Vec<i64>> {
    let s_bits = cfg.r_bits + cfg.lr_shift;
    // wire validation allows R+lr up to 125; beyond 64 the shift below would
    // silently drop high bits of the weight difference and an in-range R
    // would not fit the i64 the prover embeds, so refuse to witness such
    // configs (an honest chain there updates no weights anyway)
    ensure!(
        (2..=64).contains(&s_bits),
        "update-remainder width R+lr = {s_bits} outside the provable 2..=64"
    );
    let half = 1i128 << (s_bits - 1);
    ensure!(
        w_prev.len() == w_next.len() && w_prev.len() == g_w.len(),
        "update tensor shape mismatch"
    );
    let mut out = Vec::with_capacity(g_w.len());
    for i in 0..g_w.len() {
        let r = (w_prev[i] as i128 - w_next[i] as i128)
            .checked_mul(1i128 << s_bits)
            .and_then(|scaled| (g_w[i] as i128).checked_sub(scaled));
        // overflow of the exact i128 value certainly exceeds the range
        match r {
            // |r| ≤ 2^63 inside the range (s_bits ≤ 64), so the cast is exact
            Some(r) if (-half..half).contains(&r) => out.push(r as i64),
            _ => anyhow::bail!(
                "update remainder out of range at index {i}: the weights do not chain"
            ),
        }
    }
    Ok(out)
}

/// Update remainders of every boundary and layer of a consecutive witness
/// chain: `result[b][l]` is boundary b / layer ℓ's remainder tensor. Fails
/// — naming the boundary and layer — if any boundary's weights are not the
/// exact rounded update of the previous step. The single source of the
/// chain-walk logic: [`validate_chain`] and the zkSGD prover
/// (`update::ChainWitness`) both build on it.
pub fn chain_remainders(wits: &[StepWitness]) -> Result<Vec<Vec<Vec<i64>>>> {
    let mut out = Vec::with_capacity(wits.len().saturating_sub(1));
    for b in 0..wits.len().saturating_sub(1) {
        let (prev, next) = (&wits[b], &wits[b + 1]);
        ensure!(prev.cfg == next.cfg, "config mismatch at boundary {b}");
        let mut per_layer = Vec::with_capacity(prev.cfg.depth);
        for l in 0..prev.cfg.depth {
            per_layer.push(
                update_remainder(
                    &prev.cfg,
                    &prev.layers[l].w,
                    &next.layers[l].w,
                    &prev.layers[l].g_w,
                )
                .with_context(|| format!("boundary {b}, layer {l}"))?,
            );
        }
        out.push(per_layer);
    }
    Ok(out)
}

/// Validate that consecutive step witnesses chain: every boundary's weights
/// satisfy W_{t+1} = W_t − ⌊G_W/2^{R+lr}⌉ exactly (equivalently, all update
/// remainders are in range — the decomposition is unique).
pub fn validate_chain(wits: &[StepWitness]) -> Result<()> {
    chain_remainders(wits).map(|_| ())
}

/// Decompose a scale-2^{2R} tensor into its zkReLU auxiliary inputs.
/// Returns (aux, rescaled values T′).
pub fn rescale_decompose(t: &[i64], r_bits: u32, q_bits: u32) -> (RescaleAux, Vec<i64>) {
    let q_mag = 1i64 << (q_bits - 1);
    let mut dprime = Vec::with_capacity(t.len());
    let mut sign = Vec::with_capacity(t.len());
    let mut rem = Vec::with_capacity(t.len());
    let mut prime = Vec::with_capacity(t.len());
    for &v in t {
        let p = crate::model::round_div_pow2(v, r_bits);
        assert!(
            (-q_mag..q_mag).contains(&p),
            "rescaled value {p} exceeds Q-bit budget (Q={q_bits}); scale down inputs"
        );
        let s = i64::from(p < 0);
        dprime.push(p + (s << (q_bits - 1)));
        sign.push(s);
        rem.push(v - (p << r_bits));
        prime.push(p);
    }
    (RescaleAux { dprime, sign, rem }, prime)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_remainder_matches_rounded_update() {
        let cfg = ModelConfig::new(1, 2, 2);
        let shift = cfg.r_bits + cfg.lr_shift;
        let w_prev = vec![1000i64, -1000, 0, 12345];
        let g_w = vec![1i64 << 40, -(1i64 << 40), 17, -(1i64 << 25)];
        let w_next: Vec<i64> = w_prev
            .iter()
            .zip(g_w.iter())
            .map(|(w, g)| w - crate::model::round_div_pow2(*g, shift))
            .collect();
        let rem = update_remainder(&cfg, &w_prev, &w_next, &g_w).expect("chains");
        let half = 1i64 << (shift - 1);
        for i in 0..4 {
            assert!((-half..half).contains(&rem[i]));
            assert_eq!(
                g_w[i],
                ((w_prev[i] - w_next[i]) << shift) + rem[i],
                "decomposition at {i}"
            );
        }
        // any off-by-one weight breaks the range — the decomposition is unique
        let mut bad = w_next.clone();
        bad[2] += 1;
        assert!(update_remainder(&cfg, &w_prev, &bad, &g_w).is_err());
    }

    #[test]
    fn update_remainder_rejects_unprovable_widths() {
        // R+lr beyond 64 would shift high bits out silently and truncate the
        // i64 embedding — refused up front rather than mis-accepted
        let mut cfg = ModelConfig::new(1, 2, 2);
        cfg.r_bits = 62;
        cfg.lr_shift = 63;
        let err = update_remainder(&cfg, &[0], &[0], &[0]);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("R+lr"), "{msg}");

        // extreme weight swings stay exact: the i128-checked path reports
        // "does not chain" instead of wrapping into range
        let cfg = ModelConfig::new(1, 2, 2); // S = 24
        assert!(update_remainder(&cfg, &[i64::MAX], &[i64::MIN], &[0]).is_err());
        let mut cfg = ModelConfig::new(1, 2, 2);
        cfg.r_bits = 32;
        cfg.lr_shift = 32; // S = 64: diff·2^S overflows i128 → must error
        assert!(update_remainder(&cfg, &[i64::MAX], &[i64::MIN], &[0]).is_err());
    }

    #[test]
    fn rescale_decompose_relation3() {
        let r = 16u32;
        let q = 32u32;
        let vals: Vec<i64> = vec![
            0,
            1,
            -1,
            65536,
            -65536,
            (1i64 << 40) + 12345,
            -(1i64 << 40) - 54321,
            32767,
            32768,
            -32768,
            -32769,
        ];
        let (aux, prime) = rescale_decompose(&vals, r, q);
        for i in 0..vals.len() {
            let rhs = (aux.dprime[i] << r) - (aux.sign[i] << (q + r - 1)) + aux.rem[i];
            assert_eq!(vals[i], rhs);
            assert_eq!(prime[i], aux.dprime[i] - (aux.sign[i] << (q - 1)));
            assert!((0..(1i64 << (q - 1))).contains(&aux.dprime[i]));
            assert!((-(1i64 << (r - 1))..(1i64 << (r - 1))).contains(&aux.rem[i]));
        }
    }
}
