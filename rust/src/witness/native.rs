//! Native (pure-rust) witness generator: the reference fixed-point training
//! step. The PJRT path (`runtime::pjrt_witness`) must agree with this
//! bit-exactly — an integration test asserts it. Benches use this generator
//! for sweep configurations that have no AOT artifact.

use super::{rescale_decompose, LayerWitness, StepWitness};
use crate::model::{matmul_a_bt, matmul_at_b, matmul_i64, ModelConfig, Weights};
use crate::update::rule::{LrSchedule, UpdateRule};

/// Execute one quantized training step and collect the full witness.
///
/// `x`, `y`: B×d row-major at scale 2^R.
pub fn compute_witness(cfg: ModelConfig, x: &[i64], y: &[i64], weights: &Weights) -> StepWitness {
    let (b, d, depth) = (cfg.batch, cfg.width, cfg.depth);
    assert_eq!(x.len(), b * d);
    assert_eq!(y.len(), b * d);
    assert_eq!(weights.layers.len(), depth);

    // ---- forward ----
    let mut zs = Vec::with_capacity(depth);
    let mut z_auxes = Vec::with_capacity(depth);
    let mut z_primes = Vec::with_capacity(depth);
    let mut acts: Vec<Vec<i64>> = Vec::with_capacity(depth); // A^{(1..L-1)}
    for (l, w) in weights.layers.iter().enumerate() {
        let a_prev: &[i64] = if l == 0 { x } else { &acts[l - 1] };
        let z = matmul_i64(a_prev, w, b, d, d);
        let (aux, z_prime) = rescale_decompose(&z, cfg.r_bits, cfg.q_bits);
        if l + 1 < depth {
            let a: Vec<i64> = aux
                .dprime
                .iter()
                .zip(aux.sign.iter())
                .map(|(&dp, &s)| (1 - s) * dp)
                .collect();
            acts.push(a);
        }
        zs.push(z);
        z_auxes.push(aux);
        z_primes.push(z_prime);
    }

    // ---- backward ----
    // g_z[L-1] = Z^{(L)'} − Y
    let mut g_zs: Vec<Vec<i64>> = vec![Vec::new(); depth];
    let mut g_as: Vec<Option<Vec<i64>>> = vec![None; depth];
    let mut g_a_primes: Vec<Option<Vec<i64>>> = vec![None; depth];
    let mut g_a_auxes: Vec<Option<super::RescaleAux>> = vec![None; depth];
    g_zs[depth - 1] = z_primes[depth - 1]
        .iter()
        .zip(y.iter())
        .map(|(&zp, &yv)| zp - yv)
        .collect();
    for l in (0..depth - 1).rev() {
        // (33): G_A^{(ℓ)} = G_Z^{(ℓ+1)}·W^{(ℓ+1)ᵀ}
        let g_a = matmul_a_bt(&g_zs[l + 1], &weights.layers[l + 1], b, d, d);
        let (aux, g_a_prime) = rescale_decompose(&g_a, cfg.r_bits, cfg.q_bits);
        // (4): G_Z = (1 − B_{Q−1})⊙G_A′ — uses Z's sign bits
        g_zs[l] = g_a_prime
            .iter()
            .zip(z_auxes[l].sign.iter())
            .map(|(&gp, &s)| (1 - s) * gp)
            .collect();
        g_as[l] = Some(g_a);
        g_a_primes[l] = Some(g_a_prime);
        g_a_auxes[l] = Some(aux);
    }

    // ---- weight gradients + assemble ----
    let mut layers = Vec::with_capacity(depth);
    for l in 0..depth {
        let a_prev: &[i64] = if l == 0 { x } else { &acts[l - 1] };
        let g_w = matmul_at_b(&g_zs[l], a_prev, b, d, d);
        layers.push(LayerWitness {
            w: weights.layers[l].clone(),
            z: std::mem::take(&mut zs[l]),
            z_prime: std::mem::take(&mut z_primes[l]),
            z_aux: z_auxes[l].clone(),
            a: if l + 1 < depth {
                Some(acts[l].clone())
            } else {
                None
            },
            g_a: g_as[l].take(),
            g_a_aux: g_a_auxes[l].take(),
            g_a_prime: g_a_primes[l].take(),
            g_z: std::mem::take(&mut g_zs[l]),
            g_w,
        });
    }

    StepWitness {
        cfg,
        x: x.to_vec(),
        y: y.to_vec(),
        layers,
        opt_state: Vec::new(),
        batch_rows: Vec::new(),
    }
}

/// T consecutive training-step witnesses under an [`UpdateRule`] and
/// per-step [`LrSchedule`], with the rule's exact quantized update applied
/// between steps — the canonical chained-trace input. Each witness carries
/// the optimizer state *entering* its step (`opt_state`), zero-initialized
/// at step 0. Weights initialize from `seed`; step t consumes batch t of
/// `ds`.
pub fn rule_witness_chain(
    cfg: ModelConfig,
    rule: &UpdateRule,
    schedule: &LrSchedule,
    ds: &crate::data::Dataset,
    steps: usize,
    seed: u64,
) -> Vec<StepWitness> {
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
    let mut weights = Weights::init(cfg, &mut rng);
    let mut state = rule.init_state(&cfg);
    let mut out = Vec::with_capacity(steps);
    for step in 0..steps {
        let rows = ds.batch_indices(&cfg, step);
        let (x, y) = ds.batch_at(&cfg, &rows);
        let mut wit = compute_witness(cfg, &x, &y, &weights);
        wit.opt_state = state.clone();
        wit.batch_rows = rows;
        rule.apply_update(
            schedule.shift_at(step),
            &mut weights,
            &mut state,
            &wit.weight_grads(),
        );
        out.push(wit);
    }
    out
}

/// [`rule_witness_chain`] specialized to plain SGD at the config's
/// constant shift — the pre-rule behavior, shared by the examples,
/// benches, and tests that need a witness chain.
pub fn sgd_witness_chain(
    cfg: ModelConfig,
    ds: &crate::data::Dataset,
    steps: usize,
    seed: u64,
) -> Vec<StepWitness> {
    rule_witness_chain(
        cfg,
        &UpdateRule::Sgd,
        &LrSchedule::Constant(cfg.lr_shift),
        ds,
        steps,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn small_setup(depth: usize) -> (ModelConfig, Vec<i64>, Vec<i64>, Weights) {
        let cfg = ModelConfig::new(depth, 8, 4);
        let mut rng = Rng::seed_from_u64(42);
        let scale = cfg.scale();
        let x: Vec<i64> = (0..cfg.batch * cfg.width)
            .map(|_| rng.gen_i64(-scale, scale))
            .collect();
        let mut y = vec![0i64; cfg.batch * cfg.width];
        for i in 0..cfg.batch {
            y[i * cfg.width + (i % cfg.width)] = scale;
        }
        let w = Weights::init(cfg, &mut rng);
        (cfg, x, y, w)
    }

    #[test]
    fn witness_validates_depth2() {
        let (cfg, x, y, w) = small_setup(2);
        let wit = compute_witness(cfg, &x, &y, &w);
        wit.validate().expect("all relations hold");
    }

    #[test]
    fn witness_validates_depth5() {
        let (cfg, x, y, w) = small_setup(5);
        let wit = compute_witness(cfg, &x, &y, &w);
        wit.validate().expect("all relations hold");
    }

    #[test]
    fn witness_validates_depth1() {
        // single layer: no ReLU at all, just rescale + loss gradient
        let (cfg, x, y, w) = small_setup(1);
        let wit = compute_witness(cfg, &x, &y, &w);
        wit.validate().expect("all relations hold");
        assert!(wit.layers[0].a.is_none());
        assert!(wit.layers[0].g_a.is_none());
    }

    #[test]
    fn validate_catches_tampering() {
        let (cfg, x, y, w) = small_setup(3);
        let good = compute_witness(cfg, &x, &y, &w);

        // flip a sign bit → relations (2)/(3) break
        let mut bad = good.clone();
        bad.layers[0].z_aux.sign[3] = 1 - bad.layers[0].z_aux.sign[3];
        assert!(bad.validate().is_err());

        // perturb an activation → relation (2) breaks
        let mut bad = good.clone();
        if let Some(a) = bad.layers[0].a.as_mut() {
            a[0] += 1;
        }
        assert!(bad.validate().is_err());

        // perturb a weight gradient → relation (34) breaks
        let mut bad = good.clone();
        bad.layers[1].g_w[0] += 1;
        assert!(bad.validate().is_err());

        // out-of-range remainder → range check breaks
        let mut bad = good.clone();
        bad.layers[0].z_aux.rem[0] += 1i64 << cfg.r_bits;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn momentum_witness_chain_validates_under_its_rule() {
        let cfg = ModelConfig::new(2, 8, 4);
        let rule = UpdateRule::momentum_default();
        let schedule = LrSchedule::StepDecay {
            base: cfg.lr_shift,
            period: 2,
            max: cfg.lr_shift + 2,
        };
        let ds = crate::data::Dataset::synthetic(64, 4, 4, cfg.r_bits, 0xbeef);
        let steps = 5;
        let wits = rule_witness_chain(cfg, &rule, &schedule, &ds, steps, 0x5eed);
        assert_eq!(wits.len(), steps);
        for wit in &wits {
            wit.validate().expect("per-step relations hold");
            assert_eq!(wit.opt_state.len(), 1, "one momentum slot");
            assert_eq!(wit.opt_state[0].len(), cfg.depth);
        }
        assert!(wits[0].opt_state[0].iter().all(|t| t.iter().all(|&v| v == 0)));
        // momentum actually accumulates: later states are non-zero
        assert!(wits[2].opt_state[0][0].iter().any(|&v| v != 0));
        let table = schedule.window_table(0, steps - 1);
        crate::witness::validate_chain_rule(&rule, &table, &wits)
            .expect("momentum chain validates under its own rule");
        // ... and does NOT chain under plain SGD (the updates differ)
        assert!(crate::witness::validate_chain(&wits).is_err());
    }

    #[test]
    fn training_reduces_loss() {
        // a few SGD steps on a fixed batch must reduce the quadratic loss
        let (cfg, x, y, mut w) = small_setup(2);
        let first = compute_witness(cfg, &x, &y, &w);
        first.validate().unwrap();
        let mut loss_prev = first.loss();
        let mut improved = 0;
        let mut wit = first;
        for _ in 0..20 {
            w.apply_update(&wit.weight_grads());
            wit = compute_witness(cfg, &x, &y, &w);
            let loss = wit.loss();
            if loss < loss_prev {
                improved += 1;
            }
            loss_prev = loss;
        }
        assert!(improved >= 15, "loss should mostly decrease, got {improved}/20");
    }
}
