//! Hand-rolled JSON value, writer, and parser — the repo is serde-free
//! offline, and telemetry needs both directions: the writer for
//! `--profile`/`BENCH_*.json` emission, the parser for golden-schema tests
//! that re-read what was written.
//!
//! Deliberately minimal: objects preserve insertion order, numbers are
//! either `u64` (counters, byte sizes) or `f64` (seconds), and the parser
//! accepts exactly the subset the writer emits plus ordinary interchange
//! JSON (it is a strict RFC 8259 subset — no comments, no trailing commas,
//! no NaN/Infinity).

/// A JSON value. `Uint` keeps counter values exact (u64 > 2^53 would lose
/// precision through f64).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Uint(u64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from `(&str, Json)` pairs (insertion order preserved).
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Field lookup on an object (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Uint(v) => Some(*v),
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Uint(v) => Some(*v as f64),
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact, no whitespace).
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        use std::fmt::Write as _;
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Uint(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Num(v) => {
                assert!(v.is_finite(), "JSON cannot represent {v}");
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    // integral floats print with a `.0` so they round-trip
                    // as floats, matching what we wrote
                    let _ = write!(out, "{v:.1}");
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed, trailing
    /// garbage rejected).
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err("unexpected end of input".into());
    };
    match c {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => Err(format!("unexpected byte {c:#04x} at {pos}")),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}"));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            return Err("unterminated string".into());
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    return Err("unterminated escape".into());
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or("truncated \\u escape")?;
                        let cp =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())?;
                        *pos += 4;
                        // BMP only — surrogate pairs are not needed for the
                        // ASCII-dominated telemetry output; reject them.
                        let c = char::from_u32(cp)
                            .ok_or_else(|| format!("unsupported codepoint \\u{hex}"))?;
                        out.push(c);
                    }
                    e => return Err(format!("bad escape \\{}", e as char)),
                }
            }
            c if c < 0x20 => return Err("raw control character in string".into()),
            _ => {
                // re-sync to char boundary: collect the UTF-8 sequence
                let start = *pos - 1;
                let len = utf8_len(c);
                let end = start + len;
                let chunk = b
                    .get(start..end)
                    .and_then(|s| std::str::from_utf8(s).ok())
                    .ok_or("invalid UTF-8 in string")?;
                out.push_str(chunk);
                *pos = end;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("ASCII number bytes");
    if !text.contains(['.', 'e', 'E']) && !text.starts_with('-') {
        if let Ok(v) = text.parse::<u64>() {
            return Ok(Json::Uint(v));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number {text:?} at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("schema", Json::str("zkdl/bench/v1")),
            ("n", Json::Uint(18446744073709551615)),
            ("t", Json::Num(1.5)),
            ("whole", Json::Num(3.0)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            (
                "cases",
                Json::Arr(vec![Json::obj(vec![("steps", Json::Uint(16))])]),
            ),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).expect("roundtrip parses");
        assert_eq!(back, v);
        assert_eq!(back.get("n").unwrap().as_u64(), Some(u64::MAX));
        assert_eq!(back.get("whole").unwrap().as_f64(), Some(3.0));
        assert_eq!(
            back.get("cases").unwrap().as_array().unwrap()[0]
                .get("steps")
                .unwrap()
                .as_u64(),
            Some(16)
        );
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::str("a\"b\\c\nd\te\u{1}é—\u{1F600}");
        let s = v.to_string();
        assert!(s.contains("\\\""));
        assert!(s.contains("\\u0001"));
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_interchange_whitespace_and_escapes() {
        let s = " { \"a\" : [ 1 , -2.5 , \"x\\u0041\" ] ,\n \"b\" : { } } ";
        let v = Json::parse(s).expect("parses");
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(-2.5));
        assert_eq!(arr[2].as_str(), Some("xA"));
        assert_eq!(v.get("b"), Some(&Json::Obj(vec![])));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "1 2", "{\"a\":1,}", "[1]]",
            "\"unterminated", "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }
}
