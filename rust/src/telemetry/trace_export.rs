//! zkFlight Perfetto export — Chrome trace-event JSON from the span stream.
//!
//! While recording (`--trace-out <path>`), every [`super::SpanGuard`]
//! emits a `B`/`E` duration-event pair onto a process-global buffer, tagged
//! with a per-thread track id, so the coordinator's pipeline overlap
//! (`prover-worker` / `aggregator-worker` vs the main thread) is visible on
//! a timeline in `ui.perfetto.dev` or `chrome://tracing`. Span exits also
//! sample two counter tracks (`msm/points`, `arena/bytes_reused`) as `C`
//! events.
//!
//! Recording is **off by default** and independent of the telemetry enable
//! flag (it only ever engages *in addition to* enabled telemetry — spans
//! are not created otherwise). The disabled cost inside an enabled span is
//! one relaxed load. Balance guarantee: an `E` is pushed iff the guard's
//! `B` was pushed (the guard remembers), so toggling recording mid-span
//! never produces an orphan event.

use crate::telemetry::json::Json;
use crate::telemetry::{counter_value, Counter};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

static RECORDING: AtomicBool = AtomicBool::new(false);
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// This thread's track id; 0 = not yet assigned.
    static TID: Cell<u64> = const { Cell::new(0) };
}

/// One buffered trace event (converted to Chrome JSON at export).
enum TraceEvent {
    Begin { name: &'static str, ts_ns: u64, tid: u64 },
    End { name: &'static str, ts_ns: u64, tid: u64 },
    ThreadName { name: String, tid: u64 },
    Counter { name: &'static str, ts_ns: u64, value: u64 },
}

#[inline]
pub fn is_recording() -> bool {
    RECORDING.load(Ordering::Relaxed)
}

/// Start (clearing any previous buffer) or stop recording. Spans already
/// open when recording starts are not back-filled; spans still open when it
/// stops flush their `E` on drop (their `B` is in the buffer).
pub fn set_recording(on: bool) {
    if on {
        events().clear();
        EPOCH.get_or_init(Instant::now);
    }
    RECORDING.store(on, Ordering::Relaxed);
}

fn events() -> std::sync::MutexGuard<'static, Vec<TraceEvent>> {
    EVENTS.lock().unwrap_or_else(|p| p.into_inner())
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// This thread's track id, assigning one (and emitting a default
/// `thread_name` metadata event) on first use.
fn tid() -> u64 {
    TID.with(|t| {
        let mut id = t.get();
        if id == 0 {
            id = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(id);
            events().push(TraceEvent::ThreadName {
                name: format!("thread-{id}"),
                tid: id,
            });
        }
        id
    })
}

/// Label this thread's track (e.g. `"prover-worker"`). No-op unless
/// recording.
pub fn set_thread_name(name: &str) {
    if !is_recording() {
        return;
    }
    let id = tid();
    events().push(TraceEvent::ThreadName {
        name: name.to_string(),
        tid: id,
    });
}

/// Span-open hook (called by `SpanGuard::enter`). Returns whether a `B`
/// event was pushed — the guard passes it back to [`on_exit`] so pairs
/// stay balanced across recording toggles.
#[inline]
pub(super) fn on_enter(name: &'static str) -> bool {
    if !is_recording() {
        return false;
    }
    let id = tid();
    events().push(TraceEvent::Begin {
        name,
        ts_ns: now_ns(),
        tid: id,
    });
    true
}

/// Span-close hook (called by `SpanGuard::drop` iff [`on_enter`] pushed).
pub(super) fn on_exit(name: &'static str) {
    let id = tid();
    let ts_ns = now_ns();
    let mut ev = events();
    ev.push(TraceEvent::End { name, ts_ns, tid: id });
    // counter tracks, sampled at span close — enough resolution to see MSM
    // work and arena reuse accrue across the timeline
    ev.push(TraceEvent::Counter {
        name: "msm/points",
        ts_ns,
        value: counter_value(Counter::MsmPoints),
    });
    ev.push(TraceEvent::Counter {
        name: "arena/bytes_reused",
        ts_ns,
        value: counter_value(Counter::ArenaBytesReused),
    });
}

fn us(ts_ns: u64) -> Json {
    Json::Num(ts_ns as f64 / 1000.0)
}

/// The buffered events as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`), loadable in `ui.perfetto.dev`. Does not
/// clear the buffer.
pub fn export_json() -> Json {
    let ev = events();
    let mut out = Vec::with_capacity(ev.len());
    for e in ev.iter() {
        out.push(match e {
            TraceEvent::Begin { name, ts_ns, tid } => Json::obj(vec![
                ("ph", Json::str("B")),
                ("name", Json::str(name)),
                ("ts", us(*ts_ns)),
                ("pid", Json::Uint(1)),
                ("tid", Json::Uint(*tid)),
            ]),
            TraceEvent::End { name, ts_ns, tid } => Json::obj(vec![
                ("ph", Json::str("E")),
                ("name", Json::str(name)),
                ("ts", us(*ts_ns)),
                ("pid", Json::Uint(1)),
                ("tid", Json::Uint(*tid)),
            ]),
            TraceEvent::ThreadName { name, tid } => Json::obj(vec![
                ("ph", Json::str("M")),
                ("name", Json::str("thread_name")),
                ("pid", Json::Uint(1)),
                ("tid", Json::Uint(*tid)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]),
            TraceEvent::Counter { name, ts_ns, value } => Json::obj(vec![
                ("ph", Json::str("C")),
                ("name", Json::str(name)),
                ("ts", us(*ts_ns)),
                ("pid", Json::Uint(1)),
                ("args", Json::obj(vec![("value", Json::Uint(*value))])),
            ]),
        });
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Number of buffered events (tests/diagnostics).
pub fn event_count() -> usize {
    events().len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry;

    #[test]
    fn records_balanced_pairs_and_thread_names() {
        // exclusive: recording is process-global, like counters
        telemetry::exclusive(|| {
            telemetry::reset();
            telemetry::set_enabled(true);
            set_recording(true);
            set_thread_name("test-main");
            telemetry::timed("test/export_outer", || {
                telemetry::timed("test/export_inner", || std::hint::black_box(1u64));
            });
            std::thread::scope(|s| {
                s.spawn(|| {
                    set_thread_name("test-worker");
                    telemetry::timed("test/export_worker", || std::hint::black_box(2u64));
                });
            });
            set_recording(false);
            telemetry::set_enabled(false);
            let doc = export_json();
            let events = doc
                .get("traceEvents")
                .and_then(|v| v.as_array())
                .expect("traceEvents array");

            let ph = |e: &Json| e.get("ph").and_then(|v| v.as_str()).unwrap().to_string();
            // filter to this test's spans: a parallel test running while
            // telemetry was enabled may have contributed its own events
            let ours = |e: &Json| {
                e.get("name")
                    .and_then(|v| v.as_str())
                    .is_some_and(|n| n.starts_with("test/export"))
            };
            let begins = events.iter().filter(|e| ph(e) == "B" && ours(e)).count();
            let ends = events.iter().filter(|e| ph(e) == "E" && ours(e)).count();
            assert_eq!(begins, 3, "outer + inner + worker");
            assert_eq!(begins, ends, "balanced B/E");
            // every B/E tid has a thread_name metadata event
            let mut tids: Vec<u64> = events
                .iter()
                .filter(|e| (ph(e) == "B" || ph(e) == "E") && ours(e))
                .map(|e| e.get("tid").and_then(|v| v.as_u64()).unwrap())
                .collect();
            tids.sort_unstable();
            tids.dedup();
            assert_eq!(tids.len(), 2, "main + worker tracks");
            for t in &tids {
                assert!(
                    events.iter().any(|e| ph(e) == "M"
                        && e.get("tid").and_then(|v| v.as_u64()) == Some(*t)),
                    "tid {t} has no thread_name"
                );
            }
            let names: Vec<String> = events
                .iter()
                .filter(|e| ph(e) == "M")
                .filter_map(|e| {
                    e.get("args")
                        .and_then(|a| a.get("name"))
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string())
                })
                .collect();
            assert!(names.iter().any(|n| n == "test-main"), "{names:?}");
            assert!(names.iter().any(|n| n == "test-worker"), "{names:?}");
            // counter samples rode along on span exits
            assert!(events.iter().any(|e| ph(e) == "C"));
        });
    }

    #[test]
    fn disabled_recording_buffers_nothing() {
        telemetry::exclusive(|| {
            telemetry::reset();
            set_recording(true);
            set_recording(false);
            telemetry::set_enabled(true);
            telemetry::timed("test/export_off", || std::hint::black_box(3u64));
            telemetry::set_enabled(false);
            assert_eq!(event_count(), 0);
        });
    }
}
