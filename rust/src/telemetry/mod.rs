//! zkObs — zero-dependency observability for the prover/verifier stack.
//!
//! Two instruments, one switch:
//!
//! * **Hierarchical spans** — RAII scoped timers (`crate::span!("ipa/prove")`)
//!   collected into a per-thread tree. Each thread's tree is merged into a
//!   process-global tree when the thread exits (the coordinator's pipeline
//!   workers are scoped threads, so their trees land before the report is
//!   read), and [`report`] additionally folds in the calling thread's live
//!   tree.
//! * **Counters** — monotonically increasing `u64`s for proof-system events:
//!   MSM invocations and point counts, accumulator flush/equation/fixed-block
//!   stats, key-cache hits/misses/evictions, transcript absorbs, wire bytes,
//!   sumcheck/IPA rounds.
//!
//! zkFlight (PR 8) layers a flight recorder on top: [`hist`] latency/size
//! histograms (rendered in [`Report`]), [`failure`] typed verification
//! failure classes with `reject/…` counters, [`journal`] append-only JSONL
//! event records, and [`trace_export`] Perfetto/Chrome trace-event dumps of
//! the span stream.
//!
//! Telemetry is **disabled by default**; the disabled fast path of both the
//! span macro and [`count`] is a single relaxed atomic load (no TLS access,
//! no allocation — pinned by `tests/telemetry.rs`). Proof bytes and artifacts
//! are never affected: telemetry observes, it does not participate in
//! transcripts or encodings.
//!
//! Span names are slash-paths, `<module>/<operation>` (e.g.
//! `aggregate/matmul_sumcheck`); counter names are slash-paths too
//! (`msm/calls`, `cache/vbases/hits`). See DESIGN.md §telemetry for the
//! full inventory.

pub mod bench;
pub mod failure;
pub mod hist;
pub mod journal;
pub mod json;
pub mod trace_export;

use std::cell::RefCell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

// ---------------------------------------------------------------------------
// enable flag
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is telemetry recording? One relaxed load — this is the entire cost of
/// every span/counter site while profiling is off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off. Does not clear previously recorded data
/// (use [`reset`] for that).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// counters
// ---------------------------------------------------------------------------

macro_rules! define_counters {
    ($($variant:ident => $name:literal),* $(,)?) => {
        /// Proof-system event counters. `Counter::name()` gives the stable
        /// slash-path used in reports and JSON.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        #[repr(usize)]
        pub enum Counter { $($variant),* }

        /// Stable names, indexed by `Counter as usize`.
        pub const COUNTER_NAMES: &[&str] = &[$($name),*];

        impl Counter {
            /// Total number of counters.
            pub const COUNT: usize = COUNTER_NAMES.len();

            /// The counter's stable slash-path name.
            pub fn name(self) -> &'static str {
                COUNTER_NAMES[self as usize]
            }
        }
    };
}

define_counters! {
    MsmCalls => "msm/calls",
    MsmPoints => "msm/points",
    MsmFlushes => "msm/flushes",
    MsmEquations => "msm/equations",
    MsmFixedBlocksNew => "msm/fixed_blocks/new",
    MsmFixedBlocksMerged => "msm/fixed_blocks/merged",
    SumcheckProveRounds => "sumcheck/prove_rounds",
    SumcheckVerifyRounds => "sumcheck/verify_rounds",
    SumcheckParChunks => "sumcheck/par_chunks",
    PoolJobs => "pool/jobs",
    PoolQueueFull => "pool/queue_full",
    IpaProveRounds => "ipa/prove_rounds",
    IpaVerifyRounds => "ipa/verify_rounds",
    TranscriptAbsorbs => "transcript/absorbs",
    TranscriptChallenges => "transcript/challenges",
    WireBytesEncoded => "wire/bytes_encoded",
    WireBytesDecoded => "wire/bytes_decoded",
    CommitKeyHits => "cache/commit_key/hits",
    CommitKeyMisses => "cache/commit_key/misses",
    UpdKeyHits => "cache/updkey/hits",
    UpdKeyMisses => "cache/updkey/misses",
    UpdKeyEvictions => "cache/updkey/evictions",
    VBasesHits => "cache/vbases/hits",
    VBasesMisses => "cache/vbases/misses",
    VBasesEvictions => "cache/vbases/evictions",
    ProvKeyHits => "cache/provkey/hits",
    ProvKeyMisses => "cache/provkey/misses",
    ProvKeyEvictions => "cache/provkey/evictions",
    MsmTableHits => "msm/table_hits",
    MsmBatchAddSweeps => "msm/batch_add_sweeps",
    ArenaBytesReused => "arena/bytes_reused",
    RejectWireDecode => "reject/wire_decode",
    RejectVersionUnsupported => "reject/version_unsupported",
    RejectShape => "reject/shape",
    RejectTranscriptBinding => "reject/transcript_binding",
    RejectSumcheck => "reject/sumcheck",
    RejectOpening => "reject/opening",
    RejectValidity => "reject/validity",
    RejectBooleanity => "reject/booleanity",
    RejectChainRelation => "reject/chain_relation",
    RejectProvenanceSelection => "reject/provenance_selection",
    RejectRootMismatch => "reject/root_mismatch",
    RejectMsmFinalCheck => "reject/msm_final_check",
    ServeFrames => "serve/frames",
    ServeBatches => "serve/batches",
    ServeCoalesced => "serve/coalesced",
    ServeOverload => "serve/overload",
}

static COUNTERS: [AtomicU64; Counter::COUNT] = [const { AtomicU64::new(0) }; Counter::COUNT];

/// Add `n` to a counter. No-op (one relaxed load) while disabled.
#[inline]
pub fn count(c: Counter, n: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Current value of one counter.
pub fn counter_value(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Snapshot of all counters, indexed by `Counter as usize`. Subtract two
/// snapshots to attribute events to a region (see [`bench`]).
pub fn counters_snapshot() -> [u64; Counter::COUNT] {
    let mut out = [0u64; Counter::COUNT];
    for (slot, c) in out.iter_mut().zip(COUNTERS.iter()) {
        *slot = c.load(Ordering::Relaxed);
    }
    out
}

/// Difference of one counter between two [`counters_snapshot`]s.
pub fn snapshot_delta(
    after: &[u64; Counter::COUNT],
    before: &[u64; Counter::COUNT],
    c: Counter,
) -> u64 {
    after[c as usize].saturating_sub(before[c as usize])
}

// ---------------------------------------------------------------------------
// span tree
// ---------------------------------------------------------------------------

/// One node of a (merged) span tree: a named scope with accumulated wall
/// time, a call count, and child scopes in first-seen order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpanNode {
    pub name: String,
    pub total_ns: u64,
    pub calls: u64,
    pub children: Vec<SpanNode>,
}

impl SpanNode {
    /// Accumulated time in milliseconds.
    pub fn total_ms(&self) -> f64 {
        self.total_ns as f64 / 1e6
    }

    /// Merge another tree into this one: children match by name (order is
    /// first-seen), times and call counts add.
    pub fn merge_from(&mut self, other: &SpanNode) {
        self.total_ns += other.total_ns;
        self.calls += other.calls;
        for oc in &other.children {
            match self.children.iter_mut().find(|c| c.name == oc.name) {
                Some(c) => c.merge_from(oc),
                None => self.children.push(oc.clone()),
            }
        }
    }

    /// Find a descendant by slash-free name anywhere in the tree
    /// (depth-first; used by tests).
    pub fn find(&self, name: &str) -> Option<&SpanNode> {
        if self.name == name {
            return Some(self);
        }
        self.children.iter().find_map(|c| c.find(name))
    }

    /// Top-level phase breakdown `(name, ms)`: descends through single-child
    /// wrapper levels (e.g. the lone `zkdl/prove_step` root) and returns the
    /// first level with siblings — the interesting phase split.
    pub fn phase_breakdown(&self) -> Vec<(String, f64)> {
        let mut node = self;
        while node.children.len() == 1 {
            node = &node.children[0];
        }
        node.children
            .iter()
            .map(|c| (c.name.clone(), c.total_ms()))
            .collect()
    }

    fn is_empty(&self) -> bool {
        self.total_ns == 0 && self.calls == 0 && self.children.is_empty()
    }

    fn render_into(&self, depth: usize, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(
            out,
            "{:indent$}{:<width$} {:>10}  x{}",
            "",
            self.name,
            crate::util::bench::fmt_dur(std::time::Duration::from_nanos(self.total_ns)),
            self.calls,
            indent = depth * 2,
            width = 36usize.saturating_sub(depth * 2),
        );
        for c in &self.children {
            c.render_into(depth + 1, out);
        }
    }

    /// JSON encoding: `{"name":..,"total_ns":..,"calls":..,"children":[..]}`.
    pub fn to_json(&self) -> json::Json {
        json::Json::obj(vec![
            ("name", json::Json::str(&self.name)),
            ("total_ns", json::Json::Uint(self.total_ns)),
            ("calls", json::Json::Uint(self.calls)),
            (
                "children",
                json::Json::Arr(self.children.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }
}

/// Per-thread span arena. Index 0 is the synthetic root; `stack` holds the
/// path of currently-open spans (root at the bottom).
struct LocalTree {
    nodes: Vec<RawNode>,
    stack: Vec<usize>,
}

struct RawNode {
    name: &'static str,
    total_ns: u64,
    calls: u64,
    children: Vec<usize>,
}

impl Default for LocalTree {
    fn default() -> Self {
        LocalTree {
            nodes: vec![RawNode {
                name: "",
                total_ns: 0,
                calls: 0,
                children: Vec::new(),
            }],
            stack: vec![0],
        }
    }
}

impl LocalTree {
    fn enter(&mut self, name: &'static str) -> usize {
        let parent = *self.stack.last().expect("span stack never empty");
        let found = self.nodes[parent]
            .children
            .iter()
            .copied()
            .find(|&c| self.nodes[c].name == name);
        let idx = match found {
            Some(i) => i,
            None => {
                let i = self.nodes.len();
                self.nodes.push(RawNode {
                    name,
                    total_ns: 0,
                    calls: 0,
                    children: Vec::new(),
                });
                self.nodes[parent].children.push(i);
                i
            }
        };
        self.stack.push(idx);
        idx
    }

    fn exit(&mut self, idx: usize, elapsed_ns: u64) {
        // Guards drop in strict LIFO order within a thread (SpanGuard is
        // !Send), so the top of the stack is the span being closed — unless
        // the tree was swapped by `isolate` under an open span, which the
        // isolate contract forbids.
        debug_assert_eq!(self.stack.last().copied(), Some(idx), "span close out of order");
        if self.stack.last().copied() == Some(idx) {
            self.stack.pop();
        }
        let n = &mut self.nodes[idx];
        n.total_ns += elapsed_ns;
        n.calls += 1;
    }

    fn to_node(&self) -> SpanNode {
        self.build(0)
    }

    fn build(&self, idx: usize) -> SpanNode {
        let raw = &self.nodes[idx];
        SpanNode {
            name: raw.name.to_string(),
            total_ns: raw.total_ns,
            calls: raw.calls,
            children: raw.children.iter().map(|&c| self.build(c)).collect(),
        }
    }

    fn clear(&mut self) {
        *self = LocalTree::default();
    }
}

/// TLS cell whose `Drop` (thread exit) merges the thread's tree into the
/// global one — how pipeline-worker spans reach the final report.
struct LocalCell(RefCell<LocalTree>);

impl Drop for LocalCell {
    fn drop(&mut self) {
        let node = self.0.borrow().to_node();
        if !node.is_empty() {
            global_spans().merge_from(&node);
        }
    }
}

thread_local! {
    static LOCAL: LocalCell = LocalCell(RefCell::new(LocalTree::default()));
}

static GLOBAL_SPANS: Mutex<Option<SpanNode>> = Mutex::new(None);

fn global_spans() -> impl std::ops::DerefMut<Target = SpanNode> {
    struct Guard<'a>(std::sync::MutexGuard<'a, Option<SpanNode>>);
    impl std::ops::Deref for Guard<'_> {
        type Target = SpanNode;
        fn deref(&self) -> &SpanNode {
            self.0.as_ref().expect("initialized in global_spans")
        }
    }
    impl std::ops::DerefMut for Guard<'_> {
        fn deref_mut(&mut self) -> &mut SpanNode {
            self.0.as_mut().expect("initialized in global_spans")
        }
    }
    let mut g = GLOBAL_SPANS.lock().unwrap_or_else(|p| p.into_inner());
    if g.is_none() {
        *g = Some(SpanNode::default());
    }
    Guard(g)
}

/// An open span; closing (drop) adds the elapsed time to the thread's tree.
/// `!Send` by construction: spans time a scope on the thread that opened it.
pub struct SpanGuard {
    start: Instant,
    idx: usize,
    name: &'static str,
    /// Whether [`trace_export`] buffered a `B` event for this span — the
    /// matching `E` is pushed iff it did, keeping pairs balanced across
    /// recording toggles.
    traced: bool,
    _not_send: PhantomData<*const ()>,
}

impl SpanGuard {
    /// Open a span under the thread's innermost open span. Prefer the
    /// [`crate::span!`] macro (which checks [`enabled`] first) or
    /// [`maybe_span`] for explicit-drop phase timing.
    pub fn enter(name: &'static str) -> SpanGuard {
        let idx = LOCAL.with(|l| l.0.borrow_mut().enter(name));
        let traced = trace_export::on_enter(name);
        SpanGuard {
            start: Instant::now(),
            idx,
            name,
            traced,
            _not_send: PhantomData,
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as u64;
        if self.traced {
            trace_export::on_exit(self.name);
        }
        // try_with: the TLS cell may already be gone during thread teardown.
        let _ = LOCAL.try_with(|l| l.0.borrow_mut().exit(self.idx, ns));
    }
}

/// `Some(open span)` while enabled, `None` (free) otherwise. For sequential
/// phases inside one function, bind and `drop()` explicitly:
///
/// ```ignore
/// let g = telemetry::maybe_span("aggregate/openings");
/// /* ... phase work ... */
/// drop(g);
/// ```
#[inline]
pub fn maybe_span(name: &'static str) -> Option<SpanGuard> {
    if enabled() {
        Some(SpanGuard::enter(name))
    } else {
        None
    }
}

/// Run `f` inside a span. The disabled path is one relaxed load plus the
/// call.
#[inline]
pub fn timed<T>(name: &'static str, f: impl FnOnce() -> T) -> T {
    let _g = maybe_span(name);
    f()
}

/// Open a span for the rest of the enclosing scope:
/// `crate::span!("module/operation")`. Expands to a `let` binding, so it
/// times from the macro to the end of the surrounding block. Disabled cost:
/// one relaxed atomic load.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        let _zkobs_span_guard = $crate::telemetry::maybe_span($name);
    };
}

/// Run `f` with a fresh span tree and return `(f(), tree)` — the per-call
/// phase breakdown used by the coordinator's `StepMetrics`. The captured
/// tree is also merged back into the thread's tree so the global report
/// still sees it. Must not be called under an open span (the swap would
/// orphan it); returns an empty tree while disabled.
pub fn isolate<T>(f: impl FnOnce() -> T) -> (T, SpanNode) {
    if !enabled() {
        return (f(), SpanNode::default());
    }
    let saved = LOCAL.with(|l| {
        let mut t = l.0.borrow_mut();
        debug_assert_eq!(t.stack.len(), 1, "telemetry::isolate under an open span");
        std::mem::take(&mut *t)
    });
    let out = f();
    let fresh = LOCAL.with(|l| std::mem::replace(&mut *l.0.borrow_mut(), saved));
    let node = fresh.to_node();
    LOCAL.with(|l| {
        let mut t = l.0.borrow_mut();
        let merged = {
            let mut cur = t.to_node();
            cur.merge_from(&node);
            cur
        };
        t.clear();
        rebuild_local(&mut t, &merged, 0);
    });
    (out, node)
}

/// Rebuild a LocalTree arena from a SpanNode tree (names are interned via
/// the static counter/span name set — SpanNode names always originate from
/// `&'static str` span sites, so leak-free re-interning just matches them).
fn rebuild_local(tree: &mut LocalTree, node: &SpanNode, idx: usize) {
    tree.nodes[idx].total_ns = node.total_ns;
    tree.nodes[idx].calls = node.calls;
    for child in &node.children {
        let ci = tree.nodes.len();
        tree.nodes.push(RawNode {
            name: intern(&child.name),
            total_ns: 0,
            calls: 0,
            children: Vec::new(),
        });
        tree.nodes[idx].children.push(ci);
        rebuild_local(tree, child, ci);
    }
}

/// Map a span name back to a `&'static str`. Span sites only ever use
/// literal names, so a leaked copy per *distinct* name is bounded by the
/// number of span sites in the binary.
fn intern(name: &str) -> &'static str {
    static INTERNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    let mut v = INTERNED.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(s) = v.iter().find(|s| **s == name) {
        return s;
    }
    let s: &'static str = Box::leak(name.to_string().into_boxed_str());
    v.push(s);
    s
}

// ---------------------------------------------------------------------------
// report
// ---------------------------------------------------------------------------

/// A merged view of everything recorded: the global span tree (exited
/// threads) plus the calling thread's live tree, and all counters.
pub struct Report {
    pub spans: SpanNode,
    /// `(name, value)` for every counter, including zeros (JSON emits all;
    /// the rendered table shows nonzero rows only).
    pub counters: Vec<(&'static str, u64)>,
    /// `(name, digest)` for every histogram with at least one sample
    /// (latency/size percentiles; see [`hist`]).
    pub hists: Vec<(&'static str, hist::HistSummary)>,
}

/// Snapshot the current telemetry state. Threads that have exited are
/// already merged; the calling thread's tree is folded in here.
pub fn report() -> Report {
    let mut spans = global_spans().clone();
    let local = LOCAL.with(|l| l.0.borrow().to_node());
    spans.merge_from(&local);
    let counters = (0..Counter::COUNT)
        .map(|i| (COUNTER_NAMES[i], COUNTERS[i].load(Ordering::Relaxed)))
        .collect();
    Report {
        spans,
        counters,
        hists: hist::summaries(),
    }
}

/// Clear counters, histograms, the global span tree, and the calling
/// thread's tree. Other threads' live trees are untouched (they merge at
/// exit).
pub fn reset() {
    for c in COUNTERS.iter() {
        c.store(0, Ordering::Relaxed);
    }
    hist::reset_all();
    *global_spans() = SpanNode::default();
    LOCAL.with(|l| l.0.borrow_mut().clear());
}

impl Report {
    /// Human-readable profile: span tree then nonzero counters, using the
    /// same fixed-width table as the benches.
    pub fn render(&self) -> String {
        let mut out = String::from("=== zkObs profile ===\n");
        if self.spans.children.is_empty() {
            out.push_str("(no spans recorded)\n");
        } else {
            out.push_str("-- spans --\n");
            for c in &self.spans.children {
                c.render_into(0, &mut out);
            }
        }
        let nonzero: Vec<_> = self.counters.iter().filter(|(_, v)| *v > 0).collect();
        if !nonzero.is_empty() {
            out.push_str("-- counters --\n");
            let mut table = crate::util::bench::Table::new(&["counter", "value"]);
            for (name, v) in nonzero {
                table.row(vec![name.to_string(), v.to_string()]);
            }
            out.push_str(&table.render());
        }
        if !self.hists.is_empty() {
            out.push_str("-- histograms --\n");
            let mut table =
                crate::util::bench::Table::new(&["hist", "count", "p50", "p95", "p99", "max"]);
            for (name, s) in &self.hists {
                table.row(vec![
                    name.to_string(),
                    s.count.to_string(),
                    s.p50.to_string(),
                    s.p95.to_string(),
                    s.p99.to_string(),
                    s.max.to_string(),
                ]);
            }
            out.push_str(&table.render());
        }
        out
    }

    /// Machine-readable profile:
    /// `{"spans": <tree>, "counters": {name: n}, "hists": {name: digest}}`.
    pub fn to_json(&self) -> json::Json {
        json::Json::obj(vec![
            ("spans", self.spans.to_json()),
            (
                "counters",
                json::Json::Obj(
                    self.counters
                        .iter()
                        .map(|(n, v)| (n.to_string(), json::Json::Uint(*v)))
                        .collect(),
                ),
            ),
            (
                "hists",
                json::Json::Obj(
                    self.hists
                        .iter()
                        .map(|(n, s)| (n.to_string(), s.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// exclusive / capture
// ---------------------------------------------------------------------------

static CAPTURE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` while holding the process-wide telemetry lock. Every test or
/// tool that asserts on global counters/spans goes through here (or through
/// [`capture`], which uses it), so concurrent telemetry users serialize
/// instead of contaminating each other's numbers.
pub fn exclusive<T>(f: impl FnOnce() -> T) -> T {
    let _g = CAPTURE_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    f()
}

/// Reset, enable, run `f`, disable, and return `(f(), report)` — the whole
/// profiled-run lifecycle in one call. Used by `--profile` and by the
/// counter-accuracy tests.
pub fn capture<T>(f: impl FnOnce() -> T) -> (T, Report) {
    exclusive(|| {
        reset();
        set_enabled(true);
        let out = f();
        set_enabled(false);
        let rep = report();
        (out, rep)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_names_cover_enum() {
        assert_eq!(COUNTER_NAMES.len(), Counter::COUNT);
        assert_eq!(Counter::MsmCalls.name(), "msm/calls");
        assert_eq!(Counter::ProvKeyEvictions.name(), "cache/provkey/evictions");
        // all names unique
        for (i, a) in COUNTER_NAMES.iter().enumerate() {
            for b in COUNTER_NAMES.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn count_is_noop_while_disabled() {
        // Telemetry is off by default and only `capture` flips it on, so
        // holding the capture lock makes this race-free under parallel tests.
        exclusive(|| {
            assert!(!enabled(), "telemetry must be off by default");
            let before = counter_value(Counter::WireBytesEncoded);
            count(Counter::WireBytesEncoded, 1000);
            assert_eq!(counter_value(Counter::WireBytesEncoded), before);
        });
    }

    #[test]
    fn span_node_merge_adds_and_unions() {
        let mut a = SpanNode {
            name: "root".into(),
            total_ns: 10,
            calls: 1,
            children: vec![SpanNode {
                name: "x".into(),
                total_ns: 4,
                calls: 2,
                children: vec![],
            }],
        };
        let b = SpanNode {
            name: "root".into(),
            total_ns: 5,
            calls: 1,
            children: vec![
                SpanNode {
                    name: "x".into(),
                    total_ns: 6,
                    calls: 1,
                    children: vec![],
                },
                SpanNode {
                    name: "y".into(),
                    total_ns: 1,
                    calls: 1,
                    children: vec![],
                },
            ],
        };
        a.merge_from(&b);
        assert_eq!(a.total_ns, 15);
        assert_eq!(a.calls, 2);
        assert_eq!(a.children.len(), 2);
        assert_eq!(a.children[0].name, "x");
        assert_eq!(a.children[0].total_ns, 10);
        assert_eq!(a.children[0].calls, 3);
        assert_eq!(a.children[1].name, "y");
    }

    #[test]
    fn phase_breakdown_descends_single_child_wrappers() {
        let tree = SpanNode {
            name: "".into(),
            total_ns: 0,
            calls: 0,
            children: vec![SpanNode {
                name: "zkdl/prove_step".into(),
                total_ns: 100,
                calls: 1,
                children: vec![
                    SpanNode {
                        name: "zkdl/commit".into(),
                        total_ns: 60_000_000,
                        calls: 1,
                        children: vec![],
                    },
                    SpanNode {
                        name: "sumcheck/prove".into(),
                        total_ns: 40_000_000,
                        calls: 3,
                        children: vec![],
                    },
                ],
            }],
        };
        let phases = tree.phase_breakdown();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].0, "zkdl/commit");
        assert!((phases[0].1 - 60.0).abs() < 1e-9);
    }

    #[test]
    fn capture_builds_local_span_tree() {
        // capture serializes against other telemetry users via the lock, so
        // asserting on *this thread's* spans is race-free even if a parallel
        // test proves things (those spans land in other threads' trees or
        // under other names).
        let ((), rep) = capture(|| {
            timed("test/outer", || {
                timed("test/inner", || std::hint::black_box(3 + 4));
                timed("test/inner", || std::hint::black_box(5 + 6));
            });
        });
        let outer = rep.spans.find("test/outer").expect("outer span recorded");
        assert_eq!(outer.calls, 1);
        let inner = outer.children.iter().find(|c| c.name == "test/inner");
        let inner = inner.expect("inner nested under outer");
        assert_eq!(inner.calls, 2);
        assert!(outer.total_ns >= inner.total_ns);
    }

    #[test]
    fn isolate_returns_per_call_tree() {
        let ((), rep) = capture(|| {
            let ((), first) = isolate(|| {
                timed("test/phase_a", || std::hint::black_box(1u64 << 20));
            });
            assert_eq!(first.children.len(), 1);
            assert_eq!(first.children[0].name, "test/phase_a");
            assert_eq!(first.children[0].calls, 1);
            let ((), second) = isolate(|| {
                timed("test/phase_a", || std::hint::black_box(2u64));
                timed("test/phase_b", || std::hint::black_box(3u64));
            });
            assert_eq!(second.children.len(), 2);
            // per-call: the second tree does not include the first call
            assert_eq!(second.children[0].calls, 1);
        });
        // ...but the merged report sees both calls
        let a = rep.spans.find("test/phase_a").expect("merged back");
        assert_eq!(a.calls, 2);
        assert_eq!(rep.spans.find("test/phase_b").map(|n| n.calls), Some(1));
    }

    #[test]
    fn report_merges_exited_threads() {
        let ((), rep) = capture(|| {
            std::thread::scope(|s| {
                for _ in 0..2 {
                    s.spawn(|| {
                        timed("test/worker", || std::hint::black_box(7u64));
                    });
                }
            });
            timed("test/main", || std::hint::black_box(8u64));
        });
        let worker = rep.spans.find("test/worker").expect("worker spans merged");
        assert_eq!(worker.calls, 2);
        assert!(rep.spans.find("test/main").is_some());
    }

    #[test]
    fn render_and_json_contain_spans_and_counters() {
        let ((), rep) = capture(|| {
            timed("test/render", || count(Counter::MsmCalls, 3));
        });
        let text = rep.render();
        assert!(text.contains("zkObs profile"));
        assert!(text.contains("test/render"));
        assert!(text.contains("msm/calls"));
        let j = rep.to_json().to_string();
        let parsed = json::Json::parse(&j).expect("report JSON parses");
        let counters = parsed.get("counters").expect("counters key");
        assert!(counters.get("msm/calls").and_then(|v| v.as_u64()).unwrap() >= 3);
        assert!(parsed.get("spans").is_some());
    }
}
