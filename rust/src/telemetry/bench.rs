//! The `zkdl bench` grid runner: prove/verify wall-clock plus MSM counters
//! over the ROADMAP grid — T ∈ {1, 16, 64} steps × depth ∈ {2, 8}, with
//! plain / zkOptim-chained / zkData-provenance variants per cell — emitted
//! as a rendered table and a `BENCH_*.json` baseline file.
//!
//! Runs as library code so both the CLI verb (`zkdl bench`) and the
//! golden-schema test share one implementation. The whole grid executes
//! under [`super::exclusive`] with telemetry enabled, so counter deltas
//! around each timed region attribute MSM work to exactly one prove or
//! verify call.

use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::aggregate::{
    prove_trace, prove_trace_chained, prove_trace_provenance, verify_trace, TraceKey,
};
use crate::data::Dataset;
use crate::model::ModelConfig;
use crate::provenance::ProverDataset;
use crate::telemetry::hist::HistSummary;
use crate::telemetry::{self, json::Json, Counter};
use crate::util::bench::{fmt_dur, time_once, Table};
use crate::util::rng::Rng;
use crate::wire;
use crate::witness::native::sgd_witness_chain;

/// Schema tag written into every bench JSON file; bump on layout changes.
/// v2 added the per-cell `threads` axis (cells are keyed on
/// (variant, steps, depth, threads); a grid may measure each cell at
/// several thread counts).
pub const BENCH_SCHEMA: &str = "zkdl/bench/v2";

/// Trace variants measured per grid cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Independent per-step relations aggregated into one trace proof.
    Plain,
    /// Plain plus the zkOptim weight-update chain (needs T ≥ 2).
    Chained,
    /// Plain plus the zkData batch-provenance argument.
    Provenance,
}

impl Variant {
    pub const ALL: [Variant; 3] = [Variant::Plain, Variant::Chained, Variant::Provenance];

    pub fn name(self) -> &'static str {
        match self {
            Variant::Plain => "plain",
            Variant::Chained => "chained",
            Variant::Provenance => "provenance",
        }
    }
}

/// Grid configuration. [`GridOptions::full`] is the recorded-baseline grid
/// from the ROADMAP; [`GridOptions::quick`] is the CI smoke cell.
#[derive(Clone, Debug)]
pub struct GridOptions {
    pub steps: Vec<usize>,
    pub depths: Vec<usize>,
    pub width: usize,
    pub batch: usize,
    /// Rows in the synthetic dataset the provenance variant binds to.
    pub data_rows: usize,
    pub seed: u64,
    /// Thread-count axis: every (variant, steps, depth) cell is measured
    /// once per entry, with `ZKDL_THREADS` set to the entry for that run.
    /// `0` means "auto" (one lane per available core). When the axis
    /// contains `1`, the rendered table adds a prove-speedup column
    /// relative to the single-threaded cell.
    pub threads: Vec<usize>,
    /// Wall-clock budget for the whole grid; cells past it are skipped
    /// (recorded with a skip reason, like the paper's timeout entries).
    pub budget: Duration,
}

impl GridOptions {
    /// The full ROADMAP grid: T ∈ {1, 16, 64} × depth ∈ {2, 8}.
    pub fn full() -> Self {
        GridOptions {
            steps: vec![1, 16, 64],
            depths: vec![2, 8],
            width: 16,
            batch: 8,
            data_rows: 256,
            seed: 0xa66,
            threads: vec![0],
            budget: Duration::from_secs(3600),
        }
    }

    /// One cheap cell (T=1, depth=2) for CI smoke runs.
    pub fn quick() -> Self {
        GridOptions {
            steps: vec![1],
            depths: vec![2],
            budget: Duration::from_secs(300),
            ..GridOptions::full()
        }
    }
}

/// MSM counter deltas attributed to one case's prove and verify calls.
/// During `verify_trace` the only [`crate::curve::msm`] invocation is the
/// accumulator flush, so `verify_calls == verify_flushes` (the one-MSM
/// invariant — asserted by `tests/telemetry.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct MsmCounts {
    pub prove_calls: u64,
    pub prove_points: u64,
    pub verify_calls: u64,
    pub verify_points: u64,
    pub verify_flushes: u64,
    pub verify_equations: u64,
}

/// One measured (or skipped) grid cell × variant.
#[derive(Clone, Debug)]
pub struct BenchCase {
    pub variant: Variant,
    pub steps: usize,
    pub depth: usize,
    /// Requested thread count for this cell (`ZKDL_THREADS` during the run;
    /// `0` = auto). Part of the cell key alongside variant/steps/depth.
    pub threads: usize,
    /// `Some(reason)` if the case was not run (chained at T=1, or the grid
    /// budget was exhausted); measurements are zero in that case.
    pub skipped: Option<String>,
    pub prove_s: f64,
    pub verify_s: f64,
    /// Wire-encoded proof size ([`wire::encode_trace_proof`]).
    pub proof_bytes: u64,
    pub msm: MsmCounts,
    /// zkFlight histogram digests for the cell (`(name, summary)`), reset
    /// around each case so latency/size distributions are per-cell.
    pub hists: Vec<(&'static str, HistSummary)>,
}

/// The full grid result: options, total wall time, and every case.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub opts: GridOptions,
    pub threads: usize,
    pub wall_s: f64,
    pub cases: Vec<BenchCase>,
}

/// Run the grid. Holds the process-wide telemetry lock for the duration and
/// leaves telemetry disabled and reset afterwards — combine with `--profile`
/// on a *separate* invocation, not the same one.
pub fn run_grid(opts: &GridOptions) -> BenchReport {
    telemetry::exclusive(|| {
        telemetry::reset();
        telemetry::set_enabled(true);
        let report = run_grid_locked(opts);
        telemetry::set_enabled(false);
        telemetry::reset();
        report
    })
}

fn run_grid_locked(opts: &GridOptions) -> BenchReport {
    let start = Instant::now();
    let mut rng = Rng::seed_from_u64(opts.seed);
    let thread_axis = if opts.threads.is_empty() {
        vec![0]
    } else {
        opts.threads.clone()
    };
    // Each cell runs with ZKDL_THREADS pinned to the axis entry; the pool
    // re-reads the variable on every dispatch, so flipping it mid-process
    // retargets lane count without restarting workers. Restore the caller's
    // setting afterwards so bench doesn't leak config into later tests.
    let saved_threads = std::env::var("ZKDL_THREADS").ok();
    let mut cases = Vec::new();
    for &depth in &opts.depths {
        for &t in &opts.steps {
            let cfg = ModelConfig::new(depth, opts.width, opts.batch);
            let cell_seed = opts.seed ^ (t as u64) ^ ((depth as u64) << 32);
            let ds = Dataset::synthetic(
                opts.data_rows,
                cfg.width / 2,
                4,
                cfg.r_bits,
                cell_seed ^ 0x77,
            );
            let wits = sgd_witness_chain(cfg, &ds, t, cell_seed);
            let tk = TraceKey::setup(cfg, t);
            for &threads in &thread_axis {
                std::env::set_var("ZKDL_THREADS", threads.to_string());
                for variant in Variant::ALL {
                    let case = if variant == Variant::Chained && t < 2 {
                        skipped_case(variant, t, depth, threads, "chained trace needs T >= 2")
                    } else if start.elapsed() > opts.budget {
                        skipped_case(variant, t, depth, threads, "grid budget exhausted")
                    } else {
                        eprintln!(
                            "bench: T={t} depth={depth} threads={threads} {} ...",
                            variant.name()
                        );
                        run_case(variant, t, depth, threads, &tk, &wits, &ds, &mut rng)
                    };
                    cases.push(case);
                }
            }
        }
    }
    match saved_threads {
        Some(v) => std::env::set_var("ZKDL_THREADS", v),
        None => std::env::remove_var("ZKDL_THREADS"),
    }
    BenchReport {
        opts: opts.clone(),
        threads: crate::util::threads::num_threads(),
        wall_s: start.elapsed().as_secs_f64(),
        cases,
    }
}

fn skipped_case(
    variant: Variant,
    steps: usize,
    depth: usize,
    threads: usize,
    reason: &str,
) -> BenchCase {
    BenchCase {
        variant,
        steps,
        depth,
        threads,
        skipped: Some(reason.to_string()),
        prove_s: 0.0,
        verify_s: 0.0,
        proof_bytes: 0,
        msm: MsmCounts::default(),
        hists: Vec::new(),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_case(
    variant: Variant,
    steps: usize,
    depth: usize,
    threads: usize,
    tk: &TraceKey,
    wits: &[crate::witness::StepWitness],
    ds: &Dataset,
    rng: &mut Rng,
) -> BenchCase {
    // Key setup, witness generation, and (for provenance) the dataset
    // commitment stay outside the timed region — in deployment they are
    // amortized across many traces.
    let pd = (variant == Variant::Provenance)
        .then(|| ProverDataset::build(ds, &tk.cfg).expect("bench dataset commits"));

    crate::telemetry::hist::reset_all();
    let before_prove = telemetry::counters_snapshot();
    let (proof, prove_d) = time_once(|| match variant {
        Variant::Plain => prove_trace(tk, wits, rng),
        Variant::Chained => prove_trace_chained(tk, wits, rng).expect("bench witnesses chain"),
        Variant::Provenance => prove_trace_provenance(tk, wits, pd.as_ref().unwrap(), rng)
            .expect("bench rows open against dataset"),
    });
    let after_prove = telemetry::counters_snapshot();

    let before_verify = telemetry::counters_snapshot();
    let ((), verify_d) = time_once(|| {
        verify_trace(tk, &proof).expect("bench trace verifies");
    });
    let after_verify = telemetry::counters_snapshot();

    let proof_bytes = wire::encode_trace_proof(&tk.cfg, &proof).len() as u64;
    let delta = telemetry::snapshot_delta;
    BenchCase {
        variant,
        steps,
        depth,
        threads,
        skipped: None,
        prove_s: prove_d.as_secs_f64(),
        verify_s: verify_d.as_secs_f64(),
        proof_bytes,
        msm: MsmCounts {
            prove_calls: delta(&after_prove, &before_prove, Counter::MsmCalls),
            prove_points: delta(&after_prove, &before_prove, Counter::MsmPoints),
            verify_calls: delta(&after_verify, &before_verify, Counter::MsmCalls),
            verify_points: delta(&after_verify, &before_verify, Counter::MsmPoints),
            verify_flushes: delta(&after_verify, &before_verify, Counter::MsmFlushes),
            verify_equations: delta(&after_verify, &before_verify, Counter::MsmEquations),
        },
        hists: crate::telemetry::hist::summaries(),
    }
}

impl BenchCase {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant.name())),
            ("steps", Json::Uint(self.steps as u64)),
            ("depth", Json::Uint(self.depth as u64)),
            ("threads", Json::Uint(self.threads as u64)),
            (
                "skipped",
                match &self.skipped {
                    Some(r) => Json::str(r),
                    None => Json::Null,
                },
            ),
            ("prove_s", Json::Num(self.prove_s)),
            ("verify_s", Json::Num(self.verify_s)),
            ("proof_bytes", Json::Uint(self.proof_bytes)),
            (
                "msm",
                Json::obj(vec![
                    ("prove_calls", Json::Uint(self.msm.prove_calls)),
                    ("prove_points", Json::Uint(self.msm.prove_points)),
                    ("verify_calls", Json::Uint(self.msm.verify_calls)),
                    ("verify_points", Json::Uint(self.msm.verify_points)),
                    ("verify_flushes", Json::Uint(self.msm.verify_flushes)),
                    ("verify_equations", Json::Uint(self.msm.verify_equations)),
                ]),
            ),
            (
                "hists",
                Json::Obj(
                    self.hists
                        .iter()
                        .map(|(name, s)| (name.to_string(), s.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

impl BenchReport {
    /// The machine-readable baseline, schema [`BENCH_SCHEMA`].
    pub fn to_json(&self) -> Json {
        let created = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("created_unix", Json::Uint(created)),
            ("threads", Json::Uint(self.threads as u64)),
            (
                "config",
                Json::obj(vec![
                    ("width", Json::Uint(self.opts.width as u64)),
                    ("batch", Json::Uint(self.opts.batch as u64)),
                    ("data_rows", Json::Uint(self.opts.data_rows as u64)),
                    ("seed", Json::Uint(self.opts.seed)),
                ]),
            ),
            (
                "grid",
                Json::obj(vec![
                    (
                        "steps",
                        Json::Arr(self.opts.steps.iter().map(|&t| Json::Uint(t as u64)).collect()),
                    ),
                    (
                        "depths",
                        Json::Arr(
                            self.opts.depths.iter().map(|&d| Json::Uint(d as u64)).collect(),
                        ),
                    ),
                    (
                        "variants",
                        Json::Arr(Variant::ALL.iter().map(|v| Json::str(v.name())).collect()),
                    ),
                    (
                        "threads",
                        Json::Arr(
                            self.opts
                                .threads
                                .iter()
                                .map(|&t| Json::Uint(t as u64))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("wall_s", Json::Num(self.wall_s)),
            (
                "cases",
                Json::Arr(self.cases.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }

    /// [`Self::to_json`] serialized — what `zkdl bench` writes to disk.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Human-readable grid table (proof sizes in kB, MSM counts as
    /// `prove/verify` pairs). The `x1` column is the prove-phase speedup
    /// of each cell over the same (variant, T, depth) cell measured with
    /// `threads = 1`, when the grid's thread axis includes 1.
    pub fn render_table(&self) -> String {
        let baseline_prove = |c: &BenchCase| {
            self.cases
                .iter()
                .find(|b| {
                    b.threads == 1
                        && b.skipped.is_none()
                        && b.variant == c.variant
                        && b.steps == c.steps
                        && b.depth == c.depth
                })
                .map(|b| b.prove_s)
        };
        let mut table = Table::new(&[
            "T",
            "depth",
            "thr",
            "variant",
            "prove",
            "x1",
            "verify",
            "proof kB",
            "msm calls p/v",
            "msm points p/v",
        ]);
        for c in &self.cases {
            match &c.skipped {
                Some(reason) => table.row(vec![
                    c.steps.to_string(),
                    c.depth.to_string(),
                    fmt_threads(c.threads),
                    c.variant.name().to_string(),
                    format!("({reason})"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
                None => table.row(vec![
                    c.steps.to_string(),
                    c.depth.to_string(),
                    fmt_threads(c.threads),
                    c.variant.name().to_string(),
                    fmt_dur(Duration::from_secs_f64(c.prove_s)),
                    match baseline_prove(c) {
                        Some(base) if c.prove_s > 0.0 => format!("{:.2}x", base / c.prove_s),
                        _ => "-".into(),
                    },
                    fmt_dur(Duration::from_secs_f64(c.verify_s)),
                    format!("{:.1}", c.proof_bytes as f64 / 1024.0),
                    format!("{}/{}", c.msm.prove_calls, c.msm.verify_calls),
                    format!("{}/{}", c.msm.prove_points, c.msm.verify_points),
                ]),
            }
        }
        table.render()
    }

    /// Per-cell delta table between this (freshly measured) report and a
    /// previously recorded baseline JSON — the parsed output of
    /// [`Self::to_json_string`]. Cells are matched on (variant, steps,
    /// depth, threads). Wall-clock deltas are percentages and inherently noisy;
    /// the MSM point deltas are exact (deterministic for a given config),
    /// so a nonzero `msm pts` delta means the protocol itself changed.
    pub fn compare_table(&self, old: &Json) -> Result<String, String> {
        match old.get("schema").and_then(|v| v.as_str()) {
            Some(s) if s == BENCH_SCHEMA => {}
            Some(s) => return Err(format!("baseline schema {s:?}, expected {BENCH_SCHEMA:?}")),
            None => return Err("baseline JSON has no schema tag".into()),
        }
        let old_cases = old
            .get("cases")
            .and_then(|v| v.as_array())
            .ok_or_else(|| "baseline JSON has no cases array".to_string())?;
        let lookup = |c: &BenchCase| {
            old_cases.iter().find(|o| {
                o.get("variant").and_then(|v| v.as_str()) == Some(c.variant.name())
                    && o.get("steps").and_then(|v| v.as_u64()) == Some(c.steps as u64)
                    && o.get("depth").and_then(|v| v.as_u64()) == Some(c.depth as u64)
                    && o.get("threads").and_then(|v| v.as_u64()) == Some(c.threads as u64)
            })
        };
        let mut table = Table::new(&[
            "T",
            "depth",
            "thr",
            "variant",
            "prove old->new",
            "d%",
            "verify old->new",
            "d%",
            "msm pts d p/v",
        ]);
        for c in &self.cases {
            let mut row = vec![
                c.steps.to_string(),
                c.depth.to_string(),
                fmt_threads(c.threads),
                c.variant.name().to_string(),
            ];
            let note = |text: String| {
                let mut cells = vec![text];
                cells.extend(vec!["-".to_string(); 4]);
                cells
            };
            let base = lookup(c);
            let base_skipped = base
                .is_some_and(|b| b.get("skipped").is_some_and(|s| s.as_str().is_some()));
            match (&c.skipped, base) {
                (Some(reason), _) => row.extend(note(format!("(skipped: {reason})"))),
                (None, None) => row.extend(note("(no baseline cell)".to_string())),
                (None, Some(_)) if base_skipped => {
                    row.extend(note("(baseline skipped this cell)".to_string()))
                }
                (None, Some(b)) => {
                    let f = |key: &str| b.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let pts = |key: &str| {
                        b.get("msm")
                            .and_then(|m| m.get(key))
                            .and_then(|v| v.as_u64())
                            .unwrap_or(0)
                    };
                    row.push(fmt_old_new(f("prove_s"), c.prove_s));
                    row.push(fmt_pct(f("prove_s"), c.prove_s));
                    row.push(fmt_old_new(f("verify_s"), c.verify_s));
                    row.push(fmt_pct(f("verify_s"), c.verify_s));
                    row.push(format!(
                        "{:+}/{:+}",
                        c.msm.prove_points as i128 - pts("prove_points") as i128,
                        c.msm.verify_points as i128 - pts("verify_points") as i128,
                    ));
                }
            }
            table.row(row);
        }
        Ok(table.render())
    }
}

fn fmt_threads(threads: usize) -> String {
    if threads == 0 {
        "auto".to_string()
    } else {
        threads.to_string()
    }
}

fn fmt_old_new(old_s: f64, new_s: f64) -> String {
    format!(
        "{} -> {}",
        fmt_dur(Duration::from_secs_f64(old_s)),
        fmt_dur(Duration::from_secs_f64(new_s))
    )
}

fn fmt_pct(old_s: f64, new_s: f64) -> String {
    if old_s <= 0.0 {
        "n/a".to_string()
    } else {
        format!("{:+.1}%", (new_s - old_s) / old_s * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_names_are_stable() {
        let names: Vec<_> = Variant::ALL.iter().map(|v| v.name()).collect();
        assert_eq!(names, ["plain", "chained", "provenance"]);
    }

    #[test]
    fn grid_options_cover_roadmap() {
        let full = GridOptions::full();
        assert_eq!(full.steps, [1, 16, 64]);
        assert_eq!(full.depths, [2, 8]);
        let quick = GridOptions::quick();
        assert_eq!(quick.steps, [1]);
        assert_eq!(quick.depths, [2]);
        assert_eq!(quick.width, full.width);
        // default thread axis is a single auto cell
        assert_eq!(full.threads, [0]);
        assert_eq!(quick.threads, [0]);
    }

    #[test]
    fn report_json_has_required_schema() {
        // Hand-built report: the expensive end-to-end quick-grid run lives in
        // tests/telemetry.rs; this pins the JSON layout cheaply.
        let report = BenchReport {
            opts: GridOptions::quick(),
            threads: 1,
            wall_s: 1.25,
            cases: vec![
                BenchCase {
                    variant: Variant::Plain,
                    steps: 1,
                    depth: 2,
                    threads: 1,
                    skipped: None,
                    prove_s: 0.5,
                    verify_s: 0.25,
                    proof_bytes: 4096,
                    msm: MsmCounts {
                        prove_calls: 10,
                        prove_points: 1000,
                        verify_calls: 1,
                        verify_points: 500,
                        verify_flushes: 1,
                        verify_equations: 7,
                    },
                    hists: vec![(
                        "lat/verify_trace_ns",
                        HistSummary {
                            count: 1,
                            p50: 250_000_000,
                            p95: 250_000_000,
                            p99: 250_000_000,
                            max: 250_000_000,
                        },
                    )],
                },
                skipped_case(Variant::Chained, 1, 2, 1, "chained trace needs T >= 2"),
            ],
        };
        let parsed = Json::parse(&report.to_json_string()).expect("bench JSON parses");
        assert_eq!(
            parsed.get("schema").and_then(|v| v.as_str()),
            Some(BENCH_SCHEMA)
        );
        for key in ["created_unix", "threads", "config", "grid", "wall_s", "cases"] {
            assert!(parsed.get(key).is_some(), "missing {key}");
        }
        let grid_threads = parsed
            .get("grid")
            .and_then(|g| g.get("threads"))
            .and_then(|v| v.as_array())
            .expect("grid threads axis");
        assert_eq!(grid_threads.len(), 1);
        let cases = parsed.get("cases").unwrap().as_array().unwrap();
        assert_eq!(cases.len(), 2);
        let first = &cases[0];
        for key in [
            "variant",
            "steps",
            "depth",
            "threads",
            "skipped",
            "prove_s",
            "verify_s",
            "proof_bytes",
        ] {
            assert!(first.get(key).is_some(), "case missing {key}");
        }
        assert_eq!(first.get("threads").and_then(|v| v.as_u64()), Some(1));
        let msm = first.get("msm").expect("msm block");
        assert_eq!(msm.get("verify_calls").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(msm.get("verify_flushes").and_then(|v| v.as_u64()), Some(1));
        let hists = first.get("hists").expect("hists block");
        let vt = hists.get("lat/verify_trace_ns").expect("verify hist cell");
        assert_eq!(vt.get("p50").and_then(|v| v.as_u64()), Some(250_000_000));
        assert_eq!(vt.get("count").and_then(|v| v.as_u64()), Some(1));
        // skipped case carries its reason and zeroed measurements
        assert_eq!(
            cases[1].get("skipped").and_then(|v| v.as_str()),
            Some("chained trace needs T >= 2")
        );
        assert_eq!(cases[1].get("proof_bytes").and_then(|v| v.as_u64()), Some(0));
        let text = report.render_table();
        assert!(text.contains("plain"));
        assert!(text.contains("chained trace needs T >= 2"));
    }

    fn sample_report() -> BenchReport {
        BenchReport {
            opts: GridOptions::quick(),
            threads: 1,
            wall_s: 1.25,
            cases: vec![
                BenchCase {
                    variant: Variant::Plain,
                    steps: 1,
                    depth: 2,
                    threads: 1,
                    skipped: None,
                    prove_s: 0.5,
                    verify_s: 0.25,
                    proof_bytes: 4096,
                    msm: MsmCounts {
                        prove_calls: 10,
                        prove_points: 1000,
                        verify_calls: 1,
                        verify_points: 500,
                        verify_flushes: 1,
                        verify_equations: 7,
                    },
                    hists: Vec::new(),
                },
                skipped_case(Variant::Chained, 1, 2, 1, "chained trace needs T >= 2"),
            ],
        }
    }

    #[test]
    fn compare_table_against_self_shows_zero_deltas() {
        let report = sample_report();
        let baseline = Json::parse(&report.to_json_string()).expect("baseline parses");
        let table = report.compare_table(&baseline).expect("same-schema compare");
        // identical measurements: 0% wall-clock drift, exact-zero point deltas
        assert!(table.contains("+0.0%"), "table:\n{table}");
        assert!(table.contains("+0/+0"), "table:\n{table}");
        // the skipped case carries its reason through
        assert!(table.contains("(skipped: chained trace needs T >= 2)"));
    }

    #[test]
    fn compare_table_reports_drift_and_point_deltas() {
        let mut new = sample_report();
        new.cases[0].prove_s = 0.25; // 2x faster
        new.cases[0].msm.prove_points = 900; // -100 points (table routing)
        let baseline = Json::parse(&sample_report().to_json_string()).unwrap();
        let table = new.compare_table(&baseline).expect("compare");
        assert!(table.contains("-50.0%"), "table:\n{table}");
        assert!(table.contains("-100/+0"), "table:\n{table}");
    }

    #[test]
    fn compare_table_handles_missing_cells_and_bad_schema() {
        let mut new = sample_report();
        new.cases[0].steps = 16; // no (plain, 16, 2) cell in the baseline
        let baseline = Json::parse(&sample_report().to_json_string()).unwrap();
        let table = new.compare_table(&baseline).expect("compare");
        assert!(table.contains("(no baseline cell)"), "table:\n{table}");

        let bad = Json::obj(vec![("schema", Json::str("zkdl/other/v9"))]);
        assert!(sample_report().compare_table(&bad).is_err());
        assert!(sample_report().compare_table(&Json::Null).is_err());
    }

    #[test]
    fn render_table_reports_speedup_over_single_thread_cell() {
        let mut report = sample_report();
        let mut fast = report.cases[0].clone();
        fast.threads = 4;
        fast.prove_s = 0.125; // 4x over the threads=1 cell
        report.cases.push(fast);
        report.opts.threads = vec![1, 4];
        let table = report.render_table();
        assert!(table.contains("4.00x"), "table:\n{table}");
        // the threads=1 cell shows its trivial 1x, auto renders as "auto"
        assert!(table.contains("1.00x"), "table:\n{table}");
    }

    #[test]
    fn compare_table_keys_cells_on_thread_count() {
        // new report measured at threads=4; baseline only has threads=1
        let mut new = sample_report();
        new.cases[0].threads = 4;
        let baseline = Json::parse(&sample_report().to_json_string()).unwrap();
        let table = new.compare_table(&baseline).expect("compare");
        assert!(table.contains("(no baseline cell)"), "table:\n{table}");
    }
}
