//! zkFlight event journal — an append-only JSONL flight recorder.
//!
//! Every CLI invocation that touches a proof artifact (`prove`,
//! `prove-trace`, `verify-trace`, batched verification) appends one record
//! **per artifact** to the journal file named by `--journal <path>`:
//! schema [`EVENT_SCHEMA`], a monotonically increasing `seq` (continued
//! across processes by re-scanning the file on open), wall-clock duration,
//! the verb, the wire version, artifact byte-length and SHA-256 digest, the
//! update-rule tag, the dataset root when provenance is on, the outcome
//! (`proved` / `accepted` / `rejected`), the typed failure class on
//! rejection, and a snapshot of nonzero counter deltas for the invocation.
//!
//! Batch records share the invocation-wide duration and counter delta
//! (attribution below one MSM is not separable) and carry `batch_index` /
//! `batch_size` so `zkdl audit` can regroup them.
//!
//! The journal is plain JSONL on purpose: `tail -f`-able, greppable, and
//! parseable by `python/check_obs_artifacts.py` without any dependency.

use crate::telemetry::json::Json;
use crate::telemetry::{Counter, COUNTER_NAMES};
use anyhow::{Context, Result};
use sha2::{Digest, Sha256};
use std::io::{BufRead as _, Write as _};
use std::path::{Path, PathBuf};

/// Schema tag stamped on every record.
pub const EVENT_SCHEMA: &str = "zkdl/events/v1";

/// One journal record. Optional fields serialize as JSON `null` so every
/// record carries the full schema (simplifies external validators).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JournalEvent {
    /// Assigned by [`Journal::append`]; strictly increasing per file.
    pub seq: u64,
    pub ts_unix: u64,
    pub verb: String,
    /// `"proved"`, `"accepted"`, or `"rejected"`.
    pub outcome: String,
    pub duration_s: f64,
    pub wire_version: u64,
    pub artifact_bytes: u64,
    /// Hex SHA-256 of the wire bytes; `None` when no artifact was written
    /// or read (e.g. an in-memory verify).
    pub artifact_sha256: Option<String>,
    /// Update-rule tag (`"sgd"`, `"momentum"`) for chained artifacts.
    pub rule: Option<String>,
    /// Hex dataset root for provenance artifacts.
    pub dataset_root: Option<String>,
    /// Kebab-case [`VerifyFailureClass`](super::failure::VerifyFailureClass)
    /// name; set iff `outcome == "rejected"`.
    pub failure_class: Option<String>,
    pub batch_index: Option<u64>,
    pub batch_size: Option<u64>,
    /// Nonzero counter deltas attributed to the invocation.
    pub counters: Vec<(String, u64)>,
}

/// Hex SHA-256 of an artifact's wire bytes.
pub fn artifact_digest(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    h.finalize().iter().map(|b| format!("{b:02x}")).collect()
}

/// Nonzero counter deltas between two [`super::counters_snapshot`]s, in
/// counter order.
pub fn counter_deltas(
    after: &[u64; Counter::COUNT],
    before: &[u64; Counter::COUNT],
) -> Vec<(String, u64)> {
    (0..Counter::COUNT)
        .filter_map(|i| {
            let d = after[i].saturating_sub(before[i]);
            (d > 0).then(|| (COUNTER_NAMES[i].to_string(), d))
        })
        .collect()
}

fn opt_str(v: &Option<String>) -> Json {
    match v {
        Some(s) => Json::str(s),
        None => Json::Null,
    }
}

fn opt_uint(v: &Option<u64>) -> Json {
    match v {
        Some(n) => Json::Uint(*n),
        None => Json::Null,
    }
}

impl JournalEvent {
    /// A record skeleton stamped with the current wall-clock time.
    pub fn new(verb: &str, outcome: &str) -> JournalEvent {
        let ts_unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        JournalEvent {
            ts_unix,
            verb: verb.to_string(),
            outcome: outcome.to_string(),
            ..JournalEvent::default()
        }
    }

    /// One JSONL record, schema [`EVENT_SCHEMA`]. Every key is always
    /// present (optionals as `null`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(EVENT_SCHEMA)),
            ("seq", Json::Uint(self.seq)),
            ("ts_unix", Json::Uint(self.ts_unix)),
            ("verb", Json::str(&self.verb)),
            ("outcome", Json::str(&self.outcome)),
            ("duration_s", Json::Num(self.duration_s)),
            ("wire_version", Json::Uint(self.wire_version)),
            ("artifact_bytes", Json::Uint(self.artifact_bytes)),
            ("artifact_sha256", opt_str(&self.artifact_sha256)),
            ("rule", opt_str(&self.rule)),
            ("dataset_root", opt_str(&self.dataset_root)),
            ("failure_class", opt_str(&self.failure_class)),
            ("batch_index", opt_uint(&self.batch_index)),
            ("batch_size", opt_uint(&self.batch_size)),
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(n, v)| (n.clone(), Json::Uint(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse one record (the audit verb's reader). Rejects wrong schemas.
    pub fn from_json(j: &Json) -> Result<JournalEvent> {
        let schema = j
            .get("schema")
            .and_then(|v| v.as_str())
            .context("journal record has no schema")?;
        anyhow::ensure!(
            schema == EVENT_SCHEMA,
            "unsupported journal schema {schema:?} (want {EVENT_SCHEMA})"
        );
        let req_u64 = |key: &str| -> Result<u64> {
            j.get(key)
                .and_then(|v| v.as_u64())
                .with_context(|| format!("journal record missing {key}"))
        };
        let req_str = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .with_context(|| format!("journal record missing {key}"))
        };
        let opt_string = |key: &str| j.get(key).and_then(|v| v.as_str()).map(|s| s.to_string());
        let opt_u64 = |key: &str| j.get(key).and_then(|v| v.as_u64());
        let counters = match j.get("counters") {
            Some(Json::Obj(pairs)) => pairs
                .iter()
                .filter_map(|(n, v)| v.as_u64().map(|v| (n.clone(), v)))
                .collect(),
            _ => Vec::new(),
        };
        Ok(JournalEvent {
            seq: req_u64("seq")?,
            ts_unix: req_u64("ts_unix")?,
            verb: req_str("verb")?,
            outcome: req_str("outcome")?,
            duration_s: j
                .get("duration_s")
                .and_then(|v| v.as_f64())
                .context("journal record missing duration_s")?,
            wire_version: req_u64("wire_version")?,
            artifact_bytes: req_u64("artifact_bytes")?,
            artifact_sha256: opt_string("artifact_sha256"),
            rule: opt_string("rule"),
            dataset_root: opt_string("dataset_root"),
            failure_class: opt_string("failure_class"),
            batch_index: opt_u64("batch_index"),
            batch_size: opt_u64("batch_size"),
            counters,
        })
    }
}

/// An open journal file: append-only, with `seq` continued from the
/// existing contents so restarts never rewind the sequence.
pub struct Journal {
    path: PathBuf,
    next_seq: u64,
}

impl Journal {
    /// Open (or create) a journal, scanning existing records for the
    /// largest `seq`. Unparseable lines are ignored for seq-recovery (the
    /// audit verb reports them instead).
    pub fn open(path: &Path) -> Result<Journal> {
        let mut next_seq = 0;
        if path.exists() {
            // streamed line-by-line: a long-lived daemon's journal can be
            // arbitrarily large, and seq recovery must not load it whole
            let f = std::fs::File::open(path)
                .with_context(|| format!("reading journal {}", path.display()))?;
            for line in std::io::BufReader::new(f).lines() {
                let line = line.with_context(|| format!("reading journal {}", path.display()))?;
                if line.trim().is_empty() {
                    continue;
                }
                if let Some(seq) = Json::parse(&line)
                    .ok()
                    .and_then(|j| j.get("seq").and_then(|v| v.as_u64()))
                {
                    next_seq = next_seq.max(seq + 1);
                }
            }
        }
        Ok(Journal {
            path: path.to_path_buf(),
            next_seq,
        })
    }

    /// Assign the next `seq` and append one JSONL record. `ts_unix` is
    /// re-stamped here: records can be *built* concurrently (zkServe
    /// handlers + collector), and stamping at the single append point keeps
    /// the journal's timestamps non-decreasing in file order — the
    /// invariant `check_obs_artifacts.py` enforces.
    pub fn append(&mut self, mut event: JournalEvent) -> Result<()> {
        event.seq = self.next_seq;
        event.ts_unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(event.ts_unix);
        self.next_seq += 1;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening journal {}", self.path.display()))?;
        writeln!(f, "{}", event.to_json().to_string())
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        Ok(())
    }
}

/// Parse a whole journal file into records (the audit verb's loader).
/// Returns `(events, bad_lines)` — malformed lines are counted, not fatal.
pub fn read_journal(path: &Path) -> Result<(Vec<JournalEvent>, usize)> {
    read_journal_since(path, 0)
}

/// Like [`read_journal`], but streams the file line by line and retains
/// only records with `seq >= since` — the audit `--since` filter on a
/// long-lived daemon journal never materializes the skipped prefix.
pub fn read_journal_since(path: &Path, since: u64) -> Result<(Vec<JournalEvent>, usize)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    let mut events = Vec::new();
    let mut bad = 0;
    for line in std::io::BufReader::new(f).lines() {
        let line = line.with_context(|| format!("reading journal {}", path.display()))?;
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(&line)
            .map_err(anyhow::Error::msg)
            .and_then(|j| JournalEvent::from_json(&j))
        {
            Ok(ev) => {
                if ev.seq >= since {
                    events.push(ev);
                }
            }
            Err(_) => bad += 1,
        }
    }
    Ok((events, bad))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_roundtrips() {
        let mut ev = JournalEvent::new("verify-trace", "rejected");
        ev.seq = 3;
        ev.duration_s = 0.125;
        ev.wire_version = 6;
        ev.artifact_bytes = 4096;
        ev.artifact_sha256 = Some("ab".repeat(32));
        ev.rule = Some("sgd".into());
        ev.dataset_root = Some("cd".repeat(32));
        ev.failure_class = Some("sumcheck".into());
        ev.batch_index = Some(1);
        ev.batch_size = Some(2);
        ev.counters = vec![("msm/calls".into(), 1), ("msm/points".into(), 512)];
        let line = ev.to_json().to_string();
        let back = JournalEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, ev);
        // optionals serialize as null but parse back to None
        let plain = JournalEvent::new("prove-trace", "proved");
        let line = plain.to_json().to_string();
        assert!(line.contains("\"failure_class\":null"), "{line}");
        let back = JournalEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.failure_class, None);
        assert_eq!(back.batch_index, None);
    }

    #[test]
    fn from_json_rejects_wrong_schema() {
        let j = Json::parse(r#"{"schema":"zkdl/events/v999","seq":0}"#).unwrap();
        assert!(JournalEvent::from_json(&j).is_err());
        let j = Json::parse(r#"{"seq":0}"#).unwrap();
        assert!(JournalEvent::from_json(&j).is_err());
    }

    #[test]
    fn digest_is_stable_sha256() {
        // sha256("abc")
        assert_eq!(
            artifact_digest(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn counter_deltas_keep_nonzero_only() {
        let before = [0u64; Counter::COUNT];
        let mut after = [0u64; Counter::COUNT];
        after[Counter::MsmCalls as usize] = 2;
        after[Counter::WireBytesDecoded as usize] = 100;
        let d = counter_deltas(&after, &before);
        assert_eq!(
            d,
            vec![
                ("msm/calls".to_string(), 2),
                ("wire/bytes_decoded".to_string(), 100)
            ]
        );
    }
}
