//! zkFlight failure taxonomy — typed verification-failure classes.
//!
//! Every verifier rejection is attributed to the *check that failed*, not
//! just an opaque string: a [`VerifyFailureClass`] is attached to the
//! `anyhow` error chain via `Context` at the phase boundary where the check
//! lives, and recovered later by [`failure_class`] (an anyhow-native
//! downcast — `err.chain()` cannot see context values, only
//! `anyhow::Error::downcast_ref` walks the context layers).
//!
//! Attachment discipline: a class is attached **at most once** per error.
//! [`Classify::classify`] and [`classified`] both leave an already-classified
//! error untouched, so an inner, more specific class (e.g. `Booleanity`
//! raised inside the provenance phase) wins over the outer phase-level class
//! (`ProvenanceSelection`). Each attachment bumps the matching `reject/…`
//! counter exactly once (gated on [`crate::telemetry::enabled`], like every
//! other counter).
//!
//! The phase → class mapping is documented in DESIGN.md §telemetry; the
//! tamper suites in `rust/tests/` pin one deterministic tamper per class.

use crate::telemetry::{count, Counter};
use std::fmt;

/// Which verifier check rejected an artifact. Display/parse use stable
/// kebab-case names (`"sumcheck"`, `"msm-final-check"`, …) — the strings
/// that appear in journals, audit filters, and reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum VerifyFailureClass {
    /// Artifact bytes failed structural decoding (bad magic, truncation,
    /// malformed payload shapes caught by the decoder).
    WireDecode,
    /// Envelope version is not the verifier's wire version.
    VersionUnsupported,
    /// Proof-shape invariant violated (lengths, counts, missing/unexpected
    /// sub-proofs) before any cryptographic check ran.
    Shape,
    /// Scalar claims disagree with transcript-bound values (factor-eval or
    /// slot-claim cross-checks, stack final-claim mismatch).
    TranscriptBinding,
    /// A sumcheck round failed (wrong degree, round-consistency, count).
    Sumcheck,
    /// A batched IPA opening failed one of its scalar-side checks.
    Opening,
    /// The zkReLU validity/range argument rejected.
    Validity,
    /// The selection-booleanity instance (zkData) rejected.
    Booleanity,
    /// The zkOptim update-chain relation rejected.
    ChainRelation,
    /// The zkData batch-provenance selection argument rejected.
    ProvenanceSelection,
    /// Dataset root differs from the endorsed/pinned root (`--expect-root`,
    /// `--require-same-root`).
    RootMismatch,
    /// All scalar checks passed but the single deferred MSM equation did
    /// not close (tampered group elements or blinds).
    MsmFinalCheck,
}

/// Every class, in enum order (drives audit summaries and tests).
pub const ALL_CLASSES: &[VerifyFailureClass] = &[
    VerifyFailureClass::WireDecode,
    VerifyFailureClass::VersionUnsupported,
    VerifyFailureClass::Shape,
    VerifyFailureClass::TranscriptBinding,
    VerifyFailureClass::Sumcheck,
    VerifyFailureClass::Opening,
    VerifyFailureClass::Validity,
    VerifyFailureClass::Booleanity,
    VerifyFailureClass::ChainRelation,
    VerifyFailureClass::ProvenanceSelection,
    VerifyFailureClass::RootMismatch,
    VerifyFailureClass::MsmFinalCheck,
];

impl VerifyFailureClass {
    /// Stable kebab-case name (journal/audit/report string).
    pub fn name(self) -> &'static str {
        match self {
            VerifyFailureClass::WireDecode => "wire-decode",
            VerifyFailureClass::VersionUnsupported => "version-unsupported",
            VerifyFailureClass::Shape => "shape",
            VerifyFailureClass::TranscriptBinding => "transcript-binding",
            VerifyFailureClass::Sumcheck => "sumcheck",
            VerifyFailureClass::Opening => "opening",
            VerifyFailureClass::Validity => "validity",
            VerifyFailureClass::Booleanity => "booleanity",
            VerifyFailureClass::ChainRelation => "chain-relation",
            VerifyFailureClass::ProvenanceSelection => "provenance-selection",
            VerifyFailureClass::RootMismatch => "root-mismatch",
            VerifyFailureClass::MsmFinalCheck => "msm-final-check",
        }
    }

    /// Inverse of [`name`](Self::name) (audit `--class` filter).
    pub fn parse(s: &str) -> Option<VerifyFailureClass> {
        ALL_CLASSES.iter().copied().find(|c| c.name() == s)
    }

    /// The `reject/…` counter bumped when this class is attached.
    pub fn counter(self) -> Counter {
        match self {
            VerifyFailureClass::WireDecode => Counter::RejectWireDecode,
            VerifyFailureClass::VersionUnsupported => Counter::RejectVersionUnsupported,
            VerifyFailureClass::Shape => Counter::RejectShape,
            VerifyFailureClass::TranscriptBinding => Counter::RejectTranscriptBinding,
            VerifyFailureClass::Sumcheck => Counter::RejectSumcheck,
            VerifyFailureClass::Opening => Counter::RejectOpening,
            VerifyFailureClass::Validity => Counter::RejectValidity,
            VerifyFailureClass::Booleanity => Counter::RejectBooleanity,
            VerifyFailureClass::ChainRelation => Counter::RejectChainRelation,
            VerifyFailureClass::ProvenanceSelection => Counter::RejectProvenanceSelection,
            VerifyFailureClass::RootMismatch => Counter::RejectRootMismatch,
            VerifyFailureClass::MsmFinalCheck => Counter::RejectMsmFinalCheck,
        }
    }
}

impl fmt::Display for VerifyFailureClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The class attached to `err`, if any. Walks anyhow's context layers and
/// returns the outermost match — which, under the attach-once discipline,
/// is the only one.
pub fn failure_class(err: &anyhow::Error) -> Option<VerifyFailureClass> {
    err.downcast_ref::<VerifyFailureClass>().copied()
}

/// Attach `class` to `err` unless it already carries a class (the inner,
/// more specific attribution wins). Bumps the class's `reject/…` counter on
/// attach.
pub fn classified(class: VerifyFailureClass, err: anyhow::Error) -> anyhow::Error {
    if failure_class(&err).is_some() {
        return err;
    }
    count(class.counter(), 1);
    err.context(class)
}

/// `Result` adapter for phase-boundary classification:
/// `sumcheck::verify(..).classify(Sumcheck).context("mm30")?`.
pub trait Classify<T> {
    fn classify(self, class: VerifyFailureClass) -> anyhow::Result<T>;
}

impl<T> Classify<T> for anyhow::Result<T> {
    fn classify(self, class: VerifyFailureClass) -> anyhow::Result<T> {
        self.map_err(|e| classified(class, e))
    }
}

/// `ensure!` with a failure class: early-returns a classified error when
/// the condition is false, keeping the message format of plain `ensure!`.
#[macro_export]
macro_rules! ensure_class {
    ($cond:expr, $class:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::telemetry::failure::classified(
                $class,
                anyhow::anyhow!($($arg)+),
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_and_are_unique() {
        for &c in ALL_CLASSES {
            assert_eq!(VerifyFailureClass::parse(c.name()), Some(c));
        }
        for (i, a) in ALL_CLASSES.iter().enumerate() {
            for b in ALL_CLASSES.iter().skip(i + 1) {
                assert_ne!(a.name(), b.name());
                assert_ne!(a.counter(), b.counter());
            }
        }
        assert_eq!(VerifyFailureClass::parse("no-such-class"), None);
    }

    #[test]
    fn downcast_recovers_class_through_context_layers() {
        let err = classified(
            VerifyFailureClass::Sumcheck,
            anyhow::anyhow!("sumcheck: round consistency check failed"),
        );
        // extra string contexts above the class must not hide it
        let err = err.context("mm30").context("batched trace 2");
        assert_eq!(failure_class(&err), Some(VerifyFailureClass::Sumcheck));
        // ...and the original message survives in the chain
        let chain = format!("{err:#}");
        assert!(chain.contains("round consistency"), "{chain}");
    }

    #[test]
    fn inner_class_wins_over_outer() {
        let inner = classified(VerifyFailureClass::Booleanity, anyhow::anyhow!("b=2"));
        let outer = classified(VerifyFailureClass::ProvenanceSelection, inner);
        assert_eq!(failure_class(&outer), Some(VerifyFailureClass::Booleanity));
    }

    #[test]
    fn classify_attaches_only_to_errors() {
        let ok: anyhow::Result<u32> = Ok(7);
        assert_eq!(ok.classify(VerifyFailureClass::Shape).unwrap(), 7);
        let err: anyhow::Result<u32> = Err(anyhow::anyhow!("v_z length"));
        let e = err.classify(VerifyFailureClass::Shape).unwrap_err();
        assert_eq!(failure_class(&e), Some(VerifyFailureClass::Shape));
    }

    #[test]
    fn ensure_class_macro_early_returns_classified() {
        fn check(n: usize) -> anyhow::Result<()> {
            crate::ensure_class!(n == 4, VerifyFailureClass::Shape, "bad count {n}");
            Ok(())
        }
        assert!(check(4).is_ok());
        let e = check(5).unwrap_err();
        assert_eq!(failure_class(&e), Some(VerifyFailureClass::Shape));
        assert!(format!("{e:#}").contains("bad count 5"));
    }
}
