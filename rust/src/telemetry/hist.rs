//! zkFlight latency/size histograms — zero-dependency, lock-free,
//! log-linear.
//!
//! Each [`Histogram`] is a fixed array of atomic buckets: values below 4
//! get exact unit buckets; above that, every octave splits into 4
//! sub-buckets (2 mantissa bits), so a recorded value lands in a bucket
//! whose lower bound is within 25% of it. Quantiles are nearest-rank over
//! bucket lower bounds — p50/p95/p99 carry the same ≤ 25% relative error,
//! which is plenty to spot a latency regression; `max` is exact.
//!
//! Like counters, recording is gated on [`crate::telemetry::enabled`] (one
//! relaxed load while disabled) and never allocates: every bucket is a
//! static `AtomicU64`. Instrument with [`record`] for sizes or [`timer`]
//! (an RAII guard that records elapsed nanoseconds on drop, even on error
//! paths — rejected proofs still get a latency sample).

use crate::telemetry::{enabled, json::Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// 2 mantissa bits per octave: 4 unit buckets + 4 sub-buckets for each of
/// the 62 octaves `[2^2, 2^64)`.
const NUM_BUCKETS: usize = 4 + 62 * 4;

/// Bucket index of a value (monotone in `v`).
fn bucket_index(v: u64) -> usize {
    if v < 4 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (msb - 2)) & 3) as usize;
        4 + (msb - 2) * 4 + sub
    }
}

/// Smallest value that maps to bucket `i` — the value quantiles report.
fn bucket_lower(i: usize) -> u64 {
    if i < 4 {
        i as u64
    } else {
        let octave = (i - 4) / 4 + 2;
        let sub = ((i - 4) % 4) as u64;
        (1u64 << octave) + sub * (1u64 << (octave - 2))
    }
}

/// A concurrent log-linear histogram. All methods are lock-free.
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [const { AtomicU64::new(0) }; NUM_BUCKETS],
            count: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`) over bucket lower bounds;
    /// 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // never report above the exact maximum (a lone top bucket
                // would otherwise round its lower bound past it)
                return bucket_lower(i).min(self.max.load(Ordering::Relaxed));
            }
        }
        self.max.load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time digest of one histogram.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSummary {
    pub count: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl HistSummary {
    /// `{"count":..,"p50":..,"p95":..,"p99":..,"max":..}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Uint(self.count)),
            ("p50", Json::Uint(self.p50)),
            ("p95", Json::Uint(self.p95)),
            ("p99", Json::Uint(self.p99)),
            ("max", Json::Uint(self.max)),
        ])
    }
}

macro_rules! define_hists {
    ($($variant:ident => $name:literal),* $(,)?) => {
        /// The process-wide histogram set. `Hist::name()` gives the stable
        /// slash-path used in reports, bench cells, and JSON.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        #[repr(usize)]
        pub enum Hist { $($variant),* }

        /// Stable names, indexed by `Hist as usize`.
        pub const HIST_NAMES: &[&str] = &[$($name),*];

        impl Hist {
            pub const COUNT: usize = HIST_NAMES.len();

            pub fn name(self) -> &'static str {
                HIST_NAMES[self as usize]
            }
        }
    };
}

define_hists! {
    ProveStepNs => "lat/prove_step_ns",
    VerifyStepNs => "lat/verify_step_ns",
    ProveTraceNs => "lat/prove_trace_ns",
    VerifyTraceNs => "lat/verify_trace_ns",
    MsmSize => "msm/size",
    WireBytes => "wire/bytes",
    ServeSubmitNs => "lat/serve_submit_ns",
    ServeBatchSize => "serve/batch_size",
}

static HISTS: [Histogram; Hist::COUNT] = [const { Histogram::new() }; Hist::COUNT];

/// Record one sample. No-op (one relaxed load) while telemetry is off.
#[inline]
pub fn record(h: Hist, v: u64) {
    if enabled() {
        HISTS[h as usize].record(v);
    }
}

/// RAII latency sampler: records elapsed nanoseconds into `h` when the
/// guard drops, so `?`-early-exits and rejections are sampled too.
/// `None` (free) while telemetry is off.
#[inline]
pub fn timer(h: Hist) -> Option<HistTimer> {
    if enabled() {
        Some(HistTimer {
            h,
            start: Instant::now(),
        })
    } else {
        None
    }
}

pub struct HistTimer {
    h: Hist,
    start: Instant,
}

impl Drop for HistTimer {
    fn drop(&mut self) {
        HISTS[self.h as usize].record(self.start.elapsed().as_nanos() as u64);
    }
}

/// Current digest of one histogram.
pub fn snapshot(h: Hist) -> HistSummary {
    HISTS[h as usize].summary()
}

/// `(name, summary)` for every histogram with at least one sample.
pub fn summaries() -> Vec<(&'static str, HistSummary)> {
    (0..Hist::COUNT)
        .filter(|&i| HISTS[i].count() > 0)
        .map(|i| (HIST_NAMES[i], HISTS[i].summary()))
        .collect()
}

/// Clear all histograms (wired into [`crate::telemetry::reset`]).
pub fn reset_all() {
    for h in &HISTS {
        h.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_names_cover_enum() {
        assert_eq!(HIST_NAMES.len(), Hist::COUNT);
        assert_eq!(Hist::MsmSize.name(), "msm/size");
        for (i, a) in HIST_NAMES.iter().enumerate() {
            for b in HIST_NAMES.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn bucket_index_is_monotone_and_lower_bound_consistent() {
        let mut prev = 0usize;
        for v in 0..4096u64 {
            let i = bucket_index(v);
            assert!(i >= prev, "index not monotone at {v}");
            prev = i;
            assert!(bucket_lower(i) <= v, "lower bound above value at {v}");
            // log-linear promise: lower bound within 25% of the value
            assert!(
                (v - bucket_lower(i)) * 4 <= v.max(4),
                "bucket too coarse at {v}: lower {}",
                bucket_lower(i)
            );
        }
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
        for i in 0..NUM_BUCKETS {
            assert_eq!(bucket_index(bucket_lower(i)), i, "lower bound of {i}");
        }
    }

    #[test]
    fn quantiles_track_a_known_distribution() {
        let h = Histogram::new();
        // 100 samples: 1..=100 (ns-ish scale)
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.max, 100);
        // nearest-rank with ≤25% bucket error
        let within = |got: u64, want: u64| {
            (got as f64 - want as f64).abs() <= 0.25 * want as f64
        };
        assert!(within(s.p50, 50), "p50={}", s.p50);
        assert!(within(s.p95, 95), "p95={}", s.p95);
        assert!(within(s.p99, 99), "p99={}", s.p99);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        h.reset();
        assert_eq!(h.summary(), HistSummary::default());
    }

    #[test]
    fn quantile_never_exceeds_exact_max() {
        let h = Histogram::new();
        h.record(1000);
        let s = h.summary();
        assert_eq!(s.max, 1000);
        assert!(s.p50 > 0 && s.p50 <= s.max);
        assert!(s.p99 <= s.max);
        // a lone sample exactly on a bucket boundary reports itself
        let h2 = Histogram::new();
        h2.record(1024);
        assert_eq!(h2.summary().p50, 1024);
    }

    #[test]
    fn summary_json_shape() {
        let s = HistSummary {
            count: 3,
            p50: 10,
            p95: 20,
            p99: 20,
            max: 21,
        };
        let j = s.to_json().to_string();
        let parsed = Json::parse(&j).expect("summary JSON parses");
        for key in ["count", "p50", "p95", "p99", "max"] {
            assert!(parsed.get(key).and_then(|v| v.as_u64()).is_some(), "{key}");
        }
        assert_eq!(parsed.get("max").and_then(|v| v.as_u64()), Some(21));
    }
}
