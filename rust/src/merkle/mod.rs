//! Proof of training-data (non-)membership (paper §4.4 + Appendix B).
//!
//! Data points are deterministically Pedersen-committed (§3.1, r = 0);
//! their hashes identify leaves of a conceptual depth-k binary tree
//! (k = hash output bits). The trainer materializes the subtree
//! T_D = Tree(H_D) ∪ Frontier(H_D): every path from a data hash to the
//! root, plus the off-path sibling "frontier" nodes valued ε. The root is
//! endorsed by the trusted verifier; membership and *non*-membership of
//! queried points are then proven by releasing the node values of
//! Tree(H_E^inc) ∪ F^exc and its frontier (Protocol 3), which the data
//! owner folds back to the root (Protocol 4 / Algorithm 2).
//!
//! Node hashing uses length-prefixed child encodings so the empty value ε,
//! leaf commitments, and fixed-length digests cannot collide.
//!
//! Leaf encoding is the canonical 32-byte compressed-point codec shared
//! with the wire format ([`point_leaf`]/[`leaf_point`]): endorsement leaves
//! and persisted artifacts agree on one byte representation per point, so
//! a dataset commitment can be cross-checked against an endorsed root
//! ([`crate::provenance::verify_dataset_endorsement`]).

use crate::curve::G1Affine;
use crate::hash::HashFn;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Canonical leaf encoding of a data-point commitment: the same 32-byte
/// compressed form the wire codec serializes (sign bit + x).
pub fn point_leaf(p: &G1Affine) -> Vec<u8> {
    p.to_bytes_compressed().to_vec()
}

/// Decode a [`point_leaf`] back to its point, rejecting malformed bytes.
pub fn leaf_point(bytes: &[u8]) -> Result<G1Affine> {
    let raw: [u8; 32] = bytes
        .try_into()
        .ok()
        .context("merkle: leaf is not 32 bytes")?;
    G1Affine::from_bytes_compressed(&raw).context("merkle: leaf is not a curve point")
}

/// A node identifier: its depth and the path bits from the root (one bool
/// per level). The root is (0, []).
pub type NodeId = (usize, Vec<bool>);

/// A node value: ε (frontier), a leaf commitment, or an inner hash.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Val {
    Empty,
    Leaf(Vec<u8>),
    Hash(Vec<u8>),
}

impl Val {
    fn bytes(&self) -> &[u8] {
        match self {
            Val::Empty => &[],
            Val::Leaf(b) | Val::Hash(b) => b,
        }
    }
}

fn hash_children(h: HashFn, left: &Val, right: &Val) -> Vec<u8> {
    let l = left.bytes();
    let r = right.bytes();
    let mut buf = Vec::with_capacity(16 + l.len() + r.len());
    buf.extend_from_slice(&(l.len() as u64).to_le_bytes());
    buf.extend_from_slice(l);
    buf.extend_from_slice(&(r.len() as u64).to_le_bytes());
    buf.extend_from_slice(r);
    h.hash(&buf)
}

/// Bits of a digest, MSB-first.
pub fn digest_bits(digest: &[u8]) -> Vec<bool> {
    let mut out = Vec::with_capacity(digest.len() * 8);
    for byte in digest {
        for i in (0..8).rev() {
            out.push((byte >> i) & 1 == 1);
        }
    }
    out
}

/// The training-set Merkle structure. Leaves are stored sorted by hash
/// bits; node values are recomputed on demand (O(n·k) per pass), so memory
/// stays O(n) instead of O(n·k).
pub struct MerkleTree {
    pub hash: HashFn,
    pub k: usize,
    /// Sorted (hash bits, commitment bytes).
    leaves: Vec<(Vec<bool>, Vec<u8>)>,
    pub root: Vec<u8>,
}

/// A (non-)membership proof for a query batch (Protocol 3 output): the
/// released node values. Proof size is measured as the number of released
/// hash/commitment values, as in Table 3.
#[derive(Clone, Debug)]
pub struct MembershipProof {
    /// Queried hashes claimed included (H_E^inc).
    pub included: Vec<Vec<u8>>,
    /// Queried hashes claimed excluded (H_E^exc).
    pub excluded: Vec<Vec<u8>>,
    /// Released node values: included-leaf commitments, F^exc frontier
    /// nodes (ε), and the sibling frontier of the union.
    pub nodes: BTreeMap<NodeId, Val>,
}

impl MembershipProof {
    /// Number of released values (the paper's "size (#)").
    pub fn size_hashes(&self) -> usize {
        self.nodes.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|((d, bits), v)| 8 + bits.len().div_ceil(8) + v.bytes().len() + d / usize::MAX.max(1))
            .sum()
    }
}

impl MerkleTree {
    /// Build from data-point commitments (already serialized). The hash of
    /// each commitment identifies its leaf.
    pub fn build(hash: HashFn, commitments: &[Vec<u8>]) -> Self {
        let k = hash.output_len() * 8;
        let mut leaves: Vec<(Vec<bool>, Vec<u8>)> = commitments
            .iter()
            .map(|c| (digest_bits(&hash.hash(c)), c.clone()))
            .collect();
        leaves.sort();
        leaves.dedup_by(|a, b| a.0 == b.0);
        let mut tree = Self {
            hash,
            k,
            leaves,
            root: Vec::new(),
        };
        tree.root = tree.value_of_range(0, 0, tree.leaves.len()).bytes().to_vec();
        tree
    }

    /// [`Self::build`] over point commitments, leaf-encoded canonically.
    pub fn build_points(hash: HashFn, points: &[G1Affine]) -> Self {
        let leaves: Vec<Vec<u8>> = points.iter().map(point_leaf).collect();
        Self::build(hash, &leaves)
    }

    pub fn len(&self) -> usize {
        self.leaves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leaves.is_empty()
    }

    /// Does a hash belong to the training set?
    pub fn contains(&self, digest: &[u8]) -> bool {
        let bits = digest_bits(digest);
        self.leaves.binary_search_by(|(b, _)| b.cmp(&bits)).is_ok()
    }

    /// Value of the node at `depth` whose subtree covers leaves [lo, hi)
    /// (all sharing the same depth-length prefix).
    fn value_of_range(&self, depth: usize, lo: usize, hi: usize) -> Val {
        if lo == hi {
            return Val::Empty;
        }
        if depth == self.k {
            debug_assert_eq!(hi - lo, 1);
            return Val::Leaf(self.leaves[lo].1.clone());
        }
        let split = self.split_point(depth, lo, hi);
        let left = self.value_of_range(depth + 1, lo, split);
        let right = self.value_of_range(depth + 1, split, hi);
        // A node with an empty subtree on BOTH sides cannot occur here
        // (lo < hi), and a node whose two children are both empty is not in
        // T_D. One empty child is the frontier sibling (ε).
        Val::Hash(hash_children(self.hash, &left, &right))
    }

    /// First leaf index in [lo, hi) whose bit at `depth` is 1.
    fn split_point(&self, depth: usize, lo: usize, hi: usize) -> usize {
        let mut a = lo;
        let mut b = hi;
        while a < b {
            let mid = (a + b) / 2;
            if self.leaves[mid].0[depth] {
                b = mid;
            } else {
                a = mid + 1;
            }
        }
        a
    }

    /// Protocol 3: prove (non-)membership of each queried hash.
    pub fn prove(&self, queries: &[Vec<u8>]) -> MembershipProof {
        let mut included = Vec::new();
        let mut excluded = Vec::new();
        let mut query_bits: Vec<Vec<bool>> = Vec::new();
        for q in queries {
            let bits = digest_bits(q);
            if self.leaves.binary_search_by(|(b, _)| b.cmp(&bits)).is_ok() {
                included.push(q.clone());
            } else {
                excluded.push(q.clone());
            }
            query_bits.push(bits);
        }
        let mut nodes = BTreeMap::new();
        self.collect(0, Vec::new(), 0, self.leaves.len(), &query_bits, &mut nodes);
        MembershipProof {
            included,
            excluded,
            nodes,
        }
    }

    /// Recursive walk: `actives` are query bit-strings passing through this
    /// node. Releases values per Protocol 3:
    /// * node off every query path but sibling to one → release its value
    ///   (the frontier of the released subtree),
    /// * empty node on a query path → release ε (an F^exc witness),
    /// * leaf on a query path → release the commitment.
    #[allow(clippy::too_many_arguments)]
    fn collect(
        &self,
        depth: usize,
        prefix: Vec<bool>,
        lo: usize,
        hi: usize,
        queries: &[Vec<bool>],
        out: &mut BTreeMap<NodeId, Val>,
    ) {
        let on_path = queries.iter().any(|q| q[..depth] == prefix[..]);
        if !on_path {
            // sibling of a query path (the caller only recurses into
            // children of on-path nodes): release the whole value
            out.insert((depth, prefix), self.value_of_range(depth, lo, hi));
            return;
        }
        if lo == hi {
            // F^exc witness: an empty node on a query path
            out.insert((depth, prefix), Val::Empty);
            return;
        }
        if depth == self.k {
            out.insert((depth, prefix), Val::Leaf(self.leaves[lo].1.clone()));
            return;
        }
        let split = self.split_point(depth, lo, hi);
        let mut left_prefix = prefix.clone();
        left_prefix.push(false);
        let mut right_prefix = prefix;
        right_prefix.push(true);
        self.collect(depth + 1, left_prefix, lo, split, queries, out);
        self.collect(depth + 1, right_prefix, split, hi, queries, out);
    }
}

/// Protocol 4: the data owner verifies a batch proof against the endorsed
/// root. Checks the inclusion/exclusion partition, the F^exc structure, and
/// reconstructs the root via Algorithm 2.
pub fn verify_membership(
    hash: HashFn,
    root: &[u8],
    queries: &[Vec<u8>],
    proof: &MembershipProof,
) -> Result<()> {
    let k = hash.output_len() * 8;
    // 1. partition check
    ensure!(
        proof.included.len() + proof.excluded.len() == queries.len(),
        "partition size mismatch"
    );
    for q in queries {
        let inc = proof.included.contains(q);
        let exc = proof.excluded.contains(q);
        ensure!(inc ^ exc, "query must be exactly one of included/excluded");
    }
    // 2. structural checks on the released nodes
    for q in &proof.included {
        let bits = digest_bits(q);
        match proof.nodes.get(&(k, bits)) {
            Some(Val::Leaf(com)) => {
                ensure!(
                    digest_bits(&hash.hash(com)) == digest_bits(q),
                    "leaf commitment does not hash to the queried identity"
                );
            }
            _ => bail!("included query has no leaf witness"),
        }
    }
    for q in &proof.excluded {
        let bits = digest_bits(q);
        // some released ε node must be a prefix of the queried hash
        let witnessed = proof.nodes.iter().any(|((d, p), v)| {
            *v == Val::Empty && *d <= k && p[..] == bits[..*d]
        });
        ensure!(witnessed, "excluded query lacks an ε-prefix witness");
    }
    // 3. Algorithm 2: fold the released nodes to the root
    let mut vals: BTreeMap<NodeId, Val> = proof.nodes.clone();
    while vals.len() > 1 || vals.keys().next().map(|(d, _)| *d) != Some(0) {
        // take the deepest depth present
        let depth = *vals.keys().map(|(d, _)| d).max().unwrap();
        if depth == 0 {
            bail!("multiple roots");
        }
        let deepest: Vec<NodeId> = vals
            .keys()
            .filter(|(d, _)| *d == depth)
            .cloned()
            .collect();
        let mut processed = std::collections::BTreeSet::new();
        for id in deepest {
            if processed.contains(&id) {
                continue;
            }
            let (d, bits) = &id;
            let mut sib_bits = bits.clone();
            let last = sib_bits.len() - 1;
            sib_bits[last] = !sib_bits[last];
            let sib = (*d, sib_bits);
            let Some(sv) = vals.get(&sib) else {
                bail!("node at depth {d} lacks a sibling witness");
            };
            let v = vals.get(&id).unwrap();
            let (lv, rv) = if bits[last] {
                (sv.clone(), v.clone())
            } else {
                (v.clone(), sv.clone())
            };
            let parent_val = Val::Hash(hash_children(hash, &lv, &rv));
            let parent = (d - 1, bits[..last].to_vec());
            processed.insert(id.clone());
            processed.insert(sib.clone());
            vals.remove(&id);
            vals.remove(&sib);
            // parent may already be released (must then agree)
            if let Some(existing) = vals.get(&parent) {
                ensure!(*existing == parent_val, "inconsistent parent value");
            } else {
                vals.insert(parent, parent_val);
            }
        }
    }
    let (_, root_val) = vals.into_iter().next().unwrap();
    ensure!(root_val.bytes() == root, "reconstructed root mismatch");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn coms(n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let mut b = vec![0u8; 64];
                r.fill_bytes(&mut b);
                b
            })
            .collect()
    }

    fn check(hash: HashFn) {
        let data = coms(50, 1);
        let tree = MerkleTree::build(hash, &data);
        assert_eq!(tree.len(), 50);

        // mixed query: 3 members, 2 non-members
        let mut queries: Vec<Vec<u8>> = data[..3].iter().map(|c| hash.hash(c)).collect();
        let outsiders = coms(2, 99);
        queries.extend(outsiders.iter().map(|c| hash.hash(c)));

        let proof = tree.prove(&queries);
        assert_eq!(proof.included.len(), 3);
        assert_eq!(proof.excluded.len(), 2);
        verify_membership(hash, &tree.root, &queries, &proof).expect("verifies");
    }

    #[test]
    fn roundtrip_md5() {
        check(HashFn::Md5);
    }

    #[test]
    fn roundtrip_sha1() {
        check(HashFn::Sha1);
    }

    #[test]
    fn roundtrip_sha256() {
        check(HashFn::Sha256);
    }

    #[test]
    fn all_excluded_small_proof() {
        let hash = HashFn::Md5;
        let data = coms(256, 2);
        let tree = MerkleTree::build(hash, &data);
        let queries: Vec<Vec<u8>> = coms(10, 77).iter().map(|c| hash.hash(c)).collect();
        let proof = tree.prove(&queries);
        assert_eq!(proof.excluded.len(), 10);
        verify_membership(hash, &tree.root, &queries, &proof).expect("verifies");
        // non-membership proofs are much shorter than membership proofs
        let mem_queries: Vec<Vec<u8>> = data[..10].iter().map(|c| hash.hash(c)).collect();
        let mem_proof = tree.prove(&mem_queries);
        verify_membership(hash, &tree.root, &mem_queries, &mem_proof).expect("verifies");
        assert!(
            proof.size_hashes() < mem_proof.size_hashes(),
            "non-membership {} should be smaller than membership {}",
            proof.size_hashes(),
            mem_proof.size_hashes()
        );
    }

    #[test]
    fn trainer_cannot_lie_about_membership() {
        let hash = HashFn::Sha256;
        let data = coms(64, 3);
        let tree = MerkleTree::build(hash, &data);
        let member = hash.hash(&data[0]);
        let queries = vec![member.clone()];
        let mut proof = tree.prove(&queries);
        // claim the member is excluded
        proof.included.clear();
        proof.excluded.push(member);
        assert!(verify_membership(hash, &tree.root, &queries, &proof).is_err());
    }

    #[test]
    fn tampered_root_rejected() {
        let hash = HashFn::Md5;
        let data = coms(32, 4);
        let tree = MerkleTree::build(hash, &data);
        let queries = vec![hash.hash(&data[5])];
        let proof = tree.prove(&queries);
        let mut bad_root = tree.root.clone();
        bad_root[0] ^= 1;
        assert!(verify_membership(hash, &bad_root, &queries, &proof).is_err());
    }

    #[test]
    fn tampered_leaf_rejected() {
        let hash = HashFn::Md5;
        let data = coms(32, 5);
        let tree = MerkleTree::build(hash, &data);
        let q = hash.hash(&data[7]);
        let queries = vec![q.clone()];
        let mut proof = tree.prove(&queries);
        // swap the leaf commitment for another one
        let id = (tree.k, digest_bits(&q));
        proof.nodes.insert(id, Val::Leaf(data[8].clone()));
        assert!(verify_membership(hash, &tree.root, &queries, &proof).is_err());
    }

    #[test]
    fn leaf_encoding_matches_the_wire_point_codec() {
        // cross-module: an endorsement leaf and a wire artifact must share
        // one canonical byte representation per point
        let mut r = Rng::seed_from_u64(0x1eaf);
        let mut points: Vec<crate::curve::G1Affine> = (0..8)
            .map(|_| crate::curve::G1::random(&mut r).to_affine())
            .collect();
        points.push(crate::curve::G1Affine::IDENTITY);
        for p in &points {
            let leaf = point_leaf(p);
            assert_eq!(leaf.len(), 32, "compressed leaves");
            let mut w = crate::wire::WireWriter::new();
            w.put(p);
            assert_eq!(leaf, w.finish(), "leaf bytes == wire point bytes");
            assert_eq!(leaf_point(&leaf).expect("roundtrips"), *p);
        }
        // malformed leaves are rejected, not mis-decoded
        assert!(leaf_point(&[0u8; 31]).is_err());
        let mut bad = point_leaf(&points[0]);
        bad[31] |= 0xc0; // sign + infinity flags together are invalid
        assert!(leaf_point(&bad).is_err());
        // build_points == build over the encoded leaves
        let leaves: Vec<Vec<u8>> = points.iter().map(point_leaf).collect();
        let a = MerkleTree::build_points(HashFn::Sha256, &points);
        let b = MerkleTree::build(HashFn::Sha256, &leaves);
        assert_eq!(a.root, b.root);
        // ... and (non-)membership proofs verify against it
        let queries = vec![HashFn::Sha256.hash(&leaves[2])];
        let proof = a.prove(&queries);
        verify_membership(HashFn::Sha256, &a.root, &queries, &proof).expect("verifies");
    }

    #[test]
    fn deterministic_root() {
        let data = coms(20, 6);
        let a = MerkleTree::build(HashFn::Sha1, &data);
        let b = MerkleTree::build(HashFn::Sha1, &data);
        assert_eq!(a.root, b.root);
        let mut data2 = data.clone();
        data2.pop();
        let c = MerkleTree::build(HashFn::Sha1, &data2);
        assert_ne!(a.root, c.root);
    }
}
