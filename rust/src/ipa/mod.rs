//! Inner-product arguments (paper §3.3, Bulletproofs [45]).
//!
//! Two variants, both with O(n) prover time and O(log n) proof size:
//!
//! * [`prove_eval`]/[`verify_eval`] — "evaluation opening": for a Pedersen
//!   commitment C = h^r·g^S and a *public* vector e, prove ⟨S, e⟩ = v.
//!   This is how every sumcheck-terminal claim S̃(u) = ⟨S, e(u)⟩ is checked
//!   against the tensor commitments. Claims at the same point are batched
//!   by random linear combination ([`batch_eval_claims`]).
//! * [`prove_ip`]/[`verify_ip`] — the two-committed-vector inner product
//!   used by zkReLU's validity equation (19): P = h^r·G^a·H^b, prove
//!   ⟨a, b⟩ = t.
//!
//! Blinding: fresh Pedersen randomness is folded through every L/R message,
//! and only the final folded scalars are revealed — the random-linear-
//! combination leakage this admits is the deviation documented in DESIGN.md.
//!
//! Verification is *deferred* (DESIGN.md §verification engine): every
//! verifier here reduces its group equation to (scalar, point) terms pushed
//! into a [`MsmAccumulator`] — no per-round point muls, no per-opening MSM.
//! The classic entry points ([`verify_eval`], [`verify_ip`],
//! [`batch_verify_eval`]) are thin wrappers that allocate an accumulator
//! and flush it once; the `_accum`/`_expr` variants let callers thread one
//! accumulator through many proofs and decide them with a single MSM.

use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::{msm::msm, G1Affine, G1};
use crate::field::Fr;
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use crate::util::threads;
use anyhow::{bail, ensure, Result};

/// Log-size IPA proof.
#[derive(Clone, Debug)]
pub struct IpaProof {
    pub l: Vec<G1Affine>,
    pub r: Vec<G1Affine>,
    /// Folded left-vector scalar.
    pub a: Fr,
    /// Folded right-vector scalar (== folded public e for `prove_eval`;
    /// kept so both variants share a wire format).
    pub b: Fr,
    /// Folded blinding factor.
    pub blind: Fr,
}

impl IpaProof {
    /// Proof size in bytes: compressed points (32 B) + 3 scalars.
    /// We serialize points uncompressed internally, but size accounting
    /// follows the standard compressed encoding the paper assumes.
    pub fn size_bytes(&self) -> usize {
        (self.l.len() + self.r.len()) * 32 + 3 * 32
    }
}

/// Extra generator for the inner-product value slot, independent of the
/// commitment bases.
pub fn ipa_u(label: &[u8]) -> G1Affine {
    let mut l = label.to_vec();
    l.extend_from_slice(b"/ipa-u");
    crate::curve::hash_to_curve(&l, u64::MAX - 1)
}

fn nonzero_challenge(t: &mut Transcript, label: &[u8]) -> Fr {
    loop {
        let c = t.challenge_fr(label);
        if !c.is_zero() {
            return c;
        }
    }
}

/// Fold-pattern vector: s[i] = Π_j x_j^{±1} with +1 iff bit j (MSB-first)
/// of i is set. g_final = Σ s[i]·g[i]. Each doubling level is tabulated
/// across the pool (one multiply per output index, as in the sequential
/// build; the small early levels run inline under the threshold).
fn s_vector(challenges: &[Fr]) -> Vec<Fr> {
    let mut inv = challenges.to_vec();
    Fr::batch_invert(&mut inv);
    let mut s = vec![Fr::ONE];
    for (x, xi) in challenges.iter().zip(inv.iter()) {
        let src = &s;
        s = threads::par_tabulate(src.len() * 2, 1 << 11, Fr::ZERO, |i| {
            // low half of each pair: exponent −1; high half: +1
            src[i / 2] * if i & 1 == 0 { *xi } else { *x }
        });
    }
    s
}

/// Parallel dot product ⟨a, b⟩ over min(len) elements, chunk partials
/// summed in ascending order (bit-identical to the sequential sum).
fn dot_par(a: &[Fr], b: &[Fr]) -> Fr {
    let n = a.len().min(b.len());
    threads::par_reduce(
        n,
        1 << 10,
        Fr::ZERO,
        |range, acc| {
            a[range.clone()]
                .iter()
                .zip(&b[range])
                .fold(acc, |s, (x, y)| s + *x * *y)
        },
        |x, y| x + y,
    )
}

/// Folded public-vector value after all rounds in one pass: the per-round
/// fold e′ = x⁻¹·e_L + x·e_R composes to exactly the s-pattern, so
/// ev_final = ⟨s_vector(challenges), e⟩ — no round-by-round cloning.
fn fold_public(s: &[Fr], e: &[Fr]) -> Fr {
    dot_par(s, e)
}

/// Lane-tiled in-place build: out[i] = f(i), each index written once.
fn fill_scal(out: &mut [Fr], f: impl Fn(usize) -> Fr + Sync) {
    threads::par_chunks_mut(out, 1024, |ci, chunk| {
        let base = ci * 1024;
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = f(base + k);
        }
    });
}

/// Lane-tiled in-place update: out[i] = g(i, out[i]).
fn update_scal(out: &mut [Fr], g: impl Fn(usize, Fr) -> Fr + Sync) {
    threads::par_chunks_mut(out, 1024, |ci, chunk| {
        let base = ci * 1024;
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = g(base + k, *slot);
        }
    });
}

/// Replay the L/R rounds against the transcript, returning the challenge
/// vector (shared by every verifier variant).
fn replay_rounds(
    proof: &IpaProof,
    l_label: &'static [u8],
    r_label: &'static [u8],
    x_label: &'static [u8],
    transcript: &mut Transcript,
) -> Vec<Fr> {
    let mut challenges = Vec::with_capacity(proof.l.len());
    for (l, r) in proof.l.iter().zip(proof.r.iter()) {
        transcript.absorb_point(l_label, l);
        transcript.absorb_point(r_label, r);
        challenges.push(nonzero_challenge(transcript, x_label));
    }
    challenges
}

/// Push the −(x²·L + x⁻²·R) round terms of the verification equation.
fn push_round_terms(acc: &mut MsmAccumulator, proof: &IpaProof, challenges: &[Fr]) {
    let mut xinv = challenges.to_vec();
    Fr::batch_invert(&mut xinv);
    for ((l, r), (x, xi)) in proof
        .l
        .iter()
        .zip(proof.r.iter())
        .zip(challenges.iter().zip(xinv.iter()))
    {
        acc.push(-x.square(), *l);
        acc.push(-xi.square(), *r);
    }
}

// ---------------------------------------------------------------------------
// Variant 1: evaluation opening ⟨S, e⟩ = v with public e
// ---------------------------------------------------------------------------

/// Prove ⟨values, e⟩ = v given C = h^blind·g^values. `values.len()` must be
/// a power of two and equal `e.len()`.
pub fn prove_eval(
    ck: &CommitKey,
    com: &G1,
    values: &[Fr],
    blind: Fr,
    e: &[Fr],
    v: Fr,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> IpaProof {
    transcript.absorb_point(b"ipa/com", &com.to_affine());
    prove_eval_core(ck, values, blind, e, v, transcript, rng)
}

/// [`prove_eval`] without the commitment absorption — used when the
/// commitment is a public combination of already-transcript-bound points
/// (the `_expr` batched openings), so re-absorbing it would only force the
/// verifier to materialize it.
pub(crate) fn prove_eval_core(
    ck: &CommitKey,
    values: &[Fr],
    blind: Fr,
    e: &[Fr],
    v: Fr,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> IpaProof {
    crate::span!("ipa/prove");
    let n = values.len();
    assert!(n.is_power_of_two() && e.len() == n && ck.g.len() >= n);
    crate::telemetry::count(
        crate::telemetry::Counter::IpaProveRounds,
        n.trailing_zeros() as u64,
    );
    transcript.absorb_fr(b"ipa/value", &v);
    transcript.absorb_u64(b"ipa/n", n as u64);
    let c = nonzero_challenge(transcript, b"ipa/u-scale");
    let u = ipa_u(&ck.label).to_projective().mul(&c);

    // The folded basis after k rounds satisfies g′_v = Σ_{i ≡ v (mod m)}
    // mult[i]·g_i, so every round's L/R is a single MSM over the *original*
    // basis with composed scalars — no per-round point folding (this is the
    // §Perf optimization: ~2n point-adds/round instead of n scalar-muls).
    let mut a = values.to_vec();
    let mut ev = e.to_vec();
    let mut mult = vec![Fr::ONE; n];
    let mut blind = blind;
    let mut ls = Vec::new();
    let mut rs = Vec::new();
    let mut scal = vec![Fr::ZERO; n];

    while a.len() > 1 {
        let m = a.len();
        let half = m / 2;
        let (a_l, a_r) = a.split_at(half);
        let (e_l, e_r) = ev.split_at(half);
        let cl = dot_par(a_l, e_r);
        let cr = dot_par(a_r, e_l);
        let r_l = Fr::random(rng);
        let r_r = Fr::random(rng);
        // L = (g′_R)^{a_L}: original i with (i mod m) ≥ half. The scalar
        // builds are lane-tiled (each index written once).
        fill_scal(&mut scal, |i| {
            let v = i % m;
            if v >= half {
                mult[i] * a_l[v - half]
            } else {
                Fr::ZERO
            }
        });
        let l_pt = ck.msm_prefix(&scal) + u.mul(&cl) + ck.h.to_projective().mul(&r_l);
        // R = (g′_L)^{a_R}
        fill_scal(&mut scal, |i| {
            let v = i % m;
            if v < half {
                mult[i] * a_r[v]
            } else {
                Fr::ZERO
            }
        });
        let r_pt = ck.msm_prefix(&scal) + u.mul(&cr) + ck.h.to_projective().mul(&r_r);
        let l_aff = l_pt.to_affine();
        let r_aff = r_pt.to_affine();
        transcript.absorb_point(b"ipa/L", &l_aff);
        transcript.absorb_point(b"ipa/R", &r_aff);
        let x = nonzero_challenge(transcript, b"ipa/x");
        let xi = x.inverse().unwrap();

        let a_next = threads::par_tabulate(half, 1 << 10, Fr::ZERO, |i| x * a_l[i] + xi * a_r[i]);
        let e_next = threads::par_tabulate(half, 1 << 10, Fr::ZERO, |i| xi * e_l[i] + x * e_r[i]);
        update_scal(&mut mult, |i, mi| mi * if i % m < half { xi } else { x });
        blind = x.square() * r_l + blind + xi.square() * r_r;
        a = a_next;
        ev = e_next;
        ls.push(l_aff);
        rs.push(r_aff);
    }

    IpaProof {
        l: ls,
        r: rs,
        a: a[0],
        b: ev[0],
        blind,
    }
}

/// Verify an evaluation opening against commitment `com`, public vector `e`
/// and claimed value `v`. Thin wrapper: one accumulator, one MSM.
pub fn verify_eval(
    ck: &CommitKey,
    com: &G1,
    e: &[Fr],
    v: Fr,
    proof: &IpaProof,
    transcript: &mut Transcript,
) -> Result<()> {
    let mut acc = MsmAccumulator::new();
    verify_eval_accum(ck, com, e, v, proof, transcript, &mut acc)?;
    ensure!(acc.flush(), "ipa: final check failed");
    Ok(())
}

/// [`verify_eval`] deferring all group arithmetic into `acc` (same
/// transcript schedule — the commitment is still absorbed).
pub fn verify_eval_accum(
    ck: &CommitKey,
    com: &G1,
    e: &[Fr],
    v: Fr,
    proof: &IpaProof,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    transcript.absorb_point(b"ipa/com", &com.to_affine());
    verify_eval_core(ck, &[(Fr::ONE, *com)], e, v, proof, transcript, acc)
}

/// Shared deferred verifier: the commitment is given symbolically as
/// Σ coeffᵢ·Pᵢ over transcript-bound points and is NOT absorbed here. The
/// entire check expect − p = 𝒪, i.e.
///   Σ s[i]·a·gᵢ + c·(a·b − v)·U + blind·h − Σ coeffᵢ·Pᵢ − Σⱼ (x²ⱼLⱼ + x⁻²ⱼRⱼ) = 𝒪,
/// lands in the accumulator as one equation — zero point operations here.
fn verify_eval_core(
    ck: &CommitKey,
    com_terms: &[(Fr, G1)],
    e: &[Fr],
    v: Fr,
    proof: &IpaProof,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("ipa/verify");
    let n = e.len();
    ensure!(n.is_power_of_two(), "ipa: length must be a power of two");
    ensure!(
        proof.l.len() == n.trailing_zeros() as usize && proof.r.len() == proof.l.len(),
        "ipa: wrong number of rounds"
    );
    crate::telemetry::count(
        crate::telemetry::Counter::IpaVerifyRounds,
        proof.l.len() as u64,
    );
    ensure!(ck.g.len() >= n, "ipa: commitment key too short");
    transcript.absorb_fr(b"ipa/value", &v);
    transcript.absorb_u64(b"ipa/n", n as u64);
    let c = nonzero_challenge(transcript, b"ipa/u-scale");
    let challenges = replay_rounds(proof, b"ipa/L", b"ipa/R", b"ipa/x", transcript);

    let s = s_vector(&challenges);
    if fold_public(&s, e) != proof.b {
        bail!("ipa: folded public vector mismatch");
    }

    acc.begin_equation();
    let g_scalars = threads::par_tabulate(s.len(), 1 << 10, Fr::ZERO, |i| s[i] * proof.a);
    acc.push_fixed_key(ck, &g_scalars);
    acc.push(c * (proof.a * proof.b - v), ipa_u(&ck.label));
    acc.push(proof.blind, ck.h);
    for (coeff, com) in com_terms {
        acc.push_proj(-*coeff, com);
    }
    push_round_terms(acc, proof, &challenges);
    Ok(())
}

// ---------------------------------------------------------------------------
// Variant 2: two committed vectors ⟨a, b⟩ = t (zkReLU eq. 19)
// ---------------------------------------------------------------------------

/// Basis for the two-vector IPA: left basis G, right basis H, blind base h.
#[derive(Clone, Debug)]
pub struct IpaBasis {
    pub g: Vec<G1Affine>,
    pub h: Vec<G1Affine>,
    pub blind_h: G1Affine,
    pub label: Vec<u8>,
}

impl IpaBasis {
    /// Commitment h^blind · G^a · H^b.
    pub fn commit(&self, a: &[Fr], b: &[Fr], blind: Fr) -> G1 {
        msm(&self.g[..a.len()], a)
            + msm(&self.h[..b.len()], b)
            + self.blind_h.to_projective().mul(&blind)
    }
}

/// Prove ⟨a, b⟩ = t given P = h^blind·G^a·H′^b, where H′ᵢ = Hᵢ^{h_scale[i]}
/// (H′ is *virtual*: the scale folds into the per-round MSM scalars, so the
/// transformed basis of zkReLU's Algorithm 1 is never materialized).
#[allow(clippy::too_many_arguments)]
pub fn prove_ip(
    basis: &IpaBasis,
    com: &G1,
    a: &[Fr],
    b: &[Fr],
    blind: Fr,
    t: Fr,
    h_scale: Option<&[Fr]>,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> IpaProof {
    transcript.absorb_point(b"ipa2/com", &com.to_affine());
    prove_ip_core(basis, a, b, blind, t, h_scale, transcript, rng)
}

/// [`prove_ip`] without the commitment absorption: used by zkReLU, where P
/// is a public combination of already-absorbed commitments and challenge-
/// derived exponents, so the verifier never needs to materialize it (and
/// the prover saves the P-sized MSM it only computed in order to absorb).
#[allow(clippy::too_many_arguments)]
pub(crate) fn prove_ip_core(
    basis: &IpaBasis,
    a: &[Fr],
    b: &[Fr],
    blind: Fr,
    t: Fr,
    h_scale: Option<&[Fr]>,
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> IpaProof {
    crate::span!("ipa/prove_ip");
    let n = a.len();
    assert!(n.is_power_of_two() && b.len() == n);
    assert!(basis.g.len() >= n && basis.h.len() >= n);
    crate::telemetry::count(
        crate::telemetry::Counter::IpaProveRounds,
        n.trailing_zeros() as u64,
    );
    transcript.absorb_fr(b"ipa2/t", &t);
    transcript.absorb_u64(b"ipa2/n", n as u64);
    let c = nonzero_challenge(transcript, b"ipa2/u-scale");
    let u = ipa_u(&basis.label).to_projective().mul(&c);

    // MSM-over-original-bases structure (see prove_eval): mult_g/mult_h
    // track the composed challenge products per original index; h folds
    // with the inverse exponent pattern of g.
    let mut a = a.to_vec();
    let mut b = b.to_vec();
    let mut mult_g = vec![Fr::ONE; n];
    let mut mult_h = match h_scale {
        Some(s) => {
            assert_eq!(s.len(), n);
            s.to_vec()
        }
        None => vec![Fr::ONE; n],
    };
    let mut blind = blind;
    let mut ls = Vec::new();
    let mut rs = Vec::new();
    let mut scal_g = vec![Fr::ZERO; n];
    let mut scal_h = vec![Fr::ZERO; n];

    while a.len() > 1 {
        let m = a.len();
        let half = m / 2;
        let (a_l, a_r) = a.split_at(half);
        let (b_l, b_r) = b.split_at(half);
        let cl = dot_par(a_l, b_r);
        let cr = dot_par(a_r, b_l);
        let r_l = Fr::random(rng);
        let r_r = Fr::random(rng);
        // L = (g′_R)^{a_L} · (h′_L)^{b_R} · u^{cl} · blind^{r_l}
        fill_scal(&mut scal_g, |i| {
            let v = i % m;
            if v >= half {
                mult_g[i] * a_l[v - half]
            } else {
                Fr::ZERO
            }
        });
        fill_scal(&mut scal_h, |i| {
            let v = i % m;
            if v < half { mult_h[i] * b_r[v] } else { Fr::ZERO }
        });
        let l_pt = msm(&basis.g[..n], &scal_g)
            + msm(&basis.h[..n], &scal_h)
            + u.mul(&cl)
            + basis.blind_h.to_projective().mul(&r_l);
        // R = (g′_L)^{a_R} · (h′_R)^{b_L} · u^{cr} · blind^{r_r}
        fill_scal(&mut scal_g, |i| {
            let v = i % m;
            if v < half { mult_g[i] * a_r[v] } else { Fr::ZERO }
        });
        fill_scal(&mut scal_h, |i| {
            let v = i % m;
            if v >= half {
                mult_h[i] * b_l[v - half]
            } else {
                Fr::ZERO
            }
        });
        let r_pt = msm(&basis.g[..n], &scal_g)
            + msm(&basis.h[..n], &scal_h)
            + u.mul(&cr)
            + basis.blind_h.to_projective().mul(&r_r);
        let l_aff = l_pt.to_affine();
        let r_aff = r_pt.to_affine();
        transcript.absorb_point(b"ipa2/L", &l_aff);
        transcript.absorb_point(b"ipa2/R", &r_aff);
        let x = nonzero_challenge(transcript, b"ipa2/x");
        let xi = x.inverse().unwrap();

        let a_next = threads::par_tabulate(half, 1 << 10, Fr::ZERO, |i| x * a_l[i] + xi * a_r[i]);
        let b_next = threads::par_tabulate(half, 1 << 10, Fr::ZERO, |i| xi * b_l[i] + x * b_r[i]);
        update_scal(&mut mult_g, |i, mi| mi * if i % m < half { xi } else { x });
        update_scal(&mut mult_h, |i, mi| mi * if i % m < half { x } else { xi });
        blind = x.square() * r_l + blind + xi.square() * r_r;
        a = a_next;
        b = b_next;
        ls.push(l_aff);
        rs.push(r_aff);
    }

    IpaProof {
        l: ls,
        r: rs,
        a: a[0],
        b: b[0],
        blind,
    }
}

/// Verify ⟨a, b⟩ = t for P = h^blind·G^a·H^b.
///
/// `h_scale`: optional per-element exponent adjustment for the right basis —
/// verifying against the *virtual* basis H′ᵢ = Hᵢ^{h_scale[i]} without ever
/// materializing it (zkReLU's Algorithm-1 basis H^{e^{∘−1}}); the scaling
/// folds into the verifier's single final MSM.
pub fn verify_ip(
    basis: &IpaBasis,
    com: &G1,
    n: usize,
    t: Fr,
    proof: &IpaProof,
    h_scale: Option<&[Fr]>,
    transcript: &mut Transcript,
) -> Result<()> {
    let mut acc = MsmAccumulator::new();
    verify_ip_accum(basis, com, n, t, proof, h_scale, transcript, &mut acc)?;
    ensure!(acc.flush(), "ipa2: final check failed");
    Ok(())
}

/// [`verify_ip`] deferring all group arithmetic into `acc` (same transcript
/// schedule — the commitment is still absorbed).
#[allow(clippy::too_many_arguments)]
pub fn verify_ip_accum(
    basis: &IpaBasis,
    com: &G1,
    n: usize,
    t: Fr,
    proof: &IpaProof,
    h_scale: Option<&[Fr]>,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    transcript.absorb_point(b"ipa2/com", &com.to_affine());
    verify_ip_core(
        &basis.g,
        &basis.h,
        basis.blind_h,
        &basis.label,
        &[(Fr::ONE, *com)],
        None,
        None,
        n,
        t,
        proof,
        h_scale,
        transcript,
        acc,
    )
}

/// Shared deferred two-vector verifier. The commitment P is given
/// symbolically: point terms in `com_terms` plus optional public exponent
/// vectors `g_pub`/`h_pub` on the two bases (zkReLU's G^{−z·1} and
/// H^{w_pub} factors) — none of it is absorbed or materialized here; the
/// caller guarantees every constituent is already transcript-bound. The
/// whole check lands in the accumulator as one equation.
#[allow(clippy::too_many_arguments)]
pub(crate) fn verify_ip_core(
    g: &[G1Affine],
    h: &[G1Affine],
    blind_h: G1Affine,
    label: &[u8],
    com_terms: &[(Fr, G1)],
    g_pub: Option<&[Fr]>,
    h_pub: Option<&[Fr]>,
    n: usize,
    t: Fr,
    proof: &IpaProof,
    h_scale: Option<&[Fr]>,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("ipa/verify_ip");
    ensure!(n.is_power_of_two(), "ipa2: length must be power of two");
    ensure!(
        proof.l.len() == n.trailing_zeros() as usize && proof.r.len() == proof.l.len(),
        "ipa2: wrong number of rounds"
    );
    crate::telemetry::count(
        crate::telemetry::Counter::IpaVerifyRounds,
        proof.l.len() as u64,
    );
    ensure!(g.len() >= n && h.len() >= n, "ipa2: basis too short");
    transcript.absorb_fr(b"ipa2/t", &t);
    transcript.absorb_u64(b"ipa2/n", n as u64);
    let c = nonzero_challenge(transcript, b"ipa2/u-scale");
    let challenges = replay_rounds(proof, b"ipa2/L", b"ipa2/R", b"ipa2/x", transcript);

    let s = s_vector(&challenges);
    // h folds with inverted exponent pattern: s'[i] = 1/s[i]
    let mut s_rec = s.clone();
    Fr::batch_invert(&mut s_rec);

    acc.begin_equation();
    let g_scalars: Vec<Fr> = match g_pub {
        None => threads::par_tabulate(s.len(), 1 << 10, Fr::ZERO, |i| s[i] * proof.a),
        Some(gp) => {
            ensure!(gp.len() == n, "ipa2: g_pub length mismatch");
            threads::par_tabulate(n, 1 << 10, Fr::ZERO, |i| s[i] * proof.a - gp[i])
        }
    };
    acc.push_fixed(&g[..n], &g_scalars);
    let mut h_scalars: Vec<Fr> = match h_scale {
        None => threads::par_tabulate(s_rec.len(), 1 << 10, Fr::ZERO, |i| s_rec[i] * proof.b),
        Some(scale) => {
            ensure!(scale.len() == n, "ipa2: h_scale length mismatch");
            threads::par_tabulate(n, 1 << 10, Fr::ZERO, |i| s_rec[i] * proof.b * scale[i])
        }
    };
    if let Some(hp) = h_pub {
        ensure!(hp.len() == n, "ipa2: h_pub length mismatch");
        update_scal(&mut h_scalars, |i, hs| hs - hp[i]);
    }
    acc.push_fixed(&h[..n], &h_scalars);
    acc.push(c * (proof.a * proof.b - t), ipa_u(label));
    acc.push(proof.blind, blind_h);
    for (coeff, com) in com_terms {
        acc.push_proj(-*coeff, com);
    }
    push_round_terms(acc, proof, &challenges);
    Ok(())
}

// ---------------------------------------------------------------------------
// Claim batching
// ---------------------------------------------------------------------------

/// A pending evaluation claim ⟨S, e⟩ = v (shared `e` across the batch).
pub struct EvalClaim {
    pub com: G1,
    pub values: Vec<Fr>,
    pub blind: Fr,
    pub v: Fr,
}

/// ρ-powered fold of the prover-side claim data: combined (values, blind,
/// value) — the one definition both batching provers share.
fn fold_claims(claims: &[EvalClaim], e_len: usize, rho: Fr) -> (Vec<Fr>, Fr, Fr) {
    // ρ-powers once, then the folded-values build tiles over the vector
    // length (each output index sums its column in claim order — the same
    // additions as the sequential fold, so the same field elements).
    let mut coeffs = Vec::with_capacity(claims.len());
    let mut coeff = Fr::ONE;
    for _ in claims {
        coeffs.push(coeff);
        coeff *= rho;
    }
    let values = threads::par_tabulate(e_len, 1 << 10, Fr::ZERO, |i| {
        claims
            .iter()
            .zip(&coeffs)
            .fold(Fr::ZERO, |acc, (cl, c)| match cl.values.get(i) {
                Some(x) => acc + *c * *x,
                None => acc,
            })
    });
    let mut blind = Fr::ZERO;
    let mut v = Fr::ZERO;
    for (cl, c) in claims.iter().zip(&coeffs) {
        blind += *c * cl.blind;
        v += *c * cl.v;
    }
    (values, blind, v)
}

/// Batch multiple evaluation claims at the *same* public vector `e` into a
/// single claim via a transcript-derived random linear combination, then
/// prove it with one IPA. Returns (combined commitment, combined value,
/// proof).
pub fn batch_prove_eval(
    ck: &CommitKey,
    claims: &[EvalClaim],
    e: &[Fr],
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> (G1, Fr, IpaProof) {
    assert!(!claims.is_empty());
    for cl in claims {
        transcript.absorb_point(b"batch/com", &cl.com.to_affine());
        transcript.absorb_fr(b"batch/v", &cl.v);
    }
    let rho = transcript.challenge_fr(b"batch/rho");
    let (values, blind, v) = fold_claims(claims, e.len(), rho);
    let mut coeff = Fr::ONE;
    let mut com = G1::IDENTITY;
    for cl in claims {
        com = com + cl.com.mul(&coeff);
        coeff *= rho;
    }
    let proof = prove_eval(ck, &com, &values, blind, e, v, transcript, rng);
    (com, v, proof)
}

/// [`batch_prove_eval`] for claims whose commitments are public
/// combinations of already-transcript-bound points: absorbs only the
/// claimed values (the commitments are bound transitively), so the matching
/// verifier ([`batch_verify_eval_expr`]) never materializes a single point.
/// Claim order must match the verifier's exactly.
pub fn batch_prove_eval_expr(
    ck: &CommitKey,
    claims: &[EvalClaim],
    e: &[Fr],
    transcript: &mut Transcript,
    rng: &mut Rng,
) -> IpaProof {
    assert!(!claims.is_empty());
    for cl in claims {
        transcript.absorb_fr(b"batch/v", &cl.v);
    }
    let rho = transcript.challenge_fr(b"batch/rho");
    let (values, blind, v) = fold_claims(claims, e.len(), rho);
    prove_eval_core(ck, &values, blind, e, v, transcript, rng)
}

/// Verifier side of [`batch_prove_eval`]. Thin wrapper: one accumulator,
/// one MSM.
pub fn batch_verify_eval(
    ck: &CommitKey,
    coms_and_values: &[(G1, Fr)],
    e: &[Fr],
    proof: &IpaProof,
    transcript: &mut Transcript,
) -> Result<()> {
    let mut acc = MsmAccumulator::new();
    batch_verify_eval_accum(ck, coms_and_values, e, proof, transcript, &mut acc)?;
    ensure!(acc.flush(), "ipa: batched final check failed");
    Ok(())
}

/// [`batch_verify_eval`] deferring the verification equation into `acc`.
/// Keeps the classic transcript schedule, which absorbs the RLC-combined
/// commitment — materializing it costs one claims-sized MSM; use the
/// `_expr` variant to avoid even that.
pub fn batch_verify_eval_accum(
    ck: &CommitKey,
    coms_and_values: &[(G1, Fr)],
    e: &[Fr],
    proof: &IpaProof,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    ensure!(!coms_and_values.is_empty(), "empty batch");
    for (com, v) in coms_and_values {
        transcript.absorb_point(b"batch/com", &com.to_affine());
        transcript.absorb_fr(b"batch/v", v);
    }
    let rho = transcript.challenge_fr(b"batch/rho");
    let mut coeff = Fr::ONE;
    let mut v = Fr::ZERO;
    let mut expr = ComExpr::default();
    for (c, val) in coms_and_values {
        v += coeff * *val;
        expr.push(coeff, *c);
        coeff *= rho;
    }
    let com = expr.eval();
    verify_eval_accum(ck, &com, e, v, proof, transcript, acc)
}

/// Verifier side of [`batch_prove_eval_expr`]: claims carry symbolic
/// commitments over transcript-bound points, only values are absorbed, and
/// every group term — including the per-claim RLC — defers into `acc`.
/// This is the zkDL verifier's workhorse: zero point operations per call.
pub fn batch_verify_eval_expr(
    ck: &CommitKey,
    claims: &[(ComExpr, Fr)],
    e: &[Fr],
    proof: &IpaProof,
    transcript: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    ensure!(!claims.is_empty(), "empty batch");
    for (_, v) in claims {
        transcript.absorb_fr(b"batch/v", v);
    }
    let rho = transcript.challenge_fr(b"batch/rho");
    let mut coeff = Fr::ONE;
    let mut v = Fr::ZERO;
    let mut com_terms: Vec<(Fr, G1)> = Vec::new();
    for (expr, val) in claims {
        v += coeff * *val;
        for (c, p) in &expr.terms {
            com_terms.push((coeff * *c, *p));
        }
        coeff *= rho;
    }
    verify_eval_core(ck, &com_terms, e, v, proof, transcript, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::{eq_table, Mle};

    fn rng() -> Rng {
        Rng::seed_from_u64(0x19a)
    }

    #[test]
    fn eval_opening_roundtrip() {
        let mut r = rng();
        for n in [2usize, 8, 64] {
            let ck = CommitKey::setup(b"ipa-test", n);
            let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
            let e: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
            let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
            let blind = Fr::random(&mut r);
            let com = ck.commit(&vals, blind);
            let mut tp = Transcript::new(b"t");
            let proof = prove_eval(&ck, &com, &vals, blind, &e, v, &mut tp, &mut r);
            let mut tv = Transcript::new(b"t");
            verify_eval(&ck, &com, &e, v, &proof, &mut tv).expect("verify");
            assert_eq!(proof.l.len(), n.trailing_zeros() as usize);
        }
    }

    #[test]
    fn eval_opening_rejects_wrong_value() {
        let mut r = rng();
        let n = 16;
        let ck = CommitKey::setup(b"ipa-test", n);
        let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let e: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
        let blind = Fr::random(&mut r);
        let com = ck.commit(&vals, blind);
        let wrong = v + Fr::ONE;
        let mut tp = Transcript::new(b"t");
        // a cheating prover proves the wrong value with honest witness
        let proof = prove_eval(&ck, &com, &vals, blind, &e, wrong, &mut tp, &mut r);
        let mut tv = Transcript::new(b"t");
        assert!(verify_eval(&ck, &com, &e, wrong, &proof, &mut tv).is_err());
    }

    #[test]
    fn eval_opening_rejects_tampered_proof() {
        let mut r = rng();
        let n = 16;
        let ck = CommitKey::setup(b"ipa-test", n);
        let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let e: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
        let blind = Fr::random(&mut r);
        let com = ck.commit(&vals, blind);
        let mut tp = Transcript::new(b"t");
        let mut proof = prove_eval(&ck, &com, &vals, blind, &e, v, &mut tp, &mut r);
        proof.a += Fr::ONE;
        let mut tv = Transcript::new(b"t");
        assert!(verify_eval(&ck, &com, &e, v, &proof, &mut tv).is_err());
    }

    #[test]
    fn mle_evaluation_opening() {
        // the real use: open S̃(u) = ⟨S, e(u)⟩ against com_S
        let mut r = rng();
        let nv = 5;
        let n = 1 << nv;
        let ck = CommitKey::setup(b"ipa-test", n);
        let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let mle = Mle::new(vals.clone());
        let u: Vec<Fr> = (0..nv).map(|_| Fr::random(&mut r)).collect();
        let e = eq_table(&u);
        let v = mle.evaluate(&u);
        let blind = Fr::random(&mut r);
        let com = ck.commit(&vals, blind);
        let mut tp = Transcript::new(b"t");
        let proof = prove_eval(&ck, &com, &vals, blind, &e, v, &mut tp, &mut r);
        let mut tv = Transcript::new(b"t");
        verify_eval(&ck, &com, &e, v, &proof, &mut tv).expect("verify");
    }

    #[test]
    fn two_vector_ip_roundtrip() {
        let mut r = rng();
        let n = 32;
        let g = crate::curve::derive_generators(b"ipa2-g", n);
        let h = crate::curve::derive_generators(b"ipa2-h", n);
        let basis = IpaBasis {
            g,
            h,
            blind_h: crate::curve::hash_to_curve(b"ipa2-blind", 0),
            label: b"ipa2".to_vec(),
        };
        let a: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let b: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let t: Fr = a.iter().zip(&b).map(|(x, y)| *x * *y).sum();
        let blind = Fr::random(&mut r);
        let com = basis.commit(&a, &b, blind);
        let mut tp = Transcript::new(b"t2");
        let proof = prove_ip(&basis, &com, &a, &b, blind, t, None, &mut tp, &mut r);
        let mut tv = Transcript::new(b"t2");
        verify_ip(&basis, &com, n, t, &proof, None, &mut tv).expect("verify");
        // wrong t rejected
        let mut tv2 = Transcript::new(b"t2");
        assert!(verify_ip(&basis, &com, n, t + Fr::ONE, &proof, None, &mut tv2).is_err());
    }

    #[test]
    fn batched_eval_claims() {
        let mut r = rng();
        let n = 16;
        let ck = CommitKey::setup(b"ipa-test", n);
        let e: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let mut claims = Vec::new();
        let mut publics = Vec::new();
        for _ in 0..4 {
            let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
            let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
            let blind = Fr::random(&mut r);
            let com = ck.commit(&vals, blind);
            publics.push((com, v));
            claims.push(EvalClaim {
                com,
                values: vals,
                blind,
                v,
            });
        }
        let mut tp = Transcript::new(b"tb");
        let (_, _, proof) = batch_prove_eval(&ck, &claims, &e, &mut tp, &mut r);
        let mut tv = Transcript::new(b"tb");
        batch_verify_eval(&ck, &publics, &e, &proof, &mut tv).expect("verify");
        // a single wrong claimed value breaks the batch
        let mut bad = publics.clone();
        bad[2].1 += Fr::ONE;
        let mut tv2 = Transcript::new(b"tb");
        assert!(batch_verify_eval(&ck, &bad, &e, &proof, &mut tv2).is_err());
    }

    #[test]
    fn expr_batch_defers_to_a_single_shared_msm() {
        let mut r = rng();
        let n = 16;
        let ck = CommitKey::setup(b"ipa-test", n);
        let e: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let mut claims = Vec::new();
        let mut publics: Vec<(ComExpr, Fr)> = Vec::new();
        for _ in 0..3 {
            let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
            let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
            let blind = Fr::random(&mut r);
            let com = ck.commit(&vals, blind);
            publics.push((ComExpr::point(com), v));
            claims.push(EvalClaim {
                com,
                values: vals,
                blind,
                v,
            });
        }
        let mut tp = Transcript::new(b"te");
        let proof = batch_prove_eval_expr(&ck, &claims, &e, &mut tp, &mut r);

        // two independent openings share one accumulator → exactly one MSM
        let mut seed = Rng::seed_from_u64(0xbeef);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        let mut tv = Transcript::new(b"te");
        batch_verify_eval_expr(&ck, &publics, &e, &proof, &mut tv, &mut acc).expect("defer");
        let mut tv_b = Transcript::new(b"te");
        batch_verify_eval_expr(&ck, &publics, &e, &proof, &mut tv_b, &mut acc).expect("defer");
        assert_eq!(acc.flushes(), 0, "no MSM before the flush");
        assert!(acc.flush(), "deferred batch verifies");
        assert_eq!(acc.flushes(), 1);

        // tampering one claimed value must break the deferred batch too
        let mut bad = publics.clone();
        bad[1].1 += Fr::ONE;
        let mut acc2 = MsmAccumulator::from_rng(&mut seed);
        let mut tv2 = Transcript::new(b"te");
        batch_verify_eval_expr(&ck, &bad, &e, &proof, &mut tv2, &mut acc2).expect("defer");
        assert!(!acc2.flush(), "tampered value must fail at the flush");
    }

    #[test]
    fn accum_variants_match_eager_wrappers() {
        // verify_eval (wrapper) and verify_eval_accum agree on accept/reject
        let mut r = rng();
        let n = 8;
        let ck = CommitKey::setup(b"ipa-test", n);
        let vals: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let e: Vec<Fr> = (0..n).map(|_| Fr::random(&mut r)).collect();
        let v: Fr = vals.iter().zip(&e).map(|(a, b)| *a * *b).sum();
        let blind = Fr::random(&mut r);
        let com = ck.commit(&vals, blind);
        let mut tp = Transcript::new(b"ta");
        let proof = prove_eval(&ck, &com, &vals, blind, &e, v, &mut tp, &mut r);
        let mut seed = Rng::seed_from_u64(7);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        let mut tv = Transcript::new(b"ta");
        verify_eval_accum(&ck, &com, &e, v, &proof, &mut tv, &mut acc).expect("defer");
        assert!(acc.flush());
        let mut tv2 = Transcript::new(b"ta");
        verify_eval(&ck, &com, &e, v, &proof, &mut tv2).expect("wrapper verifies");
    }
}
