//! zkOptim update rules — optimizers as *data*, not chain machinery.
//!
//! ISSUE 3's chain argument hard-coded plain SGD: one remainder tensor per
//! boundary, one global learning-rate shift, one digit width everywhere.
//! This module factors the optimizer out of the chain: an [`UpdateRule`]
//! declares, per training step, a set of committed *state tensors* (the
//! momentum accumulator `m` for heavy-ball; none for SGD — weights are
//! already the trace's statement) and, per boundary, a table of linear
//! *update relations*
//!
//! ```text
//!     Σ_k c_k·X_k = 2^{S_b}·(Σ_k d_k·Y_k) + R_j,
//!     R_j ∈ [−2^{S_b−1}, 2^{S_b−1}),
//! ```
//!
//! one per rounded division the optimizer performs, each with its own
//! remainder tensor R_j and per-boundary digit budget S_b. Because the
//! remainder range is exactly the round-to-nearest range of
//! [`crate::model::round_div_pow2`], the decomposition is *unique*:
//! proving every relation proves the exact quantized update, whatever the
//! rule. The chain prover/verifier ([`crate::update`]) consume only this
//! table — a new optimizer is a new relation table, not a new argument.
//!
//! Rules shipped here:
//!
//! * **SGD** — `W_{t+1} = W_t − ⌊G_W/2^{S_b}⌉`, the trivial one-relation
//!   rule, byte-for-byte the semantics of the pre-rule chain;
//! * **heavy-ball momentum** — `m_{t+1} = ⌊β·m_t⌉ + G_W` and
//!   `W_{t+1} = W_t − ⌊m_{t+1}/2^{S_b}⌉` with β = β_num/2^{β_shift} < 1:
//!   two relations, two remainders, one committed state tensor per
//!   (step, layer). Adam's (m, v) pair slots into the same shape — two
//!   state slots, three relations — see DESIGN.md §update.
//!
//! The learning rate is a per-boundary shift table ([`LrSchedule`]):
//! lr at step t = 2^{−shift(t)}, so S_b = R + shift(b) varies across the
//! window and each boundary's remainder gets its own digit budget.

use crate::model::{round_div_pow2, round_div_pow2_i128, ModelConfig, Weights};
use anyhow::{bail, ensure, Result};

/// A committed tensor referenced by a relation at boundary b (the boundary
/// between step b and step b+1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Operand {
    /// Weights W_b entering the boundary (trace commitment, scale 2^R).
    WPrev,
    /// Weights W_{b+1} leaving the boundary.
    WNext,
    /// Weight gradient G_W of step b (trace commitment, scale 2^{2R}).
    GradW,
    /// Rule state tensor `slot` of step b (chain commitment).
    StatePrev(usize),
    /// Rule state tensor `slot` of step b+1.
    StateNext(usize),
}

/// One term c·X of a relation side; coefficients are small signed integers
/// (exact over i128 on the witness side, embedded via `Fr::from_i64` on
/// the field side).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RelTerm {
    pub coeff: i64,
    pub op: Operand,
}

/// Digit budget S_b of a relation's remainder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShiftKind {
    /// S_b = r_bits + lr_shift_b — the learning-rate division, the one
    /// place the per-boundary schedule enters the argument.
    LrSchedule,
    /// S_b = const — boundary-independent divisions (momentum decay).
    Fixed(u32),
}

/// One linear update relation; see the module doc for the equation.
#[derive(Clone, Debug)]
pub struct Relation {
    pub name: &'static str,
    /// Σ_k c_k·X_k — the dividend side.
    pub lhs: Vec<RelTerm>,
    /// Σ_k d_k·Y_k — the side multiplied by 2^{S_b}.
    pub shifted: Vec<RelTerm>,
    pub shift: ShiftKind,
}

impl Relation {
    /// Digit budget at boundary b given the per-boundary lr shift.
    pub fn digits(&self, cfg: &ModelConfig, lr_shift_b: u32) -> u32 {
        match self.shift {
            ShiftKind::LrSchedule => cfg.r_bits + lr_shift_b,
            ShiftKind::Fixed(s) => s,
        }
    }
}

/// Wire tag byte of a rule (part of the artifact statement).
pub const RULE_TAG_SGD: u8 = 1;
/// Wire tag byte of the heavy-ball momentum rule.
pub const RULE_TAG_MOMENTUM: u8 = 2;

/// The optimizer whose exact quantized updates a chained trace proves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UpdateRule {
    /// Plain SGD: W_{t+1} = W_t − ⌊G_W/2^{R+lr_b}⌉.
    Sgd,
    /// Heavy-ball momentum with β = beta_num/2^{beta_shift} < 1:
    /// m_{t+1} = ⌊β·m_t⌉ + G_W,  W_{t+1} = W_t − ⌊m_{t+1}/2^{R+lr_b}⌉.
    Momentum { beta_num: u32, beta_shift: u32 },
}

impl UpdateRule {
    /// Heavy-ball with the conventional β = 7/8.
    pub fn momentum_default() -> Self {
        UpdateRule::Momentum {
            beta_num: 7,
            beta_shift: 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            UpdateRule::Sgd => "sgd",
            UpdateRule::Momentum { .. } => "momentum",
        }
    }

    pub fn tag(&self) -> u8 {
        match self {
            UpdateRule::Sgd => RULE_TAG_SGD,
            UpdateRule::Momentum { .. } => RULE_TAG_MOMENTUM,
        }
    }

    /// Number of rule-owned state tensors committed per (step, layer).
    pub fn n_state(&self) -> usize {
        match self {
            UpdateRule::Sgd => 0,
            UpdateRule::Momentum { .. } => 1,
        }
    }

    /// Display names of the state slots (for CLI/report output).
    pub fn state_names(&self) -> &'static [&'static str] {
        match self {
            UpdateRule::Sgd => &[],
            UpdateRule::Momentum { .. } => &["m"],
        }
    }

    /// Number of update relations — remainder tensors per (boundary, layer).
    pub fn n_rem(&self) -> usize {
        self.relations().len()
    }

    /// The relation table (see the module doc for the derivations).
    pub fn relations(&self) -> Vec<Relation> {
        match *self {
            // G_W = 2^{S_b}·(W_b − W_{b+1}) + R
            UpdateRule::Sgd => vec![Relation {
                name: "sgd-step",
                lhs: vec![RelTerm {
                    coeff: 1,
                    op: Operand::GradW,
                }],
                shifted: vec![
                    RelTerm {
                        coeff: 1,
                        op: Operand::WPrev,
                    },
                    RelTerm {
                        coeff: -1,
                        op: Operand::WNext,
                    },
                ],
                shift: ShiftKind::LrSchedule,
            }],
            // β_num·m_b = 2^{β_shift}·(m_{b+1} − G_W) + R_m
            // m_{b+1}   = 2^{S_b}·(W_b − W_{b+1}) + R_w
            UpdateRule::Momentum {
                beta_num,
                beta_shift,
            } => vec![
                Relation {
                    name: "momentum-accum",
                    lhs: vec![RelTerm {
                        coeff: beta_num as i64,
                        op: Operand::StatePrev(0),
                    }],
                    shifted: vec![
                        RelTerm {
                            coeff: 1,
                            op: Operand::StateNext(0),
                        },
                        RelTerm {
                            coeff: -1,
                            op: Operand::GradW,
                        },
                    ],
                    shift: ShiftKind::Fixed(beta_shift),
                },
                Relation {
                    name: "momentum-step",
                    lhs: vec![RelTerm {
                        coeff: 1,
                        op: Operand::StateNext(0),
                    }],
                    shifted: vec![
                        RelTerm {
                            coeff: 1,
                            op: Operand::WPrev,
                        },
                        RelTerm {
                            coeff: -1,
                            op: Operand::WNext,
                        },
                    ],
                    shift: ShiftKind::LrSchedule,
                },
            ],
        }
    }

    /// Reject malformed rule parameters (decoded artifacts reach this
    /// before any key setup).
    pub fn validate(&self) -> Result<()> {
        match *self {
            UpdateRule::Sgd => Ok(()),
            UpdateRule::Momentum {
                beta_num,
                beta_shift,
            } => {
                // β_shift is a Fixed digit budget: zkReLU needs ≥ 2 digits
                // and the i64 remainder embedding caps it at 64; β < 1
                // keeps the accumulator geometrically bounded.
                ensure!(
                    (2..=63).contains(&beta_shift),
                    "momentum beta_shift {beta_shift} outside 2..=63"
                );
                ensure!(
                    beta_num >= 1 && (beta_num as u64) < (1u64 << beta_shift),
                    "momentum beta {beta_num}/2^{beta_shift} not in (0, 1)"
                );
                Ok(())
            }
        }
    }

    /// Canonical descriptor bytes: tag ‖ params. Pins commitment-key and
    /// validity-basis cache entries and feeds the transcript, so distinct
    /// rules can never share bases or challenges.
    pub fn descriptor_bytes(&self) -> Vec<u8> {
        match *self {
            UpdateRule::Sgd => vec![RULE_TAG_SGD],
            UpdateRule::Momentum {
                beta_num,
                beta_shift,
            } => {
                let mut out = vec![RULE_TAG_MOMENTUM];
                out.extend_from_slice(&beta_num.to_le_bytes());
                out.extend_from_slice(&beta_shift.to_le_bytes());
                out
            }
        }
    }

    /// Zero-initialized optimizer state (the canonical start of a run; a
    /// mid-run window's state commitment is part of its statement, like
    /// W_0 itself).
    pub fn init_state(&self, cfg: &ModelConfig) -> Vec<Vec<Vec<i64>>> {
        let d2 = cfg.width * cfg.width;
        (0..self.n_state())
            .map(|_| (0..cfg.depth).map(|_| vec![0i64; d2]).collect())
            .collect()
    }

    /// Apply one step's exact quantized update in place — the integer
    /// semantics the chain argument proves. `lr_shift_b` is this
    /// boundary's schedule entry; `grads` are the step's G_W tensors.
    /// Panics if a momentum accumulator overflows i64 (scale down inputs),
    /// mirroring the matmul overflow policy.
    pub fn apply_update(
        &self,
        lr_shift_b: u32,
        weights: &mut Weights,
        state: &mut [Vec<Vec<i64>>],
        grads: &[Vec<i64>],
    ) {
        let cfg = weights.cfg;
        let s_bits = cfg.r_bits + lr_shift_b;
        assert_eq!(grads.len(), cfg.depth);
        assert_eq!(state.len(), self.n_state());
        match *self {
            UpdateRule::Sgd => {
                for (w, g) in weights.layers.iter_mut().zip(grads.iter()) {
                    for (wi, gi) in w.iter_mut().zip(g.iter()) {
                        *wi -= round_div_pow2(*gi, s_bits);
                    }
                }
            }
            UpdateRule::Momentum {
                beta_num,
                beta_shift,
            } => {
                let m_state = &mut state[0];
                for l in 0..cfg.depth {
                    let (w, m, g) = (&mut weights.layers[l], &mut m_state[l], &grads[l]);
                    for i in 0..g.len() {
                        let decayed =
                            round_div_pow2_i128(beta_num as i128 * m[i] as i128, beta_shift);
                        m[i] = i64::try_from(decayed + g[i] as i128)
                            .expect("momentum accumulator overflow: scale down inputs");
                        w[i] -= round_div_pow2(m[i], s_bits);
                    }
                }
            }
        }
    }
}

/// Per-step learning-rate schedule: lr at step t = 2^{−shift_at(t)}.
/// A *decaying* learning rate is an *increasing* shift.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LrSchedule {
    /// The same shift at every step (the pre-schedule behavior when set to
    /// `cfg.lr_shift`).
    Constant(u32),
    /// shift(t) = min(base + t/period, max): the lr halves every `period`
    /// steps until it reaches 2^{−max}.
    StepDecay { base: u32, period: usize, max: u32 },
}

impl LrSchedule {
    pub fn shift_at(&self, step: usize) -> u32 {
        match *self {
            LrSchedule::Constant(s) => s,
            LrSchedule::StepDecay { base, period, max } => {
                let bump = (step / period.max(1)) as u64;
                let shifted = (base as u64).saturating_add(bump);
                shifted.min(max as u64) as u32
            }
        }
    }

    /// The explicit shift table a window's chain proof carries: one entry
    /// per boundary, boundary b of a window starting at `start_step` being
    /// the update applied after global step `start_step + b`.
    pub fn window_table(&self, start_step: usize, boundaries: usize) -> Vec<u32> {
        (0..boundaries)
            .map(|b| self.shift_at(start_step + b))
            .collect()
    }

    /// Parse the CLI spec: `"8"` or `"const:8"` for a constant shift,
    /// `"decay:base,period,max"` (e.g. `decay:6,2,12`) for step decay.
    pub fn parse(spec: &str) -> Result<Self> {
        if let Some(rest) = spec.strip_prefix("decay:") {
            let parts: Vec<&str> = rest.split(',').collect();
            ensure!(
                parts.len() == 3,
                "lr-schedule decay wants base,period,max — got {spec:?}"
            );
            let base: u32 = parts[0].parse()?;
            let period: usize = parts[1].parse()?;
            let max: u32 = parts[2].parse()?;
            ensure!(period >= 1, "lr-schedule decay period must be ≥ 1");
            ensure!(max >= base, "lr-schedule decay max {max} below base {base}");
            Ok(LrSchedule::StepDecay { base, period, max })
        } else {
            let plain = spec.strip_prefix("const:").unwrap_or(spec);
            match plain.parse::<u32>() {
                Ok(s) => Ok(LrSchedule::Constant(s)),
                Err(_) => bail!("unrecognized lr-schedule {spec:?} (want N, const:N, or decay:base,period,max)"),
            }
        }
    }
}

/// Validate a per-boundary shift table against the provable digit range:
/// every S_b = r_bits + shift_b (and every fixed relation budget) must be
/// a signed digit count in 2..=64 — beyond 64 the i64 remainder embedding
/// and the i128 witness arithmetic lose exactness, so such schedules are
/// refused at prove, verify, *and* decode time.
pub fn validate_shift_table(cfg: &ModelConfig, rule: &UpdateRule, lr_shifts: &[u32]) -> Result<()> {
    rule.validate()?;
    for rel in rule.relations() {
        for (b, &shift) in lr_shifts.iter().enumerate() {
            let s = rel.digits(cfg, shift) as u64;
            ensure!(
                (2..=64).contains(&s),
                "relation {} digit budget {s} at boundary {b} outside the provable 2..=64",
                rel.name
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rule_shapes() {
        assert_eq!(UpdateRule::Sgd.n_rem(), 1);
        assert_eq!(UpdateRule::Sgd.n_state(), 0);
        let m = UpdateRule::momentum_default();
        assert_eq!(m.n_rem(), 2);
        assert_eq!(m.n_state(), 1);
        assert_ne!(
            UpdateRule::Sgd.descriptor_bytes(),
            m.descriptor_bytes(),
            "descriptors separate rules"
        );
        m.validate().unwrap();
        assert!(UpdateRule::Momentum {
            beta_num: 8,
            beta_shift: 3
        }
        .validate()
        .is_err());
        assert!(UpdateRule::Momentum {
            beta_num: 1,
            beta_shift: 1
        }
        .validate()
        .is_err());
    }

    #[test]
    fn sgd_apply_matches_legacy_weights_update() {
        let cfg = ModelConfig::new(2, 8, 4);
        let mut rng = Rng::seed_from_u64(0x5d);
        let mut a = Weights::init(cfg, &mut rng);
        let mut b = a.clone();
        let grads: Vec<Vec<i64>> = (0..cfg.depth)
            .map(|_| {
                (0..cfg.width * cfg.width)
                    .map(|_| rng.gen_i64(-(1 << 40), 1 << 40))
                    .collect()
            })
            .collect();
        a.apply_update(&grads);
        let mut state = UpdateRule::Sgd.init_state(&cfg);
        UpdateRule::Sgd.apply_update(cfg.lr_shift, &mut b, &mut state, &grads);
        assert_eq!(a.layers, b.layers, "trivial rule = legacy SGD update");
    }

    #[test]
    fn momentum_update_satisfies_its_relations() {
        let cfg = ModelConfig::new(1, 2, 2);
        let rule = UpdateRule::momentum_default();
        let (bn, bs) = (7i128, 3u32);
        let mut rng = Rng::seed_from_u64(0x6d);
        let mut w = Weights {
            layers: vec![(0..4).map(|_| rng.gen_i64(-1000, 1000)).collect()],
            cfg,
        };
        let mut state = rule.init_state(&cfg);
        state[0][0] = (0..4).map(|_| rng.gen_i64(-(1 << 30), 1 << 30)).collect();
        let grads = vec![(0..4).map(|_| rng.gen_i64(-(1 << 38), 1 << 38)).collect::<Vec<i64>>()];
        let (w0, m0) = (w.layers[0].clone(), state[0][0].clone());
        let lr_b = 9u32;
        rule.apply_update(lr_b, &mut w, &mut state, &grads);
        let s_bits = cfg.r_bits + lr_b;
        for i in 0..4 {
            let (m1, w1) = (state[0][0][i], w.layers[0][i]);
            // β_num·m0 = 2^{βs}·(m1 − g) + R_m with R_m in range
            let r_m = bn * m0[i] as i128 - ((m1 - grads[0][i]) as i128) * (1i128 << bs);
            assert!((-(1i128 << (bs - 1))..(1i128 << (bs - 1))).contains(&r_m), "i={i}");
            // m1 = 2^{S}·(w0 − w1) + R_w with R_w in range
            let r_w = m1 as i128 - ((w0[i] - w1) as i128) * (1i128 << s_bits);
            let half = 1i128 << (s_bits - 1);
            assert!((-half..half).contains(&r_w), "i={i}");
        }
    }

    #[test]
    fn schedule_shapes_and_parsing() {
        let s = LrSchedule::StepDecay {
            base: 6,
            period: 2,
            max: 8,
        };
        assert_eq!(
            (0..7).map(|t| s.shift_at(t)).collect::<Vec<_>>(),
            vec![6, 6, 7, 7, 8, 8, 8]
        );
        assert_eq!(s.window_table(2, 3), vec![7, 7, 8]);
        assert_eq!(LrSchedule::parse("8").unwrap(), LrSchedule::Constant(8));
        assert_eq!(
            LrSchedule::parse("const:11").unwrap(),
            LrSchedule::Constant(11)
        );
        assert_eq!(
            LrSchedule::parse("decay:6,2,12").unwrap(),
            LrSchedule::StepDecay {
                base: 6,
                period: 2,
                max: 12
            }
        );
        assert!(LrSchedule::parse("warmup:3").is_err());
        assert!(LrSchedule::parse("decay:6,0,12").is_err());
    }

    #[test]
    fn shift_table_rejects_unprovable_widths() {
        let cfg = ModelConfig::new(2, 8, 4); // R = 16
        let rule = UpdateRule::Sgd;
        validate_shift_table(&cfg, &rule, &[8, 9, 48]).expect("S ≤ 64 ok");
        // S = 16 + 49 = 65 > 64: refused
        assert!(validate_shift_table(&cfg, &rule, &[8, 49]).is_err());
    }
}
