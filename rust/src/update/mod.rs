//! zkSGD — weight-update chaining for end-to-end verifiable training traces.
//!
//! A plain [`crate::aggregate::TraceProof`] certifies T *independent* SGD
//! steps: each step is proven against its own committed weights, and nothing
//! ties step t+1's weights to step t's update. This module closes that gap
//! with the paper's own zkReLU recipe (§4.1: turn a non-arithmetic relation
//! into a committed auxiliary decomposition). The coordinator's quantized
//! update W_{t+1} = W_t − ⌊G_W / 2^{R+lr}⌉ rounds, so it is not linear over
//! the committed integers — but its *decomposition* is:
//!
//! ```text
//! G_W = 2^S·(W_t − W_{t+1}) + R,   R ∈ [−2^{S−1}, 2^{S−1}),  S = R+lr,
//! ```
//!
//! and the remainder range makes the decomposition unique: proving it proves
//! the exact rounded update. Per step boundary t→t+1 and layer ℓ the prover
//! commits the remainder tensor R (d² entries) into block (t·L̄ + ℓ) of a
//! stacked basis, then
//!
//! * **linear part, checked homomorphically against the already-committed
//!   tensors**: one transcript point p over the d² weight-index space; the
//!   batched-opening engine opens every W̃_t(p) and G̃_W(p) (one RLC'd IPA on
//!   the shared `zkdl/mat` basis) and opens each R̃(p) against the claimed
//!   value G̃_W(p) − 2^S·(W̃_t(p) − W̃_{t+1}(p)) — the verifier *derives* the
//!   remainder claims from the weight/gradient claims, so the boundary
//!   relation holds iff the openings do (Schwartz–Zippel over p);
//! * **range part**: the stacked remainders feed one zkReLU Protocol-1 /
//!   Algorithm-1 validity instance over the padded digit basis
//!   ([`crate::zkrelu::s_basis_digits`]): S = R+lr bits is not a power of
//!   two, so the instance uses width S̄ = 2^⌈log S⌉ with zero-weight pad
//!   columns — the pattern check forces pad bits to zero, keeping the proven
//!   range *exactly* [−2^{S−1}, 2^{S−1}).
//!
//! Everything defers into the trace's `MsmAccumulator`: a chained
//! `TraceProof` still verifies with exactly one MSM flush. See
//! DESIGN.md §update.

use crate::aggregate::StepCommitmentSet;
use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::{G1, G1Affine};
use crate::field::Fr;
use crate::ipa::{self, EvalClaim, IpaProof};
use crate::model::ModelConfig;
use crate::poly::eq_table;
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use crate::witness::StepWitness;
use crate::zkdl::{commit, frs, tile_claims_at, tiled_eq, Committed};
use crate::zkrelu::{self, Protocol1Msg, ProverAux, ValidityBases, ValidityProof};
use anyhow::{ensure, Context, Result};

/// Padded boundary count B̄ = (T−1)̄, padded layer count L̄, and the stacked
/// remainder size N_U = B̄·L̄·d². Boundary b's layer ℓ owns block (b·L̄ + ℓ).
pub fn update_stack_dims(cfg: &ModelConfig, steps: usize) -> (usize, usize, usize) {
    assert!(steps >= 2, "chaining needs at least two steps");
    let bbar = (steps - 1).next_power_of_two();
    let lbar = cfg.depth.next_power_of_two();
    let n = bbar * lbar * cfg.width * cfg.width;
    assert!(n >= 2, "degenerate update stack");
    (bbar, lbar, n)
}

/// Active digit count S = R + lr of an update remainder and the padded
/// power-of-two decomposition width the validity instance runs at.
pub fn update_widths(cfg: &ModelConfig) -> (usize, usize) {
    let digits = (cfg.r_bits + cfg.lr_shift) as usize;
    (digits, digits.next_power_of_two())
}

/// Commitment basis for the stacked update remainders of a T-step trace.
pub struct UpdateKey {
    pub cfg: ModelConfig,
    /// Number of live steps T (T−1 live boundaries).
    pub steps: usize,
    /// Stacked remainder basis, length B̄·L̄·d².
    pub g_upd: CommitKey,
}

impl UpdateKey {
    pub fn setup(cfg: ModelConfig, steps: usize) -> Self {
        let (_, _, n) = update_stack_dims(&cfg, steps);
        Self {
            cfg,
            steps,
            g_upd: CommitKey::setup(b"zkdl/trace-aux/upd", n),
        }
    }

    /// Commitment key slice for boundary b / layer ℓ's remainder block.
    pub fn block(&self, b: usize, l: usize) -> CommitKey {
        let d2 = self.cfg.width * self.cfg.width;
        let lbar = self.cfg.depth.next_power_of_two();
        let s = b * lbar + l;
        CommitKey {
            g: self.g_upd.g[s * d2..(s + 1) * d2].to_vec(),
            h: self.g_upd.h,
            label: self.g_upd.label.clone(),
        }
    }
}

/// Validity bases for the remainder range instance; the label pins (T, L)
/// like the trace validity labels do.
fn update_validity_bases(uk: &UpdateKey) -> ValidityBases {
    let (_, _, n) = update_stack_dims(&uk.cfg, uk.steps);
    let (digits, width) = update_widths(&uk.cfg);
    let t = uk.steps as u64;
    let l = uk.cfg.depth as u64;
    let label = [
        b"zkdl/trace/validity/upd/".as_ref(),
        &t.to_le_bytes(),
        &l.to_le_bytes(),
    ]
    .concat();
    ValidityBases::setup_plain_digits(&label, uk.g_upd.h, n / 2, width, digits)
}

/// 2^S as a field scalar, S = R + lr.
fn two_s(cfg: &ModelConfig) -> Fr {
    Fr::from_u128(1u128 << (cfg.r_bits + cfg.lr_shift))
}

fn dot(a: &[Fr], b: &[Fr]) -> Fr {
    a.iter().zip(b.iter()).map(|(x, y)| *x * *y).sum()
}

/// The chain argument appended to a [`crate::aggregate::TraceProof`].
#[derive(Clone, Debug)]
pub struct ChainProof {
    /// Per-boundary, per-layer remainder commitments, (T−1)×L.
    pub com_ru: Vec<Vec<G1Affine>>,
    pub p1_upd: Protocol1Msg,
    /// W̃ evaluations at the boundary point, step-major, length T·L.
    pub v_w: Vec<Fr>,
    /// G̃_W evaluations at the boundary point for steps 0..T−1, (T−1)·L.
    pub v_gw: Vec<Fr>,
    /// Stacked R̃ evaluation at the validity point.
    pub v_stack: Fr,
    /// Opening IPAs: [W+G_W @ p, R @ p (tiled), stacked R @ validity point].
    pub openings: Vec<IpaProof>,
    pub validity: ValidityProof,
}

impl ChainProof {
    /// Compressed-point accounting, matching
    /// [`crate::aggregate::TraceProof::size_bytes`].
    pub fn size_bytes(&self) -> usize {
        let coms: usize = self.com_ru.iter().map(|row| row.len()).sum();
        let scalars = self.v_w.len() + self.v_gw.len() + 1;
        let openings: usize = self.openings.iter().map(|o| o.size_bytes()).sum();
        (coms + scalars) * 32 + 32 + openings + self.validity.size_bytes()
    }
}

/// Prover-side chain witness: one remainder tensor per (boundary, layer).
pub struct ChainWitness {
    /// (T−1) × L × d² remainders, embedded in 𝔽.
    pub rems: Vec<Vec<Vec<Fr>>>,
}

impl ChainWitness {
    /// Compute the remainders from consecutive step witnesses
    /// ([`crate::witness::chain_remainders`]), failing if any boundary's
    /// weights are not the exact rounded update.
    pub fn build(wits: &[StepWitness]) -> Result<Self> {
        ensure!(wits.len() >= 2, "chaining needs at least two steps");
        let rems: Vec<Vec<Vec<Fr>>> = crate::witness::chain_remainders(wits)?
            .iter()
            .map(|per_layer| per_layer.iter().map(|r| frs(r)).collect())
            .collect();
        Ok(Self { rems })
    }
}

/// Prover-side commitments of the chain, produced before any transcript
/// challenge is drawn (the trace absorbs them up front, alongside the step
/// commitments, so the shared-randomness property extends to the chain).
pub(crate) struct ChainCommitments {
    pub(crate) ru: Vec<Vec<Committed>>,
    pub(crate) com_ru: Vec<Vec<G1Affine>>,
    pub(crate) p1: Protocol1Msg,
    pub(crate) aux: ProverAux,
    /// The stacked remainder tensor, length N_U (padding slots zero).
    pub(crate) stacked: Vec<Fr>,
}

pub(crate) fn commit_chain(uk: &UpdateKey, cw: &ChainWitness, rng: &mut Rng) -> ChainCommitments {
    let cfg = &uk.cfg;
    let depth = cfg.depth;
    let d2 = cfg.width * cfg.width;
    let (_, lbar, n_upd) = update_stack_dims(cfg, uk.steps);
    assert_eq!(cw.rems.len(), uk.steps - 1, "boundary count mismatch");
    let mut ru = Vec::with_capacity(cw.rems.len());
    let mut stacked = vec![Fr::ZERO; n_upd];
    for (b, per_layer) in cw.rems.iter().enumerate() {
        assert_eq!(per_layer.len(), depth, "layer count mismatch");
        let mut row = Vec::with_capacity(depth);
        for (l, vals) in per_layer.iter().enumerate() {
            let s = b * lbar + l;
            stacked[s * d2..(s + 1) * d2].copy_from_slice(vals);
            row.push(commit(&uk.block(b, l), vals.clone(), rng));
        }
        ru.push(row);
    }
    let com_ru: Vec<Vec<G1Affine>> = ru
        .iter()
        .map(|row| G1::batch_to_affine(&row.iter().map(|c| c.com).collect::<Vec<_>>()))
        .collect();
    let vb = update_validity_bases(uk);
    let (p1, aux) = zkrelu::protocol1_plain(&vb, &stacked, rng);
    ChainCommitments {
        ru,
        com_ru,
        p1,
        aux,
        stacked,
    }
}

/// Absorb the chain's remainder commitments (call sites: right after the
/// per-step commitment sets, before Protocol 1 / any challenge).
pub(crate) fn absorb_chain_ru(tr: &mut Transcript, com_ru: &[Vec<G1Affine>]) {
    for (b, row) in com_ru.iter().enumerate() {
        tr.absorb_u64(b"chain/boundary", b as u64);
        tr.absorb_points(b"com/ru", row);
    }
}

/// The chain argument proper, appended after the trace's Phase 4. `w` and
/// `gw` are the per-step weight / weight-gradient commitments on `g_mat`
/// (the same objects the trace's matmul openings use).
pub(crate) fn prove_chain(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    w: &[&[Committed]],
    gw: &[&[Committed]],
    cc: &ChainCommitments,
    tr: &mut Transcript,
    rng: &mut Rng,
) -> ChainProof {
    let cfg = &uk.cfg;
    let t_steps = uk.steps;
    let depth = cfg.depth;
    let d2 = cfg.width * cfg.width;
    let log_d2 = d2.trailing_zeros() as usize;
    let (bbar, lbar, n_upd) = update_stack_dims(cfg, t_steps);
    let slots = bbar * lbar;
    let nb = t_steps - 1;
    let two_s = two_s(cfg);

    // one boundary point over the d² weight-index space, shared by every
    // (boundary, layer) — the chain analogue of the trace-global bundle
    let p_u = tr.challenge_frs(b"upd/p", log_d2);
    let e_u = eq_table(&p_u);

    let mut v_w = Vec::with_capacity(t_steps * depth);
    for step in w.iter().take(t_steps) {
        for c in step.iter().take(depth) {
            v_w.push(dot(&c.values, &e_u));
        }
    }
    let mut v_gw = Vec::with_capacity(nb * depth);
    for step in gw.iter().take(nb) {
        for c in step.iter().take(depth) {
            v_gw.push(dot(&c.values, &e_u));
        }
    }
    // derived remainder evaluations — the linear boundary relation at p:
    // R̃(p) = G̃_W(p) − 2^S·(W̃_t(p) − W̃_{t+1}(p))
    let mut v_ru = Vec::with_capacity(nb * depth);
    for b in 0..nb {
        for l in 0..depth {
            let v = v_gw[b * depth + l] - two_s * (v_w[b * depth + l] - v_w[(b + 1) * depth + l]);
            debug_assert_eq!(v, dot(&cc.ru[b][l].values, &e_u), "chain witness drift");
            v_ru.push(v);
        }
    }

    let mut openings = Vec::with_capacity(3);
    // U1: every W̃_t(p) and G̃_W(p) on the shared g_mat basis, one RLC'd IPA
    {
        let mut claims = Vec::with_capacity((t_steps + nb) * depth);
        for (t, step) in w.iter().enumerate().take(t_steps) {
            for (l, c) in step.iter().enumerate().take(depth) {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_w[t * depth + l],
                });
            }
        }
        for (b, step) in gw.iter().enumerate().take(nb) {
            for (l, c) in step.iter().enumerate().take(depth) {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_gw[b * depth + l],
                });
            }
        }
        openings.push(ipa::batch_prove_eval_expr(g_mat, &claims, &e_u, tr, rng));
    }
    // U2: each remainder block at p, tiled over the stacked basis
    {
        let mut claims = Vec::with_capacity(nb * depth);
        let mut slot_idx = Vec::with_capacity(nb * depth);
        for (b, row) in cc.ru.iter().enumerate() {
            for (l, c) in row.iter().enumerate() {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_ru[b * depth + l],
                });
                slot_idx.push(b * lbar + l);
            }
        }
        openings.push(ipa::batch_prove_eval_expr(
            &uk.g_upd,
            &tile_claims_at(claims, &slot_idx, slots, d2),
            &tiled_eq(&p_u, slots),
            tr,
            rng,
        ));
    }
    // validity point over the stacked remainder tensor
    let u_dd = tr.challenge_fr(b"upd/u_dd");
    let log_n = n_upd.trailing_zeros() as usize;
    let rho = tr.challenge_frs(b"upd/rho", log_n - 1);
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    // ⟨stacked, e(vpoint)⟩ IS the MLE evaluation — no tensor copy needed
    let v_stack = dot(&cc.stacked, &e_row);
    // U3: the stacked opening binding v_stack to the summed commitments
    {
        let mut com = G1::IDENTITY;
        let mut blind = Fr::ZERO;
        for row in &cc.ru {
            for c in row {
                com = com + c.com;
                blind += c.blind;
            }
        }
        let claim = EvalClaim {
            com,
            values: cc.stacked.clone(),
            blind,
            v: v_stack,
        };
        openings.push(ipa::batch_prove_eval_expr(&uk.g_upd, &[claim], &e_row, tr, rng));
    }
    let vb = update_validity_bases(uk);
    let validity = zkrelu::prove_validity(&vb, &cc.aux, &e_row, u_dd, v_stack, Fr::ZERO, tr, rng);

    ChainProof {
        com_ru: cc.com_ru.clone(),
        p1_upd: cc.p1.clone(),
        v_w,
        v_gw,
        v_stack,
        openings,
        validity,
    }
}

/// Transcript replay + deferred checks of the chain argument (mirrors
/// [`prove_chain`] exactly). No curve arithmetic: every group equation —
/// the three batched openings and the validity instance — lands in `acc`,
/// preserving the trace's one-MSM invariant.
pub(crate) fn verify_chain_accum(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    coms: &[StepCommitmentSet],
    chain: &ChainProof,
    tr: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    let cfg = &uk.cfg;
    let t_steps = uk.steps;
    let depth = cfg.depth;
    let log_d2 = (cfg.width * cfg.width).trailing_zeros() as usize;
    let (bbar, lbar, n_upd) = update_stack_dims(cfg, t_steps);
    let slots = bbar * lbar;
    let nb = t_steps - 1;

    ensure!(coms.len() == t_steps, "chain: step commitment count");
    ensure!(chain.com_ru.len() == nb, "chain: boundary count");
    for row in &chain.com_ru {
        ensure!(row.len() == depth, "chain: per-boundary layer count");
    }
    ensure!(chain.v_w.len() == t_steps * depth, "chain: v_w length");
    ensure!(chain.v_gw.len() == nb * depth, "chain: v_gw length");
    ensure!(chain.openings.len() == 3, "chain: opening count");
    ensure!(
        chain.p1_upd.com_sign_prime.is_none(),
        "chain: unexpected sign coupling"
    );

    let two_s = two_s(cfg);
    let p_u = tr.challenge_frs(b"upd/p", log_d2);
    let e_u = eq_table(&p_u);

    // the boundary relation *defines* the remainder claims
    let mut v_ru = Vec::with_capacity(nb * depth);
    for b in 0..nb {
        for l in 0..depth {
            v_ru.push(
                chain.v_gw[b * depth + l]
                    - two_s * (chain.v_w[b * depth + l] - chain.v_w[(b + 1) * depth + l]),
            );
        }
    }

    // U1
    {
        let mut claims = Vec::with_capacity((t_steps + nb) * depth);
        for (t, set) in coms.iter().enumerate() {
            for l in 0..depth {
                claims.push((
                    ComExpr::point(set.com_w[l].to_projective()),
                    chain.v_w[t * depth + l],
                ));
            }
        }
        for (b, set) in coms.iter().enumerate().take(nb) {
            for l in 0..depth {
                claims.push((
                    ComExpr::point(set.com_gw[l].to_projective()),
                    chain.v_gw[b * depth + l],
                ));
            }
        }
        ipa::batch_verify_eval_expr(g_mat, &claims, &e_u, &chain.openings[0], tr, acc)
            .context("chain boundary opening")?;
    }
    // U2
    {
        let mut claims = Vec::with_capacity(nb * depth);
        for (b, row) in chain.com_ru.iter().enumerate() {
            for (l, p) in row.iter().enumerate() {
                claims.push((ComExpr::point(p.to_projective()), v_ru[b * depth + l]));
            }
        }
        ipa::batch_verify_eval_expr(
            &uk.g_upd,
            &claims,
            &tiled_eq(&p_u, slots),
            &chain.openings[1],
            tr,
            acc,
        )
        .context("chain remainder opening")?;
    }
    let u_dd = tr.challenge_fr(b"upd/u_dd");
    let log_n = n_upd.trailing_zeros() as usize;
    let rho = tr.challenge_frs(b"upd/rho", log_n - 1);
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    // U3
    {
        let stack = ComExpr::sum(
            chain
                .com_ru
                .iter()
                .flat_map(|row| row.iter().map(|p| p.to_projective())),
        );
        ipa::batch_verify_eval_expr(
            &uk.g_upd,
            &[(stack, chain.v_stack)],
            &e_row,
            &chain.openings[2],
            tr,
            acc,
        )
        .context("chain stacked opening")?;
    }
    let vb = update_validity_bases(uk);
    zkrelu::verify_validity_accum(
        &vb,
        &chain.p1_upd,
        None,
        &e_row,
        u_dd,
        chain.v_stack,
        Fr::ZERO,
        &chain.validity,
        tr,
        acc,
    )
    .context("chain validity")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_pad_boundaries_and_layers() {
        let cfg = ModelConfig::new(3, 8, 4);
        let (bbar, lbar, n) = update_stack_dims(&cfg, 4);
        assert_eq!((bbar, lbar), (4, 4)); // 3 boundaries pad to 4
        assert_eq!(n, 4 * 4 * 64);
        let (digits, width) = update_widths(&cfg);
        assert_eq!(digits, 24); // R=16 + lr=8
        assert_eq!(width, 32);
    }

    #[test]
    fn chain_witness_rejects_broken_boundary() {
        use crate::data::Dataset;
        use crate::witness::native::sgd_witness_chain;
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(64, 4, 4, cfg.r_bits, 9);
        let mut wits = sgd_witness_chain(cfg, &ds, 3, 0xc4a1);
        assert!(ChainWitness::build(&wits).is_ok());
        crate::witness::validate_chain(&wits).expect("honest chain validates");
        // perturb one weight of step 1: boundary 0 no longer chains
        wits[1].layers[0].w[5] += 1;
        assert!(ChainWitness::build(&wits).is_err());
        assert!(crate::witness::validate_chain(&wits).is_err());
    }
}
