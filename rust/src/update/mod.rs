//! zkOptim — rule-driven weight-update chaining for end-to-end verifiable
//! training traces.
//!
//! A plain [`crate::aggregate::TraceProof`] certifies T *independent*
//! training steps: each step is proven against its own committed weights,
//! and nothing ties step t+1's weights to step t's update. This module
//! closes that gap with the paper's own zkReLU recipe (§4.1: turn a
//! non-arithmetic relation into a committed auxiliary decomposition),
//! generalized from the original zkSGD argument to any optimizer expressed
//! as an [`UpdateRule`]: a table of linear update relations
//!
//! ```text
//! Σ_k c_k·X_k = 2^{S_{j,b}}·(Σ_k d_k·Y_k) + R_j,
//! R_j ∈ [−2^{S_{j,b}−1}, 2^{S_{j,b}−1}),
//! ```
//!
//! one per rounded division the optimizer performs at boundary b (plain
//! SGD: one; heavy-ball momentum: two, with a committed accumulator tensor
//! m per step), each with its own remainder tensor and per-boundary digit
//! budget S_{j,b} = R + lr_shift_b for the learning-rate relation — so
//! per-step lr schedules are first-class. The remainder ranges make every
//! decomposition unique: proving the relations proves the exact quantized
//! updates.
//!
//! The prover lays every (boundary, layer, relation) remainder tensor
//! (d² entries, slot (b·L̄ + ℓ)·R̄ + j) into ONE stacked tensor U of size
//! B̄·L̄·R̄·d² and commits it with a single Pedersen commitment `com_u` on
//! the rule-labelled `zkdl/trace-aux/upd` basis. One commitment — not one
//! per block — is what makes the argument sound: every sub-claim below
//! opens the *same* committed vector, so a block's content cannot be
//! smuggled into another block or cancelled across commitments. Then
//!
//! * **linear part, checked homomorphically against the committed
//!   tensors**: one transcript point p over the d² weight-index space; the
//!   batched-opening engine opens every W̃_t(p), G̃_W(p), and rule-state
//!   m̃_t(p) (one RLC'd IPA on the shared `zkdl/mat` basis), and the
//!   verifier *derives* each slot's remainder claim from the rule's
//!   relation table. A fresh challenge γ then folds the live blocks of U
//!   into one opening: the public vector puts γⁱ·e(p) in live block i and
//!   zero in every pad block, so ⟨U, ·⟩ = Σᵢ γⁱ·Ũᵢ(p) and Schwartz–Zippel
//!   over γ pins *each* live block's MLE at p to its derived claim
//!   (equivalently: the stacked MLE opened at (bits(slotᵢ) ∥ p),
//!   γ-batched). The relations hold iff the openings do (Schwartz–Zippel
//!   over p);
//! * **range part**: the same stacked tensor U feeds one zkReLU
//!   Protocol-1 / Algorithm-1 validity instance over a *multi-width*
//!   padded digit basis ([`crate::zkrelu::DigitLayout::PerBlock`]): each
//!   slot's rows carry exactly its relation's digit budget at its
//!   boundary, with zero-weight pad columns above — the pattern check
//!   forces pad bits to zero, keeping each proven range exactly
//!   [−2^{S−1}, 2^{S−1}) per slot. The instance is bound to `com_u` by
//!   opening U at the validity point, so the range check is entrywise on
//!   the very tensor the linear part constrained.
//!
//! Everything defers into the trace's `MsmAccumulator`: a chained
//! `TraceProof` still verifies with exactly one MSM flush, whatever the
//! rule. See DESIGN.md §update.

pub mod rule;

pub use rule::{LrSchedule, UpdateRule};

use crate::aggregate::StepCommitmentSet;
use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::G1Affine;
use crate::field::Fr;
use crate::ipa::{self, EvalClaim, IpaProof};
use crate::model::ModelConfig;
use crate::poly::eq_table;
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use crate::util::threads;
use crate::witness::StepWitness;
use crate::zkdl::{commit, frs, Committed};
use crate::zkrelu::{self, DigitLayout, Protocol1Msg, ProverAux, ValidityBases, ValidityProof};
use anyhow::{ensure, Context, Result};
use once_cell::sync::Lazy;
use rule::Operand;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Padded boundary count B̄ = (T−1)̄, padded layer count L̄, padded relation
/// count R̄ = n_rem̄, and the stacked remainder size N_U = B̄·L̄·R̄·d².
/// Boundary b's layer ℓ, relation j owns block (b·L̄ + ℓ)·R̄ + j. Panics on
/// invalid dimensions — callers on untrusted input must guard with
/// [`checked_stack_dims`] first.
pub fn update_stack_dims(
    cfg: &ModelConfig,
    steps: usize,
    n_rem: usize,
) -> (usize, usize, usize, usize) {
    checked_stack_dims(cfg, steps, n_rem).expect("invalid update stack dimensions")
}

/// [`update_stack_dims`] that reports too-few steps, a relation-free rule,
/// overflow, and the degenerate 1-element stack (width 1, depth 1, one
/// boundary, one relation — the chain argument cannot run on it) as errors
/// instead of panicking. The single source of the size formula: the wire
/// decoder, `prove_trace_chained_with`, and `verify_trace_accum` all guard
/// with this before any key setup.
pub fn checked_stack_dims(
    cfg: &ModelConfig,
    steps: usize,
    n_rem: usize,
) -> Result<(usize, usize, usize, usize)> {
    ensure!(steps >= 2, "chaining needs at least two steps");
    ensure!(n_rem >= 1, "update rule declares no relations");
    let bbar = (steps - 1).next_power_of_two();
    let lbar = cfg.depth.next_power_of_two();
    let rbar = n_rem.next_power_of_two();
    let n = bbar
        .checked_mul(lbar)
        .and_then(|x| x.checked_mul(rbar))
        .and_then(|x| x.checked_mul(cfg.width))
        .and_then(|x| x.checked_mul(cfg.width))
        .context("update stack dimensions overflow")?;
    ensure!(n >= 2, "degenerate update stack");
    Ok((bbar, lbar, rbar, n))
}

/// Per-slot digit budgets of the stacked remainder tensor plus the shared
/// power-of-two decomposition width: slot (b·L̄ + ℓ)·R̄ + j carries relation
/// j's budget at boundary b; pad slots (whose values are zero) get the
/// minimal 2 digits. Deterministic in (cfg, rule, shift table), so prover
/// and verifier derive identical layouts from the artifact statement.
pub fn chain_digit_layout(
    cfg: &ModelConfig,
    steps: usize,
    r: &UpdateRule,
    lr_shifts: &[u32],
) -> Result<(DigitLayout, usize)> {
    rule::validate_shift_table(cfg, r, lr_shifts)?;
    ensure!(
        lr_shifts.len() == steps - 1,
        "shift table length {} != {} boundaries",
        lr_shifts.len(),
        steps - 1
    );
    let relations = r.relations();
    let (bbar, lbar, rbar, _) = checked_stack_dims(cfg, steps, relations.len())?;
    let nb = steps - 1;
    let mut digits = Vec::with_capacity(bbar * lbar * rbar);
    for b in 0..bbar {
        for l in 0..lbar {
            for j in 0..rbar {
                let live = b < nb && l < cfg.depth && j < relations.len();
                digits.push(if live {
                    relations[j].digits(cfg, lr_shifts[b]) as usize
                } else {
                    2
                });
            }
        }
    }
    let width = digits.iter().copied().max().unwrap_or(2).next_power_of_two();
    let d2 = cfg.width * cfg.width;
    Ok((DigitLayout::PerBlock { block: d2, digits }, width))
}

/// Commitment basis for the stacked update remainders of a T-step trace
/// under one update rule.
pub struct UpdateKey {
    pub cfg: ModelConfig,
    /// Number of live steps T (T−1 live boundaries).
    pub steps: usize,
    /// The rule whose relation table sizes this key.
    pub rule: UpdateRule,
    /// Stacked remainder basis, length B̄·L̄·R̄·d².
    pub g_upd: CommitKey,
}

#[allow(clippy::type_complexity)]
static UPDKEY_CACHE: Lazy<
    Mutex<HashMap<((usize, usize, usize, u32, u32, u32), usize, Vec<u8>), Arc<UpdateKey>>>,
> = Lazy::new(|| Mutex::new(HashMap::new()));

/// Cache-entry ceiling: the key includes artifact-controlled rule
/// parameters, so verifying hostile artifacts must not grow resident
/// memory without bound — at the cap, an arbitrary entry is evicted
/// (honest deployments use a handful of (cfg, T, rule) tuples).
const UPDKEY_CACHE_CAP: usize = 128;

impl UpdateKey {
    /// Derive (or fetch) the key for (cfg, steps, rule). Cached behind an
    /// `Arc` like the zkReLU `VBASES_CACHE`; the cache key includes the
    /// full rule descriptor (tag, parameters — hence tensor and relation
    /// counts), so distinct rules never share stale bases even when their
    /// stacks happen to be the same size.
    pub fn setup(cfg: ModelConfig, steps: usize, r: &UpdateRule) -> Arc<Self> {
        let cfg_key = (cfg.depth, cfg.width, cfg.batch, cfg.r_bits, cfg.q_bits, cfg.lr_shift);
        let desc = r.descriptor_bytes();
        let key = (cfg_key, steps, desc.clone());
        if let Some(uk) = UPDKEY_CACHE.lock().unwrap().get(&key) {
            crate::telemetry::count(crate::telemetry::Counter::UpdKeyHits, 1);
            return uk.clone();
        }
        crate::telemetry::count(crate::telemetry::Counter::UpdKeyMisses, 1);
        let (_, _, _, n) = update_stack_dims(&cfg, steps, r.n_rem());
        let label = [b"zkdl/trace-aux/upd/".as_ref(), &desc].concat();
        let uk = Arc::new(Self {
            cfg,
            steps,
            rule: *r,
            g_upd: CommitKey::setup(&label, n),
        });
        // fixed-base table for the stacked remainder basis, amortized by
        // the Arc cache (skipped automatically for bases past the table
        // size cap)
        uk.g_upd.warm_table();
        let mut cache = UPDKEY_CACHE.lock().unwrap();
        if cache.len() >= UPDKEY_CACHE_CAP {
            // bounded eviction rather than insert-refusal: hostile key
            // churn cannot grow memory OR permanently disable caching
            let evict = cache.keys().next().cloned();
            if let Some(evict) = evict {
                cache.remove(&evict);
                crate::telemetry::count(crate::telemetry::Counter::UpdKeyEvictions, 1);
            }
        }
        cache.insert(key, uk.clone());
        uk
    }
}

/// Validity bases for the remainder range instance; the label pins (T, L)
/// and the rule descriptor, and the `VBASES_CACHE` key additionally pins
/// the full digit layout — so two schedules over the same shape never
/// share an instance. Arc-cached: repeated calls (prove + per-proof
/// verify) never clone the bases.
fn update_validity_bases(uk: &UpdateKey, layout: &DigitLayout, width: usize) -> Arc<ValidityBases> {
    let (_, _, _, n) = update_stack_dims(&uk.cfg, uk.steps, uk.rule.n_rem());
    let t = uk.steps as u64;
    let l = uk.cfg.depth as u64;
    let label = [
        b"zkdl/trace/validity/upd/".as_ref(),
        &t.to_le_bytes(),
        &l.to_le_bytes(),
        &uk.rule.descriptor_bytes(),
    ]
    .concat();
    ValidityBases::setup_plain_layout(&label, uk.g_upd.h, n / 2, width, layout.clone())
}

fn dot(a: &[Fr], b: &[Fr]) -> Fr {
    let n = a.len().min(b.len());
    threads::par_reduce(
        n,
        1 << 10,
        Fr::ZERO,
        |r, acc| {
            a[r.clone()]
                .iter()
                .zip(&b[r])
                .fold(acc, |s, (x, y)| s + *x * *y)
        },
        |x, y| x + y,
    )
}

/// γ-folded slot selector over the stacked basis: block `slots[i]` of the
/// returned length-`n` vector carries γⁱ·e, every other block — pads
/// included — is zero. Pairing the stacked tensor U with it gives
/// Σᵢ γⁱ·⟨U_blockᵢ, e⟩, i.e. the γ-batch of the per-block MLE openings
/// (block i's weight equals eq((bits(slotᵢ) ∥ p), ·) scaled by γⁱ, since
/// eq at boolean slot bits is the slot indicator). This is what binds each
/// live block *individually* — a tiled e (same weight in every block) would
/// only constrain the sum over blocks, letting mass hide in pad blocks or
/// cancel across boundaries.
fn gamma_selected_eq(e: &[Fr], n: usize, slots: &[usize], gamma: Fr) -> Vec<Fr> {
    let d = e.len().max(1);
    let mut out = vec![Fr::ZERO; n];
    // γ-powers precomputed and inverted into a block → coefficient table,
    // so the fill tiles the stacked vector block-aligned across the pool
    // (each block written by exactly one lane; pads stay untouched zeros).
    let mut coeff_of: Vec<Option<Fr>> = vec![None; n.div_ceil(d)];
    let mut coeff = Fr::ONE;
    for &s in slots {
        coeff_of[s] = Some(coeff);
        coeff *= gamma;
    }
    threads::par_chunks_mut(&mut out, d, |bi, block| {
        if let Some(c) = coeff_of[bi] {
            for (o, x) in block.iter_mut().zip(e.iter()) {
                *o = c * *x;
            }
        }
    });
    out
}

/// Σᵢ γⁱ·valsᵢ — the claimed-value side of [`gamma_selected_eq`].
fn gamma_fold(vals: &[Fr], gamma: Fr) -> Fr {
    let mut coeff = Fr::ONE;
    let mut acc = Fr::ZERO;
    for v in vals {
        acc += coeff * *v;
        coeff *= gamma;
    }
    acc
}

/// Live block indices in claim order (boundary-major, then layer, then
/// relation): slot (b·L̄ + ℓ)·R̄ + j.
fn live_slots(nb: usize, depth: usize, lbar: usize, n_rem: usize, rbar: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(nb * depth * n_rem);
    for b in 0..nb {
        for l in 0..depth {
            for j in 0..n_rem {
                out.push((b * lbar + l) * rbar + j);
            }
        }
    }
    out
}

/// Derived remainder claims at the boundary point, in live-slot order:
/// v[b·L·J + ℓ·J + j] = (Σ c_k·X̃_k(p)) − 2^{S_{j,b}}·(Σ d_k·Ỹ_k(p)),
/// the field-side mirror of [`crate::witness::relation_remainder`]. Both
/// sides compute this from opened evaluations — the relation *defines* the
/// remainder claims.
fn derived_remainder_claims(
    cfg: &ModelConfig,
    r: &UpdateRule,
    lr_shifts: &[u32],
    depth: usize,
    v_w: &[Fr],
    v_gw: &[Fr],
    v_state: &[Vec<Fr>],
) -> Vec<Fr> {
    let relations = r.relations();
    let nb = lr_shifts.len();
    let mut out = Vec::with_capacity(nb * depth * relations.len());
    for (b, &shift) in lr_shifts.iter().enumerate() {
        for l in 0..depth {
            let op_eval = |op: Operand| -> Fr {
                match op {
                    Operand::WPrev => v_w[b * depth + l],
                    Operand::WNext => v_w[(b + 1) * depth + l],
                    Operand::GradW => v_gw[b * depth + l],
                    Operand::StatePrev(s) => v_state[s][b * depth + l],
                    Operand::StateNext(s) => v_state[s][(b + 1) * depth + l],
                }
            };
            for rel in &relations {
                let side = |terms: &[rule::RelTerm]| -> Fr {
                    terms
                        .iter()
                        .map(|t| Fr::from_i64(t.coeff) * op_eval(t.op))
                        .sum()
                };
                let pow2 = Fr::from_u128(1u128 << rel.digits(cfg, shift));
                out.push(side(&rel.lhs) - pow2 * side(&rel.shifted));
            }
        }
    }
    out
}

/// The chain argument appended to a [`crate::aggregate::TraceProof`]. The
/// rule descriptor, shift table, and state commitments are part of the
/// *statement* — a verifying party audits them exactly like the step
/// commitments (and the initial state m_0, like W_0 itself, is pinned by
/// its commitment, not recomputed).
#[derive(Clone, Debug)]
pub struct ChainProof {
    /// The optimizer whose exact updates this chain proves.
    pub rule: UpdateRule,
    /// Per-boundary learning-rate shifts (length T−1).
    pub lr_shifts: Vec<u32>,
    /// Rule state commitments on `g_mat`: `com_state[s][t·L + ℓ]` is state
    /// slot s of step t, layer ℓ (empty for SGD).
    pub com_state: Vec<Vec<G1Affine>>,
    /// The single commitment to the stacked remainder tensor U (all T−1
    /// boundaries × L layers × n_rem relations, pad blocks zero).
    pub com_u: G1Affine,
    pub p1_upd: Protocol1Msg,
    /// W̃ evaluations at the boundary point, step-major, length T·L.
    pub v_w: Vec<Fr>,
    /// G̃_W evaluations at the boundary point for steps 0..T−1, (T−1)·L.
    pub v_gw: Vec<Fr>,
    /// State-tensor evaluations at the boundary point: `v_state[s]` is
    /// step-major of length T·L.
    pub v_state: Vec<Vec<Fr>>,
    /// Stacked Ũ evaluation at the validity point.
    pub v_stack: Fr,
    /// Opening IPAs: [W+G_W+state @ p, γ-folded live blocks of U @ p,
    /// U @ validity point].
    pub openings: Vec<IpaProof>,
    pub validity: ValidityProof,
}

impl ChainProof {
    /// Compressed-point accounting, matching
    /// [`crate::aggregate::TraceProof::size_bytes`].
    pub fn size_bytes(&self) -> usize {
        let coms = 1 + self.com_state.iter().map(|r| r.len()).sum::<usize>();
        let scalars = self.v_w.len()
            + self.v_gw.len()
            + self.v_state.iter().map(|r| r.len()).sum::<usize>()
            + 1;
        let statement = self.rule.descriptor_bytes().len() + 4 * self.lr_shifts.len();
        let openings: usize = self.openings.iter().map(|o| o.size_bytes()).sum();
        (coms + scalars) * 32 + 32 + statement + openings + self.validity.size_bytes()
    }
}

/// Prover-side chain witness: remainder tensors per (boundary, layer,
/// relation) plus the rule's committed state tensors per (step, layer).
pub struct ChainWitness {
    /// (T−1) × L × n_rem remainders, embedded in 𝔽.
    pub rems: Vec<Vec<Vec<Vec<Fr>>>>,
    /// State tensors, `state[s][t·L + ℓ]`, embedded in 𝔽.
    pub state: Vec<Vec<Vec<Fr>>>,
}

impl ChainWitness {
    /// Compute the remainders from consecutive step witnesses
    /// ([`crate::witness::rule_chain_remainders`]), failing if any boundary
    /// is not the exact rounded update of the previous step under `r`.
    pub fn build(r: &UpdateRule, lr_shifts: &[u32], wits: &[StepWitness]) -> Result<Self> {
        ensure!(wits.len() >= 2, "chaining needs at least two steps");
        let cfg = wits[0].cfg;
        let rems: Vec<Vec<Vec<Vec<Fr>>>> =
            crate::witness::rule_chain_remainders(r, lr_shifts, wits)?
                .iter()
                .map(|per_layer| {
                    per_layer
                        .iter()
                        .map(|per_rel| per_rel.iter().map(|t| frs(t)).collect())
                        .collect()
                })
                .collect();
        let mut state = vec![Vec::with_capacity(wits.len() * cfg.depth); r.n_state()];
        for (t, wit) in wits.iter().enumerate() {
            ensure!(
                wit.opt_state.len() == r.n_state(),
                "step {t} carries {} state tensors, rule wants {}",
                wit.opt_state.len(),
                r.n_state()
            );
            for (s, per_layer) in wit.opt_state.iter().enumerate() {
                ensure!(per_layer.len() == cfg.depth, "state layer count at step {t}");
                for tensor in per_layer {
                    ensure!(
                        tensor.len() == cfg.width * cfg.width,
                        "state tensor shape at step {t}"
                    );
                    state[s].push(frs(tensor));
                }
            }
        }
        Ok(Self { rems, state })
    }
}

/// Prover-side commitments of the chain, produced before any transcript
/// challenge is drawn (the trace absorbs them up front, alongside the step
/// commitments, so the shared-randomness property extends to the chain).
pub(crate) struct ChainCommitments {
    /// Shift table (statement, absorbed with the commitments).
    pub(crate) lr_shifts: Vec<u32>,
    /// Rule state tensors on `g_mat`, `state[s][t·L + ℓ]`.
    pub(crate) state: Vec<Vec<Committed>>,
    pub(crate) com_state: Vec<Vec<G1Affine>>,
    /// The stacked remainder tensor U with its single opening (blind).
    pub(crate) u: Committed,
    pub(crate) com_u: G1Affine,
    pub(crate) p1: Protocol1Msg,
    pub(crate) aux: ProverAux,
    /// Validity bases of the range instance, derived once here and reused
    /// by [`prove_chain`] (their digit layout is a pure function of the
    /// statement, so recomputing would only duplicate work).
    pub(crate) vb: Arc<ValidityBases>,
}

pub(crate) fn commit_chain(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    lr_shifts: Vec<u32>,
    cw: ChainWitness,
    rng: &mut Rng,
) -> Result<ChainCommitments> {
    crate::span!("update/commit_chain");
    let cfg = &uk.cfg;
    let depth = cfg.depth;
    let d2 = cfg.width * cfg.width;
    let n_rem = uk.rule.n_rem();
    let (_, lbar, rbar, n_upd) = update_stack_dims(cfg, uk.steps, n_rem);
    ensure!(cw.rems.len() == uk.steps - 1, "boundary count mismatch");
    let mut stacked = vec![Fr::ZERO; n_upd];
    for (b, per_layer) in cw.rems.iter().enumerate() {
        ensure!(per_layer.len() == depth, "layer count mismatch");
        for (l, per_rel) in per_layer.iter().enumerate() {
            ensure!(per_rel.len() == n_rem, "relation count mismatch");
            for (j, vals) in per_rel.iter().enumerate() {
                let s = (b * lbar + l) * rbar + j;
                stacked[s * d2..(s + 1) * d2].copy_from_slice(vals);
            }
        }
    }
    let (layout, width) = chain_digit_layout(cfg, uk.steps, &uk.rule, &lr_shifts)?;
    let vb = update_validity_bases(uk, &layout, width);
    let (p1, aux) = zkrelu::protocol1_plain(&vb, &stacked, rng);
    let state: Vec<Vec<Committed>> = cw
        .state
        .into_iter()
        .map(|per_slot| {
            per_slot
                .into_iter()
                .map(|tensor| commit(g_mat, tensor, rng))
                .collect()
        })
        .collect();
    let com_state: Vec<Vec<G1Affine>> = state
        .iter()
        .map(|per_slot| {
            crate::curve::G1::batch_to_affine(
                &per_slot.iter().map(|c| c.com).collect::<Vec<_>>(),
            )
        })
        .collect();
    let u = commit(&uk.g_upd, stacked, rng);
    let com_u = u.com.to_affine();
    Ok(ChainCommitments {
        lr_shifts,
        state,
        com_state,
        u,
        com_u,
        p1,
        aux,
        vb,
    })
}

/// Absorb the chain's statement — rule descriptor, shift table, state
/// commitments, stacked-remainder commitment — right after the per-step
/// commitment sets, before Protocol 1 / any challenge. A swapped rule tag,
/// edited schedule, or substituted state tensor therefore lands in a
/// different transcript and fails every subsequent check.
pub(crate) fn absorb_chain_statement(
    tr: &mut Transcript,
    r: &UpdateRule,
    lr_shifts: &[u32],
    com_state: &[Vec<G1Affine>],
    com_u: &G1Affine,
) {
    tr.absorb_bytes(b"upd/rule", &r.descriptor_bytes());
    let shift_bytes: Vec<u8> = lr_shifts.iter().flat_map(|s| s.to_le_bytes()).collect();
    tr.absorb_bytes(b"upd/shifts", &shift_bytes);
    for per_slot in com_state {
        tr.absorb_points(b"com/state", per_slot);
    }
    tr.absorb_point(b"com/u", com_u);
}

/// The chain argument proper, appended after the trace's Phase 4. `w` and
/// `gw` are the per-step weight / weight-gradient commitments on `g_mat`
/// (the same objects the trace's matmul openings use).
pub(crate) fn prove_chain(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    w: &[&[Committed]],
    gw: &[&[Committed]],
    cc: ChainCommitments,
    tr: &mut Transcript,
    rng: &mut Rng,
) -> ChainProof {
    crate::span!("update/prove_chain");
    // taken by value so the stacked tensor (up to B̄·L̄·R̄·d² field elements)
    // is moved into the final opening instead of cloned per claim
    let ChainCommitments {
        lr_shifts,
        state,
        com_state,
        u,
        com_u,
        p1,
        aux,
        vb,
    } = cc;
    let cfg = &uk.cfg;
    let t_steps = uk.steps;
    let depth = cfg.depth;
    let d2 = cfg.width * cfg.width;
    let log_d2 = d2.trailing_zeros() as usize;
    let n_rem = uk.rule.n_rem();
    let (_, lbar, rbar, n_upd) = update_stack_dims(cfg, t_steps, n_rem);
    let nb = t_steps - 1;

    // one boundary point over the d² weight-index space, shared by every
    // (boundary, layer, relation) — the chain analogue of the trace-global
    // bundle
    let p_u = tr.challenge_frs(b"upd/p", log_d2);
    let e_u = eq_table(&p_u);

    let mut v_w = Vec::with_capacity(t_steps * depth);
    for step in w.iter().take(t_steps) {
        for c in step.iter().take(depth) {
            v_w.push(dot(&c.values, &e_u));
        }
    }
    let mut v_gw = Vec::with_capacity(nb * depth);
    for step in gw.iter().take(nb) {
        for c in step.iter().take(depth) {
            v_gw.push(dot(&c.values, &e_u));
        }
    }
    let v_state: Vec<Vec<Fr>> = state
        .iter()
        .map(|per_slot| per_slot.iter().map(|c| dot(&c.values, &e_u)).collect())
        .collect();
    // derived remainder evaluations — the rule's relations at p
    let v_ru = derived_remainder_claims(cfg, &uk.rule, &lr_shifts, depth, &v_w, &v_gw, &v_state);
    debug_assert!({
        let slots = live_slots(nb, depth, lbar, n_rem, rbar);
        slots.iter().zip(v_ru.iter()).all(|(&s, v)| {
            *v == dot(&u.values[s * d2..(s + 1) * d2], &e_u)
        })
    }, "chain witness drift");

    let mut openings = Vec::with_capacity(3);
    // U1: every W̃_t(p), G̃_W(p), and state m̃_t(p) on the shared g_mat
    // basis, one RLC'd IPA
    {
        let mut claims = Vec::with_capacity((t_steps + nb + uk.rule.n_state() * t_steps) * depth);
        for (t, step) in w.iter().enumerate().take(t_steps) {
            for (l, c) in step.iter().enumerate().take(depth) {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_w[t * depth + l],
                });
            }
        }
        for (b, step) in gw.iter().enumerate().take(nb) {
            for (l, c) in step.iter().enumerate().take(depth) {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_gw[b * depth + l],
                });
            }
        }
        for (s, per_slot) in state.iter().enumerate() {
            for (i, c) in per_slot.iter().enumerate() {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_state[s][i],
                });
            }
        }
        openings.push(ipa::batch_prove_eval_expr(g_mat, &claims, &e_u, tr, rng));
    }
    // U2: the γ-folded live blocks of U at p. γ is drawn after p and after
    // U1 absorbed every opened evaluation (which fix the derived claims),
    // so Schwartz–Zippel over γ pins each live block's MLE at p
    // individually.
    {
        let gamma = tr.challenge_fr(b"upd/gamma");
        let w_sel = gamma_selected_eq(
            &e_u,
            n_upd,
            &live_slots(nb, depth, lbar, n_rem, rbar),
            gamma,
        );
        let claim = EvalClaim {
            com: u.com,
            values: u.values.clone(),
            blind: u.blind,
            v: gamma_fold(&v_ru, gamma),
        };
        openings.push(ipa::batch_prove_eval_expr(&uk.g_upd, &[claim], &w_sel, tr, rng));
    }
    // validity point over the stacked remainder tensor
    let u_dd = tr.challenge_fr(b"upd/u_dd");
    let log_n = n_upd.trailing_zeros() as usize;
    let rho = tr.challenge_frs(b"upd/rho", log_n - 1);
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    // ⟨U, e(vpoint)⟩ IS the MLE evaluation — no tensor copy needed
    let v_stack = dot(&u.values, &e_row);
    // U3: the stacked opening binding v_stack (and thus the range instance)
    // to com_u — the same commitment U2 constrained; the last use of the
    // tensor, so it moves into the claim
    {
        let claim = EvalClaim {
            com: u.com,
            values: u.values,
            blind: u.blind,
            v: v_stack,
        };
        openings.push(ipa::batch_prove_eval_expr(&uk.g_upd, &[claim], &e_row, tr, rng));
    }
    let validity = zkrelu::prove_validity(&vb, &aux, &e_row, u_dd, v_stack, Fr::ZERO, tr, rng);

    ChainProof {
        rule: uk.rule,
        lr_shifts,
        com_state,
        com_u,
        p1_upd: p1,
        v_w,
        v_gw,
        v_state,
        v_stack,
        openings,
        validity,
    }
}

/// Structural validation shared by the wire decoder and the verifier:
/// rule parameters, shift-table shape and digit budgets, stack dimensions,
/// and the per-step tensor counts the proof must carry.
pub fn validate_chain_shape(cfg: &ModelConfig, steps: usize, chain: &ChainProof) -> Result<()> {
    let r = &chain.rule;
    ensure!(steps >= 2, "chained trace needs at least two steps");
    ensure!(
        chain.lr_shifts.len() == steps - 1,
        "chain: shift table length {} != {} boundaries",
        chain.lr_shifts.len(),
        steps - 1
    );
    rule::validate_shift_table(cfg, r, &chain.lr_shifts).context("chain: shift table")?;
    checked_stack_dims(cfg, steps, r.n_rem())?;
    ensure!(chain.v_w.len() == steps * cfg.depth, "chain: v_w length");
    ensure!(
        chain.v_gw.len() == (steps - 1) * cfg.depth,
        "chain: v_gw length"
    );
    ensure!(
        chain.v_state.len() == r.n_state() && chain.com_state.len() == r.n_state(),
        "chain: state slot count"
    );
    for (vs, cs) in chain.v_state.iter().zip(chain.com_state.iter()) {
        ensure!(
            vs.len() == steps * cfg.depth && cs.len() == steps * cfg.depth,
            "chain: state tensor count"
        );
    }
    Ok(())
}

/// Transcript replay + deferred checks of the chain argument (mirrors
/// [`prove_chain`] exactly). No curve arithmetic: every group equation —
/// the three batched openings and the validity instance — lands in `acc`,
/// preserving the trace's one-MSM invariant.
pub(crate) fn verify_chain_accum(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    coms: &[StepCommitmentSet],
    chain: &ChainProof,
    tr: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("update/verify_chain");
    let cfg = &uk.cfg;
    let t_steps = uk.steps;
    let depth = cfg.depth;
    let log_d2 = (cfg.width * cfg.width).trailing_zeros() as usize;
    ensure!(chain.rule == uk.rule, "chain: rule/key mismatch");
    validate_chain_shape(cfg, t_steps, chain)?;
    let n_rem = uk.rule.n_rem();
    let (_, lbar, rbar, n_upd) = update_stack_dims(cfg, t_steps, n_rem);
    let nb = t_steps - 1;

    ensure!(coms.len() == t_steps, "chain: step commitment count");
    ensure!(chain.openings.len() == 3, "chain: opening count");
    ensure!(
        chain.p1_upd.com_sign_prime.is_none(),
        "chain: unexpected sign coupling"
    );

    let p_u = tr.challenge_frs(b"upd/p", log_d2);
    let e_u = eq_table(&p_u);

    // the rule's relation table *defines* the remainder claims
    let v_ru = derived_remainder_claims(
        cfg,
        &uk.rule,
        &chain.lr_shifts,
        depth,
        &chain.v_w,
        &chain.v_gw,
        &chain.v_state,
    );

    // U1
    {
        let mut claims =
            Vec::with_capacity((t_steps + nb + uk.rule.n_state() * t_steps) * depth);
        for (t, set) in coms.iter().enumerate() {
            for l in 0..depth {
                claims.push((
                    ComExpr::point(set.com_w[l].to_projective()),
                    chain.v_w[t * depth + l],
                ));
            }
        }
        for (b, set) in coms.iter().enumerate().take(nb) {
            for l in 0..depth {
                claims.push((
                    ComExpr::point(set.com_gw[l].to_projective()),
                    chain.v_gw[b * depth + l],
                ));
            }
        }
        for (s, per_slot) in chain.com_state.iter().enumerate() {
            for (i, p) in per_slot.iter().enumerate() {
                claims.push((ComExpr::point(p.to_projective()), chain.v_state[s][i]));
            }
        }
        ipa::batch_verify_eval_expr(g_mat, &claims, &e_u, &chain.openings[0], tr, acc)
            .context("chain boundary opening")?;
    }
    // U2
    {
        let gamma = tr.challenge_fr(b"upd/gamma");
        let w_sel = gamma_selected_eq(
            &e_u,
            n_upd,
            &live_slots(nb, depth, lbar, n_rem, rbar),
            gamma,
        );
        ipa::batch_verify_eval_expr(
            &uk.g_upd,
            &[(ComExpr::point(chain.com_u.to_projective()), gamma_fold(&v_ru, gamma))],
            &w_sel,
            &chain.openings[1],
            tr,
            acc,
        )
        .context("chain remainder opening")?;
    }
    let u_dd = tr.challenge_fr(b"upd/u_dd");
    let log_n = n_upd.trailing_zeros() as usize;
    let rho = tr.challenge_frs(b"upd/rho", log_n - 1);
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    // U3
    {
        ipa::batch_verify_eval_expr(
            &uk.g_upd,
            &[(ComExpr::point(chain.com_u.to_projective()), chain.v_stack)],
            &e_row,
            &chain.openings[2],
            tr,
            acc,
        )
        .context("chain stacked opening")?;
    }
    let (layout, width) = chain_digit_layout(cfg, t_steps, &uk.rule, &chain.lr_shifts)?;
    let vb = update_validity_bases(uk, &layout, width);
    zkrelu::verify_validity_accum(
        &vb,
        &chain.p1_upd,
        None,
        &e_row,
        u_dd,
        chain.v_stack,
        Fr::ZERO,
        &chain.validity,
        tr,
        acc,
    )
    .context("chain validity")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_pad_boundaries_layers_and_relations() {
        let cfg = ModelConfig::new(3, 8, 4);
        let (bbar, lbar, rbar, n) = update_stack_dims(&cfg, 4, 1);
        assert_eq!((bbar, lbar, rbar), (4, 4, 1)); // 3 boundaries pad to 4
        assert_eq!(n, 4 * 4 * 64);
        // momentum: two relations pad to R̄ = 2, doubling the stack
        let (_, _, rbar2, n2) = update_stack_dims(&cfg, 4, 2);
        assert_eq!(rbar2, 2);
        assert_eq!(n2, 2 * n);
        // three relations (an Adam-shaped rule) pad to 4
        let (_, _, rbar3, _) = update_stack_dims(&cfg, 4, 3);
        assert_eq!(rbar3, 4);
    }

    #[test]
    fn checked_dims_reject_degenerate_stacks() {
        // width 1 × depth 1 × one boundary × one relation: 1-element stack
        assert!(checked_stack_dims(&ModelConfig::new(1, 1, 1), 2, 1).is_err());
        // fewer than two steps: nothing to chain
        assert!(checked_stack_dims(&ModelConfig::new(2, 8, 4), 1, 1).is_err());
        // a relation-free rule has nothing to prove
        assert!(checked_stack_dims(&ModelConfig::new(2, 8, 4), 3, 0).is_err());
        assert!(checked_stack_dims(&ModelConfig::new(2, 8, 4), 3, 1).is_ok());
    }

    #[test]
    fn digit_layout_tracks_schedule_and_relations() {
        let cfg = ModelConfig::new(1, 2, 2); // L̄ = 1, d² = 4, R = 16
        let r = UpdateRule::momentum_default(); // budgets: [3, 16 + lr_b]
        let (layout, width) = chain_digit_layout(&cfg, 3, &r, &[8, 9]).expect("layout");
        // B̄ = 2, L̄ = 1, R̄ = 2 → 4 slots of 4 rows each
        assert_eq!(width, 32); // max budget 16 + 9 = 25 → next pow2
        let DigitLayout::PerBlock { block, digits } = &layout else {
            panic!("chain layouts are per-block");
        };
        assert_eq!(*block, 4);
        assert_eq!(digits.as_slice(), &[3, 24, 3, 25]);
        // an S_b beyond 64 is refused outright
        assert!(chain_digit_layout(&cfg, 3, &r, &[8, 49]).is_err());
    }

    #[test]
    fn gamma_selector_binds_blocks_individually() {
        // 4 slots of 4 entries, slots {0, 2} live; the selector must weight
        // live block i by γⁱ·e and ignore pad blocks entirely — the property
        // a tiled e lacks (it only constrains the sum over ALL blocks,
        // letting a cheating prover park cancelling mass in pad blocks).
        let mut rng = Rng::seed_from_u64(0x5e1);
        let d = 4;
        let n = 4 * d;
        let e: Vec<Fr> = (0..d).map(|_| Fr::random(&mut rng)).collect();
        let gamma = Fr::random(&mut rng);
        let mut stacked: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
        let slots = [0usize, 2];
        let w_sel = gamma_selected_eq(&e, n, &slots, gamma);
        let block_evals = [dot(&stacked[0..d], &e), dot(&stacked[2 * d..3 * d], &e)];
        let expect = block_evals[0] + gamma * block_evals[1];
        assert_eq!(dot(&stacked, &w_sel), expect);
        assert_eq!(expect, gamma_fold(&block_evals, gamma));
        // pad-block mass (slots 1 and 3) does not move the opening
        stacked[d] += Fr::from_u128(1 << 20);
        stacked[3 * d + 2] -= Fr::from_u128(1 << 20);
        assert_eq!(dot(&stacked, &w_sel), expect);
        // but live-block mass does — the claim is really per-block
        stacked[2 * d] += Fr::ONE;
        assert_ne!(dot(&stacked, &w_sel), expect);
    }

    #[test]
    fn live_slots_interleave_relations() {
        // nb=2, depth=2 (lbar 2), n_rem=2 (rbar 2): slot (b·2+l)·2+j
        assert_eq!(
            live_slots(2, 2, 2, 2, 2),
            vec![0, 1, 2, 3, 4, 5, 6, 7]
        );
        // padded layers (depth 3 → lbar 4) leave holes
        assert_eq!(
            live_slots(1, 3, 4, 1, 1),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn update_key_cache_keys_on_rule_descriptor() {
        let cfg = ModelConfig::new(2, 8, 4);
        let a = UpdateKey::setup(cfg, 3, &UpdateRule::Sgd);
        let b = UpdateKey::setup(cfg, 3, &UpdateRule::Sgd);
        assert!(Arc::ptr_eq(&a, &b), "same (cfg, steps, rule) shares one key");
        let c = UpdateKey::setup(cfg, 4, &UpdateRule::Sgd);
        assert!(!Arc::ptr_eq(&a, &c), "different step count, different key");
        // distinct rules never share a key, even at identical stack sizes:
        // momentum with R̄ = 2 vs SGD at double the boundary padding
        let m = UpdateKey::setup(cfg, 3, &UpdateRule::momentum_default());
        assert!(!Arc::ptr_eq(&a, &m), "cache miss across rule descriptors");
        assert_eq!(m.g_upd.g.len(), 2 * a.g_upd.g.len());
        // ... and two momentum parameterizations are distinct descriptors
        let m2 = UpdateKey::setup(
            cfg,
            3,
            &UpdateRule::Momentum {
                beta_num: 3,
                beta_shift: 2,
            },
        );
        assert!(!Arc::ptr_eq(&m, &m2), "β is part of the descriptor");
        assert_eq!(m.g_upd.g.len(), m2.g_upd.g.len(), "same size, different bases");
    }

    #[test]
    fn chain_witness_rejects_broken_boundary() {
        use crate::data::Dataset;
        use crate::witness::native::sgd_witness_chain;
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(64, 4, 4, cfg.r_bits, 9);
        let mut wits = sgd_witness_chain(cfg, &ds, 3, 0xc4a1);
        let shifts = vec![cfg.lr_shift; 2];
        assert!(ChainWitness::build(&UpdateRule::Sgd, &shifts, &wits).is_ok());
        crate::witness::validate_chain(&wits).expect("honest chain validates");
        // perturb one weight of step 1: boundary 0 no longer chains
        wits[1].layers[0].w[5] += 1;
        assert!(ChainWitness::build(&UpdateRule::Sgd, &shifts, &wits).is_err());
        assert!(crate::witness::validate_chain(&wits).is_err());
    }

    #[test]
    fn momentum_chain_witness_builds_state_tensors() {
        use crate::data::Dataset;
        use crate::witness::native::rule_witness_chain;
        let cfg = ModelConfig::new(2, 8, 4);
        let r = UpdateRule::momentum_default();
        let sched = LrSchedule::Constant(cfg.lr_shift);
        let ds = Dataset::synthetic(64, 4, 4, cfg.r_bits, 10);
        let wits = rule_witness_chain(cfg, &r, &sched, &ds, 3, 0xc4a2);
        let shifts = sched.window_table(0, 2);
        let cw = ChainWitness::build(&r, &shifts, &wits).expect("momentum chain builds");
        assert_eq!(cw.rems.len(), 2);
        assert_eq!(cw.rems[0][0].len(), 2, "two remainders per (b, ℓ)");
        assert_eq!(cw.state.len(), 1);
        assert_eq!(cw.state[0].len(), 3 * cfg.depth);
        // a tampered accumulator cannot be witnessed
        let mut bad = wits;
        bad[1].opt_state[0][1][3] += 1;
        assert!(ChainWitness::build(&r, &shifts, &bad).is_err());
    }
}
