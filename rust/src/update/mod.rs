//! zkSGD — weight-update chaining for end-to-end verifiable training traces.
//!
//! A plain [`crate::aggregate::TraceProof`] certifies T *independent* SGD
//! steps: each step is proven against its own committed weights, and nothing
//! ties step t+1's weights to step t's update. This module closes that gap
//! with the paper's own zkReLU recipe (§4.1: turn a non-arithmetic relation
//! into a committed auxiliary decomposition). The coordinator's quantized
//! update W_{t+1} = W_t − ⌊G_W / 2^{R+lr}⌉ rounds, so it is not linear over
//! the committed integers — but its *decomposition* is:
//!
//! ```text
//! G_W = 2^S·(W_t − W_{t+1}) + R,   R ∈ [−2^{S−1}, 2^{S−1}),  S = R+lr,
//! ```
//!
//! and the remainder range makes the decomposition unique: proving it proves
//! the exact rounded update. The prover lays every boundary/layer remainder
//! tensor R (d² entries, boundary b / layer ℓ in block b·L̄ + ℓ) into ONE
//! stacked tensor U of size B̄·L̄·d² and commits it with a single Pedersen
//! commitment `com_u` on the `zkdl/trace-aux/upd` basis. One commitment —
//! not one per block — is what makes the argument sound: every sub-claim
//! below opens the *same* committed vector, so a block's content cannot be
//! smuggled into another block or cancelled across commitments. Then
//!
//! * **linear part, checked homomorphically against the already-committed
//!   tensors**: one transcript point p over the d² weight-index space; the
//!   batched-opening engine opens every W̃_t(p) and G̃_W(p) (one RLC'd IPA on
//!   the shared `zkdl/mat` basis), and the verifier *derives* each boundary's
//!   remainder claim G̃_W(p) − 2^S·(W̃_t(p) − W̃_{t+1}(p)). A fresh challenge
//!   γ then folds the live blocks of U into one opening: the public vector
//!   puts γⁱ·e(p) in live block i and zero in every pad block, so
//!   ⟨U, ·⟩ = Σᵢ γⁱ·Ũᵢ(p) and Schwartz–Zippel over γ pins *each* live
//!   block's MLE at p to its derived claim (equivalently: the stacked MLE
//!   opened at (bits(slotᵢ) ∥ p), γ-batched). The boundary relation holds
//!   iff the openings do (Schwartz–Zippel over p);
//! * **range part**: the same stacked tensor U feeds one zkReLU Protocol-1 /
//!   Algorithm-1 validity instance over the padded digit basis
//!   ([`crate::zkrelu::s_basis_digits`]): S = R+lr bits is not a power of
//!   two, so the instance uses width S̄ = 2^⌈log S⌉ with zero-weight pad
//!   columns — the pattern check forces pad bits to zero, keeping the proven
//!   range *exactly* [−2^{S−1}, 2^{S−1}). The instance is bound to `com_u`
//!   by opening U at the validity point, so the range check is entrywise on
//!   the very tensor the linear part constrained.
//!
//! Everything defers into the trace's `MsmAccumulator`: a chained
//! `TraceProof` still verifies with exactly one MSM flush. See
//! DESIGN.md §update.

use crate::aggregate::StepCommitmentSet;
use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::G1Affine;
use crate::field::Fr;
use crate::ipa::{self, EvalClaim, IpaProof};
use crate::model::ModelConfig;
use crate::poly::eq_table;
use crate::transcript::Transcript;
use crate::util::rng::Rng;
use crate::witness::StepWitness;
use crate::zkdl::{commit, frs, Committed};
use crate::zkrelu::{self, Protocol1Msg, ProverAux, ValidityBases, ValidityProof};
use anyhow::{ensure, Context, Result};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Padded boundary count B̄ = (T−1)̄, padded layer count L̄, and the stacked
/// remainder size N_U = B̄·L̄·d². Boundary b's layer ℓ owns block (b·L̄ + ℓ).
/// Panics on invalid dimensions — callers on untrusted input must guard
/// with [`checked_stack_dims`] first.
pub fn update_stack_dims(cfg: &ModelConfig, steps: usize) -> (usize, usize, usize) {
    checked_stack_dims(cfg, steps).expect("invalid update stack dimensions")
}

/// [`update_stack_dims`] that reports too-few steps, overflow, and the
/// degenerate 1-element stack (width 1, depth 1, one boundary — the chain
/// argument cannot run on it) as errors instead of panicking. The single
/// source of the size formula: the wire decoder, `prove_trace_chained`,
/// and `verify_trace_accum` all guard with this before any key setup.
pub fn checked_stack_dims(cfg: &ModelConfig, steps: usize) -> Result<(usize, usize, usize)> {
    ensure!(steps >= 2, "chaining needs at least two steps");
    let bbar = (steps - 1).next_power_of_two();
    let lbar = cfg.depth.next_power_of_two();
    let n = bbar
        .checked_mul(lbar)
        .and_then(|x| x.checked_mul(cfg.width))
        .and_then(|x| x.checked_mul(cfg.width))
        .context("update stack dimensions overflow")?;
    ensure!(n >= 2, "degenerate update stack");
    Ok((bbar, lbar, n))
}

/// Active digit count S = R + lr of an update remainder and the padded
/// power-of-two decomposition width the validity instance runs at.
pub fn update_widths(cfg: &ModelConfig) -> (usize, usize) {
    let digits = (cfg.r_bits + cfg.lr_shift) as usize;
    (digits, digits.next_power_of_two())
}

/// Commitment basis for the stacked update remainders of a T-step trace.
pub struct UpdateKey {
    pub cfg: ModelConfig,
    /// Number of live steps T (T−1 live boundaries).
    pub steps: usize,
    /// Stacked remainder basis, length B̄·L̄·d².
    pub g_upd: CommitKey,
}

#[allow(clippy::type_complexity)]
static UPDKEY_CACHE: Lazy<
    Mutex<HashMap<((usize, usize, usize, u32, u32, u32), usize), Arc<UpdateKey>>>,
> = Lazy::new(|| Mutex::new(HashMap::new()));

impl UpdateKey {
    /// Derive (or fetch) the key for (cfg, steps). Cached behind an `Arc`
    /// like the zkReLU `VBASES_CACHE`: `CommitKey::setup` already caches the
    /// hash-to-curve derivation, but `verify_trace_accum` runs once per
    /// proof and cloning a B̄·L̄·d²-point basis per verified proof is a
    /// measurable cost under batched multi-proof verification.
    pub fn setup(cfg: ModelConfig, steps: usize) -> Arc<Self> {
        let cfg_key = (cfg.depth, cfg.width, cfg.batch, cfg.r_bits, cfg.q_bits, cfg.lr_shift);
        let key = (cfg_key, steps);
        if let Some(uk) = UPDKEY_CACHE.lock().unwrap().get(&key) {
            return uk.clone();
        }
        let (_, _, n) = update_stack_dims(&cfg, steps);
        let uk = Arc::new(Self {
            cfg,
            steps,
            g_upd: CommitKey::setup(b"zkdl/trace-aux/upd", n),
        });
        UPDKEY_CACHE.lock().unwrap().insert(key, uk.clone());
        uk
    }
}

/// Validity bases for the remainder range instance; the label pins (T, L)
/// like the trace validity labels do. Arc-cached inside `VBASES_CACHE`, so
/// repeated calls (prove + per-proof verify) never clone the bases.
fn update_validity_bases(uk: &UpdateKey) -> Arc<ValidityBases> {
    let (_, _, n) = update_stack_dims(&uk.cfg, uk.steps);
    let (digits, width) = update_widths(&uk.cfg);
    let t = uk.steps as u64;
    let l = uk.cfg.depth as u64;
    let label = [
        b"zkdl/trace/validity/upd/".as_ref(),
        &t.to_le_bytes(),
        &l.to_le_bytes(),
    ]
    .concat();
    ValidityBases::setup_plain_digits(&label, uk.g_upd.h, n / 2, width, digits)
}

/// 2^S as a field scalar, S = R + lr.
fn two_s(cfg: &ModelConfig) -> Fr {
    Fr::from_u128(1u128 << (cfg.r_bits + cfg.lr_shift))
}

fn dot(a: &[Fr], b: &[Fr]) -> Fr {
    a.iter().zip(b.iter()).map(|(x, y)| *x * *y).sum()
}

/// γ-folded slot selector over the stacked basis: block `slots[i]` of the
/// returned length-`n` vector carries γⁱ·e, every other block — pads
/// included — is zero. Pairing the stacked tensor U with it gives
/// Σᵢ γⁱ·⟨U_blockᵢ, e⟩, i.e. the γ-batch of the per-block MLE openings
/// (block i's weight equals eq((bits(slotᵢ) ∥ p), ·) scaled by γⁱ, since
/// eq at boolean slot bits is the slot indicator). This is what binds each
/// live block *individually* — a tiled e (same weight in every block) would
/// only constrain the sum over blocks, letting mass hide in pad blocks or
/// cancel across boundaries.
fn gamma_selected_eq(e: &[Fr], n: usize, slots: &[usize], gamma: Fr) -> Vec<Fr> {
    let d = e.len();
    let mut out = vec![Fr::ZERO; n];
    let mut coeff = Fr::ONE;
    for &s in slots {
        for (o, x) in out[s * d..(s + 1) * d].iter_mut().zip(e.iter()) {
            *o = coeff * *x;
        }
        coeff *= gamma;
    }
    out
}

/// Σᵢ γⁱ·valsᵢ — the claimed-value side of [`gamma_selected_eq`].
fn gamma_fold(vals: &[Fr], gamma: Fr) -> Fr {
    let mut coeff = Fr::ONE;
    let mut acc = Fr::ZERO;
    for v in vals {
        acc += coeff * *v;
        coeff *= gamma;
    }
    acc
}

/// Live block indices in claim order (boundary-major): slot b·L̄ + ℓ.
fn live_slots(nb: usize, depth: usize, lbar: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(nb * depth);
    for b in 0..nb {
        for l in 0..depth {
            out.push(b * lbar + l);
        }
    }
    out
}

/// The chain argument appended to a [`crate::aggregate::TraceProof`].
#[derive(Clone, Debug)]
pub struct ChainProof {
    /// The single commitment to the stacked remainder tensor U (all T−1
    /// boundaries × L layers, pad blocks zero) on `g_upd`.
    pub com_u: G1Affine,
    pub p1_upd: Protocol1Msg,
    /// W̃ evaluations at the boundary point, step-major, length T·L.
    pub v_w: Vec<Fr>,
    /// G̃_W evaluations at the boundary point for steps 0..T−1, (T−1)·L.
    pub v_gw: Vec<Fr>,
    /// Stacked Ũ evaluation at the validity point.
    pub v_stack: Fr,
    /// Opening IPAs: [W+G_W @ p, γ-folded live blocks of U @ p,
    /// U @ validity point].
    pub openings: Vec<IpaProof>,
    pub validity: ValidityProof,
}

impl ChainProof {
    /// Compressed-point accounting, matching
    /// [`crate::aggregate::TraceProof::size_bytes`].
    pub fn size_bytes(&self) -> usize {
        let coms = 1; // com_u
        let scalars = self.v_w.len() + self.v_gw.len() + 1;
        let openings: usize = self.openings.iter().map(|o| o.size_bytes()).sum();
        (coms + scalars) * 32 + 32 + openings + self.validity.size_bytes()
    }
}

/// Prover-side chain witness: one remainder tensor per (boundary, layer).
pub struct ChainWitness {
    /// (T−1) × L × d² remainders, embedded in 𝔽.
    pub rems: Vec<Vec<Vec<Fr>>>,
}

impl ChainWitness {
    /// Compute the remainders from consecutive step witnesses
    /// ([`crate::witness::chain_remainders`]), failing if any boundary's
    /// weights are not the exact rounded update.
    pub fn build(wits: &[StepWitness]) -> Result<Self> {
        ensure!(wits.len() >= 2, "chaining needs at least two steps");
        let rems: Vec<Vec<Vec<Fr>>> = crate::witness::chain_remainders(wits)?
            .iter()
            .map(|per_layer| per_layer.iter().map(|r| frs(r)).collect())
            .collect();
        Ok(Self { rems })
    }
}

/// Prover-side commitments of the chain, produced before any transcript
/// challenge is drawn (the trace absorbs them up front, alongside the step
/// commitments, so the shared-randomness property extends to the chain).
pub(crate) struct ChainCommitments {
    /// The stacked remainder tensor U with its single opening (blind).
    pub(crate) u: Committed,
    pub(crate) com_u: G1Affine,
    pub(crate) p1: Protocol1Msg,
    pub(crate) aux: ProverAux,
}

pub(crate) fn commit_chain(uk: &UpdateKey, cw: &ChainWitness, rng: &mut Rng) -> ChainCommitments {
    let cfg = &uk.cfg;
    let depth = cfg.depth;
    let d2 = cfg.width * cfg.width;
    let (_, lbar, n_upd) = update_stack_dims(cfg, uk.steps);
    assert_eq!(cw.rems.len(), uk.steps - 1, "boundary count mismatch");
    let mut stacked = vec![Fr::ZERO; n_upd];
    for (b, per_layer) in cw.rems.iter().enumerate() {
        assert_eq!(per_layer.len(), depth, "layer count mismatch");
        for (l, vals) in per_layer.iter().enumerate() {
            let s = b * lbar + l;
            stacked[s * d2..(s + 1) * d2].copy_from_slice(vals);
        }
    }
    let vb = update_validity_bases(uk);
    let (p1, aux) = zkrelu::protocol1_plain(&vb, &stacked, rng);
    let u = commit(&uk.g_upd, stacked, rng);
    let com_u = u.com.to_affine();
    ChainCommitments { u, com_u, p1, aux }
}

/// Absorb the chain's stacked-remainder commitment (call sites: right after
/// the per-step commitment sets, before Protocol 1 / any challenge).
pub(crate) fn absorb_chain_com(tr: &mut Transcript, com_u: &G1Affine) {
    tr.absorb_point(b"com/u", com_u);
}

/// The chain argument proper, appended after the trace's Phase 4. `w` and
/// `gw` are the per-step weight / weight-gradient commitments on `g_mat`
/// (the same objects the trace's matmul openings use).
pub(crate) fn prove_chain(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    w: &[&[Committed]],
    gw: &[&[Committed]],
    cc: ChainCommitments,
    tr: &mut Transcript,
    rng: &mut Rng,
) -> ChainProof {
    // taken by value so the stacked tensor (up to B̄·L̄·d² field elements)
    // is moved into the final opening instead of cloned per claim
    let ChainCommitments { u, com_u, p1, aux } = cc;
    let cfg = &uk.cfg;
    let t_steps = uk.steps;
    let depth = cfg.depth;
    let d2 = cfg.width * cfg.width;
    let log_d2 = d2.trailing_zeros() as usize;
    let (_, lbar, n_upd) = update_stack_dims(cfg, t_steps);
    let nb = t_steps - 1;
    let two_s = two_s(cfg);

    // one boundary point over the d² weight-index space, shared by every
    // (boundary, layer) — the chain analogue of the trace-global bundle
    let p_u = tr.challenge_frs(b"upd/p", log_d2);
    let e_u = eq_table(&p_u);

    let mut v_w = Vec::with_capacity(t_steps * depth);
    for step in w.iter().take(t_steps) {
        for c in step.iter().take(depth) {
            v_w.push(dot(&c.values, &e_u));
        }
    }
    let mut v_gw = Vec::with_capacity(nb * depth);
    for step in gw.iter().take(nb) {
        for c in step.iter().take(depth) {
            v_gw.push(dot(&c.values, &e_u));
        }
    }
    // derived remainder evaluations — the linear boundary relation at p:
    // Ũ_{b,ℓ}(p) = G̃_W(p) − 2^S·(W̃_t(p) − W̃_{t+1}(p))
    let mut v_ru = Vec::with_capacity(nb * depth);
    for b in 0..nb {
        for l in 0..depth {
            let v = v_gw[b * depth + l] - two_s * (v_w[b * depth + l] - v_w[(b + 1) * depth + l]);
            debug_assert_eq!(
                v,
                dot(&u.values[(b * lbar + l) * d2..(b * lbar + l + 1) * d2], &e_u),
                "chain witness drift"
            );
            v_ru.push(v);
        }
    }

    let mut openings = Vec::with_capacity(3);
    // U1: every W̃_t(p) and G̃_W(p) on the shared g_mat basis, one RLC'd IPA
    {
        let mut claims = Vec::with_capacity((t_steps + nb) * depth);
        for (t, step) in w.iter().enumerate().take(t_steps) {
            for (l, c) in step.iter().enumerate().take(depth) {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_w[t * depth + l],
                });
            }
        }
        for (b, step) in gw.iter().enumerate().take(nb) {
            for (l, c) in step.iter().enumerate().take(depth) {
                claims.push(EvalClaim {
                    com: c.com,
                    values: c.values.clone(),
                    blind: c.blind,
                    v: v_gw[b * depth + l],
                });
            }
        }
        openings.push(ipa::batch_prove_eval_expr(g_mat, &claims, &e_u, tr, rng));
    }
    // U2: the γ-folded live blocks of U at p. γ is drawn after p and after
    // U1 absorbed every v_w/v_gw (which fix the derived claims), so
    // Schwartz–Zippel over γ pins each live block's MLE at p individually.
    {
        let gamma = tr.challenge_fr(b"upd/gamma");
        let w_sel = gamma_selected_eq(&e_u, n_upd, &live_slots(nb, depth, lbar), gamma);
        let claim = EvalClaim {
            com: u.com,
            values: u.values.clone(),
            blind: u.blind,
            v: gamma_fold(&v_ru, gamma),
        };
        openings.push(ipa::batch_prove_eval_expr(&uk.g_upd, &[claim], &w_sel, tr, rng));
    }
    // validity point over the stacked remainder tensor
    let u_dd = tr.challenge_fr(b"upd/u_dd");
    let log_n = n_upd.trailing_zeros() as usize;
    let rho = tr.challenge_frs(b"upd/rho", log_n - 1);
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    // ⟨U, e(vpoint)⟩ IS the MLE evaluation — no tensor copy needed
    let v_stack = dot(&u.values, &e_row);
    // U3: the stacked opening binding v_stack (and thus the range instance)
    // to com_u — the same commitment U2 constrained; the last use of the
    // tensor, so it moves into the claim
    {
        let claim = EvalClaim {
            com: u.com,
            values: u.values,
            blind: u.blind,
            v: v_stack,
        };
        openings.push(ipa::batch_prove_eval_expr(&uk.g_upd, &[claim], &e_row, tr, rng));
    }
    let vb = update_validity_bases(uk);
    let validity = zkrelu::prove_validity(&vb, &aux, &e_row, u_dd, v_stack, Fr::ZERO, tr, rng);

    ChainProof {
        com_u,
        p1_upd: p1,
        v_w,
        v_gw,
        v_stack,
        openings,
        validity,
    }
}

/// Transcript replay + deferred checks of the chain argument (mirrors
/// [`prove_chain`] exactly). No curve arithmetic: every group equation —
/// the three batched openings and the validity instance — lands in `acc`,
/// preserving the trace's one-MSM invariant.
pub(crate) fn verify_chain_accum(
    uk: &UpdateKey,
    g_mat: &CommitKey,
    coms: &[StepCommitmentSet],
    chain: &ChainProof,
    tr: &mut Transcript,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    let cfg = &uk.cfg;
    let t_steps = uk.steps;
    let depth = cfg.depth;
    let log_d2 = (cfg.width * cfg.width).trailing_zeros() as usize;
    let (_, lbar, n_upd) = update_stack_dims(cfg, t_steps);
    let nb = t_steps - 1;

    ensure!(coms.len() == t_steps, "chain: step commitment count");
    ensure!(chain.v_w.len() == t_steps * depth, "chain: v_w length");
    ensure!(chain.v_gw.len() == nb * depth, "chain: v_gw length");
    ensure!(chain.openings.len() == 3, "chain: opening count");
    ensure!(
        chain.p1_upd.com_sign_prime.is_none(),
        "chain: unexpected sign coupling"
    );

    let two_s = two_s(cfg);
    let p_u = tr.challenge_frs(b"upd/p", log_d2);
    let e_u = eq_table(&p_u);

    // the boundary relation *defines* the remainder claims
    let mut v_ru = Vec::with_capacity(nb * depth);
    for b in 0..nb {
        for l in 0..depth {
            v_ru.push(
                chain.v_gw[b * depth + l]
                    - two_s * (chain.v_w[b * depth + l] - chain.v_w[(b + 1) * depth + l]),
            );
        }
    }

    // U1
    {
        let mut claims = Vec::with_capacity((t_steps + nb) * depth);
        for (t, set) in coms.iter().enumerate() {
            for l in 0..depth {
                claims.push((
                    ComExpr::point(set.com_w[l].to_projective()),
                    chain.v_w[t * depth + l],
                ));
            }
        }
        for (b, set) in coms.iter().enumerate().take(nb) {
            for l in 0..depth {
                claims.push((
                    ComExpr::point(set.com_gw[l].to_projective()),
                    chain.v_gw[b * depth + l],
                ));
            }
        }
        ipa::batch_verify_eval_expr(g_mat, &claims, &e_u, &chain.openings[0], tr, acc)
            .context("chain boundary opening")?;
    }
    // U2
    {
        let gamma = tr.challenge_fr(b"upd/gamma");
        let w_sel = gamma_selected_eq(&e_u, n_upd, &live_slots(nb, depth, lbar), gamma);
        ipa::batch_verify_eval_expr(
            &uk.g_upd,
            &[(ComExpr::point(chain.com_u.to_projective()), gamma_fold(&v_ru, gamma))],
            &w_sel,
            &chain.openings[1],
            tr,
            acc,
        )
        .context("chain remainder opening")?;
    }
    let u_dd = tr.challenge_fr(b"upd/u_dd");
    let log_n = n_upd.trailing_zeros() as usize;
    let rho = tr.challenge_frs(b"upd/rho", log_n - 1);
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    // U3
    {
        ipa::batch_verify_eval_expr(
            &uk.g_upd,
            &[(ComExpr::point(chain.com_u.to_projective()), chain.v_stack)],
            &e_row,
            &chain.openings[2],
            tr,
            acc,
        )
        .context("chain stacked opening")?;
    }
    let vb = update_validity_bases(uk);
    zkrelu::verify_validity_accum(
        &vb,
        &chain.p1_upd,
        None,
        &e_row,
        u_dd,
        chain.v_stack,
        Fr::ZERO,
        &chain.validity,
        tr,
        acc,
    )
    .context("chain validity")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_pad_boundaries_and_layers() {
        let cfg = ModelConfig::new(3, 8, 4);
        let (bbar, lbar, n) = update_stack_dims(&cfg, 4);
        assert_eq!((bbar, lbar), (4, 4)); // 3 boundaries pad to 4
        assert_eq!(n, 4 * 4 * 64);
        let (digits, width) = update_widths(&cfg);
        assert_eq!(digits, 24); // R=16 + lr=8
        assert_eq!(width, 32);
    }

    #[test]
    fn checked_dims_reject_degenerate_stacks() {
        // width 1 × depth 1 × one boundary: 1-element stack, unprovable
        assert!(checked_stack_dims(&ModelConfig::new(1, 1, 1), 2).is_err());
        // fewer than two steps: nothing to chain
        assert!(checked_stack_dims(&ModelConfig::new(2, 8, 4), 1).is_err());
        assert!(checked_stack_dims(&ModelConfig::new(2, 8, 4), 3).is_ok());
    }

    #[test]
    fn gamma_selector_binds_blocks_individually() {
        // 4 slots of 4 entries, slots {0, 2} live; the selector must weight
        // live block i by γⁱ·e and ignore pad blocks entirely — the property
        // a tiled e lacks (it only constrains the sum over ALL blocks,
        // letting a cheating prover park cancelling mass in pad blocks).
        let mut rng = Rng::seed_from_u64(0x5e1);
        let d = 4;
        let n = 4 * d;
        let e: Vec<Fr> = (0..d).map(|_| Fr::random(&mut rng)).collect();
        let gamma = Fr::random(&mut rng);
        let mut stacked: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
        let slots = [0usize, 2];
        let w_sel = gamma_selected_eq(&e, n, &slots, gamma);
        let block_evals = [dot(&stacked[0..d], &e), dot(&stacked[2 * d..3 * d], &e)];
        let expect = block_evals[0] + gamma * block_evals[1];
        assert_eq!(dot(&stacked, &w_sel), expect);
        assert_eq!(expect, gamma_fold(&block_evals, gamma));
        // pad-block mass (slots 1 and 3) does not move the opening
        stacked[d] += Fr::from_u128(1 << 20);
        stacked[3 * d + 2] -= Fr::from_u128(1 << 20);
        assert_eq!(dot(&stacked, &w_sel), expect);
        // but live-block mass does — the claim is really per-block
        stacked[2 * d] += Fr::ONE;
        assert_ne!(dot(&stacked, &w_sel), expect);
    }

    #[test]
    fn update_key_setup_is_cached() {
        let cfg = ModelConfig::new(2, 8, 4);
        let a = UpdateKey::setup(cfg, 3);
        let b = UpdateKey::setup(cfg, 3);
        assert!(Arc::ptr_eq(&a, &b), "same (cfg, steps) shares one key");
        let c = UpdateKey::setup(cfg, 4);
        assert!(!Arc::ptr_eq(&a, &c), "different step count, different key");
    }

    #[test]
    fn chain_witness_rejects_broken_boundary() {
        use crate::data::Dataset;
        use crate::witness::native::sgd_witness_chain;
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(64, 4, 4, cfg.r_bits, 9);
        let mut wits = sgd_witness_chain(cfg, &ds, 3, 0xc4a1);
        assert!(ChainWitness::build(&wits).is_ok());
        crate::witness::validate_chain(&wits).expect("honest chain validates");
        // perturb one weight of step 1: boundary 0 no longer chains
        wits[1].layers[0].w[5] += 1;
        assert!(ChainWitness::build(&wits).is_err());
        assert!(crate::witness::validate_chain(&wits).is_err());
    }
}
