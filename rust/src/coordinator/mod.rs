//! The training coordinator: drives batches through the AOT-compiled
//! training step (PJRT), applies SGD updates, and generates + verifies a
//! zkDL proof per step. This is the L3 request loop — pure rust, no Python.

use crate::data::Dataset;
use crate::model::{ModelConfig, Weights};
use crate::runtime::WitnessSource;
use crate::util::rng::Rng;
use crate::zkdl::{prove_step, verify_step, ProofMode, ProverKey};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Instant;

/// Per-step metrics of one proven training step.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    pub witness_ms: f64,
    pub prove_ms: f64,
    pub verify_ms: f64,
    pub proof_bytes: usize,
    pub witness_source: &'static str,
}

/// Outcome of a proven training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub steps: Vec<StepMetrics>,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        if self.steps.is_empty() {
            return "no steps".into();
        }
        let n = self.steps.len() as f64;
        let avg = |f: &dyn Fn(&StepMetrics) -> f64| self.steps.iter().map(|s| f(s)).sum::<f64>() / n;
        format!(
            "steps={} loss {:.4}→{:.4} acc {:.2}→{:.2} | avg witness {:.1} ms, prove {:.1} ms, verify {:.1} ms, proof {:.1} kB",
            self.steps.len(),
            self.steps.first().unwrap().loss,
            self.steps.last().unwrap().loss,
            self.steps.first().unwrap().accuracy,
            self.steps.last().unwrap().accuracy,
            avg(&|s| s.witness_ms),
            avg(&|s| s.prove_ms),
            avg(&|s| s.verify_ms),
            avg(&|s| s.proof_bytes as f64) / 1024.0,
        )
    }

    /// CSV dump (for EXPERIMENTS.md / plotting).
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("step,loss,accuracy,witness_ms,prove_ms,verify_ms,proof_bytes,source\n");
        for s in &self.steps {
            out.push_str(&format!(
                "{},{:.6},{:.4},{:.2},{:.2},{:.2},{},{}\n",
                s.step, s.loss, s.accuracy, s.witness_ms, s.prove_ms, s.verify_ms, s.proof_bytes,
                s.witness_source
            ));
        }
        out
    }
}

/// Options for a proven training run.
pub struct TrainOptions {
    pub steps: usize,
    /// Generate + verify a proof every k-th step (every step when 1;
    /// un-proven steps still run the witness + SGD update).
    pub prove_every: usize,
    pub mode: ProofMode,
    pub seed: u64,
    /// Skip proof *verification* (prover-side timing runs).
    pub skip_verify: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 10,
            prove_every: 1,
            mode: ProofMode::Parallel,
            seed: 0x5eed,
            skip_verify: false,
        }
    }
}

/// Train `opts.steps` SGD steps on `dataset`, proving each `prove_every`-th
/// step end-to-end. Returns the metrics trail.
pub fn train_and_prove(
    cfg: ModelConfig,
    dataset: &Dataset,
    artifact_dir: &Path,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    ensure!(opts.steps > 0 && opts.prove_every > 0);
    let mut rng = Rng::seed_from_u64(opts.seed);
    let mut weights = Weights::init(cfg, &mut rng);
    let source = WitnessSource::auto(artifact_dir, cfg);
    // prover key setup is a one-time cost, shared across steps
    let pk = ProverKey::setup(cfg);

    let mut report = TrainReport::default();
    for step in 0..opts.steps {
        let (x, y) = dataset.batch(&cfg, step);
        let t0 = Instant::now();
        let wit = source
            .compute_witness(&x, &y, &weights)
            .with_context(|| format!("witness at step {step}"))?;
        let witness_ms = t0.elapsed().as_secs_f64() * 1e3;

        let loss = wit.loss();
        let z_prime_last = &wit.layers[cfg.depth - 1].z_prime;
        let accuracy = dataset.batch_accuracy(&cfg, step, z_prime_last);

        let (prove_ms, verify_ms, proof_bytes) = if step % opts.prove_every == 0 {
            let t1 = Instant::now();
            let proof = prove_step(&pk, &wit, opts.mode, &mut rng);
            let prove_ms = t1.elapsed().as_secs_f64() * 1e3;
            let bytes = proof.size_bytes();
            let verify_ms = if opts.skip_verify {
                0.0
            } else {
                let t2 = Instant::now();
                verify_step(&pk, &proof).with_context(|| format!("verify at step {step}"))?;
                t2.elapsed().as_secs_f64() * 1e3
            };
            (prove_ms, verify_ms, bytes)
        } else {
            (0.0, 0.0, 0)
        };

        weights.apply_update(&wit.weight_grads());
        report.steps.push(StepMetrics {
            step,
            loss,
            accuracy,
            witness_ms,
            prove_ms,
            verify_ms,
            proof_bytes,
            witness_source: source.name(),
        });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_end_to_end_small() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(64, 4, 4, cfg.r_bits, 42);
        let opts = TrainOptions {
            steps: 3,
            prove_every: 2,
            ..Default::default()
        };
        let report =
            train_and_prove(cfg, &ds, Path::new("artifacts"), &opts).expect("run succeeds");
        assert_eq!(report.steps.len(), 3);
        // steps 0 and 2 proven, step 1 not
        assert!(report.steps[0].proof_bytes > 0);
        assert_eq!(report.steps[1].proof_bytes, 0);
        assert!(report.steps[2].proof_bytes > 0);
        assert!(report.to_csv().lines().count() == 4);
    }

    #[test]
    fn training_loss_decreases_over_run() {
        // single repeated batch (dataset size == batch size) so the loss
        // trajectory is comparable step to step
        let cfg = ModelConfig::new(2, 16, 8);
        let ds = Dataset::synthetic(8, 8, 4, cfg.r_bits, 7);
        let opts = TrainOptions {
            steps: 20,
            prove_every: 1000, // no proofs — just the training loop
            ..Default::default()
        };
        let report = train_and_prove(cfg, &ds, Path::new("artifacts"), &opts).unwrap();
        let first = report.steps[0].loss;
        let last = report.steps.last().unwrap().loss;
        assert!(last < first, "loss should fall: {first} → {last}");
    }
}
