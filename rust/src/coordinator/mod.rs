//! The training coordinator: drives batches through the AOT-compiled
//! training step (PJRT), applies SGD updates, and generates + verifies
//! zkDL proofs. This is the L3 request loop — pure rust, no Python.
//!
//! Proving is **pipelined**: witness generation for step k+1 runs on the
//! coordinator thread while a dedicated prover worker handles step k,
//! connected by a bounded channel (`TrainOptions::pipeline_depth` caps the
//! number of in-flight witnesses, bounding memory). The same driver feeds
//! the FAC4DNN aggregator: [`train_and_prove_trace`] collects witnesses
//! into windows of T steps and emits one [`TraceProof`] per window, proving
//! window k while the witnesses of window k+1 are being generated.

use crate::aggregate::{
    prove_trace, prove_trace_chained_provenance_with, prove_trace_chained_with,
    prove_trace_provenance, verify_trace, TraceKey, TraceProof,
};
use crate::data::{BatchSampler, Dataset};
use crate::model::{ModelConfig, Weights};
use crate::provenance::ProverDataset;
use crate::runtime::WitnessSource;
use crate::update::{LrSchedule, UpdateRule};
use crate::util::rng::Rng;
use crate::witness::StepWitness;
use crate::zkdl::{prove_step, verify_step, ProofMode, ProverKey};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

/// Per-step metrics of one proven training step.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    pub witness_ms: f64,
    pub prove_ms: f64,
    pub verify_ms: f64,
    pub proof_bytes: usize,
    pub witness_source: &'static str,
    /// Span-sourced `(phase, ms)` breakdown of the prove call (zkObs);
    /// empty when telemetry is disabled or the step was not proven.
    pub phases: Vec<(String, f64)>,
}

impl StepMetrics {
    /// One-line phase breakdown, e.g. `"zkdl/commit 12.3 ms, sumcheck/prove
    /// 4.5 ms"`; empty string when no phases were recorded.
    pub fn phase_summary(&self) -> String {
        fmt_phases(&self.phases)
    }
}

fn fmt_phases(phases: &[(String, f64)]) -> String {
    phases
        .iter()
        .map(|(name, ms)| format!("{name} {ms:.1} ms"))
        .collect::<Vec<_>>()
        .join(", ")
}

/// Outcome of a proven training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub steps: Vec<StepMetrics>,
    /// End-to-end wall-clock of the pipelined run, in seconds.
    pub wall_s: f64,
}

impl TrainReport {
    /// Aggregate throughput of the pipelined run (steps per second of
    /// wall-clock, witness + prove + verify overlapped).
    pub fn throughput_steps_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.steps.len() as f64 / self.wall_s
    }

    pub fn summary(&self) -> String {
        if self.steps.is_empty() {
            return "no steps".into();
        }
        let n = self.steps.len() as f64;
        let avg = |f: &dyn Fn(&StepMetrics) -> f64| self.steps.iter().map(|s| f(s)).sum::<f64>() / n;
        format!(
            "steps={} loss {:.4}→{:.4} acc {:.2}→{:.2} | avg witness {:.1} ms, prove {:.1} ms, verify {:.1} ms, proof {:.1} kB | {:.2} steps/s pipelined",
            self.steps.len(),
            self.steps.first().unwrap().loss,
            self.steps.last().unwrap().loss,
            self.steps.first().unwrap().accuracy,
            self.steps.last().unwrap().accuracy,
            avg(&|s| s.witness_ms),
            avg(&|s| s.prove_ms),
            avg(&|s| s.verify_ms),
            avg(&|s| s.proof_bytes as f64) / 1024.0,
            self.throughput_steps_per_s(),
        )
    }

    /// CSV dump (for EXPERIMENTS.md / plotting).
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("step,loss,accuracy,witness_ms,prove_ms,verify_ms,proof_bytes,source\n");
        for s in &self.steps {
            out.push_str(&format!(
                "{},{:.6},{:.4},{:.2},{:.2},{:.2},{},{}\n",
                s.step, s.loss, s.accuracy, s.witness_ms, s.prove_ms, s.verify_ms, s.proof_bytes,
                s.witness_source
            ));
        }
        out
    }
}

/// Options for a proven training run.
pub struct TrainOptions {
    pub steps: usize,
    /// Generate + verify a proof every k-th step (every step when 1;
    /// un-proven steps still run the witness + SGD update).
    pub prove_every: usize,
    pub mode: ProofMode,
    pub seed: u64,
    /// Skip proof *verification* (prover-side timing runs).
    pub skip_verify: bool,
    /// Max in-flight witnesses between the coordinator thread and the
    /// prover worker; 1 degenerates to lock-step serial execution.
    pub pipeline_depth: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 10,
            prove_every: 1,
            mode: ProofMode::Parallel,
            seed: 0x5eed,
            skip_verify: false,
            pipeline_depth: 2,
        }
    }
}

/// Work item flowing from the witness generator to the prover worker.
struct PendingStep {
    step: usize,
    wit: StepWitness,
    witness_ms: f64,
    loss: f64,
    accuracy: f64,
}

/// Train `opts.steps` SGD steps on `dataset`, proving each `prove_every`-th
/// step end-to-end. Witness generation (step k+1) overlaps with proving
/// (step k) via a bounded channel. Returns the metrics trail.
pub fn train_and_prove(
    cfg: ModelConfig,
    dataset: &Dataset,
    artifact_dir: &Path,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    ensure!(opts.steps > 0 && opts.prove_every > 0 && opts.pipeline_depth > 0);
    let mut rng = Rng::seed_from_u64(opts.seed);
    let mut weights = Weights::init(cfg, &mut rng);
    let source = WitnessSource::auto(artifact_dir, cfg);
    // prover key setup is a one-time cost, shared across steps
    let pk = ProverKey::setup(cfg);
    let pk = &pk;
    let source_name = source.name();

    let t_run = Instant::now();
    let steps = std::thread::scope(|scope| -> Result<Vec<StepMetrics>> {
        let (tx, rx) = mpsc::sync_channel::<PendingStep>(opts.pipeline_depth);
        let prover = scope.spawn(move || -> Result<Vec<StepMetrics>> {
            crate::telemetry::trace_export::set_thread_name("prover-worker");
            let mut prng = Rng::seed_from_u64(opts.seed ^ 0x9e3779b97f4a7c15);
            let mut out = Vec::new();
            while let Ok(pending) = rx.recv() {
                let PendingStep {
                    step,
                    wit,
                    witness_ms,
                    loss,
                    accuracy,
                } = pending;
                let (prove_ms, verify_ms, proof_bytes, phases) = if step % opts.prove_every == 0 {
                    let t1 = Instant::now();
                    // isolate: the worker runs at top level (no open span),
                    // so each step gets its own per-call phase tree
                    let (proof, prove_tree) = crate::telemetry::isolate(|| {
                        prove_step(pk, &wit, opts.mode, &mut prng)
                    });
                    let prove_ms = t1.elapsed().as_secs_f64() * 1e3;
                    let bytes = proof.size_bytes();
                    let verify_ms = if opts.skip_verify {
                        0.0
                    } else {
                        let t2 = Instant::now();
                        verify_step(pk, &proof)
                            .with_context(|| format!("verify at step {step}"))?;
                        t2.elapsed().as_secs_f64() * 1e3
                    };
                    (prove_ms, verify_ms, bytes, prove_tree.phase_breakdown())
                } else {
                    (0.0, 0.0, 0, Vec::new())
                };
                out.push(StepMetrics {
                    step,
                    loss,
                    accuracy,
                    witness_ms,
                    prove_ms,
                    verify_ms,
                    proof_bytes,
                    witness_source: source_name,
                    phases,
                });
            }
            Ok(out)
        });

        for step in 0..opts.steps {
            let (x, y) = dataset.batch(&cfg, step);
            let t0 = Instant::now();
            let wit = source
                .compute_witness(&x, &y, &weights)
                .with_context(|| format!("witness at step {step}"))?;
            let witness_ms = t0.elapsed().as_secs_f64() * 1e3;
            let loss = wit.loss();
            let z_prime_last = &wit.layers[cfg.depth - 1].z_prime;
            let accuracy = dataset.batch_accuracy(&cfg, step, z_prime_last);
            // the SGD update needs only the gradients, not the proof — so
            // the next witness can be generated while this one is proven
            weights.apply_update(&wit.weight_grads());
            let pending = PendingStep {
                step,
                wit,
                witness_ms,
                loss,
                accuracy,
            };
            if tx.send(pending).is_err() {
                // worker exited early — stop feeding and surface its error
                break;
            }
        }
        drop(tx);
        prover.join().expect("prover worker panicked")
    })?;

    Ok(TrainReport {
        steps,
        wall_s: t_run.elapsed().as_secs_f64(),
    })
}

/// Options for an aggregated (FAC4DNN multi-step) proven training run.
pub struct TraceTrainOptions {
    pub steps: usize,
    /// Aggregation window T: one [`TraceProof`] per `window` consecutive
    /// steps (the final window may be shorter). 0 means one window covering
    /// the whole run.
    pub window: usize,
    pub seed: u64,
    pub skip_verify: bool,
    /// Prove each window with the zkOptim chain argument (inter-step
    /// weight/state recurrence under `rule`); a trailing 1-step window
    /// falls back to an unchained proof, since it has no boundary to chain.
    pub chained: bool,
    /// The optimizer driving (and, when `chained`, proven by) the run.
    pub rule: UpdateRule,
    /// Per-step learning-rate schedule; `None` = the config's constant
    /// `lr_shift` (the pre-schedule behavior).
    pub lr_schedule: Option<LrSchedule>,
    /// Prove every window with the zkData batch-provenance argument: the
    /// dataset is committed ONCE up front (its Merkle root is the
    /// endorsable Appendix-B statement) and every window's proof binds its
    /// steps' inputs to that one commitment.
    pub provenance: bool,
    /// Max in-flight *windows* of witnesses between the coordinator thread
    /// and the aggregator worker (channel capacity = window × depth).
    /// Affects scheduling only: artifacts are byte-identical at any depth.
    pub pipeline_depth: usize,
}

impl Default for TraceTrainOptions {
    fn default() -> Self {
        Self {
            steps: 8,
            window: 0,
            seed: 0x5eed,
            skip_verify: false,
            chained: false,
            rule: UpdateRule::Sgd,
            lr_schedule: None,
            provenance: false,
            pipeline_depth: 2,
        }
    }
}

/// Metrics of one aggregated window.
#[derive(Clone, Debug)]
pub struct TraceWindowMetrics {
    pub start_step: usize,
    pub steps: usize,
    pub prove_ms: f64,
    pub verify_ms: f64,
    pub proof_bytes: usize,
    /// Span-sourced `(phase, ms)` breakdown of the window's prove call
    /// (zkObs); empty when telemetry is disabled.
    pub phases: Vec<(String, f64)>,
}

impl TraceWindowMetrics {
    /// One-line phase breakdown; empty string when no phases were recorded.
    pub fn phase_summary(&self) -> String {
        fmt_phases(&self.phases)
    }
}

/// Outcome of an aggregated proven training run.
pub struct TraceRunReport {
    pub windows: Vec<TraceWindowMetrics>,
    pub proofs: Vec<TraceProof>,
    pub losses: Vec<f64>,
    pub witness_ms_total: f64,
    pub wall_s: f64,
    /// The Appendix-B root of the committed dataset (provenance runs only)
    /// — the statement a trusted verifier endorses once for the whole run.
    pub dataset_root: Option<Vec<u8>>,
}

impl TraceRunReport {
    pub fn total_proof_bytes(&self) -> usize {
        self.windows.iter().map(|w| w.proof_bytes).sum()
    }

    pub fn summary(&self) -> String {
        let steps: usize = self.windows.iter().map(|w| w.steps).sum();
        format!(
            "trace windows={} steps={} | witness {:.1} ms total | prove {:.1} ms, verify {:.1} ms | {:.1} kB aggregated | wall {:.2} s",
            self.windows.len(),
            steps,
            self.witness_ms_total,
            self.windows.iter().map(|w| w.prove_ms).sum::<f64>(),
            self.windows.iter().map(|w| w.verify_ms).sum::<f64>(),
            self.total_proof_bytes() as f64 / 1024.0,
            self.wall_s,
        )
    }
}

/// Train and prove with multi-step aggregation: witnesses stream through a
/// bounded channel into the aggregator worker, which proves window k while
/// the coordinator generates witnesses for window k+1.
pub fn train_and_prove_trace(
    cfg: ModelConfig,
    dataset: &Dataset,
    artifact_dir: &Path,
    opts: &TraceTrainOptions,
) -> Result<TraceRunReport> {
    ensure!(opts.steps > 0 && opts.pipeline_depth > 0);
    let window = if opts.window == 0 { opts.steps } else { opts.window };
    // window = 1 would hit the 1-step fallback on EVERY window: the run
    // would silently produce only unchained proofs while the caller asked
    // for chained ones
    ensure!(
        !opts.chained || window >= 2,
        "chained proving needs windows of at least two steps (window = 1 chains nothing)"
    );
    let rule = opts.rule;
    let schedule = opts.lr_schedule.unwrap_or(LrSchedule::Constant(cfg.lr_shift));
    // fail the whole run up front, not at the first window flush, if any
    // step's digit budget is unprovable
    crate::update::rule::validate_shift_table(
        &cfg,
        &rule,
        &schedule.window_table(0, opts.steps),
    )?;
    let mut rng = Rng::seed_from_u64(opts.seed);
    let mut weights = Weights::init(cfg, &mut rng);
    let mut opt_state = rule.init_state(&cfg);
    let source = WitnessSource::auto(artifact_dir, cfg);
    // provenance proves one-hot selections, so a batch cannot repeat rows;
    // plain runs with batch > dataset keep the legacy wrapping schedule
    ensure!(
        !opts.provenance || cfg.batch <= dataset.len(),
        "batch {} exceeds dataset size {} (provenance needs without-replacement sampling)",
        cfg.batch,
        dataset.len()
    );
    // seeded without-replacement batch schedule — reproducible from the
    // run seed, and the source of each witness's provenance rows
    let mut sampler = (cfg.batch <= dataset.len())
        .then(|| BatchSampler::new(dataset.len(), opts.seed ^ 0xda7a));
    // the dataset commitment is a one-time cost, shared by every window of
    // the run (and across runs: its root is what gets endorsed)
    let prover_dataset: Option<ProverDataset> = opts
        .provenance
        .then(|| ProverDataset::build(dataset, &cfg))
        .transpose()
        .context("committing the dataset")?;
    let dataset_root = prover_dataset.as_ref().map(|pd| pd.commitment.root.clone());

    let t_run = Instant::now();
    let mut witness_ms_total = 0.0;
    let mut losses = Vec::with_capacity(opts.steps);

    struct WindowOut {
        metrics: TraceWindowMetrics,
        proof: TraceProof,
    }

    let (windows, proofs) = std::thread::scope(|scope| -> Result<(Vec<TraceWindowMetrics>, Vec<TraceProof>)> {
        let capacity = window.saturating_mul(opts.pipeline_depth).max(2);
        let (tx, rx) = mpsc::sync_channel::<(usize, StepWitness)>(capacity);
        let skip_verify = opts.skip_verify;
        let chained = opts.chained;
        let seed = opts.seed;
        let prover_dataset = &prover_dataset;
        let aggregator = scope.spawn(move || -> Result<Vec<WindowOut>> {
            crate::telemetry::trace_export::set_thread_name("aggregator-worker");
            let mut prng = Rng::seed_from_u64(seed ^ 0x7ace);
            let mut out = Vec::new();
            let mut buf: Vec<StepWitness> = Vec::with_capacity(window);
            let mut start_step = 0usize;
            let mut flush = |buf: &mut Vec<StepWitness>,
                             start_step: usize,
                             prng: &mut Rng|
             -> Result<WindowOut> {
                let t = buf.len();
                let tk = TraceKey::setup(cfg, t);
                let t1 = Instant::now();
                // isolate: the aggregator runs at top level (no open span),
                // so each window gets its own per-call phase tree
                let (proof, prove_tree) = crate::telemetry::isolate(|| -> Result<TraceProof> {
                    Ok(match (chained && t >= 2, prover_dataset) {
                        (true, Some(pd)) => {
                            // boundary b of this window is the update applied
                            // after global step start_step + b
                            let shifts = schedule.window_table(start_step, t - 1);
                            prove_trace_chained_provenance_with(&tk, buf, &rule, &shifts, pd, prng)?
                        }
                        (true, None) => {
                            let shifts = schedule.window_table(start_step, t - 1);
                            prove_trace_chained_with(&tk, buf, &rule, &shifts, prng)?
                        }
                        (false, Some(pd)) => prove_trace_provenance(&tk, buf, pd, prng)?,
                        (false, None) => prove_trace(&tk, buf, prng),
                    })
                });
                let proof = proof?;
                let prove_ms = t1.elapsed().as_secs_f64() * 1e3;
                let verify_ms = if skip_verify {
                    0.0
                } else {
                    let t2 = Instant::now();
                    verify_trace(&tk, &proof)
                        .with_context(|| format!("verify trace window at step {start_step}"))?;
                    t2.elapsed().as_secs_f64() * 1e3
                };
                let metrics = TraceWindowMetrics {
                    start_step,
                    steps: t,
                    prove_ms,
                    verify_ms,
                    proof_bytes: proof.size_bytes(),
                    phases: prove_tree.phase_breakdown(),
                };
                buf.clear();
                Ok(WindowOut { metrics, proof })
            };
            while let Ok((step, wit)) = rx.recv() {
                if buf.is_empty() {
                    start_step = step;
                }
                buf.push(wit);
                if buf.len() == window {
                    out.push(flush(&mut buf, start_step, &mut prng)?);
                }
            }
            if !buf.is_empty() {
                out.push(flush(&mut buf, start_step, &mut prng)?);
            }
            Ok(out)
        });

        for step in 0..opts.steps {
            let rows = match sampler.as_mut() {
                Some(s) => s.next_batch(cfg.batch),
                None => dataset.batch_indices(&cfg, step),
            };
            let (x, y) = dataset.batch_at(&cfg, &rows);
            let t0 = Instant::now();
            let mut wit = source
                .compute_witness(&x, &y, &weights)
                .with_context(|| format!("witness at step {step}"))?;
            witness_ms_total += t0.elapsed().as_secs_f64() * 1e3;
            losses.push(wit.loss());
            // the witness carries the optimizer state *entering* its step
            // and the provenance rows behind its batch; the rule's exact
            // quantized update then advances weights and state
            wit.opt_state = opt_state.clone();
            wit.batch_rows = rows;
            rule.apply_update(
                schedule.shift_at(step),
                &mut weights,
                &mut opt_state,
                &wit.weight_grads(),
            );
            if tx.send((step, wit)).is_err() {
                // worker exited early — stop feeding and surface its error
                break;
            }
        }
        drop(tx);
        let outs = aggregator.join().expect("aggregator worker panicked")?;
        let mut metrics = Vec::with_capacity(outs.len());
        let mut proofs = Vec::with_capacity(outs.len());
        for o in outs {
            metrics.push(o.metrics);
            proofs.push(o.proof);
        }
        Ok((metrics, proofs))
    })?;

    Ok(TraceRunReport {
        windows,
        proofs,
        losses,
        witness_ms_total,
        wall_s: t_run.elapsed().as_secs_f64(),
        dataset_root,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_end_to_end_small() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(64, 4, 4, cfg.r_bits, 42);
        let opts = TrainOptions {
            steps: 3,
            prove_every: 2,
            ..Default::default()
        };
        let report =
            train_and_prove(cfg, &ds, Path::new("artifacts"), &opts).expect("run succeeds");
        assert_eq!(report.steps.len(), 3);
        // steps 0 and 2 proven, step 1 not
        assert!(report.steps[0].proof_bytes > 0);
        assert_eq!(report.steps[1].proof_bytes, 0);
        assert!(report.steps[2].proof_bytes > 0);
        assert!(report.to_csv().lines().count() == 4);
        assert!(report.wall_s > 0.0);
        assert!(report.throughput_steps_per_s() > 0.0);
        // pipelining must preserve step order in the metrics trail
        assert!(report.steps.windows(2).all(|w| w[0].step + 1 == w[1].step));
    }

    #[test]
    fn training_loss_decreases_over_run() {
        // single repeated batch (dataset size == batch size) so the loss
        // trajectory is comparable step to step
        let cfg = ModelConfig::new(2, 16, 8);
        let ds = Dataset::synthetic(8, 8, 4, cfg.r_bits, 7);
        let opts = TrainOptions {
            steps: 20,
            prove_every: 1000, // no proofs — just the training loop
            ..Default::default()
        };
        let report = train_and_prove(cfg, &ds, Path::new("artifacts"), &opts).unwrap();
        let first = report.steps[0].loss;
        let last = report.steps.last().unwrap().loss;
        assert!(last < first, "loss should fall: {first} → {last}");
    }

    #[test]
    fn trace_driver_windows_cover_all_steps() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 11);
        let opts = TraceTrainOptions {
            steps: 3,
            window: 2, // windows of 2 and 1
            seed: 3,
            skip_verify: false,
            ..Default::default()
        };
        let report =
            train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts).expect("trace run");
        assert_eq!(report.windows.len(), 2);
        assert_eq!(report.windows[0].steps, 2);
        assert_eq!(report.windows[1].steps, 1);
        assert_eq!(report.windows[1].start_step, 2);
        assert_eq!(report.proofs.len(), 2);
        assert_eq!(report.losses.len(), 3);
        assert!(report.total_proof_bytes() > 0);
    }

    #[test]
    fn chained_trace_driver_verifies_and_marks_chain() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 12);
        let opts = TraceTrainOptions {
            steps: 5,
            window: 2, // windows of 2, 2, and a 1-step tail
            seed: 4,
            chained: true,
            ..Default::default()
        };
        let report =
            train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts).expect("chained run");
        assert_eq!(report.proofs.len(), 3);
        // full windows carry the chain; the 1-step tail has no boundary
        assert!(report.proofs[0].chain.is_some());
        assert!(report.proofs[1].chain.is_some());
        assert!(report.proofs[2].chain.is_none());
    }

    #[test]
    fn momentum_chained_driver_with_decay_schedule_verifies() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 14);
        let opts = TraceTrainOptions {
            steps: 4,
            window: 2,
            seed: 6,
            chained: true,
            rule: UpdateRule::momentum_default(),
            lr_schedule: Some(LrSchedule::StepDecay {
                base: cfg.lr_shift,
                period: 2,
                max: cfg.lr_shift + 3,
            }),
            ..Default::default()
        };
        let report =
            train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts).expect("momentum run");
        assert_eq!(report.proofs.len(), 2);
        for (i, proof) in report.proofs.iter().enumerate() {
            let chain = proof.chain.as_ref().expect("window chained");
            assert_eq!(chain.rule, UpdateRule::momentum_default());
            // window 0 covers boundary 0 (shift 8), window 1 boundary 2
            // (shift 9) — the per-window tables track the global schedule
            let want = if i == 0 { vec![cfg.lr_shift] } else { vec![cfg.lr_shift + 1] };
            assert_eq!(chain.lr_shifts, want, "window {i}");
        }
        // an unprovable schedule is refused before any training happens
        let bad = TraceTrainOptions {
            lr_schedule: Some(LrSchedule::Constant(60)), // S = 76 > 64
            ..opts
        };
        assert!(train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &bad).is_err());
    }

    #[test]
    fn provenance_driver_reuses_one_dataset_commitment_across_windows() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 15);
        let opts = TraceTrainOptions {
            steps: 4,
            window: 2,
            seed: 7,
            chained: true,
            provenance: true,
            ..Default::default()
        };
        let report = train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts)
            .expect("provenance run");
        let root = report.dataset_root.as_ref().expect("root reported");
        assert_eq!(report.proofs.len(), 2);
        for proof in &report.proofs {
            let prov = proof.provenance.as_ref().expect("window carries provenance");
            assert_eq!(&prov.dataset.root, root, "one commitment, every window");
            assert_eq!(prov.dataset.n_rows, 32);
            assert!(proof.chain.is_some(), "chain and provenance compose");
        }
        // a batch larger than the dataset cannot be sampled without
        // replacement — refused up front
        let tiny = Dataset::synthetic(2, 4, 2, cfg.r_bits, 16);
        assert!(train_and_prove_trace(cfg, &tiny, Path::new("artifacts"), &opts).is_err());
    }

    #[test]
    fn trace_metrics_carry_phase_breakdowns_when_profiling() {
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 21);
        let opts = TraceTrainOptions {
            steps: 2,
            window: 2,
            seed: 9,
            ..Default::default()
        };
        let (report, _) = crate::telemetry::capture(|| {
            train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts).expect("trace run")
        });
        let w = &report.windows[0];
        assert!(!w.phases.is_empty(), "profiled run records phases");
        assert!(w.phase_summary().contains("ms"));
        // telemetry off (the default) ⇒ no phases; under the exclusive lock
        // no parallel test can flip it on mid-run
        let report = crate::telemetry::exclusive(|| {
            train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts).expect("trace run")
        });
        assert!(report.windows[0].phases.is_empty());
        assert_eq!(report.windows[0].phase_summary(), "");
    }

    #[test]
    fn pipeline_depth_yields_byte_identical_trace_artifacts() {
        // pipeline_depth changes only the channel capacity (scheduling);
        // the persisted artifacts must not depend on it
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(32, 4, 4, cfg.r_bits, 13);
        let run = |pipeline_depth: usize| -> Vec<Vec<u8>> {
            let opts = TraceTrainOptions {
                steps: 4,
                window: 2,
                seed: 5,
                skip_verify: true,
                chained: true,
                pipeline_depth,
                ..Default::default()
            };
            let report = train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts)
                .expect("trace run");
            report
                .proofs
                .iter()
                .map(|p| crate::wire::encode_trace_proof(&cfg, p))
                .collect()
        };
        let base = run(1);
        assert_eq!(base.len(), 2);
        for depth in [2usize, 4] {
            assert_eq!(
                base,
                run(depth),
                "pipeline_depth={depth} must not change the artifacts"
            );
        }
    }
}
