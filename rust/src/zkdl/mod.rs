//! zkDL Protocol 2 — the full training-step prover and verifier (paper §4).
//!
//! One [`StepProof`] certifies that the committed witness of one SGD step
//! satisfies every relation of Example 4.5:
//!   (30) Z^ℓ = A^{ℓ−1}·W^ℓ          — batched matmul sumcheck
//!   (33) G_A^ℓ = G_Z^{ℓ+1}·W^{ℓ+1ᵀ} — batched matmul sumcheck
//!   (34) G_W^ℓ = G_Z^{ℓᵀ}·A^{ℓ−1}   — batched matmul sumcheck
//!   (2)/(4)  A = (1−B)⊙Z″, G_Z = (1−B)⊙G_A′ — the stacking sumcheck (27)
//!   (3)/(5)  Z/G_A rescale decompositions    — homomorphically derived
//!                                              commitment openings
//!   (32) G_Z^L = Z^{L′} − Y                  — derived commitment opening
//!   aux ranges (Thm 4.1)                     — zkReLU validity (eq. 19)
//!
//! Two proof-generation orders are supported (Figure 4's comparison):
//! * [`ProofMode::Parallel`] — the paper's contribution: all layers share
//!   the same randomness, per-layer claims are batched by random linear
//!   combination, aux tensors are stacked, and one validity instance covers
//!   the whole network. Proof size grows O(log L).
//! * [`ProofMode::Sequential`] — the conventional layer-by-layer order
//!   (Liu et al. [1]): per-layer randomness, per-layer openings, per-layer
//!   validity. Proof size grows O(L).

use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::{G1, G1Affine};
use crate::field::Fr;
use crate::gkr;
use crate::ipa::{self, EvalClaim, IpaProof};
use crate::model::ModelConfig;
use crate::poly::{self, eq_table, Mle};
use crate::sumcheck::{self, Instance, SumcheckProof, Term};
use crate::transcript::Transcript;
use crate::util::arena::FrArena;
use crate::util::rng::Rng;
use crate::witness::StepWitness;
use crate::zkrelu::{self, Protocol1Msg, ValidityBases, ValidityProof};
use anyhow::{bail, ensure, Context, Result};

/// Proof-generation order (Figure 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProofMode {
    Parallel,
    Sequential,
}

impl ProofMode {
    pub fn name(&self) -> &'static str {
        match self {
            ProofMode::Parallel => "parallel",
            ProofMode::Sequential => "sequential",
        }
    }
}

/// Commitment bases sized for one model configuration.
pub struct ProverKey {
    pub cfg: ModelConfig,
    /// Stacked-aux basis, length N = L̄·D; layer ℓ owns block [ℓD, (ℓ+1)D).
    pub g_aux: CommitKey,
    /// Weight/weight-gradient basis, length d².
    pub g_mat: CommitKey,
    /// Input basis, length D.
    pub g_x: CommitKey,
}

/// Padded layer count L̄ and stacked size N for a config.
pub fn stack_dims(cfg: &ModelConfig) -> (usize, usize) {
    let lbar = cfg.depth.next_power_of_two();
    (lbar, lbar * cfg.d_size())
}

impl ProverKey {
    pub fn setup(cfg: ModelConfig) -> Self {
        let (_, n) = stack_dims(&cfg);
        let d2 = cfg.width * cfg.width;
        let key = Self {
            cfg,
            g_aux: CommitKey::setup(b"zkdl/aux", n),
            g_mat: CommitKey::setup(b"zkdl/mat", d2),
            g_x: CommitKey::setup(b"zkdl/x", cfg.d_size()),
        };
        // fixed-base tables, built once per cached key at setup
        key.g_aux.warm_table();
        key.g_mat.warm_table();
        key.g_x.warm_table();
        key
    }

    /// Commitment key slice for layer ℓ's aux block. Shares the stacked
    /// basis' fixed-base table via the slice offset.
    pub fn block(&self, l: usize) -> CommitKey {
        let d = self.cfg.d_size();
        self.g_aux.slice(l * d, (l + 1) * d)
    }
}

/// One committed tensor with its opening (prover side).
#[derive(Clone)]
pub(crate) struct Committed {
    pub(crate) values: Vec<Fr>,
    pub(crate) blind: Fr,
    pub(crate) com: G1,
}

pub(crate) fn commit(ck: &CommitKey, values: Vec<Fr>, rng: &mut Rng) -> Committed {
    let blind = Fr::random(rng);
    let com = ck.commit(&values, blind);
    Committed { values, blind, com }
}

pub(crate) fn frs(v: &[i64]) -> Vec<Fr> {
    v.iter().map(|&x| Fr::from_i64(x)).collect()
}

/// Proof of one layer group (all layers in Parallel mode, one layer per
/// group in Sequential mode).
#[derive(Clone, Debug)]
pub struct GroupProof {
    pub p1_main: Protocol1Msg,
    pub p1_rem: Protocol1Msg,
    /// Claimed output evaluations, per layer in group: Z̃(pz), G̃_A(pga)
    /// (inner layers only), G̃_W(pgw).
    pub v_z: Vec<Fr>,
    pub v_ga: Vec<Fr>,
    pub v_gw: Vec<Fr>,
    pub mm30: SumcheckProof,
    /// (Ã^{ℓ−1}(u_zr,r30), W̃^ℓ(r30,u_zc)) per layer in group.
    pub mm30_evals: Vec<(Fr, Fr)>,
    pub mm33: Option<SumcheckProof>,
    /// (G̃_Z^{ℓ+1}(u_gar,r33), W̃^{ℓ+1}(u_gac,r33)).
    pub mm33_evals: Vec<(Fr, Fr)>,
    pub mm34: SumcheckProof,
    /// (G̃_Z^ℓ(r34,u_gwr), Ã^{ℓ−1}(r34,u_gwc)).
    pub mm34_evals: Vec<(Fr, Fr)>,
    /// Stacking sumcheck (27); absent when the group has no inner-layer
    /// claims (e.g. depth-1 networks / the last layer's group).
    pub stack: Option<SumcheckProof>,
    /// Prover-supplied slot claims for the four stacking terms (length L̄
    /// of the group); entries covered by matmul factor evals are checked
    /// against them by the verifier.
    pub va1: Vec<Fr>,
    pub va2: Vec<Fr>,
    pub vgz1: Vec<Fr>,
    pub vgz2: Vec<Fr>,
    /// Opened stacked-aux evaluations at ρ: (sign, Z″, G_A′, R_Z, R_GA).
    pub aux_evals: [Fr; 5],
    /// Batched opening IPAs, in canonical group order.
    pub openings: Vec<IpaProof>,
    pub validity_main: ValidityProof,
    pub validity_rem: ValidityProof,
}

/// Full proof of one training step.
#[derive(Clone, Debug)]
pub struct StepProof {
    pub mode: ProofMode,
    pub com_w: Vec<G1Affine>,
    pub com_gw: Vec<G1Affine>,
    pub com_zdp: Vec<G1Affine>,
    pub com_sign: Vec<G1Affine>,
    pub com_rz: Vec<G1Affine>,
    pub com_gap: Vec<G1Affine>,
    pub com_rga: Vec<G1Affine>,
    pub com_x: G1Affine,
    pub com_y: G1Affine,
    pub groups: Vec<GroupProof>,
}

impl GroupProof {
    pub fn size_bytes(&self) -> usize {
        let scalars = self.v_z.len()
            + self.v_ga.len()
            + self.v_gw.len()
            + 2 * (self.mm30_evals.len() + self.mm33_evals.len() + self.mm34_evals.len())
            + self.va1.len()
            + self.va2.len()
            + self.vgz1.len()
            + self.vgz2.len()
            + 5;
        let p1 = 32 + 32 + if self.p1_main.com_sign_prime.is_some() { 32 } else { 0 };
        let sumchecks = self.mm30.size_bytes()
            + self.mm33.as_ref().map_or(0, |p| p.size_bytes())
            + self.mm34.size_bytes()
            + self.stack.as_ref().map_or(0, |p| p.size_bytes());
        let openings: usize = self.openings.iter().map(|o| o.size_bytes()).sum();
        scalars * 32
            + p1
            + sumchecks
            + openings
            + self.validity_main.size_bytes()
            + self.validity_rem.size_bytes()
    }
}

impl StepProof {
    /// Total proof size in bytes (compressed-point accounting, as the paper
    /// reports kB figures).
    pub fn size_bytes(&self) -> usize {
        let coms = self.com_w.len()
            + self.com_gw.len()
            + self.com_zdp.len()
            + self.com_sign.len()
            + self.com_rz.len()
            + self.com_gap.len()
            + self.com_rga.len()
            + 2;
        coms * 32 + self.groups.iter().map(|g| g.size_bytes()).sum::<usize>()
    }
}

// ---------------------------------------------------------------------------
// Prover
// ---------------------------------------------------------------------------

/// Prover-side tensors of one layer group.
pub(crate) struct ProverLayers<'a> {
    pub(crate) wit: &'a StepWitness,
    // field copies of all tensors, indexed by layer
    pub(crate) w: Vec<gkr::Matrix>,
    pub(crate) a: Vec<gkr::Matrix>, // activations A^0..A^{L-1}; A^{-1} = X handled apart
    pub(crate) x: gkr::Matrix,
    pub(crate) g_z: Vec<gkr::Matrix>,
    pub(crate) zdp: Vec<Vec<Fr>>,
    pub(crate) sign: Vec<Vec<Fr>>,
    pub(crate) rz: Vec<Vec<Fr>>,
    pub(crate) gap: Vec<Vec<Fr>>,
    pub(crate) rga: Vec<Vec<Fr>>,
}

impl<'a> ProverLayers<'a> {
    pub(crate) fn build(wit: &'a StepWitness) -> Self {
        let cfg = &wit.cfg;
        let (b, d) = (cfg.batch, cfg.width);
        let depth = cfg.depth;
        let x = gkr::Matrix::from_i64(&wit.x, b, d);
        let mut w = Vec::new();
        let mut a = Vec::new();
        let mut g_z = Vec::new();
        let mut zdp = Vec::new();
        let mut sign = Vec::new();
        let mut rz = Vec::new();
        let mut gap = Vec::new();
        let mut rga = Vec::new();
        for (l, lw) in wit.layers.iter().enumerate() {
            w.push(gkr::Matrix::from_i64(&lw.w, d, d));
            g_z.push(gkr::Matrix::from_i64(&lw.g_z, b, d));
            zdp.push(frs(&lw.z_aux.dprime));
            sign.push(frs(&lw.z_aux.sign));
            rz.push(frs(&lw.z_aux.rem));
            if l + 1 < depth {
                a.push(gkr::Matrix::from_i64(lw.a.as_ref().unwrap(), b, d));
                gap.push(frs(lw.g_a_prime.as_ref().unwrap()));
                rga.push(frs(&lw.g_a_aux.as_ref().unwrap().rem));
            } else {
                // virtual A^{L−1} = (1−B)⊙Z″ (never used in matmuls) and
                // zero gradient-aux tensors keep the stacks uniform.
                let va: Vec<Fr> = zdp[l]
                    .iter()
                    .zip(sign[l].iter())
                    .map(|(z, s)| (Fr::ONE - *s) * *z)
                    .collect();
                a.push(gkr::Matrix::new(va, b, d));
                gap.push(vec![Fr::ZERO; b * d]);
                rga.push(vec![Fr::ZERO; b * d]);
            }
        }
        Self {
            wit,
            w,
            a,
            x,
            g_z,
            zdp,
            sign,
            rz,
            gap,
            rga,
        }
    }

    /// Stacked tensor over `layers` slots (padded to L̄·D with zeros).
    pub(crate) fn stacked(&self, per_layer: &[Vec<Fr>], layers: &[usize], lbar: usize, d: usize) -> Vec<Fr> {
        let mut out = vec![Fr::ZERO; lbar * d];
        for (slot, &l) in layers.iter().enumerate() {
            out[slot * d..slot * d + d].copy_from_slice(&per_layer[l]);
        }
        out
    }
}

/// All commitments + blinds for one step (prover side).
struct StepCommitments {
    w: Vec<Committed>,
    gw: Vec<Committed>,
    zdp: Vec<Committed>,
    sign: Vec<Committed>,
    rz: Vec<Committed>,
    gap: Vec<Committed>,
    rga: Vec<Committed>,
    x: Committed,
    y: Committed,
}

fn commit_step(pk: &ProverKey, pl: &ProverLayers, rng: &mut Rng) -> StepCommitments {
    crate::span!("zkdl/commit");
    let depth = pk.cfg.depth;
    let mut w = Vec::new();
    let mut gw = Vec::new();
    let mut zdp = Vec::new();
    let mut sign = Vec::new();
    let mut rz = Vec::new();
    let mut gap = Vec::new();
    let mut rga = Vec::new();
    for l in 0..depth {
        let blk = pk.block(l);
        w.push(commit(&pk.g_mat, pl.w[l].data.clone(), rng));
        gw.push(commit(&pk.g_mat, frs(&pl.wit.layers[l].g_w), rng));
        zdp.push(commit(&blk, pl.zdp[l].clone(), rng));
        sign.push(commit(&blk, pl.sign[l].clone(), rng));
        rz.push(commit(&blk, pl.rz[l].clone(), rng));
        gap.push(commit(&blk, pl.gap[l].clone(), rng));
        rga.push(commit(&blk, pl.rga[l].clone(), rng));
    }
    let x = commit(&pk.g_x, pl.x.data.clone(), rng);
    // Y lives in layer L−1's aux block so that the derived commitment of
    // G_Z^{L−1} = Z″ − 2^{Q−1}B − Y stays single-basis.
    let y = commit(&pk.block(depth - 1), frs(&pl.wit.y), rng);
    StepCommitments {
        w,
        gw,
        zdp,
        sign,
        rz,
        gap,
        rga,
        x,
        y,
    }
}

pub(crate) fn absorb_commitments(t: &mut Transcript, coms: &[(&[u8], Vec<G1Affine>)]) {
    for (label, pts) in coms {
        t.absorb_points(label, pts);
    }
}

/// Challenge bundle of one group's matmul phase.
pub(crate) struct GroupChallenges {
    pub(crate) gamma: Fr,
    pub(crate) u_zr: Vec<Fr>,
    pub(crate) u_zc: Vec<Fr>,
    pub(crate) u_gar: Vec<Fr>,
    pub(crate) u_gac: Vec<Fr>,
    pub(crate) u_gwr: Vec<Fr>,
    pub(crate) u_gwc: Vec<Fr>,
}

pub(crate) fn draw_group_challenges(t: &mut Transcript, log_b: usize, log_d: usize) -> GroupChallenges {
    GroupChallenges {
        gamma: t.challenge_fr(b"zkdl/gamma"),
        u_zr: t.challenge_frs(b"zkdl/u_zr", log_b),
        u_zc: t.challenge_frs(b"zkdl/u_zc", log_d),
        u_gar: t.challenge_frs(b"zkdl/u_gar", log_b),
        u_gac: t.challenge_frs(b"zkdl/u_gac", log_d),
        u_gwr: t.challenge_frs(b"zkdl/u_gwr", log_d),
        u_gwc: t.challenge_frs(b"zkdl/u_gwc", log_d),
    }
}

/// Symbolic derived commitment of Z^ℓ via (3):
/// com_zdp^{2^R}·com_sign^{−2^{Q+R−1}}·com_rz. The expression form is the
/// single source of the coefficients — the deferred verifier merges it into
/// the one MSM, the prover materializes it via [`ComExpr::eval`].
pub(crate) fn derived_expr_z(cfg: &ModelConfig, zdp: G1, sign: G1, rz: G1) -> ComExpr {
    let two_r = Fr::from_u128(1u128 << cfg.r_bits);
    let two_qr = Fr::from_u128(1u128 << (cfg.q_bits + cfg.r_bits - 1));
    ComExpr {
        terms: vec![(two_r, zdp), (-two_qr, sign), (Fr::ONE, rz)],
    }
}

/// Symbolic derived commitment of G_A^ℓ via (5): com_gap^{2^R}·com_rga.
pub(crate) fn derived_expr_ga(cfg: &ModelConfig, gap: G1, rga: G1) -> ComExpr {
    ComExpr {
        terms: vec![(Fr::from_u128(1u128 << cfg.r_bits), gap), (Fr::ONE, rga)],
    }
}

/// Symbolic derived commitment of G_Z^{L−1} via (32):
/// com_zdp·com_sign^{−2^{Q−1}}·com_y^{−1}.
pub(crate) fn derived_expr_gz_last(cfg: &ModelConfig, zdp: G1, sign: G1, y: G1) -> ComExpr {
    let two_q = Fr::from_u128(1u128 << (cfg.q_bits - 1));
    ComExpr {
        terms: vec![(Fr::ONE, zdp), (-two_q, sign), (-Fr::ONE, y)],
    }
}

/// Materialized forms (prover side), evaluated from the same expressions so
/// prover and deferred verifier can never drift on a coefficient.
pub(crate) fn derived_com_z(cfg: &ModelConfig, zdp: &G1, sign: &G1, rz: &G1) -> G1 {
    derived_expr_z(cfg, *zdp, *sign, *rz).eval()
}

pub(crate) fn derived_com_ga(cfg: &ModelConfig, gap: &G1, rga: &G1) -> G1 {
    derived_expr_ga(cfg, *gap, *rga).eval()
}

pub(crate) fn derived_com_gz_last(cfg: &ModelConfig, zdp: &G1, sign: &G1, y: &G1) -> G1 {
    derived_expr_gz_last(cfg, *zdp, *sign, *y).eval()
}

/// Prover-side derived openings (values + blinds follow the same linear
/// combinations as the commitments).
pub(crate) fn derived_open_z(cfg: &ModelConfig, zdp: &Committed, sign: &Committed, rz: &Committed) -> (Vec<Fr>, Fr) {
    let two_r = Fr::from_u128(1u128 << cfg.r_bits);
    let two_qr = Fr::from_u128(1u128 << (cfg.q_bits + cfg.r_bits - 1));
    let vals = zdp
        .values
        .iter()
        .zip(sign.values.iter())
        .zip(rz.values.iter())
        .map(|((z, s), r)| two_r * *z - two_qr * *s + *r)
        .collect();
    (vals, two_r * zdp.blind - two_qr * sign.blind + rz.blind)
}

pub(crate) fn derived_open_ga(cfg: &ModelConfig, gap: &Committed, rga: &Committed) -> (Vec<Fr>, Fr) {
    let two_r = Fr::from_u128(1u128 << cfg.r_bits);
    let vals = gap
        .values
        .iter()
        .zip(rga.values.iter())
        .map(|(g, r)| two_r * *g + *r)
        .collect();
    (vals, two_r * gap.blind + rga.blind)
}

pub(crate) fn derived_open_gz_last(cfg: &ModelConfig, zdp: &Committed, sign: &Committed, y: &Committed) -> (Vec<Fr>, Fr) {
    let two_q = Fr::from_u128(1u128 << (cfg.q_bits - 1));
    let vals = zdp
        .values
        .iter()
        .zip(sign.values.iter())
        .zip(y.values.iter())
        .map(|((z, s), yv)| *z - two_q * *s - *yv)
        .collect();
    (vals, zdp.blind - two_q * sign.blind - y.blind)
}

/// A batched opening task: claims ⟨Vᵢ, evec⟩ = vᵢ against commitments Cᵢ,
/// all sharing one public vector; proven with one RLC'd IPA.
struct OpeningTask {
    evec: Vec<Fr>,
    claims: Vec<EvalClaim>,
}

/// Verifier-side mirror: (symbolic com, claimed value) pairs + the public
/// vector. Commitments stay deferred expressions over transcript-bound
/// proof points so the whole check lands in the MSM accumulator.
struct OpeningCheck {
    evec: Vec<Fr>,
    claims: Vec<(ComExpr, Fr)>,
}

/// e(p) repeated in every slot block: ⟨V, tiled⟩ = ⟨V_slot, e(p)⟩ when V is
/// zero outside one block. This is how per-layer claims open against
/// commitments living in different blocks of the stacked basis.
pub(crate) fn tiled_eq(p: &[Fr], lbar: usize) -> Vec<Fr> {
    let e = eq_table(p);
    let mut out = Vec::with_capacity(lbar * e.len());
    for _ in 0..lbar {
        out.extend_from_slice(&e);
    }
    out
}

/// Layer groups for a mode.
fn layer_groups(mode: ProofMode, depth: usize) -> Vec<Vec<usize>> {
    match mode {
        ProofMode::Parallel => vec![(0..depth).collect()],
        ProofMode::Sequential => (0..depth).map(|l| vec![l]).collect(),
    }
}

/// Validity bases for a group: main instance ties the sign column to the
/// group's aux blocks.
fn group_validity_bases(
    pk: &ProverKey,
    layers: &[usize],
) -> (std::sync::Arc<ValidityBases>, std::sync::Arc<ValidityBases>) {
    let cfg = &pk.cfg;
    let d = cfg.d_size();
    let lbar = layers.len().next_power_of_two();
    let n = lbar * d;
    // group-local aux basis: blocks of the group's layers, zero-padded with
    // deterministic extra generators for padding slots
    let mut g = Vec::with_capacity(n);
    for (slot, &l) in layers.iter().enumerate() {
        let _ = slot;
        g.extend_from_slice(&pk.g_aux.g[l * d..(l + 1) * d]);
    }
    if g.len() < n {
        let extra = crate::curve::derive_generators(b"zkdl/aux-pad", n - g.len());
        g.extend(extra);
    }
    let ck = CommitKey::from_parts(g, pk.g_aux.h, pk.g_aux.label.clone());
    // label must pin the exact block layout: first layer AND group length
    // (a depth-3 and a depth-4 parallel group share lbar=4 but differ in
    // which slots are real blocks vs padding)
    let tag = layers.first().copied().unwrap_or(0) as u64;
    let cnt = layers.len() as u64;
    let main_label = [
        b"zkdl/validity/main/".as_ref(),
        &tag.to_le_bytes(),
        &cnt.to_le_bytes(),
    ]
    .concat();
    let rem_label = [
        b"zkdl/validity/rem/".as_ref(),
        &tag.to_le_bytes(),
        &cnt.to_le_bytes(),
    ]
    .concat();
    let q = cfg.q_bits as usize;
    let r = cfg.r_bits as usize;
    let vb_main = ValidityBases::setup_main(&main_label, &ck, n, q);
    let vb_rem = ValidityBases::setup_plain(&rem_label, pk.g_aux.h, n, r);
    (vb_main, vb_rem)
}

/// Prove one training step.
pub fn prove_step(
    pk: &ProverKey,
    wit: &StepWitness,
    mode: ProofMode,
    rng: &mut Rng,
) -> StepProof {
    crate::span!("zkdl/prove_step");
    let _lat = crate::telemetry::hist::timer(crate::telemetry::hist::Hist::ProveStepNs);
    let cfg = &pk.cfg;
    assert_eq!(*cfg, wit.cfg, "config mismatch");
    let depth = cfg.depth;
    let d = cfg.d_size();
    let log_b = cfg.batch.trailing_zeros() as usize;
    let log_d = cfg.width.trailing_zeros() as usize;
    let log_dd = log_b + log_d;

    let pl = ProverLayers::build(wit);
    let sc = commit_step(pk, &pl, rng);

    let mut t = Transcript::new(b"zkdl/step");
    t.absorb_u64(b"depth", depth as u64);
    t.absorb_u64(b"width", cfg.width as u64);
    t.absorb_u64(b"batch", cfg.batch as u64);
    t.absorb_u64(b"mode", mode as u64);
    let affine = |cs: &[Committed]| -> Vec<G1Affine> {
        G1::batch_to_affine(&cs.iter().map(|c| c.com).collect::<Vec<_>>())
    };
    let com_w = affine(&sc.w);
    let com_gw = affine(&sc.gw);
    let com_zdp = affine(&sc.zdp);
    let com_sign = affine(&sc.sign);
    let com_rz = affine(&sc.rz);
    let com_gap = affine(&sc.gap);
    let com_rga = affine(&sc.rga);
    let com_x = sc.x.com.to_affine();
    let com_y = sc.y.com.to_affine();
    absorb_commitments(
        &mut t,
        &[
            (b"com/w", com_w.clone()),
            (b"com/gw", com_gw.clone()),
            (b"com/zdp", com_zdp.clone()),
            (b"com/sign", com_sign.clone()),
            (b"com/rz", com_rz.clone()),
            (b"com/gap", com_gap.clone()),
            (b"com/rga", com_rga.clone()),
            (b"com/x", vec![com_x]),
            (b"com/y", vec![com_y]),
        ],
    );

    let groups = layer_groups(mode, depth);

    // ---- Protocol 1 per group (bit commitments precede all randomness) ----
    struct GroupState {
        layers: Vec<usize>,
        lbar: usize,
        vb_main: std::sync::Arc<ValidityBases>,
        vb_rem: std::sync::Arc<ValidityBases>,
        p1_main: Protocol1Msg,
        p1_rem: Protocol1Msg,
        aux_main: zkrelu::ProverAux,
        aux_rem: zkrelu::ProverAux,
        sign_stack: Vec<Fr>,
        zdp_stack: Vec<Fr>,
        gap_stack: Vec<Fr>,
        rz_stack: Vec<Fr>,
        rga_stack: Vec<Fr>,
        sign_blind: Fr,
    }
    let mut gstates: Vec<GroupState> = Vec::new();
    for layers in &groups {
        let lbar = layers.len().next_power_of_two();
        let n = lbar * d;
        let (vb_main, vb_rem) = group_validity_bases(pk, layers);
        let zdp_stack = pl.stacked(&pl.zdp, layers, lbar, d);
        let gap_stack = pl.stacked(&pl.gap, layers, lbar, d);
        let sign_stack = pl.stacked(&pl.sign, layers, lbar, d);
        let rz_stack = pl.stacked(&pl.rz, layers, lbar, d);
        let rga_stack = pl.stacked(&pl.rga, layers, lbar, d);
        let sign_blind: Fr = layers.iter().map(|&l| sc.sign[l].blind).sum();
        let paired: Vec<Fr> = zdp_stack.iter().chain(gap_stack.iter()).copied().collect();
        let (p1_main, aux_main) =
            zkrelu::protocol1_main(&vb_main, &paired, &sign_stack, sign_blind, rng);
        let paired_rem: Vec<Fr> = rz_stack.iter().chain(rga_stack.iter()).copied().collect();
        let (p1_rem, aux_rem) = zkrelu::protocol1_plain(&vb_rem, &paired_rem, rng);
        t.absorb_point(b"p1/main", &p1_main.com_b_ip);
        if let Some(p) = &p1_main.com_sign_prime {
            t.absorb_point(b"p1/main/sign", p);
        }
        t.absorb_point(b"p1/rem", &p1_rem.com_b_ip);
        let _ = n;
        gstates.push(GroupState {
            layers: layers.clone(),
            lbar,
            vb_main,
            vb_rem,
            p1_main,
            p1_rem,
            aux_main,
            aux_rem,
            sign_stack,
            zdp_stack,
            gap_stack,
            rz_stack,
            rga_stack,
            sign_blind,
        });
    }

    // ---- Phase 1: batched matmul sumchecks per group ----
    // Per-layer claim registry for the stacking phase: claims on A^ℓ and
    // G_Z^ℓ with the points they were made at.
    #[derive(Clone, Default)]
    struct TensorClaims {
        a1: Option<(Vec<Fr>, Fr)>,
        a2: Option<(Vec<Fr>, Fr)>,
        gz1: Option<(Vec<Fr>, Fr)>,
        gz2: Option<(Vec<Fr>, Fr)>,
    }
    let mut claims: Vec<TensorClaims> = vec![TensorClaims::default(); depth];

    struct Phase1Out {
        ch: GroupChallenges,
        v_z: Vec<Fr>,
        v_ga: Vec<Fr>,
        v_gw: Vec<Fr>,
        mm30: SumcheckProof,
        mm30_evals: Vec<(Fr, Fr)>,
        mm33: Option<SumcheckProof>,
        mm33_evals: Vec<(Fr, Fr)>,
        mm34: SumcheckProof,
        mm34_evals: Vec<(Fr, Fr)>,
        r30: Vec<Fr>,
        r33: Vec<Fr>,
        r34: Vec<Fr>,
    }
    let mut phase1: Vec<Phase1Out> = Vec::new();

    // eq-table scratch shared across all groups and all three sumcheck
    // families (see aggregate::eval_i64_with_eq for the same shape)
    let mut arena = FrArena::new();

    for gs in &gstates {
        let ch = draw_group_challenges(&mut t, log_b, log_d);
        // (30): claimed Z̃^ℓ(u_zr,u_zc), factors A^{ℓ−1}(u_zr,·), W^{ℓᵀ}(u_zc,·)
        let pz: Vec<Fr> = [ch.u_zr.clone(), ch.u_zc.clone()].concat();
        let mut v_z = Vec::new();
        let mut terms30 = Vec::new();
        let mut coeff = Fr::ONE;
        arena.scratch(1 << pz.len(), |eq_pz| {
            poly::eq_table_into(&pz, eq_pz);
            for &l in &gs.layers {
                v_z.push(poly::eval_i64_with_eq(&wit.layers[l].z, eq_pz));
                let a_prev = if l == 0 { &pl.x } else { &pl.a[l - 1] };
                terms30.push(Term::new(
                    coeff,
                    vec![a_prev.fix_rows(&ch.u_zr), pl.w[l].transpose().fix_rows(&ch.u_zc)],
                ));
                coeff *= ch.gamma;
            }
        });
        t.absorb_frs(b"v_z", &v_z);
        let out30 = sumcheck::prove(Instance::new(terms30), &mut t);
        let mm30_evals: Vec<(Fr, Fr)> =
            out30.factor_evals.iter().map(|f| (f[0], f[1])).collect();
        for (e, _) in mm30_evals.iter().zip(gs.layers.iter()) {
            let _ = e;
        }
        t.absorb_frs(
            b"mm30/evals",
            &mm30_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
        );
        let r30 = out30.point.clone();

        // register A^{ℓ−1} claims (ℓ≥1) at (u_zr, r30)
        let p_a1: Vec<Fr> = [ch.u_zr.clone(), r30.clone()].concat();
        for (i, &l) in gs.layers.iter().enumerate() {
            if l >= 1 {
                claims[l - 1].a1 = Some((p_a1.clone(), mm30_evals[i].0));
            }
        }

        // (33): inner layers ℓ ≤ L−2: G̃_A^ℓ(u_gar,u_gac),
        // factors G_Z^{ℓ+1}(u_gar,·), W^{ℓ+1}(u_gac,·)
        let pga: Vec<Fr> = [ch.u_gar.clone(), ch.u_gac.clone()].concat();
        let inner: Vec<usize> = gs.layers.iter().copied().filter(|&l| l + 1 < depth).collect();
        let mut v_ga = Vec::new();
        let mut mm33 = None;
        let mut mm33_evals = Vec::new();
        let mut r33 = Vec::new();
        if !inner.is_empty() {
            let mut terms33 = Vec::new();
            let mut coeff = Fr::ONE;
            arena.scratch(1 << pga.len(), |eq_pga| {
                poly::eq_table_into(&pga, eq_pga);
                for &l in &inner {
                    v_ga.push(poly::eval_i64_with_eq(
                        wit.layers[l].g_a.as_ref().unwrap(),
                        eq_pga,
                    ));
                    terms33.push(Term::new(
                        coeff,
                        vec![
                            pl.g_z[l + 1].fix_rows(&ch.u_gar),
                            pl.w[l + 1].fix_rows(&ch.u_gac),
                        ],
                    ));
                    coeff *= ch.gamma;
                }
            });
            t.absorb_frs(b"v_ga", &v_ga);
            let out33 = sumcheck::prove(Instance::new(terms33), &mut t);
            mm33_evals = out33.factor_evals.iter().map(|f| (f[0], f[1])).collect();
            t.absorb_frs(
                b"mm33/evals",
                &mm33_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
            );
            r33 = out33.point.clone();
            mm33 = Some(out33.proof);
            // register G_Z^{ℓ+1} claims at (u_gar, r33)
            let q1: Vec<Fr> = [ch.u_gar.clone(), r33.clone()].concat();
            for (i, &l) in inner.iter().enumerate() {
                claims[l + 1].gz1 = Some((q1.clone(), mm33_evals[i].0));
            }
        }

        // (34): G̃_W^ℓ(u_gwr,u_gwc), factors G_Z^{ℓᵀ}(u_gwr,·), A^{ℓ−1ᵀ}(u_gwc,·)
        let pgw: Vec<Fr> = [ch.u_gwr.clone(), ch.u_gwc.clone()].concat();
        let mut v_gw = Vec::new();
        let mut terms34 = Vec::new();
        let mut coeff = Fr::ONE;
        arena.scratch(1 << pgw.len(), |eq_pgw| {
            poly::eq_table_into(&pgw, eq_pgw);
            for &l in &gs.layers {
                v_gw.push(poly::eval_i64_with_eq(&wit.layers[l].g_w, eq_pgw));
                let a_prev = if l == 0 { &pl.x } else { &pl.a[l - 1] };
                terms34.push(Term::new(
                    coeff,
                    vec![
                        pl.g_z[l].transpose().fix_rows(&ch.u_gwr),
                        a_prev.transpose().fix_rows(&ch.u_gwc),
                    ],
                ));
                coeff *= ch.gamma;
            }
        });
        t.absorb_frs(b"v_gw", &v_gw);
        let out34 = sumcheck::prove(Instance::new(terms34), &mut t);
        let mm34_evals: Vec<(Fr, Fr)> =
            out34.factor_evals.iter().map(|f| (f[0], f[1])).collect();
        t.absorb_frs(
            b"mm34/evals",
            &mm34_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
        );
        let r34 = out34.point.clone();
        // register claims: G_Z^ℓ at (r34, u_gwr); A^{ℓ−1} (ℓ≥1) at (r34, u_gwc)
        let q2: Vec<Fr> = [r34.clone(), ch.u_gwr.clone()].concat();
        let p_a2: Vec<Fr> = [r34.clone(), ch.u_gwc.clone()].concat();
        for (i, &l) in gs.layers.iter().enumerate() {
            claims[l].gz2 = Some((q2.clone(), mm34_evals[i].0));
            if l >= 1 {
                claims[l - 1].a2 = Some((p_a2.clone(), mm34_evals[i].1));
            }
        }

        phase1.push(Phase1Out {
            ch,
            v_z,
            v_ga,
            v_gw,
            mm30: out30.proof,
            mm30_evals,
            mm33,
            mm33_evals,
            mm34: out34.proof,
            mm34_evals,
            r30,
            r33,
            r34,
        });
    }

    // ---- Phase 2: stacking sumcheck (27) per group + Phase 3 openings +
    //      Phase 4 validity ----
    let mut group_proofs = Vec::new();
    for (gi, gs) in gstates.iter().enumerate() {
        let p1 = &phase1[gi];
        let lbar = gs.lbar;
        let log_lbar = lbar.trailing_zeros() as usize;
        let n = lbar * d;

        // Stacking terms: for each of the four claim kinds, the claims of
        // the group's layers must share a single point (true by
        // construction: parallel mode uses shared challenges; sequential
        // groups have one layer).
        // Build full slot-claim vectors (virtual slots included).
        let one_minus_sign: Vec<Fr> =
            gs.sign_stack.iter().map(|s| Fr::ONE - *s).collect();
        let zdp_mle = Mle::new(gs.zdp_stack.clone());
        let gap_mle = Mle::new(gs.gap_stack.clone());
        let oms_mle = Mle::new(one_minus_sign);

        // helper: the point of the first present claim of a kind. Only
        // inner layers (ℓ < L−1) flow through the stack; the last layer's
        // G_Z claims are opened against the derived commitment instead.
        let find_point = |get: &dyn Fn(&TensorClaims) -> Option<(Vec<Fr>, Fr)>| -> Option<Vec<Fr>> {
            gs.layers
                .iter()
                .filter(|&&l| l < depth - 1)
                .filter_map(|&l| get(&claims[l]).map(|(p, _)| p))
                .next()
        };
        let pa1 = find_point(&|c| c.a1.clone());
        let pa2 = find_point(&|c| c.a2.clone());
        let qz1 = find_point(&|c| c.gz1.clone());
        let qz2 = find_point(&|c| c.gz2.clone());

        // Prover-supplied slot claim vectors (length lbar).
        let slot_claims = |point: &Option<Vec<Fr>>, tensor: &dyn Fn(usize) -> Vec<Fr>| -> Vec<Fr> {
            match point {
                None => vec![Fr::ZERO; lbar],
                Some(p) => {
                    let e = eq_table(p);
                    (0..lbar)
                        .map(|slot| {
                            if slot < gs.layers.len() {
                                let tv = tensor(gs.layers[slot]);
                                tv.iter().zip(e.iter()).map(|(a, b)| *a * *b).sum()
                            } else {
                                Fr::ZERO
                            }
                        })
                        .collect()
                }
            }
        };
        let a_tensor = |l: usize| pl.a[l].data.clone();
        let gz_virtual = |l: usize| -> Vec<Fr> {
            pl.gap[l]
                .iter()
                .zip(pl.sign[l].iter())
                .map(|(g, s)| (Fr::ONE - *s) * *g)
                .collect()
        };
        let va1 = slot_claims(&pa1, &a_tensor);
        let va2 = slot_claims(&pa2, &a_tensor);
        let vgz1 = slot_claims(&qz1, &gz_virtual);
        let vgz2 = slot_claims(&qz2, &gz_virtual);
        t.absorb_frs(b"stack/va1", &va1);
        t.absorb_frs(b"stack/va2", &va2);
        t.absorb_frs(b"stack/vgz1", &vgz1);
        t.absorb_frs(b"stack/vgz2", &vgz2);

        let any_term = pa1.is_some() || pa2.is_some() || qz1.is_some() || qz2.is_some();
        let u_stack = t.challenge_frs(b"stack/u", log_lbar);
        let gammas = t.challenge_frs(b"stack/gamma", 4);
        let e_stack = eq_table(&u_stack);

        let (stack_proof, rho) = if any_term {
            let mut terms = Vec::new();
            let mut add_term = |coeff: Fr, point: &Option<Vec<Fr>>, tensor: &Mle| {
                if let Some(p) = point {
                    let full_point: Vec<Fr> = [u_stack.clone(), p.clone()].concat();
                    terms.push(Term::new(
                        coeff,
                        vec![Mle::new(eq_table(&full_point)), oms_mle.clone(), tensor.clone()],
                    ));
                }
            };
            add_term(gammas[0], &pa1, &zdp_mle);
            add_term(gammas[1], &pa2, &zdp_mle);
            add_term(gammas[2], &qz1, &gap_mle);
            add_term(gammas[3], &qz2, &gap_mle);
            let out = sumcheck::prove(Instance::new(terms), &mut t);
            (Some(out.proof), out.point)
        } else {
            (None, t.challenge_frs(b"stack/rho", log_lbar + log_dd))
        };
        let _ = e_stack;

        // opened stacked-aux evaluations at ρ
        let sign_mle = Mle::new(gs.sign_stack.clone());
        let v_sign = sign_mle.evaluate(&rho);
        let v_zdp = zdp_mle.evaluate(&rho);
        let v_gap = gap_mle.evaluate(&rho);
        let v_rz = Mle::new(gs.rz_stack.clone()).evaluate(&rho);
        let v_rga = Mle::new(gs.rga_stack.clone()).evaluate(&rho);
        let aux_evals = [v_sign, v_zdp, v_gap, v_rz, v_rga];
        t.absorb_frs(b"aux/evals", &aux_evals);

        // ---- Phase 3: batched openings ----
        // group-local commitment key (blocks of this group's layers)
        let mut gk_g = Vec::with_capacity(n);
        for &l in &gs.layers {
            gk_g.extend_from_slice(&pk.g_aux.g[l * d..(l + 1) * d]);
        }
        if gk_g.len() < n {
            gk_g.extend(crate::curve::derive_generators(b"zkdl/aux-pad", n - gk_g.len()));
        }
        let gk = CommitKey::from_parts(gk_g, pk.g_aux.h, pk.g_aux.label.clone());

        let mut tasks: Vec<(CommitKey, OpeningTask)> = Vec::new();

        // OG-A: stacked aux at ρ (5 claims, basis = group aux key)
        {
            let stack_com = |cs: &[Committed]| -> (G1, Fr, Vec<Fr>) {
                let com: G1 = gs.layers.iter().map(|&l| cs[l].com).sum();
                let blind: Fr = gs.layers.iter().map(|&l| cs[l].blind).sum();
                let vals = pl.stacked(
                    &cs.iter().map(|c| c.values.clone()).collect::<Vec<_>>(),
                    &gs.layers,
                    lbar,
                    d,
                );
                (com, blind, vals)
            };
            let mk_claim = |cs: &[Committed], v: Fr| -> EvalClaim {
                let (com, blind, values) = stack_com(cs);
                EvalClaim {
                    com,
                    values,
                    blind,
                    v,
                }
            };
            tasks.push((
                gk.clone(),
                OpeningTask {
                    evec: eq_table(&rho),
                    claims: vec![
                        mk_claim(&sc.sign, v_sign),
                        mk_claim(&sc.zdp, v_zdp),
                        mk_claim(&sc.gap, v_gap),
                        mk_claim(&sc.rz, v_rz),
                        mk_claim(&sc.rga, v_rga),
                    ],
                },
            ));
        }

        // OG-Z: derived Z commitments at pz (tiled-RLC over the group)
        {
            let pz: Vec<Fr> = [p1.ch.u_zr.clone(), p1.ch.u_zc.clone()].concat();
            let claims_z: Vec<EvalClaim> = gs
                .layers
                .iter()
                .zip(p1.v_z.iter())
                .map(|(&l, &v)| {
                    let (values, blind) = derived_open_z(cfg, &sc.zdp[l], &sc.sign[l], &sc.rz[l]);
                    let com = derived_com_z(cfg, &sc.zdp[l].com, &sc.sign[l].com, &sc.rz[l].com);
                    EvalClaim {
                        com,
                        values,
                        blind,
                        v,
                    }
                })
                .collect();
            // per-layer commitments live in different blocks → tile the point
            tasks.push((
                gk.clone(),
                OpeningTask {
                    evec: tiled_eq(&pz, lbar),
                    claims: tile_claims(claims_z, lbar, d),
                },
            ));
        }

        // OG-GA: derived G_A commitments at pga (inner layers)
        {
            let inner: Vec<usize> =
                gs.layers.iter().copied().filter(|&l| l + 1 < depth).collect();
            if !inner.is_empty() {
                let pga: Vec<Fr> = [p1.ch.u_gar.clone(), p1.ch.u_gac.clone()].concat();
                let claims_ga: Vec<EvalClaim> = inner
                    .iter()
                    .zip(p1.v_ga.iter())
                    .map(|(&l, &v)| {
                        let (values, blind) = derived_open_ga(cfg, &sc.gap[l], &sc.rga[l]);
                        let com = derived_com_ga(cfg, &sc.gap[l].com, &sc.rga[l].com);
                        EvalClaim {
                            com,
                            values,
                            blind,
                            v,
                        }
                    })
                    .collect();
                let slots: Vec<usize> = inner
                    .iter()
                    .map(|l| gs.layers.iter().position(|x| x == l).unwrap())
                    .collect();
                tasks.push((
                    gk.clone(),
                    OpeningTask {
                        evec: tiled_eq(&pga, lbar),
                        claims: tile_claims_at(claims_ga, &slots, lbar, d),
                    },
                ));
            }
        }

        // OG-GW: com_gw at pgw (same basis — plain RLC batch)
        {
            let pgw: Vec<Fr> = [p1.ch.u_gwr.clone(), p1.ch.u_gwc.clone()].concat();
            let claims_gw: Vec<EvalClaim> = gs
                .layers
                .iter()
                .zip(p1.v_gw.iter())
                .map(|(&l, &v)| EvalClaim {
                    com: sc.gw[l].com,
                    values: sc.gw[l].values.clone(),
                    blind: sc.gw[l].blind,
                    v,
                })
                .collect();
            tasks.push((
                pk.g_mat.clone(),
                OpeningTask {
                    evec: eq_table(&pgw),
                    claims: claims_gw,
                },
            ));
        }

        // OG-W30: com_w at (r30, u_zc)
        {
            let p: Vec<Fr> = [p1.r30.clone(), p1.ch.u_zc.clone()].concat();
            let claims_w: Vec<EvalClaim> = gs
                .layers
                .iter()
                .enumerate()
                .map(|(i, &l)| EvalClaim {
                    com: sc.w[l].com,
                    values: sc.w[l].values.clone(),
                    blind: sc.w[l].blind,
                    v: p1.mm30_evals[i].1,
                })
                .collect();
            tasks.push((
                pk.g_mat.clone(),
                OpeningTask {
                    evec: eq_table(&p),
                    claims: claims_w,
                },
            ));
        }

        // OG-W33: com_w^{ℓ+1} at (u_gac, r33)
        {
            let inner: Vec<usize> =
                gs.layers.iter().copied().filter(|&l| l + 1 < depth).collect();
            if !inner.is_empty() {
                let p: Vec<Fr> = [p1.ch.u_gac.clone(), p1.r33.clone()].concat();
                let claims_w: Vec<EvalClaim> = inner
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| EvalClaim {
                        com: sc.w[l + 1].com,
                        values: sc.w[l + 1].values.clone(),
                        blind: sc.w[l + 1].blind,
                        v: p1.mm33_evals[i].1,
                    })
                    .collect();
                tasks.push((
                    pk.g_mat.clone(),
                    OpeningTask {
                        evec: eq_table(&p),
                        claims: claims_w,
                    },
                ));
            }
        }

        // OG-X: com_x claims from layer 0's (30) and (34)
        if gs.layers.contains(&0) {
            let i0 = gs.layers.iter().position(|&l| l == 0).unwrap();
            let p30: Vec<Fr> = [p1.ch.u_zr.clone(), p1.r30.clone()].concat();
            tasks.push((
                pk.g_x.clone(),
                OpeningTask {
                    evec: eq_table(&p30),
                    claims: vec![EvalClaim {
                        com: sc.x.com,
                        values: sc.x.values.clone(),
                        blind: sc.x.blind,
                        v: p1.mm30_evals[i0].0,
                    }],
                },
            ));
            let p34: Vec<Fr> = [p1.r34.clone(), p1.ch.u_gwc.clone()].concat();
            tasks.push((
                pk.g_x.clone(),
                OpeningTask {
                    evec: eq_table(&p34),
                    claims: vec![EvalClaim {
                        com: sc.x.com,
                        values: sc.x.values.clone(),
                        blind: sc.x.blind,
                        v: p1.mm34_evals[i0].1,
                    }],
                },
            ));
        }

        // OG-GZlast: derived G_Z^{L−1} claims (from mm34 of layer L−1, and
        // from mm33 whose inner layer is L−2)
        {
            let last = depth - 1;
            let last_ck = pk.block(last);
            let (gz_vals, gz_blind) =
                derived_open_gz_last(cfg, &sc.zdp[last], &sc.sign[last], &sc.y);
            let gz_com =
                derived_com_gz_last(cfg, &sc.zdp[last].com, &sc.sign[last].com, &sc.y.com);
            if let Some(i) = gs.layers.iter().position(|&l| l == last) {
                let p: Vec<Fr> = [p1.r34.clone(), p1.ch.u_gwr.clone()].concat();
                tasks.push((
                    last_ck.clone(),
                    OpeningTask {
                        evec: eq_table(&p),
                        claims: vec![EvalClaim {
                            com: gz_com,
                            values: gz_vals.clone(),
                            blind: gz_blind,
                            v: p1.mm34_evals[i].0,
                        }],
                    },
                ));
            }
            let inner: Vec<usize> =
                gs.layers.iter().copied().filter(|&l| l + 1 < depth).collect();
            if let Some(j) = inner.iter().position(|&l| l + 1 == last) {
                let p: Vec<Fr> = [p1.ch.u_gar.clone(), p1.r33.clone()].concat();
                tasks.push((
                    last_ck,
                    OpeningTask {
                        evec: eq_table(&p),
                        claims: vec![EvalClaim {
                            com: gz_com,
                            values: gz_vals,
                            blind: gz_blind,
                            v: p1.mm33_evals[j].0,
                        }],
                    },
                ));
            }
        }

        let mut openings = Vec::new();
        for (ck, task) in &tasks {
            // values-only absorption: every constituent commitment point is
            // already transcript-bound, so the verifier can keep the claims
            // symbolic (batch_verify_eval_expr) and defer all group work
            openings.push(ipa::batch_prove_eval_expr(
                ck,
                &task.claims,
                &task.evec,
                &mut t,
                rng,
            ));
        }

        // ---- Phase 4: validity ----
        let u_dd = t.challenge_fr(b"zkdl/u_dd");
        let mut vpoint = vec![u_dd];
        vpoint.extend_from_slice(&rho);
        let e_row = eq_table(&vpoint);
        let v = (Fr::ONE - u_dd) * v_zdp + u_dd * v_gap;
        let validity_main = zkrelu::prove_validity(
            &gs.vb_main,
            &gs.aux_main,
            &e_row,
            u_dd,
            v,
            v_sign,
            &mut t,
            rng,
        );
        let u_dd_r = t.challenge_fr(b"zkdl/u_dd_rem");
        let mut vpoint_r = vec![u_dd_r];
        vpoint_r.extend_from_slice(&rho);
        let e_row_r = eq_table(&vpoint_r);
        let v_rem = (Fr::ONE - u_dd_r) * v_rz + u_dd_r * v_rga;
        let validity_rem = zkrelu::prove_validity(
            &gs.vb_rem,
            &gs.aux_rem,
            &e_row_r,
            u_dd_r,
            v_rem,
            Fr::ZERO,
            &mut t,
            rng,
        );

        group_proofs.push(GroupProof {
            p1_main: gs.p1_main.clone(),
            p1_rem: gs.p1_rem.clone(),
            v_z: p1.v_z.clone(),
            v_ga: p1.v_ga.clone(),
            v_gw: p1.v_gw.clone(),
            mm30: p1.mm30.clone(),
            mm30_evals: p1.mm30_evals.clone(),
            mm33: p1.mm33.clone(),
            mm33_evals: p1.mm33_evals.clone(),
            mm34: p1.mm34.clone(),
            mm34_evals: p1.mm34_evals.clone(),
            stack: stack_proof,
            va1,
            va2,
            vgz1,
            vgz2,
            aux_evals,
            openings,
            validity_main,
            validity_rem,
        });
        let _ = gs.sign_blind;
    }

    StepProof {
        mode,
        com_w,
        com_gw,
        com_zdp,
        com_sign,
        com_rz,
        com_gap,
        com_rga,
        com_x,
        com_y,
        groups: group_proofs,
    }
}

/// Lay per-layer claims out over the stacked basis: claim i's value vector
/// occupies slot i's block; the opening point is (0…0, point) so the tiled
/// eq-table weights exactly one block per claim.
fn tile_claims(claims: Vec<EvalClaim>, lbar: usize, d: usize) -> Vec<EvalClaim> {
    let slots: Vec<usize> = (0..claims.len()).collect();
    tile_claims_at(claims, &slots, lbar, d)
}

pub(crate) fn tile_claims_at(claims: Vec<EvalClaim>, slots: &[usize], lbar: usize, d: usize) -> Vec<EvalClaim> {
    claims
        .into_iter()
        .zip(slots.iter())
        .map(|(c, &slot)| {
            let mut values = vec![Fr::ZERO; lbar * d];
            values[slot * d..slot * d + d].copy_from_slice(&c.values);
            // The commitment lives in the slot's block of the stacked
            // basis; pairing the block-embedded vector with the *tiled*
            // public vector (e(p) in every block) leaves the inner product
            // ⟨V, e_tiled⟩ = ⟨values, e(p)⟩ unchanged.
            EvalClaim {
                com: c.com,
                values,
                blind: c.blind,
                v: c.v,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Verifier
// ---------------------------------------------------------------------------

/// Verify a [`StepProof`]. `pk` provides the public bases (no secrets).
/// Thin wrapper over [`verify_step_accum`]: allocates one accumulator and
/// flushes it once — exactly one Pippenger MSM for the whole proof.
pub fn verify_step(pk: &ProverKey, proof: &StepProof) -> Result<()> {
    let mut acc = MsmAccumulator::new();
    verify_step_accum(pk, proof, &mut acc)?;
    crate::ensure_class!(
        acc.flush(),
        crate::telemetry::failure::VerifyFailureClass::MsmFinalCheck,
        "step proof: deferred MSM check failed"
    );
    Ok(())
}

/// Verify a batch of step proofs with ONE MSM total: each proof's deferred
/// terms are scaled by an independent verifier-chosen random ρᵢ before
/// merging into the shared accumulator, so equations of different proofs
/// cannot cancel each other (standard batch-verification argument).
pub fn verify_steps_batch(pk: &ProverKey, proofs: &[StepProof], rng: &mut Rng) -> Result<()> {
    ensure!(!proofs.is_empty(), "empty proof batch");
    let mut acc = MsmAccumulator::from_rng(rng);
    for (i, proof) in proofs.iter().enumerate() {
        acc.set_scale(Fr::random_nonzero(rng));
        verify_step_accum(pk, proof, &mut acc)
            .with_context(|| format!("batched proof {i}"))?;
    }
    crate::ensure_class!(
        acc.flush(),
        crate::telemetry::failure::VerifyFailureClass::MsmFinalCheck,
        "step proof batch: aggregate MSM check failed"
    );
    Ok(())
}

/// The transcript replay and every scalar-side check of [`verify_step`],
/// with all group equations deferred into `acc`. Performs no curve
/// arithmetic itself — callers decide the proof by flushing the
/// accumulator.
pub fn verify_step_accum(
    pk: &ProverKey,
    proof: &StepProof,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("zkdl/verify_step");
    let _lat = crate::telemetry::hist::timer(crate::telemetry::hist::Hist::VerifyStepNs);
    let cfg = &pk.cfg;
    let depth = cfg.depth;
    let d = cfg.d_size();
    let log_b = cfg.batch.trailing_zeros() as usize;
    let log_d = cfg.width.trailing_zeros() as usize;
    let log_dd = log_b + log_d;

    ensure!(proof.com_w.len() == depth, "wrong commitment count");

    let mut t = Transcript::new(b"zkdl/step");
    t.absorb_u64(b"depth", depth as u64);
    t.absorb_u64(b"width", cfg.width as u64);
    t.absorb_u64(b"batch", cfg.batch as u64);
    t.absorb_u64(b"mode", proof.mode as u64);
    absorb_commitments(
        &mut t,
        &[
            (b"com/w", proof.com_w.clone()),
            (b"com/gw", proof.com_gw.clone()),
            (b"com/zdp", proof.com_zdp.clone()),
            (b"com/sign", proof.com_sign.clone()),
            (b"com/rz", proof.com_rz.clone()),
            (b"com/gap", proof.com_gap.clone()),
            (b"com/rga", proof.com_rga.clone()),
            (b"com/x", vec![proof.com_x]),
            (b"com/y", vec![proof.com_y]),
        ],
    );

    let groups = layer_groups(proof.mode, depth);
    ensure!(proof.groups.len() == groups.len(), "wrong group count");

    // Protocol 1 absorption + validity bases
    let mut vbases = Vec::new();
    for (layers, gp) in groups.iter().zip(proof.groups.iter()) {
        let (vb_main, vb_rem) = group_validity_bases(pk, layers);
        t.absorb_point(b"p1/main", &gp.p1_main.com_b_ip);
        if let Some(p) = &gp.p1_main.com_sign_prime {
            t.absorb_point(b"p1/main/sign", p);
        } else {
            bail!("main validity instance must carry com_sign_prime");
        }
        t.absorb_point(b"p1/rem", &gp.p1_rem.com_b_ip);
        vbases.push((vb_main, vb_rem));
    }

    // Phase 1 verification
    struct VClaims {
        a1: Option<(Vec<Fr>, Fr)>,
        a2: Option<(Vec<Fr>, Fr)>,
        gz1: Option<(Vec<Fr>, Fr)>,
        gz2: Option<(Vec<Fr>, Fr)>,
    }
    let mut claims: Vec<VClaims> = (0..depth)
        .map(|_| VClaims {
            a1: None,
            a2: None,
            gz1: None,
            gz2: None,
        })
        .collect();
    struct VPhase1 {
        ch: GroupChallenges,
        r30: Vec<Fr>,
        r33: Vec<Fr>,
        r34: Vec<Fr>,
    }
    let mut vphase1 = Vec::new();

    for (layers, gp) in groups.iter().zip(proof.groups.iter()) {
        let ch = draw_group_challenges(&mut t, log_b, log_d);
        ensure!(gp.v_z.len() == layers.len(), "v_z length");
        ensure!(gp.mm30_evals.len() == layers.len(), "mm30 evals length");
        t.absorb_frs(b"v_z", &gp.v_z);
        // claimed sum = Σ γ^i v_z[i]
        let mut claimed = Fr::ZERO;
        let mut coeff = Fr::ONE;
        for v in &gp.v_z {
            claimed += coeff * *v;
            coeff *= ch.gamma;
        }
        let out30 = sumcheck::verify(claimed, &gp.mm30, &mut t).context("mm30")?;
        // final claim = Σ γ^i·evalA_i·evalW_i
        let mut expect = Fr::ZERO;
        let mut coeff = Fr::ONE;
        for (ea, ew) in &gp.mm30_evals {
            expect += coeff * *ea * *ew;
            coeff *= ch.gamma;
        }
        ensure!(expect == out30.final_claim, "mm30 factor evals mismatch");
        t.absorb_frs(
            b"mm30/evals",
            &gp.mm30_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
        );
        let r30 = out30.point;
        let p_a1: Vec<Fr> = [ch.u_zr.clone(), r30.clone()].concat();
        for (i, &l) in layers.iter().enumerate() {
            if l >= 1 {
                claims[l - 1].a1 = Some((p_a1.clone(), gp.mm30_evals[i].0));
            }
        }

        let inner: Vec<usize> = layers.iter().copied().filter(|&l| l + 1 < depth).collect();
        let mut r33 = Vec::new();
        if !inner.is_empty() {
            ensure!(gp.v_ga.len() == inner.len(), "v_ga length");
            ensure!(gp.mm33_evals.len() == inner.len(), "mm33 evals length");
            t.absorb_frs(b"v_ga", &gp.v_ga);
            let mut claimed = Fr::ZERO;
            let mut coeff = Fr::ONE;
            for v in &gp.v_ga {
                claimed += coeff * *v;
                coeff *= ch.gamma;
            }
            let sc33 = gp.mm33.as_ref().context("missing mm33")?;
            let out33 = sumcheck::verify(claimed, sc33, &mut t).context("mm33")?;
            let mut expect = Fr::ZERO;
            let mut coeff = Fr::ONE;
            for (ea, ew) in &gp.mm33_evals {
                expect += coeff * *ea * *ew;
                coeff *= ch.gamma;
            }
            ensure!(expect == out33.final_claim, "mm33 factor evals mismatch");
            t.absorb_frs(
                b"mm33/evals",
                &gp.mm33_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
            );
            r33 = out33.point;
            let q1: Vec<Fr> = [ch.u_gar.clone(), r33.clone()].concat();
            for (i, &l) in inner.iter().enumerate() {
                claims[l + 1].gz1 = Some((q1.clone(), gp.mm33_evals[i].0));
            }
        } else {
            ensure!(gp.mm33.is_none(), "unexpected mm33");
        }

        ensure!(gp.v_gw.len() == layers.len(), "v_gw length");
        t.absorb_frs(b"v_gw", &gp.v_gw);
        let mut claimed = Fr::ZERO;
        let mut coeff = Fr::ONE;
        for v in &gp.v_gw {
            claimed += coeff * *v;
            coeff *= ch.gamma;
        }
        let out34 = sumcheck::verify(claimed, &gp.mm34, &mut t).context("mm34")?;
        let mut expect = Fr::ZERO;
        let mut coeff = Fr::ONE;
        for (ea, eb) in &gp.mm34_evals {
            expect += coeff * *ea * *eb;
            coeff *= ch.gamma;
        }
        ensure!(expect == out34.final_claim, "mm34 factor evals mismatch");
        t.absorb_frs(
            b"mm34/evals",
            &gp.mm34_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
        );
        let r34 = out34.point;
        let q2: Vec<Fr> = [r34.clone(), ch.u_gwr.clone()].concat();
        let p_a2: Vec<Fr> = [r34.clone(), ch.u_gwc.clone()].concat();
        for (i, &l) in layers.iter().enumerate() {
            claims[l].gz2 = Some((q2.clone(), gp.mm34_evals[i].0));
            if l >= 1 {
                claims[l - 1].a2 = Some((p_a2.clone(), gp.mm34_evals[i].1));
            }
        }
        vphase1.push(VPhase1 { ch, r30, r33, r34 });
    }

    // Phases 2–4 per group
    for (gi, (layers, gp)) in groups.iter().zip(proof.groups.iter()).enumerate() {
        let p1 = &vphase1[gi];
        let lbar = layers.len().next_power_of_two();
        let log_lbar = lbar.trailing_zeros() as usize;

        ensure!(gp.va1.len() == lbar && gp.va2.len() == lbar, "slot claims");
        ensure!(gp.vgz1.len() == lbar && gp.vgz2.len() == lbar, "slot claims");
        // slot claims covered by matmul factor evals must match
        for (slot, &l) in layers.iter().enumerate() {
            if let Some((_, v)) = &claims[l].a1 {
                if l < depth - 1 {
                    ensure!(gp.va1[slot] == *v, "va1 slot {slot} mismatch");
                }
            }
            if let Some((_, v)) = &claims[l].a2 {
                if l < depth - 1 {
                    ensure!(gp.va2[slot] == *v, "va2 slot {slot} mismatch");
                }
            }
            if let Some((_, v)) = &claims[l].gz1 {
                if l < depth - 1 {
                    ensure!(gp.vgz1[slot] == *v, "vgz1 slot {slot} mismatch");
                }
            }
            if let Some((_, v)) = &claims[l].gz2 {
                if l < depth - 1 {
                    ensure!(gp.vgz2[slot] == *v, "vgz2 slot {slot} mismatch");
                }
            }
        }
        for slot in layers.len()..lbar {
            ensure!(
                gp.va1[slot].is_zero()
                    && gp.va2[slot].is_zero()
                    && gp.vgz1[slot].is_zero()
                    && gp.vgz2[slot].is_zero(),
                "padding slot claims must be zero"
            );
        }
        t.absorb_frs(b"stack/va1", &gp.va1);
        t.absorb_frs(b"stack/va2", &gp.va2);
        t.absorb_frs(b"stack/vgz1", &gp.vgz1);
        t.absorb_frs(b"stack/vgz2", &gp.vgz2);

        // reconstruct the four stack points
        let pick = |get: &dyn Fn(&VClaims) -> Option<(Vec<Fr>, Fr)>| -> Option<Vec<Fr>> {
            layers
                .iter()
                .filter(|&&l| l < depth - 1)
                .filter_map(|&l| get(&claims[l]).map(|(p, _)| p))
                .next()
        };
        // A-claims on layer l<depth−1 tensors; note claim registry indexes
        // the *owning* layer
        let pa1 = pick(&|c| c.a1.clone());
        let pa2 = pick(&|c| c.a2.clone());
        let qz1 = pick(&|c| c.gz1.clone());
        let qz2 = pick(&|c| c.gz2.clone());

        let any_term = pa1.is_some() || pa2.is_some() || qz1.is_some() || qz2.is_some();
        let u_stack = t.challenge_frs(b"stack/u", log_lbar);
        let gammas = t.challenge_frs(b"stack/gamma", 4);
        let e_stack = eq_table(&u_stack);

        let rho = if any_term {
            // claimed sum = Σ_t γ_t Σ_s β(u_stack,s)·v_t[s]
            let lhs = |point: &Option<Vec<Fr>>, vs: &[Fr]| -> Fr {
                if point.is_none() {
                    return Fr::ZERO;
                }
                vs.iter().zip(e_stack.iter()).map(|(v, e)| *v * *e).sum()
            };
            let claimed = gammas[0] * lhs(&pa1, &gp.va1)
                + gammas[1] * lhs(&pa2, &gp.va2)
                + gammas[2] * lhs(&qz1, &gp.vgz1)
                + gammas[3] * lhs(&qz2, &gp.vgz2);
            let stack = gp.stack.as_ref().context("missing stack proof")?;
            let out = sumcheck::verify(claimed, stack, &mut t).context("stack")?;
            // final check uses the opened aux evals below
            let [v_sign, v_zdp, v_gap, _, _] = gp.aux_evals;
            let oms = Fr::ONE - v_sign;
            let term = |point: &Option<Vec<Fr>>, tensor_eval: Fr, gamma: Fr| -> Fr {
                match point {
                    None => Fr::ZERO,
                    Some(p) => {
                        let full: Vec<Fr> = [u_stack.clone(), p.clone()].concat();
                        gamma * crate::poly::eq_eval(&full, &out.point) * oms * tensor_eval
                    }
                }
            };
            let expect = term(&pa1, v_zdp, gammas[0])
                + term(&pa2, v_zdp, gammas[1])
                + term(&qz1, v_gap, gammas[2])
                + term(&qz2, v_gap, gammas[3]);
            ensure!(expect == out.final_claim, "stack final claim mismatch");
            out.point
        } else {
            ensure!(gp.stack.is_none(), "unexpected stack proof");
            t.challenge_frs(b"stack/rho", log_lbar + log_dd)
        };
        t.absorb_frs(b"aux/evals", &gp.aux_evals);
        let [v_sign, v_zdp, v_gap, v_rz, v_rga] = gp.aux_evals;

        // ---- Phase 3: opening checks (must mirror prover's task order) ----
        let mut gk_g = Vec::with_capacity(lbar * d);
        for &l in layers {
            gk_g.extend_from_slice(&pk.g_aux.g[l * d..(l + 1) * d]);
        }
        if gk_g.len() < lbar * d {
            gk_g.extend(crate::curve::derive_generators(
                b"zkdl/aux-pad",
                lbar * d - gk_g.len(),
            ));
        }
        let gk = CommitKey::from_parts(gk_g, pk.g_aux.h, pk.g_aux.label.clone());

        let stack_expr = |cs: &[G1Affine]| -> ComExpr {
            ComExpr::sum(layers.iter().map(|&l| cs[l].to_projective()))
        };
        let mut checks: Vec<(CommitKey, OpeningCheck)> = Vec::new();
        checks.push((
            gk.clone(),
            OpeningCheck {
                evec: eq_table(&rho),
                claims: vec![
                    (stack_expr(&proof.com_sign), v_sign),
                    (stack_expr(&proof.com_zdp), v_zdp),
                    (stack_expr(&proof.com_gap), v_gap),
                    (stack_expr(&proof.com_rz), v_rz),
                    (stack_expr(&proof.com_rga), v_rga),
                ],
            },
        ));
        {
            let pz: Vec<Fr> = [p1.ch.u_zr.clone(), p1.ch.u_zc.clone()].concat();
            let claims_z: Vec<(ComExpr, Fr)> = layers
                .iter()
                .zip(gp.v_z.iter())
                .map(|(&l, &v)| {
                    (
                        derived_expr_z(
                            cfg,
                            proof.com_zdp[l].to_projective(),
                            proof.com_sign[l].to_projective(),
                            proof.com_rz[l].to_projective(),
                        ),
                        v,
                    )
                })
                .collect();
            checks.push((
                gk.clone(),
                OpeningCheck {
                    evec: tiled_eq(&pz, lbar),
                    claims: claims_z,
                },
            ));
        }
        {
            let inner: Vec<usize> = layers.iter().copied().filter(|&l| l + 1 < depth).collect();
            if !inner.is_empty() {
                let pga: Vec<Fr> = [p1.ch.u_gar.clone(), p1.ch.u_gac.clone()].concat();
                let claims_ga: Vec<(ComExpr, Fr)> = inner
                    .iter()
                    .zip(gp.v_ga.iter())
                    .map(|(&l, &v)| {
                        (
                            derived_expr_ga(
                                cfg,
                                proof.com_gap[l].to_projective(),
                                proof.com_rga[l].to_projective(),
                            ),
                            v,
                        )
                    })
                    .collect();
                checks.push((
                    gk.clone(),
                    OpeningCheck {
                        evec: tiled_eq(&pga, lbar),
                        claims: claims_ga,
                    },
                ));
            }
        }
        {
            let pgw: Vec<Fr> = [p1.ch.u_gwr.clone(), p1.ch.u_gwc.clone()].concat();
            let claims_gw: Vec<(ComExpr, Fr)> = layers
                .iter()
                .zip(gp.v_gw.iter())
                .map(|(&l, &v)| (ComExpr::point(proof.com_gw[l].to_projective()), v))
                .collect();
            checks.push((
                pk.g_mat.clone(),
                OpeningCheck {
                    evec: eq_table(&pgw),
                    claims: claims_gw,
                },
            ));
        }
        {
            let p: Vec<Fr> = [p1.r30.clone(), p1.ch.u_zc.clone()].concat();
            let claims_w: Vec<(ComExpr, Fr)> = layers
                .iter()
                .enumerate()
                .map(|(i, &l)| {
                    (
                        ComExpr::point(proof.com_w[l].to_projective()),
                        gp.mm30_evals[i].1,
                    )
                })
                .collect();
            checks.push((
                pk.g_mat.clone(),
                OpeningCheck {
                    evec: eq_table(&p),
                    claims: claims_w,
                },
            ));
        }
        {
            let inner: Vec<usize> = layers.iter().copied().filter(|&l| l + 1 < depth).collect();
            if !inner.is_empty() {
                let p: Vec<Fr> = [p1.ch.u_gac.clone(), p1.r33.clone()].concat();
                let claims_w: Vec<(ComExpr, Fr)> = inner
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| {
                        (
                            ComExpr::point(proof.com_w[l + 1].to_projective()),
                            gp.mm33_evals[i].1,
                        )
                    })
                    .collect();
                checks.push((
                    pk.g_mat.clone(),
                    OpeningCheck {
                        evec: eq_table(&p),
                        claims: claims_w,
                    },
                ));
            }
        }
        if layers.contains(&0) {
            let i0 = layers.iter().position(|&l| l == 0).unwrap();
            let p30: Vec<Fr> = [p1.ch.u_zr.clone(), p1.r30.clone()].concat();
            checks.push((
                pk.g_x.clone(),
                OpeningCheck {
                    evec: eq_table(&p30),
                    claims: vec![(
                        ComExpr::point(proof.com_x.to_projective()),
                        gp.mm30_evals[i0].0,
                    )],
                },
            ));
            let p34: Vec<Fr> = [p1.r34.clone(), p1.ch.u_gwc.clone()].concat();
            checks.push((
                pk.g_x.clone(),
                OpeningCheck {
                    evec: eq_table(&p34),
                    claims: vec![(
                        ComExpr::point(proof.com_x.to_projective()),
                        gp.mm34_evals[i0].1,
                    )],
                },
            ));
        }
        {
            let last = depth - 1;
            let last_ck = pk.block(last);
            let gz_expr = derived_expr_gz_last(
                cfg,
                proof.com_zdp[last].to_projective(),
                proof.com_sign[last].to_projective(),
                proof.com_y.to_projective(),
            );
            if let Some(i) = layers.iter().position(|&l| l == last) {
                let p: Vec<Fr> = [p1.r34.clone(), p1.ch.u_gwr.clone()].concat();
                checks.push((
                    last_ck.clone(),
                    OpeningCheck {
                        evec: eq_table(&p),
                        claims: vec![(gz_expr.clone(), gp.mm34_evals[i].0)],
                    },
                ));
            }
            let inner: Vec<usize> = layers.iter().copied().filter(|&l| l + 1 < depth).collect();
            if let Some(j) = inner.iter().position(|&l| l + 1 == last) {
                let p: Vec<Fr> = [p1.ch.u_gar.clone(), p1.r33.clone()].concat();
                checks.push((
                    last_ck,
                    OpeningCheck {
                        evec: eq_table(&p),
                        claims: vec![(gz_expr, gp.mm33_evals[j].0)],
                    },
                ));
            }
        }

        ensure!(
            gp.openings.len() == checks.len(),
            "opening count mismatch: {} vs {}",
            gp.openings.len(),
            checks.len()
        );
        for ((ck, check), opening) in checks.iter().zip(gp.openings.iter()) {
            ipa::batch_verify_eval_expr(ck, &check.claims, &check.evec, opening, &mut t, acc)
                .context("batched opening")?;
        }

        // ---- Phase 4: validity ----
        let (vb_main, vb_rem) = &vbases[gi];
        let u_dd = t.challenge_fr(b"zkdl/u_dd");
        let mut vpoint = vec![u_dd];
        vpoint.extend_from_slice(&rho);
        let e_row = eq_table(&vpoint);
        let v = (Fr::ONE - u_dd) * v_zdp + u_dd * v_gap;
        let com_sign_stacked = stack_expr(&proof.com_sign);
        zkrelu::verify_validity_accum(
            vb_main,
            &gp.p1_main,
            Some(&com_sign_stacked),
            &e_row,
            u_dd,
            v,
            v_sign,
            &gp.validity_main,
            &mut t,
            acc,
        )
        .context("main validity")?;
        let u_dd_r = t.challenge_fr(b"zkdl/u_dd_rem");
        let mut vpoint_r = vec![u_dd_r];
        vpoint_r.extend_from_slice(&rho);
        let e_row_r = eq_table(&vpoint_r);
        let v_rem = (Fr::ONE - u_dd_r) * v_rz + u_dd_r * v_rga;
        zkrelu::verify_validity_accum(
            vb_rem,
            &gp.p1_rem,
            None,
            &e_row_r,
            u_dd_r,
            v_rem,
            Fr::ZERO,
            &gp.validity_rem,
            &mut t,
            acc,
        )
        .context("remainder validity")?;
    }

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::model::Weights;
    use crate::witness::native::compute_witness;

    fn setup(depth: usize, width: usize, batch: usize) -> (ProverKey, StepWitness) {
        let cfg = ModelConfig::new(depth, width, batch);
        let mut rng = Rng::seed_from_u64(0xe2e);
        let ds = Dataset::synthetic(64, width / 2, 4, cfg.r_bits, 3);
        let (x, y) = ds.batch(&cfg, 0);
        let w = Weights::init(cfg, &mut rng);
        let wit = compute_witness(cfg, &x, &y, &w);
        wit.validate().expect("witness valid");
        (ProverKey::setup(cfg), wit)
    }

    #[test]
    fn parallel_roundtrip_depth2() {
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(1);
        let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        verify_step(&pk, &proof).expect("verifies");
        assert!(proof.size_bytes() > 0);
    }

    #[test]
    fn parallel_roundtrip_depth3() {
        let (pk, wit) = setup(3, 8, 4);
        let mut rng = Rng::seed_from_u64(2);
        let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        verify_step(&pk, &proof).expect("verifies");
    }

    #[test]
    fn parallel_roundtrip_depth1() {
        // no ReLU layers at all — stacking degenerates, validity still runs
        let (pk, wit) = setup(1, 8, 4);
        let mut rng = Rng::seed_from_u64(3);
        let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        verify_step(&pk, &proof).expect("verifies");
    }

    #[test]
    fn sequential_roundtrip_depth2() {
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(4);
        let proof = prove_step(&pk, &wit, ProofMode::Sequential, &mut rng);
        verify_step(&pk, &proof).expect("verifies");
    }

    #[test]
    fn sequential_larger_than_parallel() {
        let (pk, wit) = setup(4, 8, 4);
        let mut rng = Rng::seed_from_u64(5);
        let par = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let seq = prove_step(&pk, &wit, ProofMode::Sequential, &mut rng);
        verify_step(&pk, &par).expect("parallel verifies");
        verify_step(&pk, &seq).expect("sequential verifies");
        assert!(
            seq.size_bytes() > par.size_bytes(),
            "sequential {} should exceed parallel {}",
            seq.size_bytes(),
            par.size_bytes()
        );
    }

    #[test]
    fn verify_step_accum_defers_to_exactly_one_msm() {
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(10);
        let proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let mut seed = Rng::seed_from_u64(11);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        verify_step_accum(&pk, &proof, &mut acc).expect("deferred verification");
        assert_eq!(acc.flushes(), 0, "no MSM during deferred verification");
        assert!(acc.pending_terms() > 0);
        assert!(acc.flush(), "single aggregate MSM decides the proof");
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn steps_batch_accepts_good_rejects_single_tamper() {
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(12);
        let p1 = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let p2 = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let p3 = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let mut vrng = Rng::seed_from_u64(13);
        verify_steps_batch(&pk, &[p1.clone(), p2.clone(), p3.clone()], &mut vrng)
            .expect("good batch verifies with one MSM");
        // tamper exactly one proof, in the one place only the deferred MSM
        // check (not a transcript-level scalar check) can catch
        let mut bad = p2.clone();
        bad.groups[0].openings[0].a += Fr::ONE;
        verify_step(&pk, &p1).expect("untouched proof verifies alone");
        assert!(verify_step(&pk, &bad).is_err(), "tampered proof fails alone");
        let mut vrng2 = Rng::seed_from_u64(14);
        assert!(
            verify_steps_batch(&pk, &[p1, bad, p3], &mut vrng2).is_err(),
            "batch with exactly one tampered member must fail"
        );
    }

    #[test]
    fn rejects_tampered_witness_claims() {
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(6);
        let mut proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        proof.groups[0].v_z[0] += Fr::ONE;
        assert!(verify_step(&pk, &proof).is_err());
    }

    #[test]
    fn rejects_tampered_commitment() {
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(7);
        let mut proof = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        proof.com_w[0] = crate::curve::hash_to_curve(b"evil", 0);
        assert!(verify_step(&pk, &proof).is_err());
    }

    #[test]
    fn rejects_wrong_training_step() {
        // prove with witness A, then swap in commitments from witness B
        let (pk, wit) = setup(2, 8, 4);
        let mut rng = Rng::seed_from_u64(8);
        let proof_a = prove_step(&pk, &wit, ProofMode::Parallel, &mut rng);
        let mut rng2 = Rng::seed_from_u64(9);
        let mut wit_b = wit.clone();
        wit_b.layers[0].w[0] += 1 << 10;
        let wit_b = {
            // recompute a fully consistent witness for the perturbed weights
            let w = Weights {
                layers: wit_b.layers.iter().map(|l| l.w.clone()).collect(),
                cfg: wit.cfg,
            };
            compute_witness(wit.cfg, &wit.x, &wit.y, &w)
        };
        let proof_b = prove_step(&pk, &wit_b, ProofMode::Parallel, &mut rng2);
        // splice group data across proofs → must fail
        let mut frankenstein = proof_a.clone();
        frankenstein.groups = proof_b.groups.clone();
        assert!(verify_step(&pk, &frankenstein).is_err());
    }
}
