//! Fiat–Shamir transcript.
//!
//! The paper describes interactive protocols between the trainer 𝒯 and a
//! trusted verifier 𝒱; we run them non-interactively: every verifier
//! challenge (u_relu, u_bit, u_stack, k, z, RLC coefficients, IPA round
//! challenges …) is derived from a SHA-256 transcript that absorbs, in
//! order, every message the prover would have sent. Verifier re-derives the
//! same challenges, so soundness reduces to the random-oracle heuristic as
//! usual.

use crate::curve::G1Affine;
use crate::field::Fr;
use crate::telemetry::{self, Counter};
use sha2::{Digest, Sha256};

/// A running Fiat–Shamir transcript. Domain-separated by construction: each
/// absorb/squeeze is tagged with a label and a type byte.
#[derive(Clone)]
pub struct Transcript {
    state: [u8; 32],
    counter: u64,
}

impl Transcript {
    pub fn new(domain: &[u8]) -> Self {
        let mut h = Sha256::new();
        h.update(b"zkdl/transcript/v1");
        h.update((domain.len() as u64).to_le_bytes());
        h.update(domain);
        Self {
            state: h.finalize().into(),
            counter: 0,
        }
    }

    fn absorb(&mut self, tag: u8, label: &[u8], data: &[u8]) {
        telemetry::count(Counter::TranscriptAbsorbs, 1);
        let mut h = Sha256::new();
        h.update(self.state);
        h.update([tag]);
        h.update((label.len() as u64).to_le_bytes());
        h.update(label);
        h.update((data.len() as u64).to_le_bytes());
        h.update(data);
        self.state = h.finalize().into();
    }

    pub fn absorb_bytes(&mut self, label: &[u8], data: &[u8]) {
        self.absorb(0x01, label, data);
    }

    pub fn absorb_u64(&mut self, label: &[u8], v: u64) {
        self.absorb(0x02, label, &v.to_le_bytes());
    }

    pub fn absorb_fr(&mut self, label: &[u8], v: &Fr) {
        self.absorb(0x03, label, &v.to_bytes());
    }

    pub fn absorb_frs(&mut self, label: &[u8], vs: &[Fr]) {
        let mut buf = Vec::with_capacity(vs.len() * 32);
        for v in vs {
            buf.extend_from_slice(&v.to_bytes());
        }
        self.absorb(0x04, label, &buf);
    }

    pub fn absorb_point(&mut self, label: &[u8], p: &G1Affine) {
        self.absorb(0x05, label, &p.to_bytes());
    }

    pub fn absorb_points(&mut self, label: &[u8], ps: &[G1Affine]) {
        let mut buf = Vec::with_capacity(ps.len() * 64);
        for p in ps {
            buf.extend_from_slice(&p.to_bytes());
        }
        self.absorb(0x06, label, &buf);
    }

    /// Squeeze one field challenge (uniform via 64-byte wide reduction).
    pub fn challenge_fr(&mut self, label: &[u8]) -> Fr {
        telemetry::count(Counter::TranscriptChallenges, 1);
        let mut wide = [0u8; 64];
        for half in 0..2u8 {
            let mut h = Sha256::new();
            h.update(self.state);
            h.update([0xF0, half]);
            h.update((label.len() as u64).to_le_bytes());
            h.update(label);
            h.update(self.counter.to_le_bytes());
            wide[half as usize * 32..(half as usize + 1) * 32]
                .copy_from_slice(&h.finalize());
        }
        self.counter += 1;
        // ratchet the state so successive challenges differ
        let mut h = Sha256::new();
        h.update(self.state);
        h.update([0xF2]);
        h.update(wide);
        self.state = h.finalize().into();
        Fr::from_bytes_wide(&wide)
    }

    /// Squeeze a vector of challenges.
    pub fn challenge_frs(&mut self, label: &[u8], n: usize) -> Vec<Fr> {
        (0..n).map(|_| self.challenge_fr(label)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Transcript::new(b"t");
        let mut b = Transcript::new(b"t");
        a.absorb_u64(b"x", 1);
        a.absorb_u64(b"y", 2);
        b.absorb_u64(b"x", 1);
        b.absorb_u64(b"y", 2);
        assert_eq!(a.challenge_fr(b"c"), b.challenge_fr(b"c"));

        let mut c = Transcript::new(b"t");
        c.absorb_u64(b"y", 2);
        c.absorb_u64(b"x", 1);
        assert_ne!(a.challenge_fr(b"c"), c.challenge_fr(b"c"));
    }

    #[test]
    fn domain_separation() {
        let mut a = Transcript::new(b"d1");
        let mut b = Transcript::new(b"d2");
        assert_ne!(a.challenge_fr(b"c"), b.challenge_fr(b"c"));
    }

    #[test]
    fn successive_challenges_differ() {
        let mut t = Transcript::new(b"t");
        let c1 = t.challenge_fr(b"c");
        let c2 = t.challenge_fr(b"c");
        assert_ne!(c1, c2);
    }

    #[test]
    fn absorbing_changes_challenges() {
        let mut a = Transcript::new(b"t");
        let mut b = Transcript::new(b"t");
        a.absorb_fr(b"v", &Fr::from_u64(5));
        b.absorb_fr(b"v", &Fr::from_u64(6));
        assert_ne!(a.challenge_fr(b"c"), b.challenge_fr(b"c"));
    }
}
