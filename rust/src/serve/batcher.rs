//! zkServe batching core — bounded admission queue, dataset-root sharding,
//! and the collector tick that turns N concurrent submissions into ONE
//! `verify_traces_batch_report` MSM.
//!
//! Submissions land in a shard keyed by the artifact's dataset root
//! (`None` for artifacts without provenance), because
//! [`verify_traces_batch_report`](crate::aggregate::verify_traces_batch_report)
//! verifies a whole shard with one Pippenger MSM and per-proof random
//! scaling — the amortized verifier cost per proof *drops* as concurrent
//! load rises. The queue is bounded: when `queue_cap` submissions are
//! already waiting, [`BatchQueue::push`] refuses with
//! [`PushError::Overloaded`] and the connection handler answers
//! `overloaded` instead of buffering without limit.
//!
//! The collector thread ticks on a condvar: a shard is flushed as soon as
//! it reaches `max_batch` entries, when its oldest entry has waited
//! `max_wait`, or unconditionally during drain. Flushing takes the whole
//! shard out under the lock and verifies it outside the lock, so admission
//! never blocks on an MSM.

use crate::aggregate::{verify_traces_batch_report, TraceKey, TraceProof};
use crate::telemetry::{self, hist, Counter};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Verdict delivered back to the waiting connection handler.
#[derive(Clone, Debug)]
pub enum Outcome {
    Accepted,
    Rejected {
        class: Option<String>,
        message: String,
    },
}

/// One admitted submission, parked in its shard until the collector ticks.
pub struct Pending {
    /// Decoded artifact and the (cached) key its shape requires.
    pub key: Arc<TraceKey>,
    pub proof: TraceProof,
    /// Dataset root (shard key); `None` = the no-provenance shard.
    pub root: Option<Vec<u8>>,
    /// Journal context captured at admission.
    pub artifact_bytes: u64,
    pub artifact_sha256: String,
    pub rule: Option<String>,
    pub submitted: Instant,
    /// Rendezvous back to the handler thread (capacity 1: the collector
    /// never blocks on a slow handler).
    pub reply: SyncSender<Outcome>,
}

/// Why [`BatchQueue::push`] refused a submission.
pub enum PushError {
    /// `queue_cap` submissions already waiting — backpressure.
    Overloaded(Pending),
    /// The daemon is draining; no new work is admitted.
    Draining(Pending),
}

struct QueueState {
    shards: HashMap<Option<Vec<u8>>, Vec<Pending>>,
    len: usize,
    draining: bool,
}

/// The shared admission queue. Handlers push; exactly one collector thread
/// drains via [`BatchQueue::collect`].
pub struct BatchQueue {
    state: Mutex<QueueState>,
    tick: Condvar,
    cap: usize,
    max_batch: usize,
    max_wait: Duration,
}

/// One flushed shard, verified by the caller outside the queue lock.
pub struct FlushedShard {
    pub root: Option<Vec<u8>>,
    pub pending: Vec<Pending>,
}

impl BatchQueue {
    pub fn new(cap: usize, max_batch: usize, max_wait: Duration) -> Arc<BatchQueue> {
        Arc::new(BatchQueue {
            state: Mutex::new(QueueState {
                shards: HashMap::new(),
                len: 0,
                draining: false,
            }),
            tick: Condvar::new(),
            cap: cap.max(1),
            max_batch: max_batch.max(1),
            max_wait,
        })
    }

    /// Admit one submission into its root shard, or refuse it.
    pub fn push(&self, p: Pending) -> Result<(), PushError> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.draining {
            return Err(PushError::Draining(p));
        }
        if st.len >= self.cap {
            return Err(PushError::Overloaded(p));
        }
        st.len += 1;
        let shard = st.shards.entry(p.root.clone()).or_default();
        shard.push(p);
        let full = shard.len() >= self.max_batch;
        drop(st);
        // wake the collector: immediately when a shard hit max_batch, and
        // otherwise too — it recomputes the nearest deadline either way
        if full {
            self.tick.notify_all();
        } else {
            self.tick.notify_one();
        }
        Ok(())
    }

    /// Number of submissions currently waiting.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enter drain mode: all waiting shards become due, new pushes are
    /// refused, and [`collect`](Self::collect) returns `None` once empty.
    pub fn begin_drain(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.draining = true;
        drop(st);
        self.tick.notify_all();
    }

    /// Collector tick: block until at least one shard is due (full, aged
    /// past `max_wait`, or draining), then take every due shard. Returns
    /// `None` exactly once — when draining and empty — which is the
    /// collector thread's exit signal.
    pub fn collect(&self) -> Option<Vec<FlushedShard>> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if st.draining && st.len == 0 {
                return None;
            }
            let now = Instant::now();
            let draining = st.draining;
            let due_roots: Vec<Option<Vec<u8>>> = st
                .shards
                .iter()
                .filter(|(_, pend)| {
                    draining
                        || pend.len() >= self.max_batch
                        || pend
                            .first()
                            .is_some_and(|p| now.duration_since(p.submitted) >= self.max_wait)
                })
                .map(|(root, _)| root.clone())
                .collect();
            if !due_roots.is_empty() {
                let mut out = Vec::with_capacity(due_roots.len());
                for root in due_roots {
                    if let Some(pending) = st.shards.remove(&root) {
                        st.len -= pending.len();
                        out.push(FlushedShard { root, pending });
                    }
                }
                return Some(out);
            }
            // sleep until the nearest shard deadline (or max_wait if idle)
            let wait = st
                .shards
                .values()
                .filter_map(|pend| pend.first())
                .map(|p| {
                    self.max_wait
                        .saturating_sub(now.duration_since(p.submitted))
                })
                .min()
                .unwrap_or(self.max_wait)
                .max(Duration::from_millis(1));
            let (next, _) = self
                .tick
                .wait_timeout(st, wait)
                .unwrap_or_else(|e| e.into_inner());
            st = next;
        }
    }
}

/// Verify one flushed shard with ONE MSM and deliver every verdict.
/// Returns `(outcomes, counter_delta)` for journaling: outcomes in shard
/// order, and the invocation-wide counter delta of the batch (attribution
/// below one MSM is not separable — same convention as the CLI's batched
/// `verify-trace`).
pub fn verify_shard(
    shard: &FlushedShard,
    rng: &mut Rng,
) -> (Vec<Outcome>, Vec<(String, u64)>, f64) {
    let start = Instant::now();
    let before = telemetry::counters_snapshot();
    telemetry::count(Counter::ServeBatches, 1);
    telemetry::count(Counter::ServeCoalesced, shard.pending.len().saturating_sub(1) as u64);
    hist::record(hist::Hist::ServeBatchSize, shard.pending.len() as u64);
    let pairs: Vec<(&TraceKey, &TraceProof)> = shard
        .pending
        .iter()
        .map(|p| (p.key.as_ref(), &p.proof))
        .collect();
    let report = verify_traces_batch_report(&pairs, rng);
    let outcomes: Vec<Outcome> = report
        .entries
        .iter()
        .map(|e| {
            if e.accepted && report.batch_error.is_none() {
                Outcome::Accepted
            } else if e.accepted {
                // the aggregate rejected but no individual proof did (e.g.
                // a cross-proof tamper only the batch MSM sees): reject all
                // members with the batch-level error
                Outcome::Rejected {
                    class: None,
                    message: report
                        .batch_error
                        .clone()
                        .unwrap_or_else(|| "batch rejected".into()),
                }
            } else {
                Outcome::Rejected {
                    class: e.failure_class.map(|c| c.name().to_string()),
                    message: e.error.clone().unwrap_or_else(|| "rejected".into()),
                }
            }
        })
        .collect();
    let after = telemetry::counters_snapshot();
    let delta = crate::telemetry::journal::counter_deltas(&after, &before);
    (outcomes, delta, start.elapsed().as_secs_f64())
}

/// Deliver one verdict: record the submit→verdict latency and hand the
/// outcome to the waiting handler (which may have vanished — a dropped
/// connection must not wedge the collector).
pub fn deliver(p: &Pending, outcome: Outcome) {
    hist::record(
        hist::Hist::ServeSubmitNs,
        p.submitted.elapsed().as_nanos() as u64,
    );
    let _ = p.reply.try_send(outcome);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn dummy_pending(root: Option<Vec<u8>>) -> (Pending, std::sync::mpsc::Receiver<Outcome>) {
        use crate::model::ModelConfig;
        let cfg = ModelConfig::new(2, 8, 4);
        static KEY: once_cell::sync::Lazy<Arc<TraceKey>> = once_cell::sync::Lazy::new(|| {
            Arc::new(TraceKey::setup(ModelConfig::new(2, 8, 4), 1))
        });
        let mut rng = Rng::seed_from_u64(7);
        let wit = {
            let ds = crate::data::Dataset::synthetic(16, cfg.width / 2, 4, cfg.r_bits, 3);
            let weights = crate::model::Weights::init(cfg, &mut rng);
            let (x, y) = ds.batch(&cfg, 0);
            crate::witness::native::compute_witness(cfg, &x, &y, &weights)
        };
        let proof = crate::aggregate::prove_trace(&KEY, std::slice::from_ref(&wit), &mut rng);
        let (tx, rx) = sync_channel(1);
        (
            Pending {
                key: KEY.clone(),
                proof,
                root,
                artifact_bytes: 0,
                artifact_sha256: String::new(),
                rule: None,
                submitted: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn queue_shards_by_root_and_flushes_full_shards() {
        let q = BatchQueue::new(8, 2, Duration::from_secs(60));
        let (a, _ra) = dummy_pending(None);
        let (b, _rb) = dummy_pending(None);
        let (c, _rc) = dummy_pending(Some(vec![1; 4]));
        q.push(a).map_err(|_| ()).unwrap();
        q.push(c).map_err(|_| ()).unwrap();
        q.push(b).map_err(|_| ()).unwrap();
        // the None shard hit max_batch=2 and is due; the root shard is not
        let shards = q.collect().expect("not draining");
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].root, None);
        assert_eq!(shards[0].pending.len(), 2);
        assert_eq!(q.len(), 1);
        // drain mode makes the remaining shard due, then ends the collector
        q.begin_drain();
        let shards = q.collect().expect("drain flush");
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].root, Some(vec![1; 4]));
        assert!(q.collect().is_none(), "collector exit after drain");
    }

    #[test]
    fn queue_refuses_over_cap_and_while_draining() {
        let q = BatchQueue::new(1, 8, Duration::from_secs(60));
        let (a, _ra) = dummy_pending(None);
        let (b, _rb) = dummy_pending(None);
        q.push(a).map_err(|_| ()).unwrap();
        match q.push(b) {
            Err(PushError::Overloaded(_)) => {}
            _ => panic!("expected overload"),
        }
        q.begin_drain();
        let (c, _rc) = dummy_pending(None);
        match q.push(c) {
            Err(PushError::Draining(_)) => {}
            _ => panic!("expected draining"),
        }
    }

    #[test]
    fn aged_shard_becomes_due_without_filling() {
        let q = BatchQueue::new(8, 100, Duration::from_millis(10));
        let (a, _ra) = dummy_pending(None);
        q.push(a).map_err(|_| ()).unwrap();
        let start = Instant::now();
        let shards = q.collect().expect("not draining");
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].pending.len(), 1);
        assert!(
            start.elapsed() < Duration::from_secs(30),
            "tick must fire on max_wait, not hang"
        );
    }

    #[test]
    fn verify_shard_accepts_valid_and_attributes_tampered() {
        let (good, _rg) = dummy_pending(None);
        let (mut bad, _rb) = dummy_pending(None);
        // tamper a scalar claim: decode-clean but verify-rejected
        bad.proof.v_z[0] = bad.proof.v_z[0] + crate::Fr::ONE;
        let shard = FlushedShard {
            root: None,
            pending: vec![good, bad],
        };
        let mut rng = Rng::seed_from_u64(0x5eed);
        let (outcomes, _delta, _dur) = verify_shard(&shard, &mut rng);
        assert_eq!(outcomes.len(), 2);
        assert!(matches!(outcomes[0], Outcome::Accepted));
        match &outcomes[1] {
            Outcome::Rejected { class, .. } => assert!(class.is_some(), "typed class expected"),
            other => panic!("expected rejection, got {other:?}"),
        }
    }
}
