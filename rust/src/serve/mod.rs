//! zkServe — a long-lived batching verifier daemon over the wire format.
//!
//! Millions of users means *verification* is the traffic-heavy path: many
//! consumers check training certificates against few provers. zkServe is a
//! zero-new-dependency daemon on [`std::net::TcpListener`] that amortizes
//! the existing machinery across concurrent requests:
//!
//! * **[`protocol`]** — a length-prefixed framed protocol (`zkdl/serve/v1`)
//!   carrying trace artifacts in the existing wire encoding, with the
//!   payload cap enforced before allocation and per-connection read/write
//!   timeouts;
//! * **[`batcher`]** — a bounded admission queue sharded by dataset root;
//!   a collector tick (configurable `max_batch` / `max_wait`) drains each
//!   shard into ONE
//!   [`verify_traces_batch_report`](crate::aggregate::verify_traces_batch_report)
//!   MSM (per-proof re-attribution only on batch rejection), so amortized
//!   verifier cost per proof *drops* with load;
//! * **operations** — graceful shutdown on SIGINT via a self-pipe (drain
//!   the queue, refuse new frames), backpressure via `overloaded` responses
//!   instead of unbounded buffering, a bounded [`TraceKey`] cache prewarmed
//!   by the first artifact of each shape, and full zkFlight integration
//!   (every decision journaled with seq + failure class; `serve/*`
//!   counters; latency histograms surfaced by the `status` frame).
//!
//! Threading: each connection gets one OS thread (handlers mostly block on
//! I/O or on their verdict rendezvous); the collector's MSM fans out on the
//! zkLanes worker pool through the existing parallel verify paths, so the
//! compute pool is never occupied by idle sockets.

pub mod batcher;
pub mod protocol;

use crate::aggregate::{trace_dataset_root, TraceKey};
use crate::telemetry::journal::{artifact_digest, Journal, JournalEvent};
use crate::telemetry::{self, hist, Counter};
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use batcher::{BatchQueue, Outcome, Pending, PushError};
use protocol::{read_frame, write_frame, Frame, ReadOutcome};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Journal verb stamped on every submission verdict.
pub const VERB_SERVE_VERIFY: &str = "serve-verify";
/// Journal verb stamped on framing-level rejections (bad magic, oversized
/// frame, truncation) where no artifact was decoded.
pub const VERB_SERVE_FRAME: &str = "serve-frame";

/// Status-frame schema tag.
pub const STATUS_SCHEMA: &str = "zkdl/serve/status/v1";

/// Most distinct (shape, steps) keys kept warm; beyond it the cache resets
/// (shapes are few in practice — a daemon serves a handful of models).
const KEY_CACHE_CAP: usize = 64;

/// Daemon configuration. `addr` may name port 0 for an ephemeral port (the
/// bound address is reported by [`Server::addr`]) — how the loopback tests
/// and bench run without port coordination.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Flush a shard as soon as it holds this many submissions.
    pub max_batch: usize,
    /// Flush a shard once its oldest submission has waited this long.
    pub max_wait: Duration,
    /// Admission-queue bound; beyond it submissions get `overloaded`.
    pub queue_cap: usize,
    /// Idle-connection poll tick (also the shutdown-latency bound for idle
    /// handlers) and the per-read socket timeout.
    pub poll_interval: Duration,
    /// Per-connection write timeout.
    pub write_timeout: Duration,
    /// Append every decision to this zkFlight journal.
    pub journal: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:9155".into(),
            max_batch: 16,
            max_wait: Duration::from_millis(50),
            queue_cap: 256,
            poll_interval: Duration::from_millis(250),
            write_timeout: Duration::from_secs(10),
            journal: None,
        }
    }
}

/// Counter snapshot rendered when the daemon exits.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub frames: u64,
    pub batches: u64,
    pub coalesced: u64,
    pub overloads: u64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} frames, {} batches ({} coalesced), {} overloads",
            self.frames, self.batches, self.coalesced, self.overloads
        )
    }
}

type KeyCacheKey = (usize, usize, usize, u32, u32, u32, usize);

struct Ctx {
    cfg: ServeConfig,
    queue: Arc<BatchQueue>,
    shutdown: AtomicBool,
    journal: Mutex<Option<Journal>>,
    keys: Mutex<HashMap<KeyCacheKey, Arc<TraceKey>>>,
    started: Instant,
}

impl Ctx {
    fn journal_event(&self, ev: JournalEvent) {
        let mut g = self.journal.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(j) = g.as_mut() {
            // journal I/O failure must not take the daemon down; the
            // journal is observability, not the verdict path
            let _ = j.append(ev);
        }
    }

    /// Key-cache prewarm: the first artifact of a (shape, steps) pays the
    /// setup (itself cheap after `commit::KEY_CACHE` has the bases); every
    /// later submission of that shape clones an `Arc`.
    fn key_for(&self, cfg: crate::model::ModelConfig, steps: usize) -> Arc<TraceKey> {
        let key: KeyCacheKey = (
            cfg.depth, cfg.width, cfg.batch, cfg.r_bits, cfg.q_bits, cfg.lr_shift, steps,
        );
        if let Some(tk) = self
            .keys
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&key)
        {
            return tk.clone();
        }
        let tk = Arc::new(TraceKey::setup(cfg, steps));
        let mut map = self.keys.lock().unwrap_or_else(|e| e.into_inner());
        if map.len() >= KEY_CACHE_CAP {
            map.clear();
        }
        map.entry(key).or_insert_with(|| tk.clone()).clone()
    }

    fn status_json(&self) -> String {
        use crate::telemetry::json::Json;
        let counter = |c: Counter| (c.name().to_string(), Json::Uint(telemetry::counter_value(c)));
        let hist_digest =
            |h: hist::Hist| (h.name().to_string(), hist::snapshot(h).to_json());
        Json::obj(vec![
            ("schema", Json::str(STATUS_SCHEMA)),
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            ("queue_len", Json::Uint(self.queue.len() as u64)),
            (
                "counters",
                Json::Obj(vec![
                    counter(Counter::ServeFrames),
                    counter(Counter::ServeBatches),
                    counter(Counter::ServeCoalesced),
                    counter(Counter::ServeOverload),
                    counter(Counter::MsmFlushes),
                    counter(Counter::MsmCalls),
                ]),
            ),
            (
                "hists",
                Json::Obj(vec![
                    hist_digest(hist::Hist::ServeSubmitNs),
                    hist_digest(hist::Hist::ServeBatchSize),
                ]),
            ),
        ])
        .to_string()
    }
}

/// A running daemon: accept loop + connection handlers + collector thread.
/// Obtain one with [`Server::spawn`]; stop it with [`Server::shutdown`]
/// (tests) or let [`run`] drive it to a SIGINT (CLI).
pub struct Server {
    addr: SocketAddr,
    ctx: Arc<Ctx>,
    accept: Option<std::thread::JoinHandle<()>>,
    collector: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, start the accept loop and the collector, and return. Never
    /// blocks on traffic.
    pub fn spawn(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("serve: binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("serve: local addr")?;
        let journal = match &cfg.journal {
            Some(path) => Some(Journal::open(path)?),
            None => None,
        };
        let queue = BatchQueue::new(cfg.queue_cap, cfg.max_batch, cfg.max_wait);
        let ctx = Arc::new(Ctx {
            cfg,
            queue,
            shutdown: AtomicBool::new(false),
            journal: Mutex::new(journal),
            keys: Mutex::new(HashMap::new()),
            started: Instant::now(),
        });

        let collector = {
            let ctx = ctx.clone();
            std::thread::Builder::new()
                .name("zkserve-collector".into())
                .spawn(move || collector_loop(&ctx))
                .context("serve: spawning collector")?
        };
        let accept = {
            let ctx = ctx.clone();
            std::thread::Builder::new()
                .name("zkserve-accept".into())
                .spawn(move || accept_loop(listener, &ctx))
                .context("serve: spawning accept loop")?
        };
        Ok(Server {
            addr,
            ctx,
            accept: Some(accept),
            collector: Some(collector),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop admitting, wake the accept loop, drain every
    /// queued shard through the collector (each gets its real verdict), and
    /// join all threads. Idempotent via [`Drop`].
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        ServeStats {
            frames: telemetry::counter_value(Counter::ServeFrames),
            batches: telemetry::counter_value(Counter::ServeBatches),
            coalesced: telemetry::counter_value(Counter::ServeCoalesced),
            overloads: telemetry::counter_value(Counter::ServeOverload),
        }
    }

    fn stop(&mut self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        // refuse new work first so the drain below is finite…
        self.ctx.queue.begin_drain();
        // …then wake the blocking accept(2) with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.collector.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn collector_loop(ctx: &Ctx) {
    let mut rng = Rng::from_entropy();
    while let Some(shards) = ctx.queue.collect() {
        for shard in shards {
            let (outcomes, delta, duration_s) = batcher::verify_shard(&shard, &mut rng);
            let batch_size = shard.pending.len() as u64;
            for (i, (p, outcome)) in shard.pending.iter().zip(&outcomes).enumerate() {
                let mut ev = match outcome {
                    Outcome::Accepted => JournalEvent::new(VERB_SERVE_VERIFY, "accepted"),
                    Outcome::Rejected { class, .. } => {
                        let mut ev = JournalEvent::new(VERB_SERVE_VERIFY, "rejected");
                        ev.failure_class = class.clone();
                        ev
                    }
                };
                ev.duration_s = duration_s;
                ev.wire_version = crate::wire::VERSION as u64;
                ev.artifact_bytes = p.artifact_bytes;
                ev.artifact_sha256 = Some(p.artifact_sha256.clone());
                ev.rule = p.rule.clone();
                ev.dataset_root = p.root.as_ref().map(|r| hex(r));
                ev.batch_index = Some(i as u64);
                ev.batch_size = Some(batch_size);
                ev.counters = delta.clone();
                ctx.journal_event(ev);
            }
            for (p, outcome) in shard.pending.iter().zip(outcomes) {
                batcher::deliver(p, outcome);
            }
        }
    }
}

fn accept_loop(listener: TcpListener, ctx: &Arc<Ctx>) {
    let mut handlers = Vec::new();
    for stream in listener.incoming() {
        if ctx.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let ctx = ctx.clone();
        if let Ok(h) = std::thread::Builder::new()
            .name("zkserve-conn".into())
            .spawn(move || handle_connection(stream, &ctx))
        {
            handlers.push(h);
        }
        // reap finished handlers so a long-lived daemon doesn't grow a
        // handle per connection it ever served
        handlers.retain(|h| !h.is_finished());
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// One connection: read frames until EOF, error, or shutdown. Submissions
/// block this thread on their verdict rendezvous — pipelining is per
/// connection-count, which keeps the protocol strictly request/response.
fn handle_connection(mut stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_read_timeout(Some(ctx.cfg.poll_interval));
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    loop {
        match read_frame(&mut stream) {
            Ok(ReadOutcome::Idle) => {
                if ctx.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Ok(ReadOutcome::Eof) => break,
            Err(e) => {
                // framing is broken (garbage magic, oversized length,
                // truncation): journal, answer best-effort, drop the
                // connection — the stream cannot be resynchronized
                telemetry::count(Counter::ServeFrames, 1);
                let mut ev = JournalEvent::new(VERB_SERVE_FRAME, "rejected");
                ev.failure_class =
                    Some(crate::telemetry::failure::VerifyFailureClass::WireDecode.name().into());
                ctx.journal_event(ev);
                let _ = write_frame(
                    &mut stream,
                    &Frame::Rejected {
                        class: Some(
                            crate::telemetry::failure::VerifyFailureClass::WireDecode
                                .name()
                                .into(),
                        ),
                        message: format!("{e:#}"),
                    },
                );
                break;
            }
            Ok(ReadOutcome::Frame(Frame::Status)) => {
                telemetry::count(Counter::ServeFrames, 1);
                if write_frame(&mut stream, &Frame::StatusReport(ctx.status_json())).is_err() {
                    break;
                }
            }
            Ok(ReadOutcome::Frame(Frame::Submit(bytes))) => {
                telemetry::count(Counter::ServeFrames, 1);
                if ctx.shutdown.load(Ordering::SeqCst) {
                    let _ = write_frame(&mut stream, &Frame::ShuttingDown);
                    break;
                }
                if !handle_submit(&mut stream, ctx, bytes) {
                    break;
                }
            }
            Ok(ReadOutcome::Frame(other)) => {
                // a server→client frame arriving at the server is a
                // protocol violation; refuse and drop
                telemetry::count(Counter::ServeFrames, 1);
                let _ = write_frame(
                    &mut stream,
                    &Frame::Rejected {
                        class: None,
                        message: format!("serve: unexpected client frame {other:?}"),
                    },
                );
                break;
            }
        }
    }
}

/// Decode, admit, await the verdict, respond. Returns `false` when the
/// connection should close (write failure or drain).
fn handle_submit(stream: &mut TcpStream, ctx: &Ctx, bytes: Vec<u8>) -> bool {
    let start = Instant::now();
    let (cfg, proof) = match crate::wire::decode_trace_proof(&bytes) {
        Ok(v) => v,
        Err(e) => {
            let class = crate::telemetry::failure::failure_class(&e).map(|c| c.name().to_string());
            let mut ev = JournalEvent::new(VERB_SERVE_VERIFY, "rejected");
            ev.duration_s = start.elapsed().as_secs_f64();
            ev.wire_version = crate::wire::VERSION as u64;
            ev.artifact_bytes = bytes.len() as u64;
            ev.artifact_sha256 = Some(artifact_digest(&bytes));
            ev.failure_class = class.clone();
            ctx.journal_event(ev);
            hist::record(hist::Hist::ServeSubmitNs, start.elapsed().as_nanos() as u64);
            return write_frame(
                stream,
                &Frame::Rejected {
                    class,
                    message: format!("{e:#}"),
                },
            )
            .is_ok();
        }
    };
    let key = ctx.key_for(cfg, proof.steps);
    let (reply, verdict) = sync_channel(1);
    let pending = Pending {
        root: trace_dataset_root(&proof),
        rule: proof.chain.as_ref().map(|c| c.rule.name().to_string()),
        artifact_bytes: bytes.len() as u64,
        artifact_sha256: artifact_digest(&bytes),
        key,
        proof,
        submitted: start,
        reply,
    };
    match ctx.queue.push(pending) {
        Ok(()) => {}
        Err(PushError::Overloaded(p)) => {
            telemetry::count(Counter::ServeOverload, 1);
            let mut ev = JournalEvent::new(VERB_SERVE_VERIFY, "overloaded");
            ev.duration_s = start.elapsed().as_secs_f64();
            ev.wire_version = crate::wire::VERSION as u64;
            ev.artifact_bytes = p.artifact_bytes;
            ev.artifact_sha256 = Some(p.artifact_sha256.clone());
            ev.dataset_root = p.root.as_ref().map(|r| hex(r));
            ctx.journal_event(ev);
            return write_frame(stream, &Frame::Overloaded).is_ok();
        }
        Err(PushError::Draining(_)) => {
            let _ = write_frame(stream, &Frame::ShuttingDown);
            return false;
        }
    }
    // the collector always delivers: every admitted submission is either
    // flushed by a tick or by the drain pass
    let outcome = verdict
        .recv()
        .unwrap_or_else(|_| Outcome::Rejected {
            class: None,
            message: "serve: daemon stopped before verdict".into(),
        });
    let frame = match outcome {
        Outcome::Accepted => Frame::Accepted,
        Outcome::Rejected { class, message } => Frame::Rejected { class, message },
    };
    write_frame(stream, &frame).is_ok()
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Submit one artifact and return the daemon's response frame. `Accepted`
/// maps to exit 0 in the CLI; everything else is a refusal with its reason.
pub fn submit(addr: &str, artifact: &[u8], timeout: Duration) -> Result<Frame> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("serve: connecting to {addr}"))?;
    stream.set_read_timeout(Some(timeout)).context("serve: read timeout")?;
    stream.set_write_timeout(Some(timeout)).context("serve: write timeout")?;
    let _ = stream.set_nodelay(true);
    write_frame(&mut stream, &Frame::Submit(artifact.to_vec()))?;
    match read_frame(&mut stream)? {
        ReadOutcome::Frame(f) => Ok(f),
        ReadOutcome::Eof => anyhow::bail!("serve: daemon closed the connection without a verdict"),
        ReadOutcome::Idle => anyhow::bail!("serve: timed out waiting for a verdict"),
    }
}

/// Fetch the daemon's status JSON.
pub fn status(addr: &str, timeout: Duration) -> Result<String> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("serve: connecting to {addr}"))?;
    stream.set_read_timeout(Some(timeout)).context("serve: read timeout")?;
    stream.set_write_timeout(Some(timeout)).context("serve: write timeout")?;
    write_frame(&mut stream, &Frame::Status)?;
    match read_frame(&mut stream)? {
        ReadOutcome::Frame(Frame::StatusReport(json)) => Ok(json),
        ReadOutcome::Frame(other) => anyhow::bail!("serve: unexpected reply {other:?}"),
        _ => anyhow::bail!("serve: no status reply"),
    }
}

// ---------------------------------------------------------------------------
// loopback bench (the `zkdl bench --serve` axis)
// ---------------------------------------------------------------------------

/// One serve-bench row: `clients` concurrent loopback submitters, each
/// sending `submissions / clients` copies of the same artifact. `coalesced`
/// counts submissions that rode along in someone else's MSM; `msm_flushes`
/// is the total MSM count for the whole row — the amortization headline.
#[derive(Clone, Copy, Debug)]
pub struct ServeBenchRow {
    pub clients: usize,
    pub submissions: u64,
    pub accepted: u64,
    pub batches: u64,
    pub coalesced: u64,
    pub msm_flushes: u64,
    /// Server-side submit latency (decode → verdict delivered), nanoseconds.
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub wall_s: f64,
}

impl ServeBenchRow {
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        Json::obj(vec![
            ("clients", Json::Uint(self.clients as u64)),
            ("submissions", Json::Uint(self.submissions)),
            ("accepted", Json::Uint(self.accepted)),
            ("batches", Json::Uint(self.batches)),
            ("coalesced", Json::Uint(self.coalesced)),
            ("msm_flushes", Json::Uint(self.msm_flushes)),
            ("p50_ns", Json::Uint(self.p50_ns)),
            ("p95_ns", Json::Uint(self.p95_ns)),
            ("wall_s", Json::Num(self.wall_s)),
        ])
    }
}

/// Measure round-trip throughput and MSM coalescing over loopback, one row
/// per entry of `clients_axis`. Holds the telemetry lock for the duration
/// (counters are the measurement); leaves telemetry disabled and reset.
pub fn bench_loopback(
    artifact: &[u8],
    clients_axis: &[usize],
    per_client: usize,
) -> Result<Vec<ServeBenchRow>> {
    telemetry::exclusive(|| {
        let mut rows = Vec::new();
        for &clients in clients_axis {
            let clients = clients.max(1);
            telemetry::reset();
            hist::reset_all();
            telemetry::set_enabled(true);
            let server = Server::spawn(ServeConfig {
                addr: "127.0.0.1:0".into(),
                // flush when every concurrent client has been admitted, or
                // after a short age — the coalescing sweet spot per row
                max_batch: clients,
                max_wait: Duration::from_millis(20),
                ..ServeConfig::default()
            })?;
            let addr = server.addr().to_string();
            let start = Instant::now();
            let mut handles = Vec::new();
            for _ in 0..clients {
                let addr = addr.clone();
                let artifact = artifact.to_vec();
                handles.push(std::thread::spawn(move || -> Result<u64> {
                    let mut ok = 0u64;
                    for _ in 0..per_client {
                        if matches!(
                            submit(&addr, &artifact, Duration::from_secs(120))?,
                            Frame::Accepted
                        ) {
                            ok += 1;
                        }
                    }
                    Ok(ok)
                }));
            }
            let mut accepted = 0u64;
            for h in handles {
                accepted += h
                    .join()
                    .map_err(|_| anyhow::anyhow!("serve bench: client thread panicked"))??;
            }
            let wall_s = start.elapsed().as_secs_f64();
            let lat = hist::snapshot(hist::Hist::ServeSubmitNs);
            rows.push(ServeBenchRow {
                clients,
                submissions: (clients * per_client) as u64,
                accepted,
                batches: telemetry::counter_value(Counter::ServeBatches),
                coalesced: telemetry::counter_value(Counter::ServeCoalesced),
                msm_flushes: telemetry::counter_value(Counter::MsmFlushes),
                p50_ns: lat.p50,
                p95_ns: lat.p95,
                wall_s,
            });
            server.shutdown();
        }
        telemetry::set_enabled(false);
        telemetry::reset();
        Ok(rows)
    })
}

// ---------------------------------------------------------------------------
// CLI driver: run until SIGINT/SIGTERM, then drain
// ---------------------------------------------------------------------------

/// The `zkdl serve` entry point: spawn the daemon, print the bound address,
/// block until SIGINT/SIGTERM (self-pipe), then drain and report.
pub fn run(cfg: ServeConfig) -> Result<()> {
    telemetry::set_enabled(true);
    let server = Server::spawn(cfg)?;
    println!("zkServe listening on {}", server.addr());
    signal::wait_for_shutdown()?;
    eprintln!("zkServe: shutdown signal received, draining queue…");
    let stats = server.shutdown();
    println!("zkServe drained: {stats}");
    Ok(())
}

#[cfg(unix)]
mod signal {
    //! SIGINT/SIGTERM via the classic self-pipe trick, with no libc crate:
    //! the handler (async-signal-safe: one `write(2)`) pokes a pipe the
    //! main thread blocks on. Declared `extern "C"` directly — the three
    //! symbols are POSIX and already linked into every binary.
    use anyhow::{ensure, Result};
    use std::sync::atomic::{AtomicI32, Ordering};

    static WRITE_FD: AtomicI32 = AtomicI32::new(-1);

    extern "C" {
        fn pipe(fds: *mut i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_sig: i32) {
        let fd = WRITE_FD.load(Ordering::Relaxed);
        if fd >= 0 {
            let byte = [1u8];
            unsafe { write(fd, byte.as_ptr(), 1) };
        }
    }

    /// Install handlers and block until the first SIGINT/SIGTERM.
    pub fn wait_for_shutdown() -> Result<()> {
        let mut fds = [0i32; 2];
        ensure!(
            unsafe { pipe(fds.as_mut_ptr()) } == 0,
            "serve: pipe(2) failed"
        );
        WRITE_FD.store(fds[1], Ordering::SeqCst);
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
        let mut byte = [0u8; 1];
        loop {
            let n = unsafe { read(fds[0], byte.as_mut_ptr(), 1) };
            if n == 1 {
                return Ok(());
            }
            // EINTR (or a spurious zero): retry; the pipe's write end is
            // process-owned, so a permanent failure is not reachable
            if n == 0 {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        }
    }
}

#[cfg(not(unix))]
mod signal {
    use anyhow::Result;

    /// No self-pipe without POSIX signals: park until the process is
    /// killed. The daemon still drains cleanly under [`super::Server`]
    /// (tests and embedders call `shutdown()` directly).
    pub fn wait_for_shutdown() -> Result<()> {
        loop {
            std::thread::park();
        }
    }
}
