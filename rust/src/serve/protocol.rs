//! zkServe framed protocol — `zkdl/serve/v1`.
//!
//! Every frame is `magic "ZKSV"` ‖ `version u16 LE` ‖ `frame-type u16 LE` ‖
//! `payload-len u32 LE` ‖ payload. The length is checked against
//! [`MAX_FRAME_PAYLOAD`] **before** any payload allocation, so an adversarial
//! header cannot make the daemon reserve gigabytes; the payload cap equals
//! the artifact cap ([`crate::wire::MAX_ARTIFACT_BYTES`]) because a `submit`
//! payload *is* one artifact in the existing wire encoding.
//!
//! Client → server frames: [`Frame::Submit`] (one trace artifact),
//! [`Frame::Status`]. Server → client frames: [`Frame::Accepted`],
//! [`Frame::Rejected`] (typed
//! [`VerifyFailureClass`](crate::telemetry::failure::VerifyFailureClass)
//! name + rendered error), [`Frame::Overloaded`] (admission queue full —
//! back off and retry), [`Frame::ShuttingDown`] (drain in progress — retry
//! elsewhere), and [`Frame::StatusReport`] (JSON counters + histograms).
//!
//! The codec is transport-agnostic (`io::Read`/`io::Write`), so the same
//! functions drive the daemon's sockets, the submit client, and the
//! loopback tests.

use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Frame magic — distinct from the artifact magic `"ZKDL"` so a proof file
/// piped at the socket is rejected as a framing error, not misparsed.
pub const FRAME_MAGIC: [u8; 4] = *b"ZKSV";

/// Protocol version (`zkdl/serve/v1`). Bump on any frame-layout change.
pub const PROTOCOL_VERSION: u16 = 1;

/// Hard payload ceiling, enforced before allocation. A submit payload is
/// one wire artifact, so the caps coincide.
pub const MAX_FRAME_PAYLOAD: usize = crate::wire::MAX_ARTIFACT_BYTES;

/// Fixed frame-header length: magic ‖ version ‖ type ‖ payload length.
pub const HEADER_BYTES: usize = 4 + 2 + 2 + 4;

const TYPE_SUBMIT: u16 = 1;
const TYPE_STATUS: u16 = 2;
const TYPE_ACCEPTED: u16 = 3;
const TYPE_REJECTED: u16 = 4;
const TYPE_OVERLOADED: u16 = 5;
const TYPE_SHUTTING_DOWN: u16 = 6;
const TYPE_STATUS_REPORT: u16 = 7;

/// One protocol frame, either direction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Frame {
    /// A trace artifact in the existing wire encoding.
    Submit(Vec<u8>),
    /// Request a [`Frame::StatusReport`].
    Status,
    /// The artifact verified (possibly as part of a coalesced batch).
    Accepted,
    /// The artifact was refused; `class` is the kebab-case failure class
    /// when one was attributed.
    Rejected {
        class: Option<String>,
        message: String,
    },
    /// Admission queue full — backpressure, not failure. Retry later.
    Overloaded,
    /// The daemon is draining; no new work is admitted.
    ShuttingDown,
    /// JSON status document (serve counters, latency histograms, queue).
    StatusReport(String),
}

impl Frame {
    fn type_tag(&self) -> u16 {
        match self {
            Frame::Submit(_) => TYPE_SUBMIT,
            Frame::Status => TYPE_STATUS,
            Frame::Accepted => TYPE_ACCEPTED,
            Frame::Rejected { .. } => TYPE_REJECTED,
            Frame::Overloaded => TYPE_OVERLOADED,
            Frame::ShuttingDown => TYPE_SHUTTING_DOWN,
            Frame::StatusReport(_) => TYPE_STATUS_REPORT,
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            Frame::Submit(bytes) => bytes.clone(),
            Frame::Status | Frame::Accepted | Frame::Overloaded | Frame::ShuttingDown => {
                Vec::new()
            }
            Frame::Rejected { class, message } => {
                let mut out = Vec::new();
                match class {
                    None => out.push(0),
                    Some(c) => {
                        out.push(1);
                        put_str(&mut out, c);
                    }
                }
                put_str(&mut out, message);
                out
            }
            Frame::StatusReport(json) => json.as_bytes().to_vec(),
        }
    }

    fn from_parts(tag: u16, payload: Vec<u8>) -> Result<Frame> {
        match tag {
            TYPE_SUBMIT => Ok(Frame::Submit(payload)),
            TYPE_STATUS => {
                ensure!(payload.is_empty(), "serve: status frame carries a payload");
                Ok(Frame::Status)
            }
            TYPE_ACCEPTED => {
                ensure!(payload.is_empty(), "serve: accepted frame carries a payload");
                Ok(Frame::Accepted)
            }
            TYPE_REJECTED => {
                let mut r = crate::wire::WireReader::new(&payload);
                let class = match r.get_u8()? {
                    0 => None,
                    1 => Some(get_str(&mut r)?),
                    other => bail!("serve: bad class tag {other}"),
                };
                let message = get_str(&mut r)?;
                r.expect_end()?;
                Ok(Frame::Rejected { class, message })
            }
            TYPE_OVERLOADED => {
                ensure!(payload.is_empty(), "serve: overloaded frame carries a payload");
                Ok(Frame::Overloaded)
            }
            TYPE_SHUTTING_DOWN => {
                ensure!(payload.is_empty(), "serve: shutting-down frame carries a payload");
                Ok(Frame::ShuttingDown)
            }
            TYPE_STATUS_REPORT => Ok(Frame::StatusReport(
                String::from_utf8(payload).context("serve: status report is not UTF-8")?,
            )),
            other => bail!("serve: unknown frame type {other}"),
        }
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(r: &mut crate::wire::WireReader) -> Result<String> {
    let n = r.get_len()?;
    String::from_utf8(r.get_raw(n)?.to_vec()).context("serve: non-UTF-8 string")
}

/// Serialize one frame onto `w`.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let payload = frame.payload();
    ensure!(
        payload.len() <= MAX_FRAME_PAYLOAD,
        "serve: frame payload of {} bytes exceeds the {MAX_FRAME_PAYLOAD}-byte cap",
        payload.len()
    );
    let mut header = [0u8; HEADER_BYTES];
    header[..4].copy_from_slice(&FRAME_MAGIC);
    header[4..6].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    header[6..8].copy_from_slice(&frame.type_tag().to_le_bytes());
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header).context("serve: writing frame header")?;
    w.write_all(&payload).context("serve: writing frame payload")?;
    w.flush().context("serve: flushing frame")?;
    Ok(())
}

/// What [`read_frame`] saw at the front of the stream.
pub enum ReadOutcome {
    Frame(Frame),
    /// The peer closed the connection cleanly (EOF before any header byte).
    Eof,
    /// The read timed out before any header byte arrived (idle poll tick —
    /// not an error; the caller re-checks shutdown and retries).
    Idle,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read exactly `buf.len()` bytes; `Ok(false)` on EOF-before-first-byte,
/// distinguishing a closed peer from a truncated frame.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "truncated frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) && filled == 0 => {
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, e));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one frame with bounded allocation: the header is validated (magic,
/// version, payload cap) before the payload buffer is ever reserved, and the
/// payload is streamed into it in place. Timeouts before the first header
/// byte surface as [`ReadOutcome::Idle`]; a timeout *inside* a frame is a
/// hard error (half-written frames poison the stream).
pub fn read_frame(r: &mut impl Read) -> Result<ReadOutcome> {
    let mut header = [0u8; HEADER_BYTES];
    match read_exact_or_eof(r, &mut header) {
        Ok(false) => return Ok(ReadOutcome::Eof),
        Ok(true) => {}
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(ReadOutcome::Idle),
        Err(e) => return Err(anyhow::Error::new(e).context("serve: reading frame header")),
    }
    ensure!(header[..4] == FRAME_MAGIC, "serve: bad frame magic");
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    ensure!(
        version == PROTOCOL_VERSION,
        "serve: unsupported protocol version {version}"
    );
    let tag = u16::from_le_bytes(header[6..8].try_into().unwrap());
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap()) as usize;
    ensure!(
        len <= MAX_FRAME_PAYLOAD,
        "serve: frame payload of {len} bytes exceeds the {MAX_FRAME_PAYLOAD}-byte cap"
    );
    let mut payload = vec![0u8; len];
    match read_exact_or_eof(r, &mut payload) {
        Ok(true) => {}
        Ok(false) if len == 0 => {}
        Ok(false) => bail!("serve: truncated frame payload"),
        Err(e) => return Err(anyhow::Error::new(e).context("serve: reading frame payload")),
    }
    Ok(ReadOutcome::Frame(Frame::from_parts(tag, payload)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        match read_frame(&mut cursor).unwrap() {
            ReadOutcome::Frame(back) => assert_eq!(back, frame),
            _ => panic!("expected a frame"),
        }
    }

    #[test]
    fn frames_roundtrip() {
        roundtrip(Frame::Submit(vec![1, 2, 3]));
        roundtrip(Frame::Submit(Vec::new()));
        roundtrip(Frame::Status);
        roundtrip(Frame::Accepted);
        roundtrip(Frame::Rejected {
            class: Some("sumcheck".into()),
            message: "round consistency".into(),
        });
        roundtrip(Frame::Rejected {
            class: None,
            message: "overlong".into(),
        });
        roundtrip(Frame::Overloaded);
        roundtrip(Frame::ShuttingDown);
        roundtrip(Frame::StatusReport("{\"ok\":true}".into()));
    }

    #[test]
    fn rejects_bad_magic_version_and_oversize() {
        // garbage magic
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Status).unwrap();
        buf[0] = b'X';
        assert!(read_frame(&mut std::io::Cursor::new(buf.clone())).is_err());
        // wrong version
        buf[0] = b'Z';
        buf[4] = 99;
        assert!(read_frame(&mut std::io::Cursor::new(buf.clone())).is_err());
        // oversized length header is rejected before allocation
        let mut huge = Vec::new();
        write_frame(&mut huge, &Frame::Status).unwrap();
        huge[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut std::io::Cursor::new(huge)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
    }

    #[test]
    fn eof_and_truncation_are_distinguished() {
        // empty stream: clean EOF
        match read_frame(&mut std::io::Cursor::new(Vec::<u8>::new())).unwrap() {
            ReadOutcome::Eof => {}
            _ => panic!("expected EOF"),
        }
        // header cut short: error, not EOF
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Submit(vec![7; 16])).unwrap();
        assert!(read_frame(&mut std::io::Cursor::new(buf[..6].to_vec())).is_err());
        // payload cut short: error
        let cut = buf[..buf.len() - 4].to_vec();
        assert!(read_frame(&mut std::io::Cursor::new(cut)).is_err());
    }

    #[test]
    fn artifact_magic_is_a_framing_error() {
        // a raw proof artifact piped at the socket must fail on magic
        let mut buf = b"ZKDL".to_vec();
        buf.extend_from_slice(&[0u8; 32]);
        assert!(read_frame(&mut std::io::Cursor::new(buf)).is_err());
    }
}
