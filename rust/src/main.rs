//! zkdl — CLI for the zkDL proving system.
//!
//! Subcommands:
//!   prove        prove + verify one training step (optionally persist it)
//!   train        proven training run (loss curve + per-step proof metrics)
//!   prove-trace  aggregate T training steps into one FAC4DNN trace proof;
//!                `--chained` adds the zkOptim update-chain argument;
//!                `--optimizer {sgd,momentum}` picks the proven update
//!                rule and `--lr-schedule {N,const:N,decay:b,p,m}` the
//!                per-step learning-rate shifts; `--provenance` commits
//!                the dataset once and binds every step's batch to it
//!                (the printed root is the endorsable Appendix-B statement)
//!   verify-trace re-read persisted trace proofs and verify out-of-process;
//!                multiple `--in` files batch into ONE MSM with a per-proof
//!                outcome report; `--expect-root <hex>` additionally pins
//!                provenance artifacts to an endorsed dataset root;
//!                `--require-same-root` rejects batches whose provenance
//!                artifacts pin different roots
//!   serve        run the zkServe batching verifier daemon: accepts framed
//!                trace artifacts over TCP, coalesces concurrent
//!                submissions into ONE MSM per dataset-root shard, and
//!                drains gracefully on SIGINT; `--addr`, `--max-batch`,
//!                `--max-wait-ms`, `--queue-cap`, `--journal`
//!   submit       send artifacts to a running daemon (`--in <path>`,
//!                repeatable); exit 0 iff every one is accepted;
//!                `--status` prints the daemon's counters/latency JSON
//!   audit        parse a zkFlight journal (`--journal <path>`), filter by
//!                `--verb/--outcome/--class/--root`, skip records before
//!                `--since <seq>`, keep only the last `--tail <n>`, and
//!                summarize
//!   membership   build the Merkle tree and answer (non-)membership queries
//!   bench        run the prove/verify grid (T × depth × variant) and write
//!                a `BENCH_*.json` baseline; `--quick` runs one cheap cell;
//!                `--compare <old.json>` prints a per-cell delta table
//!                against a previously recorded baseline; `--serve` appends
//!                a loopback daemon axis (round-trip latency + coalesced
//!                MSM counts at `--serve-clients 1,8,32`)
//!   info         print configuration and environment
//!
//! Every verb accepts `--profile`: telemetry (zkObs) records a span tree,
//! proof-system counters, and latency histograms during the run and prints
//! the profile after the verb completes. Without `--profile`, telemetry
//! stays disabled (one relaxed atomic load per instrumentation site).
//!
//! zkFlight flight-recorder flags (each implies telemetry on):
//!   --journal <path>      append one `zkdl/events/v1` JSONL record per
//!                         artifact: verb, outcome, typed failure class on
//!                         rejection, digest, dataset root, counter deltas
//!   --trace-out <path>    write a Chrome trace-event JSON timeline of the
//!                         invocation's spans (load in ui.perfetto.dev)
//!   --profile-out <path>  write the zkObs report as JSON to a file
//!
//! Example:
//!   zkdl prove --depth 2 --width 64 --batch 16 --mode parallel --out step.zkp
//!   zkdl train --depth 3 --width 64 --batch 16 --steps 50 --prove-every 10
//!   zkdl prove-trace --depth 2 --width 16 --batch 8 --steps 16 --out trace.zkp
//!   zkdl prove-trace --chained --depth 2 --width 16 --batch 8 --steps 4
//!   zkdl prove-trace --chained --optimizer momentum --lr-schedule decay:8,2,12 --steps 4
//!   zkdl prove-trace --provenance --depth 2 --width 16 --batch 8 --steps 4 --data-n 64
//!   zkdl verify-trace --in trace.zkp
//!   zkdl verify-trace --profile --in trace.zkp
//!   zkdl verify-trace --in a.zkp --in b.zkp --in c.zkp --require-same-root
//!   zkdl verify-trace --in trace.zkp --journal flight.jsonl --trace-out trace.perfetto.json
//!   zkdl serve --addr 127.0.0.1:9155 --max-batch 16 --journal serve.jsonl
//!   zkdl submit --in trace.zkp --addr 127.0.0.1:9155
//!   zkdl submit --addr 127.0.0.1:9155 --status
//!   zkdl audit --journal flight.jsonl --outcome rejected --class sumcheck
//!   zkdl audit --journal serve.jsonl --since 1000 --tail 50
//!   zkdl membership --n 1000 --queries 100 --hash sha256 --positivity 0.5
//!   zkdl bench
//!   zkdl bench --quick --out BENCH_ci.json
//!   zkdl bench --compare BENCH_trace_seed.json

use anyhow::{Context, Result};
use std::path::Path;
use zkdl::aggregate::{
    prove_trace, trace_dataset_root, verify_trace, verify_traces_batch_report, ensure_same_root,
    TraceKey, TraceProof,
};
use zkdl::coordinator::{train_and_prove, train_and_prove_trace, TraceTrainOptions, TrainOptions};
use zkdl::data::Dataset;
use zkdl::hash::HashFn;
use zkdl::merkle::{verify_membership, MerkleTree};
use zkdl::model::{ModelConfig, Weights};
use zkdl::runtime::WitnessSource;
use zkdl::telemetry::failure::{classified, failure_class, VerifyFailureClass};
use zkdl::telemetry::journal::{artifact_digest, read_journal_since, Journal, JournalEvent};
use zkdl::update::{LrSchedule, UpdateRule};
use zkdl::util::bench::Table;
use zkdl::util::cli::Cli;
use zkdl::util::rng::Rng;
use zkdl::zkdl::{prove_step, verify_step, ProofMode, ProverKey};

fn model_config(cli: &Cli) -> ModelConfig {
    ModelConfig::new(
        cli.get_usize("depth", 2),
        cli.get_usize("width", 64),
        cli.get_usize("batch", 16),
    )
}

fn proof_mode(cli: &Cli) -> ProofMode {
    match cli.get_str("mode", "parallel") {
        "sequential" => ProofMode::Sequential,
        _ => ProofMode::Parallel,
    }
}

fn hex_encode(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn hex_decode(s: &str) -> Result<Vec<u8>> {
    // byte-offset slicing below would panic mid-char on non-ASCII input
    anyhow::ensure!(s.is_ascii(), "hex string must be ASCII");
    anyhow::ensure!(s.len() % 2 == 0, "odd-length hex string");
    (0..s.len() / 2)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .with_context(|| format!("bad hex at byte {i}"))
        })
        .collect()
}

/// Per-invocation zkFlight state: the open journal (when `--journal` was
/// given) plus the counter snapshot and clock that every record's
/// invocation-wide delta and duration are computed against.
struct Flight {
    journal: Option<Journal>,
    before: [u64; zkdl::telemetry::Counter::COUNT],
    start: std::time::Instant,
}

impl Flight {
    fn open(cli: &Cli) -> Result<Flight> {
        let journal = cli
            .get("journal")
            .map(|p| Journal::open(Path::new(p)))
            .transpose()?;
        Ok(Flight {
            journal,
            before: zkdl::telemetry::counters_snapshot(),
            start: std::time::Instant::now(),
        })
    }

    /// Stamp invocation duration + counter deltas and append. No-op when no
    /// journal is open.
    fn record(&mut self, mut event: JournalEvent) -> Result<()> {
        if let Some(j) = &mut self.journal {
            event.duration_s = self.start.elapsed().as_secs_f64();
            let after = zkdl::telemetry::counters_snapshot();
            event.counters = zkdl::telemetry::journal::counter_deltas(&after, &self.before);
            j.append(event)?;
        }
        Ok(())
    }
}

/// The envelope version an artifact claims (0 when the magic is absent) —
/// journaled even for artifacts the decoder rejects.
fn artifact_wire_version(bytes: &[u8]) -> u64 {
    if bytes.len() >= 6 && bytes[0..4] == zkdl::wire::MAGIC {
        u16::from_le_bytes([bytes[4], bytes[5]]) as u64
    } else {
        0
    }
}

fn cmd_prove(cli: &Cli) -> Result<()> {
    let mut flight = Flight::open(cli)?;
    let cfg = model_config(cli);
    let mode = proof_mode(cli);
    let mut rng = Rng::seed_from_u64(cli.get_u64("seed", 1));
    println!(
        "proving one training step: L={} d={} B={} ({} mode, {} params)",
        cfg.depth,
        cfg.width,
        cfg.batch,
        mode.name(),
        cfg.param_count()
    );
    let ds = Dataset::synthetic(256, cfg.width.min(512), 10, cfg.r_bits, 3);
    let (x, y) = ds.batch(&cfg, 0);
    let w = Weights::init(cfg, &mut rng);
    let src = WitnessSource::auto(Path::new("artifacts"), cfg);
    let t = std::time::Instant::now();
    let wit = src.compute_witness(&x, &y, &w)?;
    println!(
        "witness ({}) in {:.1} ms",
        src.name(),
        t.elapsed().as_secs_f64() * 1e3
    );
    let t = std::time::Instant::now();
    let pk = ProverKey::setup(cfg);
    println!("key setup in {:.1} ms", t.elapsed().as_secs_f64() * 1e3);
    let t = std::time::Instant::now();
    let proof = prove_step(&pk, &wit, mode, &mut rng);
    let prove_s = t.elapsed().as_secs_f64();
    let t = std::time::Instant::now();
    verify_step(&pk, &proof)?;
    println!(
        "prove {:.3} s | verify {:.3} s | proof {:.1} kB",
        prove_s,
        t.elapsed().as_secs_f64(),
        proof.size_bytes() as f64 / 1024.0
    );
    let mut ev = JournalEvent::new("prove", "proved");
    ev.wire_version = zkdl::wire::VERSION as u64;
    if let Some(path) = cli.get("out") {
        let bytes = zkdl::wire::encode_step_proof(&cfg, &proof);
        std::fs::write(path, &bytes)?;
        println!("wrote {path} ({} wire bytes)", bytes.len());
        ev.artifact_bytes = bytes.len() as u64;
        ev.artifact_sha256 = Some(artifact_digest(&bytes));
    }
    flight.record(ev)?;
    Ok(())
}

fn cmd_prove_trace(cli: &Cli) -> Result<()> {
    let mut flight = Flight::open(cli)?;
    let cfg = model_config(cli);
    let steps = cli.get_usize("steps", 8);
    let out = cli.get("out").unwrap_or("trace.zkp");
    let rule = match cli.get_str("optimizer", "sgd") {
        "sgd" => UpdateRule::Sgd,
        "momentum" => UpdateRule::momentum_default(),
        other => anyhow::bail!("unknown optimizer {other:?} (want sgd or momentum)"),
    };
    let lr_schedule = cli
        .get("lr-schedule")
        .map(LrSchedule::parse)
        .transpose()
        .context("parsing --lr-schedule")?;
    let opts = TraceTrainOptions {
        steps,
        window: cli.get_usize("window", 0), // 0 = one window over the run
        seed: cli.get_u64("seed", 1),
        skip_verify: cli.flag("skip-verify"),
        chained: cli.flag("chained"),
        rule,
        lr_schedule,
        provenance: cli.flag("provenance"),
        pipeline_depth: cli.get_usize("pipeline-depth", 2),
    };
    println!(
        "aggregating {steps} training steps: L={} d={} B={} optimizer={}{}{}{}",
        cfg.depth,
        cfg.width,
        cfg.batch,
        rule.name(),
        match lr_schedule {
            Some(LrSchedule::StepDecay { base, period, max }) =>
                format!(" lr=2^-{base}→2^-{max} (decay every {period})"),
            Some(LrSchedule::Constant(s)) => format!(" lr=2^-{s}"),
            None => format!(" lr=2^-{}", cfg.lr_shift),
        },
        if opts.chained { " (zkOptim chained)" } else { "" },
        if opts.provenance { " (zkData provenance)" } else { "" }
    );
    let ds = synthetic_dataset(cli, &cfg);
    let report = train_and_prove_trace(cfg, &ds, Path::new("artifacts"), &opts)?;
    println!("{}", report.summary());
    if let Some(root) = &report.dataset_root {
        println!(
            "dataset: {} rows committed, endorsable root {}",
            ds.len(),
            hex_encode(root)
        );
    }
    let n_windows = report.proofs.len();
    for (i, (w, proof)) in report.windows.iter().zip(report.proofs.iter()).enumerate() {
        let path = if n_windows == 1 {
            out.to_string()
        } else {
            format!("{out}.{i}")
        };
        let bytes = zkdl::wire::encode_trace_proof(&cfg, proof);
        std::fs::write(&path, &bytes)?;
        println!(
            "window {i}: steps {}..{} → {path} ({} wire bytes, {} proof bytes)",
            w.start_step,
            w.start_step + w.steps,
            bytes.len(),
            w.proof_bytes
        );
        let mut ev = JournalEvent::new("prove-trace", "proved");
        ev.wire_version = zkdl::wire::VERSION as u64;
        ev.artifact_bytes = bytes.len() as u64;
        ev.artifact_sha256 = Some(artifact_digest(&bytes));
        ev.rule = proof.chain.as_ref().map(|c| c.rule.name().to_string());
        ev.dataset_root = trace_dataset_root(proof).map(|r| hex_encode(&r));
        if n_windows > 1 {
            ev.batch_index = Some(i as u64);
            ev.batch_size = Some(n_windows as u64);
        }
        flight.record(ev)?;
    }
    Ok(())
}

/// Pin a provenance artifact to an endorsed root. Failures (no provenance
/// payload, or a different root) carry the `root-mismatch` class.
fn check_expected_root(path: &str, proof: &TraceProof, root: &[u8]) -> Result<()> {
    let Some(got) = trace_dataset_root(proof) else {
        return Err(classified(
            VerifyFailureClass::RootMismatch,
            anyhow::anyhow!("{path}: --expect-root given but artifact has no provenance"),
        ));
    };
    if got != root {
        return Err(classified(
            VerifyFailureClass::RootMismatch,
            anyhow::anyhow!("{path}: dataset root does not match the endorsed root"),
        ));
    }
    Ok(())
}

fn cmd_verify_trace(cli: &Cli) -> Result<()> {
    let mut flight = Flight::open(cli)?;
    let mut paths: Vec<String> = cli.get_all("in").iter().map(|s| s.to_string()).collect();
    paths.extend(cli.positional.iter().cloned());
    if paths.is_empty() {
        paths.push("trace.zkp".to_string());
    }
    let expect_root = cli
        .get("expect-root")
        .map(hex_decode)
        .transpose()
        .context("parsing --expect-root")?;

    // journal a rejection for artifact `idx` (or all of them when None),
    // then surface the error
    let reject = |flight: &mut Flight,
                      metas: &[(String, u64, String, u64)],
                      idx: Option<usize>,
                      e: &anyhow::Error|
     -> Result<()> {
        let class = failure_class(e).map(|c| c.name().to_string());
        for (i, (_, bytes, sha, ver)) in metas.iter().enumerate() {
            if idx.is_some_and(|want| want != i) {
                continue;
            }
            let mut ev = JournalEvent::new("verify-trace", "rejected");
            ev.wire_version = *ver;
            ev.artifact_bytes = *bytes;
            ev.artifact_sha256 = Some(sha.clone());
            ev.failure_class = class.clone();
            flight.record(ev)?;
        }
        Ok(())
    };

    let mut decoded: Vec<TraceProof> = Vec::with_capacity(paths.len());
    let mut keys: Vec<TraceKey> = Vec::with_capacity(paths.len());
    // (path, wire bytes, sha256, claimed wire version) per artifact
    let mut metas: Vec<(String, u64, String, u64)> = Vec::with_capacity(paths.len());
    for path in &paths {
        // read_artifact refuses oversized files by stat before reading —
        // the same MAX_ARTIFACT_BYTES guard the decoder and daemon apply
        let bytes = match zkdl::wire::read_artifact(Path::new(path)) {
            Ok(b) => b,
            Err(e) => {
                let e = e.context(format!("reading {path}"));
                if let Some(class) = failure_class(&e) {
                    let mut ev = JournalEvent::new("verify-trace", "rejected");
                    ev.artifact_bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                    ev.failure_class = Some(class.name().to_string());
                    flight.record(ev)?;
                }
                return Err(e);
            }
        };
        metas.push((
            path.clone(),
            bytes.len() as u64,
            artifact_digest(&bytes),
            artifact_wire_version(&bytes),
        ));
        let (cfg, proof) = match zkdl::wire::decode_trace_proof(&bytes) {
            Ok(v) => v,
            Err(e) => {
                let e = e.context(format!("decoding {path}"));
                reject(&mut flight, &metas, Some(metas.len() - 1), &e)?;
                return Err(e);
            }
        };
        println!(
            "{path}: {} steps{}{}, L={} d={} B={}, {} wire bytes",
            proof.steps,
            match &proof.chain {
                Some(chain) => format!(" (chained, {})", chain.rule.name()),
                None => String::new(),
            },
            match &proof.provenance {
                Some(prov) => format!(
                    " (provenance: {} rows, root {})",
                    prov.dataset.n_rows,
                    hex_encode(&prov.dataset.root)
                ),
                None => String::new(),
            },
            cfg.depth,
            cfg.width,
            cfg.batch,
            bytes.len()
        );
        if let Some(root) = &expect_root {
            if let Err(e) = check_expected_root(path, &proof, root) {
                reject(&mut flight, &metas, Some(metas.len() - 1), &e)?;
                return Err(e);
            }
        }
        keys.push(TraceKey::setup(cfg, proof.steps));
        decoded.push(proof);
    }

    if cli.flag("require-same-root") {
        let refs: Vec<&TraceProof> = decoded.iter().collect();
        if let Err(e) = ensure_same_root(&refs) {
            reject(&mut flight, &metas, None, &e)?;
            return Err(e);
        }
    }

    let fill = |mut ev: JournalEvent, i: usize| -> JournalEvent {
        let (_, bytes, sha, ver) = &metas[i];
        ev.wire_version = *ver;
        ev.artifact_bytes = *bytes;
        ev.artifact_sha256 = Some(sha.clone());
        ev.rule = decoded[i].chain.as_ref().map(|c| c.rule.name().to_string());
        ev.dataset_root = trace_dataset_root(&decoded[i]).map(|r| hex_encode(&r));
        ev
    };

    let t = std::time::Instant::now();
    if decoded.len() == 1 {
        if let Err(e) = verify_trace(&keys[0], &decoded[0]) {
            let e = e.context("trace verification failed");
            let class = failure_class(&e).map(|c| c.name().to_string());
            let mut ev = fill(JournalEvent::new("verify-trace", "rejected"), 0);
            ev.failure_class = class;
            flight.record(ev)?;
            return Err(e);
        }
        println!("verified in {:.3} s (one MSM)", t.elapsed().as_secs_f64());
        flight.record(fill(JournalEvent::new("verify-trace", "accepted"), 0))?;
    } else {
        let pairs: Vec<(&TraceKey, &TraceProof)> = keys.iter().zip(decoded.iter()).collect();
        let mut rng = Rng::from_entropy();
        let report = verify_traces_batch_report(&pairs, &mut rng);
        let n = decoded.len();
        let mut table = Table::new(&["idx", "path", "root", "outcome", "class"]);
        for entry in &report.entries {
            table.row(vec![
                entry.index.to_string(),
                metas[entry.index].0.clone(),
                entry
                    .root
                    .as_ref()
                    .map(|r| hex_encode(r))
                    .unwrap_or_else(|| "-".to_string()),
                if entry.accepted { "accepted" } else { "rejected" }.to_string(),
                entry
                    .failure_class
                    .map(|c| c.name().to_string())
                    .unwrap_or_else(|| "-".to_string()),
            ]);
        }
        table.print();
        for entry in &report.entries {
            let outcome = if entry.accepted { "accepted" } else { "rejected" };
            let mut ev = fill(JournalEvent::new("verify-trace", outcome), entry.index);
            ev.failure_class = entry.failure_class.map(|c| c.name().to_string());
            ev.batch_index = Some(entry.index as u64);
            ev.batch_size = Some(n as u64);
            flight.record(ev)?;
        }
        if let Some(batch_err) = &report.batch_error {
            anyhow::bail!("batched trace verification failed: {batch_err}");
        }
        println!(
            "batch-verified {n} proofs in {:.3} s (one MSM total)",
            t.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

fn cmd_audit(cli: &Cli) -> Result<()> {
    let default_path = "journal.jsonl".to_string();
    let path = cli
        .get("journal")
        .or_else(|| cli.get("in"))
        .map(|s| s.to_string())
        .or_else(|| cli.positional.first().cloned())
        .unwrap_or(default_path);
    // --since streams past old records without keeping them — a long-lived
    // zkServe journal stays queryable no matter how big it has grown
    let since = cli.get_u64("since", 0);
    let (events, bad) = read_journal_since(Path::new(&path), since)?;
    if let Some(class) = cli.get("class") {
        anyhow::ensure!(
            VerifyFailureClass::parse(class).is_some(),
            "unknown failure class {class:?} (see DESIGN.md §telemetry for the taxonomy)"
        );
    }
    let keep = |ev: &JournalEvent| -> bool {
        cli.get("verb").map_or(true, |v| ev.verb == v)
            && cli.get("outcome").map_or(true, |o| ev.outcome == o)
            && cli
                .get("class")
                .map_or(true, |c| ev.failure_class.as_deref() == Some(c))
            && cli
                .get("root")
                .map_or(true, |r| ev.dataset_root.as_deref() == Some(r))
    };
    let mut filtered: Vec<&JournalEvent> = events.iter().filter(|ev| keep(ev)).collect();
    if let Some(tail) = cli.get("tail") {
        let n: usize = tail
            .parse()
            .with_context(|| format!("parsing --tail {tail:?} (want a record count)"))?;
        if filtered.len() > n {
            filtered.drain(..filtered.len() - n);
        }
    }

    let mut table = Table::new(&["seq", "verb", "outcome", "class", "dur s", "bytes", "root"]);
    for ev in &filtered {
        table.row(vec![
            ev.seq.to_string(),
            ev.verb.clone(),
            ev.outcome.clone(),
            ev.failure_class.clone().unwrap_or_else(|| "-".to_string()),
            format!("{:.3}", ev.duration_s),
            ev.artifact_bytes.to_string(),
            ev.dataset_root
                .as_deref()
                .map(|r| {
                    // roots are 64 hex chars; keep rows narrow
                    if r.len() > 12 {
                        format!("{}…", &r[..12])
                    } else {
                        r.to_string()
                    }
                })
                .unwrap_or_else(|| "-".to_string()),
        ]);
    }
    table.print();

    // verb × outcome summary over the *filtered* set
    let mut counts: Vec<((String, String), u64)> = Vec::new();
    for ev in &filtered {
        let key = (ev.verb.clone(), ev.outcome.clone());
        match counts.iter_mut().find(|(k, _)| *k == key) {
            Some((_, n)) => *n += 1,
            None => counts.push((key, 1)),
        }
    }
    let mut summary = Table::new(&["verb", "outcome", "count"]);
    for ((verb, outcome), n) in &counts {
        summary.row(vec![verb.clone(), outcome.clone(), n.to_string()]);
    }
    println!("-- summary --");
    summary.print();
    let rejected: Vec<&&JournalEvent> = filtered
        .iter()
        .filter(|ev| ev.outcome == "rejected")
        .collect();
    if !rejected.is_empty() {
        let mut by_class: Vec<(String, u64)> = Vec::new();
        for ev in &rejected {
            let class = ev
                .failure_class
                .clone()
                .unwrap_or_else(|| "unclassified".to_string());
            match by_class.iter_mut().find(|(k, _)| *k == class) {
                Some((_, n)) => *n += 1,
                None => by_class.push((class, 1)),
            }
        }
        let mut classes = Table::new(&["failure class", "count"]);
        for (class, n) in &by_class {
            classes.row(vec![class.clone(), n.to_string()]);
        }
        println!("-- rejections by class --");
        classes.print();
    }
    println!(
        "{} records shown ({} filtered out, {} malformed lines) from {path}",
        filtered.len(),
        events.len() - filtered.len(),
        bad
    );
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let cfg = zkdl::serve::ServeConfig {
        addr: cli.get_str("addr", "127.0.0.1:9155").to_string(),
        max_batch: cli.get_usize("max-batch", 16),
        max_wait: std::time::Duration::from_millis(cli.get_u64("max-wait-ms", 50)),
        queue_cap: cli.get_usize("queue-cap", 256),
        poll_interval: std::time::Duration::from_millis(cli.get_u64("poll-ms", 250)),
        write_timeout: std::time::Duration::from_secs(cli.get_u64("write-timeout-s", 10)),
        journal: cli.get("journal").map(std::path::PathBuf::from),
    };
    println!(
        "zkServe: max_batch={} max_wait={}ms queue_cap={}{}",
        cfg.max_batch,
        cfg.max_wait.as_millis(),
        cfg.queue_cap,
        cfg.journal
            .as_ref()
            .map(|p| format!(" journal={}", p.display()))
            .unwrap_or_default()
    );
    zkdl::serve::run(cfg)
}

fn cmd_submit(cli: &Cli) -> Result<()> {
    use zkdl::serve::protocol::Frame;
    let addr = cli.get_str("addr", "127.0.0.1:9155");
    let timeout = std::time::Duration::from_secs_f64(cli.get_f64("timeout-s", 30.0));
    if cli.flag("status") {
        println!("{}", zkdl::serve::status(addr, timeout)?);
        return Ok(());
    }
    let mut paths: Vec<String> = cli.get_all("in").iter().map(|s| s.to_string()).collect();
    paths.extend(cli.positional.iter().cloned());
    anyhow::ensure!(
        !paths.is_empty(),
        "submit needs --in <artifact> (repeatable) or --status"
    );
    let mut refused = 0usize;
    for path in &paths {
        let bytes = zkdl::wire::read_artifact(Path::new(path))?;
        match zkdl::serve::submit(addr, &bytes, timeout)? {
            Frame::Accepted => println!("{path}: accepted"),
            Frame::Rejected { class, message } => {
                eprintln!(
                    "{path}: rejected ({}): {message}",
                    class.as_deref().unwrap_or("unclassified")
                );
                refused += 1;
            }
            Frame::Overloaded => {
                eprintln!("{path}: overloaded — daemon queue is full, back off and retry");
                refused += 1;
            }
            Frame::ShuttingDown => {
                eprintln!("{path}: daemon is shutting down");
                refused += 1;
            }
            other => {
                eprintln!("{path}: unexpected reply {other:?}");
                refused += 1;
            }
        }
    }
    anyhow::ensure!(refused == 0, "{refused} submission(s) not accepted");
    Ok(())
}

/// Shared synthetic-dataset recipe for the training verbs.
fn synthetic_dataset(cli: &Cli, cfg: &ModelConfig) -> Dataset {
    Dataset::synthetic(
        cli.get_usize("data-n", 1024),
        cfg.width.min(512),
        10,
        cfg.r_bits,
        3,
    )
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let cfg = model_config(cli);
    let opts = TrainOptions {
        steps: cli.get_usize("steps", 20),
        prove_every: cli.get_usize("prove-every", 5),
        mode: proof_mode(cli),
        seed: cli.get_u64("seed", 1),
        skip_verify: cli.flag("skip-verify"),
        pipeline_depth: cli.get_usize("pipeline-depth", 2),
    };
    let ds = synthetic_dataset(cli, &cfg);
    let report = train_and_prove(cfg, &ds, Path::new("artifacts"), &opts)?;
    println!("{}", report.summary());
    if let Some(path) = cli.get("csv") {
        std::fs::write(path, report.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_membership(cli: &Cli) -> Result<()> {
    let n = cli.get_usize("n", 1000);
    let n_queries = cli.get_usize("queries", 100);
    let positivity = cli.get_f64("positivity", 0.5);
    let hash = HashFn::parse(cli.get_str("hash", "sha256")).expect("md5|sha1|sha256");
    let mut rng = Rng::seed_from_u64(cli.get_u64("seed", 1));

    // deterministic per-point Pedersen commitments (paper §3.1, r = 0),
    // leaf-encoded with the canonical 32-byte compressed-point codec
    let dim = cli.get_usize("dim", 64);
    let ck = zkdl::commit::CommitKey::setup(b"zkdl/data", dim);
    let ds = Dataset::synthetic(n, dim, 10, 16, 9);
    let t = std::time::Instant::now();
    let coms: Vec<Vec<u8>> = ds
        .points
        .iter()
        .map(|p| {
            let frs: Vec<zkdl::Fr> = p.iter().map(|&v| zkdl::Fr::from_i64(v)).collect();
            zkdl::merkle::point_leaf(&ck.commit_deterministic(&frs).to_affine())
        })
        .collect();
    println!("committed {n} points in {:.2} s", t.elapsed().as_secs_f64());
    let t = std::time::Instant::now();
    let tree = MerkleTree::build(hash, &coms);
    println!(
        "tree ({}) built in {:.2} s",
        hash.name(),
        t.elapsed().as_secs_f64()
    );

    let n_pos = (n_queries as f64 * positivity).round() as usize;
    let mut queries: Vec<Vec<u8>> = coms[..n_pos.min(n)].iter().map(|c| hash.hash(c)).collect();
    while queries.len() < n_queries {
        let mut fake = vec![0u8; 64];
        rng.fill_bytes(&mut fake);
        queries.push(hash.hash(&fake));
    }
    let t = std::time::Instant::now();
    let proof = tree.prove(&queries);
    let prove_ms = t.elapsed().as_secs_f64() * 1e3;
    let t = std::time::Instant::now();
    verify_membership(hash, &tree.root, &queries, &proof)?;
    println!(
        "queries={} positivity={:.1} | proof {} hashes | prove {:.2} ms | verify {:.2} ms",
        n_queries,
        positivity,
        proof.size_hashes(),
        prove_ms,
        t.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}

fn cmd_bench(cli: &Cli) -> Result<()> {
    use zkdl::telemetry::bench::{run_grid, GridOptions};
    let mut opts = if cli.flag("quick") {
        GridOptions::quick()
    } else {
        GridOptions::full()
    };
    opts.width = cli.get_usize("width", opts.width);
    opts.batch = cli.get_usize("batch", opts.batch);
    opts.data_rows = cli.get_usize("data-n", opts.data_rows);
    opts.seed = cli.get_u64("seed", opts.seed);
    opts.budget =
        std::time::Duration::from_secs_f64(cli.get_f64("budget-s", opts.budget.as_secs_f64()));
    if let Some(list) = cli.get("threads") {
        opts.threads = list
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<Vec<_>, _>>()
            .with_context(|| format!("parsing --threads {list:?} (comma-separated, 0 = auto)"))?;
        if opts.threads.is_empty() {
            anyhow::bail!("--threads needs at least one value (0 = auto)");
        }
    }
    let out = cli.get("out").unwrap_or("BENCH_trace.json");
    println!(
        "bench grid: T={:?} depth={:?} threads={:?} d={} B={} budget {:.0} s ({} lanes auto)",
        opts.steps,
        opts.depths,
        opts.threads,
        opts.width,
        opts.batch,
        opts.budget.as_secs_f64(),
        zkdl::util::threads::num_threads()
    );
    let report = run_grid(&opts);
    print!("{}", report.render_table());
    let mut doc = report.to_json();
    if cli.flag("serve") {
        let rows = bench_serve_rows(cli, &opts)?;
        if let zkdl::telemetry::json::Json::Obj(fields) = &mut doc {
            fields.push((
                "serve".to_string(),
                zkdl::telemetry::json::Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
            ));
        }
    }
    std::fs::write(out, doc.to_string()).with_context(|| format!("writing {out}"))?;
    println!("wrote {out} ({:.1} s total)", report.wall_s);
    if let Some(baseline_path) = cli.get("compare") {
        let text = std::fs::read_to_string(baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?;
        let baseline = zkdl::telemetry::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing baseline {baseline_path}: {e}"))?;
        let delta = report
            .compare_table(&baseline)
            .map_err(|e| anyhow::anyhow!("comparing against {baseline_path}: {e}"))?;
        println!("delta vs {baseline_path} (wall-clock noisy, msm pts exact):");
        print!("{delta}");
    }
    Ok(())
}

/// `zkdl bench --serve`: prove one quick artifact, then measure loopback
/// round-trips and MSM coalescing at each `--serve-clients` count.
fn bench_serve_rows(
    cli: &Cli,
    opts: &zkdl::telemetry::bench::GridOptions,
) -> Result<Vec<zkdl::serve::ServeBenchRow>> {
    let clients: Vec<usize> = cli
        .get("serve-clients")
        .unwrap_or("1,8,32")
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<std::result::Result<Vec<_>, _>>()
        .context("parsing --serve-clients (comma-separated counts)")?;
    anyhow::ensure!(!clients.is_empty(), "--serve-clients needs at least one count");
    let per_client = cli.get_usize("serve-reps", 2);
    let cfg = ModelConfig::new(2, opts.width, opts.batch);
    let ds = Dataset::synthetic(opts.data_rows, cfg.width / 2, 4, cfg.r_bits, opts.seed ^ 0x77);
    let wits = zkdl::witness::native::sgd_witness_chain(cfg, &ds, 1, opts.seed);
    let tk = TraceKey::setup(cfg, 1);
    let mut rng = Rng::seed_from_u64(opts.seed);
    let artifact = zkdl::wire::encode_trace_proof(&cfg, &prove_trace(&tk, &wits, &mut rng));
    eprintln!("bench: serve axis clients={clients:?} ({per_client} submissions each) ...");
    let rows = zkdl::serve::bench_loopback(&artifact, &clients, per_client)?;
    let mut table = Table::new(&[
        "clients", "subs", "accepted", "batches", "coalesced", "msm", "p50 ms", "p95 ms", "wall s",
    ]);
    for r in &rows {
        table.row(vec![
            r.clients.to_string(),
            r.submissions.to_string(),
            r.accepted.to_string(),
            r.batches.to_string(),
            r.coalesced.to_string(),
            r.msm_flushes.to_string(),
            format!("{:.2}", r.p50_ns as f64 / 1e6),
            format!("{:.2}", r.p95_ns as f64 / 1e6),
            format!("{:.2}", r.wall_s),
        ]);
    }
    table.print();
    Ok(rows)
}

fn cmd_info() {
    println!("zkdl — zero-knowledge proofs of deep learning training");
    println!("threads: {}", zkdl::util::threads::num_threads());
    println!("artifacts present: {}", Path::new("artifacts").exists());
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    // zkFlight/zkObs lifecycle: any flight-recorder output implies telemetry
    // on for the invocation. `bench` manages telemetry itself (reset +
    // exclusive), so profiling composes with every verb but reads empty
    // after a bench run.
    let profile = cli.flag("profile");
    let trace_out = cli.get("trace-out").map(|s| s.to_string());
    let profile_out = cli.get("profile-out").map(|s| s.to_string());
    let telemetry_on =
        profile || trace_out.is_some() || profile_out.is_some() || cli.get("journal").is_some();
    if telemetry_on {
        zkdl::telemetry::set_enabled(true);
    }
    if trace_out.is_some() {
        zkdl::telemetry::trace_export::set_recording(true);
        zkdl::telemetry::trace_export::set_thread_name("main");
    }
    let result = match cli.subcommand.as_deref() {
        Some("prove") => cmd_prove(&cli),
        Some("train") => cmd_train(&cli),
        Some("prove-trace") => cmd_prove_trace(&cli),
        Some("verify-trace") => cmd_verify_trace(&cli),
        Some("serve") => cmd_serve(&cli),
        Some("submit") => cmd_submit(&cli),
        Some("audit") => cmd_audit(&cli),
        Some("membership") => cmd_membership(&cli),
        Some("bench") => cmd_bench(&cli),
        Some("info") | None => {
            cmd_info();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand: {other}");
            eprintln!(
                "usage: zkdl [prove|train|prove-trace|verify-trace|serve|submit|audit|membership|bench|info] [--key value]"
            );
            std::process::exit(2);
        }
    };
    // flight-recorder outputs are written even when the verb failed — a
    // rejected verification is exactly the flight worth replaying
    let outputs = (|| -> Result<()> {
        if let Some(path) = &trace_out {
            zkdl::telemetry::trace_export::set_recording(false);
            let doc = zkdl::telemetry::trace_export::export_json();
            std::fs::write(path, doc.to_string()).with_context(|| format!("writing {path}"))?;
            println!(
                "wrote {path} ({} trace events) — load in ui.perfetto.dev",
                zkdl::telemetry::trace_export::event_count()
            );
        }
        if profile || profile_out.is_some() {
            let report = zkdl::telemetry::report();
            if let Some(path) = &profile_out {
                std::fs::write(path, report.to_json().to_string())
                    .with_context(|| format!("writing {path}"))?;
                println!("wrote {path}");
            }
            if profile {
                print!("{}", report.render());
            }
        }
        Ok(())
    })();
    if telemetry_on {
        zkdl::telemetry::set_enabled(false);
    }
    result.and(outputs)
}
