//! FAC4DNN multi-step aggregation (paper §4): one [`TraceProof`] certifies
//! T training steps at once, "without being constrained by their sequential
//! order".
//!
//! Where [`crate::zkdl::prove_step`] batches the per-*layer* claims of one
//! step by random linear combination under shared transcript randomness,
//! this module extends the same construction with a *step* dimension:
//!
//! * the stacked-aux layout gains a step axis ([`trace_stack_dims`]):
//!   slot(t, ℓ) = t·L̄ + ℓ of a T̄·L̄·D basis, so every step's aux
//!   commitments live in mutually disjoint blocks of one basis;
//! * all T·L matmul claims (30)/(33)/(34) share one challenge bundle and
//!   are folded into three sumchecks via γ-powers, exactly as
//!   `ProofMode::Parallel` folds layers;
//! * one stacking sumcheck (27), one batch of opening IPAs, and one
//!   zkReLU validity pair cover the whole trace.
//!
//! Proof size therefore grows as O(T·L) *commitments* (the statement) plus
//! O(log(T·L·D)) *argument* — versus O(T) full arguments for T independent
//! [`crate::zkdl::StepProof`]s. `benches/trace_agg.rs` measures the gap.
//!
//! A plain trace does **not** constrain step t+1's weights to step t's
//! update; [`prove_trace_chained`] closes that gap with the zkSGD chain
//! argument ([`crate::update`]): the rounded learning-rate shift is
//! witnessed by committed remainder tensors whose exact range rides a
//! zkReLU validity instance, turning "T proven steps" into a proof of
//! *training*. See DESIGN.md §aggregate and §update.

use crate::commit::{ComExpr, CommitKey};
use crate::curve::accum::MsmAccumulator;
use crate::curve::{G1, G1Affine};
use crate::field::Fr;
use crate::ipa::{self, EvalClaim, IpaProof};
use crate::model::ModelConfig;
use crate::poly::{self, eq_eval, eq_table, eval_i64_with_eq, Mle};
use crate::provenance::{self, ProvenanceCommitments, ProvenanceKey, ProvenanceProof, ProverDataset};
use crate::sumcheck::{self, Instance, SumcheckProof, Term};
use crate::transcript::Transcript;
use crate::update::{self, ChainProof, LrSchedule, UpdateKey, UpdateRule};
use crate::util::arena::FrArena;
use crate::util::rng::Rng;
use crate::util::threads;
use crate::witness::StepWitness;
use crate::zkdl::{
    self, commit, derived_com_ga, derived_com_gz_last, derived_com_z, derived_expr_ga,
    derived_expr_gz_last, derived_expr_z, derived_open_ga, derived_open_gz_last, derived_open_z,
    draw_group_challenges, frs, tile_claims_at, tiled_eq, Committed, ProverLayers,
};
use crate::telemetry::failure::{classified, failure_class, Classify, VerifyFailureClass};
use crate::telemetry::hist::{self, Hist};
use crate::zkrelu::{self, Protocol1Msg, ValidityBases, ValidityProof};
use anyhow::{Context, Result};

/// First `n` powers of γ (γ⁰..γ^{n−1}), precomputed so parallel γ-folds
/// can index a slot's coefficient by position instead of threading a
/// running product through a sequential loop.
fn gamma_powers(gamma: Fr, n: usize) -> Vec<Fr> {
    let mut out = Vec::with_capacity(n);
    let mut c = Fr::ONE;
    for _ in 0..n {
        out.push(c);
        c *= gamma;
    }
    out
}

/// Padded step count T̄, padded layer count L̄, and the trace-stacked aux
/// size N = T̄·L̄·D. Step t's layer ℓ owns block (t·L̄ + ℓ)·D.
pub fn trace_stack_dims(cfg: &ModelConfig, steps: usize) -> (usize, usize, usize) {
    let lbar = cfg.depth.next_power_of_two();
    let tbar = steps.next_power_of_two();
    (tbar, lbar, tbar * lbar * cfg.d_size())
}

/// Commitment bases sized for a T-step trace of one model configuration.
/// `g_mat`/`g_x` are shared with the per-step [`crate::zkdl::ProverKey`]
/// (same labels); `g_aux` is the step-extended stacked basis.
pub struct TraceKey {
    pub cfg: ModelConfig,
    /// Number of live steps T (T̄ − T trailing slots are padding).
    pub steps: usize,
    /// Trace-stacked aux basis, length T̄·L̄·D.
    pub g_aux: CommitKey,
    /// Weight/weight-gradient basis, length d².
    pub g_mat: CommitKey,
    /// Input basis, length D.
    pub g_x: CommitKey,
}

impl TraceKey {
    pub fn setup(cfg: ModelConfig, steps: usize) -> Self {
        crate::span!("aggregate/key_setup");
        assert!(steps >= 1);
        let (_, _, n) = trace_stack_dims(&cfg, steps);
        let d2 = cfg.width * cfg.width;
        let key = Self {
            cfg,
            steps,
            g_aux: CommitKey::setup(b"zkdl/trace-aux", n),
            g_mat: CommitKey::setup(b"zkdl/mat", d2),
            g_x: CommitKey::setup(b"zkdl/x", cfg.d_size()),
        };
        // fixed-base tables: built here (setup, outside any proved/timed
        // region), hit by every block commit, stacking commit, and IPA
        // round across all T steps
        key.g_aux.warm_table();
        key.g_mat.warm_table();
        key.g_x.warm_table();
        key
    }

    /// Commitment key slice for step t / layer ℓ's aux block. Shares the
    /// stacked basis' fixed-base table via the slice offset.
    pub fn block(&self, t: usize, l: usize) -> CommitKey {
        let d = self.cfg.d_size();
        let lbar = self.cfg.depth.next_power_of_two();
        let s = t * lbar + l;
        self.g_aux.slice(s * d, (s + 1) * d)
    }
}

/// Validity bases for a trace; the label pins (T, L) so two traces with the
/// same padded layout but different live extents never share an instance.
fn trace_validity_bases(
    tk: &TraceKey,
) -> (std::sync::Arc<ValidityBases>, std::sync::Arc<ValidityBases>) {
    let cfg = &tk.cfg;
    let (_, _, n) = trace_stack_dims(cfg, tk.steps);
    let t = tk.steps as u64;
    let l = cfg.depth as u64;
    let main_label = [
        b"zkdl/trace/validity/main/".as_ref(),
        &t.to_le_bytes(),
        &l.to_le_bytes(),
    ]
    .concat();
    let rem_label = [
        b"zkdl/trace/validity/rem/".as_ref(),
        &t.to_le_bytes(),
        &l.to_le_bytes(),
    ]
    .concat();
    let vb_main = ValidityBases::setup_main(&main_label, &tk.g_aux, n, cfg.q_bits as usize);
    let vb_rem = ValidityBases::setup_plain(&rem_label, tk.g_aux.h, n, cfg.r_bits as usize);
    (vb_main, vb_rem)
}

/// One step's commitments inside a trace (same layout as the commitment
/// prefix of a [`crate::zkdl::StepProof`]).
#[derive(Clone, Debug)]
pub struct StepCommitmentSet {
    pub com_w: Vec<G1Affine>,
    pub com_gw: Vec<G1Affine>,
    pub com_zdp: Vec<G1Affine>,
    pub com_sign: Vec<G1Affine>,
    pub com_rz: Vec<G1Affine>,
    pub com_gap: Vec<G1Affine>,
    pub com_rga: Vec<G1Affine>,
    pub com_x: G1Affine,
    pub com_y: G1Affine,
}

/// Aggregated proof of T training steps.
#[derive(Clone, Debug)]
pub struct TraceProof {
    pub steps: usize,
    /// Per-step tensor commitments (the statement), length T.
    pub coms: Vec<StepCommitmentSet>,
    pub p1_main: Protocol1Msg,
    pub p1_rem: Protocol1Msg,
    /// Claimed Z̃ evaluations, step-major: index t·L + ℓ.
    pub v_z: Vec<Fr>,
    /// Claimed G̃_A evaluations over inner layers: index t·(L−1) + ℓ.
    pub v_ga: Vec<Fr>,
    /// Claimed G̃_W evaluations, step-major.
    pub v_gw: Vec<Fr>,
    pub mm30: SumcheckProof,
    pub mm30_evals: Vec<(Fr, Fr)>,
    pub mm33: Option<SumcheckProof>,
    pub mm33_evals: Vec<(Fr, Fr)>,
    pub mm34: SumcheckProof,
    pub mm34_evals: Vec<(Fr, Fr)>,
    /// Trace-wide stacking sumcheck; absent for depth-1 networks.
    pub stack: Option<SumcheckProof>,
    /// Slot claims over T̄·L̄ slots for the four stacking terms.
    pub va1: Vec<Fr>,
    pub va2: Vec<Fr>,
    pub vgz1: Vec<Fr>,
    pub vgz2: Vec<Fr>,
    /// Opened trace-stacked aux evaluations at ρ: (sign, Z″, G_A′, R_Z, R_GA).
    pub aux_evals: [Fr; 5],
    /// Batched opening IPAs in canonical task order.
    pub openings: Vec<IpaProof>,
    pub validity_main: ValidityProof,
    pub validity_rem: ValidityProof,
    /// zkSGD chain argument tying consecutive steps' weights together
    /// ([`prove_trace_chained`]); `None` for a plain trace.
    pub chain: Option<ChainProof>,
    /// zkData batch-provenance argument binding every step's `com_x` and
    /// labels to a committed, endorsable dataset
    /// ([`prove_trace_provenance`]); `None` for an unbound trace.
    pub provenance: Option<ProvenanceProof>,
}

impl StepCommitmentSet {
    fn point_count(&self) -> usize {
        self.com_w.len()
            + self.com_gw.len()
            + self.com_zdp.len()
            + self.com_sign.len()
            + self.com_rz.len()
            + self.com_gap.len()
            + self.com_rga.len()
            + 2
    }
}

impl TraceProof {
    /// Total proof size in bytes (compressed-point accounting, matching
    /// [`crate::zkdl::StepProof::size_bytes`]).
    pub fn size_bytes(&self) -> usize {
        let coms: usize = self.coms.iter().map(|c| c.point_count()).sum();
        let scalars = self.v_z.len()
            + self.v_ga.len()
            + self.v_gw.len()
            + 2 * (self.mm30_evals.len() + self.mm33_evals.len() + self.mm34_evals.len())
            + self.va1.len()
            + self.va2.len()
            + self.vgz1.len()
            + self.vgz2.len()
            + 5;
        let p1 = 32 + 32 + if self.p1_main.com_sign_prime.is_some() { 32 } else { 0 };
        let sumchecks = self.mm30.size_bytes()
            + self.mm33.as_ref().map_or(0, |p| p.size_bytes())
            + self.mm34.size_bytes()
            + self.stack.as_ref().map_or(0, |p| p.size_bytes());
        let openings: usize = self.openings.iter().map(|o| o.size_bytes()).sum();
        (coms + scalars) * 32
            + p1
            + sumchecks
            + openings
            + self.validity_main.size_bytes()
            + self.validity_rem.size_bytes()
            + self.chain.as_ref().map_or(0, |c| c.size_bytes())
            + self.provenance.as_ref().map_or(0, |p| p.size_bytes())
    }
}

/// Prover-side commitments of one step in the trace.
struct TraceStepCommitments {
    w: Vec<Committed>,
    gw: Vec<Committed>,
    zdp: Vec<Committed>,
    sign: Vec<Committed>,
    rz: Vec<Committed>,
    gap: Vec<Committed>,
    rga: Vec<Committed>,
    x: Committed,
    y: Committed,
}

fn commit_trace_step(
    tk: &TraceKey,
    t: usize,
    pl: &ProverLayers,
    rng: &mut Rng,
) -> TraceStepCommitments {
    let depth = tk.cfg.depth;
    let mut w = Vec::new();
    let mut gw = Vec::new();
    let mut zdp = Vec::new();
    let mut sign = Vec::new();
    let mut rz = Vec::new();
    let mut gap = Vec::new();
    let mut rga = Vec::new();
    for l in 0..depth {
        let blk = tk.block(t, l);
        w.push(commit(&tk.g_mat, pl.w[l].data.clone(), rng));
        gw.push(commit(&tk.g_mat, frs(&pl.wit.layers[l].g_w), rng));
        zdp.push(commit(&blk, pl.zdp[l].clone(), rng));
        sign.push(commit(&blk, pl.sign[l].clone(), rng));
        rz.push(commit(&blk, pl.rz[l].clone(), rng));
        gap.push(commit(&blk, pl.gap[l].clone(), rng));
        rga.push(commit(&blk, pl.rga[l].clone(), rng));
    }
    let x = commit(&tk.g_x, pl.x.data.clone(), rng);
    // Y lives in the step's last-layer block (cf. zkdl::commit_step).
    let y = commit(&tk.block(t, depth - 1), frs(&pl.wit.y), rng);
    TraceStepCommitments {
        w,
        gw,
        zdp,
        sign,
        rz,
        gap,
        rga,
        x,
        y,
    }
}

fn absorb_step_commitments(t: &mut Transcript, step: usize, set: &StepCommitmentSet) {
    t.absorb_u64(b"trace/step", step as u64);
    zkdl::absorb_commitments(
        t,
        &[
            (b"com/w", set.com_w.clone()),
            (b"com/gw", set.com_gw.clone()),
            (b"com/zdp", set.com_zdp.clone()),
            (b"com/sign", set.com_sign.clone()),
            (b"com/rz", set.com_rz.clone()),
            (b"com/gap", set.com_gap.clone()),
            (b"com/rga", set.com_rga.clone()),
            (b"com/x", vec![set.com_x]),
            (b"com/y", vec![set.com_y]),
        ],
    );
}

/// A batched opening task (shared public vector, RLC'd claims).
struct OpeningTask {
    evec: Vec<Fr>,
    claims: Vec<EvalClaim>,
}

/// Verifier-side mirror of [`OpeningTask`]: commitments stay symbolic so
/// the whole check defers into the MSM accumulator.
struct OpeningCheck {
    evec: Vec<Fr>,
    claims: Vec<(ComExpr, Fr)>,
}

// ---------------------------------------------------------------------------
// Prover
// ---------------------------------------------------------------------------

/// Prove T training steps as one aggregated trace. `wits.len()` must equal
/// `tk.steps`; every witness must share `tk.cfg`. Steps are proven
/// independently (no inter-step weight constraint) — see
/// [`prove_trace_chained`] for the zkSGD-chained variant.
pub fn prove_trace(tk: &TraceKey, wits: &[StepWitness], rng: &mut Rng) -> TraceProof {
    prove_trace_with_parts(tk, wits, None, None, rng)
}

/// Build the zkData selection commitment bundle for a trace: recover the
/// per-step batch rows from the witnesses, validate them against the
/// committed dataset, and commit the stacked selection tensor (before any
/// transcript challenge, like every other commitment).
fn build_provenance(
    tk: &TraceKey,
    wits: &[StepWitness],
    pd: &ProverDataset,
    rng: &mut Rng,
) -> Result<(std::sync::Arc<ProvenanceKey>, ProvenanceCommitments)> {
    provenance::checked_selection_dims(&tk.cfg, wits.len(), pd.n_rows())
        .context("provenance trace")?;
    let pw = provenance::ProvenanceWitness::build(pd, wits)?;
    let pkey = ProvenanceKey::setup(tk.cfg, wits.len(), pd.n_rows());
    let pc = provenance::commit_provenance(&pkey, pd, &pw, rng)?;
    Ok((pkey, pc))
}

/// Prove T training steps with the zkData batch-provenance argument
/// ([`crate::provenance`]) on top: every step's committed input X_t and
/// target Y_t is proven to be rows of `pd`'s committed dataset, whose
/// Merkle root rides the statement for Appendix-B endorsement. Fails if
/// any witness's batch rows do not actually open against the dataset.
pub fn prove_trace_provenance(
    tk: &TraceKey,
    wits: &[StepWitness],
    pd: &ProverDataset,
    rng: &mut Rng,
) -> Result<TraceProof> {
    let prov = build_provenance(tk, wits, pd, rng)?;
    Ok(prove_trace_with_parts(tk, wits, None, Some(prov), rng))
}

/// [`prove_trace_chained_with`] + [`prove_trace_provenance`] combined: the
/// chained trace additionally binds every step's inputs to the committed
/// dataset — the full "trained THIS model on THIS data" statement.
pub fn prove_trace_chained_provenance_with(
    tk: &TraceKey,
    wits: &[StepWitness],
    rule: &UpdateRule,
    lr_shifts: &[u32],
    pd: &ProverDataset,
    rng: &mut Rng,
) -> Result<TraceProof> {
    update::checked_stack_dims(&tk.cfg, wits.len(), rule.n_rem()).context("chained trace")?;
    let cw = update::ChainWitness::build(rule, lr_shifts, wits)?;
    let prov = build_provenance(tk, wits, pd, rng)?;
    Ok(prove_trace_with_parts(
        tk,
        wits,
        Some((*rule, lr_shifts.to_vec(), cw)),
        Some(prov),
        rng,
    ))
}

/// Prove T ≥ 2 consecutive training steps as one *chained* trace under an
/// [`UpdateRule`] and per-boundary shift table: on top of the per-step
/// relations, the zkOptim chain argument ([`crate::update`]) proves that
/// every boundary satisfies the rule's exact quantized update relations
/// (plain SGD: W_{t+1} = W_t − ⌊G_W/2^{R+lr_b}⌉; heavy-ball momentum
/// additionally chains the committed accumulator m). Fails if the
/// witnesses do not actually chain under the rule.
pub fn prove_trace_chained_with(
    tk: &TraceKey,
    wits: &[StepWitness],
    rule: &UpdateRule,
    lr_shifts: &[u32],
    rng: &mut Rng,
) -> Result<TraceProof> {
    update::checked_stack_dims(&tk.cfg, wits.len(), rule.n_rem()).context("chained trace")?;
    let cw = update::ChainWitness::build(rule, lr_shifts, wits)?;
    Ok(prove_trace_with_parts(
        tk,
        wits,
        Some((*rule, lr_shifts.to_vec(), cw)),
        None,
        rng,
    ))
}

/// [`prove_trace_chained_with`] specialized to plain SGD at the config's
/// constant shift — the pre-rule entry point, byte-identical artifacts
/// for byte-identical inputs.
pub fn prove_trace_chained(
    tk: &TraceKey,
    wits: &[StepWitness],
    rng: &mut Rng,
) -> Result<TraceProof> {
    let shifts = LrSchedule::Constant(tk.cfg.lr_shift)
        .window_table(0, wits.len().saturating_sub(1));
    prove_trace_chained_with(tk, wits, &UpdateRule::Sgd, &shifts, rng)
}

pub(crate) fn prove_trace_with_parts(
    tk: &TraceKey,
    wits: &[StepWitness],
    chain_wit: Option<(UpdateRule, Vec<u32>, update::ChainWitness)>,
    prov: Option<(std::sync::Arc<ProvenanceKey>, ProvenanceCommitments)>,
    rng: &mut Rng,
) -> TraceProof {
    crate::span!("aggregate/prove_trace");
    let _lat = hist::timer(Hist::ProveTraceNs);
    let cfg = &tk.cfg;
    let t_steps = wits.len();
    assert_eq!(t_steps, tk.steps, "witness count mismatch");
    assert!(t_steps >= 1);
    for w in wits {
        assert_eq!(*cfg, w.cfg, "config mismatch");
    }
    let depth = cfg.depth;
    let d = cfg.d_size();
    let (tbar, lbar, _n) = trace_stack_dims(cfg, t_steps);
    let slots = tbar * lbar;
    let log_b = cfg.batch.trailing_zeros() as usize;
    let log_d = cfg.width.trailing_zeros() as usize;
    let log_dd = log_b + log_d;
    let log_s = slots.trailing_zeros() as usize;

    let pls: Vec<ProverLayers> = crate::telemetry::timed("aggregate/witness_layers", || {
        wits.iter().map(ProverLayers::build).collect()
    });
    let scs: Vec<TraceStepCommitments> = crate::telemetry::timed("aggregate/commit", || {
        pls.iter()
            .enumerate()
            .map(|(t, pl)| commit_trace_step(tk, t, pl, rng))
            .collect()
    });

    // zkOptim chain: remainder and state tensors committed before any
    // challenge, so the shared-randomness property covers the chain too
    let chain_cc = chain_wit.map(|(rule, lr_shifts, cw)| {
        let uk = UpdateKey::setup(*cfg, t_steps, &rule);
        let cc = update::commit_chain(&uk, &tk.g_mat, lr_shifts, cw, rng)
            .expect("chain witness validated at build");
        (uk, cc)
    });

    let mut tr = Transcript::new(b"zkdl/trace");
    tr.absorb_u64(b"depth", depth as u64);
    tr.absorb_u64(b"width", cfg.width as u64);
    tr.absorb_u64(b"batch", cfg.batch as u64);
    tr.absorb_u64(b"steps", t_steps as u64);
    tr.absorb_u64(b"chained", chain_cc.is_some() as u64);
    tr.absorb_u64(b"provenance", prov.is_some() as u64);

    let affine = |cs: &[Committed]| -> Vec<G1Affine> {
        G1::batch_to_affine(&cs.iter().map(|c| c.com).collect::<Vec<_>>())
    };
    let com_sets: Vec<StepCommitmentSet> = scs
        .iter()
        .map(|sc| StepCommitmentSet {
            com_w: affine(&sc.w),
            com_gw: affine(&sc.gw),
            com_zdp: affine(&sc.zdp),
            com_sign: affine(&sc.sign),
            com_rz: affine(&sc.rz),
            com_gap: affine(&sc.gap),
            com_rga: affine(&sc.rga),
            com_x: sc.x.com.to_affine(),
            com_y: sc.y.com.to_affine(),
        })
        .collect();
    for (t, set) in com_sets.iter().enumerate() {
        absorb_step_commitments(&mut tr, t, set);
    }
    if let Some((uk, cc)) = &chain_cc {
        update::absorb_chain_statement(&mut tr, &uk.rule, &cc.lr_shifts, &cc.com_state, &cc.com_u);
    }
    if let Some((_, pc)) = &prov {
        provenance::absorb_provenance_statement(&mut tr, &pc.dataset, &pc.com_s);
    }

    // ---- Protocol 1 over the trace stack ----
    let p1_span = crate::telemetry::maybe_span("aggregate/protocol1");
    macro_rules! stack_trace {
        ($field:ident) => {{
            let mut out = vec![Fr::ZERO; slots * d];
            for (t, pl) in pls.iter().enumerate() {
                for l in 0..depth {
                    let s = t * lbar + l;
                    out[s * d..s * d + d].copy_from_slice(&pl.$field[l]);
                }
            }
            out
        }};
    }
    let zdp_stack = stack_trace!(zdp);
    let gap_stack = stack_trace!(gap);
    let sign_stack = stack_trace!(sign);
    let rz_stack = stack_trace!(rz);
    let rga_stack = stack_trace!(rga);

    let (vb_main, vb_rem) = trace_validity_bases(tk);
    let sign_blind: Fr = scs
        .iter()
        .flat_map(|sc| sc.sign.iter().map(|c| c.blind))
        .sum();
    let paired: Vec<Fr> = zdp_stack.iter().chain(gap_stack.iter()).copied().collect();
    let (p1_main, aux_main) =
        zkrelu::protocol1_main(&vb_main, &paired, &sign_stack, sign_blind, rng);
    let paired_rem: Vec<Fr> = rz_stack.iter().chain(rga_stack.iter()).copied().collect();
    let (p1_rem, aux_rem) = zkrelu::protocol1_plain(&vb_rem, &paired_rem, rng);
    tr.absorb_point(b"p1/main", &p1_main.com_b_ip);
    if let Some(p) = &p1_main.com_sign_prime {
        tr.absorb_point(b"p1/main/sign", p);
    }
    tr.absorb_point(b"p1/rem", &p1_rem.com_b_ip);
    if let Some((_, cc)) = &chain_cc {
        tr.absorb_point(b"p1/upd", &cc.p1.com_b_ip);
    }
    if let Some((_, pc)) = &prov {
        tr.absorb_point(b"p1/sel", &pc.p1.com_b_ip);
        if let Some(p) = &pc.p1.com_sign_prime {
            tr.absorb_point(b"p1/sel/sign", p);
        }
    }

    // ---- Phase 1: one challenge bundle, three trace-wide matmul sumchecks ----
    drop(p1_span);
    let mm_span = crate::telemetry::maybe_span("aggregate/matmul_sumcheck");
    let ch = draw_group_challenges(&mut tr, log_b, log_d);

    // One arena backs the per-loop eq tables below: the point's eq table
    // is computed once per challenge point into reused scratch (instead of
    // materializing a fresh Fr matrix + eq table per (t, ℓ) — 2·T·L·b·d
    // transient allocations in the old shape).
    let mut arena = FrArena::new();

    // (30): Z̃_t^ℓ(u_zr,u_zc) for every (t, ℓ), γ-folded step-major. The
    // per-(t, ℓ) work — an eval against the shared eq table plus two
    // fix_rows restrictions — is independent, so it fans out over the
    // zkLanes pool; γ-powers are precomputed so every slot's coefficient
    // is position-determined (byte-identical at every lane count).
    let pz: Vec<Fr> = [ch.u_zr.clone(), ch.u_zc.clone()].concat();
    let gpow30 = gamma_powers(ch.gamma, t_steps * depth);
    let (v_z, terms30): (Vec<Fr>, Vec<Term>) = arena.scratch(1 << pz.len(), |eq_pz| {
        poly::eq_table_into(&pz, eq_pz);
        let eq_pz = &*eq_pz;
        threads::par_map_indexed(t_steps * depth, |k| {
            let (t, l) = (k / depth, k % depth);
            let pl = &pls[t];
            let a_prev = if l == 0 { &pl.x } else { &pl.a[l - 1] };
            (
                eval_i64_with_eq(&wits[t].layers[l].z, eq_pz),
                Term::new(
                    gpow30[k],
                    vec![a_prev.fix_rows(&ch.u_zr), pl.w[l].transpose().fix_rows(&ch.u_zc)],
                ),
            )
        })
        .into_iter()
        .unzip()
    });
    tr.absorb_frs(b"v_z", &v_z);
    let out30 = sumcheck::prove(Instance::new(terms30), &mut tr);
    let mm30_evals: Vec<(Fr, Fr)> = out30.factor_evals.iter().map(|f| (f[0], f[1])).collect();
    tr.absorb_frs(
        b"mm30/evals",
        &mm30_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
    );
    let r30 = out30.point.clone();

    // (33): inner layers of every step.
    let pga: Vec<Fr> = [ch.u_gar.clone(), ch.u_gac.clone()].concat();
    let mut v_ga = Vec::new();
    let mut mm33 = None;
    let mut mm33_evals: Vec<(Fr, Fr)> = Vec::new();
    let mut r33 = Vec::new();
    if depth >= 2 {
        let inner = depth - 1;
        let gpow33 = gamma_powers(ch.gamma, t_steps * inner);
        let (v, terms33): (Vec<Fr>, Vec<Term>) = arena.scratch(1 << pga.len(), |eq_pga| {
            poly::eq_table_into(&pga, eq_pga);
            let eq_pga = &*eq_pga;
            threads::par_map_indexed(t_steps * inner, |k| {
                let (t, l) = (k / inner, k % inner);
                let pl = &pls[t];
                (
                    eval_i64_with_eq(wits[t].layers[l].g_a.as_ref().unwrap(), eq_pga),
                    Term::new(
                        gpow33[k],
                        vec![
                            pl.g_z[l + 1].fix_rows(&ch.u_gar),
                            pl.w[l + 1].fix_rows(&ch.u_gac),
                        ],
                    ),
                )
            })
            .into_iter()
            .unzip()
        });
        v_ga = v;
        tr.absorb_frs(b"v_ga", &v_ga);
        let out33 = sumcheck::prove(Instance::new(terms33), &mut tr);
        mm33_evals = out33.factor_evals.iter().map(|f| (f[0], f[1])).collect();
        tr.absorb_frs(
            b"mm33/evals",
            &mm33_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
        );
        r33 = out33.point.clone();
        mm33 = Some(out33.proof);
    }

    // (34): G̃_W for every (t, ℓ).
    let pgw: Vec<Fr> = [ch.u_gwr.clone(), ch.u_gwc.clone()].concat();
    let gpow34 = gamma_powers(ch.gamma, t_steps * depth);
    let (v_gw, terms34): (Vec<Fr>, Vec<Term>) = arena.scratch(1 << pgw.len(), |eq_pgw| {
        poly::eq_table_into(&pgw, eq_pgw);
        let eq_pgw = &*eq_pgw;
        threads::par_map_indexed(t_steps * depth, |k| {
            let (t, l) = (k / depth, k % depth);
            let pl = &pls[t];
            let a_prev = if l == 0 { &pl.x } else { &pl.a[l - 1] };
            (
                eval_i64_with_eq(&wits[t].layers[l].g_w, eq_pgw),
                Term::new(
                    gpow34[k],
                    vec![
                        pl.g_z[l].transpose().fix_rows(&ch.u_gwr),
                        a_prev.transpose().fix_rows(&ch.u_gwc),
                    ],
                ),
            )
        })
        .into_iter()
        .unzip()
    });
    tr.absorb_frs(b"v_gw", &v_gw);
    let out34 = sumcheck::prove(Instance::new(terms34), &mut tr);
    let mm34_evals: Vec<(Fr, Fr)> = out34.factor_evals.iter().map(|f| (f[0], f[1])).collect();
    tr.absorb_frs(
        b"mm34/evals",
        &mm34_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
    );
    let r34 = out34.point.clone();

    // ---- Phase 2: trace-wide stacking sumcheck ----
    // The four claim kinds share trace-global points (all steps use the same
    // challenge bundle); presence depends only on depth.
    drop(mm_span);
    let stack_span = crate::telemetry::maybe_span("aggregate/stacking");
    let pa1: Option<Vec<Fr>> = (depth >= 2).then(|| [ch.u_zr.clone(), r30.clone()].concat());
    let pa2: Option<Vec<Fr>> = (depth >= 2).then(|| [r34.clone(), ch.u_gwc.clone()].concat());
    let qz1: Option<Vec<Fr>> = (depth >= 3).then(|| [ch.u_gar.clone(), r33.clone()].concat());
    let qz2: Option<Vec<Fr>> = (depth >= 2).then(|| [r34.clone(), ch.u_gwr.clone()].concat());

    // Each live slot is an independent b·d-sized dot against the point's
    // eq table: fan the slots out over the pool (the dots themselves are
    // chunk-reduced when the slot fan-out is too small to split, e.g.
    // T=1 — nested pool calls run inline, so the two levels compose).
    let slot_claims = |point: &Option<Vec<Fr>>, use_a: bool| -> Vec<Fr> {
        match point {
            None => vec![Fr::ZERO; slots],
            Some(p) => {
                let e = eq_table(p);
                let e = &e;
                threads::par_map_indexed(slots, |s| {
                    let (t, l) = (s / lbar, s % lbar);
                    if t >= t_steps || l >= depth {
                        return Fr::ZERO;
                    }
                    let pl = &pls[t];
                    if use_a {
                        let a = &pl.a[l].data;
                        let n = a.len().min(e.len());
                        threads::par_reduce(
                            n,
                            1 << 10,
                            Fr::ZERO,
                            |r, acc| {
                                a[r.clone()]
                                    .iter()
                                    .zip(&e[r])
                                    .fold(acc, |s, (x, y)| s + *x * *y)
                            },
                            |x, y| x + y,
                        )
                    } else {
                        let (gap, sign) = (&pl.gap[l], &pl.sign[l]);
                        let n = gap.len().min(sign.len()).min(e.len());
                        threads::par_reduce(
                            n,
                            1 << 10,
                            Fr::ZERO,
                            |r, acc| {
                                r.fold(acc, |s, i| s + (Fr::ONE - sign[i]) * gap[i] * e[i])
                            },
                            |x, y| x + y,
                        )
                    }
                })
            }
        }
    };
    let va1 = slot_claims(&pa1, true);
    let va2 = slot_claims(&pa2, true);
    let vgz1 = slot_claims(&qz1, false);
    let vgz2 = slot_claims(&qz2, false);
    tr.absorb_frs(b"stack/va1", &va1);
    tr.absorb_frs(b"stack/va2", &va2);
    tr.absorb_frs(b"stack/vgz1", &vgz1);
    tr.absorb_frs(b"stack/vgz2", &vgz2);

    let any_term = depth >= 2;
    let u_stack = tr.challenge_frs(b"stack/u", log_s);
    let gammas = tr.challenge_frs(b"stack/gamma", 4);

    let one_minus_sign: Vec<Fr> = sign_stack.iter().map(|s| Fr::ONE - *s).collect();
    let zdp_mle = Mle::new(zdp_stack.clone());
    let gap_mle = Mle::new(gap_stack.clone());
    let oms_mle = Mle::new(one_minus_sign);

    let (stack_proof, rho) = if any_term {
        let mut terms = Vec::new();
        let mut add_term = |coeff: Fr, point: &Option<Vec<Fr>>, tensor: &Mle| {
            if let Some(p) = point {
                let full_point: Vec<Fr> = [u_stack.clone(), p.clone()].concat();
                terms.push(Term::new(
                    coeff,
                    vec![Mle::new(eq_table(&full_point)), oms_mle.clone(), tensor.clone()],
                ));
            }
        };
        add_term(gammas[0], &pa1, &zdp_mle);
        add_term(gammas[1], &pa2, &zdp_mle);
        add_term(gammas[2], &qz1, &gap_mle);
        add_term(gammas[3], &qz2, &gap_mle);
        let out = sumcheck::prove(Instance::new(terms), &mut tr);
        (Some(out.proof), out.point)
    } else {
        (None, tr.challenge_frs(b"stack/rho", log_s + log_dd))
    };

    let sign_mle = Mle::new(sign_stack.clone());
    let v_sign = sign_mle.evaluate(&rho);
    let v_zdp = zdp_mle.evaluate(&rho);
    let v_gap = gap_mle.evaluate(&rho);
    let v_rz = Mle::new(rz_stack.clone()).evaluate(&rho);
    let v_rga = Mle::new(rga_stack.clone()).evaluate(&rho);
    let aux_evals = [v_sign, v_zdp, v_gap, v_rz, v_rga];
    tr.absorb_frs(b"aux/evals", &aux_evals);

    // ---- Phase 3: batched openings (one task list for the whole trace) ----
    drop(stack_span);
    let open_span = crate::telemetry::maybe_span("aggregate/openings");
    let gk = tk.g_aux.clone();
    let mut tasks: Vec<(CommitKey, OpeningTask)> = Vec::new();

    // OT-A: trace-stacked aux at ρ (5 claims).
    {
        macro_rules! stack_claim {
            ($field:ident, $v:expr) => {{
                let mut com = G1::IDENTITY;
                let mut blind = Fr::ZERO;
                let mut values = vec![Fr::ZERO; slots * d];
                for (t, sc) in scs.iter().enumerate() {
                    for l in 0..depth {
                        let s = t * lbar + l;
                        com = com + sc.$field[l].com;
                        blind += sc.$field[l].blind;
                        values[s * d..s * d + d].copy_from_slice(&sc.$field[l].values);
                    }
                }
                EvalClaim {
                    com,
                    values,
                    blind,
                    v: $v,
                }
            }};
        }
        tasks.push((
            gk.clone(),
            OpeningTask {
                evec: eq_table(&rho),
                claims: vec![
                    stack_claim!(sign, v_sign),
                    stack_claim!(zdp, v_zdp),
                    stack_claim!(gap, v_gap),
                    stack_claim!(rz, v_rz),
                    stack_claim!(rga, v_rga),
                ],
            },
        ));
    }

    // OT-Z: derived Z commitments of every (t, ℓ) at pz, tiled over the
    // trace basis.
    {
        let mut claims_z = Vec::with_capacity(t_steps * depth);
        let mut z_slots = Vec::with_capacity(t_steps * depth);
        for (t, sc) in scs.iter().enumerate() {
            for l in 0..depth {
                let (values, blind) = derived_open_z(cfg, &sc.zdp[l], &sc.sign[l], &sc.rz[l]);
                let com = derived_com_z(cfg, &sc.zdp[l].com, &sc.sign[l].com, &sc.rz[l].com);
                claims_z.push(EvalClaim {
                    com,
                    values,
                    blind,
                    v: v_z[t * depth + l],
                });
                z_slots.push(t * lbar + l);
            }
        }
        tasks.push((
            gk.clone(),
            OpeningTask {
                evec: tiled_eq(&pz, slots),
                claims: tile_claims_at(claims_z, &z_slots, slots, d),
            },
        ));
    }

    // OT-GA: derived G_A commitments of inner layers at pga.
    if depth >= 2 {
        let mut claims_ga = Vec::new();
        let mut ga_slots = Vec::new();
        for (t, sc) in scs.iter().enumerate() {
            for l in 0..depth - 1 {
                let (values, blind) = derived_open_ga(cfg, &sc.gap[l], &sc.rga[l]);
                let com = derived_com_ga(cfg, &sc.gap[l].com, &sc.rga[l].com);
                claims_ga.push(EvalClaim {
                    com,
                    values,
                    blind,
                    v: v_ga[t * (depth - 1) + l],
                });
                ga_slots.push(t * lbar + l);
            }
        }
        tasks.push((
            gk.clone(),
            OpeningTask {
                evec: tiled_eq(&pga, slots),
                claims: tile_claims_at(claims_ga, &ga_slots, slots, d),
            },
        ));
    }

    // OT-GW: com_gw at pgw (shared g_mat basis → plain RLC batch).
    {
        let mut claims_gw = Vec::with_capacity(t_steps * depth);
        for (t, sc) in scs.iter().enumerate() {
            for l in 0..depth {
                claims_gw.push(EvalClaim {
                    com: sc.gw[l].com,
                    values: sc.gw[l].values.clone(),
                    blind: sc.gw[l].blind,
                    v: v_gw[t * depth + l],
                });
            }
        }
        tasks.push((
            tk.g_mat.clone(),
            OpeningTask {
                evec: eq_table(&pgw),
                claims: claims_gw,
            },
        ));
    }

    // OT-W30: com_w at (r30, u_zc).
    {
        let p: Vec<Fr> = [r30.clone(), ch.u_zc.clone()].concat();
        let mut claims_w = Vec::with_capacity(t_steps * depth);
        for (t, sc) in scs.iter().enumerate() {
            for l in 0..depth {
                claims_w.push(EvalClaim {
                    com: sc.w[l].com,
                    values: sc.w[l].values.clone(),
                    blind: sc.w[l].blind,
                    v: mm30_evals[t * depth + l].1,
                });
            }
        }
        tasks.push((
            tk.g_mat.clone(),
            OpeningTask {
                evec: eq_table(&p),
                claims: claims_w,
            },
        ));
    }

    // OT-W33: com_w^{ℓ+1} at (u_gac, r33).
    if depth >= 2 {
        let p: Vec<Fr> = [ch.u_gac.clone(), r33.clone()].concat();
        let mut claims_w = Vec::new();
        for (t, sc) in scs.iter().enumerate() {
            for l in 0..depth - 1 {
                claims_w.push(EvalClaim {
                    com: sc.w[l + 1].com,
                    values: sc.w[l + 1].values.clone(),
                    blind: sc.w[l + 1].blind,
                    v: mm33_evals[t * (depth - 1) + l].1,
                });
            }
        }
        tasks.push((
            tk.g_mat.clone(),
            OpeningTask {
                evec: eq_table(&p),
                claims: claims_w,
            },
        ));
    }

    // OT-X30 / OT-X34: per-step input commitments at layer 0's points.
    {
        let p30: Vec<Fr> = [ch.u_zr.clone(), r30.clone()].concat();
        let claims_x: Vec<EvalClaim> = scs
            .iter()
            .enumerate()
            .map(|(t, sc)| EvalClaim {
                com: sc.x.com,
                values: sc.x.values.clone(),
                blind: sc.x.blind,
                v: mm30_evals[t * depth].0,
            })
            .collect();
        tasks.push((
            tk.g_x.clone(),
            OpeningTask {
                evec: eq_table(&p30),
                claims: claims_x,
            },
        ));
        let p34: Vec<Fr> = [r34.clone(), ch.u_gwc.clone()].concat();
        let claims_x: Vec<EvalClaim> = scs
            .iter()
            .enumerate()
            .map(|(t, sc)| EvalClaim {
                com: sc.x.com,
                values: sc.x.values.clone(),
                blind: sc.x.blind,
                v: mm34_evals[t * depth].1,
            })
            .collect();
        tasks.push((
            tk.g_x.clone(),
            OpeningTask {
                evec: eq_table(&p34),
                claims: claims_x,
            },
        ));
    }

    // OT-GZlast34 / OT-GZlast33: derived G_Z^{L−1} per step, tiled at the
    // step's last-layer slot.
    {
        let last = depth - 1;
        let gz_opens: Vec<(Vec<Fr>, Fr, G1)> = scs
            .iter()
            .map(|sc| {
                let (vals, blind) = derived_open_gz_last(cfg, &sc.zdp[last], &sc.sign[last], &sc.y);
                let com = derived_com_gz_last(cfg, &sc.zdp[last].com, &sc.sign[last].com, &sc.y.com);
                (vals, blind, com)
            })
            .collect();
        let gz_slots: Vec<usize> = (0..t_steps).map(|t| t * lbar + last).collect();
        let p: Vec<Fr> = [r34.clone(), ch.u_gwr.clone()].concat();
        let claims: Vec<EvalClaim> = gz_opens
            .iter()
            .enumerate()
            .map(|(t, (vals, blind, com))| EvalClaim {
                com: *com,
                values: vals.clone(),
                blind: *blind,
                v: mm34_evals[t * depth + last].0,
            })
            .collect();
        tasks.push((
            gk.clone(),
            OpeningTask {
                evec: tiled_eq(&p, slots),
                claims: tile_claims_at(claims, &gz_slots, slots, d),
            },
        ));
        if depth >= 2 {
            let p: Vec<Fr> = [ch.u_gar.clone(), r33.clone()].concat();
            let claims: Vec<EvalClaim> = gz_opens
                .iter()
                .enumerate()
                .map(|(t, (vals, blind, com))| EvalClaim {
                    com: *com,
                    values: vals.clone(),
                    blind: *blind,
                    v: mm33_evals[t * (depth - 1) + (depth - 2)].0,
                })
                .collect();
            tasks.push((
                gk.clone(),
                OpeningTask {
                    evec: tiled_eq(&p, slots),
                    claims: tile_claims_at(claims, &gz_slots, slots, d),
                },
            ));
        }
    }

    let mut openings = Vec::new();
    for (ck, task) in &tasks {
        // values-only absorption — mirrors the verifier's symbolic claims
        openings.push(ipa::batch_prove_eval_expr(
            ck,
            &task.claims,
            &task.evec,
            &mut tr,
            rng,
        ));
    }

    // ---- Phase 4: one validity pair for the whole trace ----
    drop(open_span);
    let validity_span = crate::telemetry::maybe_span("aggregate/validity");
    let u_dd = tr.challenge_fr(b"zkdl/u_dd");
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    let v = (Fr::ONE - u_dd) * v_zdp + u_dd * v_gap;
    let validity_main =
        zkrelu::prove_validity(&vb_main, &aux_main, &e_row, u_dd, v, v_sign, &mut tr, rng);
    let u_dd_r = tr.challenge_fr(b"zkdl/u_dd_rem");
    let mut vpoint_r = vec![u_dd_r];
    vpoint_r.extend_from_slice(&rho);
    let e_row_r = eq_table(&vpoint_r);
    let v_rem = (Fr::ONE - u_dd_r) * v_rz + u_dd_r * v_rga;
    let validity_rem = zkrelu::prove_validity(
        &vb_rem,
        &aux_rem,
        &e_row_r,
        u_dd_r,
        v_rem,
        Fr::ZERO,
        &mut tr,
        rng,
    );

    // ---- Phase 5: zkSGD chain argument (chained traces only) ----
    drop(validity_span);
    let chain = chain_cc.map(|(uk, cc)| {
        let w_refs: Vec<&[Committed]> = scs.iter().map(|sc| sc.w.as_slice()).collect();
        let gw_refs: Vec<&[Committed]> = scs.iter().map(|sc| sc.gw.as_slice()).collect();
        update::prove_chain(&uk, &tk.g_mat, &w_refs, &gw_refs, cc, &mut tr, rng)
    });

    // ---- Phase 6: zkData batch-provenance argument ----
    let provenance = prov.map(|(pkey, pc)| {
        let x_refs: Vec<&Committed> = scs.iter().map(|sc| &sc.x).collect();
        let y_refs: Vec<&Committed> = scs.iter().map(|sc| &sc.y).collect();
        let y_slots: Vec<usize> = (0..t_steps).map(|t| t * lbar + (depth - 1)).collect();
        provenance::prove_provenance(
            &pkey, &tk.g_x, &tk.g_aux, slots, &y_slots, &x_refs, &y_refs, pc, &mut tr, rng,
        )
    });

    TraceProof {
        steps: t_steps,
        coms: com_sets,
        p1_main,
        p1_rem,
        v_z,
        v_ga,
        v_gw,
        mm30: out30.proof,
        mm30_evals,
        mm33,
        mm33_evals,
        mm34: out34.proof,
        mm34_evals,
        stack: stack_proof,
        va1,
        va2,
        vgz1,
        vgz2,
        aux_evals,
        openings,
        validity_main,
        validity_rem,
        chain,
        provenance,
    }
}

// ---------------------------------------------------------------------------
// Verifier
// ---------------------------------------------------------------------------

/// Verify a [`TraceProof`] against the public trace key. Thin wrapper over
/// [`verify_trace_accum`]: exactly one Pippenger MSM for the whole trace.
pub fn verify_trace(tk: &TraceKey, proof: &TraceProof) -> Result<()> {
    let mut acc = MsmAccumulator::new();
    verify_trace_accum(tk, proof, &mut acc)?;
    crate::ensure_class!(
        acc.flush(),
        VerifyFailureClass::MsmFinalCheck,
        "trace proof: deferred MSM check failed"
    );
    Ok(())
}

/// Verify a batch of trace proofs (possibly over different keys) with ONE
/// MSM total. Each proof's deferred terms are scaled by an independent
/// verifier-chosen random ρᵢ before merging into the shared accumulator,
/// preventing cross-proof cancellation.
pub fn verify_traces_batch(pairs: &[(&TraceKey, &TraceProof)], rng: &mut Rng) -> Result<()> {
    crate::ensure_class!(
        !pairs.is_empty(),
        VerifyFailureClass::Shape,
        "empty trace batch"
    );
    let mut acc = MsmAccumulator::from_rng(rng);
    for (i, (tk, proof)) in pairs.iter().enumerate() {
        acc.set_scale(Fr::random_nonzero(rng));
        verify_trace_accum(tk, proof, &mut acc)
            .with_context(|| format!("batched trace {i}"))?;
    }
    crate::ensure_class!(
        acc.flush(),
        VerifyFailureClass::MsmFinalCheck,
        "trace batch: aggregate MSM check failed"
    );
    Ok(())
}

/// Per-proof entry of a [`BatchVerifyReport`]: which artifact, which dataset
/// root it claims (when provenance is on), and — on rejection — the typed
/// failure class attributed by individual re-verification.
#[derive(Clone, Debug)]
pub struct BatchEntry {
    pub index: usize,
    /// Dataset root the proof commits to, if it carries provenance.
    pub root: Option<Vec<u8>>,
    pub accepted: bool,
    pub failure_class: Option<VerifyFailureClass>,
    /// Rendered error chain for rejected entries.
    pub error: Option<String>,
}

/// Outcome of [`verify_traces_batch_report`]: one entry per proof plus the
/// batch-level error when the aggregate check rejected.
#[derive(Clone, Debug)]
pub struct BatchVerifyReport {
    pub entries: Vec<BatchEntry>,
    /// Set when the batch as a whole rejected (even after per-proof
    /// attribution — e.g. a cross-proof tamper only the aggregate sees).
    pub batch_error: Option<String>,
}

impl BatchVerifyReport {
    pub fn all_accepted(&self) -> bool {
        self.batch_error.is_none() && self.entries.iter().all(|e| e.accepted)
    }
}

/// The dataset root a trace proof commits to, if it carries provenance.
pub fn trace_dataset_root(proof: &TraceProof) -> Option<Vec<u8>> {
    proof.provenance.as_ref().map(|p| p.dataset.root.to_vec())
}

fn hex_bytes(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// Reject a batch whose proofs pin different dataset roots (the
/// `--require-same-root` policy). Proofs without provenance are treated as
/// root-less and never conflict.
pub fn ensure_same_root(proofs: &[&TraceProof]) -> Result<()> {
    let mut first: Option<(usize, Vec<u8>)> = None;
    for (i, p) in proofs.iter().enumerate() {
        let Some(root) = trace_dataset_root(p) else { continue };
        match &first {
            None => first = Some((i, root)),
            Some((j, want)) => {
                if *want != root {
                    return Err(classified(
                        VerifyFailureClass::RootMismatch,
                        anyhow::anyhow!(
                            "mixed dataset roots in batch: proof {j} pins {}, proof {i} pins {}",
                            hex_bytes(want),
                            hex_bytes(&root)
                        ),
                    ));
                }
            }
        }
    }
    Ok(())
}

/// [`verify_traces_batch`] with per-proof attribution. The fast path is the
/// unchanged one-MSM batch; only when the batch rejects does it fall back to
/// verifying each proof individually (one MSM each) so the report can name
/// the offending index and its [`VerifyFailureClass`]. A batch that rejects
/// with every individual proof accepting records the aggregate error alone.
pub fn verify_traces_batch_report(
    pairs: &[(&TraceKey, &TraceProof)],
    rng: &mut Rng,
) -> BatchVerifyReport {
    let mut entries: Vec<BatchEntry> = pairs
        .iter()
        .enumerate()
        .map(|(index, (_, proof))| BatchEntry {
            index,
            root: trace_dataset_root(proof),
            accepted: true,
            failure_class: None,
            error: None,
        })
        .collect();
    match verify_traces_batch(pairs, rng) {
        Ok(()) => BatchVerifyReport {
            entries,
            batch_error: None,
        },
        Err(batch_err) => {
            for (entry, (tk, proof)) in entries.iter_mut().zip(pairs) {
                if let Err(e) = verify_trace(tk, proof) {
                    entry.accepted = false;
                    entry.failure_class = failure_class(&e);
                    entry.error = Some(format!("{e:#}"));
                }
            }
            BatchVerifyReport {
                entries,
                batch_error: Some(format!("{batch_err:#}")),
            }
        }
    }
}

/// Transcript replay and scalar checks of [`verify_trace`], every group
/// equation deferred into `acc` — no curve arithmetic here.
pub fn verify_trace_accum(
    tk: &TraceKey,
    proof: &TraceProof,
    acc: &mut MsmAccumulator,
) -> Result<()> {
    crate::span!("aggregate/verify_trace");
    let _lat = hist::timer(Hist::VerifyTraceNs);
    let cfg = &tk.cfg;
    let t_steps = tk.steps;
    let depth = cfg.depth;
    let (tbar, lbar, _n) = trace_stack_dims(cfg, t_steps);
    let slots = tbar * lbar;
    let log_b = cfg.batch.trailing_zeros() as usize;
    let log_d = cfg.width.trailing_zeros() as usize;
    let log_dd = log_b + log_d;
    let log_s = slots.trailing_zeros() as usize;

    crate::ensure_class!(
        proof.steps == t_steps,
        VerifyFailureClass::Shape,
        "step count mismatch"
    );
    crate::ensure_class!(
        proof.coms.len() == t_steps,
        VerifyFailureClass::Shape,
        "commitment set count"
    );
    for set in &proof.coms {
        crate::ensure_class!(
            set.com_w.len() == depth
                && set.com_gw.len() == depth
                && set.com_zdp.len() == depth
                && set.com_sign.len() == depth
                && set.com_rz.len() == depth
                && set.com_gap.len() == depth
                && set.com_rga.len() == depth,
            VerifyFailureClass::Shape,
            "wrong per-step commitment count"
        );
    }

    let chained = proof.chain.is_some();
    crate::ensure_class!(
        !chained || t_steps >= 2,
        VerifyFailureClass::Shape,
        "chained trace needs at least two steps"
    );

    let mut tr = Transcript::new(b"zkdl/trace");
    tr.absorb_u64(b"depth", depth as u64);
    tr.absorb_u64(b"width", cfg.width as u64);
    tr.absorb_u64(b"batch", cfg.batch as u64);
    tr.absorb_u64(b"steps", t_steps as u64);
    tr.absorb_u64(b"chained", chained as u64);
    tr.absorb_u64(b"provenance", proof.provenance.is_some() as u64);
    for (t, set) in proof.coms.iter().enumerate() {
        absorb_step_commitments(&mut tr, t, set);
    }
    if let Some(chain) = &proof.chain {
        update::absorb_chain_statement(
            &mut tr,
            &chain.rule,
            &chain.lr_shifts,
            &chain.com_state,
            &chain.com_u,
        );
    }
    if let Some(prov) = &proof.provenance {
        provenance::absorb_provenance_statement(&mut tr, &prov.dataset, &prov.com_s);
    }

    let (vb_main, vb_rem) = trace_validity_bases(tk);
    tr.absorb_point(b"p1/main", &proof.p1_main.com_b_ip);
    if let Some(p) = &proof.p1_main.com_sign_prime {
        tr.absorb_point(b"p1/main/sign", p);
    } else {
        return Err(classified(
            VerifyFailureClass::Shape,
            anyhow::anyhow!("main validity instance must carry com_sign_prime"),
        ));
    }
    tr.absorb_point(b"p1/rem", &proof.p1_rem.com_b_ip);
    if let Some(chain) = &proof.chain {
        tr.absorb_point(b"p1/upd", &chain.p1_upd.com_b_ip);
    }
    if let Some(prov) = &proof.provenance {
        tr.absorb_point(b"p1/sel", &prov.p1_sel.com_b_ip);
        match &prov.p1_sel.com_sign_prime {
            Some(p) => tr.absorb_point(b"p1/sel/sign", p),
            None => {
                return Err(classified(
                    VerifyFailureClass::Shape,
                    anyhow::anyhow!("selection booleanity instance must carry com_sign_prime"),
                ))
            }
        }
    }

    // ---- Phase 1 ----
    let mm_span = crate::telemetry::maybe_span("aggregate/matmul_sumcheck");
    let ch = draw_group_challenges(&mut tr, log_b, log_d);
    let n_zl = t_steps * depth;
    let n_inner = t_steps * (depth - 1);
    crate::ensure_class!(proof.v_z.len() == n_zl, VerifyFailureClass::Shape, "v_z length");
    crate::ensure_class!(
        proof.mm30_evals.len() == n_zl,
        VerifyFailureClass::Shape,
        "mm30 evals length"
    );
    tr.absorb_frs(b"v_z", &proof.v_z);
    let rlc = |vs: &[Fr]| -> Fr {
        let mut acc = Fr::ZERO;
        let mut c = Fr::ONE;
        for v in vs {
            acc += c * *v;
            c *= ch.gamma;
        }
        acc
    };
    let rlc_prod = |es: &[(Fr, Fr)]| -> Fr {
        let mut acc = Fr::ZERO;
        let mut c = Fr::ONE;
        for (a, b) in es {
            acc += c * *a * *b;
            c *= ch.gamma;
        }
        acc
    };
    let out30 = sumcheck::verify(rlc(&proof.v_z), &proof.mm30, &mut tr)
        .classify(VerifyFailureClass::Sumcheck)
        .context("mm30")?;
    crate::ensure_class!(
        rlc_prod(&proof.mm30_evals) == out30.final_claim,
        VerifyFailureClass::TranscriptBinding,
        "mm30 factor evals mismatch"
    );
    tr.absorb_frs(
        b"mm30/evals",
        &proof.mm30_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
    );
    let r30 = out30.point;

    let mut r33 = Vec::new();
    if depth >= 2 {
        crate::ensure_class!(
            proof.v_ga.len() == n_inner,
            VerifyFailureClass::Shape,
            "v_ga length"
        );
        crate::ensure_class!(
            proof.mm33_evals.len() == n_inner,
            VerifyFailureClass::Shape,
            "mm33 evals length"
        );
        tr.absorb_frs(b"v_ga", &proof.v_ga);
        let sc33 = proof
            .mm33
            .as_ref()
            .context("missing mm33")
            .classify(VerifyFailureClass::Shape)?;
        let out33 = sumcheck::verify(rlc(&proof.v_ga), sc33, &mut tr)
            .classify(VerifyFailureClass::Sumcheck)
            .context("mm33")?;
        crate::ensure_class!(
            rlc_prod(&proof.mm33_evals) == out33.final_claim,
            VerifyFailureClass::TranscriptBinding,
            "mm33 factor evals mismatch"
        );
        tr.absorb_frs(
            b"mm33/evals",
            &proof.mm33_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
        );
        r33 = out33.point;
    } else {
        crate::ensure_class!(proof.mm33.is_none(), VerifyFailureClass::Shape, "unexpected mm33");
        crate::ensure_class!(
            proof.v_ga.is_empty() && proof.mm33_evals.is_empty(),
            VerifyFailureClass::Shape,
            "unexpected mm33 evals"
        );
    }

    crate::ensure_class!(proof.v_gw.len() == n_zl, VerifyFailureClass::Shape, "v_gw length");
    crate::ensure_class!(
        proof.mm34_evals.len() == n_zl,
        VerifyFailureClass::Shape,
        "mm34 evals length"
    );
    tr.absorb_frs(b"v_gw", &proof.v_gw);
    let out34 = sumcheck::verify(rlc(&proof.v_gw), &proof.mm34, &mut tr)
        .classify(VerifyFailureClass::Sumcheck)
        .context("mm34")?;
    crate::ensure_class!(
        rlc_prod(&proof.mm34_evals) == out34.final_claim,
        VerifyFailureClass::TranscriptBinding,
        "mm34 factor evals mismatch"
    );
    tr.absorb_frs(
        b"mm34/evals",
        &proof.mm34_evals.iter().flat_map(|(a, b)| [*a, *b]).collect::<Vec<_>>(),
    );
    let r34 = out34.point;

    // ---- Phase 2 ----
    drop(mm_span);
    let stack_span = crate::telemetry::maybe_span("aggregate/stacking");
    crate::ensure_class!(
        proof.va1.len() == slots
            && proof.va2.len() == slots
            && proof.vgz1.len() == slots
            && proof.vgz2.len() == slots,
        VerifyFailureClass::Shape,
        "slot claims"
    );
    // Slot claims covered by matmul factor evals must match them; the
    // owning-layer index shift mirrors the per-step claim registry.
    for t in 0..t_steps {
        for l in 0..depth {
            let s = t * lbar + l;
            if l + 1 < depth {
                crate::ensure_class!(
                    proof.va1[s] == proof.mm30_evals[t * depth + l + 1].0,
                    VerifyFailureClass::TranscriptBinding,
                    "va1 slot {s} mismatch"
                );
                crate::ensure_class!(
                    proof.va2[s] == proof.mm34_evals[t * depth + l + 1].1,
                    VerifyFailureClass::TranscriptBinding,
                    "va2 slot {s} mismatch"
                );
                crate::ensure_class!(
                    proof.vgz2[s] == proof.mm34_evals[t * depth + l].0,
                    VerifyFailureClass::TranscriptBinding,
                    "vgz2 slot {s} mismatch"
                );
                if l >= 1 {
                    crate::ensure_class!(
                        proof.vgz1[s] == proof.mm33_evals[t * (depth - 1) + l - 1].0,
                        VerifyFailureClass::TranscriptBinding,
                        "vgz1 slot {s} mismatch"
                    );
                }
            }
        }
    }
    for s in 0..slots {
        let (t, l) = (s / lbar, s % lbar);
        if t >= t_steps || l >= depth {
            crate::ensure_class!(
                proof.va1[s].is_zero()
                    && proof.va2[s].is_zero()
                    && proof.vgz1[s].is_zero()
                    && proof.vgz2[s].is_zero(),
                VerifyFailureClass::TranscriptBinding,
                "padding slot claims must be zero"
            );
        }
    }
    tr.absorb_frs(b"stack/va1", &proof.va1);
    tr.absorb_frs(b"stack/va2", &proof.va2);
    tr.absorb_frs(b"stack/vgz1", &proof.vgz1);
    tr.absorb_frs(b"stack/vgz2", &proof.vgz2);

    let pa1: Option<Vec<Fr>> = (depth >= 2).then(|| [ch.u_zr.clone(), r30.clone()].concat());
    let pa2: Option<Vec<Fr>> = (depth >= 2).then(|| [r34.clone(), ch.u_gwc.clone()].concat());
    let qz1: Option<Vec<Fr>> = (depth >= 3).then(|| [ch.u_gar.clone(), r33.clone()].concat());
    let qz2: Option<Vec<Fr>> = (depth >= 2).then(|| [r34.clone(), ch.u_gwr.clone()].concat());

    let any_term = depth >= 2;
    let u_stack = tr.challenge_frs(b"stack/u", log_s);
    let gammas = tr.challenge_frs(b"stack/gamma", 4);
    let e_stack = eq_table(&u_stack);

    let rho = if any_term {
        let lhs = |point: &Option<Vec<Fr>>, vs: &[Fr]| -> Fr {
            if point.is_none() {
                return Fr::ZERO;
            }
            vs.iter().zip(e_stack.iter()).map(|(v, e)| *v * *e).sum()
        };
        let claimed = gammas[0] * lhs(&pa1, &proof.va1)
            + gammas[1] * lhs(&pa2, &proof.va2)
            + gammas[2] * lhs(&qz1, &proof.vgz1)
            + gammas[3] * lhs(&qz2, &proof.vgz2);
        let stack = proof
            .stack
            .as_ref()
            .context("missing stack proof")
            .classify(VerifyFailureClass::Shape)?;
        let out = sumcheck::verify(claimed, stack, &mut tr)
            .classify(VerifyFailureClass::Sumcheck)
            .context("stack")?;
        let [v_sign, v_zdp, v_gap, _, _] = proof.aux_evals;
        let oms = Fr::ONE - v_sign;
        let term = |point: &Option<Vec<Fr>>, tensor_eval: Fr, gamma: Fr| -> Fr {
            match point {
                None => Fr::ZERO,
                Some(p) => {
                    let full: Vec<Fr> = [u_stack.clone(), p.clone()].concat();
                    gamma * eq_eval(&full, &out.point) * oms * tensor_eval
                }
            }
        };
        let expect = term(&pa1, v_zdp, gammas[0])
            + term(&pa2, v_zdp, gammas[1])
            + term(&qz1, v_gap, gammas[2])
            + term(&qz2, v_gap, gammas[3]);
        crate::ensure_class!(
            expect == out.final_claim,
            VerifyFailureClass::TranscriptBinding,
            "stack final claim mismatch"
        );
        out.point
    } else {
        crate::ensure_class!(
            proof.stack.is_none(),
            VerifyFailureClass::Shape,
            "unexpected stack proof"
        );
        tr.challenge_frs(b"stack/rho", log_s + log_dd)
    };
    tr.absorb_frs(b"aux/evals", &proof.aux_evals);
    let [v_sign, v_zdp, v_gap, v_rz, v_rga] = proof.aux_evals;

    // ---- Phase 3: opening checks (must mirror the prover's task order) ----
    drop(stack_span);
    let open_span = crate::telemetry::maybe_span("aggregate/openings");
    let gk = tk.g_aux.clone();
    let stack_expr = |get: &dyn Fn(&StepCommitmentSet) -> &Vec<G1Affine>| -> ComExpr {
        ComExpr::sum(
            proof
                .coms
                .iter()
                .flat_map(|set| get(set).iter().map(|p| p.to_projective())),
        )
    };
    let mut checks: Vec<(CommitKey, OpeningCheck)> = Vec::new();
    checks.push((
        gk.clone(),
        OpeningCheck {
            evec: eq_table(&rho),
            claims: vec![
                (stack_expr(&|s| &s.com_sign), v_sign),
                (stack_expr(&|s| &s.com_zdp), v_zdp),
                (stack_expr(&|s| &s.com_gap), v_gap),
                (stack_expr(&|s| &s.com_rz), v_rz),
                (stack_expr(&|s| &s.com_rga), v_rga),
            ],
        },
    ));
    {
        let pz: Vec<Fr> = [ch.u_zr.clone(), ch.u_zc.clone()].concat();
        let mut claims_z = Vec::with_capacity(n_zl);
        for (t, set) in proof.coms.iter().enumerate() {
            for l in 0..depth {
                claims_z.push((
                    derived_expr_z(
                        cfg,
                        set.com_zdp[l].to_projective(),
                        set.com_sign[l].to_projective(),
                        set.com_rz[l].to_projective(),
                    ),
                    proof.v_z[t * depth + l],
                ));
            }
        }
        checks.push((
            gk.clone(),
            OpeningCheck {
                evec: tiled_eq(&pz, slots),
                claims: claims_z,
            },
        ));
    }
    if depth >= 2 {
        let pga: Vec<Fr> = [ch.u_gar.clone(), ch.u_gac.clone()].concat();
        let mut claims_ga = Vec::with_capacity(n_inner);
        for (t, set) in proof.coms.iter().enumerate() {
            for l in 0..depth - 1 {
                claims_ga.push((
                    derived_expr_ga(
                        cfg,
                        set.com_gap[l].to_projective(),
                        set.com_rga[l].to_projective(),
                    ),
                    proof.v_ga[t * (depth - 1) + l],
                ));
            }
        }
        checks.push((
            gk.clone(),
            OpeningCheck {
                evec: tiled_eq(&pga, slots),
                claims: claims_ga,
            },
        ));
    }
    {
        let pgw: Vec<Fr> = [ch.u_gwr.clone(), ch.u_gwc.clone()].concat();
        let mut claims_gw = Vec::with_capacity(n_zl);
        for (t, set) in proof.coms.iter().enumerate() {
            for l in 0..depth {
                claims_gw.push((
                    ComExpr::point(set.com_gw[l].to_projective()),
                    proof.v_gw[t * depth + l],
                ));
            }
        }
        checks.push((
            tk.g_mat.clone(),
            OpeningCheck {
                evec: eq_table(&pgw),
                claims: claims_gw,
            },
        ));
    }
    {
        let p: Vec<Fr> = [r30.clone(), ch.u_zc.clone()].concat();
        let mut claims_w = Vec::with_capacity(n_zl);
        for (t, set) in proof.coms.iter().enumerate() {
            for l in 0..depth {
                claims_w.push((
                    ComExpr::point(set.com_w[l].to_projective()),
                    proof.mm30_evals[t * depth + l].1,
                ));
            }
        }
        checks.push((
            tk.g_mat.clone(),
            OpeningCheck {
                evec: eq_table(&p),
                claims: claims_w,
            },
        ));
    }
    if depth >= 2 {
        let p: Vec<Fr> = [ch.u_gac.clone(), r33.clone()].concat();
        let mut claims_w = Vec::with_capacity(n_inner);
        for (t, set) in proof.coms.iter().enumerate() {
            for l in 0..depth - 1 {
                claims_w.push((
                    ComExpr::point(set.com_w[l + 1].to_projective()),
                    proof.mm33_evals[t * (depth - 1) + l].1,
                ));
            }
        }
        checks.push((
            tk.g_mat.clone(),
            OpeningCheck {
                evec: eq_table(&p),
                claims: claims_w,
            },
        ));
    }
    {
        let p30: Vec<Fr> = [ch.u_zr.clone(), r30.clone()].concat();
        let claims_x: Vec<(ComExpr, Fr)> = proof
            .coms
            .iter()
            .enumerate()
            .map(|(t, set)| {
                (
                    ComExpr::point(set.com_x.to_projective()),
                    proof.mm30_evals[t * depth].0,
                )
            })
            .collect();
        checks.push((
            tk.g_x.clone(),
            OpeningCheck {
                evec: eq_table(&p30),
                claims: claims_x,
            },
        ));
        let p34: Vec<Fr> = [r34.clone(), ch.u_gwc.clone()].concat();
        let claims_x: Vec<(ComExpr, Fr)> = proof
            .coms
            .iter()
            .enumerate()
            .map(|(t, set)| {
                (
                    ComExpr::point(set.com_x.to_projective()),
                    proof.mm34_evals[t * depth].1,
                )
            })
            .collect();
        checks.push((
            tk.g_x.clone(),
            OpeningCheck {
                evec: eq_table(&p34),
                claims: claims_x,
            },
        ));
    }
    {
        let last = depth - 1;
        let gz_exprs: Vec<ComExpr> = proof
            .coms
            .iter()
            .map(|set| {
                derived_expr_gz_last(
                    cfg,
                    set.com_zdp[last].to_projective(),
                    set.com_sign[last].to_projective(),
                    set.com_y.to_projective(),
                )
            })
            .collect();
        let p: Vec<Fr> = [r34.clone(), ch.u_gwr.clone()].concat();
        let claims: Vec<(ComExpr, Fr)> = gz_exprs
            .iter()
            .enumerate()
            .map(|(t, expr)| (expr.clone(), proof.mm34_evals[t * depth + last].0))
            .collect();
        checks.push((
            gk.clone(),
            OpeningCheck {
                evec: tiled_eq(&p, slots),
                claims,
            },
        ));
        if depth >= 2 {
            let p: Vec<Fr> = [ch.u_gar.clone(), r33.clone()].concat();
            let claims: Vec<(ComExpr, Fr)> = gz_exprs
                .iter()
                .enumerate()
                .map(|(t, expr)| {
                    (
                        expr.clone(),
                        proof.mm33_evals[t * (depth - 1) + (depth - 2)].0,
                    )
                })
                .collect();
            checks.push((
                gk.clone(),
                OpeningCheck {
                    evec: tiled_eq(&p, slots),
                    claims,
                },
            ));
        }
    }

    crate::ensure_class!(
        proof.openings.len() == checks.len(),
        VerifyFailureClass::Shape,
        "opening count mismatch: {} vs {}",
        proof.openings.len(),
        checks.len()
    );
    for ((ck, check), opening) in checks.iter().zip(proof.openings.iter()) {
        ipa::batch_verify_eval_expr(ck, &check.claims, &check.evec, opening, &mut tr, acc)
            .classify(VerifyFailureClass::Opening)
            .context("batched opening")?;
    }

    // ---- Phase 4: validity ----
    drop(open_span);
    let validity_span = crate::telemetry::maybe_span("aggregate/validity");
    let u_dd = tr.challenge_fr(b"zkdl/u_dd");
    let mut vpoint = vec![u_dd];
    vpoint.extend_from_slice(&rho);
    let e_row = eq_table(&vpoint);
    let v = (Fr::ONE - u_dd) * v_zdp + u_dd * v_gap;
    let com_sign_stacked = stack_expr(&|s| &s.com_sign);
    zkrelu::verify_validity_accum(
        &vb_main,
        &proof.p1_main,
        Some(&com_sign_stacked),
        &e_row,
        u_dd,
        v,
        v_sign,
        &proof.validity_main,
        &mut tr,
        acc,
    )
    .classify(VerifyFailureClass::Validity)
    .context("main validity")?;
    let u_dd_r = tr.challenge_fr(b"zkdl/u_dd_rem");
    let mut vpoint_r = vec![u_dd_r];
    vpoint_r.extend_from_slice(&rho);
    let e_row_r = eq_table(&vpoint_r);
    let v_rem = (Fr::ONE - u_dd_r) * v_rz + u_dd_r * v_rga;
    zkrelu::verify_validity_accum(
        &vb_rem,
        &proof.p1_rem,
        None,
        &e_row_r,
        u_dd_r,
        v_rem,
        Fr::ZERO,
        &proof.validity_rem,
        &mut tr,
        acc,
    )
    .classify(VerifyFailureClass::Validity)
    .context("remainder validity")?;

    // ---- Phase 5: zkOptim chain argument (chained traces only) ----
    drop(validity_span);
    if let Some(chain) = &proof.chain {
        // key setup asserts on invalid dimensions; guard just the sizing
        // here so untrusted proofs fail cleanly — the full statement
        // validation (shift table, tensor counts) lives in
        // `verify_chain_accum`, its single source
        update::checked_stack_dims(cfg, t_steps, chain.rule.n_rem())
            .classify(VerifyFailureClass::Shape)
            .context("chained trace")?;
        let uk = UpdateKey::setup(*cfg, t_steps, &chain.rule);
        update::verify_chain_accum(&uk, &tk.g_mat, &proof.coms, chain, &mut tr, acc)
            .classify(VerifyFailureClass::ChainRelation)
            .context("zkOptim chain")?;
    }

    // ---- Phase 6: zkData batch-provenance argument ----
    if let Some(prov) = &proof.provenance {
        // sizing + structural guards before any key setup, so untrusted
        // proofs fail cleanly instead of panicking the verifier
        provenance::validate_provenance_shape(cfg, t_steps, prov)
            .classify(VerifyFailureClass::Shape)
            .context("provenance payload")?;
        let pkey = ProvenanceKey::setup(*cfg, t_steps, prov.dataset.n_rows);
        let y_slots: Vec<usize> = (0..t_steps).map(|t| t * lbar + (depth - 1)).collect();
        provenance::verify_provenance_accum(
            &pkey, &tk.g_x, &tk.g_aux, slots, &y_slots, &proof.coms, prov, &mut tr, acc,
        )
        .classify(VerifyFailureClass::ProvenanceSelection)
        .context("zkData provenance")?;
    }

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::witness::native::sgd_witness_chain;

    /// T consecutive SGD-step witnesses (weights actually updated between
    /// steps, as the coordinator would), validated before use.
    pub(crate) fn witness_chain(cfg: ModelConfig, steps: usize, seed: u64) -> Vec<StepWitness> {
        let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, seed ^ 0x77);
        let wits = sgd_witness_chain(cfg, &ds, steps, seed);
        for wit in &wits {
            wit.validate().expect("witness valid");
        }
        wits
    }

    #[test]
    fn dims_extend_stack_with_step_axis() {
        let cfg = ModelConfig::new(3, 8, 4);
        let (tbar, lbar, n) = trace_stack_dims(&cfg, 5);
        assert_eq!(tbar, 8);
        assert_eq!(lbar, 4);
        assert_eq!(n, 8 * 4 * cfg.d_size());
    }

    #[test]
    fn trace_roundtrip_single_step_depth1() {
        // smallest instance: no ReLU layers, no stack sumcheck
        let cfg = ModelConfig::new(1, 8, 4);
        let wits = witness_chain(cfg, 1, 0xa11);
        let tk = TraceKey::setup(cfg, 1);
        let mut rng = Rng::seed_from_u64(1);
        let proof = prove_trace(&tk, &wits, &mut rng);
        verify_trace(&tk, &proof).expect("verifies");
        assert!(proof.size_bytes() > 0);
    }

    #[test]
    fn verify_trace_accum_defers_to_exactly_one_msm() {
        let cfg = ModelConfig::new(2, 8, 4);
        let wits = witness_chain(cfg, 2, 0xb22);
        let tk = TraceKey::setup(cfg, 2);
        let mut rng = Rng::seed_from_u64(2);
        let proof = prove_trace(&tk, &wits, &mut rng);
        let mut seed = Rng::seed_from_u64(3);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        verify_trace_accum(&tk, &proof, &mut acc).expect("deferred verification");
        assert_eq!(acc.flushes(), 0, "no MSM before the flush");
        assert!(acc.flush(), "single aggregate MSM decides the trace");
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn chained_trace_verifies_with_exactly_one_msm_flush() {
        let cfg = ModelConfig::new(2, 8, 4);
        let wits = witness_chain(cfg, 3, 0xc0de);
        let tk = TraceKey::setup(cfg, 3);
        let mut rng = Rng::seed_from_u64(20);
        let proof = prove_trace_chained(&tk, &wits, &mut rng).expect("witnesses chain");
        assert!(proof.chain.is_some());
        verify_trace(&tk, &proof).expect("chained trace verifies");
        let mut seed = Rng::seed_from_u64(21);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        verify_trace_accum(&tk, &proof, &mut acc).expect("deferred verification");
        assert_eq!(acc.flushes(), 0, "no MSM before the flush");
        assert!(acc.flush(), "single aggregate MSM decides the chained trace");
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn momentum_chained_trace_verifies_with_exactly_one_msm_flush() {
        // the one-MSM invariant must survive the rule generalization: a
        // momentum chain (two relations, committed accumulator, decaying
        // shift table) still defers everything into one flush
        let cfg = ModelConfig::new(2, 8, 4);
        let rule = UpdateRule::momentum_default();
        let sched = LrSchedule::StepDecay {
            base: cfg.lr_shift,
            period: 1,
            max: cfg.lr_shift + 2,
        };
        let ds = Dataset::synthetic(64, cfg.width / 2, 4, cfg.r_bits, 0x7777);
        let wits = crate::witness::native::rule_witness_chain(cfg, &rule, &sched, &ds, 3, 0xd0d0);
        let tk = TraceKey::setup(cfg, 3);
        let mut rng = Rng::seed_from_u64(22);
        let table = sched.window_table(0, 2);
        let proof = prove_trace_chained_with(&tk, &wits, &rule, &table, &mut rng)
            .expect("momentum witnesses chain");
        let chain = proof.chain.as_ref().expect("chained");
        assert_eq!(chain.rule, rule);
        assert_eq!(chain.lr_shifts, table);
        assert_eq!(chain.com_state.len(), 1, "one committed accumulator slot");
        verify_trace(&tk, &proof).expect("momentum chained trace verifies");
        let mut seed = Rng::seed_from_u64(23);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        verify_trace_accum(&tk, &proof, &mut acc).expect("deferred verification");
        assert_eq!(acc.flushes(), 0, "no MSM before the flush");
        assert!(acc.flush(), "single aggregate MSM decides the momentum chain");
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn provenance_trace_verifies_with_exactly_one_msm_flush() {
        // the one-MSM invariant must survive the zkData extension: a trace
        // with the batch-selection argument (and its booleanity instance)
        // still defers everything into one flush
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(24, cfg.width / 2, 4, cfg.r_bits, 0x9a7a);
        let wits = sgd_witness_chain(cfg, &ds, 3, 0xf00d);
        let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
        let tk = TraceKey::setup(cfg, 3);
        let mut rng = Rng::seed_from_u64(30);
        let proof = prove_trace_provenance(&tk, &wits, &pd, &mut rng).expect("rows open");
        let prov = proof.provenance.as_ref().expect("provenance present");
        assert_eq!(prov.dataset.root, pd.commitment.root, "endorsed root rides the statement");
        verify_trace(&tk, &proof).expect("provenance trace verifies");
        let mut seed = Rng::seed_from_u64(31);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        verify_trace_accum(&tk, &proof, &mut acc).expect("deferred verification");
        assert_eq!(acc.flushes(), 0, "no MSM before the flush");
        assert!(acc.flush(), "single aggregate MSM decides the provenance trace");
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn chained_provenance_trace_verifies_with_exactly_one_msm_flush() {
        // chain + provenance together: the full "this model, this data"
        // statement still costs one MSM
        let cfg = ModelConfig::new(2, 8, 4);
        let ds = Dataset::synthetic(24, cfg.width / 2, 4, cfg.r_bits, 0x9a7b);
        let wits = sgd_witness_chain(cfg, &ds, 3, 0xf00e);
        let pd = ProverDataset::build(&ds, &cfg).expect("dataset commits");
        let tk = TraceKey::setup(cfg, 3);
        let mut rng = Rng::seed_from_u64(32);
        let shifts = vec![cfg.lr_shift; 2];
        let proof =
            prove_trace_chained_provenance_with(&tk, &wits, &UpdateRule::Sgd, &shifts, &pd, &mut rng)
                .expect("chains and opens");
        assert!(proof.chain.is_some() && proof.provenance.is_some());
        verify_trace(&tk, &proof).expect("chained provenance trace verifies");
        let mut seed = Rng::seed_from_u64(33);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        verify_trace_accum(&tk, &proof, &mut acc).expect("deferred verification");
        assert_eq!(acc.flushes(), 0, "no MSM before the flush");
        assert!(acc.flush());
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn traces_batch_one_msm_accepts_good_rejects_tampered() {
        let cfg = ModelConfig::new(2, 8, 4);
        let tk = TraceKey::setup(cfg, 1);
        let mut rng = Rng::seed_from_u64(4);
        let a = prove_trace(&tk, &witness_chain(cfg, 1, 0x1), &mut rng);
        let b = prove_trace(&tk, &witness_chain(cfg, 1, 0x2), &mut rng);

        // good batch: one MSM total, accepted
        let mut seed = Rng::seed_from_u64(5);
        let mut acc = MsmAccumulator::from_rng(&mut seed);
        for proof in [&a, &b] {
            acc.set_scale(Fr::random_nonzero(&mut seed));
            verify_trace_accum(&tk, proof, &mut acc).expect("defer");
        }
        assert_eq!(acc.flushes(), 0);
        assert!(acc.flush(), "good trace batch verifies with one MSM");

        let mut vrng = Rng::seed_from_u64(6);
        verify_traces_batch(&[(&tk, &a), (&tk, &b)], &mut vrng).expect("public batch API");

        // tamper one opening scalar — catchable only by the MSM check
        let mut bad = b.clone();
        bad.openings[0].a += Fr::ONE;
        verify_trace(&tk, &a).expect("untampered trace verifies alone");
        assert!(verify_trace(&tk, &bad).is_err(), "tampered trace fails alone");
        let mut vrng2 = Rng::seed_from_u64(7);
        assert!(
            verify_traces_batch(&[(&tk, &a), (&tk, &bad)], &mut vrng2).is_err(),
            "batch with exactly one tampered trace must fail"
        );
    }
}
