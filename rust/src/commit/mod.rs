//! Pedersen vector commitments (paper §3.1).
//!
//! Commit(v; r) = hʳ · Πᵢ gᵢ^{vᵢ} over BN254 G1, with deterministic
//! nothing-up-my-sleeve bases derived by hash-to-curve. The scheme is
//! homomorphic — the verifier exploits this everywhere in zkDL: deriving
//! com_Z from the committed auxiliary inputs via eq. (3)/(5), stacking
//! per-layer commitments, the Protocol-1 product com_B·com_{B'}, and the
//! Algorithm-1 basis transformations.
//!
//! Commitment keys are cached per (label, size): for large tensors the base
//! derivation itself is a measurable cost and the paper amortizes it as a
//! one-time setup.

use crate::curve::fixed::{self, FixedBaseTable, TableHandle};
use crate::curve::{derive_generators, msm::msm, G1Affine, G1};
use crate::field::Fr;
use crate::util::rng::Rng;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::Mutex;

/// A commitment key: vector basis `g` plus blinding base `h`.
///
/// Keys optionally carry a lazily-built [`FixedBaseTable`] over their full
/// basis (see [`CommitKey::warm_table`]); key slices share the parent's
/// handle with an offset, so a block commit against a slice of the stacked
/// aux basis hits the one table built at key setup.
#[derive(Clone, Debug)]
pub struct CommitKey {
    pub g: Vec<G1Affine>,
    pub h: G1Affine,
    pub label: Vec<u8>,
    /// Shared fixed-base table slot (empty until [`Self::warm_table`]).
    table: TableHandle,
    /// Position of `g[0]` within the basis the table was (or would be)
    /// built over — nonzero only for keys produced by [`Self::slice`].
    table_offset: usize,
}

static KEY_CACHE: Lazy<Mutex<HashMap<(Vec<u8>, usize), CommitKey>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

impl CommitKey {
    /// Derive (or fetch from cache) a key of size `n` under `label`.
    /// Different labels give bases with mutually unknown discrete logs.
    pub fn setup(label: &[u8], n: usize) -> Self {
        use crate::telemetry::{self, Counter};
        {
            let cache = KEY_CACHE.lock().unwrap();
            if let Some(k) = cache.get(&(label.to_vec(), n)) {
                telemetry::count(Counter::CommitKeyHits, 1);
                return k.clone();
            }
            // reuse a longer cached key with the same label: a prefix of a
            // hash-derived basis is itself a valid basis
            if let Some(k) = cache
                .iter()
                .filter(|((l, m), _)| l == label && *m >= n)
                .min_by_key(|((_, m), _)| *m)
                .map(|(_, k)| k)
            {
                telemetry::count(Counter::CommitKeyHits, 1);
                // share the longer key's table handle: the prefix starts
                // at offset 0 of the same derived basis, and table lookups
                // are length-guarded
                return CommitKey {
                    g: k.g[..n].to_vec(),
                    h: k.h,
                    label: label.to_vec(),
                    table: k.table.clone(),
                    table_offset: 0,
                };
            }
        }
        telemetry::count(Counter::CommitKeyMisses, 1);
        let g = derive_generators(label, n);
        let mut blind_label = label.to_vec();
        blind_label.extend_from_slice(b"/blind");
        let h = crate::curve::hash_to_curve(&blind_label, u64::MAX);
        let key = CommitKey {
            g,
            h,
            label: label.to_vec(),
            table: TableHandle::default(),
            table_offset: 0,
        };
        KEY_CACHE
            .lock()
            .unwrap()
            .insert((label.to_vec(), n), key.clone());
        key
    }

    /// Assemble a key from explicit bases — for ad-hoc composed bases
    /// (e.g. a stacked key concatenated from block slices). Starts with an
    /// empty table slot; call [`Self::warm_table`] if the composition is
    /// long-lived.
    pub fn from_parts(g: Vec<G1Affine>, h: G1Affine, label: Vec<u8>) -> Self {
        CommitKey {
            g,
            h,
            label,
            table: TableHandle::default(),
            table_offset: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.g.len()
    }

    pub fn is_empty(&self) -> bool {
        self.g.is_empty()
    }

    /// Build this key's fixed-base table if eligible (full-basis key, at
    /// most [`fixed::MAX_POINTS`] points). Call from key *setup* paths so
    /// the build cost lands outside proved/timed regions; every clone and
    /// slice of the key (including the cached copy) sees the warm table.
    pub fn warm_table(&self) {
        if self.table_offset == 0 && !self.g.is_empty() && self.g.len() <= fixed::MAX_POINTS {
            self.table.get_or_build(&self.g);
        }
    }

    /// Shared table handle (for the one-MSM accumulator's fixed blocks).
    pub(crate) fn table_handle(&self) -> &TableHandle {
        &self.table
    }

    /// The warm table covering a `len`-scalar query against this key,
    /// with this key's offset into it — `None` if no table was built or
    /// it is too short (a shorter prefix key may have built it first).
    pub(crate) fn table_for(&self, len: usize) -> Option<(&FixedBaseTable, usize)> {
        let t = self.table.get()?;
        (self.table_offset + len <= t.len()).then_some((t, self.table_offset))
    }

    /// Σᵢ scalars[i]·g[i] over the basis prefix, via the fixed-base table
    /// when warm (counted as `msm/table_hits`) and plain Pippenger
    /// otherwise. All commitment MSMs route through here.
    pub fn msm_prefix(&self, scalars: &[Fr]) -> G1 {
        assert!(scalars.len() <= self.g.len(), "commit key too short");
        match self.table_for(scalars.len()) {
            Some((t, off)) => t.msm_range(off, scalars),
            None => msm(&self.g[..scalars.len()], scalars),
        }
    }

    /// Commit to `values` (≤ key length; implicitly zero-padded) with
    /// blinding `r`.
    pub fn commit(&self, values: &[Fr], r: Fr) -> G1 {
        let mut acc = self.msm_prefix(values);
        if !r.is_zero() {
            acc = acc.add(&self.h.to_projective().mul(&r));
        }
        acc
    }

    /// The sub-key over `g[start..end]` (same `h`, same label). Shares the
    /// parent's table handle with an adjusted offset, so slice commits hit
    /// the parent's table.
    pub fn slice(&self, start: usize, end: usize) -> CommitKey {
        CommitKey {
            g: self.g[start..end].to_vec(),
            h: self.h,
            label: self.label.clone(),
            table: self.table.clone(),
            table_offset: self.table_offset + start,
        }
    }

    /// Deterministic commitment (r = 0) — used for data-point commitments
    /// feeding the Merkle tree (paper §3.1 "randomness set to 0").
    pub fn commit_deterministic(&self, values: &[Fr]) -> G1 {
        self.commit(values, Fr::ZERO)
    }

    /// Commit with fresh randomness drawn from `rng`; returns (com, r).
    pub fn commit_hiding(&self, values: &[Fr], rng: &mut Rng) -> (G1, Fr) {
        let r = Fr::random(rng);
        (self.commit(values, r), r)
    }

    /// Split into two half keys (for IPA recursion bases).
    pub fn split_at(&self, mid: usize) -> (CommitKey, CommitKey) {
        (self.slice(0, mid), self.slice(mid, self.g.len()))
    }
}

/// A commitment with its opening (prover side).
#[derive(Clone, Debug)]
pub struct Opening {
    pub values: Vec<Fr>,
    pub blind: Fr,
}

/// Homomorphic combination: Π comᵢ^{cᵢ} (e.g. random linear combination of
/// commitments; exponents are public).
pub fn combine(coms: &[G1], coeffs: &[Fr]) -> G1 {
    assert_eq!(coms.len(), coeffs.len());
    let affine = G1::batch_to_affine(coms);
    msm(&affine, coeffs)
}

/// A commitment kept *symbolic* as a public linear combination Σ cᵢ·Pᵢ of
/// points, so that verifiers can defer its evaluation into the one-MSM
/// engine (`curve::accum::MsmAccumulator`) instead of performing eager
/// scalar multiplications. Every derived commitment the zkDL verifier
/// checks — eq. (3)/(5)/(32) combinations, stacked aux commitments, RLC'd
/// opening batches — is one of these.
///
/// Soundness note: the deferred-absorption IPA variants
/// (`ipa::batch_verify_eval_expr`) skip re-absorbing the combined
/// commitment into the transcript, so the constituent points of a
/// `ComExpr` MUST already be transcript-bound (they are: every proof point
/// is absorbed before any challenge is drawn) and the coefficients must be
/// public constants or transcript challenges.
#[derive(Clone, Debug, Default)]
pub struct ComExpr {
    pub terms: Vec<(Fr, G1)>,
}

impl ComExpr {
    /// The single point `p` with coefficient 1.
    pub fn point(p: G1) -> Self {
        Self {
            terms: vec![(Fr::ONE, p)],
        }
    }

    /// Σᵢ pᵢ with unit coefficients.
    pub fn sum<I: IntoIterator<Item = G1>>(points: I) -> Self {
        Self {
            terms: points.into_iter().map(|p| (Fr::ONE, p)).collect(),
        }
    }

    pub fn push(&mut self, coeff: Fr, point: G1) {
        self.terms.push((coeff, point));
    }

    /// Materialize the combination (wrappers and tests only — the verifier
    /// hot path defers instead).
    pub fn eval(&self) -> G1 {
        let (coeffs, points): (Vec<Fr>, Vec<G1>) = self.terms.iter().copied().unzip();
        combine(&points, &coeffs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(0xc0117)
    }

    #[test]
    fn homomorphic_addition() {
        let ck = CommitKey::setup(b"test", 8);
        let mut r = rng();
        let a: Vec<Fr> = (0..8).map(|_| Fr::random(&mut r)).collect();
        let b: Vec<Fr> = (0..8).map(|_| Fr::random(&mut r)).collect();
        let ra = Fr::random(&mut r);
        let rb = Fr::random(&mut r);
        let ca = ck.commit(&a, ra);
        let cb = ck.commit(&b, rb);
        let sum: Vec<Fr> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        assert_eq!(ca + cb, ck.commit(&sum, ra + rb));
    }

    #[test]
    fn homomorphic_scaling() {
        let ck = CommitKey::setup(b"test", 4);
        let mut r = rng();
        let a: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let ra = Fr::random(&mut r);
        let k = Fr::random(&mut r);
        let scaled: Vec<Fr> = a.iter().map(|x| *x * k).collect();
        assert_eq!(
            ck.commit(&a, ra).mul(&k),
            ck.commit(&scaled, ra * k)
        );
    }

    #[test]
    fn binding_different_values() {
        let ck = CommitKey::setup(b"test", 4);
        let mut r = rng();
        let a: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let mut b = a.clone();
        b[2] += Fr::ONE;
        let blind = Fr::random(&mut r);
        assert_ne!(ck.commit(&a, blind), ck.commit(&b, blind));
    }

    #[test]
    fn hiding_blind_changes_commitment() {
        let ck = CommitKey::setup(b"test", 4);
        let mut r = rng();
        let a: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        assert_ne!(
            ck.commit(&a, Fr::from_u64(1)),
            ck.commit(&a, Fr::from_u64(2))
        );
    }

    #[test]
    fn warm_table_matches_cold_commits() {
        let ck = CommitKey::setup(b"tabletest", 32);
        let mut r = rng();
        let a: Vec<Fr> = (0..32).map(|_| Fr::random(&mut r)).collect();
        let blind = Fr::random(&mut r);
        let cold_full = ck.commit(&a, blind);
        let cold_prefix = ck.commit(&a[..9], blind);
        let cold_slice = ck.slice(4, 20).commit(&a[4..20], blind);
        ck.warm_table();
        assert!(ck.table_handle().is_warm());
        assert_eq!(ck.commit(&a, blind), cold_full);
        assert_eq!(ck.commit(&a[..9], blind), cold_prefix);
        // a slice taken after warming shares the table via its offset
        assert_eq!(ck.slice(4, 20).commit(&a[4..20], blind), cold_slice);
        // split halves too (IPA recursion bases)
        let (lo, hi) = ck.split_at(16);
        assert_eq!(
            lo.commit(&a[..16], Fr::ZERO).add(&hi.commit(&a[16..], Fr::ZERO)),
            ck.commit(&a, Fr::ZERO)
        );
    }

    #[test]
    fn short_table_guard_falls_back() {
        // Warm a shorter prefix key first: the longer key shares the
        // handle but must fall back to plain Pippenger rather than query
        // past the table's end.
        let big = CommitKey::setup(b"tableguard", 16);
        let small = CommitKey::setup(b"tableguard", 8);
        small.warm_table();
        assert!(big.table_handle().is_warm());
        assert!(big.table_for(16).is_none());
        assert!(small.table_for(8).is_some());
        let mut r = rng();
        let a: Vec<Fr> = (0..16).map(|_| Fr::random(&mut r)).collect();
        assert_eq!(
            big.commit(&a, Fr::ZERO),
            crate::curve::msm::msm(&big.g, &a)
        );
    }

    #[test]
    fn cache_and_prefix_reuse() {
        let big = CommitKey::setup(b"cachetest", 16);
        let small = CommitKey::setup(b"cachetest", 8);
        assert_eq!(&big.g[..8], &small.g[..]);
        assert_eq!(big.h, small.h);
    }

    #[test]
    fn combine_matches_manual() {
        let ck = CommitKey::setup(b"test", 4);
        let mut r = rng();
        let a: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let b: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let ca = ck.commit(&a, Fr::ZERO);
        let cb = ck.commit(&b, Fr::ZERO);
        let k1 = Fr::random(&mut r);
        let k2 = Fr::random(&mut r);
        let rlc: Vec<Fr> = a
            .iter()
            .zip(&b)
            .map(|(x, y)| *x * k1 + *y * k2)
            .collect();
        assert_eq!(combine(&[ca, cb], &[k1, k2]), ck.commit(&rlc, Fr::ZERO));
    }

    #[test]
    fn zero_padding_consistent() {
        let ck = CommitKey::setup(b"test", 8);
        let a = vec![Fr::from_u64(3), Fr::from_u64(5)];
        let padded = vec![
            Fr::from_u64(3),
            Fr::from_u64(5),
            Fr::ZERO,
            Fr::ZERO,
            Fr::ZERO,
            Fr::ZERO,
            Fr::ZERO,
            Fr::ZERO,
        ];
        let r = Fr::from_u64(7);
        assert_eq!(ck.commit(&a, r), ck.commit(&padded, r));
    }
}
