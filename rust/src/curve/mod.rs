//! BN254 (alt-bn128) G1 group used by the Pedersen commitment scheme.
//!
//! Curve: y² = x³ + 3 over Fq, prime group order r (= [`Fr`]'s modulus),
//! cofactor 1, generator (1, 2). Jacobian coordinates for arithmetic,
//! affine for storage and transcript serialization.

pub mod accum;
pub mod fixed;
pub mod msm;

use crate::field::{Fq, Fr};
use crate::util::rng::Rng;

/// Affine point; `infinity` flag encodes the identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct G1Affine {
    pub x: Fq,
    pub y: Fq,
    pub infinity: bool,
}

/// Jacobian point (X/Z², Y/Z³); Z = 0 encodes the identity.
#[derive(Clone, Copy, Debug)]
pub struct G1 {
    pub x: Fq,
    pub y: Fq,
    pub z: Fq,
}

const CURVE_B: u64 = 3;

impl G1Affine {
    pub const IDENTITY: Self = Self {
        x: Fq::ZERO,
        y: Fq::ZERO,
        infinity: true,
    };

    /// The standard generator (1, 2).
    pub fn generator() -> Self {
        Self {
            x: Fq::from_u64(1),
            y: Fq::from_u64(2),
            infinity: false,
        }
    }

    pub fn is_on_curve(&self) -> bool {
        if self.infinity {
            return true;
        }
        self.y.square() == self.x.square() * self.x + Fq::from_u64(CURVE_B)
    }

    pub fn to_projective(&self) -> G1 {
        if self.infinity {
            G1::IDENTITY
        } else {
            G1 {
                x: self.x,
                y: self.y,
                z: Fq::ONE,
            }
        }
    }

    pub fn neg(&self) -> Self {
        Self {
            x: self.x,
            y: -self.y,
            infinity: self.infinity,
        }
    }

    /// 64-byte uncompressed encoding (x ‖ y little-endian); identity is all
    /// zeros (x=y=0 is not on the curve, so the encoding is unambiguous).
    pub fn to_bytes(&self) -> [u8; 64] {
        let mut out = [0u8; 64];
        if !self.infinity {
            out[..32].copy_from_slice(&self.x.to_bytes());
            out[32..].copy_from_slice(&self.y.to_bytes());
        }
        out
    }

    /// Parse the [`Self::to_bytes`] encoding. All-zero bytes decode to the
    /// identity; anything else must be a canonical (fully reduced) coordinate
    /// pair on the curve — BN254 has cofactor 1, so on-curve implies
    /// in-group. Returns `None` for any malformed encoding.
    pub fn from_bytes(bytes: &[u8; 64]) -> Option<Self> {
        if bytes.iter().all(|&b| b == 0) {
            return Some(Self::IDENTITY);
        }
        let mut xb = [0u8; 32];
        let mut yb = [0u8; 32];
        xb.copy_from_slice(&bytes[..32]);
        yb.copy_from_slice(&bytes[32..]);
        let x = Fq::from_bytes(&xb);
        let y = Fq::from_bytes(&yb);
        // `Fq::from_bytes` reduces silently; demand canonical encodings so
        // every point has exactly one wire representation.
        if x.to_bytes() != xb || y.to_bytes() != yb {
            return None;
        }
        let p = Self {
            x,
            y,
            infinity: false,
        };
        p.is_on_curve().then_some(p)
    }

    /// 32-byte compressed encoding: canonical little-endian x with the sign
    /// of y in bit 7 of byte 31 (the parity of y's canonical representative;
    /// q < 2²⁵⁴, so the top two bits of a canonical x are always clear) and
    /// an identity flag in bit 6. This is the representation the paper's
    /// proof-size figures count, and the wire format serializes.
    pub fn to_bytes_compressed(&self) -> [u8; 32] {
        let mut out = [0u8; 32];
        if self.infinity {
            out[31] = COMPRESSED_INFINITY_BIT;
            return out;
        }
        out.copy_from_slice(&self.x.to_bytes());
        debug_assert_eq!(out[31] & (COMPRESSED_SIGN_BIT | COMPRESSED_INFINITY_BIT), 0);
        if self.y.to_repr()[0] & 1 == 1 {
            out[31] |= COMPRESSED_SIGN_BIT;
        }
        out
    }

    /// Decode a batch of compressed encodings in one pass — the bulk-load
    /// path for `verify-trace` artifacts, whose point vectors dominate
    /// file-decode time on big traces. One sweep parses and canonicality-
    /// checks every x and computes the y² = x³ + 3 candidates; the square
    /// roots — one ~254-bit exponentiation each, the irreducible per-point
    /// cost (roots, unlike inverses, admit no Montgomery-style product
    /// sharing: the ± ambiguity makes individual roots unrecoverable from
    /// a combined root) — then run data-parallel across worker threads,
    /// and a final sweep validates each candidate and selects the signed
    /// root. Exactly equivalent to [`Self::from_bytes_compressed`] per
    /// element (a unit test pins this); returns `None` if *any* encoding
    /// is malformed.
    pub fn batch_from_bytes_compressed(encodings: &[[u8; 32]]) -> Option<Vec<Self>> {
        // pass 1: flags + canonical x + y² candidates (cheap, sequential)
        struct Parsed {
            x: Fq,
            want_odd: bool,
            /// index into the sqrt batch; identity points have none
            sqrt_slot: Option<usize>,
        }
        let mut parsed = Vec::with_capacity(encodings.len());
        let mut y2s: Vec<Fq> = Vec::with_capacity(encodings.len());
        for bytes in encodings {
            let flags = bytes[31] & (COMPRESSED_SIGN_BIT | COMPRESSED_INFINITY_BIT);
            let mut xb = *bytes;
            xb[31] &= !(COMPRESSED_SIGN_BIT | COMPRESSED_INFINITY_BIT);
            if flags & COMPRESSED_INFINITY_BIT != 0 {
                if flags != COMPRESSED_INFINITY_BIT || xb.iter().any(|&b| b != 0) {
                    return None;
                }
                parsed.push(Parsed {
                    x: Fq::ZERO,
                    want_odd: false,
                    sqrt_slot: None,
                });
                continue;
            }
            let x = Fq::from_bytes(&xb);
            if x.to_bytes() != xb {
                return None;
            }
            parsed.push(Parsed {
                x,
                want_odd: flags & COMPRESSED_SIGN_BIT != 0,
                sqrt_slot: Some(y2s.len()),
            });
            y2s.push(x.square() * x + Fq::from_u64(CURVE_B));
        }
        // pass 2: the square-root exponentiations, across threads
        let roots = crate::util::threads::par_map(y2s, |y2| y2.sqrt());
        // pass 3: validate + sign-select (sqrt() already verified s² = y²)
        let mut out = Vec::with_capacity(parsed.len());
        for p in parsed {
            let Some(slot) = p.sqrt_slot else {
                out.push(Self::IDENTITY);
                continue;
            };
            let y = roots[slot]?;
            if y.is_zero() && p.want_odd {
                return None;
            }
            let y = if (y.to_repr()[0] & 1 == 1) == p.want_odd {
                y
            } else {
                -y
            };
            out.push(Self {
                x: p.x,
                y,
                infinity: false,
            });
        }
        Some(out)
    }

    /// Parse the [`Self::to_bytes_compressed`] encoding. Rejects
    /// non-canonical x coordinates, x with no square root of x³ + 3 (not a
    /// curve point), and malformed identity encodings, so every group
    /// element has exactly one compressed byte representation.
    pub fn from_bytes_compressed(bytes: &[u8; 32]) -> Option<Self> {
        let flags = bytes[31] & (COMPRESSED_SIGN_BIT | COMPRESSED_INFINITY_BIT);
        let mut xb = *bytes;
        xb[31] &= !(COMPRESSED_SIGN_BIT | COMPRESSED_INFINITY_BIT);
        if flags & COMPRESSED_INFINITY_BIT != 0 {
            // identity: the flag alone, no sign bit, zero x
            if flags != COMPRESSED_INFINITY_BIT || xb.iter().any(|&b| b != 0) {
                return None;
            }
            return Some(Self::IDENTITY);
        }
        let x = Fq::from_bytes(&xb);
        // `Fq::from_bytes` reduces silently; demand the canonical encoding
        if x.to_bytes() != xb {
            return None;
        }
        let y2 = x.square() * x + Fq::from_u64(CURVE_B);
        let y = y2.sqrt()?;
        let want_odd = flags & COMPRESSED_SIGN_BIT != 0;
        // y = 0 would make both signs encode identically; no such point
        // exists on an odd-order curve, but reject the malformed encoding
        if y.is_zero() && want_odd {
            return None;
        }
        let y = if (y.to_repr()[0] & 1 == 1) == want_odd {
            y
        } else {
            -y
        };
        Some(Self {
            x,
            y,
            infinity: false,
        })
    }
}

/// Flag bits of the compressed encoding (free because q < 2²⁵⁴).
const COMPRESSED_SIGN_BIT: u8 = 0x80;
const COMPRESSED_INFINITY_BIT: u8 = 0x40;

impl G1 {
    pub const IDENTITY: Self = Self {
        x: Fq::ONE,
        y: Fq::ONE,
        z: Fq::ZERO,
    };

    pub fn generator() -> Self {
        G1Affine::generator().to_projective()
    }

    #[inline]
    pub fn is_identity(&self) -> bool {
        self.z.is_zero()
    }

    /// Point doubling (Jacobian, a = 0 formulas).
    pub fn double(&self) -> Self {
        if self.is_identity() {
            return *self;
        }
        // http://hyperelliptic.org/EFD/g1p/auto-shortw-jacobian-0.html#doubling-dbl-2009-l
        let a = self.x.square();
        let b = self.y.square();
        let c = b.square();
        let d = ((self.x + b).square() - a - c).double();
        let e = a.double() + a;
        let f = e.square();
        let x3 = f - d.double();
        let y3 = e * (d - x3) - c.double().double().double();
        let z3 = (self.y * self.z).double();
        Self {
            x: x3,
            y: y3,
            z: z3,
        }
    }

    /// Mixed addition: self (Jacobian) + other (affine).
    pub fn add_affine(&self, other: &G1Affine) -> Self {
        if other.infinity {
            return *self;
        }
        if self.is_identity() {
            return other.to_projective();
        }
        // madd-2007-bl
        let z1z1 = self.z.square();
        let u2 = other.x * z1z1;
        let s2 = other.y * self.z * z1z1;
        if u2 == self.x {
            if s2 == self.y {
                return self.double();
            }
            return Self::IDENTITY;
        }
        let h = u2 - self.x;
        let hh = h.square();
        let i = hh.double().double();
        let j = h * i;
        let r = (s2 - self.y).double();
        let v = self.x * i;
        let x3 = r.square() - j - v.double();
        let y3 = r * (v - x3) - (self.y * j).double();
        let z3 = (self.z + h).square() - z1z1 - hh;
        Self {
            x: x3,
            y: y3,
            z: z3,
        }
    }

    /// Full Jacobian addition.
    pub fn add(&self, other: &Self) -> Self {
        if self.is_identity() {
            return *other;
        }
        if other.is_identity() {
            return *self;
        }
        // add-2007-bl
        let z1z1 = self.z.square();
        let z2z2 = other.z.square();
        let u1 = self.x * z2z2;
        let u2 = other.x * z1z1;
        let s1 = self.y * other.z * z2z2;
        let s2 = other.y * self.z * z1z1;
        if u1 == u2 {
            if s1 == s2 {
                return self.double();
            }
            return Self::IDENTITY;
        }
        let h = u2 - u1;
        let i = h.double().square();
        let j = h * i;
        let r = (s2 - s1).double();
        let v = u1 * i;
        let x3 = r.square() - j - v.double();
        let y3 = r * (v - x3) - (s1 * j).double();
        let z3 = ((self.z + other.z).square() - z1z1 - z2z2) * h;
        Self {
            x: x3,
            y: y3,
            z: z3,
        }
    }

    pub fn neg(&self) -> Self {
        Self {
            x: self.x,
            y: -self.y,
            z: self.z,
        }
    }

    /// Scalar multiplication (double-and-add over the canonical bits).
    pub fn mul(&self, scalar: &Fr) -> Self {
        let bits = scalar.to_repr();
        let mut acc = Self::IDENTITY;
        let mut started = false;
        for i in (0..4).rev() {
            for b in (0..64).rev() {
                if started {
                    acc = acc.double();
                }
                if (bits[i] >> b) & 1 == 1 {
                    acc = acc.add(self);
                    started = true;
                }
            }
        }
        acc
    }

    pub fn to_affine(&self) -> G1Affine {
        if self.is_identity() {
            return G1Affine::IDENTITY;
        }
        let zinv = self.z.inverse().unwrap();
        let zinv2 = zinv.square();
        G1Affine {
            x: self.x * zinv2,
            y: self.y * zinv2 * zinv,
            infinity: false,
        }
    }

    /// Normalize many points with one field inversion (Montgomery's trick).
    pub fn batch_to_affine(points: &[Self]) -> Vec<G1Affine> {
        let mut zs: Vec<Fq> = points.iter().map(|p| p.z).collect();
        Fq::batch_invert(&mut zs);
        points
            .iter()
            .zip(zs.iter())
            .map(|(p, zinv)| {
                if p.is_identity() {
                    G1Affine::IDENTITY
                } else {
                    let zinv2 = zinv.square();
                    G1Affine {
                        x: p.x * zinv2,
                        y: p.y * zinv2 * *zinv,
                        infinity: false,
                    }
                }
            })
            .collect()
    }

    /// Uniformly random group element (random scalar times the generator).
    pub fn random(rng: &mut Rng) -> Self {
        Self::generator().mul(&Fr::random(rng))
    }
}

impl PartialEq for G1 {
    /// Equality in the group (cross-multiplied Jacobian comparison).
    fn eq(&self, other: &Self) -> bool {
        if self.is_identity() {
            return other.is_identity();
        }
        if other.is_identity() {
            return false;
        }
        let z1z1 = self.z.square();
        let z2z2 = other.z.square();
        self.x * z2z2 == other.x * z1z1
            && self.y * z2z2 * other.z == other.y * z1z1 * self.z
    }
}
impl Eq for G1 {}

impl core::ops::Add for G1 {
    type Output = G1;
    fn add(self, rhs: Self) -> G1 {
        G1::add(&self, &rhs)
    }
}
impl core::ops::AddAssign for G1 {
    fn add_assign(&mut self, rhs: Self) {
        *self = G1::add(self, &rhs);
    }
}
impl core::ops::Neg for G1 {
    type Output = G1;
    fn neg(self) -> G1 {
        G1::neg(&self)
    }
}
impl core::iter::Sum for G1 {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(G1::IDENTITY, |a, b| a + b)
    }
}

/// Derive a deterministic, nothing-up-my-sleeve generator from a seed label
/// and index by try-and-increment: x = H(label ‖ i ‖ ctr) as a field element,
/// solve y² = x³ + 3 (q ≡ 3 mod 4 so sqrt is a single exponentiation), clear
/// nothing (cofactor 1). Independent of the standard generator's dlog.
pub fn hash_to_curve(label: &[u8], index: u64) -> G1Affine {
    use sha2::{Digest, Sha256};
    let mut ctr: u64 = 0;
    loop {
        let mut h = Sha256::new();
        h.update(b"zkdl/hash-to-curve/v1");
        h.update(label);
        h.update(index.to_le_bytes());
        h.update(ctr.to_le_bytes());
        let d1 = h.finalize();
        let mut h2 = Sha256::new();
        h2.update(b"zkdl/hash-to-curve/v1/extend");
        h2.update(d1);
        let d2 = h2.finalize();
        let mut wide = [0u8; 64];
        wide[..32].copy_from_slice(&d1);
        wide[32..].copy_from_slice(&d2);
        let x = Fq::from_bytes_wide(&wide);
        let y2 = x.square() * x + Fq::from_u64(CURVE_B);
        if let Some(y) = y2.sqrt() {
            // canonicalize sign by parity of the canonical repr
            let y = if y.to_repr()[0] & 1 == 0 { y } else { -y };
            let p = G1Affine {
                x,
                y,
                infinity: false,
            };
            debug_assert!(p.is_on_curve());
            return p;
        }
        ctr += 1;
    }
}

/// Derive `n` independent generators for a vector commitment basis.
/// Parallelized: each point is an independent hash-to-curve evaluation.
pub fn derive_generators(label: &[u8], n: usize) -> Vec<G1Affine> {
    crate::util::threads::par_map_indexed(n, |i| hash_to_curve(label, i as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(0xc0de)
    }

    #[test]
    fn generator_on_curve() {
        assert!(G1Affine::generator().is_on_curve());
    }

    #[test]
    fn group_laws() {
        let mut r = rng();
        let p = G1::random(&mut r);
        let q = G1::random(&mut r);
        let s = G1::random(&mut r);
        assert_eq!(p + q, q + p);
        assert_eq!((p + q) + s, p + (q + s));
        assert_eq!(p + G1::IDENTITY, p);
        assert_eq!(p + p.neg(), G1::IDENTITY);
        assert_eq!(p.double(), p + p);
        assert!(p.to_affine().is_on_curve());
    }

    #[test]
    fn mixed_addition_matches() {
        let mut r = rng();
        let p = G1::random(&mut r);
        let q = G1::random(&mut r);
        let qa = q.to_affine();
        assert_eq!(p.add_affine(&qa), p + q);
        // doubling path
        assert_eq!(p.add_affine(&p.to_affine()), p.double());
        // inverse path
        assert_eq!(p.add_affine(&p.neg().to_affine()), G1::IDENTITY);
    }

    #[test]
    fn scalar_mul_properties() {
        let mut r = rng();
        let p = G1::random(&mut r);
        let a = Fr::random(&mut r);
        let b = Fr::random(&mut r);
        // (a+b)P = aP + bP
        assert_eq!(p.mul(&(a + b)), p.mul(&a) + p.mul(&b));
        // (ab)P = a(bP)
        assert_eq!(p.mul(&(a * b)), p.mul(&b).mul(&a));
        assert_eq!(p.mul(&Fr::ZERO), G1::IDENTITY);
        assert_eq!(p.mul(&Fr::ONE), p);
        assert_eq!(p.mul(&Fr::from_u64(5)), p + p + p + p + p);
    }

    #[test]
    fn order_annihilates() {
        // r·G = identity: scalar r ≡ 0 in Fr, so multiply by (r-1) and add G
        let g = G1::generator();
        let r_minus_1 = -Fr::ONE;
        assert_eq!(g.mul(&r_minus_1) + g, G1::IDENTITY);
    }

    #[test]
    fn batch_to_affine_matches() {
        let mut r = rng();
        let pts: Vec<G1> = (0..17).map(|_| G1::random(&mut r)).collect();
        let batch = G1::batch_to_affine(&pts);
        for (p, a) in pts.iter().zip(batch.iter()) {
            assert_eq!(p.to_affine(), *a);
        }
    }

    #[test]
    fn hash_to_curve_deterministic_and_distinct() {
        let a = hash_to_curve(b"test", 0);
        let b = hash_to_curve(b"test", 0);
        let c = hash_to_curve(b"test", 1);
        let d = hash_to_curve(b"other", 0);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        assert!(a.is_on_curve() && c.is_on_curve() && d.is_on_curve());
    }

    #[test]
    fn affine_bytes_unambiguous() {
        let mut r = rng();
        let p = G1::random(&mut r).to_affine();
        assert_ne!(p.to_bytes(), G1Affine::IDENTITY.to_bytes());
    }

    #[test]
    fn compressed_roundtrip_both_signs_and_identity() {
        let mut r = rng();
        for _ in 0..20 {
            let p = G1::random(&mut r).to_affine();
            let n = p.neg();
            let pc = p.to_bytes_compressed();
            let nc = n.to_bytes_compressed();
            // same x, opposite sign bit
            assert_eq!(&pc[..31], &nc[..31]);
            assert_eq!(pc[31] ^ nc[31], 0x80);
            assert_eq!(G1Affine::from_bytes_compressed(&pc), Some(p));
            assert_eq!(G1Affine::from_bytes_compressed(&nc), Some(n));
        }
        let id = G1Affine::IDENTITY.to_bytes_compressed();
        assert_eq!(G1Affine::from_bytes_compressed(&id), Some(G1Affine::IDENTITY));
        assert_eq!(id[31], 0x40);
        assert!(id[..31].iter().all(|&b| b == 0));
    }

    #[test]
    fn compressed_rejects_malformed() {
        // non-canonical x: the field modulus itself (reduces to 0)
        let mut nc = [0u8; 32];
        let q_le: [u64; 4] =
            <crate::field::FqParams as crate::field::FieldParams>::MODULUS;
        for i in 0..4 {
            nc[i * 8..i * 8 + 8].copy_from_slice(&q_le[i].to_le_bytes());
        }
        assert!(G1Affine::from_bytes_compressed(&nc).is_none());
        // identity flag with a sign bit or nonzero x
        let mut bad = [0u8; 32];
        bad[31] = 0xc0;
        assert!(G1Affine::from_bytes_compressed(&bad).is_none());
        let mut bad = [0u8; 32];
        bad[31] = 0x40;
        bad[0] = 1;
        assert!(G1Affine::from_bytes_compressed(&bad).is_none());
        // some x in 0..32 must have no square root of x³+3 (half the field
        // elements are non-residues; all-residue runs of 32 don't happen)
        let rejected = (0u64..32).any(|v| {
            let mut b = [0u8; 32];
            b[..8].copy_from_slice(&v.to_le_bytes());
            G1Affine::from_bytes_compressed(&b).is_none()
        });
        assert!(rejected, "expected at least one non-residue x below 32");
    }

    #[test]
    fn batch_decompression_matches_scalar_path() {
        let mut r = rng();
        // a mixed batch: random points, both signs, and identities sprinkled
        let mut encs: Vec<[u8; 32]> = Vec::new();
        let mut expect: Vec<G1Affine> = Vec::new();
        for i in 0..37 {
            let p = if i % 7 == 3 {
                G1Affine::IDENTITY
            } else if i % 2 == 0 {
                G1::random(&mut r).to_affine()
            } else {
                G1::random(&mut r).to_affine().neg()
            };
            encs.push(p.to_bytes_compressed());
            expect.push(p);
        }
        let batch = G1Affine::batch_from_bytes_compressed(&encs).expect("all valid");
        assert_eq!(batch.len(), expect.len());
        for (i, (b, e)) in batch.iter().zip(expect.iter()).enumerate() {
            assert_eq!(b, e, "batch element {i} diverges from scalar decode");
            assert_eq!(
                Some(*b),
                G1Affine::from_bytes_compressed(&encs[i]),
                "scalar path agrees"
            );
        }
        // the empty batch is fine
        assert_eq!(
            G1Affine::batch_from_bytes_compressed(&[]).expect("empty ok"),
            Vec::new()
        );
        // one malformed element poisons the whole batch, exactly like the
        // scalar decoder rejects it alone
        let mut bad = encs.clone();
        bad[5][31] = 0xc0; // identity flag + sign bit: invalid
        assert!(G1Affine::batch_from_bytes_compressed(&bad).is_none());
        assert!(G1Affine::from_bytes_compressed(&bad[5]).is_none());
        // a non-residue x is caught by the batched sqrt validation too
        let non_residue = (0u64..32).find_map(|v| {
            let mut b = [0u8; 32];
            b[..8].copy_from_slice(&v.to_le_bytes());
            G1Affine::from_bytes_compressed(&b).is_none().then_some(b)
        });
        let mut bad = encs;
        bad[11] = non_residue.expect("a non-residue below 32 exists");
        assert!(G1Affine::batch_from_bytes_compressed(&bad).is_none());
    }

    #[test]
    fn compressed_matches_uncompressed_semantics() {
        let mut r = rng();
        let p = G1::random(&mut r).to_affine();
        let back = G1Affine::from_bytes_compressed(&p.to_bytes_compressed()).unwrap();
        assert_eq!(G1Affine::from_bytes(&p.to_bytes()), Some(back));
    }
}
