//! Multi-scalar multiplication (Pippenger's bucket method) behind a
//! pluggable [`MsmBackend`].
//!
//! The dominant cost of the zkDL prover is committing to tensors and
//! auxiliary inputs: Σᵢ sᵢ·Gᵢ over thousands-to-millions of terms. Pippenger
//! reduces this from n scalar-muls to roughly n·(256/log n) point additions;
//! windows are processed in parallel across threads.
//!
//! All MSMs route through the process-wide backend object so alternative
//! implementations (SIMD, GPU) can slot in without touching any prover or
//! verifier. Two backends ship:
//!
//! * [`BatchAffineBackend`] (default) resolves each window's bucket
//!   additions in *affine* coordinates, batching the per-addition field
//!   inversions with Montgomery's trick ([`crate::field::Fp::batch_invert`]):
//!   one inversion plus ~6 muls per addition versus ~11 muls for a mixed
//!   Jacobian add, and the intermediate points stay 64 bytes instead of 96.
//! * [`ProjectiveBackend`] is the legacy per-bucket Jacobian accumulation,
//!   kept for differential tests and as the reference cost model.
//!
//! The window *digit → bucket* machinery is shared (including with the
//! fixed-base tables in [`super::fixed`]); a backend only supplies the
//! bucket-sum kernel, which is where all the point arithmetic lives.

use super::{G1, G1Affine};
use crate::field::{Fq, Fr};
use crate::telemetry::{self, Counter};
use crate::util::threads;
use once_cell::sync::Lazy;
use std::sync::{Arc, RwLock};

/// Pick the Pippenger window size (bits) for n terms.
pub(crate) fn window_size(n: usize) -> usize {
    match n {
        0..=3 => 1,
        4..=15 => 3,
        16..=127 => 5,
        128..=1023 => 7,
        1024..=8191 => 9,
        8192..=65535 => 11,
        65536..=524287 => 13,
        _ => 15,
    }
}

/// One bucketed term of a window pass: digit value (≥ 1) and base point.
/// Backends receive the terms pre-filtered — no zero digits, no points at
/// infinity.
pub type BucketEntry = (u32, G1Affine);

/// MSM execution backend: supplies the bucket-sum kernel every window pass
/// bottoms out in. `msm`/`msm_u64` have provided implementations built on
/// it, so a SIMD or GPU backend can start by overriding only
/// [`MsmBackend::bucket_sums`] and later take over whole MSMs.
pub trait MsmBackend: Send + Sync {
    /// Stable backend name (reports, DESIGN.md §perf).
    fn name(&self) -> &'static str;

    /// Per-bucket sums: out[i] = Σ {p : (i+1, p) ∈ entries} for buckets
    /// 1..=num_buckets. Entries carry digit ≥ 1 and finite points only.
    fn bucket_sums(&self, num_buckets: usize, entries: &[BucketEntry]) -> Vec<G1>;

    /// MSM: Σᵢ scalars[i]·bases[i] over full 256-bit scalars.
    fn msm(&self, bases: &[G1Affine], scalars: &[Fr]) -> G1 {
        assert_eq!(bases.len(), scalars.len(), "msm length mismatch");
        let n = bases.len();
        if n == 0 {
            return G1::IDENTITY;
        }
        if n < 8 {
            // naive is faster at tiny sizes
            let mut acc = G1::IDENTITY;
            for (b, s) in bases.iter().zip(scalars.iter()) {
                acc = acc.add(&b.to_projective().mul(s));
            }
            return acc;
        }

        let repr: Vec<[u64; 4]> = scalars.iter().map(|s| s.to_repr()).collect();
        // window sized by the number of *effective* terms: zero scalars are
        // skipped during bucketing, and the IPA round MSMs are half zeros —
        // sizing by total length would let the 2^w bucket-combine cost
        // dominate
        let effective = repr
            .iter()
            .filter(|r| r.iter().any(|&l| l != 0))
            .count()
            .max(1);
        let w = window_size(effective);
        let num_windows = 256usize.div_ceil(w);

        // Each window is independent: compute its bucket sum in parallel.
        let window_sums: Vec<G1> = threads::par_map_indexed(num_windows, |wi| {
            let mut entries = Vec::with_capacity(effective);
            for (base, sc) in bases.iter().zip(repr.iter()) {
                if base.infinity {
                    continue;
                }
                let digit = scalar_digit(sc, wi * w, w);
                if digit > 0 {
                    entries.push((digit, *base));
                }
            }
            let sums = self.bucket_sums((1usize << w) - 1, &entries);
            combine_bucket_sums(&sums)
        });

        horner_windows(&window_sums, w)
    }

    /// MSM with u64 scalars (bit tensors, exponent vectors): the same
    /// bucket method, but windowed over 64 bits only — ceil(64/w) window
    /// passes instead of ceil(256/w).
    fn msm_u64(&self, bases: &[G1Affine], scalars: &[u64]) -> G1 {
        assert_eq!(bases.len(), scalars.len(), "msm length mismatch");
        let n = bases.len();
        if n == 0 {
            return G1::IDENTITY;
        }
        if n < 8 {
            let mut acc = G1::IDENTITY;
            for (b, s) in bases.iter().zip(scalars.iter()) {
                acc = acc.add(&b.to_projective().mul(&Fr::from_u64(*s)));
            }
            return acc;
        }
        let effective = scalars.iter().filter(|&&s| s != 0).count().max(1);
        let w = window_size(effective);
        let num_windows = 64usize.div_ceil(w);
        let window_sums: Vec<G1> = threads::par_map_indexed(num_windows, |wi| {
            let shift = wi * w;
            let mut entries = Vec::with_capacity(effective);
            for (base, &sc) in bases.iter().zip(scalars.iter()) {
                if base.infinity {
                    continue;
                }
                let digit = ((sc >> shift) & ((1u64 << w) - 1)) as u32;
                if digit > 0 {
                    entries.push((digit, *base));
                }
            }
            let sums = self.bucket_sums((1usize << w) - 1, &entries);
            combine_bucket_sums(&sums)
        });
        horner_windows(&window_sums, w)
    }
}

/// Extract bits [shift, shift+w) of a 256-bit little-endian limb scalar.
#[inline]
pub(crate) fn scalar_digit(repr: &[u64; 4], shift: usize, w: usize) -> u32 {
    let limb = shift / 64;
    if limb >= 4 {
        return 0;
    }
    let off = shift % 64;
    let mut frag = repr[limb] >> off;
    if off + w > 64 && limb + 1 < 4 {
        frag |= repr[limb + 1] << (64 - off);
    }
    (frag & ((1u64 << w) - 1)) as u32
}

/// Σ (i+1)·sums[i] via the running-sum trick, walking only the *nonempty*
/// buckets (descending) and jumping the gaps with small double-and-add
/// multiplications. For dense bucket arrays this is the classic running
/// sum; for sparse ones (fixed-base tables queried over short basis
/// ranges) the cost is O(nonempty·log gap) instead of O(2^w).
pub(crate) fn combine_bucket_sums(sums: &[G1]) -> G1 {
    // Σ_j prefix_j · (i_j − i_{j+1}) over descending nonempty 1-based
    // indices i_1 > i_2 > … > i_k, with i_{k+1} = 0 and
    // prefix_j = B_{i_1} + … + B_{i_j}.
    let mut acc = G1::IDENTITY;
    let mut running = G1::IDENTITY;
    let mut prev: usize = 0; // previous (larger) 1-based index, 0 = none yet
    for (i, b) in sums.iter().enumerate().rev() {
        if b.is_identity() {
            continue;
        }
        let idx = i + 1;
        if prev != 0 {
            acc = acc.add(&mul_small(&running, (prev - idx) as u64));
        }
        running = running.add(b);
        prev = idx;
    }
    if prev != 0 {
        acc = acc.add(&mul_small(&running, prev as u64));
    }
    acc
}

/// Double-and-add by a small unsigned scalar (bucket-index gaps).
fn mul_small(p: &G1, k: u64) -> G1 {
    debug_assert!(k > 0);
    if k == 1 {
        return *p;
    }
    let mut acc = *p;
    let top = 63 - k.leading_zeros();
    for b in (0..top).rev() {
        acc = acc.double();
        if (k >> b) & 1 == 1 {
            acc = acc.add(p);
        }
    }
    acc
}

/// Horner-combine per-window sums (most significant first) with w doublings
/// per step.
pub(crate) fn horner_windows(window_sums: &[G1], w: usize) -> G1 {
    let mut total = G1::IDENTITY;
    for ws in window_sums.iter().rev() {
        for _ in 0..w {
            total = total.double();
        }
        total = total.add(ws);
    }
    total
}

// ---------------------------------------------------------------------------
// Batch-affine backend (default)
// ---------------------------------------------------------------------------

/// Default backend: bucket additions in affine coordinates, pairwise tree
/// reduction per bucket, one [`Fq::batch_invert`] sweep per reduction level
/// across *all* buckets — Montgomery's trick amortizes the per-addition
/// inversion to ~3 muls, so an affine add costs ~6 muls total versus ~11
/// for the mixed Jacobian formula.
pub struct BatchAffineBackend;

/// Classified affine pair awaiting its batched inverse.
enum PairKind {
    /// λ = (y₂−y₁)/(x₂−x₁); the stored denominator is x₂−x₁.
    Add,
    /// P + P: λ = 3x²/(2y); the stored denominator is 2y. (y = 0 cannot
    /// occur: BN254 G1 has odd prime order, so there is no 2-torsion.)
    Double,
    /// P + (−P) = 𝒪: the pair is dropped entirely.
    Cancel,
}

impl MsmBackend for BatchAffineBackend {
    fn name(&self) -> &'static str {
        "batch-affine"
    }

    fn bucket_sums(&self, num_buckets: usize, entries: &[BucketEntry]) -> Vec<G1> {
        // Counting-sort the points into per-bucket runs of one flat buffer.
        let mut counts = vec![0usize; num_buckets];
        for &(d, _) in entries {
            counts[(d - 1) as usize] += 1;
        }
        let mut starts = vec![0usize; num_buckets];
        let mut acc = 0usize;
        for (s, &c) in starts.iter_mut().zip(counts.iter()) {
            *s = acc;
            acc += c;
        }
        let mut cur: Vec<G1Affine> = vec![G1Affine::IDENTITY; acc];
        let mut fill = starts.clone();
        for &(d, p) in entries {
            let b = (d - 1) as usize;
            cur[fill[b]] = p;
            fill[b] += 1;
        }
        // (start, len) of each bucket's live run inside `cur`.
        let mut runs: Vec<(usize, usize)> = starts
            .iter()
            .zip(counts.iter())
            .map(|(&s, &c)| (s, c))
            .collect();

        // Pairwise reduction: every sweep halves each bucket's run, paying
        // ONE field inversion (batched over every pair of every bucket).
        let mut next: Vec<G1Affine> = Vec::with_capacity(cur.len().div_ceil(2));
        let mut kinds: Vec<PairKind> = Vec::new();
        let mut denoms: Vec<Fq> = Vec::new();
        while runs.iter().any(|&(_, len)| len >= 2) {
            telemetry::count(Counter::MsmBatchAddSweeps, 1);
            kinds.clear();
            denoms.clear();
            for &(start, len) in runs.iter() {
                for k in (0..len.saturating_sub(1)).step_by(2) {
                    let p = &cur[start + k];
                    let q = &cur[start + k + 1];
                    if p.x == q.x {
                        if p.y == q.y {
                            kinds.push(PairKind::Double);
                            denoms.push(p.y.double());
                        } else {
                            kinds.push(PairKind::Cancel);
                            denoms.push(Fq::ZERO); // skipped by batch_invert
                        }
                    } else {
                        kinds.push(PairKind::Add);
                        denoms.push(q.x - p.x);
                    }
                }
            }
            Fq::batch_invert(&mut denoms);

            next.clear();
            let mut cursor = 0usize;
            let mut new_runs = Vec::with_capacity(runs.len());
            for &(start, len) in runs.iter() {
                let out_start = next.len();
                for k in (0..len.saturating_sub(1)).step_by(2) {
                    let p = cur[start + k];
                    let q = cur[start + k + 1];
                    let d = denoms[cursor];
                    match kinds[cursor] {
                        PairKind::Cancel => {}
                        PairKind::Double => {
                            let xx = p.x.square();
                            let lam = (xx.double() + xx) * d;
                            next.push(affine_add_with_lambda(&p, &q, lam));
                        }
                        PairKind::Add => {
                            let lam = (q.y - p.y) * d;
                            next.push(affine_add_with_lambda(&p, &q, lam));
                        }
                    }
                    cursor += 1;
                }
                if len % 2 == 1 {
                    next.push(cur[start + len - 1]);
                }
                new_runs.push((out_start, next.len() - out_start));
            }
            std::mem::swap(&mut cur, &mut next);
            runs = new_runs;
        }

        runs.iter()
            .map(|&(start, len)| {
                if len == 0 {
                    G1::IDENTITY
                } else {
                    cur[start].to_projective()
                }
            })
            .collect()
    }
}

/// x₃ = λ² − x₁ − x₂, y₃ = λ(x₁ − x₃) − y₁, with λ supplied (its
/// denominator came out of the batched inversion).
#[inline]
fn affine_add_with_lambda(p: &G1Affine, q: &G1Affine, lam: Fq) -> G1Affine {
    let x3 = lam.square() - p.x - q.x;
    G1Affine {
        x: x3,
        y: lam * (p.x - x3) - p.y,
        infinity: false,
    }
}

// ---------------------------------------------------------------------------
// Projective backend (legacy reference)
// ---------------------------------------------------------------------------

/// The pre-zkTurbo kernel: one Jacobian accumulator per bucket, mixed
/// addition per term. Kept as the differential-testing reference and the
/// fallback cost model.
pub struct ProjectiveBackend;

impl MsmBackend for ProjectiveBackend {
    fn name(&self) -> &'static str {
        "projective"
    }

    fn bucket_sums(&self, num_buckets: usize, entries: &[BucketEntry]) -> Vec<G1> {
        let mut buckets = vec![G1::IDENTITY; num_buckets];
        for &(d, p) in entries {
            let b = (d - 1) as usize;
            buckets[b] = buckets[b].add_affine(&p);
        }
        buckets
    }
}

// ---------------------------------------------------------------------------
// Process-wide backend routing
// ---------------------------------------------------------------------------

static BACKEND: Lazy<RwLock<Arc<dyn MsmBackend>>> =
    Lazy::new(|| RwLock::new(Arc::new(BatchAffineBackend)));

/// The currently installed backend (read-lock + Arc clone; negligible next
/// to any actual MSM).
pub fn backend() -> Arc<dyn MsmBackend> {
    BACKEND.read().unwrap().clone()
}

/// Install a process-wide MSM backend (e.g. a SIMD/GPU implementation).
/// Returns the previous one. All backends compute identical group elements,
/// so swapping backends never changes proof artifacts.
pub fn set_backend(b: Arc<dyn MsmBackend>) -> Arc<dyn MsmBackend> {
    std::mem::replace(&mut *BACKEND.write().unwrap(), b)
}

/// MSM: Σᵢ scalars[i]·bases[i]. Lengths must match. Routes through the
/// installed [`MsmBackend`].
pub fn msm(bases: &[G1Affine], scalars: &[Fr]) -> G1 {
    telemetry::count(Counter::MsmCalls, 1);
    telemetry::count(Counter::MsmPoints, bases.len() as u64);
    telemetry::hist::record(telemetry::hist::Hist::MsmSize, bases.len() as u64);
    backend().msm(bases, scalars)
}

/// MSM with u64 scalars (bit tensors, exponent vectors): windows cover 64
/// bits instead of 256 — a 4× window-pass reduction over widening to `Fr`.
pub fn msm_u64(bases: &[G1Affine], scalars: &[u64]) -> G1 {
    telemetry::count(Counter::MsmCalls, 1);
    telemetry::count(Counter::MsmPoints, bases.len() as u64);
    telemetry::hist::record(telemetry::hist::Hist::MsmSize, bases.len() as u64);
    backend().msm_u64(bases, scalars)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(bases: &[G1Affine], scalars: &[Fr]) -> G1 {
        let mut acc = G1::IDENTITY;
        for (b, s) in bases.iter().zip(scalars.iter()) {
            acc = acc.add(&b.to_projective().mul(s));
        }
        acc
    }

    #[test]
    fn msm_matches_naive() {
        let mut rng = Rng::seed_from_u64(7);
        for n in [1usize, 2, 7, 8, 33, 100, 257] {
            let bases: Vec<G1Affine> = (0..n).map(|_| G1::random(&mut rng).to_affine()).collect();
            let scalars: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
            assert_eq!(msm(&bases, &scalars), naive(&bases, &scalars), "n={n}");
        }
    }

    #[test]
    fn msm_with_zero_and_identity() {
        let mut rng = Rng::seed_from_u64(8);
        let mut bases: Vec<G1Affine> =
            (0..20).map(|_| G1::random(&mut rng).to_affine()).collect();
        bases[3] = G1Affine::IDENTITY;
        let mut scalars: Vec<Fr> = (0..20).map(|_| Fr::random(&mut rng)).collect();
        scalars[5] = Fr::ZERO;
        assert_eq!(msm(&bases, &scalars), naive(&bases, &scalars));
    }

    #[test]
    fn msm_empty() {
        assert_eq!(msm(&[], &[]), G1::IDENTITY);
    }

    #[test]
    fn msm_small_scalars() {
        let mut rng = Rng::seed_from_u64(9);
        let bases: Vec<G1Affine> = (0..50).map(|_| G1::random(&mut rng).to_affine()).collect();
        let scalars: Vec<Fr> = (0..50).map(|i| Fr::from_i64(i as i64 - 25)).collect();
        assert_eq!(msm(&bases, &scalars), naive(&bases, &scalars));
    }

    // --- batch-affine bucket kernel: the affine special cases ---

    #[test]
    fn batch_affine_equal_points_double() {
        let mut rng = Rng::seed_from_u64(10);
        let p = G1::random(&mut rng).to_affine();
        let sums = BatchAffineBackend.bucket_sums(3, &[(1, p), (1, p)]);
        assert_eq!(sums[0], p.to_projective().double());
        assert!(sums[1].is_identity() && sums[2].is_identity());
    }

    #[test]
    fn batch_affine_inverse_points_cancel() {
        let mut rng = Rng::seed_from_u64(11);
        let p = G1::random(&mut rng).to_affine();
        let sums = BatchAffineBackend.bucket_sums(2, &[(2, p), (2, p.neg())]);
        assert!(sums[1].is_identity());
        // cancellation interleaved with a surviving odd leftover
        let q = G1::random(&mut rng).to_affine();
        let sums = BatchAffineBackend.bucket_sums(1, &[(1, p), (1, p.neg()), (1, q)]);
        assert_eq!(sums[0], q.to_projective());
    }

    #[test]
    fn batch_affine_many_duplicates_force_repeated_doublings() {
        // 9 copies of one point exercise doubling at every sweep level and
        // the odd-leftover carry: ceil(log2 9) = 4 sweeps.
        let mut rng = Rng::seed_from_u64(12);
        let p = G1::random(&mut rng).to_affine();
        let entries: Vec<BucketEntry> = (0..9).map(|_| (1u32, p)).collect();
        let sums = BatchAffineBackend.bucket_sums(1, &entries);
        assert_eq!(sums[0], p.to_projective().mul(&Fr::from_u64(9)));
    }

    #[test]
    fn backends_agree_on_random_inputs() {
        let mut rng = Rng::seed_from_u64(13);
        for n in [8usize, 33, 200] {
            let mut bases: Vec<G1Affine> =
                (0..n).map(|_| G1::random(&mut rng).to_affine()).collect();
            let mut scalars: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
            // engineer same-bucket collisions: duplicate base+scalar and an
            // exact inverse pair
            bases[1] = bases[0];
            scalars[1] = scalars[0];
            bases[3] = bases[2].neg();
            scalars[3] = scalars[2];
            let fast = BatchAffineBackend.msm(&bases, &scalars);
            let slow = ProjectiveBackend.msm(&bases, &scalars);
            assert_eq!(fast, slow, "n={n}");
            assert_eq!(fast, naive(&bases, &scalars), "n={n}");
        }
    }

    #[test]
    fn msm_u64_direct_windows_match_naive() {
        let mut rng = Rng::seed_from_u64(14);
        for n in [3usize, 8, 40, 300] {
            let bases: Vec<G1Affine> =
                (0..n).map(|_| G1::random(&mut rng).to_affine()).collect();
            let mut scalars: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            scalars[0] = 0;
            if n > 1 {
                scalars[1] = u64::MAX; // saturates the top 64-bit window
            }
            let frs: Vec<Fr> = scalars.iter().map(|&s| Fr::from_u64(s)).collect();
            assert_eq!(msm_u64(&bases, &scalars), naive(&bases, &frs), "n={n}");
            assert_eq!(
                ProjectiveBackend.msm_u64(&bases, &scalars),
                naive(&bases, &frs),
                "projective n={n}"
            );
        }
    }

    #[test]
    fn combine_bucket_sums_handles_sparse_gaps() {
        let mut rng = Rng::seed_from_u64(15);
        let p = G1::random(&mut rng);
        let q = G1::random(&mut rng);
        // Σ idx·B_idx with only buckets 3 and 250 occupied (1-based).
        let mut sums = vec![G1::IDENTITY; 255];
        sums[2] = p;
        sums[249] = q;
        let want = p.mul(&Fr::from_u64(3)).add(&q.mul(&Fr::from_u64(250)));
        assert_eq!(combine_bucket_sums(&sums), want);
        // empty and all-identity inputs
        assert!(combine_bucket_sums(&[]).is_identity());
        assert!(combine_bucket_sums(&[G1::IDENTITY; 7]).is_identity());
    }

    #[test]
    fn default_backend_is_batch_affine() {
        assert_eq!(backend().name(), "batch-affine");
    }
}
