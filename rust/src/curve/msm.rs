//! Multi-scalar multiplication (Pippenger's bucket method).
//!
//! The dominant cost of the zkDL prover is committing to tensors and
//! auxiliary inputs: Σᵢ sᵢ·Gᵢ over thousands-to-millions of terms. Pippenger
//! reduces this from n scalar-muls to roughly n·(256/log n) point additions;
//! windows are processed in parallel across threads.

use super::{G1, G1Affine};
use crate::field::Fr;
use crate::telemetry::{self, Counter};
use crate::util::threads;

/// Pick the Pippenger window size (bits) for n terms.
fn window_size(n: usize) -> usize {
    match n {
        0..=3 => 1,
        4..=15 => 3,
        16..=127 => 5,
        128..=1023 => 7,
        1024..=8191 => 9,
        8192..=65535 => 11,
        65536..=524287 => 13,
        _ => 15,
    }
}

/// MSM: Σᵢ scalars[i]·bases[i]. Lengths must match.
pub fn msm(bases: &[G1Affine], scalars: &[Fr]) -> G1 {
    assert_eq!(bases.len(), scalars.len(), "msm length mismatch");
    let n = bases.len();
    telemetry::count(Counter::MsmCalls, 1);
    telemetry::count(Counter::MsmPoints, n as u64);
    if n == 0 {
        return G1::IDENTITY;
    }
    if n < 8 {
        // naive is faster at tiny sizes
        let mut acc = G1::IDENTITY;
        for (b, s) in bases.iter().zip(scalars.iter()) {
            acc = acc.add(&b.to_projective().mul(s));
        }
        return acc;
    }

    let repr: Vec<[u64; 4]> = scalars.iter().map(|s| s.to_repr()).collect();
    // window sized by the number of *effective* terms: zero scalars are
    // skipped during bucketing, and the IPA round MSMs are half zeros —
    // sizing by total length would let the 2^w bucket-combine cost dominate
    let effective = repr
        .iter()
        .filter(|r| r.iter().any(|&l| l != 0))
        .count()
        .max(1);
    let w = window_size(effective);
    let num_windows = 256usize.div_ceil(w);

    // Each window is independent: compute its bucket sum in parallel.
    let window_sums: Vec<G1> = threads::par_map_indexed(num_windows, |wi| {
        let shift = wi * w;
        let mut buckets = vec![G1::IDENTITY; (1usize << w) - 1];
        for (base, sc) in bases.iter().zip(repr.iter()) {
            if base.infinity {
                continue;
            }
            // extract bits [shift, shift+w) of the 256-bit scalar
            let limb = shift / 64;
            let off = shift % 64;
            let mut frag = sc[limb] >> off;
            if off + w > 64 && limb + 1 < 4 {
                frag |= sc[limb + 1] << (64 - off);
            }
            let idx = (frag & ((1u64 << w) - 1)) as usize;
            if idx > 0 {
                buckets[idx - 1] = buckets[idx - 1].add_affine(base);
            }
        }
        // running-sum trick: Σ idx·bucket[idx]
        let mut running = G1::IDENTITY;
        let mut acc = G1::IDENTITY;
        for b in buckets.iter().rev() {
            running = running.add(b);
            acc = acc.add(&running);
        }
        acc
    });

    // Horner combine the windows (most significant first).
    let mut total = G1::IDENTITY;
    for ws in window_sums.iter().rev() {
        for _ in 0..w {
            total = total.double();
        }
        total = total.add(ws);
    }
    total
}

/// MSM with u64 scalars (bit tensors, exponent vectors): same bucket method
/// over 64-bit fragments only.
pub fn msm_u64(bases: &[G1Affine], scalars: &[u64]) -> G1 {
    let frs: Vec<Fr> = scalars.iter().map(|&s| Fr::from_u64(s)).collect();
    msm(bases, &frs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(bases: &[G1Affine], scalars: &[Fr]) -> G1 {
        let mut acc = G1::IDENTITY;
        for (b, s) in bases.iter().zip(scalars.iter()) {
            acc = acc.add(&b.to_projective().mul(s));
        }
        acc
    }

    #[test]
    fn msm_matches_naive() {
        let mut rng = Rng::seed_from_u64(7);
        for n in [1usize, 2, 7, 8, 33, 100, 257] {
            let bases: Vec<G1Affine> = (0..n).map(|_| G1::random(&mut rng).to_affine()).collect();
            let scalars: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
            assert_eq!(msm(&bases, &scalars), naive(&bases, &scalars), "n={n}");
        }
    }

    #[test]
    fn msm_with_zero_and_identity() {
        let mut rng = Rng::seed_from_u64(8);
        let mut bases: Vec<G1Affine> =
            (0..20).map(|_| G1::random(&mut rng).to_affine()).collect();
        bases[3] = G1Affine::IDENTITY;
        let mut scalars: Vec<Fr> = (0..20).map(|_| Fr::random(&mut rng)).collect();
        scalars[5] = Fr::ZERO;
        assert_eq!(msm(&bases, &scalars), naive(&bases, &scalars));
    }

    #[test]
    fn msm_empty() {
        assert_eq!(msm(&[], &[]), G1::IDENTITY);
    }

    #[test]
    fn msm_small_scalars() {
        let mut rng = Rng::seed_from_u64(9);
        let bases: Vec<G1Affine> = (0..50).map(|_| G1::random(&mut rng).to_affine()).collect();
        let scalars: Vec<Fr> = (0..50).map(|i| Fr::from_i64(i as i64 - 25)).collect();
        assert_eq!(msm(&bases, &scalars), naive(&bases, &scalars));
    }
}
