//! Precomputed fixed-base window tables for long-lived commitment bases.
//!
//! Every commitment key in the system (`CommitKey`, `UpdateKey`'s stacked
//! basis, `ProvenanceKey`'s data/selector bases) holds points that are
//! fixed for the lifetime of a (label, shape) pair — and the key caches
//! already keep them alive across proofs. Plain Pippenger re-pays the
//! window doublings on every call even though the bases never change.
//!
//! A [`FixedBaseTable`] stores, for each window j, the shifted copies
//! 2^{j·w}·Pᵢ in affine form. A fixed-base MSM then becomes **one** bucket
//! pass over n·ceil(256/w) (digit, point) entries — no doublings, no
//! Horner combine — using the same [`MsmBackend`](super::msm::MsmBackend)
//! bucket kernel as variable-base MSMs, so the batch-affine win applies
//! here too.
//!
//! Memory/window trade-off: the table stores n·ceil(256/w) affine points
//! (64 bytes each); the per-query cost is ~n·ceil(256/w) bucket adds plus
//! a sparse bucket combine. Larger w shrinks the add count but grows both
//! the table and the bucket space; the sparse descending combine in
//! `msm::combine_bucket_sums` keeps big-w tables usable for *short* query
//! ranges (a 128-point block commit touches at most 128·ceil(256/w)
//! buckets, not 2^w). [`FixedBaseTable::auto_window`] picks w minimizing
//! per-query adds + bucket traffic for the basis length; [`MAX_POINTS`]
//! caps table construction so huge one-shot bases don't pay a build they
//! never amortize.

use super::msm::{self, BucketEntry};
use super::{G1, G1Affine};
use crate::field::Fr;
use crate::telemetry::{self, Counter};
use crate::util::threads;
use std::sync::{Arc, OnceLock};

/// Bases longer than this don't get tables: the build cost (n·256
/// doublings) plus the memory (n·ceil(256/w)·64 bytes) stops amortizing
/// for bases that large — at 2^14 points and w = 13 the table is ~21 MB.
pub const MAX_POINTS: usize = 1 << 14;

/// Shared, lazily-built table slot. A handle is cloned along with its key
/// through the key caches (and through key *slices*, with an offset kept
/// by the key), so a table is built at most once per cached (label, shape)
/// and evicted exactly when the key itself is.
#[derive(Clone, Debug, Default)]
pub struct TableHandle(Arc<OnceLock<FixedBaseTable>>);

impl TableHandle {
    /// The table, if some owner of this handle has built it.
    pub fn get(&self) -> Option<&FixedBaseTable> {
        self.0.get()
    }

    /// Build the table over `bases` if not already built (idempotent,
    /// thread-safe; concurrent callers block on the single build).
    pub fn get_or_build(&self, bases: &[G1Affine]) -> &FixedBaseTable {
        self.0.get_or_init(|| FixedBaseTable::build_auto(bases))
    }

    pub fn is_warm(&self) -> bool {
        self.0.get().is_some()
    }
}

/// Window table over a fixed basis: `shifted[j·n + i] = 2^{j·w}·base[i]`.
#[derive(Clone, Debug)]
pub struct FixedBaseTable {
    /// Window width in bits.
    w: usize,
    /// Number of windows = ceil(256 / w).
    windows: usize,
    /// Basis length.
    n: usize,
    /// Row-major shifted copies, `windows` rows of `n` points.
    shifted: Vec<G1Affine>,
}

impl FixedBaseTable {
    /// Window width minimizing per-query work for an n-point basis
    /// evaluated over its full length: argmin over w of
    /// ceil(256/w)·(n + 2^w) — every window row pays its n bucket adds
    /// *and* its 2^w-slot bucket array (allocation + merge traffic), so
    /// the bucket-space term scales with the row count too. Charging it
    /// per row also bounds transient memory: unmoderated, w = 16 at the
    /// [`MAX_POINTS`] cap would allocate 16 rows × 2^16 × 96-byte bucket
    /// accumulators (~100 MB) per evaluation.
    pub fn auto_window(n: usize) -> usize {
        let mut best = (usize::MAX, 4usize);
        for w in 4..=16usize {
            let windows = 256usize.div_ceil(w);
            let cost = windows * (n + (1usize << w));
            if cost < best.0 {
                best = (cost, w);
            }
        }
        best.1
    }

    /// Build the table: n·256 doublings total (progressive row-by-row
    /// doubling), normalized to affine one row at a time via
    /// `batch_to_affine`.
    pub fn build(bases: &[G1Affine], w: usize) -> Self {
        assert!((1..=16).contains(&w), "window width out of range");
        let n = bases.len();
        let windows = 256usize.div_ceil(w);
        let mut shifted = Vec::with_capacity(windows * n);
        shifted.extend_from_slice(bases);
        let mut cur: Vec<G1> = bases.iter().map(|b| b.to_projective()).collect();
        for _ in 1..windows {
            threads::par_chunks_mut(&mut cur, 256, |_, chunk| {
                for p in chunk.iter_mut() {
                    for _ in 0..w {
                        *p = p.double();
                    }
                }
            });
            shifted.extend(G1::batch_to_affine(&cur));
        }
        FixedBaseTable {
            w,
            windows,
            n,
            shifted,
        }
    }

    /// Build with the automatic window choice.
    pub fn build_auto(bases: &[G1Affine]) -> Self {
        Self::build(bases, Self::auto_window(bases.len()))
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn window(&self) -> usize {
        self.w
    }

    /// Table footprint in bytes (affine points only).
    pub fn bytes(&self) -> usize {
        self.shifted.len() * std::mem::size_of::<G1Affine>()
    }

    /// Fixed-base MSM over the basis prefix starting at `offset`:
    /// Σᵢ scalars[i]·base[offset + i]. One bucket pass, no doublings.
    /// Counts [`Counter::MsmTableHits`], *not* `MsmCalls`/`MsmPoints` —
    /// table evaluations are internal to higher-level MSMs (accumulator
    /// flushes, commits) whose call-count invariants stay untouched.
    pub fn msm_range(&self, offset: usize, scalars: &[Fr]) -> G1 {
        let k = scalars.len();
        assert!(offset + k <= self.n, "table range out of bounds");
        if k == 0 {
            return G1::IDENTITY;
        }
        telemetry::count(Counter::MsmTableHits, 1);
        let repr: Vec<[u64; 4]> = scalars.iter().map(|s| s.to_repr()).collect();
        let w = self.w;
        let backend = msm::backend();
        // Window rows are independent bucket-entry producers, but the
        // whole evaluation is ONE logical bucket pass: per-row partial
        // bucket sums are combined bucket-wise. Parallelize over rows —
        // they are the long axis for full-length queries.
        let num_buckets = (1usize << w) - 1;
        let row_sums: Vec<Vec<G1>> = threads::par_map_indexed(self.windows, |j| {
            let row = &self.shifted[j * self.n + offset..j * self.n + offset + k];
            let mut entries: Vec<BucketEntry> = Vec::with_capacity(k);
            for (p, sc) in row.iter().zip(repr.iter()) {
                if p.infinity {
                    continue;
                }
                let digit = msm::scalar_digit(sc, j * w, w);
                if digit > 0 {
                    entries.push((digit, *p));
                }
            }
            backend.bucket_sums(num_buckets, &entries)
        });
        let mut sums = vec![G1::IDENTITY; num_buckets];
        for row in &row_sums {
            for (acc, s) in sums.iter_mut().zip(row.iter()) {
                if !s.is_identity() {
                    *acc = acc.add(s);
                }
            }
        }
        msm::combine_bucket_sums(&sums)
    }

    /// Fixed-base MSM over the basis prefix `[0, scalars.len())`.
    pub fn msm(&self, scalars: &[Fr]) -> G1 {
        self.msm_range(0, scalars)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::curve::msm::msm as plain_msm;
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (Vec<G1Affine>, Vec<Fr>) {
        let mut rng = Rng::seed_from_u64(seed);
        let bases: Vec<G1Affine> = (0..n).map(|_| G1::random(&mut rng).to_affine()).collect();
        let mut scalars: Vec<Fr> = (0..n).map(|_| Fr::random(&mut rng)).collect();
        scalars[0] = Fr::ZERO;
        if n > 2 {
            scalars[2] = -Fr::ONE; // max scalar exercises the top window
        }
        (bases, scalars)
    }

    #[test]
    fn table_matches_plain_msm_across_windows() {
        let (bases, scalars) = setup(33, 21);
        let want = plain_msm(&bases, &scalars);
        for w in [4usize, 8, 13, 16] {
            let table = FixedBaseTable::build(&bases, w);
            assert_eq!(table.msm(&scalars), want, "w={w}");
            assert_eq!(table.windows, 256usize.div_ceil(w));
        }
    }

    #[test]
    fn table_prefix_and_offset_ranges() {
        let (bases, scalars) = setup(24, 22);
        let table = FixedBaseTable::build(&bases, 8);
        // prefix
        assert_eq!(
            table.msm(&scalars[..10]),
            plain_msm(&bases[..10], &scalars[..10])
        );
        // interior range (block commits slice the stacked aux basis)
        assert_eq!(
            table.msm_range(5, &scalars[5..17]),
            plain_msm(&bases[5..17], &scalars[5..17])
        );
        // empty query
        assert!(table.msm(&[]).is_identity());
    }

    #[test]
    fn auto_window_grows_with_basis() {
        assert!(FixedBaseTable::auto_window(16) < FixedBaseTable::auto_window(1 << 13));
        for n in [1usize, 100, MAX_POINTS] {
            let w = FixedBaseTable::auto_window(n);
            assert!((4..=16).contains(&w));
        }
    }

    #[test]
    fn table_with_identity_base_point() {
        let mut rng = Rng::seed_from_u64(23);
        let mut bases: Vec<G1Affine> =
            (0..9).map(|_| G1::random(&mut rng).to_affine()).collect();
        bases[4] = G1Affine::IDENTITY;
        let scalars: Vec<Fr> = (0..9).map(|_| Fr::random(&mut rng)).collect();
        let table = FixedBaseTable::build(&bases, 6);
        assert_eq!(table.msm(&scalars), plain_msm(&bases, &scalars));
    }

    #[test]
    fn bytes_reports_footprint() {
        let (bases, _) = setup(8, 24);
        let table = FixedBaseTable::build(&bases, 16);
        assert_eq!(
            table.bytes(),
            8 * 16 * std::mem::size_of::<G1Affine>() // ceil(256/16) = 16 rows
        );
    }
}
