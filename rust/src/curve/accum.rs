//! Deferred multi-scalar-multiplication accumulator — the one-MSM
//! verification engine.
//!
//! Every group-equation check in the verifier stack has the shape
//! Σᵢ sᵢ·Pᵢ = 𝒪: an IPA final check, a batched-opening check, a zkReLU
//! validity check. Instead of evaluating each equation eagerly (per-round
//! Jacobian muls plus a fresh Pippenger MSM per opening), verifiers push the
//! (scalar, point) terms into an [`MsmAccumulator`] and the whole proof —
//! or a whole *batch* of proofs — is decided by a single Pippenger call
//! over the union of terms.
//!
//! Soundness of the merge: equation j's terms are scaled by a fresh
//! verifier-chosen random coefficient cⱼ (drawn at [`begin_equation`]), and
//! each proof's contribution is additionally scaled by an outer ρᵢ
//! ([`set_scale`]) in cross-proof batching. Σⱼ cⱼ·Eⱼ = 𝒪 with independent
//! uniform cⱼ implies every Eⱼ = 𝒪 except with probability ≈ #eq/|Fr| —
//! the standard random-linear-combination argument used by Bulletproofs
//! batch verification. The coefficients are verifier-local (never shown to
//! the prover), so no grinding is possible.
//!
//! [`begin_equation`]: MsmAccumulator::begin_equation
//! [`set_scale`]: MsmAccumulator::set_scale

use super::fixed::TableHandle;
use super::{msm::msm, G1, G1Affine};
use crate::commit::CommitKey;
use crate::field::Fr;
use crate::telemetry::{self, Counter};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// A deduplicated fixed-base block: one copy of a basis slice plus the
/// running per-generator scalar sums contributed by every equation that
/// pushed against it.
struct FixedBlock {
    points: Vec<G1Affine>,
    scalars: Vec<Fr>,
    /// Warm fixed-base table covering this block (handle + offset of
    /// `points[0]` in the table), recorded when the block was pushed via
    /// [`MsmAccumulator::push_fixed_key`]. At flush the block is then
    /// evaluated through the table and joins the final MSM as a single
    /// projective term instead of `points.len()` fresh Pippenger inputs.
    table: Option<(TableHandle, usize)>,
}

/// Collects deferred Σ sᵢ·Pᵢ = 𝒪 checks and decides them with one MSM.
pub struct MsmAccumulator {
    rng: Rng,
    /// Outer per-proof scale (cross-proof batching), set by [`Self::set_scale`].
    scale: Fr,
    /// Per-equation random coefficient, redrawn by [`Self::begin_equation`].
    eq_coeff: Fr,
    /// scale · eq_coeff, applied to every pushed scalar.
    cur: Fr,
    points: Vec<G1Affine>,
    scalars: Vec<Fr>,
    proj_points: Vec<G1>,
    proj_scalars: Vec<Fr>,
    /// Fixed-base blocks, merged scalar-wise across equations: repeated
    /// pushes of the same basis slice (the common case in cross-proof
    /// batching — every proof opens against the same commitment keys) cost
    /// field additions, not duplicate MSM points, so the fixed-base share
    /// of the final MSM stays constant-size in the batch length.
    blocks: Vec<FixedBlock>,
    /// (length, first-point encoding) → candidate block indices; candidates
    /// are confirmed by full slice comparison before merging.
    block_index: HashMap<(usize, [u8; 64]), Vec<usize>>,
    /// Eager mode: run one MSM per equation instead of deferring — the
    /// pre-refactor verification strategy, kept for benchmarking and for
    /// differential tests against the deferred path.
    eager: bool,
    ok: bool,
    flushes: usize,
    equations: usize,
}

impl MsmAccumulator {
    /// Accumulator with entropy-seeded batching coefficients (the normal
    /// verifier entry point).
    pub fn new() -> Self {
        Self::from_rng(&mut Rng::from_entropy())
    }

    /// Accumulator whose batching coefficients derive from `seed` —
    /// deterministic, for tests and benches. The child generator carries
    /// the seed's full 256-bit state (`Rng::split`), so entropy-seeded
    /// callers keep their full entropy width.
    pub fn from_rng(seed: &mut Rng) -> Self {
        Self {
            rng: seed.split(),
            scale: Fr::ONE,
            eq_coeff: Fr::ONE,
            cur: Fr::ONE,
            points: Vec::new(),
            scalars: Vec::new(),
            proj_points: Vec::new(),
            proj_scalars: Vec::new(),
            blocks: Vec::new(),
            block_index: HashMap::new(),
            eager: false,
            ok: true,
            flushes: 0,
            equations: 0,
        }
    }

    /// Per-equation-MSM accumulator (see the `eager` field).
    pub fn eager_from_rng(seed: &mut Rng) -> Self {
        let mut acc = Self::from_rng(seed);
        acc.eager = true;
        acc
    }

    /// Set the outer scale applied to all subsequently pushed terms —
    /// cross-proof batching sets an independent random ρᵢ before feeding
    /// proof i's equations in. Must be nonzero (a zero scale would erase
    /// the proof's contribution entirely).
    pub fn set_scale(&mut self, scale: Fr) {
        assert!(!scale.is_zero(), "accumulator scale must be nonzero");
        self.scale = scale;
        self.cur = self.scale * self.eq_coeff;
    }

    /// Start a new deferred equation: draws a fresh random coefficient so
    /// distinct equations cannot cancel each other inside the shared MSM.
    /// In eager mode, first decides the pending equation with its own MSM.
    pub fn begin_equation(&mut self) {
        if self.eager && self.pending_terms() > 0 {
            self.run_msm();
        }
        self.equations += 1;
        telemetry::count(Counter::MsmEquations, 1);
        self.eq_coeff = Fr::random_nonzero(&mut self.rng);
        self.cur = self.scale * self.eq_coeff;
    }

    /// Defer `scalar·point` into the current equation.
    #[inline]
    pub fn push(&mut self, scalar: Fr, point: G1Affine) {
        self.scalars.push(self.cur * scalar);
        self.points.push(point);
    }

    /// Defer `scalar·point` for a projective point (normalized in bulk at
    /// flush time via Montgomery's trick).
    #[inline]
    pub fn push_proj(&mut self, scalar: Fr, point: &G1) {
        self.proj_scalars.push(self.cur * scalar);
        self.proj_points.push(*point);
    }

    /// Defer a fixed-base block Σᵢ scalars[i]·bases[i] (commitment-key
    /// slices, IPA bases). Blocks over an identical basis slice — every
    /// proof in a batch opening against the same keys — merge scalar-wise,
    /// so repeats cost field additions instead of duplicate MSM points.
    /// (Merging identical slices is always sound: Σ s·P + Σ s′·P =
    /// Σ (s+s′)·P regardless of which equations the terms came from.)
    pub fn push_fixed(&mut self, bases: &[G1Affine], scalars: &[Fr]) {
        self.push_fixed_inner(bases, scalars, None);
    }

    /// [`Self::push_fixed`] against a commitment key's basis prefix. When
    /// the key carries a warm [`FixedBaseTable`](super::fixed::FixedBaseTable)
    /// covering the prefix, the block is tagged with it and evaluated
    /// through the table at flush time — the one-MSM shape (a single
    /// [`msm`] per flush) is unchanged; the table result enters it as one
    /// projective term.
    pub fn push_fixed_key(&mut self, ck: &CommitKey, scalars: &[Fr]) {
        let table = ck
            .table_for(scalars.len())
            .map(|(_, off)| (ck.table_handle().clone(), off));
        self.push_fixed_inner(&ck.g[..scalars.len()], scalars, table);
    }

    fn push_fixed_inner(
        &mut self,
        bases: &[G1Affine],
        scalars: &[Fr],
        table: Option<(TableHandle, usize)>,
    ) {
        assert_eq!(bases.len(), scalars.len(), "accumulator block mismatch");
        if bases.is_empty() {
            return;
        }
        let cur = self.cur;
        let key = (bases.len(), bases[0].to_bytes());
        let found = self
            .block_index
            .get(&key)
            .and_then(|cands| cands.iter().copied().find(|&bi| self.blocks[bi].points == bases));
        match found {
            Some(bi) => {
                telemetry::count(Counter::MsmFixedBlocksMerged, 1);
                for (acc_s, s) in self.blocks[bi].scalars.iter_mut().zip(scalars.iter()) {
                    *acc_s += cur * *s;
                }
                if self.blocks[bi].table.is_none() {
                    self.blocks[bi].table = table;
                }
            }
            None => {
                telemetry::count(Counter::MsmFixedBlocksNew, 1);
                let bi = self.blocks.len();
                self.blocks.push(FixedBlock {
                    points: bases.to_vec(),
                    scalars: scalars.iter().map(|s| cur * *s).collect(),
                    table,
                });
                self.block_index.entry(key).or_default().push(bi);
            }
        }
    }

    fn run_msm(&mut self) {
        // Table-backed blocks first: each evaluates through its fixed-base
        // table into ONE projective term (normalized with the rest below);
        // untabled blocks feed the final MSM point-by-point as before.
        for blk in self.blocks.drain(..) {
            let evaluated = blk.table.as_ref().and_then(|(h, off)| {
                let t = h.get()?;
                (off + blk.points.len() <= t.len()).then(|| t.msm_range(*off, &blk.scalars))
            });
            match evaluated {
                Some(r) => {
                    self.proj_points.push(r);
                    self.proj_scalars.push(Fr::ONE);
                }
                None => {
                    self.points.extend(blk.points);
                    self.scalars.extend(blk.scalars);
                }
            }
        }
        self.block_index.clear();
        if !self.proj_points.is_empty() {
            let affine = G1::batch_to_affine(&self.proj_points);
            self.points.extend(affine);
            self.scalars.append(&mut self.proj_scalars);
            self.proj_points.clear();
        }
        let result = msm(&self.points, &self.scalars);
        self.ok &= result.is_identity();
        self.points.clear();
        self.scalars.clear();
        self.flushes += 1;
        telemetry::count(Counter::MsmFlushes, 1);
    }

    /// Decide every deferred equation with one Pippenger MSM. Returns true
    /// iff all of them hold (in eager mode: iff every per-equation MSM
    /// held). Resets the accumulator — terms, verdict, and scales — for
    /// reuse.
    pub fn flush(&mut self) -> bool {
        self.run_msm();
        let ok = self.ok;
        self.ok = true;
        self.scale = Fr::ONE;
        self.eq_coeff = Fr::ONE;
        self.cur = Fr::ONE;
        ok
    }

    /// Number of MSMs executed so far — verification-cost ground truth for
    /// the one-MSM-per-proof assertions in tests and benches.
    pub fn flushes(&self) -> usize {
        self.flushes
    }

    /// Number of equations begun so far.
    pub fn equations(&self) -> usize {
        self.equations
    }

    /// Deferred term count (loose affine + projective + merged fixed-base
    /// blocks) awaiting the next flush.
    pub fn pending_terms(&self) -> usize {
        self.points.len()
            + self.proj_points.len()
            + self.blocks.iter().map(|b| b.points.len()).sum::<usize>()
    }
}

impl Default for MsmAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(0xacc)
    }

    /// Push a random identity-summing equation: a·P + b·P − (a+b)·P.
    fn push_true_equation(acc: &mut MsmAccumulator, r: &mut Rng) {
        let p = G1::random(r).to_affine();
        let a = Fr::random(r);
        let b = Fr::random(r);
        acc.begin_equation();
        acc.push(a, p);
        acc.push(b, p);
        acc.push_proj(-(a + b), &p.to_projective());
    }

    #[test]
    fn accepts_true_equations() {
        let mut r = rng();
        let mut acc = MsmAccumulator::from_rng(&mut r);
        for _ in 0..5 {
            push_true_equation(&mut acc, &mut r);
        }
        assert_eq!(acc.flushes(), 0);
        assert!(acc.flush());
        assert_eq!(acc.flushes(), 1);
    }

    #[test]
    fn rejects_one_bad_equation_among_many() {
        let mut r = rng();
        let mut acc = MsmAccumulator::from_rng(&mut r);
        for _ in 0..3 {
            push_true_equation(&mut acc, &mut r);
        }
        acc.begin_equation();
        acc.push(Fr::ONE, G1::random(&mut r).to_affine());
        push_true_equation(&mut acc, &mut r);
        assert!(!acc.flush());
    }

    #[test]
    fn opposite_errors_do_not_cancel_across_equations() {
        // two equations whose raw term sums cancel (E and −E): without the
        // per-equation random coefficients one MSM over the union would
        // accept; with them it must reject.
        let mut r = rng();
        let p = G1::random(&mut r).to_affine();
        let mut acc = MsmAccumulator::from_rng(&mut r);
        acc.begin_equation();
        acc.push(Fr::ONE, p);
        acc.begin_equation();
        acc.push(-Fr::ONE, p);
        assert!(!acc.flush());
    }

    #[test]
    fn eager_mode_agrees_with_deferred() {
        for bad in [false, true] {
            let r = rng();
            let mut seed_a = Rng::seed_from_u64(1);
            let mut seed_b = Rng::seed_from_u64(2);
            let mut deferred = MsmAccumulator::from_rng(&mut seed_a);
            let mut eager = MsmAccumulator::eager_from_rng(&mut seed_b);
            for acc in [&mut deferred, &mut eager] {
                let mut rr = r.clone();
                for _ in 0..4 {
                    push_true_equation(acc, &mut rr);
                }
                if bad {
                    acc.begin_equation();
                    acc.push(Fr::from_u64(3), G1::random(&mut rr).to_affine());
                }
            }
            assert_eq!(deferred.flush(), eager.flush());
            assert_eq!(deferred.flushes(), 1);
            assert!(eager.flushes() > 1);
        }
    }

    #[test]
    fn fixed_base_blocks_merge_across_equations() {
        let mut r = rng();
        let bases: Vec<G1Affine> = (0..4).map(|_| G1::random(&mut r).to_affine()).collect();
        let mut acc = MsmAccumulator::from_rng(&mut r);
        // two equations over the same basis slice; each individually holds
        for _ in 0..2 {
            let s: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
            let sum = bases
                .iter()
                .zip(&s)
                .map(|(p, x)| p.to_projective().mul(x))
                .fold(G1::IDENTITY, |a, b| a + b);
            acc.begin_equation();
            acc.push_fixed(&bases, &s);
            acc.push_proj(-Fr::ONE, &sum);
        }
        // the basis is stored once despite two pushes (4 merged points +
        // 2 projective sum terms), and the merged check still accepts
        assert_eq!(acc.pending_terms(), 4 + 2);
        assert!(acc.flush());

        // a violated second equation over the same basis must still reject
        let mut acc2 = MsmAccumulator::from_rng(&mut r);
        let s: Vec<Fr> = (0..4).map(|_| Fr::random(&mut r)).collect();
        let sum = bases
            .iter()
            .zip(&s)
            .map(|(p, x)| p.to_projective().mul(x))
            .fold(G1::IDENTITY, |a, b| a + b);
        acc2.begin_equation();
        acc2.push_fixed(&bases, &s);
        acc2.push_proj(-Fr::ONE, &sum);
        acc2.begin_equation();
        acc2.push_fixed(&bases, &s); // same scalars, no cancelling term
        assert!(!acc2.flush());
    }

    #[test]
    fn table_backed_blocks_flush_identically() {
        let ck = CommitKey::setup(b"accumtable", 8);
        ck.warm_table();
        let mut r = rng();
        let s: Vec<Fr> = (0..8).map(|_| Fr::random(&mut r)).collect();
        let sum = ck.commit(&s, Fr::ZERO);
        let mut acc = MsmAccumulator::from_rng(&mut r);
        acc.begin_equation();
        acc.push_fixed_key(&ck, &s);
        acc.push_proj(-Fr::ONE, &sum);
        // table-backed blocks still report their points as pending work
        assert_eq!(acc.pending_terms(), 8 + 1);
        assert!(acc.flush());
        assert_eq!(acc.flushes(), 1);

        // a violated table-backed equation must still reject
        let mut acc2 = MsmAccumulator::from_rng(&mut r);
        acc2.begin_equation();
        acc2.push_fixed_key(&ck, &s);
        assert!(!acc2.flush());

        // and a cold key (no table) goes through the legacy block path
        // with the same verdicts
        let cold = CommitKey::setup(b"accumtable-cold", 8);
        let sum2 = cold.commit(&s, Fr::ZERO);
        let mut acc3 = MsmAccumulator::from_rng(&mut r);
        acc3.begin_equation();
        acc3.push_fixed_key(&cold, &s);
        acc3.push_proj(-Fr::ONE, &sum2);
        assert!(acc3.flush());
    }

    #[test]
    fn scale_preserves_validity_of_true_batches() {
        let mut r = rng();
        let mut acc = MsmAccumulator::from_rng(&mut r);
        for _ in 0..3 {
            let rho = Fr::random(&mut r);
            acc.set_scale(if rho.is_zero() { Fr::ONE } else { rho });
            push_true_equation(&mut acc, &mut r);
        }
        assert!(acc.flush());
    }

    #[test]
    fn empty_flush_is_vacuously_true() {
        let mut r = rng();
        let mut acc = MsmAccumulator::from_rng(&mut r);
        assert!(acc.flush());
        assert_eq!(acc.flushes(), 1);
    }
}
