//! # zkDL — Efficient Zero-Knowledge Proofs of Deep Learning Training
//!
//! A from-scratch reproduction of *zkDL* (Sun & Zhang, 2023): a prover that
//! convinces a verifier that one fixed-point SGD training step of an L-layer
//! ReLU fully-connected network was executed correctly over committed data,
//! weights and gradients — without revealing any of them — plus the paper's
//! Merkle-tree proof of training-data (non-)membership.
//!
//! Architecture (see DESIGN.md):
//! * crypto substrate: [`field`], [`curve`], [`hash`], [`transcript`],
//!   [`commit`], [`poly`], [`sumcheck`], [`ipa`]
//! * the paper's contribution: [`gkr`] (anchored layer proofs),
//!   [`zkrelu`] (auxiliary-input validity), [`zkdl`] (Protocol 2),
//!   [`aggregate`] (FAC4DNN multi-step trace aggregation),
//!   [`update`] (zkSGD weight-update chaining),
//!   [`provenance`] (zkData batch-provenance against a committed dataset),
//!   [`merkle`] (Appendix B), [`baseline`] (SC-BD comparator)
//! * the workload: [`model`] (fixed-point quantized network), [`witness`],
//!   [`data`]
//! * the runtime: [`runtime`] (PJRT AOT artifacts), [`coordinator`]
//!   (pipelined proving driver), [`wire`] (persisted proof artifacts),
//!   [`telemetry`] (zkObs spans + proof-system counters, `--profile`/bench),
//!   [`serve`] (zkServe batching verifier daemon + submit client)

pub mod aggregate;
pub mod baseline;
pub mod commit;
pub mod coordinator;
pub mod curve;
pub mod merkle;
pub mod data;
pub mod field;
pub mod gkr;
pub mod ipa;
pub mod model;
pub mod wire;
pub mod witness;
pub mod zkdl;
pub mod zkrelu;
pub mod hash;
pub mod poly;
pub mod provenance;
pub mod runtime;
pub mod serve;
pub mod sumcheck;
pub mod telemetry;
pub mod transcript;
pub mod update;
pub mod util;

pub use field::{Fq, Fr};
