//! Prime fields for the zkDL proof system.
//!
//! Two BN254 (alt-bn128) fields:
//! * [`Fr`] — the scalar field of G1, order r. All proof-system arithmetic
//!   (sumcheck, multilinear extensions, inner products, quantized tensors
//!   embedded as signed integers) lives here. This is the paper's 𝔽.
//! * [`Fq`] — the base field (point coordinates) used by `curve`.
//!
//! Representation: 4×u64 little-endian Montgomery form with R = 2²⁵⁶; all
//! Montgomery constants are derived from the modulus by `const fn`s in
//! [`limbs`], so the only magic numbers in this module are the two moduli
//! and the Fr two-adic generator used for testing.

pub mod limbs;

use core::fmt;
use core::hash::{Hash, Hasher};
use core::marker::PhantomData;
use core::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub, SubAssign};
use limbs::*;

/// Compile-time parameters of a 4-limb prime field.
pub trait FieldParams: 'static + Copy + Clone + Send + Sync + fmt::Debug + PartialEq + Eq {
    /// The prime modulus (little-endian limbs), odd, < 2²⁵⁵.
    const MODULUS: [u64; 4];
    /// −MODULUS⁻¹ mod 2⁶⁴ (derived).
    const NINV: u64 = mont_ninv(Self::MODULUS[0]);
    /// R mod MODULUS (Montgomery form of 1).
    const R: [u64; 4] = mont_r(&Self::MODULUS);
    /// R² mod MODULUS.
    const R2: [u64; 4] = mont_r2(&Self::MODULUS);
    /// R³ mod MODULUS.
    const R3: [u64; 4] = mont_r3(&Self::MODULUS, mont_ninv(Self::MODULUS[0]));
    /// MODULUS − 2 (Fermat inversion exponent).
    const MOD_MINUS_2: [u64; 4] = sub2(&Self::MODULUS);
    /// (MODULUS+1)/4, the sqrt exponent when MODULUS ≡ 3 (mod 4).
    const SQRT_EXP: [u64; 4] = plus1_div4(&Self::MODULUS);
}

/// BN254 scalar field parameters (order of G1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrParams;
impl FieldParams for FrParams {
    // r = 21888242871839275222246405745257275088548364400416034343698204186575808495617
    const MODULUS: [u64; 4] = [
        0x43e1f593f0000001,
        0x2833e84879b97091,
        0xb85045b68181585d,
        0x30644e72e131a029,
    ];
}

/// BN254 base field parameters (coordinates of G1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FqParams;
impl FieldParams for FqParams {
    // q = 21888242871839275222246405745257275088696311157297823662689037894645226208583
    const MODULUS: [u64; 4] = [
        0x3c208c16d87cfd47,
        0x97816a916871ca8d,
        0xb85045b68181585d,
        0x30644e72e131a029,
    ];
}

/// An element of the prime field defined by `P`, in Montgomery form.
pub struct Fp<P: FieldParams>(pub(crate) [u64; 4], PhantomData<P>);

/// The zkDL proof field 𝔽 (BN254 scalar field).
pub type Fr = Fp<FrParams>;
/// The curve coordinate field.
pub type Fq = Fp<FqParams>;

impl<P: FieldParams> Clone for Fp<P> {
    #[inline(always)]
    fn clone(&self) -> Self {
        *self
    }
}
impl<P: FieldParams> Copy for Fp<P> {}
impl<P: FieldParams> PartialEq for Fp<P> {
    #[inline(always)]
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0
    }
}
impl<P: FieldParams> Eq for Fp<P> {}
impl<P: FieldParams> Hash for Fp<P> {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.0.hash(state)
    }
}
impl<P: FieldParams> Default for Fp<P> {
    #[inline(always)]
    fn default() -> Self {
        Self::ZERO
    }
}
impl<P: FieldParams> fmt::Debug for Fp<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let r = self.to_repr();
        write!(f, "0x{:016x}{:016x}{:016x}{:016x}", r[3], r[2], r[1], r[0])
    }
}
impl<P: FieldParams> fmt::Display for Fp<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl<P: FieldParams> Fp<P> {
    pub const ZERO: Self = Self([0; 4], PhantomData);
    pub const ONE: Self = Self(P::R, PhantomData);

    /// From raw Montgomery limbs (internal).
    #[allow(dead_code)]
    #[inline(always)]
    pub(crate) const fn from_mont(limbs: [u64; 4]) -> Self {
        Self(limbs, PhantomData)
    }

    /// Canonical (non-Montgomery) little-endian limbs.
    #[inline]
    pub fn to_repr(&self) -> [u64; 4] {
        mont_mul(&self.0, &[1, 0, 0, 0], &P::MODULUS, P::NINV)
    }

    /// From canonical limbs; values ≥ modulus are reduced.
    #[inline]
    pub fn from_repr(mut v: [u64; 4]) -> Self {
        if !lt(&v, &P::MODULUS) {
            let (r, _) = sub4(&v, &P::MODULUS);
            v = r;
        }
        Self(mont_mul(&v, &P::R2, &P::MODULUS, P::NINV), PhantomData)
    }

    #[inline]
    pub fn from_u64(v: u64) -> Self {
        Self::from_repr([v, 0, 0, 0])
    }

    #[inline]
    pub fn from_u128(v: u128) -> Self {
        Self::from_repr([v as u64, (v >> 64) as u64, 0, 0])
    }

    /// Signed-integer embedding: negative values map to modulus − |v|.
    #[inline]
    pub fn from_i64(v: i64) -> Self {
        if v >= 0 {
            Self::from_u64(v as u64)
        } else {
            -Self::from_u64(v.unsigned_abs())
        }
    }

    /// Signed 128-bit embedding.
    #[inline]
    pub fn from_i128(v: i128) -> Self {
        if v >= 0 {
            Self::from_u128(v as u128)
        } else {
            -Self::from_u128(v.unsigned_abs())
        }
    }

    /// Interpret a canonical element as a signed integer if it is small
    /// (|v| < 2¹²⁷); used to pull quantized tensor values back out of 𝔽.
    pub fn to_i128(&self) -> Option<i128> {
        let r = self.to_repr();
        if r[2] == 0 && r[3] == 0 && r[1] >> 63 == 0 {
            return Some(((r[1] as u128) << 64 | r[0] as u128) as i128);
        }
        let neg = (-*self).to_repr();
        if neg[2] == 0 && neg[3] == 0 && neg[1] >> 63 == 0 {
            return Some(-(((neg[1] as u128) << 64 | neg[0] as u128) as i128));
        }
        None
    }

    /// Reduce 64 bytes (little-endian) mod p — uniform field sampling from a
    /// hash output: v = hi·2²⁵⁶ + lo ⇒ mont(lo,R²) + mont(hi,R³).
    pub fn from_bytes_wide(bytes: &[u8; 64]) -> Self {
        let mut lo = [0u64; 4];
        let mut hi = [0u64; 4];
        for i in 0..4 {
            lo[i] = u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap());
            hi[i] = u64::from_le_bytes(bytes[32 + i * 8..40 + i * 8].try_into().unwrap());
        }
        let lo_m = mont_mul(&lo, &P::R2, &P::MODULUS, P::NINV);
        let hi_m = mont_mul(&hi, &P::R3, &P::MODULUS, P::NINV);
        Self(add_mod(&lo_m, &hi_m, &P::MODULUS), PhantomData)
    }

    /// Canonical 32-byte little-endian encoding.
    pub fn to_bytes(&self) -> [u8; 32] {
        let r = self.to_repr();
        let mut out = [0u8; 32];
        for i in 0..4 {
            out[i * 8..i * 8 + 8].copy_from_slice(&r[i].to_le_bytes());
        }
        out
    }

    /// Parse canonical 32-byte little-endian encoding (reduces if needed).
    pub fn from_bytes(bytes: &[u8; 32]) -> Self {
        let mut v = [0u64; 4];
        for i in 0..4 {
            v[i] = u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap());
        }
        Self::from_repr(v)
    }

    #[inline(always)]
    pub fn is_zero(&self) -> bool {
        is_zero(&self.0)
    }

    #[inline(always)]
    pub fn double(&self) -> Self {
        Self(double_mod(&self.0, &P::MODULUS), PhantomData)
    }

    #[inline(always)]
    pub fn square(&self) -> Self {
        Self(mont_mul(&self.0, &self.0, &P::MODULUS, P::NINV), PhantomData)
    }

    /// Exponentiation by a 4-limb little-endian exponent.
    pub fn pow(&self, exp: &[u64; 4]) -> Self {
        let mut acc = Self::ONE;
        let mut started = false;
        for i in (0..4).rev() {
            for b in (0..64).rev() {
                if started {
                    acc = acc.square();
                }
                if (exp[i] >> b) & 1 == 1 {
                    acc *= *self;
                    started = true;
                }
            }
        }
        acc
    }

    /// Multiplicative inverse (Fermat). Returns None for zero.
    pub fn inverse(&self) -> Option<Self> {
        if self.is_zero() {
            None
        } else {
            Some(self.pow(&P::MOD_MINUS_2))
        }
    }

    /// Square root when MODULUS ≡ 3 (mod 4) (true for both BN254 fields).
    /// Returns None if `self` is a non-residue.
    pub fn sqrt(&self) -> Option<Self> {
        let s = self.pow(&P::SQRT_EXP);
        if s.square() == *self {
            Some(s)
        } else {
            None
        }
    }

    /// Uniform random element from a PRNG.
    pub fn random(rng: &mut crate::util::rng::Rng) -> Self {
        let mut b = [0u8; 64];
        rng.fill_bytes(&mut b);
        Self::from_bytes_wide(&b)
    }

    /// Uniform random *nonzero* element (rejection sampling; one retry per
    /// ~2^−254 draws) — the batching/scaling coefficients of the deferred
    /// verification engine must never be zero.
    pub fn random_nonzero(rng: &mut crate::util::rng::Rng) -> Self {
        loop {
            let v = Self::random(rng);
            if !v.is_zero() {
                return v;
            }
        }
    }

    /// Batch inversion (Montgomery's trick): inverts all non-zero entries in
    /// place with one field inversion + 3n multiplications.
    pub fn batch_invert(values: &mut [Self]) {
        let mut prods = Vec::with_capacity(values.len());
        let mut acc = Self::ONE;
        for v in values.iter() {
            prods.push(acc);
            if !v.is_zero() {
                acc *= *v;
            }
        }
        let mut inv = acc.inverse().expect("product of non-zero elements");
        for (v, p) in values.iter_mut().zip(prods.into_iter()).rev() {
            if !v.is_zero() {
                let new_v = inv * p;
                inv *= *v;
                *v = new_v;
            }
        }
    }
}

impl<P: FieldParams> Add for Fp<P> {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        Self(add_mod(&self.0, &rhs.0, &P::MODULUS), PhantomData)
    }
}
impl<P: FieldParams> Sub for Fp<P> {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        Self(sub_mod(&self.0, &rhs.0, &P::MODULUS), PhantomData)
    }
}
impl<P: FieldParams> Mul for Fp<P> {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        Self(mont_mul(&self.0, &rhs.0, &P::MODULUS, P::NINV), PhantomData)
    }
}
impl<P: FieldParams> Neg for Fp<P> {
    type Output = Self;
    #[inline(always)]
    fn neg(self) -> Self {
        Self(neg_mod(&self.0, &P::MODULUS), PhantomData)
    }
}
impl<P: FieldParams> AddAssign for Fp<P> {
    #[inline(always)]
    fn add_assign(&mut self, rhs: Self) {
        *self = *self + rhs;
    }
}
impl<P: FieldParams> SubAssign for Fp<P> {
    #[inline(always)]
    fn sub_assign(&mut self, rhs: Self) {
        *self = *self - rhs;
    }
}
impl<P: FieldParams> MulAssign for Fp<P> {
    #[inline(always)]
    fn mul_assign(&mut self, rhs: Self) {
        *self = *self * rhs;
    }
}
impl<P: FieldParams> core::iter::Sum for Fp<P> {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(Self::ZERO, |a, b| a + b)
    }
}
impl<'a, P: FieldParams> core::iter::Sum<&'a Fp<P>> for Fp<P> {
    fn sum<I: Iterator<Item = &'a Self>>(iter: I) -> Self {
        iter.fold(Self::ZERO, |a, b| a + *b)
    }
}
impl<P: FieldParams> core::iter::Product for Fp<P> {
    fn product<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(Self::ONE, |a, b| a * b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rng() -> Rng {
        Rng::seed_from_u64(0x5eed)
    }

    #[test]
    fn constants_consistent() {
        // R derived by doubling matches Montgomery form of 1
        assert_eq!(Fr::ONE.to_repr(), [1, 0, 0, 0]);
        assert_eq!(Fq::ONE.to_repr(), [1, 0, 0, 0]);
        // NINV * MODULUS ≡ −1 mod 2⁶⁴
        assert_eq!(
            FrParams::MODULUS[0].wrapping_mul(FrParams::NINV),
            u64::MAX
        );
        assert_eq!(
            FqParams::MODULUS[0].wrapping_mul(FqParams::NINV),
            u64::MAX
        );
    }

    #[test]
    fn field_axioms_random() {
        let mut r = rng();
        for _ in 0..200 {
            let a = Fr::random(&mut r);
            let b = Fr::random(&mut r);
            let c = Fr::random(&mut r);
            assert_eq!(a + b, b + a);
            assert_eq!(a * b, b * a);
            assert_eq!((a + b) + c, a + (b + c));
            assert_eq!((a * b) * c, a * (b * c));
            assert_eq!(a * (b + c), a * b + a * c);
            assert_eq!(a - a, Fr::ZERO);
            assert_eq!(a + (-a), Fr::ZERO);
            assert_eq!(a * Fr::ONE, a);
            assert_eq!(a.double(), a + a);
            assert_eq!(a.square(), a * a);
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let mut r = rng();
        for _ in 0..50 {
            let a = Fr::random(&mut r);
            if a.is_zero() {
                continue;
            }
            assert_eq!(a * a.inverse().unwrap(), Fr::ONE);
        }
        assert!(Fr::ZERO.inverse().is_none());
    }

    #[test]
    fn batch_invert_matches() {
        let mut r = rng();
        let vals: Vec<Fr> = (0..33).map(|i| if i == 7 { Fr::ZERO } else { Fr::random(&mut r) }).collect();
        let mut batch = vals.clone();
        Fr::batch_invert(&mut batch);
        for (v, b) in vals.iter().zip(batch.iter()) {
            if v.is_zero() {
                assert!(b.is_zero());
            } else {
                assert_eq!(*v * *b, Fr::ONE);
            }
        }
    }

    #[test]
    fn sqrt_fq() {
        let mut r = rng();
        let mut found = 0;
        for _ in 0..32 {
            let a = Fq::random(&mut r);
            let sq = a.square();
            let s = sq.sqrt().expect("square must have a root");
            assert!(s == a || s == -a);
            if a.sqrt().is_some() {
                found += 1;
            }
        }
        // roughly half the elements are residues
        assert!(found > 4 && found < 29, "found={found}");
    }

    #[test]
    fn signed_embedding() {
        for v in [-5i64, -1, 0, 1, 7, i64::MAX, i64::MIN + 1] {
            let f = Fr::from_i64(v);
            assert_eq!(f.to_i128(), Some(v as i128), "v={v}");
        }
        assert_eq!(Fr::from_i64(-3) + Fr::from_i64(5), Fr::from_u64(2));
        assert_eq!(
            Fr::from_i128(-(1i128 << 100)).to_i128(),
            Some(-(1i128 << 100))
        );
    }

    #[test]
    fn bytes_roundtrip() {
        let mut r = rng();
        for _ in 0..20 {
            let a = Fr::random(&mut r);
            assert_eq!(Fr::from_bytes(&a.to_bytes()), a);
        }
    }

    #[test]
    fn mont_mul_vs_u128_reference() {
        // cross-check Montgomery multiplication against schoolbook
        // multiply-then-reduce on random small-limb values
        let mut r = rng();
        for _ in 0..100 {
            let a = (r.next_u64() % 1000) as u64;
            let b = (r.next_u64() % 1000) as u64;
            let fa = Fr::from_u64(a);
            let fb = Fr::from_u64(b);
            assert_eq!((fa * fb).to_repr(), [(a as u128 * b as u128) as u64, 0, 0, 0]);
        }
    }

    #[test]
    fn pow_small() {
        let a = Fr::from_u64(3);
        assert_eq!(a.pow(&[5, 0, 0, 0]), Fr::from_u64(243));
        assert_eq!(a.pow(&[0, 0, 0, 0]), Fr::ONE);
    }

    #[test]
    fn fermat_little() {
        // a^(r-1) = 1
        let mut r = rng();
        let a = Fr::random(&mut r);
        let exp = limbs::add4(&FrParams::MOD_MINUS_2, &[1, 0, 0, 0]).0;
        assert_eq!(a.pow(&exp), Fr::ONE);
    }
}
